#!/usr/bin/env python3
"""String/comment-aware brace/paren/bracket balance over all .rs files.

Crude syntax sanity for containers without a Rust toolchain (see
.claude/skills/verify/SKILL.md): catches gross slips — an unclosed
brace, a stray delimiter in merged code — not real parsing. Exit 1 on
any imbalance.
"""
import sys
from pathlib import Path

OPEN = {"{": "}", "(": ")", "[": "]"}
CLOSE = {v: k for k, v in OPEN.items()}


def check(path: Path) -> list[str]:
    src = path.read_text(encoding="utf-8")
    stack: list[tuple[str, int]] = []
    errs: list[str] = []
    i, n, line = 0, len(src), 1
    state = "code"  # code | line_comment | block_comment | str | char | raw_str
    block_depth = 0
    raw_hashes = 0
    while i < n:
        c = src[i]
        nxt = src[i + 1] if i + 1 < n else ""
        if c == "\n":
            line += 1
            if state == "line_comment":
                state = "code"
            i += 1
            continue
        if state == "line_comment":
            i += 1
            continue
        if state == "block_comment":
            if c == "/" and nxt == "*":
                block_depth += 1
                i += 2
                continue
            if c == "*" and nxt == "/":
                block_depth -= 1
                if block_depth == 0:
                    state = "code"
                i += 2
                continue
            i += 1
            continue
        if state == "str":
            if c == "\\":
                i += 2
                continue
            if c == '"':
                state = "code"
            i += 1
            continue
        if state == "raw_str":
            if c == '"' and src[i + 1 : i + 1 + raw_hashes] == "#" * raw_hashes:
                state = "code"
                i += 1 + raw_hashes
                continue
            i += 1
            continue
        if state == "char":
            if c == "\\":
                i += 2
                continue
            if c == "'":
                state = "code"
            i += 1
            continue
        # state == code
        if c == "/" and nxt == "/":
            state = "line_comment"
            i += 2
            continue
        if c == "/" and nxt == "*":
            state = "block_comment"
            block_depth = 1
            i += 2
            continue
        if c == "r" and (nxt == '"' or nxt == "#"):
            j = i + 1
            hashes = 0
            while j < n and src[j] == "#":
                hashes += 1
                j += 1
            if j < n and src[j] == '"':
                state = "raw_str"
                raw_hashes = hashes
                i = j + 1
                continue
        if c == "b" and nxt == '"':
            state = "str"
            i += 2
            continue
        if c == '"':
            state = "str"
            i += 1
            continue
        if c == "'":
            # lifetime ('a) vs char literal: a char literal closes with '
            # within a few chars; lifetimes are followed by ident chars and
            # no closing quote. Handle escapes ('\n') and plain ('x').
            if nxt == "\\":
                state = "char"
                i += 1  # step past the quote only; char state eats the escape
                continue
            if i + 2 < n and src[i + 2] == "'":
                i += 3
                continue
            i += 1  # lifetime or label: skip the quote, idents are harmless
            continue
        if c in OPEN:
            stack.append((c, line))
            i += 1
            continue
        if c in CLOSE:
            if not stack or stack[-1][0] != CLOSE[c]:
                errs.append(f"{path}:{line}: unmatched `{c}`")
                if stack:
                    stack.pop()
            else:
                stack.pop()
            i += 1
            continue
        i += 1
    for d, ln in stack:
        errs.append(f"{path}:{ln}: unclosed `{d}`")
    return errs


def main() -> int:
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(".")
    files = sorted(p for p in root.rglob("*.rs") if "target" not in p.parts)
    bad = 0
    for f in files:
        for e in check(f):
            print(e)
            bad += 1
    print(f"[check_balance] {len(files)} files, {bad} problems")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
