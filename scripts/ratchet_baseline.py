#!/usr/bin/env python3
"""Ratchet ci/bench_baseline.json floors from measured bench artifacts.

Usage:
    scripts/ratchet_baseline.py [--native BENCH_native.json]
                                [--analog BENCH_analog.json]
                                [--fraction 0.5] [--dry-run]

Downloads of the CI bench artifacts (bench-smoke uploads BENCH_native.json,
analog-smoke BENCH_analog.json, wire-smoke the wire section inside
BENCH_native.json) feed the committed smoke floors:

    req_s        <- fraction * BENCH_native.json req_s
    analog_req_s <- fraction * BENCH_analog.json req_s
    wire_req_s   <- fraction * BENCH_native.json wire.req_s

Each ratcheted key is marked `measured: true` in the baseline's `measured`
map so readers can tell a real ratchet from a hand-picked smoke value.
Floors only move up (a measured value below the committed floor is
reported, not applied) unless --allow-lower is given. The gate in
bench::check_regression allows a 30% drop below the floor, so fraction 0.5
leaves ~2x headroom between a typical run and a failure.
"""
import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
BASELINE = REPO / "ci" / "bench_baseline.json"


def load(path):
    with open(path, encoding="utf-8") as f:
        return json.load(f)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--native", help="BENCH_native.json artifact "
                    "(ratchets req_s and, if a wire section is present, "
                    "wire_req_s)")
    ap.add_argument("--analog", help="BENCH_analog.json artifact "
                    "(ratchets analog_req_s)")
    ap.add_argument("--fraction", type=float, default=0.5,
                    help="floor = fraction * measured req/s (default 0.5)")
    ap.add_argument("--allow-lower", action="store_true",
                    help="let a ratchet lower an existing floor")
    ap.add_argument("--dry-run", action="store_true",
                    help="print the would-be baseline, write nothing")
    args = ap.parse_args()
    if not args.native and not args.analog:
        ap.error("give at least one of --native / --analog")
    if not 0.0 < args.fraction <= 1.0:
        ap.error("--fraction must be in (0, 1]")

    base = load(BASELINE)
    measured = base.setdefault("measured", {})
    updates = []  # (key, measured req/s)
    if args.native:
        native = load(args.native)
        updates.append(("req_s", float(native["req_s"])))
        if "wire" in native:
            updates.append(("wire_req_s", float(native["wire"]["req_s"])))
    if args.analog:
        updates.append(("analog_req_s", float(load(args.analog)["req_s"])))

    changed = False
    for key, value in updates:
        floor = round(args.fraction * value, 1)
        old = base.get(key)
        if old is not None and floor < old and not args.allow_lower:
            print(f"  {key}: measured {value:.1f} -> floor {floor} is BELOW "
                  f"the committed {old}; skipping (use --allow-lower to "
                  "accept a regression as the new normal)")
            continue
        print(f"  {key}: {old} -> {floor}  (measured {value:.1f}, "
              f"fraction {args.fraction})")
        base[key] = floor
        measured[key] = True
        changed = True

    if not changed:
        print("nothing to ratchet")
        return 0
    text = json.dumps(base, indent=2) + "\n"
    if args.dry_run:
        sys.stdout.write(text)
    else:
        BASELINE.write_text(text, encoding="utf-8")
        print(f"wrote {BASELINE}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
