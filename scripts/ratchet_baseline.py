#!/usr/bin/env python3
"""Ratchet ci/bench_baseline.json floors from measured bench artifacts.

Usage:
    scripts/ratchet_baseline.py [--native BENCH_native.json]
                                [--analog BENCH_analog.json]
                                [--fraction 0.5] [--dry-run]
                                [--check] [--out PATH]

CI mode (`--check`): never touches the committed baseline. Instead it
computes the would-be ratcheted baseline from the given artifacts and
writes it to --out (default bench_baseline.proposed.json next to the
artifact inputs' working directory) so the smoke jobs can upload it as an
artifact; a maintainer who wants to ratchet copies the proposed file over
ci/bench_baseline.json (or re-runs this script without --check on the
downloaded artifacts). --check is tolerant of partial artifacts — the
wire-smoke BENCH_native.json has only a `wire` section and no top-level
`req_s`, so missing keys are skipped, not errors — and always exits 0 on
well-formed inputs: regressions are the bench gates' job, not this
report's.

Downloads of the CI bench artifacts (bench-smoke uploads BENCH_native.json,
analog-smoke BENCH_analog.json, wire-smoke the wire section inside
BENCH_native.json) feed the committed smoke floors:

    req_s        <- fraction * BENCH_native.json req_s
    analog_req_s <- fraction * BENCH_analog.json req_s
    wire_req_s   <- fraction * BENCH_native.json wire.req_s
    kws_req_s    <- fraction * BENCH_native.json multi.kws_req_s
    vww_req_s    <- fraction * BENCH_native.json multi.vww_req_s

Each ratcheted key is marked `measured: true` in the baseline's `measured`
map so readers can tell a real ratchet from a hand-picked smoke value.
Floors only move up (a measured value below the committed floor is
reported, not applied) unless --allow-lower is given. The gate in
bench::check_regression allows a 30% drop below the floor, so fraction 0.5
leaves ~2x headroom between a typical run and a failure.

`fault_acc_gap_max` and `energy_tol_rel` are inverted gates: upper bounds,
so their ratchet direction flips — they only move DOWN (tighten), and
--allow-lower is what permits loosening them. A measured BENCH_analog.json
fault_sweep.mild_gap_max sets fault_acc_gap_max to max(0.02, 2 * measured)
(2x headroom: the accuracy sweep is sampling-noisy at 64 samples); a
measured energy.max_rel_dev sets energy_tol_rel to max(0.05, 1.1 *
measured) (1.1x headroom is enough because the modeled-energy deviation is
pure arithmetic, identical on every host).
"""
import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
BASELINE = REPO / "ci" / "bench_baseline.json"


def load(path):
    with open(path, encoding="utf-8") as f:
        return json.load(f)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--native", help="BENCH_native.json artifact "
                    "(ratchets req_s and, if a wire section is present, "
                    "wire_req_s)")
    ap.add_argument("--analog", help="BENCH_analog.json artifact "
                    "(ratchets analog_req_s)")
    ap.add_argument("--fraction", type=float, default=0.5,
                    help="floor = fraction * measured req/s (default 0.5)")
    ap.add_argument("--allow-lower", action="store_true",
                    help="let a ratchet lower an existing floor")
    ap.add_argument("--dry-run", action="store_true",
                    help="print the would-be baseline, write nothing")
    ap.add_argument("--check", action="store_true",
                    help="CI mode: tolerate partial artifacts, never write "
                    "the committed baseline, emit the proposal to --out")
    ap.add_argument("--out", default="bench_baseline.proposed.json",
                    help="where --check writes the proposed baseline "
                    "(default bench_baseline.proposed.json)")
    args = ap.parse_args()
    if not args.native and not args.analog:
        ap.error("give at least one of --native / --analog")
    if not 0.0 < args.fraction <= 1.0:
        ap.error("--fraction must be in (0, 1]")

    base = load(BASELINE)
    measured = base.setdefault("measured", {})

    def pick(obj, *keys):
        """Walk nested keys; in --check mode a miss is None, else KeyError."""
        for key in keys:
            if args.check and (not isinstance(obj, dict) or key not in obj):
                return None
            obj = obj[key]
        return float(obj)

    updates = []  # (key, measured req/s)
    if args.native:
        native = load(args.native)
        updates.append(("req_s", pick(native, "req_s")))
        if "wire" in native:
            updates.append(("wire_req_s", pick(native, "wire", "req_s")))
        if "multi" in native:
            updates.append(("kws_req_s", pick(native, "multi", "kws_req_s")))
            updates.append(("vww_req_s", pick(native, "multi", "vww_req_s")))
    # inverted (upper-bound) gates: (key, measured, floor, headroom factor)
    gap_updates = []
    if args.analog:
        analog = load(args.analog)
        updates.append(("analog_req_s", pick(analog, "req_s")))
        if "fault_sweep" in analog:
            gap_updates.append(
                ("fault_acc_gap_max",
                 pick(analog, "fault_sweep", "mild_gap_max"), 0.02, 2.0))
        if "energy" in analog:
            gap_updates.append(
                ("energy_tol_rel",
                 pick(analog, "energy", "max_rel_dev"), 0.05, 1.1))
    updates = [(k, v) for k, v in updates if v is not None]
    gap_updates = [u for u in gap_updates if u[1] is not None]

    changed = False
    for key, value in updates:
        floor = round(args.fraction * value, 1)
        old = base.get(key)
        if old is not None and floor < old and not args.allow_lower:
            print(f"  {key}: measured {value:.1f} -> floor {floor} is BELOW "
                  f"the committed {old}; skipping (use --allow-lower to "
                  "accept a regression as the new normal)")
            continue
        print(f"  {key}: {old} -> {floor}  (measured {value:.1f}, "
              f"fraction {args.fraction})")
        base[key] = floor
        measured[key] = True
        changed = True

    for key, value, lo, factor in gap_updates:
        # upper-bound gate: headroom-scaled measured value (floored at `lo`
        # so a perfect run does not ratchet to zero and fail on the next
        # run's noise), tightening only
        bound = round(max(lo, factor * value), 4)
        old = base.get(key)
        if old is not None and bound > old and not args.allow_lower:
            print(f"  {key}: measured {value:.4f} -> bound {bound} is "
                  f"LOOSER than the committed {old}; skipping (use "
                  "--allow-lower to accept a regression as the new normal)")
            continue
        print(f"  {key}: {old} -> {bound}  (measured {value:.4f}, "
              f"bound = max({lo}, {factor}x))")
        base[key] = bound
        measured[key] = True
        changed = True

    text = json.dumps(base, indent=2) + "\n"
    if args.check:
        # always emit the proposal (unchanged == floors already current) so
        # the CI artifact exists on every run; the committed file is never
        # written from CI
        out = Path(args.out)
        out.write_text(text, encoding="utf-8")
        state = "ratchet available" if changed else "floors already current"
        print(f"wrote proposed baseline to {out} ({state})")
        return 0
    if not changed:
        print("nothing to ratchet")
        return 0
    if args.dry_run:
        sys.stdout.write(text)
    else:
        BASELINE.write_text(text, encoding="utf-8")
        print(f"wrote {BASELINE}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
