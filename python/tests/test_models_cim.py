"""Model architecture and CiM forward-graph tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import cim, layers as L
from compile.config import ARRAY_COLS, ARRAY_ROWS
from compile.models import get_model

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("name,classes", [
    ("analognet_kws", 12),
    ("analognet_vww", 2),
    ("analognet_vww_bottleneck", 2),
    ("micronet_kws_s", 12),
])
def test_forward_shapes(name, classes):
    model = get_model(name)
    key = jax.random.PRNGKey(0)
    params = L.init_params(model, key)
    state = L.init_state(model)
    h, w, c = model.input_hwc
    x = jnp.zeros((2, h, w, c))
    logits, st = cim.forward(model, params, state, x, train=False)
    assert logits.shape == (2, classes)
    assert len(st) == len(model.layers)


@pytest.mark.parametrize("name", ["analognet_kws", "analognet_vww"])
def test_analognets_fit_array_unsplit(name):
    """Section 6.2: 'configured with a 1024x512 CiM array, such that no
    layers are split' — every AnalogNet layer must fit whole."""
    model = get_model(name)
    total = 0
    for l in model.layers:
        assert l.k <= ARRAY_ROWS, f"{l.name} is too tall ({l.k})"
        assert l.out_ch <= ARRAY_COLS, f"{l.name} is too wide"
        total += l.k * l.out_ch
    # and the whole model fits the array at once (layer-serial, Figure 6)
    assert total <= ARRAY_ROWS * ARRAY_COLS
    # utilization in the paper's reported ballpark (57.3% / 67.5%)
    util = total / (ARRAY_ROWS * ARRAY_COLS)
    assert 0.5 < util < 0.75, f"utilization {util:.3f}"


def test_analognets_have_no_depthwise():
    for name in ("analognet_kws", "analognet_vww"):
        model = get_model(name)
        assert all(l.kind != "dw3x3" for l in model.layers)


def test_micronet_has_depthwise():
    model = get_model("micronet_kws_s")
    assert any(l.kind == "dw3x3" for l in model.layers)


def test_bottleneck_variant_has_narrow_layer():
    m = get_model("analognet_vww_bottleneck")
    widths = [l.out_ch for l in m.layers]
    assert min(widths) <= 8


def test_patches3x3_matches_lax_conv():
    """im2col + GEMM must equal XLA's native convolution."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 9, 7, 3)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((9 * 3, 5)).astype(np.float32))
    for stride in [(1, 1), (2, 2), (2, 1)]:
        p = L.patches3x3(x, stride)
        got = p.reshape(-1, 27) @ w
        ho, wo = p.shape[1], p.shape[2]
        got = got.reshape(2, ho, wo, 5)
        # reference: lax conv with (ky, kx, c) filter layout, pad=1
        wk = w.reshape(3, 3, 3, 5)
        want = jax.lax.conv_general_dilated(
            x, wk, window_strides=stride, padding=[(1, 1), (1, 1)],
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        want = want[:, :ho, :wo, :]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_dw_dense_expansion_equivalence():
    """Dense-expanded depthwise GEMM == compact einsum path."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, 6, 6, 4)).astype(np.float32))
    w9c = jnp.asarray(rng.standard_normal((9, 4)).astype(np.float32))
    compact = L.apply_dw_compact(x, w9c, (1, 1))
    dense = L.dw_dense_weight(w9c)
    p = L.patches3x3(x, (1, 1)).reshape(-1, 36)
    got = (p @ dense).reshape(2, 6, 6, 4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(compact),
                               rtol=1e-4, atol=1e-4)


def test_quantized_forward_changes_logits_at_low_bits():
    model = get_model("analognet_kws")
    key = jax.random.PRNGKey(0)
    params = L.init_params(model, key)
    state = L.init_state(model)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 49, 10, 1))
    clips = [(jnp.asarray(-0.3), jnp.asarray(0.3))] * len(model.layers)
    ranges = {"s": jnp.asarray(0.2),
              "r_adc": jnp.ones((len(model.layers),)) * 4.0}
    fp, _ = cim.forward(model, params, state, x, train=False, clips=clips)
    q4, _ = cim.forward(model, params, state, x, train=False, clips=clips,
                        ranges=ranges, adc_bits=4)
    assert not np.allclose(np.asarray(fp), np.asarray(q4))


def test_bn_fold_matches_bn_apply():
    rng = np.random.default_rng(2)
    y = jnp.asarray(rng.standard_normal((10, 4)).astype(np.float32))
    gamma = np.abs(rng.standard_normal(4)).astype(np.float32) + 0.5
    beta = rng.standard_normal(4).astype(np.float32)
    mean = rng.standard_normal(4).astype(np.float32)
    var = np.abs(rng.standard_normal(4)).astype(np.float32) + 0.5
    want = L.bn_apply(y, jnp.asarray(gamma), jnp.asarray(beta),
                      jnp.asarray(mean), jnp.asarray(var))
    scale, bias = L.bn_fold(gamma, beta, mean, var)
    got = np.asarray(y) * scale + bias
    np.testing.assert_allclose(got, np.asarray(want), rtol=1e-4, atol=1e-5)
