"""Quantizer (eq. 3-5) unit and property tests."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from compile import quantizers as Q
from compile.config import dac_bits

jax.config.update("jax_platform_name", "cpu")


def test_dac_one_more_bit_than_adc():
    assert dac_bits(8) == 9
    assert dac_bits(4) == 5


@hypothesis.given(r=st.floats(0.1, 100.0), bits=st.sampled_from([4, 6, 8]),
                  seed=st.integers(0, 1000))
@hypothesis.settings(max_examples=30, deadline=None)
def test_fake_quant_error_bound(r, bits, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-r, r, 100).astype(np.float32))
    q = Q.fake_quant(x, jnp.asarray(r), bits)
    step = r / (2 ** (bits - 1) - 1)
    assert float(jnp.max(jnp.abs(q - x))) <= step / 2 + 1e-5


def test_fake_quant_grid_fixed_points():
    r, bits = 2.0, 5
    step = r / (2 ** (bits - 1) - 1)
    grid = jnp.arange(-15, 16) * step
    np.testing.assert_allclose(np.asarray(Q.fake_quant(grid, r, bits)),
                               np.asarray(grid), atol=1e-6)


def test_fake_quant_gradients_flow():
    # STE: d/dx inside range ~ 1, outside ~ 0; differentiable in r too
    f = lambda x, r: jnp.sum(Q.fake_quant(x, r, 8))
    gx = jax.grad(f, argnums=0)(jnp.asarray([0.3, 5.0]), jnp.asarray(1.0))
    assert float(gx[0]) == 1.0 and float(gx[1]) == 0.0
    gr = jax.grad(f, argnums=1)(jnp.asarray([0.3, 5.0]), jnp.asarray(1.0))
    assert np.isfinite(float(gr))


def test_round_ste_gradient_identity():
    g = jax.grad(lambda x: jnp.sum(Q.round_ste(x)))(jnp.asarray([0.4, 1.7]))
    np.testing.assert_array_equal(np.asarray(g), [1.0, 1.0])


def test_quant_noise_mixes():
    key = jax.random.PRNGKey(0)
    x = jnp.ones((1000,)) * 0.31
    xq = jnp.zeros((1000,))
    out = Q.quant_noise(x, xq, 0.5, key)
    frac_quant = float(jnp.mean((out == 0.0).astype(jnp.float32)))
    assert 0.4 < frac_quant < 0.6
    # p=1 -> fully quantized
    np.testing.assert_array_equal(np.asarray(Q.quant_noise(x, xq, 1.0, key)),
                                  np.asarray(xq))


def test_dac_range_constraint_eq5():
    # r_dac = r_adc * |S| / w_max, and S may be negative during GD
    r = Q.dac_range(jnp.asarray(2.0), jnp.asarray(-0.5), 0.25)
    assert float(r) == 4.0
