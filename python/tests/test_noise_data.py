"""Noise injection (eq. 1-2) and synthetic dataset tests."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import data, noise

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# noise.py
# ---------------------------------------------------------------------------

def test_clip_ranges_2sigma():
    w = jnp.asarray(np.random.default_rng(0).standard_normal(10_000) * 0.3)
    lo, hi = noise.clip_ranges_from_sigma(w)
    assert abs(float(hi) - 0.6) < 0.02
    assert float(lo) == -float(hi)


def test_inject_statistics():
    key = jax.random.PRNGKey(1)
    w = jnp.zeros((50_000,))
    eta = 0.1
    out = noise.inject(w, -0.5, 0.5, eta, key)
    # sigma = eta * w_max = 0.05
    assert abs(float(jnp.std(out)) - 0.05) < 0.002
    assert abs(float(jnp.mean(out))) < 0.002


def test_inject_ste_gradient():
    # gradient flows to w0 as identity through clip+noise
    key = jax.random.PRNGKey(2)
    f = lambda w: jnp.sum(noise.inject(w, -1.0, 1.0, 0.05, key) ** 2)
    w0 = jnp.asarray([0.3, -2.0])  # second is clipped
    g = jax.grad(f)(w0)
    assert g.shape == w0.shape
    assert np.all(np.isfinite(np.asarray(g)))


def test_inject_zero_eta_is_clip():
    key = jax.random.PRNGKey(3)
    w = jnp.asarray([0.2, 3.0, -3.0])
    out = noise.inject(w, -1.0, 1.0, 0.0, key)
    np.testing.assert_allclose(np.asarray(out), [0.2, 1.0, -1.0], atol=1e-7)


# ---------------------------------------------------------------------------
# data.py
# ---------------------------------------------------------------------------

def test_kws_shapes_and_determinism():
    x1, y1 = data.make_kws(64, seed=42)
    x2, y2 = data.make_kws(64, seed=42)
    assert x1.shape == (64, 49, 10, 1) and y1.shape == (64,)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    assert set(np.unique(y1)) <= set(range(12))


def test_vww_shapes_and_range():
    x, y = data.make_vww(16, seed=7)
    assert x.shape == (16, 100, 100, 3)
    assert x.min() >= -1.0 and x.max() <= 1.0
    assert set(np.unique(y)) <= {0, 1}


def test_kws_classes_separable_from_prototypes():
    # nearest-prototype classification should beat chance by a wide margin
    protos = data.kws_prototypes()
    x, y = data.make_kws(256, seed=9)
    feats = x[:, :, :, 0]
    correct = 0
    for i in range(len(y)):
        best, bestd = -1, 1e18
        for c in range(12):
            d = np.min([np.sum((np.roll(protos[c], s, axis=0) - feats[i]) ** 2)
                        for s in range(-5, 6)])
            if d < bestd:
                best, bestd = c, d
        correct += best == y[i]
    assert correct / len(y) > 0.5, f"nearest-proto acc {correct/len(y)}"


def test_dataset_bin_roundtrip(tmp_path):
    x, y = data.make_kws(8, seed=1)
    p = str(tmp_path / "t.bin")
    data.write_dataset_bin(p, x, y)
    x2, y2 = data.read_dataset_bin(p)
    np.testing.assert_array_equal(x, x2)
    np.testing.assert_array_equal(y.astype(np.int32), y2)
