"""L1 correctness: the pallas cim_mvm kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the kernel that every exported graph
embeds.  Hypothesis sweeps shapes, ranges, bitwidths and block sizes.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.cim_mvm import cim_mvm, vmem_footprint_bytes
from compile.kernels.ref import cim_mvm_ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32) * scale)


def assert_quantized_close(got, want, r_adc, adc_bits, max_flip_frac=0.005):
    """Quantized-output contract: f32 accumulation order may flip a value
    sitting exactly on a rounding boundary by ONE ADC step, but never more,
    and only rarely."""
    got = np.asarray(got)
    want = np.asarray(want)
    step = r_adc / (2 ** (adc_bits - 1) - 1)
    diff = np.abs(got - want)
    assert diff.max() <= step + 1e-5, f"max diff {diff.max()} > step {step}"
    flips = np.mean(diff > 1e-6)
    assert flips <= max_flip_frac, f"boundary-flip fraction {flips}"


@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (128, 432, 128), (100, 27, 48),
                                   (256, 648, 88), (1, 88, 12)])
@pytest.mark.parametrize("bits", [8, 6, 4])
def test_kernel_matches_ref(m, k, n, bits):
    x = rand((m, k), seed=m + k)
    w = rand((k, n), seed=n, scale=0.1)
    kw = dict(r_dac=2.0, r_adc=4.0, dac_bits=bits + 1, adc_bits=bits)
    got = cim_mvm(x, w, **kw)
    want = cim_mvm_ref(x, w, **kw)
    assert_quantized_close(got, want, 4.0, bits)


@hypothesis.given(
    m=st.integers(1, 70),
    k=st.integers(1, 96),
    n=st.integers(1, 64),
    bits=st.sampled_from([4, 6, 8]),
    r_dac=st.floats(0.1, 8.0),
    r_adc=st.floats(0.5, 32.0),
    block_m=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**16),
)
@hypothesis.settings(max_examples=40, deadline=None)
def test_kernel_hypothesis_sweep(m, k, n, bits, r_dac, r_adc, block_m, seed):
    x = rand((m, k), seed=seed)
    w = rand((k, n), seed=seed + 1, scale=0.2)
    kw = dict(r_dac=r_dac, r_adc=r_adc, dac_bits=bits + 1, adc_bits=bits)
    got = cim_mvm(x, w, block_m=block_m, block_n=min(n, 32), **kw)
    want = cim_mvm_ref(x, w, **kw)
    assert_quantized_close(got, want, r_adc, bits, max_flip_frac=0.01)


def test_kernel_block_size_invariance():
    x = rand((96, 50), seed=3)
    w = rand((50, 40), seed=4, scale=0.2)
    kw = dict(r_dac=1.0, r_adc=8.0, dac_bits=9, adc_bits=8)
    outs = [np.asarray(cim_mvm(x, w, block_m=bm, block_n=bn, **kw))
            for bm, bn in [(8, 8), (32, 40), (96, 16), (128, 128)]]
    for o in outs[1:]:
        assert_quantized_close(outs[0], o, 8.0, 8)


def test_kernel_output_on_adc_grid():
    x = rand((32, 16), seed=5)
    w = rand((16, 8), seed=6)
    bits = 6
    r_adc = 4.0
    out = np.asarray(cim_mvm(x, w, r_dac=2.0, r_adc=r_adc,
                             dac_bits=bits + 1, adc_bits=bits))
    step = r_adc / (2 ** (bits - 1) - 1)
    codes = out / step
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)
    assert np.abs(out).max() <= r_adc + 1e-6


def test_kernel_clips_to_adc_range():
    x = jnp.ones((4, 64), jnp.float32) * 10.0
    w = jnp.ones((64, 4), jnp.float32)
    out = np.asarray(cim_mvm(x, w, r_dac=1.0, r_adc=2.0,
                             dac_bits=9, adc_bits=8))
    np.testing.assert_allclose(out, 2.0, atol=1e-6)


def test_vmem_footprint_estimate():
    # 128x128 tiles with K=648 stay under 1 MB — far inside a 16 MB VMEM
    assert vmem_footprint_bytes(648) < 1 << 20
    assert vmem_footprint_bytes(648, 256, 256) > vmem_footprint_bytes(648)
