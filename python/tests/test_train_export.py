"""Training-step smoke tests + export round-trips (no full trainings here —
the AOT pipeline covers those; these keep the unit suite fast)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, export, heuristics, optim, train
from compile.config import LayerCfg, ModelCfg, TrainCfg
from compile.models import get_model

jax.config.update("jax_platform_name", "cpu")


def tiny_model() -> ModelCfg:
    layers = (
        LayerCfg("c0", "conv3x3", 1, 4, stride=(2, 1)),
        LayerCfg("fc", "dense", 4, 12, bn=False, relu=False),
    )
    return ModelCfg("tiny_kws", (49, 10, 1), 12, layers)


TINY_TCFG = TrainCfg(steps_stage1=12, steps_stage2=10, batch=16,
                     lr_stage1=1e-3, lr_stage2=1e-4)


# ---------------------------------------------------------------------------
# optim
# ---------------------------------------------------------------------------

def test_adam_reduces_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    st = optim.adam_init(params)
    for _ in range(200):
        g = {"w": 2.0 * params["w"]}
        params, st = optim.adam_update(g, st, params, 0.1)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.2


def test_schedules():
    cos = optim.cosine_lr(1.0, 100)
    assert float(cos(0)) == 1.0
    assert float(cos(100)) < 1e-6
    exp = optim.exp_decay_lr(1e-3, 1e-4, 100)
    assert abs(float(exp(100)) - 1e-4) / 1e-4 < 1e-6


def test_grad_clip():
    g = jnp.asarray([3.0, 4.0])  # norm 5
    c = optim.global_norm_clip(g, 0.5)
    assert abs(float(jnp.sqrt(jnp.sum(c * c))) - 0.5) < 1e-6
    # under threshold: untouched
    np.testing.assert_allclose(np.asarray(optim.global_norm_clip(g, 10.0)),
                               np.asarray(g))


# ---------------------------------------------------------------------------
# two-stage training on a tiny model
# ---------------------------------------------------------------------------

def test_stage1_trains_and_clips():
    model = tiny_model()
    tr = train.run_stage1(model, "kws", TINY_TCFG, log=lambda *a: None)
    assert tr.clips.shape == (2, 2)
    assert np.all(tr.clips[:, 1] > 0)
    assert tr.ranges is None
    assert 0.0 <= tr.fp_test_acc <= 1.0


def test_stage2_full_produces_ranges():
    model = tiny_model()
    s1 = train.run_stage1(model, "kws", TINY_TCFG, log=lambda *a: None)
    tr = train.run_stage2(model, "kws", TINY_TCFG, s1, "full",
                          log=lambda *a: None)
    assert tr.ranges is not None
    assert tr.ranges["r_adc"].shape == (2,)
    assert float(np.abs(tr.ranges["s"])) > 0
    assert tr.adc_bits == 8


def test_stage2_noise_keeps_no_ranges():
    model = tiny_model()
    s1 = train.run_stage1(model, "kws", TINY_TCFG, log=lambda *a: None)
    tr = train.run_stage2(model, "kws", TINY_TCFG, s1, "noise",
                          log=lambda *a: None)
    assert tr.ranges is None


# ---------------------------------------------------------------------------
# heuristics + export
# ---------------------------------------------------------------------------

def _trained(variant="base"):
    model = tiny_model()
    s1 = train.run_stage1(model, "kws", TINY_TCFG, log=lambda *a: None)
    if variant == "base":
        return s1
    return train.run_stage2(model, "kws", TINY_TCFG, s1, variant,
                            log=lambda *a: None)


def test_heuristic_ranges_positive():
    tr = _trained()
    x, _ = data.load("kws", "test")
    heur = heuristics.calibrate_ranges(tr.model, tr.params, tr.bn_state,
                                       tr.clips, x[:64])
    assert all(v > 0 for v in heur["r_dac"])
    assert all(v > 0 for v in heur["r_adc"])


def test_export_bundle_roundtrip(tmp_path):
    tr = _trained("full")
    infos = export.layer_export_info(tr)
    export.resolve_ranges(tr, infos, 8, None)

    hlo = tmp_path / "tiny_8b_b4.hlo.txt"
    export.export_hlo(tr.model, infos, 8, 4, str(hlo))
    text = hlo.read_text()
    assert "HloModule" in text and len(text) > 1000
    # regression: the default HLO printer elides large constants as `{...}`,
    # which the Rust side's xla_extension 0.5.1 parses back as ZEROS
    assert "constant({...})" not in text, "large constants were elided"

    wbin = tmp_path / "tiny.weights.bin"
    export.write_weights_bin(str(wbin), infos)
    raw = wbin.read_bytes()
    assert raw[:4] == b"ANWT"

    meta = tmp_path / "tiny.meta.json"
    export.write_meta_json(str(meta), tr.model, infos, tr, "tiny_full",
                           {"8b_b4": hlo.name},
                           export.layer_input_hws(tr.model))
    js = json.loads(meta.read_text())
    assert js["num_classes"] == 12
    assert len(js["layers"]) == 2
    assert js["layers"][0]["r_dac"] > 0
    # weights clipped to [w_min, w_max] and w_scale consistent
    for l, info in zip(js["layers"], infos):
        assert abs(l["w_scale"] - float(np.max(np.abs(info["w"])))) < 1e-6


def test_exported_graph_weight_shapes_dw():
    m = get_model("micronet_kws_s")
    for l in m.layers:
        shape = export.graph_weight_shape(l)
        if l.kind == "dw3x3":
            assert shape == (9 * l.in_ch, l.out_ch)


def test_resolve_ranges_trained_uses_eq5():
    tr = _trained("full")
    infos = export.layer_export_info(tr)
    export.resolve_ranges(tr, infos, 8, None)
    s = abs(float(tr.ranges["s"]))
    for li, info in enumerate(infos):
        want = abs(float(tr.ranges["r_adc"][li])) + 1e-9
        assert abs(info["r_adc"] - want) < 1e-9
        assert abs(info["r_dac"] - want * s / info["w_max"]) < 1e-9
