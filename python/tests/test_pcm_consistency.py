"""Python-side mirror of the PCM statistical model (Section 6.1), used to
cross-check the calibration constants against the Rust implementation (which
carries the authoritative copies in rust/src/pcm/device.rs)."""

import numpy as np

G_MAX_US = 25.0


def sigma_prog(g_t):
    return np.maximum(-1.1731 * g_t**2 + 1.9650 * g_t + 0.2635, 0.0) / G_MAX_US


def q_factor(g_t):
    g_us = np.maximum(g_t * G_MAX_US, 1e-9)
    return np.minimum(0.0088 / g_us**0.65, 0.2)


def drift_factor(t, nu, t_c=25.0):
    return (np.maximum(t, t_c) / t_c) ** (-nu)


def test_sigma_prog_range():
    g = np.linspace(0, 1, 101)
    s = sigma_prog(g)
    assert np.all(s >= 0)
    # 1% .. 4.3% of G_max over the full range (Joshi et al. calibration)
    assert 0.010 < s[0] < 0.011
    assert s.max() < 0.045


def test_q_factor_monotone_capped():
    g = np.linspace(0.001, 1, 200)
    q = q_factor(g)
    assert np.all(np.diff(q) <= 1e-12)
    assert q.max() <= 0.2


def test_drift_magnitudes():
    # at nu = 0.031: ~1 day -> ~0.777, 1 year -> ~0.647
    f_day = drift_factor(86_400.0, 0.031)
    f_year = drift_factor(31_536_000.0, 0.031)
    assert abs(f_day - (86_400.0 / 25.0) ** -0.031) < 1e-12
    assert 0.7 < f_day < 0.85
    assert 0.6 < f_year < 0.7


def test_gdc_compensates_global_drift():
    rng = np.random.default_rng(0)
    g = rng.uniform(0.1, 1.0, 10_000)
    nu = np.maximum(rng.normal(0.031, 0.007, g.shape), 0)
    t = 86_400.0
    g_d = g * drift_factor(t, nu)
    alpha = g.sum() / g_d.sum()
    # compensated mean magnitude restored
    assert abs((alpha * g_d).mean() - g.mean()) / g.mean() < 1e-3
    # but per-device error remains (the nu spread is uncompensated)
    rel_err = np.abs(alpha * g_d - g) / g.mean()
    assert rel_err.std() > 0.01
