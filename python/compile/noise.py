"""Weight clipping + Gaussian noise injection (Section 4.2, eq. 1-2).

The clip-then-perturb composite is treated as a straight-through estimator:
gradients are computed with the clipped, noise-perturbed weights and applied
to the underlying float weights ``w0``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def clip_ranges_from_sigma(w0: jnp.ndarray, n_sigma: float = 2.0):
    """Static clipping range [-n_sigma*std(w0), +n_sigma*std(w0)] (Section 4.2)."""
    s = jnp.std(w0)
    return -n_sigma * s, n_sigma * s


def clip_weights(w0: jnp.ndarray, w_min, w_max) -> jnp.ndarray:
    return jnp.clip(w0, w_min, w_max)


def inject(w0: jnp.ndarray, w_min, w_max, eta: float,
           key: jax.Array) -> jnp.ndarray:
    """W = clip(W0) + N(0, (eta * W_max)^2), with STE back to W0 (eq. 1-2)."""
    wc = clip_weights(w0, w_min, w_max)
    sigma = eta * jnp.maximum(jnp.abs(w_min), jnp.abs(w_max))
    noisy = wc + sigma * jax.random.normal(key, w0.shape, w0.dtype)
    # straight-through: forward uses `noisy`, gradient flows to w0 unchanged
    return w0 + jax.lax.stop_gradient(noisy - w0)
