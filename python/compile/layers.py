"""L2 layer primitives: im2col patch extraction, BN, pooling, init.

Convolutions are deliberately expressed as *explicit im2col + GEMM*, because
that is how the CiM crossbar executes them (Figure 2c): the GEMM inner
dimension is the crossbar row range of the layer and the output channels are
its columns.  The patch ordering ``(ky, kx, c)`` is a contract shared with
``rust/src/simulator/im2col.rs`` and the mapper — do not change one side only.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import LayerCfg, ModelCfg

BN_EPS = 1e-3
BN_MOMENTUM = 0.95


def patches3x3(x: jnp.ndarray, stride: Tuple[int, int]) -> jnp.ndarray:
    """Extract 3x3 SAME patches: [N,H,W,C] -> [N,Ho,Wo,9*C].

    Feature ordering is (ky, kx, c): feature[(ky*3+kx)*C + c] = padded
    x[n, ho*sh + ky, wo*sw + kx, c].
    """
    n, h, w, c = x.shape
    sh, sw = stride
    ho = (h + 1) // sh if sh > 1 else h
    wo = (w + 1) // sw if sw > 1 else w
    # SAME padding for kernel 3: one pixel each side (for odd strides the
    # left/top pad of 1 matches TF 'SAME' when H is odd or stride 1).
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    # ensure the strided slices below stay in bounds for every (ky, kx)
    xp = jnp.pad(xp, ((0, 0), (0, 2), (0, 2), (0, 0)))
    cols = []
    for ky in range(3):
        for kx in range(3):
            sl = xp[:, ky: ky + (ho - 1) * sh + 1: sh,
                    kx: kx + (wo - 1) * sw + 1: sw, :]
            cols.append(sl)
    return jnp.concatenate(cols, axis=-1)


def out_hw(h: int, w: int, cfg: LayerCfg) -> Tuple[int, int]:
    if cfg.kind in ("conv3x3", "dw3x3"):
        sh, sw = cfg.stride
        return ((h + sh - 1) // sh, (w + sw - 1) // sw)
    if cfg.kind == "conv1x1":
        return (h, w)
    if cfg.kind == "dense":
        return (1, 1)
    raise ValueError(cfg.kind)


def layer_input_matrix(x: jnp.ndarray, cfg: LayerCfg) -> jnp.ndarray:
    """Flatten a layer input to the im2col GEMM matrix [N*Ho*Wo, K]."""
    if cfg.kind == "conv3x3":
        p = patches3x3(x, cfg.stride)
        return p.reshape(-1, p.shape[-1])
    if cfg.kind == "conv1x1":
        return x.reshape(-1, x.shape[-1])
    if cfg.kind == "dense":
        return x.reshape(x.shape[0], -1)
    if cfg.kind == "dw3x3":
        p = patches3x3(x, cfg.stride)
        return p.reshape(-1, p.shape[-1])   # dense-expanded form [*, 9*C]
    raise ValueError(cfg.kind)


def dw_dense_weight(w9c: jnp.ndarray) -> jnp.ndarray:
    """Expand a compact depthwise weight [9, C] to its dense CiM form [9C, C].

    Row (t*C + i) , column j is w9c[t, i] if i == j else 0 — the 'non-zero
    diagonal' expansion of Figure 3 / Figure 11.
    """
    t, c = w9c.shape
    eye = jnp.eye(c, dtype=w9c.dtype)
    return (w9c[:, :, None] * eye[None, :, :]).reshape(t * c, c)


def apply_dw_compact(x: jnp.ndarray, w9c: jnp.ndarray,
                     stride: Tuple[int, int]) -> jnp.ndarray:
    """Depthwise conv via patches + einsum (the exact/digital path)."""
    n, h, w, c = x.shape
    p = patches3x3(x, stride)
    ho, wo = p.shape[1], p.shape[2]
    p = p.reshape(n, ho, wo, 9, c)
    return jnp.einsum("nhwtc,tc->nhwc", p, w9c)


# ---------------------------------------------------------------------------
# Batch normalization (applied in the digital domain, Section 3.1)
# ---------------------------------------------------------------------------

def bn_apply(y: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
             mean: jnp.ndarray, var: jnp.ndarray) -> jnp.ndarray:
    inv = gamma * jax.lax.rsqrt(var + BN_EPS)
    return y * inv + (beta - mean * inv)


def bn_train(y: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
             state: Dict[str, jnp.ndarray]):
    axes = tuple(range(y.ndim - 1))
    mean = jnp.mean(y, axes)
    var = jnp.var(y, axes)
    out = bn_apply(y, gamma, beta, mean, var)
    new_state = {
        "mean": BN_MOMENTUM * state["mean"] + (1 - BN_MOMENTUM) * mean,
        "var": BN_MOMENTUM * state["var"] + (1 - BN_MOMENTUM) * var,
    }
    return out, new_state


def bn_fold(gamma: np.ndarray, beta: np.ndarray, mean: np.ndarray,
            var: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Fold inference BN into a per-channel digital affine (scale, bias)."""
    inv = gamma / np.sqrt(var + BN_EPS)
    return inv, beta - mean * inv


# ---------------------------------------------------------------------------
# Parameter / state initialization
# ---------------------------------------------------------------------------

def init_params(model: ModelCfg, key: jax.Array) -> List[Dict[str, jnp.ndarray]]:
    params = []
    for cfg in model.layers:
        key, k1 = jax.random.split(key)
        shape = cfg.weight_shape
        fan_in = cfg.k if cfg.kind != "dw3x3" else 9
        w = jax.random.normal(k1, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)
        p = {"w": w}
        if cfg.bn:
            p["gamma"] = jnp.ones((cfg.out_ch,), jnp.float32)
            p["beta"] = jnp.zeros((cfg.out_ch,), jnp.float32)
        if cfg.kind == "dense":
            p["bias"] = jnp.zeros((cfg.out_ch,), jnp.float32)
        params.append(p)
    return params


def init_state(model: ModelCfg) -> List[Dict[str, jnp.ndarray]]:
    state = []
    for cfg in model.layers:
        ch = cfg.out_ch if cfg.kind != "dw3x3" else cfg.in_ch
        if cfg.bn:
            state.append({
                "mean": jnp.zeros((ch,), jnp.float32),
                "var": jnp.ones((ch,), jnp.float32),
            })
        else:
            state.append({})
    return state
