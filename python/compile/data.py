"""Synthetic stand-ins for Google Speech Commands V2 and Visual Wake Words.

The real datasets are not available in this offline environment (repro gate);
per DESIGN.md we substitute procedural datasets with the *same tensor shapes*
and a difficulty calibrated so that the paper's relative effects (noise
robustness orderings, bitwidth degradation) are exercised on the identical
code path.

Both generators are deterministic given a seed, and the test split is
exported to ``artifacts/<task>_test.bin`` for the Rust side.
"""

from __future__ import annotations

import struct
from typing import Tuple

import numpy as np

from . import config


def _smooth2d(rng: np.random.Generator, h: int, w: int, passes: int = 2) -> np.ndarray:
    """Low-frequency random field in [-1, 1] (box-blurred white noise)."""
    x = rng.standard_normal((h, w))
    for _ in range(passes):
        x = (
            x
            + np.roll(x, 1, 0) + np.roll(x, -1, 0)
            + np.roll(x, 1, 1) + np.roll(x, -1, 1)
        ) / 5.0
    x -= x.mean()
    m = np.abs(x).max()
    return x / (m + 1e-9)


# ---------------------------------------------------------------------------
# KWS: 12-way "spectrogram" classification, 49x10x1 (MFCC-shaped)
# ---------------------------------------------------------------------------

def kws_prototypes(seed: int = 1234) -> np.ndarray:
    """One fixed smooth time-frequency prototype per keyword class."""
    rng = np.random.default_rng(seed)
    h, w, _ = (49, 10, 1)
    protos = np.stack(
        [_smooth2d(rng, h, w, passes=3) for _ in range(config.KWS_CLASSES)]
    )
    return protos.astype(np.float32)


def make_kws(n: int, seed: int) -> Tuple[np.ndarray, np.ndarray]:
    """Return (x[n,49,10,1] float32, y[n] int32)."""
    protos = kws_prototypes()
    rng = np.random.default_rng(seed)
    y = rng.integers(0, config.KWS_CLASSES, size=n).astype(np.int32)
    xs = np.empty((n, 49, 10, 1), np.float32)
    for i in range(n):
        p = protos[y[i]]
        # temporal jitter: roll along the time axis
        shift = int(rng.integers(-5, 6))
        p = np.roll(p, shift, axis=0)
        amp = rng.uniform(0.8, 1.25)
        noise = rng.standard_normal((49, 10)) * 0.45
        xs[i, :, :, 0] = amp * p + noise
    return xs, y


# ---------------------------------------------------------------------------
# VWW: binary "person present" task, 100x100x3
# ---------------------------------------------------------------------------

def _draw_blob(img: np.ndarray, cy: float, cx: float, ry: float, rx: float,
               val: np.ndarray) -> None:
    h, w, _ = img.shape
    yy, xx = np.mgrid[0:h, 0:w]
    mask = ((yy - cy) / ry) ** 2 + ((xx - cx) / rx) ** 2 <= 1.0
    img[mask] = img[mask] * 0.3 + 0.7 * val


def _person(rng: np.random.Generator, img: np.ndarray) -> None:
    """A 'person': vertically elongated torso ellipse + head circle."""
    h, w, _ = img.shape
    scale = rng.uniform(0.5, 1.4)
    cy = rng.uniform(0.35 * h, 0.8 * h)
    cx = rng.uniform(0.15 * w, 0.85 * w)
    tone = rng.uniform(-1.0, 1.0, size=3).astype(np.float32)
    torso_ry, torso_rx = 14 * scale, 5 * scale
    _draw_blob(img, cy, cx, torso_ry, torso_rx, tone)
    _draw_blob(img, cy - torso_ry - 4 * scale, cx, 4 * scale, 4 * scale, tone)


def _clutter(rng: np.random.Generator, img: np.ndarray) -> None:
    """Background distractors: horizontal blobs and boxes (never person-shaped)."""
    h, w, _ = img.shape
    for _ in range(int(rng.integers(2, 6))):
        tone = rng.uniform(-1.0, 1.0, size=3).astype(np.float32)
        if rng.uniform() < 0.5:
            ry = rng.uniform(2, 6)
            rx = ry * rng.uniform(1.8, 4.0)   # horizontal: aspect flipped
            _draw_blob(img, rng.uniform(0, h), rng.uniform(0, w), ry, rx, tone)
        else:
            y0, x0 = int(rng.integers(0, h - 12)), int(rng.integers(0, w - 12))
            dy, dx = int(rng.integers(6, 12)), int(rng.integers(6, 12))
            img[y0:y0 + dy, x0:x0 + dx] = (
                img[y0:y0 + dy, x0:x0 + dx] * 0.4 + 0.6 * tone
            )


def make_vww(n: int, seed: int) -> Tuple[np.ndarray, np.ndarray]:
    """Return (x[n,100,100,3] float32 in [-1,1], y[n] int32)."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 2, size=n).astype(np.int32)
    xs = np.empty((n, 100, 100, 3), np.float32)
    for i in range(n):
        img = np.repeat(
            _smooth2d(rng, 100, 100, passes=2)[..., None], 3, axis=2
        ).astype(np.float32) * 0.4
        _clutter(rng, img)
        if y[i] == 1:
            for _ in range(int(rng.integers(1, 3))):
                _person(rng, img)
        img += rng.standard_normal(img.shape).astype(np.float32) * 0.08
        xs[i] = np.clip(img, -1.0, 1.0)
    return xs, y


# ---------------------------------------------------------------------------
# Dataset accessors + binary export (shared format with rust/src/datasets)
# ---------------------------------------------------------------------------

_CACHE: dict = {}


def load(task: str, split: str) -> Tuple[np.ndarray, np.ndarray]:
    """Dataset accessor, memoized (procedural generation is not free and the
    trainer/calibrator/evaluator all ask for the same splits)."""
    key = (task, split)
    if key in _CACHE:
        return _CACHE[key]
    if task == "kws":
        n = config.KWS_TRAIN if split == "train" else config.KWS_TEST
        out = make_kws(n, seed=100 if split == "train" else 101)
    elif task == "vww":
        n = config.VWW_TRAIN if split == "train" else config.VWW_TEST
        out = make_vww(n, seed=200 if split == "train" else 201)
    else:
        raise ValueError(task)
    _CACHE[key] = out
    return out


MAGIC = b"ANDS"


def write_dataset_bin(path: str, x: np.ndarray, y: np.ndarray) -> None:
    """Flat little-endian binary: magic, n, ndim, dims..., f32 data, u32 labels."""
    x = np.ascontiguousarray(x, np.float32)
    y = np.ascontiguousarray(y, np.uint32)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", x.shape[0], x.ndim - 1))
        for d in x.shape[1:]:
            f.write(struct.pack("<I", d))
        f.write(x.tobytes())
        f.write(y.tobytes())


def read_dataset_bin(path: str) -> Tuple[np.ndarray, np.ndarray]:
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC
        n, nd = struct.unpack("<II", f.read(8))
        dims = [struct.unpack("<I", f.read(4))[0] for _ in range(nd)]
        x = np.frombuffer(f.read(4 * n * int(np.prod(dims))), np.float32)
        x = x.reshape([n] + dims).copy()
        y = np.frombuffer(f.read(4 * n), np.uint32).astype(np.int32)
    return x, y
