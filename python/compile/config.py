"""Central configuration for models, training and export.

Everything that the paper specifies numerically lives here so the
experiments are driven from one place (and so the Rust side, which reads
the exported ``meta.json``, never has to guess).
"""

from __future__ import annotations

import dataclasses
import os
from typing import List, Optional, Tuple

# ---------------------------------------------------------------------------
# CiM array geometry (Section 5 / Table 2)
# ---------------------------------------------------------------------------
ARRAY_ROWS = 1024
ARRAY_COLS = 512
ADC_MUX = 4                     # 4-input analog mux on the bitlines
G_MAX_US = 25.0                 # max device conductance, micro-Siemens

# PWM DAC cycle time per activation precision (Table 2)
T_CIM_NS = {8: 130.0, 6: 34.0, 4: 10.0}
T_DIGITAL_NS = 1.25             # 800 MHz digital pipeline

# ---------------------------------------------------------------------------
# Training hyper-parameters (Section 4.2 / 6.1)
# ---------------------------------------------------------------------------
QUANT_NOISE_P = 0.5             # stochastic quantization-noise probability
S_GRAD_CLIP = 0.01              # gradient clipping threshold on the ADC gain S
RANGE_LR_INIT = 1e-3            # quantizer-range LR, exponential decay ...
RANGE_LR_FINAL = 1e-4           # ... to this value
CLIP_SIGMA = 2.0                # weight clipping at +/- 2 sigma
SIGMA_UPDATE_EVERY = 10         # stage-1 recomputes sigma every 10 steps

# DAC gets one more bit than the ADC (eq. 3)
def dac_bits(adc_bits: int) -> int:
    return adc_bits + 1


# Appendix C heuristics
HEUR_IN_PERCENTILE = 99.995
HEUR_N_STD_OUT = 4.0

FAST = os.environ.get("FAST", "0") not in ("", "0", "false")


@dataclasses.dataclass(frozen=True)
class LayerCfg:
    """One CiM-mapped layer (a conv expressed as an im2col GEMM, or a dense).

    kind: 'conv3x3' | 'conv1x1' | 'dw3x3' | 'dense'
    stride: (sh, sw) for convs
    analog: False => executed on a digital processor (exact weights, no
            DAC/ADC quantization) -- used for the Fig. 9 depthwise-in-digital
            ablation.
    residual_from: index of an earlier layer whose *output* is added to this
            layer's output (digital domain), or None.
    """

    name: str
    kind: str
    in_ch: int
    out_ch: int
    stride: Tuple[int, int] = (1, 1)
    relu: bool = True
    bn: bool = True
    analog: bool = True
    residual_from: Optional[int] = None

    @property
    def k(self) -> int:
        """im2col GEMM inner dimension (crossbar rows for this layer)."""
        if self.kind == "conv3x3":
            return 9 * self.in_ch
        if self.kind == "dw3x3":
            return 9 * self.in_ch       # dense-expanded form
        if self.kind == "conv1x1":
            return self.in_ch
        if self.kind == "dense":
            return self.in_ch
        raise ValueError(self.kind)

    @property
    def weight_shape(self) -> Tuple[int, int]:
        if self.kind == "dw3x3":
            return (9, self.in_ch)      # stored compactly; expanded on map
        return (self.k, self.out_ch)


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    name: str
    input_hwc: Tuple[int, int, int]
    num_classes: int
    layers: Tuple[LayerCfg, ...]

    def param_count(self) -> int:
        n = 0
        for l in self.layers:
            r, c = l.weight_shape
            n += r * c
        n += self.num_classes  # final dense bias
        return n


@dataclasses.dataclass(frozen=True)
class TrainCfg:
    steps_stage1: int
    steps_stage2: int
    batch: int
    lr_stage1: float
    lr_stage2: float           # 1/10 of stage-1 LR per the paper
    eta: float = 0.10          # training noise-injection level (eq. 1)
    adc_bits: int = 8
    seed: int = 0

    def scaled(self) -> "TrainCfg":
        """FAST mode: shrink step counts for CI / smoke runs."""
        if not FAST:
            return self
        return dataclasses.replace(
            self,
            steps_stage1=max(40, self.steps_stage1 // 10),
            steps_stage2=max(40, self.steps_stage2 // 10),
        )


# ---------------------------------------------------------------------------
# Dataset sizes (synthetic substitutes; see DESIGN.md "Substitutions")
# ---------------------------------------------------------------------------
KWS_TRAIN, KWS_TEST, KWS_CLASSES = 4096, 1024, 12
VWW_TRAIN, VWW_TEST, VWW_CLASSES = 2048, 512, 2

EVAL_BATCH = 128                # batch size of the exported evaluation graphs
SERVE_BATCHES = (1, 8, 32)      # batch sizes of the exported serving graphs
