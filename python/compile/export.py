"""AOT export: inference graphs (HLO text), weights, metadata, datasets.

The exported graph signature is the contract with ``rust/src/runtime``:

    (x[B,...], w_0, ..., w_{L-1}, gdc[L]) -> (logits[B, classes],)

* weights enter as runtime *parameters* so the Rust PCM substrate can feed
  drifted/noisy effective weights without recompiling;
* quantizer ranges and folded-BN digital affines are baked as constants;
* ``gdc`` is the per-layer global-drift-compensation scale, applied digitally
  *after* the ADC (order matters — see DESIGN.md section 4);
* HLO **text** is the interchange format: the crate's xla_extension 0.5.1
  rejects jax>=0.5 serialized protos (64-bit instruction ids), while the text
  parser reassigns ids and round-trips cleanly.
"""

from __future__ import annotations

import json
import os
import struct
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import layers as L
from .config import ModelCfg, dac_bits
from .kernels.cim_mvm import cim_mvm
from .train import Trained

WEIGHTS_MAGIC = b"ANWT"

# Interpret-mode pallas becomes an HLO while-loop over the grid; bigger M
# blocks = fewer loop iterations on the CPU backend. 2048 keeps the weight
# tile + activation tile well inside a realistic VMEM budget for every layer
# (see EXPERIMENTS.md §Perf L1).
EXPORT_BLOCK_M = 2048


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants is ESSENTIAL: the default printer elides big
    # constants (e.g. the folded-BN per-channel affines) as `{...}`, which
    # xla_extension 0.5.1's text parser silently reads back as zeros.
    return comp.as_hlo_text(True)


# ---------------------------------------------------------------------------
# Per-variant export bundle
# ---------------------------------------------------------------------------

def layer_export_info(trained: Trained) -> List[dict]:
    """Per-layer constants: folded BN affine, clipped weights, scales."""
    model = trained.model
    out = []
    for li, cfg in enumerate(model.layers):
        p = trained.params[li]
        w = np.clip(p["w"], trained.clips[li, 0], trained.clips[li, 1])
        w = np.asarray(w, np.float32)
        w_scale = float(np.max(np.abs(w))) or 1.0
        if cfg.bn:
            st = trained.bn_state[li]
            scale, bias = L.bn_fold(p["gamma"], p["beta"], st["mean"], st["var"])
        else:
            scale = np.ones((cfg.out_ch,), np.float32)
            bias = np.asarray(p.get("bias", np.zeros(cfg.out_ch)), np.float32)
        out.append({
            "cfg": cfg,
            "w": w,                       # compact trained weights
            "w_scale": w_scale,           # max|W|: conductance <-> weight map
            "w_max": float(max(abs(trained.clips[li, 0]),
                               abs(trained.clips[li, 1]))),
            "dig_scale": np.asarray(scale, np.float32),
            "dig_bias": np.asarray(bias, np.float32),
        })
    return out


def resolve_ranges(trained: Trained, infos: List[dict], adc_bits: int,
                   heuristic: Optional[Dict[str, List[float]]]) -> None:
    """Attach per-layer (r_dac, r_adc) to each layer info, either from the
    trained (S, r_ADC,l) parameters (eq. 5) or from the Appendix C heuristics.
    """
    if trained.ranges is not None:
        s = abs(float(trained.ranges["s"]))
        for li, info in enumerate(infos):
            r_adc = abs(float(trained.ranges["r_adc"][li])) + 1e-9
            info["r_adc"] = r_adc
            info["r_dac"] = r_adc * s / info["w_max"]
    else:
        assert heuristic is not None, "untrained ranges need calibration"
        for li, info in enumerate(infos):
            info["r_dac"] = float(heuristic["r_dac"][li])
            info["r_adc"] = float(heuristic["r_adc"][li])


def build_infer_fn(model: ModelCfg, infos: List[dict], adc_bits: int):
    """Inference graph: pallas CiM kernel per analog layer + digital post-ops."""
    b_dac = dac_bits(adc_bits)
    nl = len(model.layers)

    def fn(x, *rest):
        ws = rest[:nl]
        gdc = rest[nl]
        h = x
        for li, info in enumerate(infos):
            cfg = info["cfg"]
            w = ws[li]
            if cfg.kind == "dw3x3" and not cfg.analog:
                # Fig. 9 ablation: depthwise on a digital processor (exact)
                y = L.apply_dw_compact(h, w, cfg.stride)
            else:
                if cfg.kind == "dense":
                    h = jnp.mean(h, axis=(1, 2))
                m = L.layer_input_matrix(h, cfg)
                if cfg.analog:
                    # avoid padding waste: full-N blocks, M blocks capped
                    bm = min(EXPORT_BLOCK_M, -((-m.shape[0]) // 128) * 128)
                    a = cim_mvm(
                        m, w,
                        r_dac=info["r_dac"], r_adc=info["r_adc"],
                        dac_bits=b_dac, adc_bits=adc_bits,
                        block_m=bm, block_n=int(w.shape[1]),
                    )
                    a = a * gdc[li]
                else:
                    a = jnp.dot(m, w, preferred_element_type=jnp.float32)
                if cfg.kind == "dense":
                    y = a
                else:
                    hh, ww = L.out_hw(h.shape[1], h.shape[2], cfg)
                    y = a.reshape(h.shape[0], hh, ww, cfg.out_ch)
            y = y * info["dig_scale"] + info["dig_bias"]
            if cfg.relu:
                y = jax.nn.relu(y)
            h = y
        return (h,)

    return fn


def graph_weight_shape(cfg, analog_dw_dense: bool = True):
    """Shape of the weight *input* in the exported graph."""
    if cfg.kind == "dw3x3" and cfg.analog and analog_dw_dense:
        return (9 * cfg.in_ch, cfg.out_ch)    # dense CiM expansion
    return cfg.weight_shape


def export_hlo(model: ModelCfg, infos: List[dict], adc_bits: int,
               batch: int, path: str) -> None:
    fn = build_infer_fn(model, infos, adc_bits)
    h, w_, c = model.input_hwc
    specs = [jax.ShapeDtypeStruct((batch, h, w_, c), jnp.float32)]
    for info in infos:
        specs.append(jax.ShapeDtypeStruct(
            graph_weight_shape(info["cfg"]), jnp.float32))
    specs.append(jax.ShapeDtypeStruct((len(infos),), jnp.float32))
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)


# ---------------------------------------------------------------------------
# Binary weights + JSON metadata
# ---------------------------------------------------------------------------

def write_weights_bin(path: str, infos: List[dict]) -> None:
    """ANWT: little-endian; per tensor: ndim, dims..., f32 data (compact form)."""
    with open(path, "wb") as f:
        f.write(WEIGHTS_MAGIC)
        f.write(struct.pack("<I", len(infos)))
        for info in infos:
            w = np.ascontiguousarray(info["w"], np.float32)
            f.write(struct.pack("<I", w.ndim))
            for d in w.shape:
                f.write(struct.pack("<I", d))
            f.write(w.tobytes())


def write_meta_json(path: str, model: ModelCfg, infos: List[dict],
                    trained: Trained, variant: str,
                    hlo_files: Dict[str, str],
                    input_hw_per_layer: List[tuple]) -> None:
    layers_js = []
    for li, info in enumerate(infos):
        cfg = info["cfg"]
        hin, win = input_hw_per_layer[li]
        hout, wout = L.out_hw(hin, win, cfg) if cfg.kind != "dense" else (1, 1)
        layers_js.append({
            "name": cfg.name,
            "kind": cfg.kind,
            "in_ch": cfg.in_ch,
            "out_ch": cfg.out_ch,
            "stride": list(cfg.stride),
            "relu": cfg.relu,
            "analog": cfg.analog,
            "in_h": hin, "in_w": win, "out_h": hout, "out_w": wout,
            "k_gemm": cfg.k,
            "weight_shape": list(info["w"].shape),
            "graph_weight_shape": list(graph_weight_shape(cfg)),
            "w_scale": info["w_scale"],
            "w_max": info["w_max"],
            "r_dac": info["r_dac"],
            "r_adc": info["r_adc"],
            "dig_scale": [float(v) for v in info["dig_scale"]],
            "dig_bias": [float(v) for v in info["dig_bias"]],
        })
    js = {
        "model": model.name,
        "variant": variant,
        "input_hwc": list(model.input_hwc),
        "num_classes": model.num_classes,
        "eta": trained.eta,
        "fp_test_acc": trained.fp_test_acc,
        "trained_adc_bits": trained.adc_bits,
        "layers": layers_js,
        "hlo": hlo_files,     # {"<bits>b_b<batch>": "file.hlo.txt"}
    }
    with open(path, "w") as f:
        json.dump(js, f, indent=1)


def layer_input_hws(model: ModelCfg) -> List[tuple]:
    h, w, _ = model.input_hwc
    out = []
    for cfg in model.layers:
        out.append((h, w))
        if cfg.kind != "dense":
            h, w = L.out_hw(h, w, cfg)
    return out


# ---------------------------------------------------------------------------
# Standalone L1 kernel export (quickstart + bench_runtime)
# ---------------------------------------------------------------------------

def export_cim_mvm_demo(path: str, m: int = 256, k: int = 432, n: int = 128,
                        adc_bits: int = 8) -> None:
    def fn(x, w):
        return (cim_mvm(x, w, r_dac=1.0, r_adc=8.0,
                        dac_bits=dac_bits(adc_bits), adc_bits=adc_bits,
                        block_m=128, block_n=128),)
    specs = (jax.ShapeDtypeStruct((m, k), jnp.float32),
             jax.ShapeDtypeStruct((k, n), jnp.float32))
    lowered = jax.jit(fn).lower(*specs)
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
