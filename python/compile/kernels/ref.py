"""Pure-jnp oracle for the analog CiM matrix-vector kernel.

This is the correctness reference for ``cim_mvm.py`` (pytest compares the
pallas kernel against this implementation) and the fast path used inside the
training loop, where running pallas in interpret mode would be needlessly
slow.
"""

from __future__ import annotations

import jax.numpy as jnp


def _fq(x: jnp.ndarray, r_max: float, bits: int) -> jnp.ndarray:
    """Inference-time fake quantization (no STE: nothing differentiates here)."""
    levels = float(2 ** (bits - 1) - 1)
    step = r_max / levels
    return jnp.round(jnp.clip(x, -r_max, r_max) / step) * step


def cim_mvm_ref(x: jnp.ndarray, w: jnp.ndarray, *, r_dac: float, r_adc: float,
                dac_bits: int, adc_bits: int) -> jnp.ndarray:
    """DAC-quantize -> analog GEMM -> ADC-quantize, all in weight units.

    x: [M, K] activations, w: [K, N] effective (possibly drifted) weights.
    Models exactly what one layer of the CiM array computes between the
    digital input register and the digital output register.
    """
    xq = _fq(x, r_dac, dac_bits)
    acc = jnp.dot(xq, w, preferred_element_type=jnp.float32)
    return _fq(acc, r_adc, adc_bits)
