"""L1 Pallas kernel: the analog CiM crossbar matrix-vector product.

The hot-spot of every AnalogNets layer is the quantize -> GEMM -> requantize
round trip through the crossbar.  On a TPU this maps naturally onto the MXU
with VMEM-resident weights (DESIGN.md section "Hardware adaptation"): the
weight tile is *stationary* across the batch grid axis (its index map ignores
``i``), mirroring how the PCM array holds conductances fixed while PWM-encoded
activations stream through; the DAC/ADC quantization is fused into the tile so
the round trip never leaves VMEM.

``interpret=True`` is mandatory here: the CPU PJRT backend cannot execute
Mosaic custom-calls, and interpret mode lowers the kernel to plain HLO that
the Rust runtime can load.  Numerics are validated against ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes; 128 matches both the MXU systolic dimension and the
# AON-CiM mux-4 column group (512/4), see DESIGN.md section 3 and the block
# sweep in EXPERIMENTS.md §Perf.
BLOCK_M = 128
BLOCK_N = 128


def _fq(x, r_max: float, bits: int):
    levels = float(2 ** (bits - 1) - 1)
    step = r_max / levels
    return jnp.round(jnp.clip(x, -r_max, r_max) / step) * step


def _kernel(x_ref, w_ref, o_ref, *, r_dac, r_adc, dac_bits, adc_bits):
    # DAC: PWM encoding of the activation tile (quantize in-register)
    xq = _fq(x_ref[...], r_dac, dac_bits)
    # analog MAC: bitline accumulation == one MXU pass over the tile
    acc = jnp.dot(xq, w_ref[...], preferred_element_type=jnp.float32)
    # ADC: integrate + convert the bitline charge
    o_ref[...] = _fq(acc, r_adc, adc_bits)


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("r_dac", "r_adc", "dac_bits", "adc_bits", "block_m", "block_n"),
)
def cim_mvm(x: jnp.ndarray, w: jnp.ndarray, *, r_dac: float, r_adc: float,
            dac_bits: int, adc_bits: int,
            block_m: int = BLOCK_M, block_n: int = BLOCK_N) -> jnp.ndarray:
    """Tiled CiM GEMM: x[M,K] @ w[K,N] with DAC/ADC fake quantization.

    The full K (crossbar rows, <= 1024 for every AnalogNets layer) stays
    resident per tile — the array computes the complete dot product in one
    'cycle', so K is never split (splitting would require digital partial-sum
    accumulation the AON-CiM design avoids).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)

    xp = _pad_to(x, 0, block_m)
    wp = _pad_to(w, 1, block_n)
    mp, np_ = xp.shape[0], wp.shape[1]

    grid = (mp // block_m, np_ // block_n)
    out = pl.pallas_call(
        functools.partial(
            _kernel, r_dac=r_dac, r_adc=r_adc,
            dac_bits=dac_bits, adc_bits=adc_bits,
        ),
        grid=grid,
        in_specs=[
            # activations stream along the batch axis
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            # weights are stationary w.r.t. i (the batch grid axis)
            pl.BlockSpec((k, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


def vmem_footprint_bytes(k: int, block_m: int = BLOCK_M,
                         block_n: int = BLOCK_N) -> int:
    """Static VMEM estimate per grid step (used by the §Perf analysis)."""
    x_tile = block_m * k * 4
    w_tile = k * block_n * 4
    o_tile = block_m * block_n * 4
    return x_tile + w_tile + o_tile
