"""CiM-aware model forward pass (the L2 training/eval graph, Figure 4).

One function drives every training configuration in the paper:

* stage 1: weight clipping only (``clips`` given, ``eta=0``, ``ranges=None``)
* 'vanilla noise injection' (Joshi et al., 2020): ``eta>0``, ``ranges=None``
* full AnalogNets training: ``eta>0`` + DAC/ADC quantizers with the learnable
  per-layer ADC ranges and the shared analog gain ``S`` (eq. 5).

The per-layer pipeline mirrors the hardware order exactly:
DAC-quantize -> analog GEMM (noisy clipped weights) -> ADC-quantize ->
digital BN -> ReLU.  Depthwise layers (MicroNet baseline) use the compact
einsum path during training; their dense CiM expansion only matters at
deployment and is handled by the exporter / Rust evaluator.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import noise as N
from . import quantizers as Q
from .config import ModelCfg, dac_bits


def forward(
    model: ModelCfg,
    params: List[Dict[str, jnp.ndarray]],
    state: List[Dict[str, jnp.ndarray]],
    x: jnp.ndarray,
    *,
    train: bool,
    key: Optional[jax.Array] = None,
    eta: float = 0.0,
    clips: Optional[Sequence[Tuple[jnp.ndarray, jnp.ndarray]]] = None,
    ranges: Optional[Dict[str, jnp.ndarray]] = None,
    adc_bits: int = 8,
    qnoise_p: float = 0.0,
) -> Tuple[jnp.ndarray, List[Dict[str, jnp.ndarray]]]:
    """Run the model; returns (logits, new_bn_state).

    ``ranges``: {"s": scalar, "r_adc": [scalar per layer]} enables the
    DAC/ADC quantizer nodes. ``clips``: per-layer static (w_min, w_max).
    """
    if (eta > 0.0 or qnoise_p > 0.0) and key is None:
        raise ValueError("stochastic forward needs a PRNG key")
    b_adc = adc_bits
    b_dac = dac_bits(adc_bits)
    new_state: List[Dict[str, jnp.ndarray]] = []
    h = x
    for li, cfg in enumerate(model.layers):
        p = params[li]
        w0 = p["w"]

        # ---- weight conditioning: clip (eq. 2) + noise injection (eq. 1)
        if clips is not None:
            w_min, w_max = clips[li]
            if eta > 0.0:
                key, sub = jax.random.split(key)
                w = N.inject(w0, w_min, w_max, eta, sub)
            else:
                w = w0 + jax.lax.stop_gradient(
                    N.clip_weights(w0, w_min, w_max) - w0
                )
        else:
            w = w0

        if cfg.kind == "dw3x3":
            # compact/exact path (training only; CiM expansion at deploy time)
            assert ranges is None, "quantized training not supported for dw"
            y = L.apply_dw_compact(h, w, cfg.stride)
            n, ho, wo = y.shape[0], y.shape[1], y.shape[2]
            ch = cfg.in_ch
        else:
            if cfg.kind == "dense":
                h = jnp.mean(h, axis=(1, 2))        # global average pool
            m = L.layer_input_matrix(h, cfg)

            # ---- DAC -> analog GEMM -> ADC
            if ranges is not None and cfg.analog:
                w_max_l = jnp.maximum(jnp.abs(clips[li][0]),
                                      jnp.abs(clips[li][1]))
                r_adc = ranges["r_adc"][li]
                r_dac = Q.dac_range(r_adc, ranges["s"], w_max_l)
                mq = Q.fake_quant(m, r_dac, b_dac)
                if qnoise_p > 0.0:
                    key, sub = jax.random.split(key)
                    mq = Q.quant_noise(m, mq, qnoise_p, sub)
                a = jnp.dot(mq, w, preferred_element_type=jnp.float32)
                aq = Q.fake_quant(a, r_adc, b_adc)
                if qnoise_p > 0.0:
                    key, sub = jax.random.split(key)
                    aq = Q.quant_noise(a, aq, qnoise_p, sub)
                a = aq
            else:
                a = jnp.dot(m, w, preferred_element_type=jnp.float32)

            if cfg.kind == "dense":
                y = a + p["bias"]
                n, ho, wo, ch = y.shape[0], 1, 1, cfg.out_ch
            else:
                hh, ww = L.out_hw(h.shape[1], h.shape[2], cfg)
                y = a.reshape(h.shape[0], hh, ww, cfg.out_ch)
                n, ho, wo, ch = y.shape
                del n, ho, wo, ch

        # ---- digital domain: BN + ReLU
        if cfg.bn:
            if train:
                y, st = L.bn_train(y, p["gamma"], p["beta"], state[li])
            else:
                st = state[li]
                y = L.bn_apply(y, p["gamma"], p["beta"], st["mean"], st["var"])
            new_state.append(st)
        else:
            new_state.append({})
        if cfg.relu:
            y = jax.nn.relu(y)
        h = y

    return h, new_state


def loss_fn(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(logits, axis=1) == labels).astype(jnp.float32))
