"""Hand-rolled Adam + LR schedules (optax is unavailable in this offline env).

Operates on arbitrary pytrees via ``jax.tree_util``; supports per-leaf
learning-rate groups so the quantizer ranges can follow their own schedule
(Section 6.1: exponential decay 1e-3 -> 1e-4) while the weights follow cosine
decay.
"""

from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adam_init(params: Any) -> AdamState:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return AdamState(jnp.zeros((), jnp.int32), zeros,
                     jax.tree_util.tree_map(jnp.zeros_like, params))


def adam_update(grads: Any, state: AdamState, params: Any, lr,
                b1: float = 0.9, b2: float = 0.999,
                eps: float = 1e-8):
    """One Adam step; ``lr`` may be a scalar or a pytree matching params."""
    step = state.step + 1
    mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                state.mu, grads)
    nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                state.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    lr_tree = lr
    if not isinstance(lr, (dict, list, tuple)) and not hasattr(lr, "keys"):
        lr_tree = jax.tree_util.tree_map(lambda _: lr, params)

    def upd(p, m, v, l):
        mhat = m / bc1
        vhat = v / bc2
        return p - l * mhat / (jnp.sqrt(vhat) + eps)

    new_params = jax.tree_util.tree_map(upd, params, mu, nu, lr_tree)
    return new_params, AdamState(step, mu, nu)


def cosine_lr(base: float, total_steps: int) -> Callable[[int], float]:
    def sched(step):
        t = jnp.minimum(step, total_steps) / max(total_steps, 1)
        return base * 0.5 * (1 + jnp.cos(math.pi * t))
    return sched


def exp_decay_lr(init: float, final: float,
                 total_steps: int) -> Callable[[int], float]:
    rate = (final / init) ** (1.0 / max(total_steps, 1))
    def sched(step):
        return init * rate ** jnp.minimum(step, total_steps)
    return sched


def global_norm_clip(g: jnp.ndarray, thresh: float) -> jnp.ndarray:
    """Clip a single tensor's gradient by norm (used for S, Section 6.1)."""
    n = jnp.sqrt(jnp.sum(g * g))
    return g * jnp.minimum(1.0, thresh / (n + 1e-12))
