"""Two-stage HW-aware training (Section 4.2 / 6.1).

Stage 1: conventional training with weight clipping to +/- 2*std(W0); the
stds are recomputed from the *unclipped* weights every 10 steps.

Stage 2: starts from the stage-1 weights with the clipping ranges frozen,
adds Gaussian noise injection (eq. 1) and — for the full method — the DAC/ADC
quantizer nodes with learnable per-layer ADC ranges ``r_ADC,l`` and the
shared analog gain ``S`` (eq. 5-6), trained by gradient descent with the
stochastic quantization-noise trick (p=0.5) and a 0.01 gradient clip on S.
The stage-2 initial LR is 1/10 of stage 1 with the same cosine schedule; the
range LR decays exponentially 1e-3 -> 1e-4.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import cim, data, layers, optim
from .config import (CLIP_SIGMA, QUANT_NOISE_P, RANGE_LR_FINAL, RANGE_LR_INIT,
                     S_GRAD_CLIP, SIGMA_UPDATE_EVERY, ModelCfg, TrainCfg)


@dataclasses.dataclass
class Trained:
    """Everything the exporter needs, as host numpy."""
    model: ModelCfg
    params: List[Dict[str, np.ndarray]]
    bn_state: List[Dict[str, np.ndarray]]
    clips: np.ndarray                      # [L, 2] (w_min, w_max)
    ranges: Optional[Dict[str, np.ndarray]]  # {"s": (), "r_adc": [L]} or None
    adc_bits: Optional[int]
    fp_test_acc: float
    eta: float


def _to_np(tree):
    return jax.tree_util.tree_map(lambda a: np.asarray(a), tree)


def _batches(x: np.ndarray, y: np.ndarray, batch: int, steps: int, seed: int):
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    idx = rng.permutation(n)
    pos = 0
    for _ in range(steps):
        if pos + batch > n:
            idx = rng.permutation(n)
            pos = 0
        sel = idx[pos: pos + batch]
        pos += batch
        yield x[sel], y[sel]


def _clips_from_params(params, n_sigma: float = CLIP_SIGMA) -> jnp.ndarray:
    rows = []
    for p in params:
        s = jnp.std(p["w"])
        rows.append(jnp.stack([-n_sigma * s, n_sigma * s]))
    return jnp.stack(rows)


def evaluate(model: ModelCfg, params, bn_state, clips, x, y,
             ranges=None, adc_bits: int = 8, batch: int = 256) -> float:
    """Clean (noise-free) test accuracy; quantizers active iff ranges given."""
    clips_l = [(clips[i, 0], clips[i, 1]) for i in range(len(model.layers))]
    rng_arg = None
    if ranges is not None:
        rng_arg = {"s": jnp.asarray(ranges["s"]),
                   "r_adc": jnp.asarray(ranges["r_adc"])}

    @jax.jit
    def fwd(xb):
        logits, _ = cim.forward(model, params, bn_state, xb, train=False,
                                clips=clips_l, ranges=rng_arg,
                                adc_bits=adc_bits)
        return logits

    correct = 0
    for i in range(0, x.shape[0], batch):
        xb = jnp.asarray(x[i: i + batch])
        logits = fwd(xb)
        correct += int(np.sum(np.argmax(np.asarray(logits), 1) == y[i: i + batch]))
    return correct / x.shape[0]


# ---------------------------------------------------------------------------
# Stage 1
# ---------------------------------------------------------------------------

def train_stage1(model: ModelCfg, task: str, tcfg: TrainCfg,
                 log=print) -> Tuple[list, list, np.ndarray]:
    xtr, ytr = data.load(task, "train")
    key = jax.random.PRNGKey(tcfg.seed)
    params = layers.init_params(model, key)
    bn_state = layers.init_state(model)
    opt = optim.adam_init(params)
    sched = optim.cosine_lr(tcfg.lr_stage1, tcfg.steps_stage1)

    @jax.jit
    def step(params, bn_state, opt, clips, xb, yb, lr):
        clips_l = [(clips[i, 0], clips[i, 1]) for i in range(len(model.layers))]

        def lossf(p):
            logits, st = cim.forward(model, p, bn_state, xb, train=True,
                                     clips=clips_l)
            return cim.loss_fn(logits, yb), st

        (loss, st), grads = jax.value_and_grad(lossf, has_aux=True)(params)
        params, opt = optim.adam_update(grads, opt, params, lr)
        return params, st, opt, loss

    clips = _clips_from_params(params)
    t0 = time.time()
    for i, (xb, yb) in enumerate(
        _batches(xtr, ytr, tcfg.batch, tcfg.steps_stage1, tcfg.seed + 1)
    ):
        if i % SIGMA_UPDATE_EVERY == 0:
            clips = _clips_from_params(params)
        params, bn_state, opt, loss = step(
            params, bn_state, opt, clips,
            jnp.asarray(xb), jnp.asarray(yb), sched(i))
        if i % 100 == 0:
            log(f"  [stage1 {model.name}] step {i} loss {float(loss):.4f} "
                f"({time.time()-t0:.1f}s)")
    clips = _clips_from_params(params)
    return params, bn_state, np.asarray(clips)


# ---------------------------------------------------------------------------
# Stage 2
# ---------------------------------------------------------------------------

def train_stage2(model: ModelCfg, task: str, tcfg: TrainCfg,
                 params, bn_state, clips: np.ndarray, *,
                 quantized: bool, log=print):
    xtr, ytr = data.load(task, "train")
    clips_j = jnp.asarray(clips)
    nl = len(model.layers)
    sched_w = optim.cosine_lr(tcfg.lr_stage2, tcfg.steps_stage2)
    sched_r = optim.exp_decay_lr(RANGE_LR_INIT, RANGE_LR_FINAL,
                                 tcfg.steps_stage2)

    if quantized:
        # The paper initializes S and r_ADC,l at 1 and lets 200 epochs of
        # gradient descent find the ranges.  Our synthetic-task schedules are
        # two orders of magnitude shorter, so we seed both from the Appendix-C
        # calibration statistics instead (a bad init clips every
        # pre-activation and training never recovers); gradient descent then
        # refines them exactly as in the paper.
        from . import heuristics
        xcal, _ = data.load(task, "train")
        np_params = [{k: np.asarray(v) for k, v in p.items()} for p in params]
        heur = heuristics.calibrate_ranges(model, np_params, bn_state, clips,
                                           xcal[: min(256, len(xcal))])
        w_maxes = np.maximum(np.abs(clips[:, 0]), np.abs(clips[:, 1]))
        # Per-layer 'ideal' gain s_l = r_dac_tgt * W_max / r_adc_tgt; the
        # shared S is their geometric mean.  Each layer's ADC range is then
        # widened so that its implied DAC range never clips the calibrated
        # input percentile: r_adc = max(r_adc_tgt, r_dac_tgt * W_max / S) —
        # converter over-range loses resolution gracefully, clipping does not.
        s_per_layer = np.array([
            heur["r_dac"][li] * w_maxes[li] / max(heur["r_adc"][li], 1e-9)
            for li in range(nl)
        ])
        s_init = float(np.exp(np.mean(np.log(np.maximum(s_per_layer, 1e-9)))))
        r_adc_init = [
            max(heur["r_adc"][li],
                heur["r_dac"][li] * w_maxes[li] / s_init)
            for li in range(nl)
        ]
        log(f"  [stage2] range init: S={s_init:.4f} s_l spread "
            f"[{s_per_layer.min():.3f}..{s_per_layer.max():.3f}] "
            f"r_adc=[{min(r_adc_init):.3f}..{max(r_adc_init):.3f}]")
        train_vars = {
            "params": params,
            "s": jnp.asarray(s_init, jnp.float32),
            "r_adc": jnp.asarray(r_adc_init, jnp.float32),
        }
    else:
        train_vars = {"params": params}
    opt = optim.adam_init(train_vars)

    @jax.jit
    def step(tv, bn_state, opt, xb, yb, lr_w, lr_r, key):
        clips_l = [(clips_j[i, 0], clips_j[i, 1]) for i in range(nl)]

        def lossf(tv):
            ranges = None
            qn = 0.0
            if quantized:
                ranges = {"s": tv["s"], "r_adc": tv["r_adc"]}
                qn = QUANT_NOISE_P
            logits, st = cim.forward(
                model, tv["params"], bn_state, xb, train=True, key=key,
                eta=tcfg.eta, clips=clips_l, ranges=ranges,
                adc_bits=tcfg.adc_bits, qnoise_p=qn)
            return cim.loss_fn(logits, yb), st

        (loss, st), grads = jax.value_and_grad(lossf, has_aux=True)(tv)
        if quantized:
            # Section 6.1: clip the gradient of S at 0.01 for stability
            grads["s"] = optim.global_norm_clip(grads["s"], S_GRAD_CLIP)
        lr_tree = jax.tree_util.tree_map(lambda _: lr_w, tv)
        if quantized:
            lr_tree["s"] = lr_r
            lr_tree["r_adc"] = jax.tree_util.tree_map(
                lambda _: lr_r, tv["r_adc"])
        tv, opt = optim.adam_update(grads, opt, tv, lr_tree)
        return tv, st, opt, loss

    key = jax.random.PRNGKey(tcfg.seed + 777)
    t0 = time.time()
    for i, (xb, yb) in enumerate(
        _batches(xtr, ytr, tcfg.batch, tcfg.steps_stage2, tcfg.seed + 2)
    ):
        key, sub = jax.random.split(key)
        train_vars, bn_state, opt, loss = step(
            train_vars, bn_state, opt, jnp.asarray(xb), jnp.asarray(yb),
            sched_w(i), sched_r(i), sub)
        if i % 100 == 0:
            log(f"  [stage2 {model.name} q={quantized} b={tcfg.adc_bits} "
                f"eta={tcfg.eta}] step {i} loss {float(loss):.4f} "
                f"({time.time()-t0:.1f}s)")

    params = train_vars["params"]
    ranges = None
    if quantized:
        ranges = {"s": np.asarray(train_vars["s"]),
                  "r_adc": np.asarray(train_vars["r_adc"])}
    return params, bn_state, ranges, np.asarray(clips)


# ---------------------------------------------------------------------------
# Variant driver
# ---------------------------------------------------------------------------

def _finish(model: ModelCfg, task: str, tcfg: TrainCfg, params, bn_state,
            clips, ranges, adc_bits, variant: str, log) -> Trained:
    xte, yte = data.load(task, "test")
    acc = evaluate(model, params, bn_state, jnp.asarray(clips), xte, yte,
                   ranges=ranges, adc_bits=tcfg.adc_bits)
    log(f"[train] {model.name}/{variant}: clean test acc {acc*100:.2f}%")
    return Trained(model=model, params=_to_np(params),
                   bn_state=_to_np(bn_state), clips=np.asarray(clips),
                   ranges=_to_np(ranges) if ranges is not None else None,
                   adc_bits=adc_bits, fp_test_acc=float(acc), eta=tcfg.eta)


def run_stage1(model: ModelCfg, task: str, tcfg: TrainCfg, log=print) -> Trained:
    """Stage-1-only model: the 'baseline, no re-training' ablation row.

    Shared by every stage-2 variant of the same model (cached by aot.py).
    """
    tcfg = tcfg.scaled()
    log(f"[train] {model.name} / stage1")
    params, bn_state, clips = train_stage1(model, task, tcfg, log=log)
    return _finish(model, task, tcfg, params, bn_state, clips, None, None,
                   "base", log)


def run_stage2(model: ModelCfg, task: str, tcfg: TrainCfg, stage1: Trained,
               variant: str, log=print) -> Trained:
    """variant: 'noise' (stage 2 w/o quantizers) or 'full' (with quantizers
    at tcfg.adc_bits), starting from a cached stage-1 model."""
    tcfg = tcfg.scaled()
    log(f"[train] {model.name} / {variant} / eta={tcfg.eta} "
        f"bits={tcfg.adc_bits}")
    quantized = variant == "full"
    if variant not in ("noise", "full"):
        raise ValueError(variant)
    params = [{k: jnp.asarray(v) for k, v in p.items()}
              for p in stage1.params]
    bn_state = [{k: jnp.asarray(v) for k, v in s.items()}
                for s in stage1.bn_state]
    params, bn_state, ranges, clips = train_stage2(
        model, task, tcfg, params, bn_state, stage1.clips,
        quantized=quantized, log=log)
    return _finish(model, task, tcfg, params, bn_state, clips, ranges,
                   tcfg.adc_bits if quantized else None, variant, log)
