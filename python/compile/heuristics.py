"""Heuristic DAC/ADC range selection (Appendix C).

Used for the ablation variants that were *not* trained with quantizer nodes
('baseline, no re-training' and 'vanilla noise injection'): the DAC range of
layer ``l`` is the 99.995th percentile of its input activations on a
calibration batch, and the ADC range covers ``n_std_out = 4`` standard
deviations of the pre-activation distribution (the pre-activation-space
equivalent of the paper's conductance-space eq. 7 — see DESIGN.md S9).
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from . import cim, layers as L
from .config import HEUR_IN_PERCENTILE, HEUR_N_STD_OUT, ModelCfg


def calibrate_ranges(model: ModelCfg, params, bn_state, clips: np.ndarray,
                     x_calib: np.ndarray) -> Dict[str, List[float]]:
    """Run a clean FP forward pass and record per-layer range statistics.

    Returns {"r_dac": [L], "r_adc": [L]} in the same units the quantizer
    nodes use (activations / pre-activations in weight units).
    """
    acts: List[np.ndarray] = []
    preacts: List[np.ndarray] = []

    @jax.jit
    def run(xb):
        outs_in = []
        outs_pre = []
        h = xb
        for li, cfg in enumerate(model.layers):
            p = params[li]
            w = jnp.clip(p["w"], clips[li, 0], clips[li, 1])
            if cfg.kind == "dw3x3":
                y = L.apply_dw_compact(h, w, cfg.stride)
                m = L.layer_input_matrix(h, cfg)
                outs_in.append(jnp.max(jnp.abs(m)))
                outs_pre.append(jnp.std(y))
            else:
                if cfg.kind == "dense":
                    h = jnp.mean(h, axis=(1, 2))
                m = L.layer_input_matrix(h, cfg)
                a = jnp.dot(m, w)
                # percentile tracked on |input|; std on pre-activations
                outs_in.append(jnp.percentile(jnp.abs(m), HEUR_IN_PERCENTILE))
                outs_pre.append(jnp.std(a))
                if cfg.kind == "dense":
                    y = a + p["bias"]
                else:
                    hh, ww = L.out_hw(h.shape[1], h.shape[2], cfg)
                    y = a.reshape(h.shape[0], hh, ww, cfg.out_ch)
            if cfg.bn:
                st = bn_state[li]
                y = L.bn_apply(y, p["gamma"], p["beta"], st["mean"], st["var"])
            if cfg.relu:
                y = jax.nn.relu(y)
            h = y
        return outs_in, outs_pre

    outs_in, outs_pre = run(jnp.asarray(x_calib))
    acts = [float(v) for v in outs_in]
    preacts = [float(v) for v in outs_pre]

    r_dac = [max(a, 1e-6) for a in acts]
    r_adc = [max(HEUR_N_STD_OUT * s, 1e-6) for s in preacts]
    return {"r_dac": r_dac, "r_adc": r_adc}
