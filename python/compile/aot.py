"""AOT driver: train (with caching) and export every artifact bundle.

Run once via ``make artifacts``; Python never appears on the request path
afterwards.  ``--sweep`` additionally trains the Figure-7 eta sweep.

Artifacts per variant (see DESIGN.md section 7):
    <vid>.meta.json            layer table + ranges + digital affines
    <vid>.weights.bin          compact trained clipped weights (ANWT)
    <vid>_<bits>b_b<batch>.hlo.txt   inference graphs
plus <task>_test.bin datasets, cim_mvm.hlo.txt, manifest.json.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from typing import Dict, List, Optional

import numpy as np

from . import config, data, export, heuristics, train as T
from .config import EVAL_BATCH, SERVE_BATCHES, TrainCfg
from .models import get_model

# Step budgets are scaled to the synthetic tasks (they converge in a couple
# hundred steps) and to the single-core build machine; the paper's
# 100/200-epoch schedules are unnecessary here.
KWS_TCFG = TrainCfg(steps_stage1=150, steps_stage2=120, batch=32,
                    lr_stage1=3e-3, lr_stage2=3e-4)
VWW_TCFG = TrainCfg(steps_stage1=150, steps_stage2=120, batch=16,
                    lr_stage1=3e-3, lr_stage2=3e-4)


def log(*a):
    print(*a, flush=True)


# ---------------------------------------------------------------------------
# Training cache (np.savez of the flattened Trained struct)
# ---------------------------------------------------------------------------

def _cache_key(model_name: str, variant: str, tcfg: TrainCfg) -> str:
    t = tcfg.scaled()
    if variant == "base":
        # stage 1 is independent of eta/bits: shared by all stage-2 variants
        return f"{model_name}__base__s{t.steps_stage1}__seed{t.seed}"
    return (f"{model_name}__{variant}__s{t.steps_stage1}-{t.steps_stage2}"
            f"__e{t.eta}__b{t.adc_bits}__seed{t.seed}")


def save_trained(path: str, tr: T.Trained) -> None:
    flat: Dict[str, np.ndarray] = {}
    for li, p in enumerate(tr.params):
        for k, v in p.items():
            flat[f"p{li}/{k}"] = v
    for li, s in enumerate(tr.bn_state):
        for k, v in s.items():
            flat[f"s{li}/{k}"] = v
    flat["clips"] = tr.clips
    if tr.ranges is not None:
        flat["ranges/s"] = tr.ranges["s"]
        flat["ranges/r_adc"] = tr.ranges["r_adc"]
    flat["meta"] = np.array([tr.fp_test_acc, tr.eta,
                             -1.0 if tr.adc_bits is None else tr.adc_bits])
    np.savez(path, **flat)


def load_trained(path: str, model) -> Optional[T.Trained]:
    if not os.path.exists(path):
        return None
    z = np.load(path)
    params, bn_state = [], []
    for li in range(len(model.layers)):
        params.append({k.split("/")[1]: z[k] for k in z.files
                       if k.startswith(f"p{li}/")})
        bn_state.append({k.split("/")[1]: z[k] for k in z.files
                         if k.startswith(f"s{li}/")})
    ranges = None
    if "ranges/s" in z.files:
        ranges = {"s": z["ranges/s"], "r_adc": z["ranges/r_adc"]}
    acc, eta, bits = [float(v) for v in z["meta"]]
    return T.Trained(model=model, params=params, bn_state=bn_state,
                     clips=z["clips"], ranges=ranges,
                     adc_bits=None if bits < 0 else int(bits),
                     fp_test_acc=acc, eta=eta)


def get_trained(model_name: str, task: str, variant: str, tcfg: TrainCfg,
                cache_dir: str) -> T.Trained:
    model = get_model(model_name)
    key = _cache_key(model_name, variant, tcfg)
    path = os.path.join(cache_dir, key + ".npz")
    tr = load_trained(path, model)
    if tr is not None:
        log(f"[cache] hit {key} (fp acc {tr.fp_test_acc*100:.2f}%)")
        return tr
    if variant == "base":
        tr = T.run_stage1(model, task, tcfg, log=log)
    else:
        stage1 = get_trained(model_name, task, "base", tcfg, cache_dir)
        tr = T.run_stage2(model, task, tcfg, stage1, variant, log=log)
    save_trained(path, tr)
    return tr


# ---------------------------------------------------------------------------
# Per-variant export
# ---------------------------------------------------------------------------

def export_variant(vid: str, tr: T.Trained, task: str, out_dir: str,
                   bits_list: List[int], batches: Dict[int, List[int]],
                   digital_dw: bool = False) -> dict:
    """Export one bundle; returns its manifest entry."""
    model = tr.model
    if digital_dw:
        new_layers = tuple(
            dataclasses.replace(l, analog=False) if l.kind == "dw3x3" else l
            for l in model.layers)
        model = dataclasses.replace(model, layers=new_layers)
        tr = dataclasses.replace(tr, model=model)

    infos = export.layer_export_info(tr)
    heur = None
    if tr.ranges is None:
        xcal, _ = data.load(task, "train")
        heur = heuristics.calibrate_ranges(
            model, [{k: np.asarray(v) for k, v in p.items()}
                    for p in tr.params],
            tr.bn_state, tr.clips, xcal[:256])
    hlo_files = {}
    for bits in bits_list:
        export.resolve_ranges(tr, infos, bits, heur)
        for batch in batches.get(bits, [EVAL_BATCH]):
            name = f"{vid}_{bits}b_b{batch}.hlo.txt"
            t0 = time.time()
            export.export_hlo(model, infos, bits, batch,
                              os.path.join(out_dir, name))
            log(f"[hlo] {name} ({time.time()-t0:.1f}s)")
            hlo_files[f"{bits}b_b{batch}"] = name
    # meta/weights use the ranges of the *last* resolve; re-resolve at 8b for
    # a deterministic meta (per-bitwidth ranges are identical for heuristic
    # variants and bitwidth-specific HLOs already bake their own).
    export.resolve_ranges(tr, infos, bits_list[0], heur)
    export.write_weights_bin(os.path.join(out_dir, f"{vid}.weights.bin"), infos)
    export.write_meta_json(
        os.path.join(out_dir, f"{vid}.meta.json"), model, infos, tr, vid,
        hlo_files, export.layer_input_hws(model))
    return {"vid": vid, "task": task, "model": model.name,
            "variant_kind": vid.split("_")[1] if "_" in vid else vid,
            "eta": tr.eta, "trained_bits": tr.adc_bits,
            "fp_test_acc": tr.fp_test_acc,
            "meta": f"{vid}.meta.json",
            "weights": f"{vid}.weights.bin", "hlo": hlo_files}


# ---------------------------------------------------------------------------
# Main build plan
# ---------------------------------------------------------------------------

def build(out_dir: str, sweep: bool, only: Optional[str] = None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    cache_dir = os.path.join(out_dir, "cache")
    os.makedirs(cache_dir, exist_ok=True)

    manifest: List[dict] = []

    def want(vid: str) -> bool:
        return only is None or only in vid

    # -- datasets ----------------------------------------------------------
    for task in ("kws", "vww"):
        p = os.path.join(out_dir, f"{task}_test.bin")
        if not os.path.exists(p):
            x, y = data.load(task, "test")
            data.write_dataset_bin(p, x, y)
            log(f"[data] wrote {p} ({x.shape})")

    # -- standalone L1 kernel ----------------------------------------------
    demo = os.path.join(out_dir, "cim_mvm.hlo.txt")
    if not os.path.exists(demo):
        export.export_cim_mvm_demo(demo)
        log(f"[hlo] {demo}")

    all_bits = [8, 6, 4]

    def plan_task(task: str, model_name: str, prefix: str, tcfg: TrainCfg):
        # baseline: stage-1 weights, heuristic ranges, all bitwidths
        vid = f"{prefix}_base"
        if want(vid):
            tr = get_trained(model_name, task, "base", tcfg, cache_dir)
            manifest.append(export_variant(
                vid, tr, task, out_dir, all_bits, {}))
        # vanilla noise injection (Joshi et al.)
        vid = f"{prefix}_noise_e10"
        if want(vid):
            tr = get_trained(model_name, task, "noise", tcfg, cache_dir)
            manifest.append(export_variant(
                vid, tr, task, out_dir, all_bits, {}))
        # full method, one trained model per bitwidth
        etas = [0.10]
        if sweep:
            etas = ([0.02, 0.05, 0.10, 0.20] if task == "kws"
                    else [0.05, 0.10, 0.20])
        for eta in etas:
            for bits in all_bits:
                e = int(round(eta * 100))
                vid = f"{prefix}_full_e{e}_{bits}b"
                if not want(vid):
                    continue
                tc = dataclasses.replace(tcfg, eta=eta, adc_bits=bits)
                tr = get_trained(model_name, task, "full", tc, cache_dir)
                batches = {}
                if eta == 0.10 and bits == 8:
                    batches = {8: [EVAL_BATCH] + list(SERVE_BATCHES)}
                manifest.append(export_variant(
                    vid, tr, task, out_dir, [bits], batches))

    plan_task("kws", "analognet_kws", "kws", KWS_TCFG)
    plan_task("vww", "analognet_vww", "vww", VWW_TCFG)

    # -- VWW bottleneck ablation (Table 1 last row) -------------------------
    for bits in all_bits:
        vid = f"vwwbott_full_e10_{bits}b"
        if want(vid):
            tc = dataclasses.replace(VWW_TCFG, adc_bits=bits)
            tr = get_trained("analognet_vww_bottleneck", "vww", "full", tc,
                             cache_dir)
            manifest.append(export_variant(vid, tr, "vww", out_dir, [bits], {}))

    # -- MicroNet-KWS-S depthwise baseline (Fig 9 / Table 3 / Fig 11) -------
    vid = "micro_noise_e10"
    if want(vid):
        tr = get_trained("micronet_kws_s", "kws", "noise", KWS_TCFG, cache_dir)
        manifest.append(export_variant(vid, tr, "kws", out_dir, all_bits, {}))
        # depthwise-on-digital-processor ablation shares the same weights
        manifest.append(export_variant(
            "microdig_noise_e10", tr, "kws", out_dir, all_bits, {},
            digital_dw=True))

    mpath = os.path.join(out_dir, "manifest.json")
    existing = []
    if only is not None and os.path.exists(mpath):
        with open(mpath) as f:
            existing = [e for e in json.load(f)
                        if all(e["vid"] != m["vid"] for m in manifest)]
    with open(mpath, "w") as f:
        json.dump(existing + manifest, f, indent=1)
    log(f"[done] {len(manifest)} variants -> {mpath}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--sweep", action="store_true",
                    help="also train the Figure-7 eta sweep variants")
    ap.add_argument("--only", default=None,
                    help="only (re)build variants whose id contains this")
    args = ap.parse_args()
    t0 = time.time()
    build(args.out, args.sweep, args.only)
    log(f"[aot] total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
