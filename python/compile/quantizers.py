"""DAC/ADC quantizer abstractions (Section 4.2, eq. 3-6).

Both converters are modeled as symmetric uniform fake-quantizers with a
*learnable* range ``r_max`` (eq. 4), differentiable in both the input and the
range via the straight-through estimator.  The fixed analog ADC gain
constraint (eq. 5) ties the per-layer DAC range to the per-layer ADC range
through a single shared scalar ``S``:

    r_DAC,l = r_ADC,l * |S| / W_l,max
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def round_ste(x: jnp.ndarray) -> jnp.ndarray:
    """round() with identity gradient (Bengio et al., 2013)."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def fake_quant(x: jnp.ndarray, r_max: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Symmetric uniform fake quantization, eq. (4), in 'dequantized' units.

    Differentiable w.r.t. both ``x`` (inside the clip range) and ``r_max``
    (through the step size and the clip boundaries).
    """
    r_max = jnp.abs(r_max) + 1e-9          # ranges must stay positive
    levels = float(2 ** (bits - 1) - 1)
    step = r_max / levels
    xc = jnp.clip(x, -r_max, r_max)
    return round_ste(xc / step) * step


def quant_codes(x: jnp.ndarray, r_max: float, bits: int) -> jnp.ndarray:
    """Integer codes in [-(2^{b-1}-1), 2^{b-1}-1] (hardware-side view)."""
    levels = float(2 ** (bits - 1) - 1)
    step = r_max / levels
    return jnp.round(jnp.clip(x, -r_max, r_max) / step)


def quant_noise(x: jnp.ndarray, xq: jnp.ndarray, p: float,
                key: jax.Array) -> jnp.ndarray:
    """Stochastic 'quantization noise' (Fan et al., 2020).

    Each element is quantized with probability ``p`` and passed through
    unquantized otherwise; accelerates convergence at low bitwidths.
    """
    if p >= 1.0:
        return xq
    mask = jax.random.bernoulli(key, p, x.shape)
    return jnp.where(mask, xq, x)


def dac_range(r_adc: jnp.ndarray, s: jnp.ndarray, w_max: float) -> jnp.ndarray:
    """eq. (5) solved for the DAC range; |S| keeps ranges positive during GD."""
    return r_adc * jnp.abs(s) / w_max
