"""Model zoo: AnalogNet-KWS, AnalogNet-VWW (+bottleneck ablation) and the
MicroNet-KWS-S depthwise baseline."""

from .analognet_kws import analognet_kws
from .analognet_vww import analognet_vww
from .micronet_kws_s import micronet_kws_s

from ..config import ModelCfg


def get_model(name: str) -> ModelCfg:
    if name == "analognet_kws":
        return analognet_kws()
    if name == "analognet_vww":
        return analognet_vww(bottleneck=False)
    if name == "analognet_vww_bottleneck":
        return analognet_vww(bottleneck=True)
    if name == "micronet_kws_s":
        return micronet_kws_s()
    raise ValueError(f"unknown model {name}")
