"""MicroNet-KWS-S-like depthwise baseline (Banbury et al., 2021).

The depthwise-separable comparison model for the Appendix A / Figure 9 / Table
3 / Figure 11 experiments.  Depthwise layers are stored compactly as [9, C]
but deploy to the CiM array in dense-expanded [9C, C] form with a non-zero
diagonal — the mapper and the PCM evaluator both use that expansion, so the
unused (zero-programmed) cells contribute programming/read noise to the
bitlines exactly as Section 4.1 describes.
"""

from __future__ import annotations

from ..config import LayerCfg, ModelCfg


def micronet_kws_s() -> ModelCfg:
    layers = (
        LayerCfg("stem", "conv3x3", 1, 84, stride=(2, 1)),       # 49x10 -> 25x10
        LayerCfg("dw1", "dw3x3", 84, 84, stride=(1, 1)),
        LayerCfg("pw1", "conv1x1", 84, 112),
        LayerCfg("dw2", "dw3x3", 112, 112, stride=(2, 2)),       # 25x10 -> 13x5
        LayerCfg("pw2", "conv1x1", 112, 112),
        LayerCfg("dw3", "dw3x3", 112, 112, stride=(1, 1)),
        LayerCfg("pw3", "conv1x1", 112, 144),
        LayerCfg("fc", "dense", 144, 12, bn=False, relu=False),
    )
    return ModelCfg("micronet_kws_s", (49, 10, 1), 12, layers)
