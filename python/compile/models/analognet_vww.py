"""AnalogNet-VWW (Section 4.1, Appendix B).

MobileNetV2-style backbone with every inverted-bottleneck MBConv replaced by
a *fused*-MBConv (regular 3x3 expansion conv + 1x1 projection, Tan & Le), and
the two early narrow bottleneck layers removed (Figure 3 right).  The
``bottleneck=True`` variant adds those narrow layers back for the Table 1
ablation (last row): few parameters, all signal squeezed through 8 channels —
exactly the noise bottleneck the paper warns about.
"""

from __future__ import annotations

from ..config import LayerCfg, ModelCfg


def analognet_vww(bottleneck: bool = False) -> ModelCfg:
    layers = [
        LayerCfg("stem", "conv3x3", 3, 24, stride=(2, 2)),        # 100 -> 50
    ]
    if bottleneck:
        # the removed noise-bottleneck layers of Figure 3 (right)
        layers += [
            LayerCfg("squeeze", "conv1x1", 24, 8),                # narrow!
            LayerCfg("expandb", "conv3x3", 8, 24, stride=(1, 1)),
        ]
    layers += [
        # fused-MBConv A: expand 3x3 s2 + project 1x1
        LayerCfg("a_exp", "conv3x3", 24, 96, stride=(2, 2)),      # 50 -> 25
        LayerCfg("a_proj", "conv1x1", 96, 32, relu=False),
        # fused-MBConv B
        LayerCfg("b_exp", "conv3x3", 32, 128, stride=(2, 2)),     # 25 -> 13
        LayerCfg("b_proj", "conv1x1", 128, 56, relu=False),
        # fused-MBConv C (stride 1)
        LayerCfg("c_exp", "conv3x3", 56, 208, stride=(1, 1)),     # 13
        LayerCfg("c_proj", "conv1x1", 208, 64, relu=False),
        # fused-MBConv D
        LayerCfg("d_exp", "conv3x3", 64, 240, stride=(2, 2)),     # 13 -> 7
        LayerCfg("d_proj", "conv1x1", 240, 88, relu=False),
        LayerCfg("fc", "dense", 88, 2, bn=False, relu=False),
    ]
    # 346,168 weights -> 66.0% of the 1024x512 array (paper: 67.5%)
    name = "analognet_vww_bottleneck" if bottleneck else "analognet_vww"
    return ModelCfg(name, (100, 100, 3), 2, tuple(layers))
