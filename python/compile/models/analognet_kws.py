"""AnalogNet-KWS (Section 4.1, Appendix B).

Derived from MicroNet-KWS-S with the CiM-specific edits the paper describes:
every depthwise-separable block is replaced by a regular 3x3 convolution and
the parameter-heavy 196-channel head is removed so the model fits the
1024x512 differential array without splitting any layer.  Channel widths are
chosen to land at the paper's reported ~57% array utilization (Figure 6
left); the mapper measures the exact figure.
"""

from __future__ import annotations

from ..config import LayerCfg, ModelCfg


def analognet_kws() -> ModelCfg:
    layers = (
        LayerCfg("conv0", "conv3x3", 1, 64, stride=(2, 1)),    # 49x10 -> 25x10
        LayerCfg("conv1", "conv3x3", 64, 64, stride=(1, 1)),   # 25x10
        LayerCfg("conv2", "conv3x3", 64, 88, stride=(2, 2)),   # 25x10 -> 13x5
        LayerCfg("conv3", "conv3x3", 88, 112, stride=(1, 1)),  # 13x5
        LayerCfg("conv4", "conv3x3", 112, 128, stride=(1, 1)), # 13x5
        # global average pool happens before this dense classifier
        LayerCfg("fc", "dense", 128, 12, bn=False, relu=False),
    )
    # 307,392 weights -> 58.6% of the 1024x512 array (paper: 57.3%)
    return ModelCfg("analognet_kws", (49, 10, 1), 12, layers)
