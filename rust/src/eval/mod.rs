//! Drift-accuracy evaluation: the engine behind Table 1, Figure 7, Figure 9.
//!
//! Per run: program the variant's weights into simulated PCM (programming
//! noise + per-device drift exponents), then for each requested time point
//! read the conductances (drift + 1/f noise), compute the per-layer GDC
//! factors, and execute the test set through an [`InferenceBackend`] —
//! the native simulator by default, the tile-faithful AnalogCim engine, or
//! the exported HLO graphs via PJRT ([`EvalOpts::backend`]). The physics is
//! identical every way; only the execution engine changes. Sweep either
//! the paper's Figure-7 time points or a single `--t-drift` override
//! ([`EvalOpts::sweep_times`]).

use std::sync::Arc;

use crate::backend::{self, BackendKind, HostTensor, InferOpts,
                     InferenceBackend};
use crate::crossbar::ArrayGeom;
use crate::nn::{expand_dw_dense, LayerKind, ModelMeta, Tensor};
use crate::pcm::{gdc, FaultSpec, LayerGdc, PcmParams, ProgrammedWeights};
use crate::runtime::ArtifactStore;
use crate::util::logits;
use crate::util::rng::Rng;

/// One layer's deployed state: PCM-programmed (analog) or exact (digital).
#[derive(Clone)]
pub enum DeployedLayer {
    Analog(ProgrammedWeights),
    Digital(Tensor),
}

/// A variant programmed onto the simulated PCM array. `Clone` on purpose:
/// the serving coordinator keeps a pristine copy and derives faulted
/// deployments from it without reprogramming.
#[derive(Clone)]
pub struct DeployedModel {
    pub meta: Arc<ModelMeta>,
    pub layers: Vec<DeployedLayer>,
}

impl DeployedModel {
    /// Program `vid`'s weights (expanding depthwise layers to their dense
    /// CiM form so the zero cells are physically programmed).
    pub fn program(store: &ArtifactStore, vid: &str, params: &PcmParams,
                   rng: &mut Rng) -> anyhow::Result<Self> {
        let meta = store.meta(vid)?;
        let tensors = store.weights(vid)?;
        anyhow::ensure!(tensors.len() == meta.layers.len(), "weights/meta mismatch");
        let mut layers = Vec::new();
        for (lm, t) in meta.layers.iter().zip(tensors.iter()) {
            if !lm.analog {
                layers.push(DeployedLayer::Digital(t.clone()));
                continue;
            }
            let dense = if lm.kind == LayerKind::Dw3x3 {
                expand_dw_dense(t)
            } else {
                t.clone()
            };
            let (rows, cols) = (dense.shape[0], dense.shape[1]);
            let mut lrng = rng.fork(layers.len() as u64 + 1);
            layers.push(DeployedLayer::Analog(ProgrammedWeights::program(
                &dense.data, rows, cols, lm.w_scale, params, &mut lrng,
            )));
        }
        Ok(DeployedModel { meta, layers })
    }

    /// Stamp a device-variability scenario onto the programmed array:
    /// stuck cells and extra conductance spread per analog layer, seeded
    /// by `(spec.seed, layer index)` so the pattern is a property of the
    /// spec alone (see `pcm::fault`). Digital layers are untouched. A
    /// weightless spec is a no-op; call on a fresh program (re-applying
    /// compounds the conductance jitter).
    pub fn apply_faults(&mut self, spec: &FaultSpec) {
        for (li, dl) in self.layers.iter_mut().enumerate() {
            if let DeployedLayer::Analog(p) = dl {
                p.apply_faults(spec, li);
            }
        }
    }

    /// Effective weight tensors + GDC vector at `t` seconds after
    /// programming, with uniform (layer-wide) drift compensation.
    pub fn read_at(&self, t_seconds: f64, params: &PcmParams, rng: &mut Rng,
                   use_gdc: bool) -> (Vec<HostTensor>, Vec<LayerGdc>) {
        self.read_at_calibrated(t_seconds, params, rng, use_gdc, None)
    }

    /// [`read_at`](Self::read_at) with per-tile GDC calibration: when
    /// `calib` names a tile geometry (take it from
    /// [`InferenceBackend::calib_geom`]), each analog layer's factors come
    /// from [`gdc::calibrate`] — every `tile_grid` tile gets its own alpha
    /// computed from that tile's actual (possibly faulted) conductance
    /// slice. `None` degenerates to the uniform read bit for bit.
    pub fn read_at_calibrated(&self, t_seconds: f64, params: &PcmParams,
                              rng: &mut Rng, use_gdc: bool,
                              calib: Option<ArrayGeom>)
                              -> (Vec<HostTensor>, Vec<LayerGdc>) {
        let mut ws = Vec::with_capacity(self.layers.len());
        let mut alphas = Vec::with_capacity(self.layers.len());
        for dl in self.layers.iter() {
            match dl {
                DeployedLayer::Analog(p) => {
                    let w = p.read_weights(t_seconds, params, rng);
                    ws.push(HostTensor::new(vec![p.rows, p.cols], w));
                    alphas.push(if use_gdc {
                        gdc::calibrate(p, t_seconds, calib)
                    } else {
                        LayerGdc::flat(1.0)
                    });
                }
                DeployedLayer::Digital(t) => {
                    ws.push(HostTensor::from_tensor(t));
                    alphas.push(LayerGdc::flat(1.0));
                }
            }
        }
        (ws, alphas)
    }
}

/// Options for an accuracy evaluation.
#[derive(Clone, Debug)]
pub struct EvalOpts {
    pub bits: u32,
    pub batch: usize,
    /// evaluate at most this many test samples (paper uses the full set; we
    /// default to a subset to keep CPU sweeps tractable — see EXPERIMENTS.md)
    pub max_samples: usize,
    pub runs: usize,
    pub seed: u64,
    pub use_gdc: bool,
    pub params: PcmParams,
    /// which execution engine runs the test set
    pub backend: BackendKind,
    /// single drift-time override in seconds (`--t-drift` on the CLI):
    /// when set, [`EvalOpts::sweep_times`] collapses the Figure-7 sweep to
    /// this one time point — evaluate a day-old or year-old array directly
    pub t_drift: Option<f64>,
    /// per-request ADC bitwidth override (`--adc-bits` on the CLI): every
    /// `run_batch` of the evaluation executes under
    /// `InferOpts { adc_bits, .. }`, so e.g. the paper's Table-2 4-bit
    /// serving scenario evaluates against artifacts exported at 8 bits
    /// without re-exporting. `None` keeps the backend's configured
    /// [`bits`](Self::bits). Weight-fed engines only (PJRT graphs are
    /// compiled at one bitwidth and reject overrides).
    pub adc_bits: Option<u32>,
    /// device-variability scenario (`--faults` on the CLI): stuck cells
    /// and conductance spread are stamped onto every programming run
    /// before reading; ADC gain/offset errors ride each `run_batch` via
    /// `InferOpts::faults`. [`FaultSpec::none()`] (the default) leaves
    /// every path bit-identical to a fault-free evaluation.
    pub faults: FaultSpec,
}

impl Default for EvalOpts {
    fn default() -> Self {
        EvalOpts {
            bits: 8,
            batch: 128,
            max_samples: 256,
            runs: 5,
            seed: 0xA11A,
            use_gdc: true,
            params: PcmParams::default(),
            backend: BackendKind::default(),
            t_drift: None,
            adc_bits: None,
            faults: FaultSpec::none(),
        }
    }
}

impl EvalOpts {
    /// Time points a drift sweep should cover: the single
    /// [`t_drift`](Self::t_drift) override when set, the paper's Figure-7
    /// sweep (25 s → 1 yr) otherwise. The shared source of truth for the
    /// CLI `eval` command and the CI analog-smoke gate.
    pub fn sweep_times(&self) -> Vec<f64> {
        match self.t_drift {
            Some(t) => vec![t],
            None => crate::pcm::FIG7_TIMES.iter().map(|(_, t)| *t).collect(),
        }
    }
}

/// Accuracy of `vid` at each `times[i]` seconds, for `opts.runs` independent
/// programming runs, on the backend selected by `opts.backend`. Returns
/// `accs[time_idx][run_idx]` in [0, 1].
pub fn drift_accuracy(store: &ArtifactStore, vid: &str, times: &[f64],
                      opts: &EvalOpts) -> anyhow::Result<Vec<Vec<f64>>> {
    let be = backend::create(opts.backend, store, vid, opts.bits)?;
    drift_accuracy_on(be.as_ref(), store, vid, times, opts)
}

/// Like [`drift_accuracy`], over a caller-constructed backend — the
/// extension hook for custom engines (anything implementing
/// [`InferenceBackend`]) and for pinning the engine explicitly in tests.
pub fn drift_accuracy_on(be: &dyn InferenceBackend, store: &ArtifactStore,
                         vid: &str, times: &[f64], opts: &EvalOpts)
                         -> anyhow::Result<Vec<Vec<f64>>> {
    let meta = store.meta(vid)?;
    let task = if meta.model.contains("vww") { "vww" } else { "kws" };
    let ds = store.dataset(task)?;
    let n = ds.len().min(opts.max_samples);
    anyhow::ensure!(n > 0, "dataset for {task} is empty");
    be.prepare(opts.batch)?;
    let classes = meta.num_classes;
    let (ih, iw, ic) = meta.input_hwc;
    // the per-request options every launch of this evaluation runs under
    // (drift time is expressed through `times` / the weight read, not
    // here); a none-spec stays out of the opts so the fault-free path is
    // bit-identical to the pre-fault evaluator
    let iopts = InferOpts {
        t_drift: None,
        adc_bits: opts.adc_bits,
        adc_bits_floor: None,
        faults: (!opts.faults.is_none()).then_some(opts.faults),
    };
    // per-tile GDC calibration kicks in only for engines that quantize
    // per tile (and only when drift compensation is on at all)
    let calib = if opts.use_gdc { be.calib_geom() } else { None };

    let mut out = vec![Vec::with_capacity(opts.runs); times.len()];
    for run in 0..opts.runs {
        let mut rng = Rng::new(opts.seed ^ (run as u64).wrapping_mul(0x9E37));
        let mut dep = DeployedModel::program(store, vid, &opts.params, &mut rng)?;
        if opts.faults.has_weight_faults() {
            dep.apply_faults(&opts.faults);
        }
        for (ti, &t) in times.iter().enumerate() {
            let (ws, alphas) = dep.read_at_calibrated(t, &opts.params, &mut rng,
                                                      opts.use_gdc, calib);
            let mut correct = 0usize;
            let mut lo = 0usize;
            while lo < n {
                let xb = ds.padded_batch(lo, opts.batch);
                debug_assert_eq!(xb.len(), opts.batch * ih * iw * ic);
                let preds = be.run_batch(&xb, opts.batch, &ws, &alphas, &iopts)?;
                let hi = (lo + opts.batch).min(n);
                correct += logits::count_correct(&preds, classes, &ds.y[lo..hi]);
                lo = hi;
            }
            out[ti].push(correct as f64 / n as f64);
        }
    }
    Ok(out)
}

/// Convenience: accuracy mean/std (%) after 24h of drift (Table 1 cells).
pub fn accuracy_24h(store: &ArtifactStore, vid: &str, opts: &EvalOpts)
                    -> anyhow::Result<(f64, f64)> {
    let accs = drift_accuracy(store, vid, &[crate::pcm::T_1D], opts)?;
    Ok(crate::util::stats::acc_summary(&accs[0]))
}
