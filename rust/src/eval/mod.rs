//! Drift-accuracy evaluation: the engine behind Table 1, Figure 7, Figure 9.
//!
//! Per run: program the variant's weights into simulated PCM (programming
//! noise + per-device drift exponents), then for each requested time point
//! read the conductances (drift + 1/f noise), compute the per-layer GDC
//! factors, and execute the test set through an [`InferenceBackend`] —
//! the native simulator by default, the tile-faithful AnalogCim engine, or
//! the exported HLO graphs via PJRT ([`EvalOpts::backend`]). The physics is
//! identical every way; only the execution engine changes. Sweep either
//! the paper's Figure-7 time points or a single `--t-drift` override
//! ([`EvalOpts::sweep_times`]).

use std::sync::Arc;

use crate::backend::{self, BackendKind, HostTensor, InferOpts,
                     InferenceBackend};
use crate::nn::{expand_dw_dense, LayerKind, ModelMeta, Tensor};
use crate::pcm::{gdc, PcmParams, ProgrammedWeights};
use crate::runtime::ArtifactStore;
use crate::util::logits;
use crate::util::rng::Rng;

/// One layer's deployed state: PCM-programmed (analog) or exact (digital).
pub enum DeployedLayer {
    Analog(ProgrammedWeights),
    Digital(Tensor),
}

/// A variant programmed onto the simulated PCM array.
pub struct DeployedModel {
    pub meta: Arc<ModelMeta>,
    pub layers: Vec<DeployedLayer>,
}

impl DeployedModel {
    /// Program `vid`'s weights (expanding depthwise layers to their dense
    /// CiM form so the zero cells are physically programmed).
    pub fn program(store: &ArtifactStore, vid: &str, params: &PcmParams,
                   rng: &mut Rng) -> anyhow::Result<Self> {
        let meta = store.meta(vid)?;
        let tensors = store.weights(vid)?;
        anyhow::ensure!(tensors.len() == meta.layers.len(), "weights/meta mismatch");
        let mut layers = Vec::new();
        for (lm, t) in meta.layers.iter().zip(tensors.iter()) {
            if !lm.analog {
                layers.push(DeployedLayer::Digital(t.clone()));
                continue;
            }
            let dense = if lm.kind == LayerKind::Dw3x3 {
                expand_dw_dense(t)
            } else {
                t.clone()
            };
            let (rows, cols) = (dense.shape[0], dense.shape[1]);
            let mut lrng = rng.fork(layers.len() as u64 + 1);
            layers.push(DeployedLayer::Analog(ProgrammedWeights::program(
                &dense.data, rows, cols, lm.w_scale, params, &mut lrng,
            )));
        }
        Ok(DeployedModel { meta, layers })
    }

    /// Effective weight tensors + GDC vector at `t` seconds after programming.
    pub fn read_at(&self, t_seconds: f64, params: &PcmParams, rng: &mut Rng,
                   use_gdc: bool) -> (Vec<HostTensor>, Vec<f32>) {
        let mut ws = Vec::with_capacity(self.layers.len());
        let mut alphas = Vec::with_capacity(self.layers.len());
        for dl in self.layers.iter() {
            match dl {
                DeployedLayer::Analog(p) => {
                    let w = p.read_weights(t_seconds, params, rng);
                    ws.push(HostTensor::new(vec![p.rows, p.cols], w));
                    alphas.push(if use_gdc { gdc::alpha(p, t_seconds) } else { 1.0 });
                }
                DeployedLayer::Digital(t) => {
                    ws.push(HostTensor::from_tensor(t));
                    alphas.push(1.0);
                }
            }
        }
        (ws, alphas)
    }
}

/// Options for an accuracy evaluation.
#[derive(Clone, Debug)]
pub struct EvalOpts {
    pub bits: u32,
    pub batch: usize,
    /// evaluate at most this many test samples (paper uses the full set; we
    /// default to a subset to keep CPU sweeps tractable — see EXPERIMENTS.md)
    pub max_samples: usize,
    pub runs: usize,
    pub seed: u64,
    pub use_gdc: bool,
    pub params: PcmParams,
    /// which execution engine runs the test set
    pub backend: BackendKind,
    /// single drift-time override in seconds (`--t-drift` on the CLI):
    /// when set, [`EvalOpts::sweep_times`] collapses the Figure-7 sweep to
    /// this one time point — evaluate a day-old or year-old array directly
    pub t_drift: Option<f64>,
    /// per-request ADC bitwidth override (`--adc-bits` on the CLI): every
    /// `run_batch` of the evaluation executes under
    /// `InferOpts { adc_bits, .. }`, so e.g. the paper's Table-2 4-bit
    /// serving scenario evaluates against artifacts exported at 8 bits
    /// without re-exporting. `None` keeps the backend's configured
    /// [`bits`](Self::bits). Weight-fed engines only (PJRT graphs are
    /// compiled at one bitwidth and reject overrides).
    pub adc_bits: Option<u32>,
}

impl Default for EvalOpts {
    fn default() -> Self {
        EvalOpts {
            bits: 8,
            batch: 128,
            max_samples: 256,
            runs: 5,
            seed: 0xA11A,
            use_gdc: true,
            params: PcmParams::default(),
            backend: BackendKind::default(),
            t_drift: None,
            adc_bits: None,
        }
    }
}

impl EvalOpts {
    /// Time points a drift sweep should cover: the single
    /// [`t_drift`](Self::t_drift) override when set, the paper's Figure-7
    /// sweep (25 s → 1 yr) otherwise. The shared source of truth for the
    /// CLI `eval` command and the CI analog-smoke gate.
    pub fn sweep_times(&self) -> Vec<f64> {
        match self.t_drift {
            Some(t) => vec![t],
            None => crate::pcm::FIG7_TIMES.iter().map(|(_, t)| *t).collect(),
        }
    }
}

/// Accuracy of `vid` at each `times[i]` seconds, for `opts.runs` independent
/// programming runs, on the backend selected by `opts.backend`. Returns
/// `accs[time_idx][run_idx]` in [0, 1].
pub fn drift_accuracy(store: &ArtifactStore, vid: &str, times: &[f64],
                      opts: &EvalOpts) -> anyhow::Result<Vec<Vec<f64>>> {
    let be = backend::create(opts.backend, store, vid, opts.bits)?;
    drift_accuracy_on(be.as_ref(), store, vid, times, opts)
}

/// Like [`drift_accuracy`], over a caller-constructed backend — the
/// extension hook for custom engines (anything implementing
/// [`InferenceBackend`]) and for pinning the engine explicitly in tests.
pub fn drift_accuracy_on(be: &dyn InferenceBackend, store: &ArtifactStore,
                         vid: &str, times: &[f64], opts: &EvalOpts)
                         -> anyhow::Result<Vec<Vec<f64>>> {
    let meta = store.meta(vid)?;
    let task = if meta.model.contains("vww") { "vww" } else { "kws" };
    let ds = store.dataset(task)?;
    let n = ds.len().min(opts.max_samples);
    anyhow::ensure!(n > 0, "dataset for {task} is empty");
    be.prepare(opts.batch)?;
    let classes = meta.num_classes;
    let (ih, iw, ic) = meta.input_hwc;
    // the per-request options every launch of this evaluation runs under
    // (drift time is expressed through `times` / the weight read, not here)
    let iopts = InferOpts { t_drift: None, adc_bits: opts.adc_bits };

    let mut out = vec![Vec::with_capacity(opts.runs); times.len()];
    for run in 0..opts.runs {
        let mut rng = Rng::new(opts.seed ^ (run as u64).wrapping_mul(0x9E37));
        let dep = DeployedModel::program(store, vid, &opts.params, &mut rng)?;
        for (ti, &t) in times.iter().enumerate() {
            let (ws, alphas) = dep.read_at(t, &opts.params, &mut rng, opts.use_gdc);
            let mut correct = 0usize;
            let mut lo = 0usize;
            while lo < n {
                let xb = ds.padded_batch(lo, opts.batch);
                debug_assert_eq!(xb.len(), opts.batch * ih * iw * ic);
                let preds = be.run_batch(&xb, opts.batch, &ws, &alphas, &iopts)?;
                let hi = (lo + opts.batch).min(n);
                correct += logits::count_correct(&preds, classes, &ds.y[lo..hi]);
                lo = hi;
            }
            out[ti].push(correct as f64 / n as f64);
        }
    }
    Ok(out)
}

/// Convenience: accuracy mean/std (%) after 24h of drift (Table 1 cells).
pub fn accuracy_24h(store: &ArtifactStore, vid: &str, opts: &EvalOpts)
                    -> anyhow::Result<(f64, f64)> {
    let accs = drift_accuracy(store, vid, &[crate::pcm::T_1D], opts)?;
    Ok(crate::util::stats::acc_summary(&accs[0]))
}
