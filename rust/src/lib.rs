//! AnalogNets: ML-HW co-design of noise-robust TinyML models and an
//! always-on analog compute-in-memory accelerator — reproduction library.
//!
//! Three-layer architecture (see DESIGN.md):
//! * L1/L2 (build time, Python): Pallas CiM kernel + JAX model graphs,
//!   AOT-lowered to the HLO artifacts this crate loads;
//! * L3 (this crate): the AON-CiM accelerator model — PCM device physics,
//!   layer mapper, cycle/energy model — and the always-on serving
//!   coordinator executing the exported graphs via PJRT.

pub mod bench;
pub mod coordinator;
pub mod crossbar;
pub mod datasets;
pub mod eval;
pub mod mapping;
pub mod nn;
pub mod pcm;
pub mod quant;
pub mod runtime;
pub mod simulator;
pub mod timing;
pub mod util;
