//! AnalogNets: ML-HW co-design of noise-robust TinyML models and an
//! always-on analog compute-in-memory accelerator — reproduction library.
//!
//! Three-layer architecture (see DESIGN.md):
//! * L1/L2 (build time, Python): Pallas CiM kernel + JAX model graphs,
//!   AOT-lowered to the HLO artifacts this crate loads;
//! * L3 (this crate): the AON-CiM accelerator model — PCM device physics,
//!   layer mapper, cycle/energy model — and the always-on serving
//!   coordinator.
//!
//! # Execution backends
//!
//! All inference flows through one trait, [`backend::InferenceBackend`]:
//!
//! ```text
//!   eval / coordinator / CLI / benches
//!            |
//!            v  run_batch(x, batch, effective_weights, gdc, infer_opts)
//!   +-------------------+--------------------+----------------------------+
//!   | NativeBackend     | AnalogCimBackend   | PjrtBackend  ("pjrt")      |
//!   | pure-Rust im2col/ | tile-faithful:     | AOT-exported HLO graphs    |
//!   | GEMM, ADC quant   | per-crossbar MVM,  | via the xla crate / PJRT   |
//!   | after full-K acc  | per-tile ADC quant | CPU client                 |
//!   +-------------------+--------------------+----------------------------+
//! ```
//!
//! The native backend is the default; it and the analog backend need
//! neither the XLA native library nor generated HLO artifacts, so
//! `cargo build && cargo test` are hermetic. Select engines with
//! [`backend::BackendKind`] (`EvalOpts::backend`, `ServeConfig::backend`,
//! `--backend` on the CLI). Per-request options ride every launch as
//! [`backend::InferOpts`] — device age `t_drift` and quantization
//! `adc_bits` (`--t-drift` / `--adc-bits` on the CLI) — so one
//! coordinator serves many device ages and bitwidths concurrently. `xla`
//! types never escape the `runtime` module.
//!
//! Internally both weight-fed engines are one
//! [`simulator::LayerExecutor`] (the shared layer-serial staging loop)
//! driven by a [`simulator::MatmulEngine`] — [`simulator::NativeGemmEngine`]
//! or the tile-faithful [`simulator::TileGridEngine`] — so a staging fix
//! or a new layer kind lands in every engine by construction.
//!
//! The coordinator also has a network front door: [`server::WireServer`]
//! speaks a line-delimited JSON protocol over TCP (`serve --listen` on
//! the CLI), parsing requests with a zero-allocation visiting JSON
//! reader and dispatching them through the same `submit_with` path as
//! in-process callers.
//!
//! Always-on deployments serve several models from one process:
//! [`coordinator::MultiCoordinator`] owns one shard per model — each with
//! its own backend, PCM drift clock, fault scenario, and modeled launch
//! schedule — behind a single `submit(model_id, x, opts)` API, with
//! per-model admission bounds and a weighted round-robin drain so a hot
//! model cannot starve a quiet one (the paper's KWS-wake -> VWW-confirm
//! pipeline is the motivating shape). The wire protocol addresses models
//! with an optional `"model"` field (`serve --models kws,vww --listen ..`
//! on the CLI, [`server::WireServer::start_multi`] in-process), and
//! per-model throughput/latency/energy land in
//! [`coordinator::metrics::MetricsSummary::per_model`].

pub mod backend;
pub mod bench;
pub mod coordinator;
pub mod crossbar;
pub mod datasets;
pub mod eval;
pub mod mapping;
pub mod nn;
pub mod pcm;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod simulator;
pub mod timing;
pub mod util;
