//! CiM crossbar array geometry (Section 5.2 / Table 2).
//!
//! One differential pair (two PCM devices) per weight; the AON-CiM array is
//! 1024 rows x 512 columns of *weights* with a 4-input analog mux in front
//! of each ADC group.

/// Geometry of one CiM array.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArrayGeom {
    /// crossbar rows (DAC-driven source lines)
    pub rows: usize,
    /// crossbar columns (weight columns; each is a differential bitline pair)
    pub cols: usize,
    /// analog column mux ratio (ADCs = cols / mux)
    pub adc_mux: usize,
}

impl ArrayGeom {
    /// The paper's AON-CiM array: 1024 x 512, mux-4.
    pub const AON: ArrayGeom = ArrayGeom {
        rows: 1024,
        cols: 512,
        adc_mux: 4,
    };

    pub fn new(rows: usize, cols: usize) -> Self {
        ArrayGeom {
            rows,
            cols,
            adc_mux: 4,
        }
    }

    /// Total weight cells (differential pairs).
    pub fn cells(&self) -> usize {
        self.rows * self.cols
    }

    /// Number of physical ADCs.
    pub fn adcs(&self) -> usize {
        self.cols / self.adc_mux
    }

    /// ADC phases needed to read `cols_used` columns (mux sharing).
    ///
    /// Columns are interleaved across mux groups, so `cols_used` columns
    /// need `ceil(cols_used / adcs)` conversion phases, capped at `adc_mux`.
    pub fn adc_phases(&self, cols_used: usize) -> usize {
        let adcs = self.adcs();
        ((cols_used + adcs - 1) / adcs).clamp(1, self.adc_mux)
    }

    /// Peak MACs per full-array MVM.
    pub fn peak_macs_per_mvm(&self) -> usize {
        self.cells()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aon_geometry() {
        let g = ArrayGeom::AON;
        assert_eq!(g.cells(), 524_288);
        assert_eq!(g.adcs(), 128);
        assert_eq!(g.adc_phases(512), 4);
        assert_eq!(g.adc_phases(128), 1);
        assert_eq!(g.adc_phases(129), 2);
        assert_eq!(g.adc_phases(1), 1);
    }
}
