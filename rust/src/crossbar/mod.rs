//! CiM crossbar array geometry (Section 5.2 / Table 2).
//!
//! One differential pair (two PCM devices) per weight; the AON-CiM array is
//! 1024 rows x 512 columns of *weights* with a 4-input analog mux in front
//! of each ADC group.

/// Geometry of one CiM array.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArrayGeom {
    /// crossbar rows (DAC-driven source lines)
    pub rows: usize,
    /// crossbar columns (weight columns; each is a differential bitline pair)
    pub cols: usize,
    /// analog column mux ratio (ADCs = cols / mux)
    pub adc_mux: usize,
}

impl ArrayGeom {
    /// The paper's AON-CiM array: 1024 x 512, mux-4.
    pub const AON: ArrayGeom = ArrayGeom {
        rows: 1024,
        cols: 512,
        adc_mux: 4,
    };

    /// A custom geometry. The analog column-mux ratio is an explicit design
    /// parameter (it sets the ADC count and the conversion phasing), so
    /// callers state it instead of inheriting a silent mux-4 default; the
    /// columns must divide evenly into mux groups so every ADC serves a
    /// full group.
    pub fn new(rows: usize, cols: usize, adc_mux: usize) -> anyhow::Result<Self> {
        anyhow::ensure!(rows >= 1 && cols >= 1,
                        "array must be at least 1x1 (got {rows}x{cols})");
        anyhow::ensure!(adc_mux >= 1, "adc mux ratio must be >= 1");
        anyhow::ensure!(
            cols % adc_mux == 0,
            "cols {cols} do not divide into mux-{adc_mux} groups \
             (each ADC must serve a full column group)"
        );
        Ok(ArrayGeom { rows, cols, adc_mux })
    }

    /// Same geometry with a different mux ratio (validated like [`new`]).
    ///
    /// [`new`]: ArrayGeom::new
    pub fn with_mux(self, adc_mux: usize) -> anyhow::Result<Self> {
        Self::new(self.rows, self.cols, adc_mux)
    }

    /// Total weight cells (differential pairs).
    pub fn cells(&self) -> usize {
        self.rows * self.cols
    }

    /// Number of physical ADCs.
    pub fn adcs(&self) -> usize {
        self.cols / self.adc_mux
    }

    /// ADC phases needed to read `cols_used` columns (mux sharing).
    ///
    /// Columns are interleaved across mux groups, so `cols_used` columns
    /// need `ceil(cols_used / adcs)` conversion phases, capped at `adc_mux`.
    pub fn adc_phases(&self, cols_used: usize) -> usize {
        let adcs = self.adcs();
        cols_used.div_ceil(adcs).clamp(1, self.adc_mux)
    }

    /// Peak MACs per full-array MVM.
    pub fn peak_macs_per_mvm(&self) -> usize {
        self.cells()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aon_geometry() {
        let g = ArrayGeom::AON;
        assert_eq!(g.cells(), 524_288);
        assert_eq!(g.adcs(), 128);
        assert_eq!(g.adc_phases(512), 4);
        assert_eq!(g.adc_phases(128), 1);
        assert_eq!(g.adc_phases(129), 2);
        assert_eq!(g.adc_phases(1), 1);
    }

    #[test]
    fn new_takes_mux_explicitly_and_validates() {
        let g = ArrayGeom::new(64, 64, 2).unwrap();
        assert_eq!(g.adc_mux, 2);
        assert_eq!(g.adcs(), 32);
        // the paper's array, spelled out
        assert_eq!(ArrayGeom::new(1024, 512, 4).unwrap(), ArrayGeom::AON);
        // mux must divide the columns; degenerate shapes refuse
        assert!(ArrayGeom::new(64, 65, 4).is_err());
        assert!(ArrayGeom::new(64, 64, 0).is_err());
        assert!(ArrayGeom::new(0, 64, 4).is_err());
        assert!(ArrayGeom::new(64, 0, 4).is_err());
    }

    #[test]
    fn with_mux_revalidates() {
        let g = ArrayGeom::AON.with_mux(8).unwrap();
        assert_eq!(g.adcs(), 64);
        assert!(ArrayGeom::new(64, 60, 4).unwrap().with_mux(8).is_err());
    }
}
