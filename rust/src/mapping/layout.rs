//! Mapping visualizations: ASCII array maps (Figures 6 and 11) and CSV
//! rectangle dumps for downstream plotting.

use super::tiler::ModelMapping;

/// Render the placement as an ASCII grid, downsampled to `gw x gh` chars.
/// Each layer gets a letter; '.' is unallocated.
pub fn ascii_map(m: &ModelMapping, gw: usize, gh: usize) -> String {
    let letters: Vec<char> = ('A'..='Z').chain('a'..='z').collect();
    let mut grid = vec!['.'; gw * gh];
    let (rows, cols) = (m.geom.rows as f64, m.geom.cols as f64);
    for (li, l) in m.layers.iter().enumerate() {
        let ch = letters[li % letters.len()];
        let y0 = (l.row0 as f64 / rows * gh as f64) as usize;
        let y1 = (((l.row0 + l.rows) as f64 / rows * gh as f64).ceil() as usize).min(gh);
        let x0 = (l.col0 as f64 / cols * gw as f64) as usize;
        let x1 = (((l.col0 + l.cols) as f64 / cols * gw as f64).ceil() as usize).min(gw);
        for y in y0..y1 {
            for x in x0..x1 {
                grid[y * gw + x] = ch;
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!(
        "array {}x{}  (rows down, cols across; alloc util {:.1}%, eff util {:.1}%)\n",
        m.geom.rows, m.geom.cols,
        100.0 * m.allocated_utilization(),
        100.0 * m.effective_utilization()
    ));
    for y in 0..gh {
        out.extend(grid[y * gw..(y + 1) * gw].iter());
        out.push('\n');
    }
    for (li, l) in m.layers.iter().enumerate() {
        out.push_str(&format!(
            "  {} = {:<10} rows {:>4}..{:<4} cols {:>3}..{:<3} ({}x{}, local util {:.1}%)\n",
            letters[li % letters.len()],
            l.name,
            l.row0,
            l.row0 + l.rows,
            l.col0,
            l.col0 + l.cols,
            l.rows,
            l.cols,
            100.0 * l.local_utilization()
        ));
    }
    out
}

/// CSV of placement rectangles.
pub fn csv_map(m: &ModelMapping) -> String {
    let mut s = String::from("layer,kind,row0,col0,rows,cols,effective,local_util\n");
    for l in &m.layers {
        s.push_str(&format!(
            "{},{},{},{},{},{},{},{:.6}\n",
            l.name,
            l.kind.as_str(),
            l.row0,
            l.col0,
            l.rows,
            l.cols,
            l.effective,
            l.local_utilization()
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crossbar::ArrayGeom;
    use crate::mapping::tiler::MappedLayer;
    use crate::nn::LayerKind;

    fn sample() -> ModelMapping {
        ModelMapping {
            geom: ArrayGeom::AON,
            layers: vec![MappedLayer {
                name: "c0".into(),
                kind: LayerKind::Conv3x3,
                row0: 0,
                col0: 0,
                rows: 512,
                cols: 256,
                effective: 512 * 256,
                mvms: 100,
            }],
        }
    }

    #[test]
    fn ascii_covers_quadrant() {
        let s = ascii_map(&sample(), 8, 8);
        // top-left half rows, half cols => 'A's in the 4x4 top-left block
        let lines: Vec<&str> = s.lines().skip(1).take(8).collect();
        assert!(lines[0].starts_with("AAAA...."));
        assert!(lines[4].starts_with("........"));
    }

    #[test]
    fn csv_has_header_and_row() {
        let s = csv_map(&sample());
        assert_eq!(s.lines().count(), 2);
        assert!(s.contains("c0,conv3x3,0,0,512,256"));
    }
}
