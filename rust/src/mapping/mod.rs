//! Layer-to-crossbar mapping (Section 5.1, Figure 6, Appendix D).

pub mod layout;
pub mod tiler;

pub use tiler::{map_model, slice_tile, split_map_model, tile_grid, MappedLayer,
                ModelMapping, SplitMapping, Tile};
