//! The layer-serial tiler: places every layer's GEMM rectangle into the
//! single shared CiM array (Figure 6), and the split-GEMM fallback for
//! arrays smaller than a layer (Appendix D, Table 3).

use crate::crossbar::ArrayGeom;
use crate::nn::{LayerKind, ModelMeta};

/// One layer's placement on the array.
#[derive(Clone, Debug)]
pub struct MappedLayer {
    pub name: String,
    pub kind: LayerKind,
    /// placement rectangle (row0, col0) .. (row0+rows, col0+cols)
    pub row0: usize,
    pub col0: usize,
    pub rows: usize,
    pub cols: usize,
    /// non-zero weights inside the rectangle (< rows*cols for depthwise)
    pub effective: usize,
    /// output pixels = MVM operations per inference
    pub mvms: usize,
}

impl MappedLayer {
    pub fn cells(&self) -> usize {
        self.rows * self.cols
    }
    /// local utilization: non-zero / allocated (the Figure 3 ~0.9% effect)
    pub fn local_utilization(&self) -> f64 {
        self.effective as f64 / self.cells() as f64
    }
}

/// A whole-model mapping onto one array.
#[derive(Clone, Debug)]
pub struct ModelMapping {
    pub geom: ArrayGeom,
    pub layers: Vec<MappedLayer>,
}

impl ModelMapping {
    /// Array utilization counting allocated cells.
    pub fn allocated_utilization(&self) -> f64 {
        let used: usize = self.layers.iter().map(|l| l.cells()).sum();
        used as f64 / self.geom.cells() as f64
    }
    /// Effective utilization counting only non-zero weights (Table 3).
    pub fn effective_utilization(&self) -> f64 {
        let used: usize = self.layers.iter().map(|l| l.effective).sum();
        used as f64 / self.geom.cells() as f64
    }
}

/// Shelf-pack the model's layers onto a single array, tallest first
/// (the paper's mapper keeps each layer whole — "no layers are split").
pub fn map_model(meta: &ModelMeta, geom: ArrayGeom) -> anyhow::Result<ModelMapping> {
    // (index, rows, cols) in placement order: tallest first, then widest
    let mut order: Vec<(usize, usize, usize)> = meta
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| (i, l.mapped_rows(), l.mapped_cols()))
        .collect();
    order.sort_by(|a, b| b.1.cmp(&a.1).then(b.2.cmp(&a.2)));

    // two-level shelf packing: shelves stack vertically; within a shelf,
    // sub-columns stack short layers on top of each other, which recovers
    // the fragmentation that tall depthwise expansions would otherwise
    // cause (MicroNet-KWS-S needs this to fit, Figure 11a).
    struct SubCol {
        col0: usize,
        width: usize,
        row_used: usize,
    }
    struct Shelf {
        row0: usize,
        height: usize,
        col_used: usize,
        subcols: Vec<SubCol>,
    }
    let mut shelves: Vec<Shelf> = Vec::new();
    let mut next_row = 0usize;
    let mut placed: Vec<Option<MappedLayer>> = vec![None; meta.layers.len()];

    for (idx, rows, cols) in order {
        if rows > geom.rows || cols > geom.cols {
            anyhow::bail!(
                "layer {} ({}x{}) exceeds the {}x{} array; use split_map_model",
                meta.layers[idx].name, rows, cols, geom.rows, geom.cols
            );
        }
        // 1) try stacking into an existing sub-column
        let mut spot: Option<(usize, usize)> = None; // (row0, col0)
        'outer: for sh in shelves.iter_mut() {
            for sc in sh.subcols.iter_mut() {
                if cols <= sc.width && sc.row_used + rows <= sh.height {
                    spot = Some((sh.row0 + sc.row_used, sc.col0));
                    sc.row_used += rows;
                    break 'outer;
                }
            }
            // 2) else a fresh sub-column on a shelf tall enough
            if sh.height >= rows && sh.col_used + cols <= geom.cols {
                spot = Some((sh.row0, sh.col_used));
                sh.subcols.push(SubCol {
                    col0: sh.col_used,
                    width: cols,
                    row_used: rows,
                });
                sh.col_used += cols;
                break 'outer;
            }
        }
        // 3) else open a new shelf
        let (row0, col0) = match spot {
            Some(s) => s,
            None => {
                if next_row + rows > geom.rows {
                    anyhow::bail!(
                        "model does not fit on the {}x{} array (layer {})",
                        geom.rows, geom.cols, meta.layers[idx].name
                    );
                }
                shelves.push(Shelf {
                    row0: next_row,
                    height: rows,
                    col_used: cols,
                    subcols: vec![SubCol { col0: 0, width: cols, row_used: rows }],
                });
                next_row += rows;
                (shelves.last().unwrap().row0, 0)
            }
        };
        let lm = &meta.layers[idx];
        placed[idx] = Some(MappedLayer {
            name: lm.name.clone(),
            kind: lm.kind,
            row0,
            col0,
            rows,
            cols,
            effective: lm.effective_weights(),
            mvms: if lm.kind == LayerKind::Dense { 1 } else { lm.out_pixels() },
        });
    }

    Ok(ModelMapping {
        geom,
        layers: placed.into_iter().map(|p| p.unwrap()).collect(),
    })
}

// ---------------------------------------------------------------------------
// Execution tiling: the crossbar tile grid behind the AnalogCim engine
// ---------------------------------------------------------------------------

/// One crossbar-sized tile of a layer's [K x N] GEMM rectangle.
///
/// `kt`/`ct` index the tile grid (K-splits x column-splits); rows
/// `k0..k0+rows` and columns `n0..n0+cols` locate the slice in the dense
/// weight matrix. Edge tiles are ragged (`rows < geom.rows` or
/// `cols < geom.cols`) when the rectangle does not divide evenly.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tile {
    pub kt: usize,
    pub ct: usize,
    pub k0: usize,
    pub rows: usize,
    pub n0: usize,
    pub cols: usize,
}

/// Split a [k x n] weight rectangle into `geom`-sized tiles, row-major over
/// the (kt, ct) grid. Every weight lands in exactly one tile. Tiles sharing
/// a `ct` produce partial sums over the same output columns, which the
/// AnalogCim engine ADC-quantizes per tile and then accumulates digitally
/// across `kt` — the quantize-before-accumulate order the hardware imposes.
pub fn tile_grid(k: usize, n: usize, geom: ArrayGeom) -> Vec<Tile> {
    let k_tiles = k.div_ceil(geom.rows);
    let n_tiles = n.div_ceil(geom.cols);
    let mut tiles = Vec::with_capacity(k_tiles * n_tiles);
    for kt in 0..k_tiles {
        let k0 = kt * geom.rows;
        let rows = geom.rows.min(k - k0);
        for ct in 0..n_tiles {
            let n0 = ct * geom.cols;
            let cols = geom.cols.min(n - n0);
            tiles.push(Tile { kt, ct, k0, rows, n0, cols });
        }
    }
    tiles
}

/// Copy one tile's weights out of a dense row-major matrix with `n_total`
/// columns — the sub-matrix a single crossbar is programmed with. Writing
/// every tile's slice back at its (k0, n0) origin reconstructs the dense
/// matrix bit-exactly, ragged edges included (property-tested in
/// tests/test_mapping_props.rs).
pub fn slice_tile(w: &[f32], n_total: usize, t: &Tile) -> Vec<f32> {
    let mut out = Vec::with_capacity(t.rows * t.cols);
    for r in t.k0..t.k0 + t.rows {
        out.extend_from_slice(&w[r * n_total + t.n0..r * n_total + t.n0 + t.cols]);
    }
    out
}

// ---------------------------------------------------------------------------
// Split-GEMM mapping for small crossbars (Appendix D)
// ---------------------------------------------------------------------------

/// A layer split into row/col tiles across (possibly many) small arrays.
#[derive(Clone, Debug)]
pub struct SplitLayer {
    pub name: String,
    pub kind: LayerKind,
    pub rows: usize,
    pub cols: usize,
    /// tiles actually allocated (tiles with at least one non-zero weight)
    pub alloc_tiles: usize,
    /// total tile grid (incl. all-zero tiles that are skipped)
    pub grid_tiles: usize,
    /// non-zero weights
    pub effective: usize,
    /// row-splits: partial sums that must be digitally accumulated
    pub row_splits: usize,
    pub mvms: usize,
}

#[derive(Clone, Debug)]
pub struct SplitMapping {
    pub geom: ArrayGeom,
    pub layers: Vec<SplitLayer>,
}

impl SplitMapping {
    pub fn alloc_tiles(&self) -> usize {
        self.layers.iter().map(|l| l.alloc_tiles).sum()
    }
    /// Effective utilization over allocated tile area (Table 3).
    pub fn effective_utilization(&self) -> f64 {
        let nz: usize = self.layers.iter().map(|l| l.effective).sum();
        let area: usize = self.alloc_tiles() * self.geom.cells();
        nz as f64 / area as f64
    }
}

/// Split every layer into `geom`-sized tiles; all-zero tiles (off-diagonal
/// blocks of expanded depthwise layers) are never allocated.
pub fn split_map_model(meta: &ModelMeta, geom: ArrayGeom) -> SplitMapping {
    let mut layers = Vec::new();
    for lm in &meta.layers {
        let rows = lm.mapped_rows();
        let cols = lm.mapped_cols();
        let rt = rows.div_ceil(geom.rows);
        let ct = cols.div_ceil(geom.cols);
        let grid = rt * ct;
        let alloc = if lm.kind == LayerKind::Dw3x3 {
            // dense-expanded dw: block (i,j) over [9C x C] holds a diagonal
            // slice iff some (t*C + c, c) falls inside it
            let c = lm.in_ch;
            let mut cnt = 0usize;
            for bi in 0..rt {
                for bj in 0..ct {
                    let r0 = bi * geom.rows;
                    let r1 = ((bi + 1) * geom.rows).min(rows);
                    let c0 = bj * geom.cols;
                    let c1 = ((bj + 1) * geom.cols).min(cols);
                    // any t, ch with ch in [c0,c1) and t*c+ch in [r0,r1)?
                    let mut hit = false;
                    't: for t in 0..9 {
                        // ch range implied by rows: [r0 - t*c, r1 - t*c)
                        let lo = r0 as isize - (t * c) as isize;
                        let hi = r1 as isize - (t * c) as isize;
                        let lo = lo.max(c0 as isize);
                        let hi = hi.min(c1 as isize);
                        if lo < hi {
                            hit = true;
                            break 't;
                        }
                    }
                    if hit {
                        cnt += 1;
                    }
                }
            }
            cnt
        } else {
            grid
        };
        layers.push(SplitLayer {
            name: lm.name.clone(),
            kind: lm.kind,
            rows,
            cols,
            alloc_tiles: alloc,
            grid_tiles: grid,
            effective: lm.effective_weights(),
            row_splits: rt,
            mvms: if lm.kind == LayerKind::Dense { 1 } else { lm.out_pixels() },
        });
    }
    SplitMapping { geom, layers }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::meta::ModelMeta;
    use crate::util::json;

    fn meta_with(layers: &[(&str, &str, usize, usize, usize)]) -> ModelMeta {
        // (name, kind, in_ch, out_ch, out_pixels as sqrt)
        let mut ls = String::new();
        for (i, (name, kind, ic, oc, op)) in layers.iter().enumerate() {
            if i > 0 {
                ls.push(',');
            }
            let k = match *kind {
                "conv3x3" | "dw3x3" => 9 * ic,
                _ => *ic,
            };
            let wshape = if *kind == "dw3x3" {
                format!("[9,{ic}]")
            } else {
                format!("[{k},{oc}]")
            };
            let gshape = format!("[{k},{oc}]");
            ls.push_str(&format!(
                r#"{{"name":"{name}","kind":"{kind}","in_ch":{ic},"out_ch":{oc},
                "stride":[1,1],"relu":true,"analog":true,
                "in_h":{op},"in_w":1,"out_h":{op},"out_w":1,
                "k_gemm":{k},"weight_shape":{wshape},
                "graph_weight_shape":{gshape},
                "w_scale":1,"w_max":1,"r_dac":1,"r_adc":1,
                "dig_scale":[{scales}],"dig_bias":[{biases}]}}"#,
                scales = vec!["1"; *oc].join(","),
                biases = vec!["0"; *oc].join(","),
            ));
        }
        let src = format!(
            r#"{{"model":"m","variant":"v","input_hwc":[8,1,1],"num_classes":2,
            "eta":0,"fp_test_acc":1,"trained_adc_bits":null,
            "layers":[{ls}],"hlo":{{}}}}"#
        );
        ModelMeta::from_json(&json::parse(&src).unwrap()).unwrap()
    }

    #[test]
    fn placements_disjoint_and_in_bounds() {
        let meta = meta_with(&[
            ("a", "conv3x3", 8, 32, 16),
            ("b", "conv3x3", 32, 48, 8),
            ("c", "dense", 48, 10, 1),
        ]);
        let m = map_model(&meta, ArrayGeom::AON).unwrap();
        for l in &m.layers {
            assert!(l.row0 + l.rows <= 1024);
            assert!(l.col0 + l.cols <= 512);
        }
        for i in 0..m.layers.len() {
            for j in 0..i {
                let (a, b) = (&m.layers[i], &m.layers[j]);
                let overlap = a.row0 < b.row0 + b.rows
                    && b.row0 < a.row0 + a.rows
                    && a.col0 < b.col0 + b.cols
                    && b.col0 < a.col0 + a.cols;
                assert!(!overlap, "{} overlaps {}", a.name, b.name);
            }
        }
    }

    #[test]
    fn rejects_oversized_layer() {
        let meta = meta_with(&[("big", "conv3x3", 200, 32, 4)]); // K=1800>1024
        assert!(map_model(&meta, ArrayGeom::AON).is_err());
    }

    #[test]
    fn dw_local_utilization_is_tiny() {
        let meta = meta_with(&[("dw", "dw3x3", 112, 112, 8)]);
        let m = map_model(&meta, ArrayGeom::AON).unwrap();
        let u = m.layers[0].local_utilization();
        assert!((u - 1.0 / 112.0).abs() < 1e-9, "u={u}");
    }

    #[test]
    fn split_skips_allzero_dw_tiles() {
        let meta = meta_with(&[("dw", "dw3x3", 112, 112, 8)]);
        let s64 = split_map_model(&meta, ArrayGeom::new(64, 64, 4).unwrap());
        let l = &s64.layers[0];
        // only tiles hit by a diagonal band are allocated
        assert!(l.alloc_tiles < l.grid_tiles, "{} vs {}",
                l.alloc_tiles, l.grid_tiles);
        // effective utilization improves with smaller tiles (Table 3 trend)
        let s128 = split_map_model(&meta, ArrayGeom::new(128, 128, 4).unwrap());
        assert!(s64.effective_utilization() > s128.effective_utilization(),
                "{} vs {}", s64.effective_utilization(),
                s128.effective_utilization());
    }

    #[test]
    fn split_dense_layer_uses_full_grid() {
        let meta = meta_with(&[("c", "conv3x3", 64, 128, 8)]); // 576x128
        let s = split_map_model(&meta, ArrayGeom::new(128, 128, 4).unwrap());
        assert_eq!(s.layers[0].grid_tiles, 5);
        assert_eq!(s.layers[0].alloc_tiles, 5);
        assert_eq!(s.layers[0].row_splits, 5);
    }

    #[test]
    fn tile_grid_covers_ragged_rectangles() {
        let geom = ArrayGeom::new(4, 4, 4).unwrap();
        let tiles = tile_grid(10, 7, geom);
        assert_eq!(tiles.len(), 3 * 2);
        let area: usize = tiles.iter().map(|t| t.rows * t.cols).sum();
        assert_eq!(area, 10 * 7);
        for t in &tiles {
            assert!(t.rows >= 1 && t.rows <= geom.rows);
            assert!(t.cols >= 1 && t.cols <= geom.cols);
            assert!(t.k0 + t.rows <= 10 && t.n0 + t.cols <= 7);
            assert_eq!(t.k0, t.kt * geom.rows);
            assert_eq!(t.n0, t.ct * geom.cols);
        }
        // a rectangle that fits is a single full tile
        let one = tile_grid(3, 4, geom);
        assert_eq!(one.len(), 1);
        assert_eq!((one[0].rows, one[0].cols), (3, 4));
    }

    #[test]
    fn slice_tile_extracts_the_submatrix() {
        let geom = ArrayGeom::new(2, 2, 2).unwrap();
        // 3x3 matrix 0..9 split on 2x2 tiles
        let w: Vec<f32> = (0..9).map(|i| i as f32).collect();
        let tiles = tile_grid(3, 3, geom);
        assert_eq!(tiles.len(), 4);
        assert_eq!(slice_tile(&w, 3, &tiles[0]), vec![0.0, 1.0, 3.0, 4.0]);
        assert_eq!(slice_tile(&w, 3, &tiles[1]), vec![2.0, 5.0]);
        assert_eq!(slice_tile(&w, 3, &tiles[2]), vec![6.0, 7.0]);
        assert_eq!(slice_tile(&w, 3, &tiles[3]), vec![8.0]);
    }
}
