//! PJRT execution engine: the AOT-exported HLO serving graphs behind the
//! [`InferenceBackend`] trait. Only compiled with the `pjrt` cargo feature;
//! this is the single module outside `runtime` allowed to touch the PJRT
//! executor (and even here only through `runtime`'s wrappers — no `xla`
//! types appear).

use std::sync::Arc;

use crate::backend::{HostTensor, InferOpts, InferenceBackend};
use crate::nn::ModelMeta;
use crate::pcm::LayerGdc;
use crate::runtime::ArtifactStore;

/// Executes the exported HLO graphs through the artifact store's compiled-
/// executable cache. Each batch size is a separate static-shaped graph;
/// [`prepare`](InferenceBackend::prepare) compiles them off the hot path.
pub struct PjrtBackend<'a> {
    store: &'a ArtifactStore,
    vid: String,
    bits: u32,
    meta: Arc<ModelMeta>,
}

impl<'a> PjrtBackend<'a> {
    pub fn new(store: &'a ArtifactStore, vid: &str, bits: u32)
               -> anyhow::Result<Self> {
        let meta = store.meta(vid)?;
        Ok(PjrtBackend {
            store,
            vid: vid.to_string(),
            bits,
            meta,
        })
    }
}

impl InferenceBackend for PjrtBackend<'_> {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn kind(&self) -> crate::backend::BackendKind {
        crate::backend::BackendKind::Pjrt
    }

    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn bits(&self) -> u32 {
        self.bits
    }

    /// Only the exported static graph shapes can launch.
    fn batch_sizes(&self) -> Vec<usize> {
        self.meta.serving_batch_sizes(self.bits)
    }

    /// Creating the PJRT client is where a missing XLA native library (or
    /// the vendored API stub) surfaces; no graph compilation happens here.
    fn probe(&self) -> anyhow::Result<()> {
        self.store.runtime().map(|_| ())
    }

    fn prepare(&self, batch: usize) -> anyhow::Result<()> {
        self.store.executable(&self.vid, self.bits, batch).map(|_| ())
    }

    fn run_batch(&self, x: &[f32], batch: usize, weights: &[HostTensor],
                 gdc: &[LayerGdc], opts: &InferOpts) -> anyhow::Result<Vec<f32>> {
        // validate_args -> backend::validate_opts refuses any adc_bits
        // override or fault spec here: the quantizers and clean weights
        // are baked into the AOT-compiled graph
        self.validate_args(x, batch, weights, gdc, opts)?;
        let (ih, iw, ic) = self.meta.input_hwc;
        let exe = self.store.executable(&self.vid, self.bits, batch)?;
        let mut inputs = Vec::with_capacity(2 + weights.len());
        inputs.push(HostTensor::new(vec![batch, ih, iw, ic], x.to_vec()));
        inputs.extend_from_slice(weights);
        // the exported graph consumes one scalar per layer: the uniform
        // alphas (per-tile granularity has no graph input to ride)
        let flat: Vec<f32> = gdc.iter().map(|g| g.uniform).collect();
        inputs.push(HostTensor::new(vec![flat.len()], flat));
        exe.run(&inputs)
    }
}
