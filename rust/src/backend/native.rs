//! The always-available execution engine: the pure-Rust simulator forward
//! pass behind the [`InferenceBackend`] trait.

use std::sync::Arc;

use crate::backend::{weight_fed_batch_sizes, HostTensor, InferOpts,
                     InferenceBackend};
use crate::nn::ModelMeta;
use crate::pcm::LayerGdc;
use crate::simulator::NativeModel;

/// Executes the deployed model with `simulator::NativeModel` — im2col +
/// GEMM + DAC/ADC fake quantization + GDC + digital affine, mirroring the
/// exported HLO graph layer by layer. Needs no XLA library and no exported
/// HLO artifacts, so it is the default backend everywhere.
pub struct NativeBackend {
    model: NativeModel,
    bits: u32,
}

impl NativeBackend {
    /// Single-threaded GEMM.
    pub fn new(meta: impl Into<Arc<ModelMeta>>, bits: u32) -> Self {
        Self::with_threads(meta, bits, 1)
    }

    /// GEMM parallelised over `threads` lanes (`0` = all available cores)
    /// of blocked macro-tiles on a persistent worker pool owned by the
    /// model — spawned here, parked between launches, never re-created on
    /// the hot path. Construction also runs the process-wide GEMM tiling
    /// autotune on this model's real layer shapes (one time-boxed probe,
    /// cached per process; `ANALOGNETS_TILING` pins it for reproducible
    /// runs) so serving never pays the probe on a request.
    pub fn with_threads(meta: impl Into<Arc<ModelMeta>>, bits: u32,
                        threads: usize) -> Self {
        NativeBackend {
            model: NativeModel::with_threads(meta, threads),
            bits,
        }
    }
}

impl InferenceBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn kind(&self) -> crate::backend::BackendKind {
        crate::backend::BackendKind::Native
    }

    fn meta(&self) -> &ModelMeta {
        self.model.meta()
    }

    fn bits(&self) -> u32 {
        self.bits
    }

    /// The native GEMM has no static-shape constraint: the coordinator may
    /// drain any number of queued requests (up to its `max_batch`) into one
    /// layer-serial `run_batch` with zero padded slots.
    fn supports_dynamic_batch(&self) -> bool {
        true
    }

    /// Prefer the exported serving-graph batch sizes (so every backend
    /// behaves identically under the batcher); see
    /// [`weight_fed_batch_sizes`] for the fallback/fail-fast policy.
    fn batch_sizes(&self) -> Vec<usize> {
        weight_fed_batch_sizes(self.meta(), self.bits)
    }

    /// The native engine numerically mirrors the AON array's exported
    /// graph, so its launch schedule is the model mapped onto
    /// `ArrayGeom::AON`. `None` only if the model does not fit the array
    /// whole (schedule estimation needs the whole-layer mapping).
    fn schedule_model(&self) -> Option<crate::timing::ScheduleModel> {
        self.model.schedule_model().ok()
    }

    fn run_batch(&self, x: &[f32], batch: usize, weights: &[HostTensor],
                 gdc: &[LayerGdc], opts: &InferOpts) -> anyhow::Result<Vec<f32>> {
        self.validate_args(x, batch, weights, gdc, opts)?;
        Ok(self.model
            .forward(x, batch, weights, gdc, opts.effective_bits(self.bits)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::FALLBACK_BATCH_SIZES;
    use crate::util::json;

    fn tiny_meta() -> ModelMeta {
        let src = r#"{
          "model": "tiny", "variant": "t", "input_hwc": [1, 1, 4],
          "num_classes": 2, "eta": 0.0, "fp_test_acc": 1.0,
          "trained_adc_bits": null,
          "layers": [{"name": "fc", "kind": "dense", "in_ch": 4, "out_ch": 2,
            "stride": [1,1], "relu": false, "analog": true,
            "in_h": 1, "in_w": 1, "out_h": 1, "out_w": 1,
            "k_gemm": 4, "weight_shape": [4, 2], "graph_weight_shape": [4, 2],
            "w_scale": 1.0, "w_max": 1.0, "r_dac": 8.0, "r_adc": 8.0,
            "dig_scale": [1, 1], "dig_bias": [0, 0]}],
          "hlo": {}
        }"#;
        ModelMeta::from_json(&json::parse(src).unwrap()).unwrap()
    }

    #[test]
    fn runs_a_batch_and_validates_inputs() {
        let be = NativeBackend::new(tiny_meta(), 8);
        assert_eq!(be.name(), "native");
        assert_eq!(be.bits(), 8);
        assert_eq!(be.feat_len(), 4);
        assert_eq!(be.num_classes(), 2);
        assert!(be.prepare(2).is_ok());

        // identity-ish dense weights: class 0 sums ch0+ch1, class 1 ch2+ch3
        let w = HostTensor::new(
            vec![4, 2],
            vec![1.0, 0.0, 1.0, 0.0, 0.0, 1.0, 0.0, 1.0],
        );
        let x = vec![0.9, 0.8, 0.1, 0.0, /* sample 2 */ 0.0, 0.1, 0.7, 0.9];
        let opts = InferOpts::default();
        let unity = crate::pcm::gdc::unity(1);
        let logits = be.run_batch(&x, 2, &[w.clone()], &unity, &opts).unwrap();
        assert_eq!(logits.len(), 4);
        assert!(logits[0] > logits[1], "{logits:?}");
        assert!(logits[3] > logits[2], "{logits:?}");

        // per-request adc_bits override changes the computed numbers; an
        // out-of-range override refuses
        let coarse = be
            .run_batch(&x, 2, &[w.clone()], &unity,
                       &InferOpts::default().with_adc_bits(3))
            .unwrap();
        assert_ne!(coarse, logits, "3-bit override must change outputs");
        assert!(be
            .run_batch(&x, 2, &[w.clone()], &unity,
                       &InferOpts::default().with_adc_bits(40))
            .is_err());

        // ADC-error fault specs need per-tile converters: refused here
        let adc_fault = crate::pcm::FaultSpec {
            adc_gain_sigma: 0.02,
            ..crate::pcm::FaultSpec::none()
        };
        assert!(be
            .run_batch(&x, 2, &[w.clone()], &unity,
                       &InferOpts::default().with_faults(adc_fault))
            .is_err());
        assert!(be.calib_geom().is_none(), "full-K engine: uniform GDC");

        // wrong weight count / gdc length / input length all refuse
        assert!(be.run_batch(&x, 2, &[], &unity, &opts).is_err());
        assert!(be.run_batch(&x, 2, &[w.clone()], &[], &opts).is_err());
        assert!(be.run_batch(&x[..4], 2, &[w], &unity, &opts).is_err());
    }

    #[test]
    fn fallback_batch_sizes_when_no_graphs() {
        let be = NativeBackend::new(tiny_meta(), 8);
        let sizes = be.batch_sizes();
        assert_eq!(sizes, FALLBACK_BATCH_SIZES.to_vec());
    }

    #[test]
    fn no_fallback_when_graphs_exist_at_other_bits() {
        // a bundle that exports graphs — just not at this bitwidth — must
        // NOT fall back: serving at a wrong --bits should fail fast
        let mut meta = tiny_meta();
        meta.hlo
            .insert("8b_b32".to_string(), "t_8b_b32.hlo.txt".to_string());
        let be8 = NativeBackend::new(meta.clone(), 8);
        assert_eq!(be8.batch_sizes(), vec![32]);
        let be4 = NativeBackend::new(meta, 4);
        assert!(be4.batch_sizes().is_empty());
    }
}
