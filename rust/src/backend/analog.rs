//! The tile-faithful AnalogCim execution engine behind [`InferenceBackend`].
//!
//! Same weight-fed contract as the native backend — callers program the
//! model onto simulated PCM (`eval::DeployedModel` /
//! `coordinator::PcmState`), read the drifted conductances at the drift
//! time of interest, and hand the effective weights plus per-layer GDC
//! factors to `run_batch` — but execution goes through
//! [`simulator::AnalogModel`](crate::simulator::AnalogModel): one MVM per
//! mapped crossbar tile, per-tile-column ADC quantization at the
//! GDC-scaled range, digital f32 accumulation across K-tiles. This is the
//! engine that makes the `crossbar`/`mapping` modules load-bearing: the
//! array geometry changes the computed numbers, not just reports.

use std::sync::Arc;

use crate::backend::{weight_fed_batch_sizes, HostTensor, InferOpts,
                     InferenceBackend};
use crate::crossbar::ArrayGeom;
use crate::nn::ModelMeta;
use crate::pcm::{AdcFault, LayerGdc};
use crate::simulator::AnalogModel;

/// Executes the deployed model tile by tile on a simulated CiM array.
/// Needs no XLA library and no exported HLO artifacts; select it with
/// `--backend analog` / [`BackendKind::AnalogCim`](crate::backend::BackendKind).
pub struct AnalogCimBackend {
    model: AnalogModel,
    bits: u32,
}

impl AnalogCimBackend {
    /// Single-threaded execution on the paper's 1024x512 mux-4 AON array.
    pub fn new(meta: impl Into<Arc<ModelMeta>>, bits: u32) -> Self {
        Self::with_geom(meta, bits, ArrayGeom::AON, 1)
    }

    /// [`new`](Self::new) with a worker-pool size (`0` = all available
    /// cores), still on the AON array geometry.
    pub fn with_threads(meta: impl Into<Arc<ModelMeta>>, bits: u32,
                        threads: usize) -> Self {
        Self::with_geom(meta, bits, ArrayGeom::AON, threads)
    }

    /// Custom array geometry: the tile-ablation entry point (`eval
    /// --backend analog --rows/--cols/--mux`). Smaller arrays split layers
    /// across more tiles, which means more independent ADC quantizations
    /// per output — the Table-3 accuracy/utilization trade-off.
    ///
    /// Like the native backend, construction triggers the one-time
    /// process-wide GEMM tiling autotune (via the shared executor): the
    /// analog path's *digital* layers and per-request staging ride the
    /// blocked packed kernel, while the per-tile analog MVM
    /// (`analog_forward::tiled_mvm`) keeps its naive-order accumulation
    /// bit-identical by design.
    pub fn with_geom(meta: impl Into<Arc<ModelMeta>>, bits: u32,
                     geom: ArrayGeom, threads: usize) -> Self {
        AnalogCimBackend {
            model: AnalogModel::with_threads(meta, geom, threads),
            bits,
        }
    }

    pub fn geom(&self) -> ArrayGeom {
        self.model.geom()
    }

    /// Crossbar tiles the model occupies across all analog layers.
    pub fn tiles_total(&self) -> usize {
        self.model.tiles_total()
    }
}

impl InferenceBackend for AnalogCimBackend {
    fn name(&self) -> &'static str {
        "analog"
    }

    fn kind(&self) -> crate::backend::BackendKind {
        crate::backend::BackendKind::AnalogCim
    }

    fn meta(&self) -> &ModelMeta {
        self.model.meta()
    }

    fn bits(&self) -> u32 {
        self.bits
    }

    /// The tiled engine is layer-serial over the whole batch like the
    /// native one, so the coordinator may drain any number of queued
    /// requests into a single launch with zero padded slots.
    fn supports_dynamic_batch(&self) -> bool {
        true
    }

    fn batch_sizes(&self) -> Vec<usize> {
        weight_fed_batch_sizes(self.meta(), self.bits)
    }

    /// Per-tile GDC calibration targets this engine's array geometry.
    fn calib_geom(&self) -> Option<ArrayGeom> {
        Some(self.geom())
    }

    /// Launch schedule on this engine's *configured* geometry — identical
    /// to the native backend's on the default AON array, per-tile under
    /// ablation geometries. `None` only if the model needs split-GEMM on
    /// this geometry (the estimator prices whole-layer mappings).
    fn schedule_model(&self) -> Option<crate::timing::ScheduleModel> {
        self.model.schedule_model().ok()
    }

    fn run_batch(&self, x: &[f32], batch: usize, weights: &[HostTensor],
                 gdc: &[LayerGdc], opts: &InferOpts) -> anyhow::Result<Vec<f32>> {
        self.validate_args(x, batch, weights, gdc, opts)?;
        // the ADC-side faults execute here; the weight-side ones already
        // happened when the provider programmed (and read) the conductances
        let adc = opts
            .faults
            .map(|f| f.adc_fault())
            .unwrap_or(AdcFault::NONE);
        Ok(self.model.forward_faulted(x, batch, weights, gdc,
                                      opts.effective_bits(self.bits), adc))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::FALLBACK_BATCH_SIZES;
    use crate::util::json;

    fn tiny_meta() -> ModelMeta {
        let src = r#"{
          "model": "tiny", "variant": "t", "input_hwc": [1, 1, 4],
          "num_classes": 2, "eta": 0.0, "fp_test_acc": 1.0,
          "trained_adc_bits": null,
          "layers": [{"name": "fc", "kind": "dense", "in_ch": 4, "out_ch": 2,
            "stride": [1,1], "relu": false, "analog": true,
            "in_h": 1, "in_w": 1, "out_h": 1, "out_w": 1,
            "k_gemm": 4, "weight_shape": [4, 2], "graph_weight_shape": [4, 2],
            "w_scale": 1.0, "w_max": 1.0, "r_dac": 8.0, "r_adc": 8.0,
            "dig_scale": [1, 1], "dig_bias": [0, 0]}],
          "hlo": {}
        }"#;
        ModelMeta::from_json(&json::parse(src).unwrap()).unwrap()
    }

    #[test]
    fn runs_a_batch_and_validates_inputs() {
        let be = AnalogCimBackend::new(tiny_meta(), 8);
        assert_eq!(be.name(), "analog");
        assert_eq!(be.bits(), 8);
        assert_eq!(be.geom(), ArrayGeom::AON);
        assert_eq!(be.tiles_total(), 1);
        assert!(be.supports_dynamic_batch());
        assert!(be.probe().is_ok());

        let w = HostTensor::new(
            vec![4, 2],
            vec![1.0, 0.0, 1.0, 0.0, 0.0, 1.0, 0.0, 1.0],
        );
        let x = vec![0.9, 0.8, 0.1, 0.0, /* sample 2 */ 0.0, 0.1, 0.7, 0.9];
        let opts = InferOpts::default();
        let unity = crate::pcm::gdc::unity(1);
        let logits = be.run_batch(&x, 2, &[w.clone()], &unity, &opts).unwrap();
        assert_eq!(logits.len(), 4);
        assert!(logits[0] > logits[1], "{logits:?}");
        assert!(logits[3] > logits[2], "{logits:?}");

        // per-request adc_bits override reaches the tiled engine too
        let coarse = be
            .run_batch(&x, 2, &[w.clone()], &unity,
                       &InferOpts::default().with_adc_bits(3))
            .unwrap();
        assert_ne!(coarse, logits, "3-bit override must change outputs");

        // a fault spec with only zero magnitudes is bit-identical to no
        // spec at all (the `FaultSpec::none()` regression guarantee), an
        // ADC-gain spec actually reaches the converters, and per-tile GDC
        // calibration targets this engine's geometry
        use crate::pcm::FaultSpec;
        let same = be
            .run_batch(&x, 2, &[w.clone()], &unity,
                       &InferOpts::default().with_faults(FaultSpec::none()))
            .unwrap();
        assert_eq!(same, logits, "none-spec must be a strict no-op");
        let gainy = FaultSpec { adc_gain_sigma: 0.3, seed: 3,
                                ..FaultSpec::none() };
        let shifted = be
            .run_batch(&x, 2, &[w.clone()], &unity,
                       &InferOpts::default().with_faults(gainy))
            .unwrap();
        assert_ne!(shifted, logits, "30% ADC gain sigma must move codes");
        assert_eq!(be.calib_geom(), Some(ArrayGeom::AON));
        // invalid specs refuse before execution
        let bad = FaultSpec { stuck_min: 2.0, ..FaultSpec::none() };
        assert!(be
            .run_batch(&x, 2, &[w.clone()], &unity,
                       &InferOpts::default().with_faults(bad))
            .is_err());

        // wrong weight count / gdc length / input length all refuse
        assert!(be.run_batch(&x, 2, &[], &unity, &opts).is_err());
        assert!(be.run_batch(&x, 2, &[w.clone()], &[], &opts).is_err());
        assert!(be.run_batch(&x[..4], 2, &[w], &unity, &opts).is_err());
    }

    #[test]
    fn custom_geometry_splits_into_tiles() {
        let geom = ArrayGeom::new(2, 1, 1).unwrap();
        let be = AnalogCimBackend::with_geom(tiny_meta(), 12, geom, 2);
        assert_eq!(be.geom(), geom);
        assert_eq!(be.tiles_total(), 2 * 2); // [4 x 2] on 2x1 tiles
        let w = HostTensor::new(
            vec![4, 2],
            vec![1.0, 0.0, 1.0, 0.0, 0.0, 1.0, 0.0, 1.0],
        );
        let x = vec![0.9, 0.8, 0.1, 0.0];
        let logits = be
            .run_batch(&x, 1, &[w], &crate::pcm::gdc::unity(1),
                       &InferOpts::default())
            .unwrap();
        assert_eq!(logits.len(), 2);
        assert!(logits[0] > logits[1], "{logits:?}");
    }

    #[test]
    fn fallback_batch_sizes_match_native_policy() {
        let be = AnalogCimBackend::new(tiny_meta(), 8);
        assert_eq!(be.batch_sizes(), FALLBACK_BATCH_SIZES.to_vec());
        let mut meta = tiny_meta();
        meta.hlo
            .insert("8b_b32".to_string(), "t_8b_b32.hlo.txt".to_string());
        let be8 = AnalogCimBackend::new(meta.clone(), 8);
        assert_eq!(be8.batch_sizes(), vec![32]);
        let be4 = AnalogCimBackend::new(meta, 4);
        assert!(be4.batch_sizes().is_empty());
    }
}
