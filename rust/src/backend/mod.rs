//! Unified inference-execution API.
//!
//! The paper evaluates one deployed model on two engines — a calibrated
//! simulator and real hardware. This repo mirrors that with three execution
//! paths behind one trait:
//!
//! * [`NativeBackend`] — the pure-Rust simulator forward pass
//!   (`simulator::NativeModel`): full-K GEMM, ADC quantized *after*
//!   accumulation. Always available; the default everywhere.
//! * [`AnalogCimBackend`] — the tile-faithful engine
//!   (`simulator::AnalogModel`): one MVM per mapped crossbar tile, ADC
//!   quantized *per tile* before digital accumulation — the schedule the
//!   AON-CiM hardware actually imposes. Always available.
//! * [`PjrtBackend`] — the AOT-exported HLO graphs executed via PJRT.
//!   Compiled only with the `pjrt` cargo feature.
//!
//! `eval`, the serving `coordinator`, the CLI, examples, and benches all
//! program weights onto the simulated PCM array, read them back (drifted,
//! noisy, at the drift time of interest), and hand the effective weights to
//! `run_batch` — they never know which engine executes. Backends are
//! selected by [`BackendKind`] and constructed with [`create`]. Each
//! `run_batch` launch additionally carries per-request options
//! ([`InferOpts`]: device age `t_drift`, quantization `adc_bits`), so
//! drift-aware serving and the paper's 4-bit ADC scenario are per-request
//! choices, not per-coordinator configuration.

mod analog;
mod native;
#[cfg(feature = "pjrt")]
mod pjrt;
mod tensor;

pub use analog::AnalogCimBackend;
pub use native::NativeBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
pub use tensor::HostTensor;

use crate::crossbar::ArrayGeom;
use crate::nn::ModelMeta;
use crate::pcm::{FaultSpec, LayerGdc};
use crate::runtime::ArtifactStore;

/// Batch sizes a [`NativeBackend`] offers when the artifact bundle exports
/// no serving graphs (the native GEMM accepts any batch; these keep the
/// dynamic batcher's padding small).
pub const FALLBACK_BATCH_SIZES: &[usize] = &[1, 2, 4, 8, 16, 32, 64, 128];

/// Serving batch sizes for weight-fed engines with no static-shape
/// constraint (native, analog): prefer the bundle's exported serving-graph
/// sizes so every backend behaves identically under the batcher; fall back
/// to [`FALLBACK_BATCH_SIZES`] only when the bundle exports *no* graphs at
/// all. A bundle that has graphs, just none at this bitwidth, deliberately
/// returns empty so serving at a wrong `--bits` still fails fast instead of
/// silently quantizing at a bitwidth the model was never exported for.
pub(crate) fn weight_fed_batch_sizes(meta: &ModelMeta, bits: u32) -> Vec<usize> {
    if meta.hlo.is_empty() {
        return FALLBACK_BATCH_SIZES.to_vec();
    }
    meta.serving_batch_sizes(bits)
}

/// Per-request inference options, threaded from a queued request through
/// the coordinator's batcher into [`InferenceBackend::run_batch`].
///
/// Every field is optional; [`InferOpts::default()`] reproduces the
/// pre-options behavior exactly (serve at the coordinator clock's device
/// age, quantize at the backend's configured bitwidth). Requests whose
/// options differ are drained into **separate** batches — one launch
/// executes under exactly one set of options
/// ([`batcher::group_fifo`](crate::coordinator::batcher::group_fifo)).
///
/// * `t_drift` — the device age (simulated seconds since programming) this
///   request should be served at. Consumed by the *weight provider*
///   ([`PcmState::weights_at`](crate::coordinator::PcmState::weights_at)),
///   which reads the PCM conductances drifted to that age; engines
///   receive already-drifted weights and ignore the field. Ages below
///   t_c = 25 s clamp up to t_c.
/// * `adc_bits` — the ADC bitwidth to quantize this request at (DAC bits
///   derive from it, eq. 3). Consumed by the engine; the paper's Table 2
///   4-bit serving scenario is `adc_bits: Some(4)` against a backend
///   configured at 8. PJRT rejects overrides (its graphs are compiled at
///   one bitwidth).
/// * `faults` — the device-variability scenario
///   ([`FaultSpec`](crate::pcm::FaultSpec)) this request should be served
///   under. The weight-side faults are consumed by the weight provider
///   (the coordinator's `PcmState` programs a faulted copy of the model);
///   the ADC-side faults by the tile engine. `None` means "whatever the
///   deployment default is" — the coordinator resolves it against its
///   configured spec. PJRT rejects any non-none spec (its graphs bake
///   clean weights in); the native engine rejects ADC-error specs (it has
///   no tiles to fault).
#[derive(Clone, Copy, Debug, Default)]
pub struct InferOpts {
    /// device age override in simulated seconds (`None` = serving clock /
    /// eval time point)
    pub t_drift: Option<f64>,
    /// ADC bitwidth override (`None` = the backend's configured bits)
    pub adc_bits: Option<u32>,
    /// lowest ADC bitwidth this request is *willing* to be served at —
    /// an explicit opt-in to the coordinator's SLO policy: under
    /// `ServeConfig::latency_slo_us`, the batcher may serve the request
    /// anywhere in `[adc_bits_floor, effective_bits]`, trading precision
    /// for modeled launch latency. `None` (the default) means the request
    /// is never requantized below its pinned/configured bitwidth, so
    /// accuracy can only change for requests that asked for the trade.
    /// Ignored when the coordinator has no latency SLO.
    pub adc_bits_floor: Option<u32>,
    /// device-variability scenario override (`None` = deployment default)
    pub faults: Option<FaultSpec>,
}

impl InferOpts {
    /// Builder-style device-age override.
    pub fn with_t_drift(mut self, t_drift_s: f64) -> Self {
        self.t_drift = Some(t_drift_s);
        self
    }

    /// Builder-style ADC bitwidth override.
    pub fn with_adc_bits(mut self, adc_bits: u32) -> Self {
        self.adc_bits = Some(adc_bits);
        self
    }

    /// Builder-style device-variability override.
    pub fn with_faults(mut self, faults: FaultSpec) -> Self {
        self.faults = Some(faults);
        self
    }

    /// Builder-style bitwidth floor (opt-in to the SLO policy's bitwidth
    /// range; see the field docs).
    pub fn with_adc_bits_floor(mut self, floor: u32) -> Self {
        self.adc_bits_floor = Some(floor);
        self
    }

    /// The bitwidth a backend configured at `backend_bits` quantizes this
    /// request at.
    pub fn effective_bits(&self, backend_bits: u32) -> u32 {
        self.adc_bits.unwrap_or(backend_bits)
    }

    /// Batch-compatibility key: two requests may share one launch iff
    /// their keys are equal. `t_drift` is clamped to t_c = 25 s *before*
    /// keying — ages below t_c are all served identically (the PCM state
    /// clamps its reads the same way), so they must not split into
    /// separate launches; this also collapses `-0.0`/`0.0`.
    /// (`f64::to_bits` makes the float field comparable; `u64::MAX` /
    /// `u32::MAX` are the `None` sentinels for the first two fields.) The
    /// fault field keys through `FaultSpec::key`: every none-equivalent
    /// spec collapses to 0 and `None` ("deployment default") stays its own
    /// `u64::MAX` class — the coordinator, not the key, resolves what the
    /// default means, so requests relying on it must not share launches
    /// with requests pinning an explicit spec. `adc_bits_floor` keys the
    /// same way (`u32::MAX` = no floor): a launch executes at exactly one
    /// bitwidth, and the SLO policy picks it per group, so requests with
    /// different permitted ranges must not share a launch.
    pub fn batch_key(&self) -> (u64, u32, u32, u64) {
        (
            self.t_drift
                .map_or(u64::MAX, |t| crate::pcm::clamp_age(t).to_bits()),
            self.adc_bits.unwrap_or(u32::MAX),
            self.adc_bits_floor.unwrap_or(u32::MAX),
            self.faults.map_or(u64::MAX, |f| f.key()),
        )
    }
}

impl PartialEq for InferOpts {
    fn eq(&self, other: &Self) -> bool {
        self.batch_key() == other.batch_key()
    }
}

impl Eq for InferOpts {}

/// The one capability check for per-request options: can an engine of
/// `kind`, configured at `backend_bits`, serve `opts` at all? Used both
/// by [`InferenceBackend::validate_args`] inside `run_batch` *and* by the
/// serving coordinator at submit time (so an unservable option fails its
/// own request instead of erroring inside the worker and killing the
/// session) — one function, so the two checks can never drift apart.
pub fn validate_opts(kind: BackendKind, backend_bits: u32,
                     opts: &InferOpts) -> anyhow::Result<()> {
    if let Some(b) = opts.adc_bits {
        anyhow::ensure!(
            (2..=16).contains(&b),
            "adc_bits override {b} outside the supported 2..=16 range"
        );
        anyhow::ensure!(
            kind != BackendKind::Pjrt || b == backend_bits,
            "adc_bits override {b} != compiled graph bitwidth \
             {backend_bits} (the pjrt backend cannot requantize per \
             request; per-request bitwidths need a weight-fed engine: \
             --backend native|analog)"
        );
    }
    if let Some(f) = opts.adc_bits_floor {
        anyhow::ensure!(
            (2..=16).contains(&f),
            "adc_bits_floor {f} outside the supported 2..=16 range"
        );
        let ceil = opts.adc_bits.unwrap_or(backend_bits);
        anyhow::ensure!(
            f <= ceil,
            "adc_bits_floor {f} exceeds the request's bitwidth {ceil} \
             (the floor bounds an SLO-policy range [floor, bits])"
        );
        anyhow::ensure!(
            kind != BackendKind::Pjrt,
            "the pjrt backend cannot serve a bitwidth range (its graphs \
             are compiled at one bitwidth); use --backend native|analog"
        );
    }
    if let Some(t) = opts.t_drift {
        anyhow::ensure!(t.is_finite(), "t_drift must be finite, got {t}");
    }
    if let Some(f) = &opts.faults {
        f.validate()?;
        anyhow::ensure!(
            kind != BackendKind::Pjrt || f.is_none(),
            "the pjrt backend cannot serve fault-injected requests (its \
             compiled graphs bake clean weights in); use --backend \
             native|analog"
        );
        anyhow::ensure!(
            kind == BackendKind::AnalogCim || !f.has_adc_error(),
            "adc_offset/adc_gain faults model per-tile converters, which \
             only the tile-faithful engine has: use --backend analog"
        );
    }
    Ok(())
}

/// One inference engine executing a deployed model.
///
/// `x` is a `[batch, H, W, C]` row-major feature block, `weights[l]` the
/// *effective* (possibly drifted) weight tensor of layer `l` in graph
/// shape, and `gdc[l]` its global-drift-compensation scale; `opts` carries
/// the per-request options the whole launch executes under (see
/// [`InferOpts`]). Returns the flattened `[batch, num_classes]` logits.
pub trait InferenceBackend {
    /// Short engine name ("native", "pjrt") for logs and tables.
    fn name(&self) -> &'static str;

    /// Which engine family this is — drives the option capability check
    /// ([`validate_opts`]).
    fn kind(&self) -> BackendKind;

    /// Metadata of the model this backend executes.
    fn meta(&self) -> &ModelMeta;

    /// ADC bitwidth the backend quantizes at (native) or was compiled for
    /// (PJRT graph selection).
    fn bits(&self) -> u32;

    /// Batch sizes this backend can launch, ascending. For PJRT these are
    /// the exported static graph shapes; the native simulator falls back to
    /// [`FALLBACK_BATCH_SIZES`] when none are exported.
    fn batch_sizes(&self) -> Vec<usize>;

    /// Whether `run_batch` accepts *arbitrary* batch sizes (no static graph
    /// shapes). Dynamic engines let the serving coordinator drain up to
    /// `ServeConfig::max_batch` queued requests into a single layer-serial
    /// launch with zero padding ([`batcher::plan_dynamic`]); static engines
    /// (PJRT's AOT graphs) go through the padded [`batcher::plan`] path.
    ///
    /// [`batcher::plan`]: crate::coordinator::batcher::plan
    /// [`batcher::plan_dynamic`]: crate::coordinator::batcher::plan_dynamic
    fn supports_dynamic_batch(&self) -> bool {
        false
    }

    /// Cheap liveness check: can this backend execute at all? PJRT verifies
    /// the runtime/client can be created (catching a missing XLA native
    /// library) *without* compiling any graph, so callers like
    /// `Coordinator::start` can fail fast on the caller thread instead of
    /// dying opaquely inside a worker. No-op for native.
    fn probe(&self) -> anyhow::Result<()> {
        Ok(())
    }

    /// Warm-up hook: compile/load whatever `run_batch(batch)` will need so
    /// it never happens on the serving hot path. No-op for native.
    fn prepare(&self, _batch: usize) -> anyhow::Result<()> {
        Ok(())
    }

    /// The tile geometry per-tile GDC calibration should target, if this
    /// engine quantizes per tile ([`AnalogCimBackend`] returns its array
    /// geometry; full-K engines return `None` and get uniform GDC).
    fn calib_geom(&self) -> Option<ArrayGeom> {
        None
    }

    /// Launch-schedule estimator for the array this engine simulates
    /// ([`ScheduleModel`](crate::timing::ScheduleModel)): modeled
    /// latency/energy of the batched layer-serial launches, used by the
    /// coordinator for energy metrics and the `latency_slo_us` policy.
    /// Weight-fed engines map their meta onto their engine's geometry;
    /// `None` (the PJRT default — real-hardware timing is unknown to the
    /// host) makes the coordinator fall back to mapping the meta onto the
    /// paper's AON array.
    fn schedule_model(&self) -> Option<crate::timing::ScheduleModel> {
        None
    }

    /// Shared `run_batch` argument validation — one set of diagnostics for
    /// every engine, instead of an opaque executor error deep inside.
    fn validate_args(&self, x: &[f32], batch: usize, weights: &[HostTensor],
                     gdc: &[LayerGdc], opts: &InferOpts) -> anyhow::Result<()> {
        validate_opts(self.kind(), self.bits(), opts)?;
        let layers = self.meta().layers.len();
        anyhow::ensure!(
            weights.len() == layers,
            "{} backend: {} weight tensors for {layers} layers",
            self.name(),
            weights.len()
        );
        anyhow::ensure!(
            gdc.len() == layers,
            "{} backend: {} gdc factors for {layers} layers",
            self.name(),
            gdc.len()
        );
        anyhow::ensure!(
            x.len() == batch * self.feat_len(),
            "{} backend: input length {} != batch {batch} x feat {}",
            self.name(),
            x.len(),
            self.feat_len()
        );
        for (t, lm) in weights.iter().zip(self.meta().layers.iter()) {
            let want: usize = lm.graph_weight_shape.iter().product();
            anyhow::ensure!(
                t.numel() == want,
                "{} backend: layer {} weight has {} elements, graph \
                 shape {:?} needs {want}",
                self.name(),
                lm.name,
                t.numel(),
                lm.graph_weight_shape
            );
        }
        Ok(())
    }

    /// Execute one batch under one set of per-request options; see the
    /// trait docs for the argument contract. Implementations call
    /// [`validate_args`](Self::validate_args) first. Pass
    /// `&InferOpts::default()` for the backend's configured behavior.
    ///
    /// `opts.adc_bits` selects the quantization bitwidth for this launch;
    /// `opts.t_drift` is metadata for the weight provider (the weights
    /// handed in are expected to already be read at that age) and is
    /// ignored by engines.
    fn run_batch(&self, x: &[f32], batch: usize, weights: &[HostTensor],
                 gdc: &[LayerGdc], opts: &InferOpts) -> anyhow::Result<Vec<f32>>;

    /// Input feature dimensions (height, width, channels).
    fn input_hwc(&self) -> (usize, usize, usize) {
        self.meta().input_hwc
    }

    /// Flattened per-sample feature length.
    fn feat_len(&self) -> usize {
        let (h, w, c) = self.input_hwc();
        h * w * c
    }

    fn num_classes(&self) -> usize {
        self.meta().num_classes
    }
}

/// Which execution engine to construct.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust simulator forward pass (always available).
    #[default]
    Native,
    /// Tile-faithful crossbar execution: per-tile MVM + per-tile ADC
    /// quantization on the mapped array geometry (always available).
    AnalogCim,
    /// Compiled HLO graphs via PJRT (requires the `pjrt` cargo feature).
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "native" | "sim" => Ok(BackendKind::Native),
            "analog" | "analog-cim" | "cim" => Ok(BackendKind::AnalogCim),
            "pjrt" | "xla" => Ok(BackendKind::Pjrt),
            _ => anyhow::bail!(
                "unknown backend `{s}` (expected native|analog|pjrt)"
            ),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::AnalogCim => "analog",
            BackendKind::Pjrt => "pjrt",
        }
    }

    /// Parse the shared `--backend` CLI option (default `native`) — the one
    /// helper behind the CLI, the examples, and the benches.
    pub fn from_args(args: &crate::util::cli::Args) -> anyhow::Result<Self> {
        Self::parse(&args.opt_or("backend", "native"))
    }

    /// Whether this binary can construct the backend at all.
    pub fn available(&self) -> bool {
        match self {
            BackendKind::Native | BackendKind::AnalogCim => true,
            BackendKind::Pjrt => cfg!(feature = "pjrt"),
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        BackendKind::parse(s)
    }
}

/// Construct the requested backend for `vid` against an opened artifact
/// store. The returned trait object borrows the store (PJRT compiles its
/// executables through the store's cache). The native GEMM pool is sized
/// automatically (all cores, capped at 8); use [`create_with_threads`] to
/// pin it.
pub fn create<'a>(kind: BackendKind, store: &'a ArtifactStore, vid: &str,
                  bits: u32) -> anyhow::Result<Box<dyn InferenceBackend + 'a>> {
    create_with_threads(kind, store, vid, bits, 0)
}

/// [`create`] with an explicit native GEMM thread-pool size. `threads == 0`
/// keeps the automatic policy (`available_parallelism`, capped at 8 — the
/// layer shapes we serve stop scaling past that). PJRT ignores the knob:
/// its intra-op parallelism belongs to the XLA runtime.
pub fn create_with_threads<'a>(kind: BackendKind, store: &'a ArtifactStore,
                               vid: &str, bits: u32, threads: usize)
                               -> anyhow::Result<Box<dyn InferenceBackend + 'a>> {
    match kind {
        BackendKind::Native => {
            let meta = store.meta(vid)?;
            Ok(Box::new(NativeBackend::with_threads(meta, bits,
                                                    auto_threads(threads))))
        }
        BackendKind::AnalogCim => {
            // the factory always builds the paper's AON array; use
            // `AnalogCimBackend::with_geom` + `eval::drift_accuracy_on` for
            // tile-geometry ablations
            let meta = store.meta(vid)?;
            Ok(Box::new(AnalogCimBackend::with_threads(meta, bits,
                                                       auto_threads(threads))))
        }
        BackendKind::Pjrt => create_pjrt(store, vid, bits),
    }
}

/// The automatic worker-pool policy behind [`create`]: all cores, capped at
/// 8 (the layer shapes we serve stop scaling past that). An explicit
/// `threads` is taken as-is. Public so caller-constructed backends (the
/// tile-ablation path building `AnalogCimBackend::with_geom` directly) can
/// apply the same policy as the factory.
pub fn auto_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get().min(8))
            .unwrap_or(1)
    } else {
        threads
    }
}

#[cfg(feature = "pjrt")]
fn create_pjrt<'a>(store: &'a ArtifactStore, vid: &str, bits: u32)
                   -> anyhow::Result<Box<dyn InferenceBackend + 'a>> {
    Ok(Box::new(pjrt::PjrtBackend::new(store, vid, bits)?))
}

#[cfg(not(feature = "pjrt"))]
fn create_pjrt<'a>(_store: &'a ArtifactStore, _vid: &str, _bits: u32)
                   -> anyhow::Result<Box<dyn InferenceBackend + 'a>> {
    anyhow::bail!(
        "backend `pjrt` is not compiled in: rebuild with `--features pjrt` \
         (and a real xla crate) to execute the exported HLO graphs; the \
         `native` backend needs neither"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parses_and_prints() {
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("analog").unwrap(),
                   BackendKind::AnalogCim);
        assert_eq!(BackendKind::parse("analog-cim").unwrap(),
                   BackendKind::AnalogCim);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(BackendKind::Native.to_string(), "native");
        assert_eq!(BackendKind::AnalogCim.to_string(), "analog");
        assert_eq!(BackendKind::default(), BackendKind::Native);
        assert!(BackendKind::Native.available());
        assert!(BackendKind::AnalogCim.available());
    }

    #[test]
    fn pjrt_availability_tracks_feature() {
        assert_eq!(BackendKind::Pjrt.available(), cfg!(feature = "pjrt"));
    }

    #[test]
    fn infer_opts_keys_and_defaults() {
        let d = InferOpts::default();
        assert_eq!(d.effective_bits(8), 8);
        assert_eq!(d, InferOpts::default());

        let aged = InferOpts::default().with_t_drift(86_400.0);
        let aged2 = InferOpts {
            t_drift: Some(86_400.0),
            adc_bits: None,
            adc_bits_floor: None,
            faults: None,
        };
        assert_eq!(aged, aged2);
        assert_ne!(aged.batch_key(), d.batch_key());

        let b4 = InferOpts::default().with_adc_bits(4);
        assert_eq!(b4.effective_bits(8), 4);
        assert_ne!(b4, d);
        assert_ne!(b4, aged);
        // both fields participate in the launch-compatibility key
        assert_ne!(aged.with_adc_bits(4).batch_key(), aged.batch_key());

        // sub-t_c ages are all served identically, so they key identically
        // (and stay distinct from "no override": the serving clock moves)
        let t_c = crate::pcm::T_C_SECONDS;
        assert_eq!(InferOpts::default().with_t_drift(0.0),
                   InferOpts::default().with_t_drift(10.0));
        assert_eq!(InferOpts::default().with_t_drift(-0.0),
                   InferOpts::default().with_t_drift(t_c));
        assert_ne!(InferOpts::default().with_t_drift(t_c), d);

        // the fault field joins the launch-compatibility key: an explicit
        // none-spec is its own class (distinct from "deployment default"),
        // and distinct seeds split launches
        let none_spec = InferOpts::default().with_faults(FaultSpec::none());
        assert_ne!(none_spec, d);
        assert_eq!(none_spec.batch_key().3, 0);

        // a bitwidth floor is part of the launch-compatibility key: the
        // SLO policy picks one bitwidth per group, so different permitted
        // ranges must not share a launch
        let ranged = InferOpts::default().with_adc_bits_floor(4);
        assert_ne!(ranged, d);
        assert_ne!(ranged.batch_key(), d.batch_key());
        assert_eq!(ranged, InferOpts::default().with_adc_bits_floor(4));
        let s1 = FaultSpec { stuck_min: 0.01, seed: 1, ..FaultSpec::none() };
        let s2 = FaultSpec { seed: 2, ..s1 };
        assert_ne!(InferOpts::default().with_faults(s1),
                   InferOpts::default().with_faults(s2));
        assert_eq!(InferOpts::default().with_faults(s1),
                   InferOpts::default().with_faults(s1));
    }

    #[test]
    fn validate_opts_gates_fault_specs_per_engine() {
        let bad = FaultSpec { stuck_min: 2.0, ..FaultSpec::none() };
        let weighty = FaultSpec { stuck_min: 0.01, ..FaultSpec::none() };
        let adc = FaultSpec { adc_gain_sigma: 0.02, ..FaultSpec::none() };
        let ok = |k, f: FaultSpec| {
            validate_opts(k, 8, &InferOpts::default().with_faults(f))
        };
        // invalid specs fail everywhere — this is the submit-time gate
        assert!(ok(BackendKind::Native, bad).is_err());
        assert!(ok(BackendKind::AnalogCim, bad).is_err());
        // weight-side faults run on any weight-fed engine
        assert!(ok(BackendKind::Native, weighty).is_ok());
        assert!(ok(BackendKind::AnalogCim, weighty).is_ok());
        assert!(ok(BackendKind::Pjrt, weighty).is_err());
        // ADC faults need per-tile converters
        assert!(ok(BackendKind::Native, adc).is_err());
        assert!(ok(BackendKind::AnalogCim, adc).is_ok());
        // explicit none is servable everywhere
        assert!(ok(BackendKind::Pjrt, FaultSpec::none()).is_ok());
    }

    #[test]
    fn validate_opts_gates_bitwidth_floors() {
        let v = |k, o: &InferOpts| validate_opts(k, 8, o);
        // a sane range is fine on weight-fed engines
        let ranged = InferOpts::default().with_adc_bits_floor(4);
        assert!(v(BackendKind::Native, &ranged).is_ok());
        assert!(v(BackendKind::AnalogCim, &ranged).is_ok());
        // ...but PJRT cannot requantize at all
        assert!(v(BackendKind::Pjrt, &ranged).is_err());
        // floor must stay inside 2..=16 and below the effective bits
        assert!(v(BackendKind::Native,
                  &InferOpts::default().with_adc_bits_floor(1)).is_err());
        assert!(v(BackendKind::Native,
                  &InferOpts::default().with_adc_bits_floor(17)).is_err());
        assert!(v(BackendKind::Native,
                  &InferOpts::default().with_adc_bits_floor(10)).is_err());
        // against a pinned per-request bitwidth, the pin is the ceiling
        let pinned = InferOpts::default().with_adc_bits(6);
        assert!(v(BackendKind::Native, &pinned.with_adc_bits_floor(4)).is_ok());
        assert!(v(BackendKind::Native, &pinned.with_adc_bits_floor(7)).is_err());
    }
}
