//! Backend-neutral host tensor: the interchange type every
//! [`InferenceBackend`](crate::backend::InferenceBackend) consumes.
//!
//! Lived in `runtime` while execution was PJRT-only; it is deliberately
//! free of `xla` types so `eval`, `coordinator`, and the native simulator
//! share it without pulling in the XLA toolchain.

/// A host-side tensor: row-major f32 data plus its shape.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    /// Panics if `shape` does not describe exactly `data.len()` elements.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape, data }
    }

    /// An all-zero tensor of the given shape (batched scratch / test rigs).
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        HostTensor { shape, data: vec![0f32; n] }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Copy a loaded weight tensor into host-tensor form.
    pub fn from_tensor(t: &crate::nn::Tensor) -> Self {
        HostTensor::new(t.shape.clone(), t.data.clone())
    }
}

impl AsRef<[f32]> for HostTensor {
    fn as_ref(&self) -> &[f32] {
        &self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_validates_shape() {
        let t = HostTensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape, vec![2, 3]);
        assert_eq!(t.numel(), 6);
    }

    #[test]
    #[should_panic]
    fn host_tensor_rejects_bad_shape() {
        HostTensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn zeros_has_shape_product_elements() {
        let t = HostTensor::zeros(vec![3, 4]);
        assert_eq!(t.numel(), 12);
        assert!(t.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn from_tensor_copies() {
        let t = crate::nn::Tensor {
            shape: vec![2, 2],
            data: vec![1.0, 2.0, 3.0, 4.0],
        };
        let h = HostTensor::from_tensor(&t);
        assert_eq!(h.shape, t.shape);
        assert_eq!(h.as_ref(), &t.data[..]);
    }
}
