//! Minimal JSON parser/writer (serde is not vendored in this environment).
//!
//! Supports the full JSON grammar minus exotic escapes; good enough for the
//! `meta.json` / `manifest.json` artifact contract, which we control on both
//! sides. Numbers parse to f64 (ints round-trip exactly up to 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key `{key}`"))
    }
    pub fn as_f64(&self) -> anyhow::Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => anyhow::bail!("expected number, got {self:?}"),
        }
    }
    pub fn as_usize(&self) -> anyhow::Result<usize> {
        Ok(self.as_f64()? as usize)
    }
    pub fn as_bool(&self) -> anyhow::Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => anyhow::bail!("expected bool, got {self:?}"),
        }
    }
    pub fn as_str(&self) -> anyhow::Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => anyhow::bail!("expected string, got {self:?}"),
        }
    }
    pub fn as_arr(&self) -> anyhow::Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => anyhow::bail!("expected array, got {self:?}"),
        }
    }
    pub fn as_obj(&self) -> anyhow::Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => anyhow::bail!("expected object, got {self:?}"),
        }
    }
    pub fn f64s(&self) -> anyhow::Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }
    pub fn f32s(&self) -> anyhow::Result<Vec<f32>> {
        Ok(self.f64s()?.into_iter().map(|v| v as f32).collect())
    }
    pub fn usizes(&self) -> anyhow::Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

pub fn parse(src: &str) -> anyhow::Result<Json> {
    let mut p = Parser {
        b: src.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        anyhow::bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let src = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
    parse(&src).map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }
    fn peek(&self) -> anyhow::Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected eof"))
    }
    fn eat(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek()? != c {
            anyhow::bail!("expected `{}` at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }
    fn lit(&mut self, s: &str, v: Json) -> anyhow::Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => anyhow::bail!("expected , or }} got `{}`", c as char),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => anyhow::bail!("expected , or ] got `{}`", c as char),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => anyhow::bail!("bad escape at byte {}", self.i),
                    }
                }
                _ => {
                    // collect the full utf-8 sequence
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

pub fn write(v: &Json) -> String {
    let mut s = String::new();
    write_into(v, &mut s);
    s
}

fn write_into(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => {
                        let _ = write!(out, "\\u{:04x}", c as u32);
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(&Json::Str(k.clone()), out);
                out.push(':');
                write_into(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "3.5", "-2", "\"hi\\n\""] {
            let v = parse(src).unwrap();
            let v2 = parse(&write(&v)).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2.5, {"b": "x", "c": null}], "d": true}"#).unwrap();
        assert_eq!(v.get("d").unwrap().as_bool().unwrap(), true);
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64().unwrap(), 2.5);
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{} trailing").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ☕");
    }

    #[test]
    fn f64s_accessor() {
        let v = parse("[1, 2, 3.5]").unwrap();
        assert_eq!(v.f64s().unwrap(), vec![1.0, 2.0, 3.5]);
    }

    #[test]
    fn writer_escapes_control() {
        let s = write(&Json::Str("a\u{1}b".into()));
        assert_eq!(s, "\"a\\u0001b\"");
    }
}
