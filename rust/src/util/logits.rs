//! Logits post-processing shared by every execution consumer.
//!
//! One implementation of argmax/accuracy instead of the three hand-rolled
//! loops that used to live in `simulator::forward`, `eval`, and
//! `coordinator::server`. Tie-breaking matches the originals: `max_by`
//! over `f32::total_cmp`, so the *last* maximal class wins and NaN orders
//! deterministically.

/// Index of the maximal logit in one row (0 for an empty row).
pub fn argmax(row: &[f32]) -> u32 {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i as u32)
        .unwrap_or(0)
}

/// Argmax predictions for flattened `[rows, classes]` logits.
pub fn predictions(logits: &[f32], classes: usize) -> Vec<u32> {
    logits.chunks_exact(classes).map(argmax).collect()
}

/// Correct predictions over the first `labels.len()` rows — extra logits
/// rows (batch padding) are ignored, so callers can pass a padded batch's
/// output against the true-sample labels directly.
pub fn count_correct(logits: &[f32], classes: usize, labels: &[u32]) -> usize {
    logits
        .chunks_exact(classes)
        .zip(labels.iter())
        .filter(|(row, &y)| argmax(row) == y)
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic_and_ties() {
        assert_eq!(argmax(&[0.1, 0.9, 0.7]), 1);
        assert_eq!(argmax(&[]), 0);
        // last maximal element wins, matching the previous max_by loops
        assert_eq!(argmax(&[0.5, 0.5]), 1);
    }

    #[test]
    fn predictions_rows() {
        assert_eq!(predictions(&[0.1, 0.9, 0.7, 0.3], 2), vec![1, 0]);
    }

    #[test]
    fn count_correct_ignores_padding() {
        // 3 logits rows, only 2 labelled samples (third row is padding)
        let logits = [0.0, 1.0, 1.0, 0.0, 9.0, 0.0];
        assert_eq!(count_correct(&logits, 2, &[1, 0]), 2);
        assert_eq!(count_correct(&logits, 2, &[1, 1]), 1);
    }
}
