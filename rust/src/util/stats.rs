//! Small statistics helpers used by the benchmark harness and experiments.

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0 for n<2).
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// p-th percentile (linear interpolation), p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Simple accuracy summary over repeated runs: (mean %, std %).
pub fn acc_summary(accs: &[f64]) -> (f64, f64) {
    (100.0 * mean(accs), 100.0 * std(accs))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std(&xs) - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentile_interp() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn empty_is_nan() {
        assert!(mean(&[]).is_nan());
        assert!(percentile(&[], 50.0).is_nan());
    }
}
