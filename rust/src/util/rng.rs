//! Deterministic PRNG: xoshiro256++ with Gaussian sampling.
//!
//! All stochastic device physics (programming noise, drift exponents, read
//! noise) flows through this generator so every simulator run is exactly
//! reproducible from its seed — a requirement for the paper's
//! mean-and-uncertainty-band experiments (Figure 7).

/// xoshiro256++ (Blackman & Vigna). Passes BigCrush; 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Gaussian from the last Box-Muller pair
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (for per-run / per-layer forks).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.uniform() * n as f64) as usize % n.max(1)
    }

    /// Standard normal via Box-Muller (pair-cached).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        // avoid log(0)
        let u1 = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * th.sin());
        r * th.cos()
    }

    /// Normal with mean/std.
    #[inline]
    pub fn gauss(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(3);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(xs, (0..100).collect::<Vec<u32>>());
    }
}
