//! Plain-text table rendering for benchmark reports (criterion is not
//! vendored; each bench binary prints the paper's table/figure rows itself).

pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push(' ');
                s.push_str(c);
                s.push_str(&" ".repeat(widths[i] - c.len() + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// CSV dump (for plotting figure data downstream).
    pub fn to_csv(&self) -> String {
        let mut s = self.headers.join(",");
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.join(","));
            s.push('\n');
        }
        s
    }
}

/// Format helper: fixed decimals.
pub fn f(v: f64, d: usize) -> String {
    format!("{v:.*}", d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "long_header"]);
        t.row(&["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("long_header"));
        assert!(s.lines().count() >= 6);
    }

    #[test]
    fn csv() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("T", &["a"]);
        t.row(&["1".into(), "2".into()]);
    }
}
