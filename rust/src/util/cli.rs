//! Tiny CLI argument parser (clap is not vendored).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name)
            .map(|v| v.parse().expect("integer option"))
            .unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name)
            .map(|v| v.parse().expect("float option"))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn mixed_forms() {
        // note: a bare `--flag value` is ambiguous; positionals go first
        let a = p("serve pos1 --task kws --runs=5 --verbose");
        assert_eq!(a.positional, vec!["serve", "pos1"]);
        assert_eq!(a.opt("task"), Some("kws"));
        assert_eq!(a.opt_usize("runs", 0), 5);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn flag_before_flag() {
        let a = p("--a --b v");
        assert!(a.flag("a"));
        assert_eq!(a.opt("b"), Some("v"));
    }

    #[test]
    fn defaults() {
        let a = p("");
        assert_eq!(a.opt_or("x", "d"), "d");
        assert_eq!(a.opt_f64("y", 1.5), 1.5);
    }
}
