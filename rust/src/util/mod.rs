//! Offline-environment utility substrates.
//!
//! The build environment vendors only the `xla` crate's dependency closure,
//! so the usual ecosystem crates (rand, serde, clap, criterion) are
//! unavailable; these modules provide the small subset the project needs
//! (see DESIGN.md "Substitutions").

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
