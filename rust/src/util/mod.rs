//! Offline-environment utility substrates.
//!
//! The build environment has no crates.io registry, so the usual ecosystem
//! crates (rand, serde, clap, criterion) are unavailable; these modules
//! provide the small subset the project needs (see DESIGN.md
//! "Substitutions"), and `rust/vendor/` carries the `anyhow` shim and the
//! `xla` API stub the Cargo manifest resolves against.

pub mod cli;
pub mod json;
pub mod logits;
pub mod rng;
pub mod stats;
pub mod table;
