//! Device-variability fault injection ("On the Accuracy of Analog Neural
//! Network Inference Accelerators", arXiv:2109.01262).
//!
//! A [`FaultSpec`] bundles the non-idealities that dominate real arrays
//! beyond the calibrated drift/noise statistics: stuck-at cells (pinned to
//! G_min or G_max regardless of programming), per-device conductance
//! variation on top of programming noise, and per-tile ADC offset/gain
//! error. Everything is seeded: the same spec always produces the same
//! fault pattern, independent of the deployment RNG, so CI fault-sweep
//! numbers are reproducible across processes.
//!
//! The weight-side faults (stuck cells, conductance sigma) are applied
//! once, at programming time, by [`ProgrammedWeights::apply_faults`]
//! (see `weights`); the ADC-side faults are applied at execution time by
//! the tile-grid engine via [`AdcFault`] — a stuck cell is a property of
//! the array, an ADC error a property of each tile's converter.

use crate::util::rng::Rng;

/// Odd 64-bit mixing constant (splitmix64's golden-gamma).
const MIX: u64 = 0x9E37_79B9_7F4A_7C15;

/// Derive an independent RNG stream for one (seed, tag) pair.
pub(crate) fn stream(seed: u64, tag: u64) -> Rng {
    // splitmix-style finalizer so nearby tags decorrelate
    let mut z = seed ^ tag.wrapping_mul(MIX);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    Rng::new(z ^ (z >> 31))
}

/// A complete device-variability scenario. `Copy` on purpose: it rides
/// inside `InferOpts` and batch keys.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultSpec {
    /// fraction of cells stuck at G_min (read as conductance 0)
    pub stuck_min: f64,
    /// fraction of cells stuck at G_max (read as conductance 1)
    pub stuck_max: f64,
    /// extra per-device multiplicative conductance sigma (relative)
    pub g_sigma: f64,
    /// per-tile ADC offset sigma, as a fraction of the tile's ADC range
    pub adc_offset_sigma: f64,
    /// per-tile ADC gain error sigma (relative, around 1.0)
    pub adc_gain_sigma: f64,
    /// fault-pattern seed (independent of the deployment RNG)
    pub seed: u64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        Self::none()
    }
}

impl FaultSpec {
    /// The fault-free spec: every path treats it exactly like "no faults".
    pub fn none() -> Self {
        FaultSpec {
            stuck_min: 0.0,
            stuck_max: 0.0,
            g_sigma: 0.0,
            adc_offset_sigma: 0.0,
            adc_gain_sigma: 0.0,
            seed: 0,
        }
    }

    /// True when every fault magnitude is zero (the seed is irrelevant).
    pub fn is_none(&self) -> bool {
        self.stuck_min == 0.0
            && self.stuck_max == 0.0
            && self.g_sigma == 0.0
            && !self.has_adc_error()
    }

    /// True when any weight-side fault (stuck cells, conductance sigma)
    /// is active — these change `ProgrammedWeights`, not the engine.
    pub fn has_weight_faults(&self) -> bool {
        self.stuck_min > 0.0 || self.stuck_max > 0.0 || self.g_sigma > 0.0
    }

    /// True when the per-tile ADC transfer function is perturbed.
    pub fn has_adc_error(&self) -> bool {
        self.adc_offset_sigma != 0.0 || self.adc_gain_sigma != 0.0
    }

    /// Reject physically meaningless specs. This is the submit-time gate:
    /// `backend::validate_opts` calls it so an invalid spec errors at
    /// `Coordinator::submit` instead of killing the worker mid-batch.
    pub fn validate(&self) -> anyhow::Result<()> {
        for (name, v) in [("stuck_min", self.stuck_min),
                          ("stuck_max", self.stuck_max)] {
            anyhow::ensure!(v.is_finite() && (0.0..=1.0).contains(&v),
                            "fault spec: {name}={v} must be in [0, 1]");
        }
        anyhow::ensure!(self.stuck_min + self.stuck_max <= 1.0,
                        "fault spec: stuck_min + stuck_max = {} exceeds 1",
                        self.stuck_min + self.stuck_max);
        for (name, v) in [("g_sigma", self.g_sigma),
                          ("adc_offset", self.adc_offset_sigma),
                          ("adc_gain", self.adc_gain_sigma)] {
            anyhow::ensure!(v.is_finite() && v >= 0.0,
                            "fault spec: {name}={v} must be finite and >= 0");
        }
        Ok(())
    }

    /// Deterministic cache/batch key. All `none()`-equivalent specs key to
    /// 0 regardless of seed, so "no faults" is one equivalence class.
    pub fn key(&self) -> u64 {
        if self.is_none() {
            return 0;
        }
        let mut h: u64 = 0xcbf2_9ce4_8422_2325; // FNV-1a offset basis
        for bits in [self.stuck_min.to_bits(),
                     self.stuck_max.to_bits(),
                     self.g_sigma.to_bits(),
                     self.adc_offset_sigma.to_bits(),
                     self.adc_gain_sigma.to_bits(),
                     self.seed] {
            h = (h ^ bits).wrapping_mul(0x1000_0000_01b3);
        }
        // never collide with the reserved "no faults" key
        h | 1
    }

    /// The execution-time (ADC) part of the spec, for the tile engine.
    pub fn adc_fault(&self) -> AdcFault {
        AdcFault {
            gain_sigma: self.adc_gain_sigma as f32,
            offset_sigma: self.adc_offset_sigma as f32,
            seed: self.seed,
        }
    }

    /// Parse the CLI grammar: comma-separated `key=value` pairs with keys
    /// `stuck_min`, `stuck_max`, `g_sigma`, `adc_offset`, `adc_gain`,
    /// `seed`; omitted keys stay 0. Example:
    /// `--faults stuck_min=0.01,adc_gain=0.02,seed=7`.
    pub fn parse(s: &str) -> anyhow::Result<FaultSpec> {
        let mut spec = FaultSpec::none();
        for part in s.split(',').filter(|p| !p.trim().is_empty()) {
            let (k, v) = part.split_once('=').ok_or_else(|| {
                anyhow::anyhow!("fault spec: `{part}` is not key=value")
            })?;
            let (k, v) = (k.trim(), v.trim());
            match k {
                "stuck_min" => spec.stuck_min = parse_f64(k, v)?,
                "stuck_max" => spec.stuck_max = parse_f64(k, v)?,
                "g_sigma" => spec.g_sigma = parse_f64(k, v)?,
                "adc_offset" => spec.adc_offset_sigma = parse_f64(k, v)?,
                "adc_gain" => spec.adc_gain_sigma = parse_f64(k, v)?,
                "seed" => {
                    spec.seed = v.parse().map_err(|_| {
                        anyhow::anyhow!("fault spec: seed=`{v}` not an integer")
                    })?
                }
                _ => anyhow::bail!(
                    "fault spec: unknown key `{k}` (expected stuck_min, \
                     stuck_max, g_sigma, adc_offset, adc_gain, seed)"
                ),
            }
        }
        spec.validate()?;
        Ok(spec)
    }
}

fn parse_f64(k: &str, v: &str) -> anyhow::Result<f64> {
    v.parse()
        .map_err(|_| anyhow::anyhow!("fault spec: {k}=`{v}` not a number"))
}

/// The ADC-side faults, carried to the tile engine. One converter serves
/// one tile (through the column mux), so gain/offset are drawn *per tile*
/// from `(seed, layer, kt, ct)` — stable across batches and processes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdcFault {
    pub gain_sigma: f32,
    pub offset_sigma: f32,
    pub seed: u64,
}

impl AdcFault {
    pub const NONE: AdcFault = AdcFault {
        gain_sigma: 0.0,
        offset_sigma: 0.0,
        seed: 0,
    };

    pub fn is_none(&self) -> bool {
        self.gain_sigma == 0.0 && self.offset_sigma == 0.0
    }

    /// This tile's (gain, offset) pair; offset is a fraction of the ADC
    /// range (the engine scales it by `r_adc`). Fault-free specs return
    /// exactly `(1.0, 0.0)`.
    pub fn tile_gain_offset(&self, layer: usize, kt: usize, ct: usize)
                            -> (f32, f32) {
        if self.is_none() {
            return (1.0, 0.0);
        }
        let tag = (layer as u64)
            .wrapping_mul(0x100_0003)
            .wrapping_add((kt as u64).wrapping_mul(0x10_001))
            .wrapping_add(ct as u64)
            ^ 0xADC0;
        let mut rng = stream(self.seed, tag);
        let gain = 1.0 + rng.gauss(0.0, self.gain_sigma as f64);
        let off = rng.gauss(0.0, self.offset_sigma as f64);
        (gain as f32, off as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_spec_is_inert_and_keys_to_zero() {
        let n = FaultSpec::none();
        assert!(n.is_none());
        assert!(!n.has_weight_faults() && !n.has_adc_error());
        assert_eq!(n.key(), 0);
        // the seed does not matter for a zero-magnitude spec
        assert_eq!(FaultSpec { seed: 99, ..n }.key(), 0);
        assert!(n.validate().is_ok());
        assert_eq!(n.adc_fault(), AdcFault::NONE);
    }

    #[test]
    fn keys_separate_distinct_specs() {
        let a = FaultSpec { stuck_min: 0.01, seed: 1, ..FaultSpec::none() };
        let b = FaultSpec { stuck_min: 0.01, seed: 2, ..FaultSpec::none() };
        let c = FaultSpec { stuck_min: 0.02, seed: 1, ..FaultSpec::none() };
        assert_ne!(a.key(), 0);
        assert_ne!(a.key(), b.key());
        assert_ne!(a.key(), c.key());
        assert_eq!(a.key(), a.key());
    }

    #[test]
    fn validate_rejects_bad_fractions_and_sigmas() {
        let n = FaultSpec::none();
        assert!(FaultSpec { stuck_min: -0.1, ..n }.validate().is_err());
        assert!(FaultSpec { stuck_max: 1.5, ..n }.validate().is_err());
        assert!(FaultSpec { stuck_min: 0.6, stuck_max: 0.6, ..n }
            .validate()
            .is_err());
        assert!(FaultSpec { g_sigma: f64::NAN, ..n }.validate().is_err());
        assert!(FaultSpec { adc_gain_sigma: -1.0, ..n }.validate().is_err());
        assert!(FaultSpec { stuck_min: 0.5, stuck_max: 0.5, g_sigma: 0.1, ..n }
            .validate()
            .is_ok());
    }

    #[test]
    fn parse_round_trips_the_cli_grammar() {
        let s = FaultSpec::parse(
            "stuck_min=0.01,stuck_max=0.005,g_sigma=0.05,adc_offset=0.02,\
             adc_gain=0.03,seed=42",
        )
        .unwrap();
        assert_eq!(s.stuck_min, 0.01);
        assert_eq!(s.stuck_max, 0.005);
        assert_eq!(s.g_sigma, 0.05);
        assert_eq!(s.adc_offset_sigma, 0.02);
        assert_eq!(s.adc_gain_sigma, 0.03);
        assert_eq!(s.seed, 42);
        // partial specs default the rest to zero
        let p = FaultSpec::parse("stuck_max=0.1").unwrap();
        assert_eq!(p.stuck_max, 0.1);
        assert_eq!(p.stuck_min, 0.0);
        // junk is refused
        assert!(FaultSpec::parse("stuck_min").is_err());
        assert!(FaultSpec::parse("wat=1").is_err());
        assert!(FaultSpec::parse("stuck_min=nope").is_err());
        assert!(FaultSpec::parse("stuck_min=2.0").is_err());
    }

    #[test]
    fn adc_fault_draws_are_per_tile_and_deterministic() {
        let f = AdcFault { gain_sigma: 0.05, offset_sigma: 0.02, seed: 9 };
        let a = f.tile_gain_offset(0, 0, 0);
        let b = f.tile_gain_offset(0, 0, 1);
        let c = f.tile_gain_offset(1, 0, 0);
        assert_eq!(a, f.tile_gain_offset(0, 0, 0), "same tile, same draw");
        assert_ne!(a, b, "neighbouring tiles decorrelate");
        assert_ne!(a, c, "layers decorrelate");
        assert!((a.0 - 1.0).abs() < 0.5 && a.1.abs() < 0.5);
        assert_eq!(AdcFault::NONE.tile_gain_offset(3, 2, 1), (1.0, 0.0));
    }
}
