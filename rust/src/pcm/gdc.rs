//! Global drift compensation (Joshi et al., 2020), per layer *and* per
//! tile.
//!
//! The *global* component of conductance drift is corrected digitally: the
//! accelerator periodically reads the summed conductance of a layer's array
//! section and scales the ADC outputs by `alpha = sum(G_target) /
//! sum(G_now)`.  Device-to-device variability remains uncompensated — that
//! residual is exactly what limits accuracy over time in Figure 7.
//!
//! Hardware calibrates each crossbar *tile section* independently (each
//! tile has its own ADC range): [`calibrate`] computes a [`LayerGdc`]
//! whose `tiles` come from the tile's actual — possibly faulted —
//! conductance slice, in `mapping::tile_grid` row-major `(kt, ct)` order.
//! For a single-tile layer the tile alpha equals the layer alpha bit for
//! bit (the rect sums replicate the full-layer accumulation order), so
//! calibration introduces no behavioral drift at the no-fault point.

use super::weights::ProgrammedWeights;
use crate::crossbar::ArrayGeom;
use crate::mapping::tile_grid;

/// A layer's drift-compensation factors: one `uniform` alpha (engines
/// without tile granularity, digital layers, PJRT graphs) plus optional
/// per-tile alphas. Empty `tiles` means "uniform everywhere".
#[derive(Clone, Debug, PartialEq)]
pub struct LayerGdc {
    pub uniform: f32,
    /// per-tile alphas in `tile_grid` row-major `(kt, ct)` order
    pub tiles: Vec<f32>,
}

impl LayerGdc {
    /// A tile-agnostic factor (the pre-calibration behavior).
    pub fn flat(alpha: f32) -> Self {
        LayerGdc { uniform: alpha, tiles: Vec::new() }
    }

    /// The alpha for tile `idx` (plan order); falls back to `uniform`
    /// when no per-tile calibration exists.
    pub fn tile(&self, idx: usize) -> f32 {
        self.tiles.get(idx).copied().unwrap_or(self.uniform)
    }
}

impl From<f32> for LayerGdc {
    fn from(alpha: f32) -> Self {
        LayerGdc::flat(alpha)
    }
}

/// `n` unity factors — the "freshly programmed, no compensation" vector
/// tests and benches pass alongside exact weights.
pub fn unity(n: usize) -> Vec<LayerGdc> {
    vec![LayerGdc::flat(1.0); n]
}

/// Wrap plain per-layer alphas (no tile granularity).
pub fn flat_vec(alphas: &[f32]) -> Vec<LayerGdc> {
    alphas.iter().map(|&a| LayerGdc::flat(a)).collect()
}

/// Per-layer GDC factor at time `t` (>= 1 once drift sets in).
pub fn alpha(layer: &ProgrammedWeights, t_seconds: f64) -> f32 {
    let target = layer.target_gsum();
    let now = layer.read_gsum(t_seconds);
    if now <= 1e-12 {
        return 1.0;
    }
    (target / now) as f32
}

/// GDC factors for a whole model.
pub fn alphas(layers: &[ProgrammedWeights], t_seconds: f64) -> Vec<f32> {
    layers.iter().map(|l| alpha(l, t_seconds)).collect()
}

/// Calibrate one layer at time `t`. With `calib_geom = Some(geom)` each
/// `tile_grid` tile of the layer's `[rows x cols]` rectangle gets its own
/// `alpha_tile = target_gsum(tile) / read_gsum(tile, t)` from its actual
/// (faulted, drifted) conductance slice; `None` yields the layer-wide
/// uniform factor only.
pub fn calibrate(layer: &ProgrammedWeights, t_seconds: f64,
                 calib_geom: Option<ArrayGeom>) -> LayerGdc {
    let uniform = alpha(layer, t_seconds);
    let tiles = match calib_geom {
        None => Vec::new(),
        Some(geom) => tile_grid(layer.rows, layer.cols, geom)
            .iter()
            .map(|t| {
                let target =
                    layer.target_gsum_rect(t.k0, t.rows, t.n0, t.cols);
                let now = layer.read_gsum_rect(t_seconds, t.k0, t.rows,
                                               t.n0, t.cols);
                if now <= 1e-12 {
                    1.0
                } else {
                    (target / now) as f32
                }
            })
            .collect(),
    };
    LayerGdc { uniform, tiles }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pcm::PcmParams;
    use crate::util::rng::Rng;

    fn programmed() -> ProgrammedWeights {
        let mut rng = Rng::new(7);
        let w: Vec<f32> = (0..2048).map(|_| rng.gauss(0.0, 0.2) as f32).collect();
        ProgrammedWeights::program(&w, 64, 32, 0.0, &PcmParams::default(), &mut rng)
    }

    #[test]
    fn alpha_near_one_at_programming_time() {
        let l = programmed();
        let a = alpha(&l, 25.0);
        assert!((a - 1.0).abs() < 0.05, "alpha={a}");
    }

    #[test]
    fn alpha_grows_with_drift() {
        let l = programmed();
        let a1 = alpha(&l, 3600.0);
        let a2 = alpha(&l, 31_536_000.0);
        assert!(a2 > a1 && a1 > 0.99, "{a1} {a2}");
    }

    #[test]
    fn layer_gdc_tile_lookup_falls_back_to_uniform() {
        let g = LayerGdc::flat(1.5);
        assert_eq!(g.tile(0), 1.5);
        assert_eq!(g.tile(7), 1.5);
        let g = LayerGdc { uniform: 1.5, tiles: vec![1.1, 1.2] };
        assert_eq!(g.tile(0), 1.1);
        assert_eq!(g.tile(1), 1.2);
        assert_eq!(g.tile(2), 1.5, "past the grid -> uniform");
        assert_eq!(unity(2), vec![LayerGdc::flat(1.0), LayerGdc::flat(1.0)]);
        assert_eq!(flat_vec(&[1.0, 2.0])[1].uniform, 2.0);
        assert_eq!(LayerGdc::from(1.25), LayerGdc::flat(1.25));
    }

    #[test]
    fn single_tile_calibration_is_bitwise_the_layer_alpha() {
        // the no-drift guarantee behind the AnalogCim refactor: a layer
        // that fits one tile calibrates to exactly gdc::alpha
        let l = programmed();
        let geom = ArrayGeom::new(64, 32, 4).unwrap();
        for t in [25.0, 3600.0, 31_536_000.0] {
            let cal = calibrate(&l, t, Some(geom));
            assert_eq!(cal.tiles.len(), 1);
            assert_eq!(cal.tiles[0].to_bits(), cal.uniform.to_bits());
            assert_eq!(cal.uniform.to_bits(), alpha(&l, t).to_bits());
        }
        // and None skips tile calibration entirely
        assert!(calibrate(&l, 3600.0, None).tiles.is_empty());
    }

    #[test]
    fn stuck_cluster_gives_that_tile_its_own_alpha() {
        // 64x32 layer on 32x32 tiles -> 2 K-tiles; pin a dense G_max
        // cluster inside tile 0 only
        let mut l = programmed();
        l.stuck_pos = (0..8 * 32).map(|i| (i as u32, 1.0f32)).collect();
        let t = 86_400.0;
        let geom = ArrayGeom::new(32, 32, 4).unwrap();
        let cal = calibrate(&l, t, Some(geom));
        assert_eq!(cal.tiles.len(), 2);
        assert_ne!(cal.tiles[0], cal.tiles[1]);
        // the stuck-at-G_max cluster inflates tile 0's conductance sum, so
        // its compensation factor is the smaller one
        assert!(cal.tiles[0] < cal.tiles[1],
                "{} !< {}", cal.tiles[0], cal.tiles[1]);
        // tile 1 carries no faults: its alpha stays near the clean layer's
        let clean = programmed();
        let clean_alpha = calibrate(&clean, t, Some(geom)).tiles[1];
        assert!((cal.tiles[1] - clean_alpha).abs() < 1e-6);
    }

    #[test]
    fn gdc_recovers_mean_weight_scale() {
        // after GDC, the *average* weight magnitude should be restored
        let mut rng = Rng::new(8);
        let w: Vec<f32> = (0..4096).map(|_| rng.gauss(0.0, 0.2) as f32).collect();
        let p = PcmParams::default();
        let l = ProgrammedWeights::program(&w, 64, 64, 0.0, &p, &mut rng);
        let t = 31_536_000.0;
        let a = alpha(&l, t) as f64;
        let r = l.read_weights(t, &p, &mut rng);
        let mag_w: f64 = w.iter().map(|x| x.abs() as f64).sum();
        let mag_r: f64 = r.iter().map(|x| x.abs() as f64).sum();
        let ratio = a * mag_r / mag_w;
        assert!((ratio - 1.0).abs() < 0.05, "ratio={ratio}");
    }
}
