//! Global drift compensation (Joshi et al., 2020).
//!
//! The *global* component of conductance drift is corrected digitally: the
//! accelerator periodically reads the summed conductance of a layer's array
//! section and scales the ADC outputs by `alpha = sum(G_target) /
//! sum(G_now)`.  Device-to-device variability remains uncompensated — that
//! residual is exactly what limits accuracy over time in Figure 7.

use super::weights::ProgrammedWeights;

/// Per-layer GDC factor at time `t` (>= 1 once drift sets in).
pub fn alpha(layer: &ProgrammedWeights, t_seconds: f64) -> f32 {
    let target = layer.target_gsum();
    let now = layer.read_gsum(t_seconds);
    if now <= 1e-12 {
        return 1.0;
    }
    (target / now) as f32
}

/// GDC factors for a whole model.
pub fn alphas(layers: &[ProgrammedWeights], t_seconds: f64) -> Vec<f32> {
    layers.iter().map(|l| alpha(l, t_seconds)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pcm::PcmParams;
    use crate::util::rng::Rng;

    fn programmed() -> ProgrammedWeights {
        let mut rng = Rng::new(7);
        let w: Vec<f32> = (0..2048).map(|_| rng.gauss(0.0, 0.2) as f32).collect();
        ProgrammedWeights::program(&w, 64, 32, 0.0, &PcmParams::default(), &mut rng)
    }

    #[test]
    fn alpha_near_one_at_programming_time() {
        let l = programmed();
        let a = alpha(&l, 25.0);
        assert!((a - 1.0).abs() < 0.05, "alpha={a}");
    }

    #[test]
    fn alpha_grows_with_drift() {
        let l = programmed();
        let a1 = alpha(&l, 3600.0);
        let a2 = alpha(&l, 31_536_000.0);
        assert!(a2 > a1 && a1 > 0.99, "{a1} {a2}");
    }

    #[test]
    fn gdc_recovers_mean_weight_scale() {
        // after GDC, the *average* weight magnitude should be restored
        let mut rng = Rng::new(8);
        let w: Vec<f32> = (0..4096).map(|_| rng.gauss(0.0, 0.2) as f32).collect();
        let p = PcmParams::default();
        let l = ProgrammedWeights::program(&w, 64, 64, 0.0, &p, &mut rng);
        let t = 31_536_000.0;
        let a = alpha(&l, t) as f64;
        let r = l.read_weights(t, &p, &mut rng);
        let mag_w: f64 = w.iter().map(|x| x.abs() as f64).sum();
        let mag_r: f64 = r.iter().map(|x| x.abs() as f64).sum();
        let ratio = a * mag_r / mag_w;
        assert!((ratio - 1.0).abs() < 0.05, "ratio={ratio}");
    }
}
