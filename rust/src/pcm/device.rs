//! Per-device PCM physics: programming noise, drift, 1/f read noise.

use super::{G_MAX_US, T_C_SECONDS, T_R_SECONDS};
use crate::util::rng::Rng;

/// Calibration constants (Section 6.1; Joshi et al. 2020 for nu).
#[derive(Clone, Debug)]
pub struct PcmParams {
    /// drift exponent distribution nu ~ N(mean, std), clipped at 0
    pub nu_mean: f64,
    pub nu_std: f64,
    /// enable instantaneous 1/f read noise
    pub read_noise: bool,
    /// enable programming noise
    pub prog_noise: bool,
    /// enable drift
    pub drift: bool,
}

impl Default for PcmParams {
    fn default() -> Self {
        PcmParams {
            nu_mean: 0.031,
            nu_std: 0.007,
            read_noise: true,
            prog_noise: true,
            drift: true,
        }
    }
}

impl PcmParams {
    /// Ideal device: no noise at all (digital reference runs).
    pub fn ideal() -> Self {
        PcmParams {
            read_noise: false,
            prog_noise: false,
            drift: false,
            ..Default::default()
        }
    }
}

/// Programming-noise std for a normalized target conductance `g_t` in [0,1].
///
/// `sigma_P = max(-1.1731 gt^2 + 1.9650 gt + 0.2635, 0)` with the polynomial
/// expressed over the normalized target and yielding uS; we return the
/// normalized sigma (divide by G_MAX). Ranges ~1-4.2% of G_MAX.
pub fn sigma_prog(g_t: f64) -> f64 {
    let us = (-1.1731 * g_t * g_t + 1.9650 * g_t + 0.2635).max(0.0);
    us / G_MAX_US
}

/// 1/f noise amplitude Q for a normalized target `g_t`:
/// `Q = min(0.0088 / G_T_uS^0.65, 0.2)` (G_T in uS).
pub fn q_factor(g_t: f64) -> f64 {
    let g_us = (g_t * G_MAX_US).max(1e-9);
    (0.0088 / g_us.powf(0.65)).min(0.2)
}

/// Deterministic drift decay factor `(t/t_c)^-nu` (t clamped at t_c).
pub fn drift_factor(t_seconds: f64, nu: f64) -> f64 {
    let t = t_seconds.max(T_C_SECONDS);
    (t / T_C_SECONDS).powf(-nu)
}

/// Read-noise std at time `t` for a drifted conductance `g_d` (normalized):
/// `sigma_nG = g_d * Q * sqrt(ln((t + t_r)/t_r))`.
pub fn sigma_read(g_d: f64, g_t: f64, t_seconds: f64) -> f64 {
    let t = t_seconds.max(0.0);
    g_d * q_factor(g_t) * ((t + T_R_SECONDS) / T_R_SECONDS).ln().sqrt()
}

/// Sample a programmed conductance for target `g_t` (normalized, clamped >= 0).
pub fn program(g_t: f64, p: &PcmParams, rng: &mut Rng) -> f64 {
    if !p.prog_noise {
        return g_t;
    }
    (g_t + rng.gauss(0.0, sigma_prog(g_t))).max(0.0)
}

/// Sample a per-device drift exponent.
pub fn sample_nu(p: &PcmParams, rng: &mut Rng) -> f64 {
    if !p.drift {
        return 0.0;
    }
    rng.gauss(p.nu_mean, p.nu_std).max(0.0)
}

/// Effective conductance at read time (drift + optional 1/f noise).
pub fn read(g_p: f64, g_t: f64, nu: f64, t_seconds: f64, p: &PcmParams, rng: &mut Rng) -> f64 {
    let g_d = if p.drift {
        g_p * drift_factor(t_seconds, nu)
    } else {
        g_p
    };
    if !p.read_noise {
        return g_d.max(0.0);
    }
    (g_d + rng.gauss(0.0, sigma_read(g_d, g_t, t_seconds))).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigma_prog_calibration_points() {
        // polynomial endpoints (normalized target, uS result / 25)
        assert!((sigma_prog(0.0) - 0.2635 / 25.0).abs() < 1e-12);
        let at1 = (-1.1731 + 1.9650 + 0.2635) / 25.0;
        assert!((sigma_prog(1.0) - at1).abs() < 1e-12);
        // never negative anywhere in range
        for i in 0..=100 {
            assert!(sigma_prog(i as f64 / 100.0) >= 0.0);
        }
    }

    #[test]
    fn q_factor_caps_small_devices() {
        assert_eq!(q_factor(0.0), 0.2);
        assert!(q_factor(1.0) < 0.01); // large devices are quiet
        assert!(q_factor(0.04) > q_factor(0.4)); // monotone decreasing
    }

    #[test]
    fn drift_decays_monotonically() {
        let nu = 0.031;
        let f25 = drift_factor(25.0, nu);
        let f1d = drift_factor(86_400.0, nu);
        let f1y = drift_factor(31_536_000.0, nu);
        assert!((f25 - 1.0).abs() < 1e-12);
        assert!(f1d < f25 && f1y < f1d);
        // ~10% after a day, ~35% after a year at nu=0.031? sanity bounds
        assert!(f1d > 0.5 && f1y > 0.3);
    }

    #[test]
    fn drift_clamps_before_tc() {
        assert_eq!(drift_factor(1.0, 0.05), 1.0);
    }

    #[test]
    fn programming_noise_statistics() {
        let p = PcmParams::default();
        let mut rng = Rng::new(11);
        let g_t = 0.5;
        let n = 20_000;
        let samples: Vec<f64> = (0..n).map(|_| program(g_t, &p, &mut rng)).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - g_t).abs() < 3e-4, "mean={mean}");
        let expect = sigma_prog(g_t);
        assert!((var.sqrt() - expect).abs() / expect < 0.05);
    }

    #[test]
    fn read_noise_grows_with_log_time() {
        let s1 = sigma_read(0.5, 0.5, 1.0);
        let s2 = sigma_read(0.5, 0.5, 86_400.0);
        assert!(s2 > s1);
        // sqrt(log) growth: a year is only ~1.15x a day
        let s3 = sigma_read(0.5, 0.5, 31_536_000.0);
        assert!(s3 / s2 < 1.25);
    }

    #[test]
    fn ideal_params_are_noiseless() {
        let p = PcmParams::ideal();
        let mut rng = Rng::new(1);
        assert_eq!(program(0.3, &p, &mut rng), 0.3);
        assert_eq!(read(0.3, 0.3, 0.0, 86_400.0, &p, &mut rng), 0.3);
    }
}
