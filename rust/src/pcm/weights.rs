//! Layer weights programmed as differential conductance pairs.
//!
//! Mirrors the paper's deployment flow (Section 6.1): clipped trained
//! weights are rescaled to [-1, 1] by `max|W_l|` and split into positive /
//! negative target conductances; programming noise is applied once (at
//! deployment), drift exponents are drawn per device, and every *read* at
//! time `t` applies drift plus fresh 1/f noise.

use super::device::{self, PcmParams};
use super::fault::{self, FaultSpec};
use crate::util::rng::Rng;

/// One layer's worth of PCM state (differential pairs).
#[derive(Clone, Debug)]
pub struct ProgrammedWeights {
    pub rows: usize,
    pub cols: usize,
    /// normalized target conductances (pos / neg halves)
    pub gt_pos: Vec<f32>,
    pub gt_neg: Vec<f32>,
    /// programmed conductances (after programming noise)
    pub gp_pos: Vec<f32>,
    pub gp_neg: Vec<f32>,
    /// per-device drift exponents
    pub nu_pos: Vec<f32>,
    pub nu_neg: Vec<f32>,
    /// cached 1/f amplitudes Q(G_T) (q_factor has a powf on the hot path)
    pub q_pos: Vec<f32>,
    pub q_neg: Vec<f32>,
    /// stuck-at devices: sorted `(flat index, pinned conductance)` per
    /// half-pair. A stuck cell reads its pinned value at every `t` — no
    /// drift, no 1/f noise, no RNG draw — so empty lists (the no-fault
    /// case) leave the read path and its RNG stream bit-identical to a
    /// build without fault support.
    pub stuck_pos: Vec<(u32, f32)>,
    pub stuck_neg: Vec<(u32, f32)>,
    /// weight <-> conductance mapping: W = (g_pos - g_neg) * w_scale
    pub w_scale: f32,
}

impl ProgrammedWeights {
    /// Program a [rows x cols] weight matrix into differential PCM pairs.
    ///
    /// `w_scale` should be `max|W|` of the clipped weights (from meta.json);
    /// if 0, it is computed from the data.
    pub fn program(w: &[f32], rows: usize, cols: usize, mut w_scale: f32,
                   params: &PcmParams, rng: &mut Rng) -> Self {
        assert_eq!(w.len(), rows * cols, "weight shape mismatch");
        if w_scale <= 0.0 {
            w_scale = w.iter().fold(0.0f32, |m, x| m.max(x.abs()));
            if w_scale == 0.0 {
                w_scale = 1.0;
            }
        }
        let n = w.len();
        let mut gt_pos = vec![0f32; n];
        let mut gt_neg = vec![0f32; n];
        for (i, &wi) in w.iter().enumerate() {
            let g = (wi / w_scale).clamp(-1.0, 1.0);
            if g >= 0.0 {
                gt_pos[i] = g;
            } else {
                gt_neg[i] = -g;
            }
        }
        let mut gp_pos = vec![0f32; n];
        let mut gp_neg = vec![0f32; n];
        let mut nu_pos = vec![0f32; n];
        let mut nu_neg = vec![0f32; n];
        let mut q_pos = vec![0f32; n];
        let mut q_neg = vec![0f32; n];
        for i in 0..n {
            gp_pos[i] = device::program(gt_pos[i] as f64, params, rng) as f32;
            gp_neg[i] = device::program(gt_neg[i] as f64, params, rng) as f32;
            nu_pos[i] = device::sample_nu(params, rng) as f32;
            nu_neg[i] = device::sample_nu(params, rng) as f32;
            q_pos[i] = device::q_factor(gt_pos[i] as f64) as f32;
            q_neg[i] = device::q_factor(gt_neg[i] as f64) as f32;
        }
        ProgrammedWeights {
            rows, cols,
            gt_pos, gt_neg, gp_pos, gp_neg, nu_pos, nu_neg, q_pos, q_neg,
            stuck_pos: Vec::new(),
            stuck_neg: Vec::new(),
            w_scale,
        }
    }

    /// Number of physical devices (2 per weight: differential pair).
    pub fn device_count(&self) -> usize {
        2 * self.rows * self.cols
    }

    /// Inject the weight-side faults of `spec` into this freshly-programmed
    /// layer (call once per programming; re-programming resets the array,
    /// so faults are re-applied to the new pristine state by the caller).
    ///
    /// The fault pattern derives from `(spec.seed, layer_index)` alone —
    /// never from the deployment RNG — so the same spec pins the same
    /// cells in every process. Per half-pair, each device draws one
    /// conductance jitter and one stuck-classification uniform, in index
    /// order; the jitter is drawn even at `g_sigma = 0` so the stuck
    /// pattern is invariant across `g_sigma` settings of one seed.
    ///
    /// A `FaultSpec` with no weight-side faults returns immediately and
    /// mutates nothing.
    pub fn apply_faults(&mut self, spec: &FaultSpec, layer_index: usize) {
        if !spec.has_weight_faults() {
            return;
        }
        let mut rng = fault::stream(spec.seed, layer_index as u64);
        let n = self.rows * self.cols;
        for half in 0..2 {
            let (gp, stuck) = if half == 0 {
                (&mut self.gp_pos, &mut self.stuck_pos)
            } else {
                (&mut self.gp_neg, &mut self.stuck_neg)
            };
            for i in 0..n {
                let jitter = rng.gauss(0.0, spec.g_sigma);
                if spec.g_sigma > 0.0 {
                    gp[i] = (gp[i] as f64 * (1.0 + jitter)).max(0.0) as f32;
                }
                let u = rng.uniform();
                if u < spec.stuck_min {
                    stuck.push((i as u32, 0.0)); // pinned at G_min
                } else if u < spec.stuck_min + spec.stuck_max {
                    stuck.push((i as u32, 1.0)); // pinned at G_max
                }
            }
        }
    }

    /// Stuck devices across both half-pairs.
    pub fn stuck_count(&self) -> usize {
        self.stuck_pos.len() + self.stuck_neg.len()
    }

    /// Read effective weights at `t` seconds after programming.
    ///
    /// Returns the weight matrix in trained-weight units, WITHOUT drift
    /// compensation (GDC is a separate digital step, see `gdc`).
    ///
    /// This is the coordinator's weight-refresh hot path: the
    /// time-dependent factors (log-time of the drift power law, the 1/f
    /// sqrt-log envelope) are hoisted out of the per-device loop so the
    /// inner loop is one exp() + one gauss() per device (see EXPERIMENTS.md
    /// §Perf L3).
    pub fn read_weights(&self, t_seconds: f64, params: &PcmParams,
                        rng: &mut Rng) -> Vec<f32> {
        let n = self.rows * self.cols;
        let mut w = vec![0f32; n];
        // drift: (t/t_c)^-nu = exp(-nu * ln(t/t_c))
        let log_t = if params.drift {
            (t_seconds.max(super::T_C_SECONDS) / super::T_C_SECONDS).ln()
        } else {
            0.0
        };
        // 1/f envelope sqrt(ln((t+t_r)/t_r)) is device-independent
        let env = if params.read_noise {
            ((t_seconds.max(0.0) + super::T_R_SECONDS) / super::T_R_SECONDS)
                .ln()
                .sqrt()
        } else {
            0.0
        };
        let scale = self.w_scale as f64;
        let read_one = |gp: f32, q: f32, nu: f32, rng: &mut Rng| -> f64 {
            let mut g = gp as f64 * (-(nu as f64) * log_t).exp();
            if params.read_noise {
                g += rng.gauss(0.0, g * q as f64 * env);
            }
            g.max(0.0)
        };
        // walk the sorted stuck lists alongside the device loop; a stuck
        // device substitutes its pinned conductance and skips `read_one`
        // entirely (no drift, no noise, no RNG draw), so the no-fault RNG
        // stream is untouched
        let (mut ip, mut ineg) = (0usize, 0usize);
        for i in 0..n {
            let gp = match self.stuck_pos.get(ip) {
                Some(&(idx, g)) if idx as usize == i => {
                    ip += 1;
                    g as f64
                }
                _ => read_one(self.gp_pos[i], self.q_pos[i], self.nu_pos[i],
                              rng),
            };
            let gn = match self.stuck_neg.get(ineg) {
                Some(&(idx, g)) if idx as usize == i => {
                    ineg += 1;
                    g as f64
                }
                _ => read_one(self.gp_neg[i], self.q_neg[i], self.nu_neg[i],
                              rng),
            };
            w[i] = ((gp - gn) * scale) as f32;
        }
        w
    }

    /// Summed absolute conductance of the *targets* (for GDC calibration).
    pub fn target_gsum(&self) -> f64 {
        self.target_gsum_rect(0, self.rows, 0, self.cols)
    }

    /// `target_gsum` restricted to the `[k0, k0+rows) x [n0, n0+cols)`
    /// sub-rectangle — the numerator of one tile's GDC alpha. Over the full
    /// rectangle the accumulation order (flat row-major, positive half
    /// then negative half) matches `target_gsum` bit for bit, so a
    /// single-tile layer calibrates to exactly the layer-wide alpha.
    pub fn target_gsum_rect(&self, k0: usize, rows: usize, n0: usize,
                            cols: usize) -> f64 {
        // each half gets its own accumulator, added once at the end — the
        // same association as `pos.sum() + neg.sum()`
        let half = |g: &[f32]| -> f64 {
            let mut s = 0.0;
            for r in k0..k0 + rows {
                for c in n0..n0 + cols {
                    s += g[r * self.cols + c] as f64;
                }
            }
            s
        };
        half(&self.gt_pos) + half(&self.gt_neg)
    }

    /// Summed absolute conductance at read time (drift only, no read noise —
    /// GDC calibration integrates long enough to average 1/f noise out).
    pub fn read_gsum(&self, t_seconds: f64) -> f64 {
        self.read_gsum_rect(t_seconds, 0, self.rows, 0, self.cols)
    }

    /// `read_gsum` restricted to a sub-rectangle — the denominator of one
    /// tile's GDC alpha. Stuck devices contribute their pinned conductance
    /// (they do not drift), which is what lets per-tile calibration absorb
    /// the average effect of a stuck cluster. Accumulation interleaves the
    /// pos/neg halves per device in flat order, matching `read_gsum`
    /// bitwise over the full rectangle.
    pub fn read_gsum_rect(&self, t_seconds: f64, k0: usize, rows: usize,
                          n0: usize, cols: usize) -> f64 {
        let mut s = 0.0;
        for r in k0..k0 + rows {
            let row0 = r * self.cols + n0;
            // sorted stuck lists: find each half's first entry in this row
            // segment once, then walk it alongside the column loop
            let mut ip = self
                .stuck_pos
                .partition_point(|&(idx, _)| (idx as usize) < row0);
            let mut ineg = self
                .stuck_neg
                .partition_point(|&(idx, _)| (idx as usize) < row0);
            for c in 0..cols {
                let i = row0 + c;
                s += match self.stuck_pos.get(ip) {
                    Some(&(idx, g)) if idx as usize == i => {
                        ip += 1;
                        g as f64
                    }
                    _ => self.gp_pos[i] as f64
                        * device::drift_factor(t_seconds,
                                               self.nu_pos[i] as f64),
                };
                s += match self.stuck_neg.get(ineg) {
                    Some(&(idx, g)) if idx as usize == i => {
                        ineg += 1;
                        g as f64
                    }
                    _ => self.gp_neg[i] as f64
                        * device::drift_factor(t_seconds,
                                               self.nu_neg[i] as f64),
                };
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_weights() -> Vec<f32> {
        let mut rng = Rng::new(42);
        (0..64 * 32).map(|_| rng.gauss(0.0, 0.1) as f32).collect()
    }

    #[test]
    fn ideal_roundtrip_is_exact() {
        let w = sample_weights();
        let p = PcmParams::ideal();
        let mut rng = Rng::new(1);
        let prog = ProgrammedWeights::program(&w, 64, 32, 0.0, &p, &mut rng);
        let back = prog.read_weights(25.0, &p, &mut rng);
        for (a, b) in w.iter().zip(back.iter()) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn differential_split_is_disjoint() {
        let w = sample_weights();
        let p = PcmParams::ideal();
        let mut rng = Rng::new(1);
        let prog = ProgrammedWeights::program(&w, 64, 32, 0.0, &p, &mut rng);
        for i in 0..w.len() {
            assert!(prog.gt_pos[i] == 0.0 || prog.gt_neg[i] == 0.0);
            assert!(prog.gt_pos[i] >= 0.0 && prog.gt_neg[i] >= 0.0);
        }
    }

    #[test]
    fn noisy_read_error_grows_with_time() {
        let w = sample_weights();
        let p = PcmParams::default();
        let mut rng = Rng::new(2);
        let prog = ProgrammedWeights::program(&w, 64, 32, 0.0, &p, &mut rng);
        let err = |t: f64, rng: &mut Rng| {
            let r = prog.read_weights(t, &p, rng);
            let se: f64 = w.iter().zip(r.iter())
                .map(|(a, b)| ((a - b) as f64).powi(2)).sum();
            (se / w.len() as f64).sqrt()
        };
        let e25 = err(25.0, &mut rng);
        let e1y = err(31_536_000.0, &mut rng);
        assert!(e1y > e25, "drift must increase weight error: {e25} vs {e1y}");
    }

    #[test]
    fn gsum_decays_with_drift() {
        let w = sample_weights();
        let p = PcmParams::default();
        let mut rng = Rng::new(3);
        let prog = ProgrammedWeights::program(&w, 64, 32, 0.0, &p, &mut rng);
        let s0 = prog.read_gsum(25.0);
        let s1 = prog.read_gsum(86_400.0);
        assert!(s1 < s0);
    }

    #[test]
    fn none_fault_spec_is_a_bitwise_noop() {
        let w = sample_weights();
        let p = PcmParams::default();
        let prog_a =
            ProgrammedWeights::program(&w, 64, 32, 0.0, &p, &mut Rng::new(5));
        let mut prog_b =
            ProgrammedWeights::program(&w, 64, 32, 0.0, &p, &mut Rng::new(5));
        prog_b.apply_faults(&FaultSpec::none(), 0);
        assert_eq!(prog_b.stuck_count(), 0);
        assert_eq!(prog_a.gp_pos, prog_b.gp_pos);
        // the read path (incl. its RNG stream) is bit-identical
        let ra = prog_a.read_weights(86_400.0, &p, &mut Rng::new(9));
        let rb = prog_b.read_weights(86_400.0, &p, &mut Rng::new(9));
        assert_eq!(ra, rb);
        assert_eq!(prog_a.read_gsum(3600.0).to_bits(),
                   prog_b.read_gsum(3600.0).to_bits());
    }

    #[test]
    fn fault_pattern_depends_only_on_spec_seed_and_layer() {
        let w = sample_weights();
        let p = PcmParams::default();
        let spec = FaultSpec { stuck_min: 0.05, stuck_max: 0.05,
                               g_sigma: 0.1, seed: 21, ..FaultSpec::none() };
        // different deployment RNGs, same spec -> same stuck pattern
        let mut a =
            ProgrammedWeights::program(&w, 64, 32, 0.0, &p, &mut Rng::new(1));
        let mut b =
            ProgrammedWeights::program(&w, 64, 32, 0.0, &p, &mut Rng::new(777));
        a.apply_faults(&spec, 3);
        b.apply_faults(&spec, 3);
        assert!(a.stuck_count() > 0);
        assert_eq!(a.stuck_pos, b.stuck_pos);
        assert_eq!(a.stuck_neg, b.stuck_neg);
        // a different layer index shifts the pattern
        let mut c =
            ProgrammedWeights::program(&w, 64, 32, 0.0, &p, &mut Rng::new(1));
        c.apply_faults(&spec, 4);
        assert_ne!(a.stuck_pos, c.stuck_pos);
        // stuck lists arrive sorted (the read path walks them linearly)
        assert!(a.stuck_pos.windows(2).all(|p| p[0].0 < p[1].0));
        assert!(a.stuck_neg.windows(2).all(|p| p[0].0 < p[1].0));
    }

    #[test]
    fn stuck_cells_are_pinned_and_never_drift() {
        let w = sample_weights();
        // no programming/read noise so every change is attributable
        let p = PcmParams::ideal();
        let mut rng = Rng::new(6);
        let mut prog = ProgrammedWeights::program(&w, 64, 32, 0.0, &p, &mut rng);
        let clean = prog.read_weights(25.0, &p, &mut rng);
        let spec = FaultSpec { stuck_max: 0.2, seed: 13, ..FaultSpec::none() };
        prog.apply_faults(&spec, 0);
        assert!(prog.stuck_count() > 200, "{}", prog.stuck_count());
        let faulted = prog.read_weights(25.0, &p, &mut rng);
        // a device stuck at G_max with a zero programmed counterpart reads
        // +w_scale no matter the age
        let year = prog.read_weights(31_536_000.0, &p, &mut rng);
        for &(idx, g) in &prog.stuck_pos {
            let i = idx as usize;
            assert_eq!(g, 1.0);
            if prog.gt_neg[i] == 0.0 && !prog.stuck_neg.iter()
                .any(|&(j, _)| j == idx)
            {
                assert!((faulted[i] - prog.w_scale).abs() < 1e-6,
                        "stuck read {} vs {}", faulted[i], prog.w_scale);
                assert_eq!(faulted[i], year[i], "stuck cells must not drift");
            }
        }
        // and the fault moved the layer away from its clean reads
        assert_ne!(clean, faulted);
    }

    #[test]
    fn rect_sums_tile_the_full_sums() {
        let w = sample_weights();
        let p = PcmParams::default();
        let mut rng = Rng::new(12);
        let mut prog = ProgrammedWeights::program(&w, 64, 32, 0.0, &p, &mut rng);
        prog.apply_faults(
            &FaultSpec { stuck_min: 0.1, seed: 3, ..FaultSpec::none() }, 1);
        // the full-rectangle call IS the layer sum (delegation)
        assert_eq!(prog.target_gsum().to_bits(),
                   prog.target_gsum_rect(0, 64, 0, 32).to_bits());
        assert_eq!(prog.read_gsum(3600.0).to_bits(),
                   prog.read_gsum_rect(3600.0, 0, 64, 0, 32).to_bits());
        // a 2x2 tiling covers every device exactly once
        let mut tgt = 0.0;
        let mut now = 0.0;
        for (k0, rows) in [(0usize, 40usize), (40, 24)] {
            for (n0, cols) in [(0usize, 20usize), (20, 12)] {
                tgt += prog.target_gsum_rect(k0, rows, n0, cols);
                now += prog.read_gsum_rect(3600.0, k0, rows, n0, cols);
            }
        }
        assert!((tgt - prog.target_gsum()).abs() < 1e-9, "{tgt}");
        assert!((now - prog.read_gsum(3600.0)).abs() < 1e-9, "{now}");
    }

    #[test]
    fn zero_weights_still_get_programming_noise() {
        // the depthwise zero-cell effect: zero targets -> sigma_P(0) > 0
        let w = vec![0f32; 128];
        let p = PcmParams::default();
        let mut rng = Rng::new(4);
        let prog = ProgrammedWeights::program(&w, 16, 8, 1.0, &p, &mut rng);
        let r = prog.read_weights(25.0, &p, &mut rng);
        // each half-pair clamps negative samples at 0, so ~75% of the
        // differential reads are non-zero in expectation
        let nonzero = r.iter().filter(|x| x.abs() > 1e-6).count();
        assert!(nonzero > 64, "zero cells must be noisy ({nonzero})");
    }
}
