//! Layer weights programmed as differential conductance pairs.
//!
//! Mirrors the paper's deployment flow (Section 6.1): clipped trained
//! weights are rescaled to [-1, 1] by `max|W_l|` and split into positive /
//! negative target conductances; programming noise is applied once (at
//! deployment), drift exponents are drawn per device, and every *read* at
//! time `t` applies drift plus fresh 1/f noise.

use super::device::{self, PcmParams};
use crate::util::rng::Rng;

/// One layer's worth of PCM state (differential pairs).
#[derive(Clone, Debug)]
pub struct ProgrammedWeights {
    pub rows: usize,
    pub cols: usize,
    /// normalized target conductances (pos / neg halves)
    pub gt_pos: Vec<f32>,
    pub gt_neg: Vec<f32>,
    /// programmed conductances (after programming noise)
    pub gp_pos: Vec<f32>,
    pub gp_neg: Vec<f32>,
    /// per-device drift exponents
    pub nu_pos: Vec<f32>,
    pub nu_neg: Vec<f32>,
    /// cached 1/f amplitudes Q(G_T) (q_factor has a powf on the hot path)
    pub q_pos: Vec<f32>,
    pub q_neg: Vec<f32>,
    /// weight <-> conductance mapping: W = (g_pos - g_neg) * w_scale
    pub w_scale: f32,
}

impl ProgrammedWeights {
    /// Program a [rows x cols] weight matrix into differential PCM pairs.
    ///
    /// `w_scale` should be `max|W|` of the clipped weights (from meta.json);
    /// if 0, it is computed from the data.
    pub fn program(w: &[f32], rows: usize, cols: usize, mut w_scale: f32,
                   params: &PcmParams, rng: &mut Rng) -> Self {
        assert_eq!(w.len(), rows * cols, "weight shape mismatch");
        if w_scale <= 0.0 {
            w_scale = w.iter().fold(0.0f32, |m, x| m.max(x.abs()));
            if w_scale == 0.0 {
                w_scale = 1.0;
            }
        }
        let n = w.len();
        let mut gt_pos = vec![0f32; n];
        let mut gt_neg = vec![0f32; n];
        for (i, &wi) in w.iter().enumerate() {
            let g = (wi / w_scale).clamp(-1.0, 1.0);
            if g >= 0.0 {
                gt_pos[i] = g;
            } else {
                gt_neg[i] = -g;
            }
        }
        let mut gp_pos = vec![0f32; n];
        let mut gp_neg = vec![0f32; n];
        let mut nu_pos = vec![0f32; n];
        let mut nu_neg = vec![0f32; n];
        let mut q_pos = vec![0f32; n];
        let mut q_neg = vec![0f32; n];
        for i in 0..n {
            gp_pos[i] = device::program(gt_pos[i] as f64, params, rng) as f32;
            gp_neg[i] = device::program(gt_neg[i] as f64, params, rng) as f32;
            nu_pos[i] = device::sample_nu(params, rng) as f32;
            nu_neg[i] = device::sample_nu(params, rng) as f32;
            q_pos[i] = device::q_factor(gt_pos[i] as f64) as f32;
            q_neg[i] = device::q_factor(gt_neg[i] as f64) as f32;
        }
        ProgrammedWeights {
            rows, cols,
            gt_pos, gt_neg, gp_pos, gp_neg, nu_pos, nu_neg, q_pos, q_neg,
            w_scale,
        }
    }

    /// Number of physical devices (2 per weight: differential pair).
    pub fn device_count(&self) -> usize {
        2 * self.rows * self.cols
    }

    /// Read effective weights at `t` seconds after programming.
    ///
    /// Returns the weight matrix in trained-weight units, WITHOUT drift
    /// compensation (GDC is a separate digital step, see `gdc`).
    ///
    /// This is the coordinator's weight-refresh hot path: the
    /// time-dependent factors (log-time of the drift power law, the 1/f
    /// sqrt-log envelope) are hoisted out of the per-device loop so the
    /// inner loop is one exp() + one gauss() per device (see EXPERIMENTS.md
    /// §Perf L3).
    pub fn read_weights(&self, t_seconds: f64, params: &PcmParams,
                        rng: &mut Rng) -> Vec<f32> {
        let n = self.rows * self.cols;
        let mut w = vec![0f32; n];
        // drift: (t/t_c)^-nu = exp(-nu * ln(t/t_c))
        let log_t = if params.drift {
            (t_seconds.max(super::T_C_SECONDS) / super::T_C_SECONDS).ln()
        } else {
            0.0
        };
        // 1/f envelope sqrt(ln((t+t_r)/t_r)) is device-independent
        let env = if params.read_noise {
            ((t_seconds.max(0.0) + super::T_R_SECONDS) / super::T_R_SECONDS)
                .ln()
                .sqrt()
        } else {
            0.0
        };
        let scale = self.w_scale as f64;
        let read_one = |gp: f32, q: f32, nu: f32, rng: &mut Rng| -> f64 {
            let mut g = gp as f64 * (-(nu as f64) * log_t).exp();
            if params.read_noise {
                g += rng.gauss(0.0, g * q as f64 * env);
            }
            g.max(0.0)
        };
        for i in 0..n {
            let gp = read_one(self.gp_pos[i], self.q_pos[i], self.nu_pos[i], rng);
            let gn = read_one(self.gp_neg[i], self.q_neg[i], self.nu_neg[i], rng);
            w[i] = ((gp - gn) * scale) as f32;
        }
        w
    }

    /// Summed absolute conductance of the *targets* (for GDC calibration).
    pub fn target_gsum(&self) -> f64 {
        self.gt_pos.iter().map(|&g| g as f64).sum::<f64>()
            + self.gt_neg.iter().map(|&g| g as f64).sum::<f64>()
    }

    /// Summed absolute conductance at read time (drift only, no read noise —
    /// GDC calibration integrates long enough to average 1/f noise out).
    pub fn read_gsum(&self, t_seconds: f64) -> f64 {
        let mut s = 0.0;
        let n = self.rows * self.cols;
        for i in 0..n {
            s += self.gp_pos[i] as f64
                * device::drift_factor(t_seconds, self.nu_pos[i] as f64);
            s += self.gp_neg[i] as f64
                * device::drift_factor(t_seconds, self.nu_neg[i] as f64);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_weights() -> Vec<f32> {
        let mut rng = Rng::new(42);
        (0..64 * 32).map(|_| rng.gauss(0.0, 0.1) as f32).collect()
    }

    #[test]
    fn ideal_roundtrip_is_exact() {
        let w = sample_weights();
        let p = PcmParams::ideal();
        let mut rng = Rng::new(1);
        let prog = ProgrammedWeights::program(&w, 64, 32, 0.0, &p, &mut rng);
        let back = prog.read_weights(25.0, &p, &mut rng);
        for (a, b) in w.iter().zip(back.iter()) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn differential_split_is_disjoint() {
        let w = sample_weights();
        let p = PcmParams::ideal();
        let mut rng = Rng::new(1);
        let prog = ProgrammedWeights::program(&w, 64, 32, 0.0, &p, &mut rng);
        for i in 0..w.len() {
            assert!(prog.gt_pos[i] == 0.0 || prog.gt_neg[i] == 0.0);
            assert!(prog.gt_pos[i] >= 0.0 && prog.gt_neg[i] >= 0.0);
        }
    }

    #[test]
    fn noisy_read_error_grows_with_time() {
        let w = sample_weights();
        let p = PcmParams::default();
        let mut rng = Rng::new(2);
        let prog = ProgrammedWeights::program(&w, 64, 32, 0.0, &p, &mut rng);
        let err = |t: f64, rng: &mut Rng| {
            let r = prog.read_weights(t, &p, rng);
            let se: f64 = w.iter().zip(r.iter())
                .map(|(a, b)| ((a - b) as f64).powi(2)).sum();
            (se / w.len() as f64).sqrt()
        };
        let e25 = err(25.0, &mut rng);
        let e1y = err(31_536_000.0, &mut rng);
        assert!(e1y > e25, "drift must increase weight error: {e25} vs {e1y}");
    }

    #[test]
    fn gsum_decays_with_drift() {
        let w = sample_weights();
        let p = PcmParams::default();
        let mut rng = Rng::new(3);
        let prog = ProgrammedWeights::program(&w, 64, 32, 0.0, &p, &mut rng);
        let s0 = prog.read_gsum(25.0);
        let s1 = prog.read_gsum(86_400.0);
        assert!(s1 < s0);
    }

    #[test]
    fn zero_weights_still_get_programming_noise() {
        // the depthwise zero-cell effect: zero targets -> sigma_P(0) > 0
        let w = vec![0f32; 128];
        let p = PcmParams::default();
        let mut rng = Rng::new(4);
        let prog = ProgrammedWeights::program(&w, 16, 8, 1.0, &p, &mut rng);
        let r = prog.read_weights(25.0, &p, &mut rng);
        // each half-pair clamps negative samples at 0, so ~75% of the
        // differential reads are non-zero in expectation
        let nonzero = r.iter().filter(|x| x.abs() > 1e-6).count();
        assert!(nonzero > 64, "zero cells must be noisy ({nonzero})");
    }
}
