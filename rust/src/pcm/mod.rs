//! Calibrated PCM statistical model (paper Section 6.1).
//!
//! Implements the exact programming-noise / conductance-drift / 1-f read
//! noise model the paper uses for its simulator evaluation, calibrated on
//! doped-GST mushroom PCM (Nandakumar et al., 2019; Joshi et al., 2020).
//!
//! Conductances are kept *normalized* (fractions of `G_MAX_US` = 25 uS);
//! the polynomial/power-law calibration constants are expressed in uS and
//! converted at the boundary — see DESIGN.md section 4 for the unit
//! conventions.

pub mod device;
pub mod fault;
pub mod gdc;
pub mod weights;

pub use device::PcmParams;
pub use fault::{AdcFault, FaultSpec};
pub use gdc::LayerGdc;
pub use weights::ProgrammedWeights;

/// Maximum device conductance, in micro-Siemens.
pub const G_MAX_US: f64 = 25.0;
/// Drift reference time t_c (seconds): devices are read relative to this.
pub const T_C_SECONDS: f64 = 25.0;

/// Clamp a device age to the earliest readable time: programming
/// completes at t_c, so ages below it snap up to t_c (non-finite ages —
/// already rejected upstream — also resolve to t_c via `f64::max`). The
/// single source of the clamp rule: both the launch-grouping key
/// (`backend::InferOpts::batch_key`) and the actual weight read
/// (`coordinator::PcmState::weights_at`) use it, so a request's batch
/// key and its served age can never disagree.
pub fn clamp_age(age_s: f64) -> f64 {
    age_s.max(T_C_SECONDS)
}
/// 1/f read-noise reference time t_r (seconds) = 250 ns.
pub const T_R_SECONDS: f64 = 250e-9;

/// Handy time points used throughout the paper's Figure 7.
pub const T_25S: f64 = 25.0;
pub const T_1H: f64 = 3600.0;
pub const T_1D: f64 = 86_400.0;
pub const T_1M: f64 = 2_592_000.0;
pub const T_1Y: f64 = 31_536_000.0;

/// (label, seconds) pairs for the Figure-7 sweep.
pub const FIG7_TIMES: [(&str, f64); 5] = [
    ("25s", T_25S),
    ("1h", T_1H),
    ("1d", T_1D),
    ("1mo", T_1M),
    ("1yr", T_1Y),
];
