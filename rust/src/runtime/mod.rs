//! Artifact loading and (optionally) PJRT execution.
//!
//! [`ArtifactStore`] — manifest-driven discovery of exported metadata,
//! weights, and datasets — is always available and is all the `native`
//! backend needs. The PJRT pieces ([`Runtime`], [`Executable`], and
//! `ArtifactStore::executable`) wrap the `xla` crate (xla_extension 0.5.1 /
//! PJRT CPU) and exist only with the `pjrt` cargo feature; this module is
//! the one place in the crate where `xla` types appear. The interchange
//! format is HLO *text* — see DESIGN.md section 7 for why serialized protos
//! are rejected.

pub mod store;

pub use store::ArtifactStore;

// Backend-neutral since the InferenceBackend redesign; re-exported here for
// continuity with older call sites.
pub use crate::backend::HostTensor;

#[cfg(feature = "pjrt")]
pub use pjrt_exec::{Executable, Runtime};

#[cfg(feature = "pjrt")]
mod pjrt_exec {
    use std::path::Path;

    use crate::backend::HostTensor;

    fn to_literal(t: &HostTensor) -> anyhow::Result<xla::Literal> {
        let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&t.data).reshape(&dims)?)
    }

    /// The PJRT client (CPU).
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        pub fn cpu() -> anyhow::Result<Self> {
            Ok(Runtime {
                client: xla::PjRtClient::cpu()?,
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load HLO text and compile to an executable.
        pub fn load_hlo(&self, path: &Path) -> anyhow::Result<Executable> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            Ok(Executable {
                exe,
                name: path.file_name().unwrap().to_string_lossy().into_owned(),
            })
        }
    }

    /// One compiled inference graph.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        pub name: String,
    }

    impl Executable {
        /// Execute with f32 host tensors; the exported graphs return a
        /// 1-tuple whose element is the logits tensor (flattened on return).
        pub fn run(&self, inputs: &[HostTensor]) -> anyhow::Result<Vec<f32>> {
            let lits: Vec<xla::Literal> = inputs
                .iter()
                .map(to_literal)
                .collect::<anyhow::Result<_>>()?;
            let result =
                self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
            let out = result.to_tuple1()?;
            Ok(out.to_vec::<f32>()?)
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        fn demo_path() -> Option<std::path::PathBuf> {
            let p = crate::nn::manifest::artifacts_dir().join("cim_mvm.hlo.txt");
            p.exists().then_some(p)
        }

        #[test]
        fn cim_mvm_artifact_roundtrip() {
            // needs `make artifacts` AND a real xla crate; skip silently
            // when either is absent so unit tests stay hermetic (the
            // integration suite requires the artifacts)
            let Some(path) = demo_path() else { return };
            let Ok(rt) = Runtime::cpu() else { return };
            let exe = rt.load_hlo(&path).unwrap();
            // graph: x[256,432] @ w[432,128], r_dac=1, r_adc=8, 9/8 bits
            let m = 256;
            let k = 432;
            let n = 128;
            let x = HostTensor::new(vec![m, k], vec![0.5f32; m * k]);
            let mut wdat = vec![0f32; k * n];
            for j in 0..n {
                wdat[j] = 1.0 / k as f32; // first input row of weights
            }
            let w = HostTensor::new(vec![k, n], wdat);
            let out = exe.run(&[x, w]).unwrap();
            assert_eq!(out.len(), m * n);
            // expected: DAC(0.5) on the 9-bit grid, only row 0 of w nonzero
            // => acc = dac(0.5)/432, then ADC-quantized at 8 bits
            let dac = (0.5f32 * 255.0).round() / 255.0;
            let adc_step = 8.0 / 127.0;
            let want = ((dac * (1.0 / 432.0)) / adc_step).round() * adc_step;
            assert!((out[0] - want).abs() < 1e-6, "{} vs {}", out[0], want);
        }
    }
}
