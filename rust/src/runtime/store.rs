//! Artifact store: manifest-driven discovery + caches.
//!
//! Always provides parsed metadata, loaded weights, and datasets (all the
//! `native` backend needs). With the `pjrt` feature it additionally owns a
//! lazily-created PJRT client and the compiled-executable cache, so benches
//! and the coordinator never recompile a graph — and a store opened only
//! for metadata never pays for (or requires) the XLA library at all.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::nn::{Manifest, ModelMeta};

pub struct ArtifactStore {
    pub manifest: Manifest,
    metas: Mutex<HashMap<String, Arc<ModelMeta>>>,
    weights: Mutex<HashMap<String, Arc<Vec<crate::nn::Tensor>>>>,
    #[cfg(feature = "pjrt")]
    runtime: Mutex<Option<Arc<crate::runtime::Runtime>>>,
    #[cfg(feature = "pjrt")]
    exes: Mutex<HashMap<String, Arc<crate::runtime::Executable>>>,
}

impl ArtifactStore {
    pub fn open(dir: &std::path::Path) -> anyhow::Result<Self> {
        Ok(ArtifactStore {
            manifest: Manifest::load(dir)?,
            metas: Mutex::new(HashMap::new()),
            weights: Mutex::new(HashMap::new()),
            #[cfg(feature = "pjrt")]
            runtime: Mutex::new(None),
            #[cfg(feature = "pjrt")]
            exes: Mutex::new(HashMap::new()),
        })
    }

    pub fn open_default() -> anyhow::Result<Self> {
        Self::open(&crate::nn::manifest::artifacts_dir())
    }

    pub fn meta(&self, vid: &str) -> anyhow::Result<Arc<ModelMeta>> {
        if let Some(m) = self.metas.lock().unwrap().get(vid) {
            return Ok(m.clone());
        }
        let e = self.manifest.find(vid)?;
        let m = Arc::new(ModelMeta::load(&self.manifest.meta_path(e))?);
        self.metas
            .lock()
            .unwrap()
            .insert(vid.to_string(), m.clone());
        Ok(m)
    }

    pub fn weights(&self, vid: &str) -> anyhow::Result<Arc<Vec<crate::nn::Tensor>>> {
        if let Some(w) = self.weights.lock().unwrap().get(vid) {
            return Ok(w.clone());
        }
        let e = self.manifest.find(vid)?;
        let w = Arc::new(crate::nn::load_weights(&self.manifest.weights_path(e))?);
        self.weights
            .lock()
            .unwrap()
            .insert(vid.to_string(), w.clone());
        Ok(w)
    }

    pub fn dataset(&self, task: &str) -> anyhow::Result<crate::datasets::Dataset> {
        crate::datasets::Dataset::load(&self.manifest.dataset_path(task))
    }

    /// The PJRT client, created on first use (so opening a store never
    /// requires the XLA library unless something actually executes HLO).
    #[cfg(feature = "pjrt")]
    pub fn runtime(&self) -> anyhow::Result<Arc<crate::runtime::Runtime>> {
        let mut guard = self.runtime.lock().unwrap();
        if let Some(rt) = guard.as_ref() {
            return Ok(rt.clone());
        }
        let rt = Arc::new(crate::runtime::Runtime::cpu()?);
        *guard = Some(rt.clone());
        Ok(rt)
    }

    /// Compiled executable for (vid, bits, batch); compiles at most once.
    #[cfg(feature = "pjrt")]
    pub fn executable(&self, vid: &str, bits: u32, batch: usize)
                      -> anyhow::Result<Arc<crate::runtime::Executable>> {
        let key = format!("{vid}/{bits}b_b{batch}");
        if let Some(e) = self.exes.lock().unwrap().get(&key) {
            return Ok(e.clone());
        }
        let meta = self.meta(vid)?;
        let file = meta.hlo_for(bits, batch).ok_or_else(|| {
            anyhow::anyhow!(
                "no HLO for {vid} at {bits}b batch {batch} (have {:?})",
                meta.hlo_keys()
            )
        })?;
        let rt = self.runtime()?;
        let exe = Arc::new(rt.load_hlo(&self.manifest.hlo_path(file))?);
        self.exes.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }
}
