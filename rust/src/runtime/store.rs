//! Artifact store: manifest-driven discovery + compiled-executable cache.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::nn::{Manifest, ModelMeta};
use crate::runtime::{Executable, Runtime};

/// Caches parsed metadata, loaded weights and compiled executables so
/// benches and the coordinator never recompile a graph.
pub struct ArtifactStore {
    pub manifest: Manifest,
    pub runtime: Runtime,
    exes: Mutex<HashMap<String, Arc<Executable>>>,
    metas: Mutex<HashMap<String, Arc<ModelMeta>>>,
    weights: Mutex<HashMap<String, Arc<Vec<crate::nn::Tensor>>>>,
}

impl ArtifactStore {
    pub fn open(dir: &std::path::Path) -> anyhow::Result<Self> {
        Ok(ArtifactStore {
            manifest: Manifest::load(dir)?,
            runtime: Runtime::cpu()?,
            exes: Mutex::new(HashMap::new()),
            metas: Mutex::new(HashMap::new()),
            weights: Mutex::new(HashMap::new()),
        })
    }

    pub fn open_default() -> anyhow::Result<Self> {
        Self::open(&crate::nn::manifest::artifacts_dir())
    }

    pub fn meta(&self, vid: &str) -> anyhow::Result<Arc<ModelMeta>> {
        if let Some(m) = self.metas.lock().unwrap().get(vid) {
            return Ok(m.clone());
        }
        let e = self.manifest.find(vid)?;
        let m = Arc::new(ModelMeta::load(&self.manifest.meta_path(e))?);
        self.metas
            .lock()
            .unwrap()
            .insert(vid.to_string(), m.clone());
        Ok(m)
    }

    pub fn weights(&self, vid: &str) -> anyhow::Result<Arc<Vec<crate::nn::Tensor>>> {
        if let Some(w) = self.weights.lock().unwrap().get(vid) {
            return Ok(w.clone());
        }
        let e = self.manifest.find(vid)?;
        let w = Arc::new(crate::nn::load_weights(&self.manifest.weights_path(e))?);
        self.weights
            .lock()
            .unwrap()
            .insert(vid.to_string(), w.clone());
        Ok(w)
    }

    /// Compiled executable for (vid, bits, batch); compiles at most once.
    pub fn executable(&self, vid: &str, bits: u32, batch: usize)
                      -> anyhow::Result<Arc<Executable>> {
        let key = format!("{vid}/{bits}b_b{batch}");
        if let Some(e) = self.exes.lock().unwrap().get(&key) {
            return Ok(e.clone());
        }
        let meta = self.meta(vid)?;
        let file = meta.hlo_for(bits, batch).ok_or_else(|| {
            anyhow::anyhow!(
                "no HLO for {vid} at {bits}b batch {batch} (have {:?})",
                meta.hlo_keys()
            )
        })?;
        let exe = Arc::new(self.runtime.load_hlo(&self.manifest.hlo_path(file))?);
        self.exes.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    pub fn dataset(&self, task: &str) -> anyhow::Result<crate::datasets::Dataset> {
        crate::datasets::Dataset::load(&self.manifest.dataset_path(task))
    }
}
