//! `artifacts/manifest.json`: the index of exported variants.

use std::path::{Path, PathBuf};

use crate::util::json;

#[derive(Clone, Debug)]
pub struct VariantEntry {
    pub vid: String,
    pub task: String,
    pub model: String,
    pub eta: f64,
    pub trained_bits: Option<u32>,
    pub fp_test_acc: f64,
    pub meta_file: String,
    pub weights_file: String,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub variants: Vec<VariantEntry>,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> anyhow::Result<Self> {
        let v = json::parse_file(&artifacts_dir.join("manifest.json"))?;
        let mut variants = Vec::new();
        for e in v.as_arr()? {
            let bits = e.get("trained_bits").and_then(|b| b.as_f64().ok());
            variants.push(VariantEntry {
                vid: e.req("vid")?.as_str()?.to_string(),
                task: e.req("task")?.as_str()?.to_string(),
                model: e.req("model")?.as_str()?.to_string(),
                eta: e.req("eta")?.as_f64()?,
                trained_bits: bits.map(|b| b as u32),
                fp_test_acc: e.req("fp_test_acc")?.as_f64()?,
                meta_file: e.req("meta")?.as_str()?.to_string(),
                weights_file: e.req("weights")?.as_str()?.to_string(),
            });
        }
        Ok(Manifest {
            dir: artifacts_dir.to_path_buf(),
            variants,
        })
    }

    pub fn find(&self, vid: &str) -> anyhow::Result<&VariantEntry> {
        self.variants
            .iter()
            .find(|v| v.vid == vid)
            .ok_or_else(|| anyhow::anyhow!(
                "variant `{vid}` not in manifest (have: {:?}); run `make artifacts`",
                self.variants.iter().map(|v| v.vid.as_str()).collect::<Vec<_>>()
            ))
    }

    pub fn meta_path(&self, e: &VariantEntry) -> PathBuf {
        self.dir.join(&e.meta_file)
    }

    pub fn weights_path(&self, e: &VariantEntry) -> PathBuf {
        self.dir.join(&e.weights_file)
    }

    pub fn hlo_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    pub fn dataset_path(&self, task: &str) -> PathBuf {
        self.dir.join(format!("{task}_test.bin"))
    }
}

/// Default artifacts directory: `$ANALOGNETS_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("ANALOGNETS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_manifest() {
        let dir = std::env::temp_dir().join("manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"[{"vid":"kws_base","task":"kws","model":"analognet_kws",
                "variant_kind":"base","eta":0.1,"trained_bits":null,
                "fp_test_acc":0.98,"meta":"kws_base.meta.json",
                "weights":"kws_base.weights.bin","hlo":{}}]"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.variants.len(), 1);
        assert!(m.find("kws_base").is_ok());
        assert!(m.find("nope").is_err());
        assert!(m.meta_path(&m.variants[0]).ends_with("kws_base.meta.json"));
    }
}
