//! Paper-exact AnalogNet topologies (Table 1, Section 3), constructed as
//! [`ModelMeta`] values without any on-disk artifact.
//!
//! The serving stack normally loads `<vid>.meta.json` exported by the
//! Python compiler, but the timing/energy benches and the CI energy gate
//! need the *paper's* AnalogNet-KWS / AnalogNet-VWW layer tables even when
//! no trained bundle is present. These constructors rebuild exactly the
//! layer shapes `python/compile/models/analognet_{kws,vww}.py` export
//! (verified by parameter-count checksums in the tests below), with
//! placeholder quantizer/affine fields: the metas carry **no weights** and
//! are meant for `mapping::map_model` + `timing::` estimation only — do not
//! feed them to an inference backend.

use std::collections::BTreeMap;

use super::meta::{LayerKind, LayerMeta, ModelMeta};

/// Same-padded output extent: `ceil(in / stride)`.
fn out_dim(i: usize, s: usize) -> usize {
    i.div_ceil(s)
}

/// Build one analog layer with placeholder (unity) quantizer/affine fields.
#[allow(clippy::too_many_arguments)]
fn layer(
    name: &str,
    kind: LayerKind,
    in_ch: usize,
    out_ch: usize,
    stride: (usize, usize),
    relu: bool,
    in_h: usize,
    in_w: usize,
) -> LayerMeta {
    let (out_h, out_w) = match kind {
        LayerKind::Dense => (1, 1),
        _ => (out_dim(in_h, stride.0), out_dim(in_w, stride.1)),
    };
    let k_gemm = match kind {
        LayerKind::Conv3x3 | LayerKind::Dw3x3 => 9 * in_ch,
        LayerKind::Conv1x1 | LayerKind::Dense => in_ch,
    };
    LayerMeta {
        name: name.to_string(),
        kind,
        in_ch,
        out_ch,
        stride,
        relu,
        analog: true,
        in_h,
        in_w,
        out_h,
        out_w,
        k_gemm,
        weight_shape: vec![k_gemm, out_ch],
        graph_weight_shape: vec![k_gemm, out_ch],
        w_scale: 1.0,
        w_max: 1.0,
        r_dac: 8.0,
        r_adc: 8.0,
        dig_scale: vec![1.0; out_ch],
        dig_bias: vec![0.0; out_ch],
    }
}

/// AnalogNet-KWS (Table 1): five same-padded 3x3 conv stages over the
/// 49x10 MFCC map, then a 12-way dense classifier. 307,392 weights.
pub fn analognet_kws() -> ModelMeta {
    use LayerKind::{Conv3x3, Dense};
    let mut layers = Vec::new();
    let (mut h, mut w) = (49usize, 10usize);
    for (i, (ic, oc, s)) in [
        (1usize, 64usize, (2usize, 1usize)),
        (64, 64, (1, 1)),
        (64, 88, (2, 2)),
        (88, 112, (1, 1)),
        (112, 128, (1, 1)),
    ]
    .into_iter()
    .enumerate()
    {
        let l = layer(&format!("conv{i}"), Conv3x3, ic, oc, s, true, h, w);
        (h, w) = (l.out_h, l.out_w);
        layers.push(l);
    }
    layers.push(layer("fc", Dense, 128, 12, (1, 1), false, h, w));
    ModelMeta {
        model: "analognet_kws".to_string(),
        variant: "paper".to_string(),
        input_hwc: (49, 10, 1),
        num_classes: 12,
        eta: 0.0,
        fp_test_acc: 0.0,
        trained_adc_bits: None,
        layers,
        hlo: BTreeMap::new(),
    }
}

/// AnalogNet-VWW (Table 1): a 3x3 stem plus four MBConv-style
/// expand/project blocks over the 100x100 RGB input, then a 2-way dense
/// classifier. 346,168 weights.
pub fn analognet_vww() -> ModelMeta {
    use LayerKind::{Conv1x1, Conv3x3, Dense};
    let specs: [(&str, LayerKind, usize, usize, (usize, usize), bool); 9] = [
        ("stem", Conv3x3, 3, 24, (2, 2), true),
        ("a_exp", Conv3x3, 24, 96, (2, 2), true),
        ("a_proj", Conv1x1, 96, 32, (1, 1), false),
        ("b_exp", Conv3x3, 32, 128, (2, 2), true),
        ("b_proj", Conv1x1, 128, 56, (1, 1), false),
        ("c_exp", Conv3x3, 56, 208, (1, 1), true),
        ("c_proj", Conv1x1, 208, 64, (1, 1), false),
        ("d_exp", Conv3x3, 64, 240, (2, 2), true),
        ("d_proj", Conv1x1, 240, 88, (1, 1), false),
    ];
    let mut layers = Vec::new();
    let (mut h, mut w) = (100usize, 100usize);
    for (name, kind, ic, oc, s, relu) in specs {
        let l = layer(name, kind, ic, oc, s, relu, h, w);
        (h, w) = (l.out_h, l.out_w);
        layers.push(l);
    }
    layers.push(layer("fc", Dense, 88, 2, (1, 1), false, h, w));
    ModelMeta {
        model: "analognet_vww".to_string(),
        variant: "paper".to_string(),
        input_hwc: (100, 100, 3),
        num_classes: 2,
        eta: 0.0,
        fp_test_acc: 0.0,
        trained_adc_bits: None,
        layers,
        hlo: BTreeMap::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crossbar::ArrayGeom;
    use crate::mapping::map_model;

    #[test]
    fn kws_matches_paper_table1() {
        let m = analognet_kws();
        // Table 1: 307k parameters; every layer fits the 1024x512 array
        assert_eq!(m.param_count(), 307_392);
        assert_eq!(m.num_classes, 12);
        assert_eq!(m.layers.len(), 6);
        let map = map_model(&m, ArrayGeom::AON).unwrap();
        // Figure 6a: ~57% array utilization for KWS
        let u = map.allocated_utilization();
        assert!((0.55..0.62).contains(&u), "kws utilization {u}");
    }

    #[test]
    fn vww_matches_paper_table1() {
        let m = analognet_vww();
        // Table 1: 346k parameters
        assert_eq!(m.param_count(), 346_168);
        assert_eq!(m.num_classes, 2);
        assert_eq!(m.layers.len(), 10);
        let map = map_model(&m, ArrayGeom::AON).unwrap();
        // Figure 6b: ~66% array utilization for VWW
        let u = map.allocated_utilization();
        assert!((0.63..0.70).contains(&u), "vww utilization {u}");
    }

    #[test]
    fn spatial_dims_follow_same_padding() {
        let m = analognet_kws();
        // 49x10 -> s(2,1) -> 25x10 -> s(1,1) -> 25x10 -> s(2,2) -> 13x5
        assert_eq!((m.layers[0].out_h, m.layers[0].out_w), (25, 10));
        assert_eq!((m.layers[2].out_h, m.layers[2].out_w), (13, 5));
        assert_eq!(m.layers[4].out_pixels(), 65);
        // dense head collapses to one MVM
        assert_eq!(m.layers[5].out_pixels(), 1);
    }
}
