//! Model descriptions and artifact loading (the Rust side of the
//! python-export contract — see DESIGN.md section 7).

pub mod analognets;
pub mod manifest;
pub mod meta;
pub mod weights;

pub use manifest::{Manifest, VariantEntry};
pub use meta::{LayerKind, LayerMeta, ModelMeta};
pub use weights::{expand_dw_dense, load_weights, Tensor};
