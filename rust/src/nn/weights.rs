//! ANWT weight binary loading + depthwise dense expansion.

use std::io::Read;
use std::path::Path;

#[derive(Clone, Debug)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

const MAGIC: &[u8; 4] = b"ANWT";

/// Load the compact trained weights written by `export.write_weights_bin`.
pub fn load_weights(path: &Path) -> anyhow::Result<Vec<Tensor>> {
    let mut f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> anyhow::Result<&[u8]> {
        if *pos + n > buf.len() {
            anyhow::bail!("truncated ANWT file");
        }
        let s = &buf[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    let u32_at = |pos: &mut usize| -> anyhow::Result<u32> {
        let b = take(pos, 4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    };
    if take(&mut pos, 4)? != MAGIC {
        anyhow::bail!("bad ANWT magic in {}", path.display());
    }
    let n_tensors = u32_at(&mut pos)? as usize;
    let mut out = Vec::with_capacity(n_tensors);
    for _ in 0..n_tensors {
        let ndim = u32_at(&mut pos)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(u32_at(&mut pos)? as usize);
        }
        let numel: usize = shape.iter().product();
        let raw = take(&mut pos, numel * 4)?;
        let mut data = vec![0f32; numel];
        for (i, c) in raw.chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        out.push(Tensor { shape, data });
    }
    if pos != buf.len() {
        anyhow::bail!("trailing bytes in ANWT file");
    }
    Ok(out)
}

/// Expand a compact depthwise weight [9, C] to its dense CiM form [9C, C].
///
/// Row `t*C + i`, column `j` holds `w[t, i]` iff `i == j`, else an explicit
/// zero — the zeros are *real programmed devices* on the array and therefore
/// receive programming/read noise (the Section 4.1 depthwise SNR effect).
pub fn expand_dw_dense(w9c: &Tensor) -> Tensor {
    assert_eq!(w9c.shape.len(), 2);
    assert_eq!(w9c.shape[0], 9, "compact dw weight must be [9, C]");
    let c = w9c.shape[1];
    let mut data = vec![0f32; 9 * c * c];
    for t in 0..9 {
        for i in 0..c {
            data[(t * c + i) * c + i] = w9c.data[t * c + i];
        }
    }
    Tensor {
        shape: vec![9 * c, c],
        data,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expand_dw_structure() {
        let w = Tensor {
            shape: vec![9, 2],
            data: (0..18).map(|i| i as f32).collect(),
        };
        let d = expand_dw_dense(&w);
        assert_eq!(d.shape, vec![18, 2]);
        // nonzeros exactly on the per-tap diagonals
        for t in 0..9 {
            for i in 0..2 {
                for j in 0..2 {
                    let v = d.data[(t * 2 + i) * 2 + j];
                    if i == j {
                        assert_eq!(v, w.data[t * 2 + i]);
                    } else {
                        assert_eq!(v, 0.0);
                    }
                }
            }
        }
    }

    #[test]
    fn anwt_roundtrip() {
        // write a file in the python format and read it back
        let dir = std::env::temp_dir().join("anwt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"ANWT");
        bytes.extend_from_slice(&2u32.to_le_bytes());
        // tensor 1: [2,3]
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&3u32.to_le_bytes());
        for i in 0..6 {
            bytes.extend_from_slice(&(i as f32).to_le_bytes());
        }
        // tensor 2: [1]
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&7.5f32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();

        let ts = load_weights(&path).unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].shape, vec![2, 3]);
        assert_eq!(ts[0].data[5], 5.0);
        assert_eq!(ts[1].data[0], 7.5);
    }

    #[test]
    fn anwt_rejects_truncated() {
        let dir = std::env::temp_dir().join("anwt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"ANWT\x01\x00\x00\x00\x02").unwrap();
        assert!(load_weights(&path).is_err());
    }
}
