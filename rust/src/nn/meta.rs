//! `<vid>.meta.json` parsing: the per-layer table exported by
//! `python/compile/export.py`.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::{self, Json};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Conv3x3,
    Conv1x1,
    Dw3x3,
    Dense,
}

impl LayerKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "conv3x3" => LayerKind::Conv3x3,
            "conv1x1" => LayerKind::Conv1x1,
            "dw3x3" => LayerKind::Dw3x3,
            "dense" => LayerKind::Dense,
            _ => anyhow::bail!("unknown layer kind {s}"),
        })
    }
    pub fn as_str(&self) -> &'static str {
        match self {
            LayerKind::Conv3x3 => "conv3x3",
            LayerKind::Conv1x1 => "conv1x1",
            LayerKind::Dw3x3 => "dw3x3",
            LayerKind::Dense => "dense",
        }
    }
}

#[derive(Clone, Debug)]
pub struct LayerMeta {
    pub name: String,
    pub kind: LayerKind,
    pub in_ch: usize,
    pub out_ch: usize,
    pub stride: (usize, usize),
    pub relu: bool,
    pub analog: bool,
    pub in_h: usize,
    pub in_w: usize,
    pub out_h: usize,
    pub out_w: usize,
    /// im2col GEMM inner dimension == crossbar rows for this layer
    pub k_gemm: usize,
    /// compact stored weight shape (dw: [9, C])
    pub weight_shape: Vec<usize>,
    /// weight shape as the HLO graph expects it (dw analog: [9C, C])
    pub graph_weight_shape: Vec<usize>,
    /// max|W| of the clipped trained weights (conductance mapping)
    pub w_scale: f32,
    /// clipping bound W_max (eq. 1-2)
    pub w_max: f32,
    /// DAC/ADC quantizer ranges baked into the graph
    pub r_dac: f32,
    pub r_adc: f32,
    /// folded digital affine (BN or bias), per output channel
    pub dig_scale: Vec<f32>,
    pub dig_bias: Vec<f32>,
}

impl LayerMeta {
    /// Output pixels per inference (MVM count for conv layers).
    pub fn out_pixels(&self) -> usize {
        self.out_h * self.out_w
    }

    /// Crossbar columns used by this layer when mapped.
    pub fn mapped_cols(&self) -> usize {
        self.out_ch
    }

    /// Crossbar rows used by this layer when mapped (dense dw expansion).
    pub fn mapped_rows(&self) -> usize {
        self.k_gemm
    }

    /// MAC ops per inference (1 MAC = 2 ops), counting the *dense* mapped
    /// form (this is what the hardware physically performs).
    pub fn macs(&self) -> usize {
        self.mapped_rows() * self.mapped_cols() * self.out_pixels()
    }

    /// Non-zero (effective) weights: differs from mapped size for dw.
    pub fn effective_weights(&self) -> usize {
        self.weight_shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub model: String,
    pub variant: String,
    pub input_hwc: (usize, usize, usize),
    pub num_classes: usize,
    pub eta: f64,
    pub fp_test_acc: f64,
    pub trained_adc_bits: Option<u32>,
    pub layers: Vec<LayerMeta>,
    /// "<bits>b_b<batch>" -> hlo filename
    pub hlo: BTreeMap<String, String>,
}

impl ModelMeta {
    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let v = json::parse_file(path)?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        let hwc = v.req("input_hwc")?.usizes()?;
        let mut layers = Vec::new();
        for l in v.req("layers")?.as_arr()? {
            let stride = l.req("stride")?.usizes()?;
            layers.push(LayerMeta {
                name: l.req("name")?.as_str()?.to_string(),
                kind: LayerKind::parse(l.req("kind")?.as_str()?)?,
                in_ch: l.req("in_ch")?.as_usize()?,
                out_ch: l.req("out_ch")?.as_usize()?,
                stride: (stride[0], stride[1]),
                relu: l.req("relu")?.as_bool()?,
                analog: l.req("analog")?.as_bool()?,
                in_h: l.req("in_h")?.as_usize()?,
                in_w: l.req("in_w")?.as_usize()?,
                out_h: l.req("out_h")?.as_usize()?,
                out_w: l.req("out_w")?.as_usize()?,
                k_gemm: l.req("k_gemm")?.as_usize()?,
                weight_shape: l.req("weight_shape")?.usizes()?,
                graph_weight_shape: l.req("graph_weight_shape")?.usizes()?,
                w_scale: l.req("w_scale")?.as_f64()? as f32,
                w_max: l.req("w_max")?.as_f64()? as f32,
                r_dac: l.req("r_dac")?.as_f64()? as f32,
                r_adc: l.req("r_adc")?.as_f64()? as f32,
                dig_scale: l.req("dig_scale")?.f32s()?,
                dig_bias: l.req("dig_bias")?.f32s()?,
            });
        }
        let mut hlo = BTreeMap::new();
        for (k, f) in v.req("hlo")?.as_obj()? {
            hlo.insert(k.clone(), f.as_str()?.to_string());
        }
        let bits = v.get("trained_adc_bits").and_then(|b| match b {
            Json::Num(n) => Some(*n as u32),
            _ => None,
        });
        Ok(ModelMeta {
            model: v.req("model")?.as_str()?.to_string(),
            variant: v.req("variant")?.as_str()?.to_string(),
            input_hwc: (hwc[0], hwc[1], hwc[2]),
            num_classes: v.req("num_classes")?.as_usize()?,
            eta: v.req("eta")?.as_f64()?,
            fp_test_acc: v.req("fp_test_acc")?.as_f64()?,
            trained_adc_bits: bits,
            layers,
            hlo,
        })
    }

    /// Total effective parameters (compact forms).
    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.effective_weights()).sum()
    }

    /// Total MACs per inference on the mapped (dense) form.
    pub fn macs(&self) -> usize {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Pick the HLO file for (bits, batch), if exported.
    pub fn hlo_for(&self, bits: u32, batch: usize) -> Option<&str> {
        self.hlo.get(&format!("{bits}b_b{batch}")).map(|s| s.as_str())
    }

    /// All (bits, batch) pairs available.
    pub fn hlo_keys(&self) -> Vec<(u32, usize)> {
        self.hlo
            .keys()
            .filter_map(|k| {
                let (b, r) = k.split_once("b_b")?;
                Some((b.parse().ok()?, r.parse().ok()?))
            })
            .collect()
    }

    /// Exported serving-graph batch sizes at `bits`, ascending and deduped —
    /// the shared source of truth for every backend's `batch_sizes()`.
    pub fn serving_batch_sizes(&self, bits: u32) -> Vec<usize> {
        let mut sizes: Vec<usize> = self
            .hlo_keys()
            .into_iter()
            .filter(|(b, _)| *b == bits)
            .map(|(_, n)| n)
            .collect();
        sizes.sort_unstable();
        sizes.dedup();
        sizes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) const SAMPLE: &str = r#"{
      "model": "m", "variant": "v", "input_hwc": [4, 4, 1], "num_classes": 2,
      "eta": 0.1, "fp_test_acc": 0.9, "trained_adc_bits": null,
      "layers": [{
        "name": "c0", "kind": "conv3x3", "in_ch": 1, "out_ch": 3,
        "stride": [2, 2], "relu": true, "analog": true,
        "in_h": 4, "in_w": 4, "out_h": 2, "out_w": 2,
        "k_gemm": 9, "weight_shape": [9, 3], "graph_weight_shape": [9, 3],
        "w_scale": 0.5, "w_max": 0.6, "r_dac": 1.0, "r_adc": 2.0,
        "dig_scale": [1, 1, 1], "dig_bias": [0, 0, 0]
      }],
      "hlo": {"8b_b256": "m_8b_b256.hlo.txt"}
    }"#;

    #[test]
    fn parses_sample() {
        let v = crate::util::json::parse(SAMPLE).unwrap();
        let m = ModelMeta::from_json(&v).unwrap();
        assert_eq!(m.layers.len(), 1);
        assert_eq!(m.layers[0].kind, LayerKind::Conv3x3);
        assert_eq!(m.layers[0].macs(), 9 * 3 * 4);
        assert_eq!(m.hlo_for(8, 256), Some("m_8b_b256.hlo.txt"));
        assert_eq!(m.hlo_for(6, 256), None);
        assert_eq!(m.hlo_keys(), vec![(8, 256)]);
        assert_eq!(m.serving_batch_sizes(8), vec![256]);
        assert!(m.serving_batch_sizes(6).is_empty());
    }
}
