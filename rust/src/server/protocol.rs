//! The wire line protocol: request grammar over the visiting JSON reader,
//! plus the response/error line writers.
//!
//! Request line (one JSON object per `\n`-terminated line):
//!
//! ```text
//! {"id": <string|integer>,            required; echoed on the reply
//!  "model": <string>,                 optional model id; only meaningful
//!                                     on a multi-model listener (default:
//!                                     the configured primary model); a
//!                                     single-model listener rejects it
//!  "x": [f32, ...],                   exactly one of `x` (an input tensor
//!  "sample": <integer>,               of model feature length) or `sample`
//!                                     (a test-set index on the server)
//!  "t_drift": <seconds>,              optional InferOpts::t_drift
//!  "adc_bits": <integer>}             optional InferOpts::adc_bits
//! ```
//!
//! Success reply:
//!
//! ```text
//! {"id": ..., "ok": true, "pred": N, "logits": [...],
//!  "sim_age_s": S, "adc_bits": B, "latency_us": U}
//! ```
//!
//! Error reply (malformed line, bad option, closed coordinator, ...):
//!
//! ```text
//! {"id": <echoed id or null>, "ok": false, "error": "..."}
//! ```
//!
//! Parsing writes into a per-connection [`ReqScratch`] — the feature
//! vector, the id, and the string-decode buffers are all reused across
//! requests, so the ingestion path performs no per-request allocation
//! (pinned by the counting-allocator test in `tests/test_wire.rs`).
//! Unknown fields are rejected: a typo'd option must fail loudly, not
//! silently serve under default options.

use std::fmt::Write as _;

use crate::backend::InferOpts;
use crate::coordinator::Response;
use crate::server::json::{self, ParseError, Scalar, Visit};

/// Reusable per-connection parse state. `features` is preallocated to the
/// model feature length and never grows past it; `id` and the JSON decode
/// buffers keep their capacity across lines.
#[derive(Debug)]
pub struct ReqScratch {
    pub json: json::Scratch,
    pub features: Vec<f32>,
    pub id: String,
    /// requested model id (empty unless the line carried `"model"`)
    pub model: String,
}

impl ReqScratch {
    pub fn new(feat_len: usize) -> Self {
        ReqScratch {
            json: json::Scratch::new(),
            features: Vec::with_capacity(feat_len),
            id: String::with_capacity(32),
            model: String::with_capacity(32),
        }
    }
}

/// Where this request's input comes from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqBody {
    /// an explicit tensor: the parsed values sit in [`ReqScratch::features`]
    Features,
    /// a server-side test-set sample index
    Sample(usize),
}

/// One parsed request line (the id text lives in [`ReqScratch::id`]).
#[derive(Clone, Copy, Debug)]
pub struct ParsedReq {
    pub body: ReqBody,
    pub t_drift: Option<f64>,
    pub adc_bits: Option<u32>,
    /// the line carried a `"model"` field (its text is in
    /// [`ReqScratch::model`]); single-model listeners reject such lines
    pub has_model: bool,
}

impl ParsedReq {
    pub fn opts(&self) -> InferOpts {
        // no wire field for fault scenarios (yet): wire requests serve the
        // coordinator's deployment-default spec
        InferOpts { t_drift: self.t_drift, adc_bits: self.adc_bits,
                    adc_bits_floor: None, faults: None }
    }
}

/// The protocol visitor: streams fields into the scratch buffers.
struct ReqVisitor<'a> {
    feat: &'a mut Vec<f32>,
    id: &'a mut String,
    model: &'a mut String,
    feat_cap: usize,
    has_id: bool,
    has_x: bool,
    has_model: bool,
    sample: Option<usize>,
    t_drift: Option<f64>,
    adc_bits: Option<u32>,
}

/// `n` as a non-negative integer index, or an error.
fn as_index(n: f64, msg: &'static str) -> Result<usize, ParseError> {
    if n.fract() != 0.0 || !(0.0..9e15).contains(&n) {
        return Err(ParseError::msg(msg));
    }
    Ok(n as usize)
}

impl Visit for ReqVisitor<'_> {
    fn scalar(&mut self, key: &str, val: Scalar<'_>) -> Result<(), ParseError> {
        match key {
            "id" => {
                self.id.clear();
                match val {
                    Scalar::Str(s) => self.id.push_str(s),
                    Scalar::Num(n) if n.fract() == 0.0 && n.abs() < 9e15 => {
                        let _ = write!(self.id, "{}", n as i64);
                    }
                    Scalar::Num(n) => {
                        let _ = write!(self.id, "{n}");
                    }
                    _ => return Err(ParseError::msg(
                        "`id` must be a string or number")),
                }
                self.has_id = true;
            }
            "model" => match val {
                Scalar::Str(s) => {
                    self.model.clear();
                    self.model.push_str(s);
                    self.has_model = true;
                }
                _ => return Err(ParseError::msg("`model` must be a string")),
            },
            "t_drift" => match val {
                Scalar::Num(n) => self.t_drift = Some(n),
                _ => return Err(ParseError::msg("`t_drift` must be a number")),
            },
            "adc_bits" => match val {
                Scalar::Num(n) => {
                    self.adc_bits = Some(as_index(
                        n, "`adc_bits` must be a small integer")?
                        as u32);
                }
                _ => return Err(ParseError::msg(
                    "`adc_bits` must be a small integer")),
            },
            "sample" => match val {
                Scalar::Num(n) => {
                    self.sample = Some(as_index(
                        n, "`sample` must be a non-negative integer")?);
                }
                _ => return Err(ParseError::msg(
                    "`sample` must be a non-negative integer")),
            },
            "x" => return Err(ParseError::msg("`x` must be an array of numbers")),
            _ => return Err(ParseError::msg(
                "unknown field (expected id, model, x, sample, t_drift, \
                 adc_bits)")),
        }
        Ok(())
    }

    fn begin_array(&mut self, key: &str) -> Result<(), ParseError> {
        if key != "x" {
            return Err(ParseError::msg("only `x` may be an array"));
        }
        if self.has_x {
            return Err(ParseError::msg("duplicate `x`"));
        }
        self.has_x = true;
        self.feat.clear();
        Ok(())
    }

    fn array_num(&mut self, _key: &str, val: f64) -> Result<(), ParseError> {
        // capacity-bounded push: an over-long `x` errors out instead of
        // growing (and reallocating) the preallocated feature buffer
        if self.feat.len() >= self.feat_cap {
            return Err(ParseError::msg(
                "`x` is longer than the model feature length"));
        }
        if !val.is_finite() {
            return Err(ParseError::msg("`x` values must be finite"));
        }
        self.feat.push(val as f32);
        Ok(())
    }
}

/// Parse one request line into `scratch` with only a *capacity* bound on
/// `x` (an over-long tensor still errors; a shorter one is accepted as
/// is). Multi-model listeners use this — the exact length depends on
/// which model the line routes to, so the per-model check happens after
/// routing. On success the id is in `scratch.id`, the model id (when
/// present) in `scratch.model`, and (for [`ReqBody::Features`]) the
/// tensor is in `scratch.features`.
pub fn parse_request_cap(line: &[u8], feat_cap: usize,
                         scratch: &mut ReqScratch)
                         -> Result<ParsedReq, ParseError> {
    scratch.features.clear();
    scratch.id.clear();
    scratch.model.clear();
    let mut v = ReqVisitor {
        feat: &mut scratch.features,
        id: &mut scratch.id,
        model: &mut scratch.model,
        feat_cap,
        has_id: false,
        has_x: false,
        has_model: false,
        sample: None,
        t_drift: None,
        adc_bits: None,
    };
    json::read_object(line, &mut scratch.json, &mut v)?;
    if !v.has_id {
        return Err(ParseError::msg("missing `id`"));
    }
    let body = match (v.has_x, v.sample) {
        (true, None) => ReqBody::Features,
        (false, Some(s)) => ReqBody::Sample(s),
        _ => {
            return Err(ParseError::msg(
                "pass exactly one of `x` or `sample`"))
        }
    };
    Ok(ParsedReq { body, t_drift: v.t_drift, adc_bits: v.adc_bits,
                   has_model: v.has_model })
}

/// Parse one request line into `scratch`. On success the id is in
/// `scratch.id` and (for [`ReqBody::Features`]) the tensor is in
/// `scratch.features`, exactly `feat_len` long.
pub fn parse_request(line: &[u8], feat_len: usize, scratch: &mut ReqScratch)
                     -> Result<ParsedReq, ParseError> {
    let p = parse_request_cap(line, feat_len, scratch)?;
    if p.body == ReqBody::Features && scratch.features.len() != feat_len {
        return Err(ParseError::msg(
            "`x` is shorter than the model feature length"));
    }
    Ok(p)
}

// ---------------------------------------------------------------------------
// Response writers (append into a reusable per-connection String)
// ---------------------------------------------------------------------------

/// JSON string literal with the same escaping as `util::json::write`.
pub fn push_json_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// JSON number: non-finite values serialize as 0 (like the metrics
/// writer), integral values without a fraction.
fn push_num(out: &mut String, x: f64) {
    if !x.is_finite() {
        out.push('0');
    } else if x.fract() == 0.0 && x.abs() < 9e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

/// Append one success line (newline-terminated) for a served response.
pub fn write_response_line(out: &mut String, id: &str, r: &Response) {
    out.push_str("{\"id\":");
    push_json_str(out, id);
    out.push_str(",\"ok\":true,\"pred\":");
    let _ = write!(out, "{}", r.pred);
    out.push_str(",\"logits\":[");
    for (i, l) in r.logits.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if l.is_finite() {
            // f32 Display is the shortest round-tripping decimal, so the
            // client-side f64 parse recovers the exact served logit
            let _ = write!(out, "{l}");
        } else {
            out.push('0');
        }
    }
    out.push_str("],\"sim_age_s\":");
    push_num(out, r.sim_age_s);
    out.push_str(",\"adc_bits\":");
    let _ = write!(out, "{}", r.adc_bits);
    out.push_str(",\"latency_us\":");
    push_num(out, r.latency.as_secs_f64() * 1e6);
    out.push_str("}\n");
}

/// Append one error line (newline-terminated). `id` is echoed when the
/// line got far enough to carry one, `null` otherwise.
pub fn write_error_line(out: &mut String, id: Option<&str>, msg: &str) {
    out.push_str("{\"id\":");
    match id {
        Some(id) => push_json_str(out, id),
        None => out.push_str("null"),
    }
    out.push_str(",\"ok\":false,\"error\":");
    push_json_str(out, msg);
    out.push_str("}\n");
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn parse(line: &str, feat_len: usize)
             -> (Result<ParsedReq, ParseError>, ReqScratch) {
        let mut sc = ReqScratch::new(feat_len);
        let r = parse_request(line.as_bytes(), feat_len, &mut sc);
        (r, sc)
    }

    #[test]
    fn full_request_with_options() {
        let (r, sc) = parse(
            r#"{"id": "c0-17", "x": [0.5, -1, 2.5e-1], "t_drift": 86400, "adc_bits": 4}"#,
            3,
        );
        let p = r.unwrap();
        assert_eq!(sc.id, "c0-17");
        assert_eq!(p.body, ReqBody::Features);
        assert_eq!(sc.features, vec![0.5, -1.0, 0.25]);
        assert_eq!(p.t_drift, Some(86_400.0));
        assert_eq!(p.adc_bits, Some(4));
        let o = p.opts();
        assert_eq!(o.t_drift, Some(86_400.0));
        assert_eq!(o.adc_bits, Some(4));
    }

    #[test]
    fn sample_reference_and_numeric_id() {
        let (r, sc) = parse(r#"{"id": 42, "sample": 3}"#, 16);
        let p = r.unwrap();
        assert_eq!(sc.id, "42");
        assert_eq!(p.body, ReqBody::Sample(3));
        assert_eq!(p.t_drift, None);
        assert_eq!(p.adc_bits, None);
    }

    #[test]
    fn rejects_bad_requests() {
        for (line, why) in [
            (r#"{"x": [1, 2]}"#, "missing id"),
            (r#"{"id": "a"}"#, "neither x nor sample"),
            (r#"{"id": "a", "x": [1], "sample": 0}"#, "both x and sample"),
            (r#"{"id": "a", "x": [1]}"#, "x too short"),
            (r#"{"id": "a", "x": [1, 2, 3]}"#, "x too long"),
            (r#"{"id": "a", "x": [1, 2], "extra": 1}"#, "unknown field"),
            (r#"{"id": "a", "x": "no"}"#, "x not an array"),
            (r#"{"id": "a", "sample": -1}"#, "negative sample"),
            (r#"{"id": "a", "sample": 1.5}"#, "fractional sample"),
            (r#"{"id": "a", "x": [1, 2], "adc_bits": 4.5}"#, "fractional bits"),
            (r#"{"id": "a", "x": [1, 2], "t_drift": "soon"}"#, "string t_drift"),
            (r#"{"id": true, "x": [1, 2]}"#, "bool id"),
            (r#"not json"#, "not json"),
        ] {
            assert!(parse(line, 2).0.is_err(), "accepted bad request: {why}");
        }
    }

    #[test]
    fn model_field_parses_and_resets() {
        let (r, sc) = parse(r#"{"id": "a", "model": "vww", "x": [1, 2]}"#, 2);
        let p = r.unwrap();
        assert!(p.has_model);
        assert_eq!(sc.model, "vww");
        assert_eq!(p.body, ReqBody::Features);
        // absent model leaves the flag clear and the buffer empty
        let (r, sc) = parse(r#"{"id": "b", "sample": 0}"#, 2);
        assert!(!r.unwrap().has_model);
        assert!(sc.model.is_empty());
        // non-string model is rejected
        let (r, _) = parse(r#"{"id": "c", "model": 3, "x": [1, 2]}"#, 2);
        assert!(r.is_err());
    }

    #[test]
    fn cap_parse_accepts_short_x_but_never_long() {
        let mut sc = ReqScratch::new(4);
        // shorter than the cap: accepted (exact check is per model,
        // downstream)
        let p = parse_request_cap(br#"{"id": "a", "x": [1, 2]}"#, 4, &mut sc)
            .unwrap();
        assert_eq!(p.body, ReqBody::Features);
        assert_eq!(sc.features, vec![1.0, 2.0]);
        // longer than the cap still errors without growing the buffer
        let r = parse_request_cap(br#"{"id": "a", "x": [1, 2, 3, 4, 5]}"#, 4,
                                  &mut sc);
        assert!(r.is_err());
        assert_eq!(sc.features.capacity(), 4);
        // the strict wrapper keeps demanding the exact length
        assert!(parse_request(br#"{"id": "a", "x": [1, 2]}"#, 4, &mut sc)
            .is_err());
    }

    #[test]
    fn scratch_survives_and_resets_between_lines() {
        let mut sc = ReqScratch::new(2);
        let p1 = parse_request(br#"{"id": "one", "x": [1, 2]}"#, 2, &mut sc)
            .unwrap();
        assert_eq!(p1.body, ReqBody::Features);
        assert_eq!(sc.features, vec![1.0, 2.0]);
        // a following sample request clears the stale tensor and id
        let p2 = parse_request(br#"{"id": "two", "sample": 0}"#, 2, &mut sc)
            .unwrap();
        assert_eq!(p2.body, ReqBody::Sample(0));
        assert_eq!(sc.id, "two");
        assert!(sc.features.is_empty());
        assert_eq!(sc.features.capacity(), 2, "capacity is kept, not grown");
    }

    #[test]
    fn response_lines_roundtrip_through_the_tree_parser() {
        let mut out = String::new();
        let resp = Response {
            pred: 1,
            logits: vec![0.25, -1.5],
            latency: Duration::from_micros(120),
            sim_age_s: 25.0,
            adc_bits: 8,
        };
        write_response_line(&mut out, "a\"b", &resp);
        assert!(out.ends_with('\n'));
        let v = crate::util::json::parse(out.trim_end()).unwrap();
        assert_eq!(v.req("id").unwrap().as_str().unwrap(), "a\"b");
        assert!(v.req("ok").unwrap().as_bool().unwrap());
        assert_eq!(v.req("pred").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(v.req("logits").unwrap().f32s().unwrap(), vec![0.25, -1.5]);
        assert_eq!(v.req("sim_age_s").unwrap().as_f64().unwrap(), 25.0);
        assert_eq!(v.req("adc_bits").unwrap().as_f64().unwrap(), 8.0);
        assert_eq!(v.req("latency_us").unwrap().as_f64().unwrap(), 120.0);

        out.clear();
        write_error_line(&mut out, None, "bad\nline");
        let v = crate::util::json::parse(out.trim_end()).unwrap();
        assert!(!v.req("ok").unwrap().as_bool().unwrap());
        assert_eq!(v.req("error").unwrap().as_str().unwrap(), "bad\nline");
        assert_eq!(*v.req("id").unwrap(), crate::util::json::Json::Null);
    }
}
