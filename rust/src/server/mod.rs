//! Wire-protocol serving front end: a std-only TCP server speaking a
//! line-delimited JSON protocol in front of the
//! [`Coordinator`](crate::coordinator::Coordinator).
//!
//! ```text
//!   TCP clients (newline-delimited JSON)
//!        |  {"id":..., "x":[...] | "sample":N, "t_drift"?, "adc_bits"?}
//!        v
//!   listener (accept loop, max_conns)            server::listener
//!        v
//!   per-connection reader ──> writer             server::connection
//!        |  visiting JSON lexer, reusable        server::json
//!        |  scratch buffers (zero-alloc parse)   server::protocol
//!        v
//!   Coordinator::submit_with(features, InferOpts)
//! ```
//!
//! Requests are validated through the same `backend::validate_opts` /
//! `submit_with` path as in-process callers, so a wire request can do
//! exactly what an embedded caller can — per-request device age and ADC
//! bitwidth included — and nothing more. Responses echo the client id
//! plus `pred`, `logits`, `sim_age_s`, `adc_bits`, and `latency_us`
//! (coordinator-measured; wire time is on top).
//!
//! Robustness contract: a malformed or oversized request line is answered
//! with an `{"ok":false,...}` error line and the connection stays up; the
//! ingestion path performs no per-request heap allocation after warm-up
//! except the feature vector handed to the coordinator queue (see
//! [`connection`] module docs; pinned by `tests/test_wire.rs`). Wire
//! traffic shows up in the coordinator metrics as `wire_requests` /
//! `wire_rejects`.
//!
//! A listener can also front a multi-model router
//! ([`WireServer::start_multi`] over a
//! [`MultiCoordinator`](crate::coordinator::MultiCoordinator)): request
//! lines pick their model with an optional `"model"` field (default: the
//! primary model), unknown model ids get a structured error line, and a
//! single-model listener rejects the field outright rather than silently
//! ignoring it.

pub mod client;
mod connection;
pub mod json;
mod listener;
pub mod protocol;

pub use client::{WireClient, WireReply};
pub use listener::{WireConfig, WireServer};
