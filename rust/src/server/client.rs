//! Minimal blocking wire client: builds request lines, parses reply
//! lines. Used by the `--wire` load generator and the loopback tests;
//! also a reference implementation of the client side of the protocol.
//!
//! The client side is allowed to allocate (it models an external caller),
//! so replies are parsed with the tree-building [`crate::util::json`]
//! parser rather than the server's visiting reader.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};

use crate::util::json::{self, Json};

use crate::server::protocol::push_json_str;

/// One parsed reply line.
#[derive(Clone, Debug, Default)]
pub struct WireReply {
    pub id: String,
    pub ok: bool,
    /// set on `ok: false` lines
    pub error: Option<String>,
    pub pred: u32,
    pub logits: Vec<f32>,
    pub sim_age_s: f64,
    pub adc_bits: u32,
    pub latency_us: f64,
}

/// A connected client. Send and receive are independent (requests
/// pipeline; the server answers in request order), so `send_*` several
/// times before draining with `recv`.
pub struct WireClient {
    write: TcpStream,
    read: BufReader<TcpStream>,
    line: String,
    out: String,
}

impl WireClient {
    pub fn connect(addr: impl ToSocketAddrs) -> anyhow::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        let read = BufReader::new(stream.try_clone()?);
        Ok(WireClient {
            write: stream,
            read,
            line: String::new(),
            out: String::new(),
        })
    }

    /// Send a request carrying an explicit input tensor.
    pub fn send_x(&mut self, id: &str, x: &[f32], t_drift: Option<f64>,
                  adc_bits: Option<u32>) -> anyhow::Result<()> {
        self.send_x_model(id, None, x, t_drift, adc_bits)
    }

    /// Send a tensor request addressed to a named model on a multi-model
    /// server (`None` routes to the server's primary model).
    pub fn send_x_model(&mut self, id: &str, model: Option<&str>, x: &[f32],
                        t_drift: Option<f64>, adc_bits: Option<u32>)
                        -> anyhow::Result<()> {
        self.out.clear();
        build_x_line_for(&mut self.out, id, model, x, t_drift, adc_bits);
        self.write.write_all(self.out.as_bytes())?;
        Ok(())
    }

    /// Send a request referencing a server-side test-set sample.
    pub fn send_sample(&mut self, id: &str, sample: usize,
                       t_drift: Option<f64>, adc_bits: Option<u32>)
                       -> anyhow::Result<()> {
        use std::fmt::Write as _;
        self.out.clear();
        self.out.push_str("{\"id\":");
        push_json_str(&mut self.out, id);
        let _ = write!(self.out, ",\"sample\":{sample}");
        push_opts(&mut self.out, t_drift, adc_bits);
        self.out.push_str("}\n");
        self.write.write_all(self.out.as_bytes())?;
        Ok(())
    }

    /// Send a raw line verbatim (protocol tests; a trailing newline is
    /// added when missing).
    pub fn send_raw(&mut self, line: &str) -> anyhow::Result<()> {
        self.write.write_all(line.as_bytes())?;
        if !line.ends_with('\n') {
            self.write.write_all(b"\n")?;
        }
        Ok(())
    }

    /// Block for the next reply line.
    pub fn recv(&mut self) -> anyhow::Result<WireReply> {
        self.line.clear();
        let n = self.read.read_line(&mut self.line)?;
        anyhow::ensure!(n > 0, "server closed the connection");
        parse_reply(self.line.trim_end())
    }

    /// Convenience: one tensor request, wait for its reply.
    pub fn roundtrip_x(&mut self, id: &str, x: &[f32], t_drift: Option<f64>,
                       adc_bits: Option<u32>) -> anyhow::Result<WireReply> {
        self.send_x(id, x, t_drift, adc_bits)?;
        self.recv()
    }

    /// Convenience: one model-addressed tensor request, wait for its
    /// reply.
    pub fn roundtrip_x_model(&mut self, id: &str, model: Option<&str>,
                             x: &[f32], t_drift: Option<f64>,
                             adc_bits: Option<u32>)
                             -> anyhow::Result<WireReply> {
        self.send_x_model(id, model, x, t_drift, adc_bits)?;
        self.recv()
    }
}

/// Build a `{"id":..,"x":[..],...}` request line (newline-terminated)
/// into `out`. Public for the load generator, which paces raw writes
/// itself.
pub fn build_x_line(out: &mut String, id: &str, x: &[f32],
                    t_drift: Option<f64>, adc_bits: Option<u32>) {
    build_x_line_for(out, id, None, x, t_drift, adc_bits)
}

/// [`build_x_line`] with an optional `"model"` field for multi-model
/// servers (`None` omits the field, routing to the primary model).
pub fn build_x_line_for(out: &mut String, id: &str, model: Option<&str>,
                        x: &[f32], t_drift: Option<f64>,
                        adc_bits: Option<u32>) {
    use std::fmt::Write as _;
    out.push_str("{\"id\":");
    push_json_str(out, id);
    if let Some(m) = model {
        out.push_str(",\"model\":");
        push_json_str(out, m);
    }
    out.push_str(",\"x\":[");
    for (i, v) in x.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{v}");
    }
    out.push(']');
    push_opts(out, t_drift, adc_bits);
    out.push_str("}\n");
}

fn push_opts(out: &mut String, t_drift: Option<f64>, adc_bits: Option<u32>) {
    use std::fmt::Write as _;
    if let Some(t) = t_drift {
        let _ = write!(out, ",\"t_drift\":{t}");
    }
    if let Some(b) = adc_bits {
        let _ = write!(out, ",\"adc_bits\":{b}");
    }
}

/// Parse one reply line (without its trailing newline).
pub fn parse_reply(line: &str) -> anyhow::Result<WireReply> {
    let v = json::parse(line)
        .map_err(|e| anyhow::anyhow!("bad reply line {line:?}: {e}"))?;
    let id = match v.get("id") {
        Some(Json::Str(s)) => s.clone(),
        Some(Json::Num(n)) => format!("{n}"),
        _ => String::new(),
    };
    let ok = v.req("ok")?.as_bool()?;
    if !ok {
        return Ok(WireReply {
            id,
            ok,
            error: Some(v.req("error")?.as_str()?.to_string()),
            ..Default::default()
        });
    }
    Ok(WireReply {
        id,
        ok,
        error: None,
        pred: v.req("pred")?.as_f64()? as u32,
        logits: v.req("logits")?.f32s()?,
        sim_age_s: v.req("sim_age_s")?.as_f64()?,
        adc_bits: v.req("adc_bits")?.as_f64()? as u32,
        latency_us: v.req("latency_us")?.as_f64()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_lines_parse_back_as_requests() {
        let mut out = String::new();
        build_x_line(&mut out, "c1-9", &[0.25, -1.5], Some(86_400.0), Some(4));
        let mut sc = crate::server::protocol::ReqScratch::new(2);
        let p = crate::server::protocol::parse_request(
            out.trim_end().as_bytes(), 2, &mut sc)
            .unwrap();
        assert_eq!(sc.id, "c1-9");
        assert_eq!(sc.features, vec![0.25, -1.5]);
        assert_eq!(p.t_drift, Some(86_400.0));
        assert_eq!(p.adc_bits, Some(4));
    }

    #[test]
    fn model_addressed_lines_carry_the_field() {
        let mut out = String::new();
        build_x_line_for(&mut out, "w1", Some("vww"), &[1.0, 2.0], None, None);
        let mut sc = crate::server::protocol::ReqScratch::new(2);
        let p = crate::server::protocol::parse_request_cap(
            out.trim_end().as_bytes(), 2, &mut sc)
            .unwrap();
        assert!(p.has_model);
        assert_eq!(sc.model, "vww");
        assert_eq!(sc.features, vec![1.0, 2.0]);
        // None omits the field entirely (identical to build_x_line)
        let mut plain = String::new();
        build_x_line_for(&mut plain, "w1", None, &[1.0, 2.0], None, None);
        let mut reference = String::new();
        build_x_line(&mut reference, "w1", &[1.0, 2.0], None, None);
        assert_eq!(plain, reference);
        assert!(!plain.contains("model"));
    }

    #[test]
    fn reply_parser_handles_both_shapes() {
        let ok = parse_reply(
            r#"{"id":"a","ok":true,"pred":2,"logits":[0.5,1.5,-2],"sim_age_s":25,"adc_bits":8,"latency_us":310.5}"#,
        )
        .unwrap();
        assert!(ok.ok);
        assert_eq!(ok.id, "a");
        assert_eq!(ok.pred, 2);
        assert_eq!(ok.logits, vec![0.5, 1.5, -2.0]);
        assert_eq!(ok.adc_bits, 8);

        let err = parse_reply(r#"{"id":null,"ok":false,"error":"nope"}"#).unwrap();
        assert!(!err.ok);
        assert_eq!(err.error.as_deref(), Some("nope"));
        assert!(err.id.is_empty());
    }
}
