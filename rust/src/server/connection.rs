//! One accepted connection: a reader thread that lexes request lines and
//! submits them, and a writer thread that streams the responses back.
//!
//! The split keeps the protocol pipelined: the reader never blocks on a
//! response, so a client may keep many requests in flight on one
//! connection; the writer answers them **in request order** (each job
//! blocks on its own reply channel before the next), so per-connection
//! FIFO holds even when the coordinator finishes launches out of order.
//!
//! Allocation discipline on the read path: the line buffer, the JSON
//! scratch, the feature buffer, and the id string are all per-connection
//! and reused; response ids are recycled back from the writer over a
//! freelist channel. After warm-up the per-request costs that remain are
//! the feature vector handed to the coordinator queue (`submit_with`
//! takes ownership) and the reply channel inside the coordinator — both
//! identical to what an in-process `submit_with` caller pays. Malformed
//! and oversized lines are answered with an error line and never
//! terminate the connection.

use std::borrow::Cow;
use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};

use crate::coordinator::{Coordinator, Metrics, MultiCoordinator, Response};
use crate::datasets::Dataset;
use crate::server::protocol::{self, ReqBody, ReqScratch};

/// What this listener fronts: one coordinator, or a multi-model router.
pub(super) enum ServeTarget {
    /// classic single-model serving: lines carrying `"model"` are
    /// rejected so a client cannot silently assume routing that is not
    /// there
    Single {
        coord: Arc<Coordinator>,
        /// test set for `"sample"` requests (absent: such requests error)
        dataset: Option<Arc<Dataset>>,
    },
    /// multi-model serving: `"model"` picks the shard (default: primary,
    /// index 0); one optional dataset per model, in `models()` order
    Multi {
        mc: Arc<MultiCoordinator>,
        datasets: Vec<Option<Arc<Dataset>>>,
    },
}

impl ServeTarget {
    pub(super) fn metrics(&self) -> &Metrics {
        match self {
            ServeTarget::Single { coord, .. } => &coord.metrics,
            ServeTarget::Multi { mc, .. } => &mc.metrics,
        }
    }

    /// Parse-time feature capacity: the largest served feature length
    /// (per-model exactness is checked after routing).
    fn feat_cap(&self) -> usize {
        match self {
            ServeTarget::Single { coord, .. } => coord.feat_len,
            ServeTarget::Multi { mc, .. } => mc
                .models()
                .iter()
                .map(|m| m.feat_len)
                .max()
                .unwrap_or(0),
        }
    }
}

/// Connection-independent serving state, shared by every reader.
pub(super) struct ConnShared {
    pub target: ServeTarget,
    /// request lines above this many bytes are rejected with an error
    /// line — the line buffer never grows past it, so a hostile client
    /// cannot OOM the server
    pub max_line_bytes: usize,
}

/// One response job for the writer, in request order.
enum Job {
    Reply { id: String, rx: mpsc::Receiver<Response> },
    Error { id: Option<String>, msg: Cow<'static, str> },
}

/// Serve one accepted connection to completion (client close, fatal IO
/// error, or server shutdown via `TcpStream::shutdown` on a clone).
pub(super) fn run_connection(stream: TcpStream, shared: Arc<ConnShared>) {
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let _ = stream.set_nodelay(true);
    let (job_tx, job_rx) = mpsc::channel::<Job>();
    let (free_tx, free_rx) = mpsc::channel::<String>();
    let writer = std::thread::Builder::new()
        .name("wire-writer".into())
        .spawn(move || writer_loop(write_half, job_rx, free_tx));
    reader_loop(stream, &shared, &job_tx, &free_rx);
    // closing the job channel lets the writer drain pending replies, then
    // exit; join it so the connection slot only frees once both halves
    // are done
    drop(job_tx);
    if let Ok(w) = writer {
        let _ = w.join();
    }
}

fn reader_loop(mut stream: TcpStream, sh: &ConnShared,
               jobs: &mpsc::Sender<Job>, free: &mpsc::Receiver<String>) {
    let mut scratch = ReqScratch::new(sh.target.feat_cap());
    let mut line: Vec<u8> = Vec::with_capacity(sh.max_line_bytes.min(64 * 1024));
    let mut chunk = [0u8; 4096];
    let mut oversized = false;
    loop {
        let n = match stream.read(&mut chunk) {
            Ok(0) => return,
            Ok(n) => n,
            Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return,
        };
        for &b in &chunk[..n] {
            if b == b'\n' {
                let alive = if oversized {
                    oversized = false;
                    let m = sh.target.metrics();
                    m.wire_requests.fetch_add(1, Ordering::Relaxed);
                    m.wire_rejects.fetch_add(1, Ordering::Relaxed);
                    jobs.send(Job::Error {
                        id: None,
                        msg: Cow::Borrowed(
                            "request line exceeds max_line_bytes"),
                    })
                    .is_ok()
                } else {
                    handle_line(&line, sh, &mut scratch, jobs, free)
                };
                line.clear();
                if !alive {
                    return; // writer gone: the client hung up
                }
            } else if line.len() >= sh.max_line_bytes {
                // cap reached: stop buffering, remember to reject at the
                // newline — the line buffer itself never grows further
                oversized = true;
            } else {
                line.push(b);
            }
        }
    }
}

/// Parse + dispatch one complete line. Returns false when the writer is
/// gone and the connection should wind down.
fn handle_line(line: &[u8], sh: &ConnShared, scratch: &mut ReqScratch,
               jobs: &mpsc::Sender<Job>, free: &mpsc::Receiver<String>)
               -> bool {
    let line = match line {
        [head @ .., b'\r'] => head,
        l => l,
    };
    if line.is_empty() {
        return true; // blank keep-alive line (e.g. an interactive `nc`)
    }
    let m = sh.target.metrics();
    m.wire_requests.fetch_add(1, Ordering::Relaxed);

    let parsed = match &sh.target {
        // single-model: exact feature length enforced at parse time (the
        // zero-alloc path, unchanged)
        ServeTarget::Single { coord, .. } => {
            protocol::parse_request(line, coord.feat_len, scratch)
        }
        // multi-model: capacity bound only — the exact length depends on
        // which model the line routes to
        ServeTarget::Multi { .. } => {
            protocol::parse_request_cap(line, sh.target.feat_cap(), scratch)
        }
    };
    let parsed = match parsed {
        Ok(p) => p,
        Err(e) => {
            m.wire_rejects.fetch_add(1, Ordering::Relaxed);
            // echo the id when the line got far enough to carry one
            let id = (!scratch.id.is_empty()).then(|| take_id(scratch, free));
            return jobs
                .send(Job::Error { id, msg: Cow::Owned(e.to_string()) })
                .is_ok();
        }
    };

    // route: which model serves this line, with its exact feature length
    // and its dataset for `"sample"` requests
    let routed: Result<(usize, usize, Option<&Arc<Dataset>>), Cow<'static, str>> =
        match &sh.target {
            ServeTarget::Single { coord, dataset } => {
                if parsed.has_model {
                    Err(Cow::Borrowed(
                        "`model` is not accepted here: this server fronts a \
                         single model"))
                } else {
                    Ok((0, coord.feat_len, dataset.as_ref()))
                }
            }
            ServeTarget::Multi { mc, datasets } => {
                let idx = if parsed.has_model {
                    mc.model_index(&scratch.model)
                } else {
                    Some(0) // default route: the primary model
                };
                match idx {
                    Some(i) => {
                        let want = mc.models()[i].feat_len;
                        if parsed.body == ReqBody::Features
                            && scratch.features.len() != want
                        {
                            Err(Cow::Owned(format!(
                                "`x` has {} values but model `{}` wants {}",
                                scratch.features.len(),
                                mc.models()[i].model_id, want)))
                        } else {
                            Ok((i, want, datasets[i].as_ref()))
                        }
                    }
                    None => {
                        let ids: Vec<&str> = mc
                            .models()
                            .iter()
                            .map(|mi| mi.model_id.as_str())
                            .collect();
                        Err(Cow::Owned(format!(
                            "unknown model `{}` (serving: {})",
                            scratch.model, ids.join(", "))))
                    }
                }
            }
        };
    let (model_idx, _feat_len, dataset) = match routed {
        Ok(r) => r,
        Err(msg) => {
            m.wire_rejects.fetch_add(1, Ordering::Relaxed);
            let id = Some(take_id(scratch, free));
            return jobs.send(Job::Error { id, msg }).is_ok();
        }
    };

    // resolve the input tensor: queue ownership of the feature vector is
    // the one deliberate per-request allocation on this path (see module
    // docs); the parse scratch keeps its capacity either way
    let features: Vec<f32> = match parsed.body {
        ReqBody::Features => scratch.features.clone(),
        ReqBody::Sample(s) => match dataset {
            None => {
                m.wire_rejects.fetch_add(1, Ordering::Relaxed);
                let id = Some(take_id(scratch, free));
                return jobs
                    .send(Job::Error {
                        id,
                        msg: Cow::Borrowed(
                            "no dataset loaded for `sample` requests"),
                    })
                    .is_ok();
            }
            Some(ds) if s >= ds.len() => {
                m.wire_rejects.fetch_add(1, Ordering::Relaxed);
                let id = Some(take_id(scratch, free));
                return jobs
                    .send(Job::Error {
                        id,
                        msg: Cow::Borrowed("`sample` index out of range"),
                    })
                    .is_ok();
            }
            Some(ds) => ds.batch(s, s + 1).to_vec(),
        },
    };

    let id = take_id(scratch, free);
    // submit-time rejects (bad options, full shard queue, stopped
    // coordinator) are counted by the coordinator itself as
    // `submit_rejects` (and per model on the router)
    let submitted = match &sh.target {
        ServeTarget::Single { coord, .. } => {
            coord.submit_with(features, parsed.opts())
        }
        ServeTarget::Multi { mc, .. } => {
            mc.submit_to(model_idx, features, parsed.opts())
        }
    };
    match submitted {
        Ok(rx) => jobs.send(Job::Reply { id, rx }).is_ok(),
        Err(e) => jobs
            .send(Job::Error { id: Some(id), msg: Cow::Owned(format!("{e:#}")) })
            .is_ok(),
    }
}

/// Move the parsed id out of the scratch, replacing it with a recycled
/// id string from the writer's freelist (or a fresh empty one when the
/// writer is momentarily behind).
fn take_id(scratch: &mut ReqScratch, free: &mpsc::Receiver<String>) -> String {
    let mut repl = free.try_recv().unwrap_or_default();
    repl.clear();
    std::mem::replace(&mut scratch.id, repl)
}

fn writer_loop(mut stream: TcpStream, jobs: mpsc::Receiver<Job>,
               free: mpsc::Sender<String>) {
    let mut out = String::with_capacity(512);
    while let Ok(job) = jobs.recv() {
        out.clear();
        let sent = match job {
            Job::Reply { id, rx } => {
                match rx.recv() {
                    Ok(resp) => protocol::write_response_line(&mut out, &id, &resp),
                    Err(_) => protocol::write_error_line(
                        &mut out, Some(&id), "coordinator dropped the request"),
                }
                let ok = stream.write_all(out.as_bytes()).is_ok();
                let _ = free.send(id);
                ok
            }
            Job::Error { id, msg } => {
                protocol::write_error_line(&mut out, id.as_deref(), &msg);
                let ok = stream.write_all(out.as_bytes()).is_ok();
                if let Some(id) = id {
                    let _ = free.send(id);
                }
                ok
            }
        };
        if !sent {
            break; // client gone: unblock the reader too
        }
    }
    let _ = stream.shutdown(Shutdown::Both);
}
