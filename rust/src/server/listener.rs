//! The TCP front door: a nonblocking accept loop handing connections to
//! reader/writer thread pairs, bounded by `max_conns`, with a graceful
//! shutdown that unblocks every in-flight reader.

use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::{Coordinator, MultiCoordinator};
use crate::datasets::Dataset;
use crate::server::connection::{self, ConnShared, ServeTarget};

/// Wire-server knobs (the coordinator's own knobs live in `ServeConfig`).
#[derive(Clone, Debug)]
pub struct WireConfig {
    /// bind address, e.g. `127.0.0.1:7878` (`:0` picks a free port —
    /// read it back via [`WireServer::local_addr`])
    pub listen: String,
    /// concurrent connection cap: connection `max_conns + 1` is answered
    /// with one error line and closed
    pub max_conns: usize,
    /// per-request-line byte cap (reject with an error line, never OOM)
    pub max_line_bytes: usize,
}

impl Default for WireConfig {
    fn default() -> Self {
        WireConfig {
            listen: "127.0.0.1:0".to_string(),
            max_conns: 64,
            max_line_bytes: 256 * 1024,
        }
    }
}

/// One live connection as the accept loop tracks it: a stream clone to
/// shut down on server stop, and the reader thread to join.
struct ConnSlot {
    stream: TcpStream,
    handle: JoinHandle<()>,
}

struct Inner {
    stop: AtomicBool,
    active: AtomicUsize,
    max_conns: usize,
    conns: Mutex<Vec<ConnSlot>>,
    shared: Arc<ConnShared>,
}

/// A running wire-protocol server. Owns the accept loop; the coordinator
/// stays caller-owned (shared in via `Arc`), so one process can front the
/// same coordinator with several listeners or mix wire and in-process
/// traffic.
pub struct WireServer {
    local_addr: SocketAddr,
    inner: Arc<Inner>,
    accept: Option<JoinHandle<()>>,
}

impl WireServer {
    /// Bind `cfg.listen` and start accepting. `dataset` backs `"sample"`
    /// requests (pass `None` to reject them).
    pub fn start(coord: Arc<Coordinator>, dataset: Option<Arc<Dataset>>,
                 cfg: WireConfig) -> anyhow::Result<WireServer> {
        Self::start_target(ServeTarget::Single { coord, dataset }, cfg)
    }

    /// Bind `cfg.listen` in front of a multi-model router: request lines
    /// pick their model with `"model"` (default: the primary). `datasets`
    /// backs `"sample"` requests per model, in
    /// [`MultiCoordinator::models`] order — it must have exactly one
    /// entry per served model.
    pub fn start_multi(mc: Arc<MultiCoordinator>,
                       datasets: Vec<Option<Arc<Dataset>>>, cfg: WireConfig)
                       -> anyhow::Result<WireServer> {
        anyhow::ensure!(
            datasets.len() == mc.models().len(),
            "need one dataset slot per served model ({} models, {} slots)",
            mc.models().len(),
            datasets.len()
        );
        Self::start_target(ServeTarget::Multi { mc, datasets }, cfg)
    }

    fn start_target(target: ServeTarget, cfg: WireConfig)
                    -> anyhow::Result<WireServer> {
        let listener = TcpListener::bind(&cfg.listen)
            .map_err(|e| anyhow::anyhow!("bind {}: {e}", cfg.listen))?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let inner = Arc::new(Inner {
            stop: AtomicBool::new(false),
            active: AtomicUsize::new(0),
            max_conns: cfg.max_conns.max(1),
            conns: Mutex::new(Vec::new()),
            shared: Arc::new(ConnShared {
                target,
                max_line_bytes: cfg.max_line_bytes.max(2),
            }),
        });
        let i2 = inner.clone();
        let accept = std::thread::Builder::new()
            .name("wire-accept".into())
            .spawn(move || accept_loop(listener, i2))?;
        Ok(WireServer { local_addr, inner, accept: Some(accept) })
    }

    /// The bound address (resolves `:0` to the picked port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Connections currently being served.
    pub fn active_connections(&self) -> usize {
        self.inner.active.load(Ordering::Acquire)
    }

    /// Stop accepting, unblock every connection, and join all threads.
    /// In-flight requests still receive their response lines (the writer
    /// drains before exiting). Idempotent; also runs on drop. Stopping
    /// the *coordinator* is the caller's call — pair this with
    /// [`Coordinator::request_stop`] for a full graceful stop.
    pub fn shutdown(&mut self) {
        self.inner.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for WireServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, inner: Arc<Inner>) {
    loop {
        if inner.stop.load(Ordering::Acquire) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nonblocking(false);
                reap_finished(&inner);
                if inner.active.load(Ordering::Acquire) >= inner.max_conns {
                    refuse(stream, &inner);
                    continue;
                }
                spawn_connection(stream, &inner);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => std::thread::sleep(Duration::from_millis(2)),
        }
    }
    // shutdown: force every blocked reader out of `read`, then join
    for slot in inner.conns.lock().unwrap().drain(..) {
        let _ = slot.stream.shutdown(Shutdown::Both);
        let _ = slot.handle.join();
    }
}

fn spawn_connection(stream: TcpStream, inner: &Arc<Inner>) {
    let clone = match stream.try_clone() {
        Ok(c) => c,
        Err(_) => return,
    };
    inner.active.fetch_add(1, Ordering::AcqRel);
    let sh = inner.shared.clone();
    let i2 = inner.clone();
    let spawned = std::thread::Builder::new()
        .name("wire-conn".into())
        .spawn(move || {
            connection::run_connection(stream, sh);
            i2.active.fetch_sub(1, Ordering::AcqRel);
        });
    match spawned {
        Ok(handle) => inner
            .conns
            .lock()
            .unwrap()
            .push(ConnSlot { stream: clone, handle }),
        Err(_) => {
            inner.active.fetch_sub(1, Ordering::AcqRel);
        }
    }
}

/// Over the connection cap: answer with one error line and close (the
/// client sees a structured reason, not a silent RST).
fn refuse(mut stream: TcpStream, inner: &Inner) {
    let m = inner.shared.target.metrics();
    m.wire_requests.fetch_add(1, Ordering::Relaxed);
    m.wire_rejects.fetch_add(1, Ordering::Relaxed);
    let line = format!(
        "{{\"id\":null,\"ok\":false,\"error\":\"server at connection limit \
         (max_conns={})\"}}\n",
        inner.max_conns
    );
    let _ = stream.write_all(line.as_bytes());
    let _ = stream.shutdown(Shutdown::Both);
}

/// Join reader threads whose connections already ended, so long-running
/// servers do not accumulate dead slots.
fn reap_finished(inner: &Inner) {
    let mut conns = inner.conns.lock().unwrap();
    let mut i = 0;
    while i < conns.len() {
        if conns[i].handle.is_finished() {
            let slot = conns.swap_remove(i);
            let _ = slot.handle.join();
        } else {
            i += 1;
        }
    }
}
