//! Visiting/callback JSON reader for the wire protocol (the SNIPPETS §1
//! idiom: a small dependency-free lexer with a visiting API instead of a
//! tree builder).
//!
//! [`read_object`] lexes one request line and invokes [`Visit`] callbacks
//! as fields stream by; nothing is ever boxed into a `Json` tree. Values
//! reach the visitor as borrows:
//!
//! * escape-free strings are borrowed straight from the input line;
//! * escaped strings are decoded into a caller-owned, reusable
//!   [`Scratch`] buffer (capacity survives across lines);
//! * numbers are parsed in place from the input bytes.
//!
//! After the scratch buffers have warmed up, lexing a line performs **no
//! heap allocation** — the property `tests/test_wire.rs` pins down with a
//! counting global allocator. Contrast with [`crate::util::json`], the
//! tree-building parser used for artifacts and the client side, which
//! allocates per node.
//!
//! The grammar is deliberately the wire subset, not full JSON: one
//! top-level object whose values are strings, numbers, booleans, null, or
//! flat arrays of numbers. Nested objects/arrays are rejected with a
//! [`ParseError`] — the request protocol never needs them, and refusing
//! them keeps the reader single-pass with zero lookahead state.

/// Position marker for errors raised by a [`Visit`] implementation (the
/// visitor does not know byte offsets; [`read_object`] fills in the
/// lexer's position before the error escapes).
const NO_POS: usize = usize::MAX;

/// A lex or protocol error for one line: a static message plus the byte
/// offset it was detected at. `Copy` and allocation-free, so malformed
/// input costs nothing to reject.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParseError {
    pub msg: &'static str,
    pub at: usize,
}

impl ParseError {
    /// An error raised by a visitor callback (position filled in by the
    /// lexer).
    pub fn msg(msg: &'static str) -> Self {
        ParseError { msg, at: NO_POS }
    }
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.at == NO_POS {
            write!(f, "{}", self.msg)
        } else {
            write!(f, "{} (byte {})", self.msg, self.at)
        }
    }
}

/// Reusable string-decode buffers: one for the current key, one for the
/// current value, so an escaped key and an escaped value can be borrowed
/// simultaneously. Owned per connection and reused line after line.
#[derive(Debug, Default)]
pub struct Scratch {
    key: String,
    val: String,
}

impl Scratch {
    pub fn new() -> Self {
        Scratch::default()
    }
}

/// One scalar field value, borrowed from the line or the scratch buffer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Scalar<'a> {
    Str(&'a str),
    Num(f64),
    Bool(bool),
    Null,
}

/// The field callbacks. Implementations write into their own reusable
/// state (e.g. push array numbers into a preallocated `Vec<f32>`) and may
/// reject a field with [`ParseError::msg`], which aborts the line.
pub trait Visit {
    /// A scalar field: `"key": value`.
    fn scalar(&mut self, key: &str, val: Scalar<'_>) -> Result<(), ParseError>;

    /// Start of an array field: `"key": [` — called before any element,
    /// including for empty arrays.
    fn begin_array(&mut self, key: &str) -> Result<(), ParseError>;

    /// One element of an array field (arrays carry numbers only on the
    /// wire).
    fn array_num(&mut self, key: &str, val: f64) -> Result<(), ParseError>;
}

/// Lex one line holding a single flat JSON object, invoking `v` per field.
/// Trailing whitespace is allowed; any other trailing bytes are an error.
pub fn read_object(line: &[u8], scratch: &mut Scratch, v: &mut dyn Visit)
                   -> Result<(), ParseError> {
    let mut lx = Lexer { b: line, i: 0 };
    lx.ws();
    lx.expect(b'{', "expected `{`")?;
    lx.ws();
    if lx.peek() == Some(b'}') {
        lx.i += 1;
    } else {
        loop {
            lx.ws();
            let key = lx.parse_string(&mut scratch.key)?;
            lx.ws();
            lx.expect(b':', "expected `:` after key")?;
            lx.ws();
            match lx.peek().ok_or(ParseError { msg: "truncated value", at: lx.i })? {
                b'"' => {
                    let s = lx.parse_string(&mut scratch.val)?;
                    v.scalar(key, Scalar::Str(s)).map_err(|e| lx.locate(e))?;
                }
                b't' => {
                    lx.lit(b"true")?;
                    v.scalar(key, Scalar::Bool(true)).map_err(|e| lx.locate(e))?;
                }
                b'f' => {
                    lx.lit(b"false")?;
                    v.scalar(key, Scalar::Bool(false)).map_err(|e| lx.locate(e))?;
                }
                b'n' => {
                    lx.lit(b"null")?;
                    v.scalar(key, Scalar::Null).map_err(|e| lx.locate(e))?;
                }
                b'[' => {
                    lx.i += 1;
                    v.begin_array(key).map_err(|e| lx.locate(e))?;
                    lx.ws();
                    if lx.peek() == Some(b']') {
                        lx.i += 1;
                    } else {
                        loop {
                            lx.ws();
                            let n = lx.parse_number()?;
                            v.array_num(key, n).map_err(|e| lx.locate(e))?;
                            lx.ws();
                            match lx.bump()? {
                                b',' => continue,
                                b']' => break,
                                _ => {
                                    return Err(lx.err_back(
                                        "expected `,` or `]` in array \
                                         (arrays carry numbers only)",
                                    ))
                                }
                            }
                        }
                    }
                }
                b'{' => {
                    return Err(lx.err("nested objects are not supported \
                                       on the wire"))
                }
                _ => {
                    let n = lx.parse_number()?;
                    v.scalar(key, Scalar::Num(n)).map_err(|e| lx.locate(e))?;
                }
            }
            lx.ws();
            match lx.bump()? {
                b',' => continue,
                b'}' => break,
                _ => return Err(lx.err_back("expected `,` or `}`")),
            }
        }
    }
    lx.ws();
    if lx.i != line.len() {
        return Err(lx.err("trailing bytes after object"));
    }
    Ok(())
}

struct Lexer<'b> {
    b: &'b [u8],
    i: usize,
}

impl<'b> Lexer<'b> {
    fn ws(&mut self) {
        while matches!(self.b.get(self.i), Some(b' ' | b'\t' | b'\r')) {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn err(&self, msg: &'static str) -> ParseError {
        ParseError { msg, at: self.i }
    }

    /// Error at the byte just consumed.
    fn err_back(&self, msg: &'static str) -> ParseError {
        ParseError { msg, at: self.i.saturating_sub(1) }
    }

    /// Fill a visitor error's position in.
    fn locate(&self, mut e: ParseError) -> ParseError {
        if e.at == NO_POS {
            e.at = self.i;
        }
        e
    }

    fn bump(&mut self) -> Result<u8, ParseError> {
        let c = self.peek().ok_or_else(|| self.err("unexpected end of input"))?;
        self.i += 1;
        Ok(c)
    }

    fn expect(&mut self, c: u8, msg: &'static str) -> Result<(), ParseError> {
        if self.peek() != Some(c) {
            return Err(self.err(msg));
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, lit: &'static [u8]) -> Result<(), ParseError> {
        if self.b[self.i..].starts_with(lit) {
            self.i += lit.len();
            Ok(())
        } else {
            Err(self.err("bad literal"))
        }
    }

    /// Parse a string: escape-free strings are borrowed from the line,
    /// escaped ones are decoded into `scratch` (cleared, capacity kept).
    fn parse_string<'s>(&mut self, scratch: &'s mut String)
                        -> Result<&'s str, ParseError>
    where
        'b: 's,
    {
        self.expect(b'"', "expected string")?;
        let b = self.b;
        let start = self.i;
        loop {
            match b.get(self.i) {
                None => return Err(ParseError { msg: "unterminated string",
                                                at: self.i }),
                Some(b'"') => {
                    let s = std::str::from_utf8(&b[start..self.i]).map_err(
                        |_| ParseError { msg: "invalid utf-8 in string",
                                         at: start },
                    )?;
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => break,
                Some(c) if *c < 0x20 => {
                    return Err(self.err("raw control byte in string"))
                }
                Some(_) => self.i += 1,
            }
        }
        // slow path: escapes — decode into the reusable scratch buffer
        scratch.clear();
        scratch.push_str(std::str::from_utf8(&b[start..self.i]).map_err(
            |_| ParseError { msg: "invalid utf-8 in string", at: start },
        )?);
        loop {
            let c = *b.get(self.i).ok_or(ParseError {
                msg: "unterminated string",
                at: self.i,
            })?;
            self.i += 1;
            match c {
                b'"' => return Ok(&*scratch),
                b'\\' => {
                    let e = *b.get(self.i).ok_or(ParseError {
                        msg: "truncated escape",
                        at: self.i,
                    })?;
                    self.i += 1;
                    match e {
                        b'"' => scratch.push('"'),
                        b'\\' => scratch.push('\\'),
                        b'/' => scratch.push('/'),
                        b'n' => scratch.push('\n'),
                        b't' => scratch.push('\t'),
                        b'r' => scratch.push('\r'),
                        b'b' => scratch.push('\u{8}'),
                        b'f' => scratch.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            scratch.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err_back("bad escape")),
                    }
                }
                c if c < 0x20 => {
                    return Err(self.err_back("raw control byte in string"))
                }
                c => {
                    let s0 = self.i - 1;
                    let len = utf8_len(c);
                    if s0 + len > b.len() {
                        return Err(self.err("truncated utf-8 sequence"));
                    }
                    self.i = s0 + len;
                    scratch.push_str(std::str::from_utf8(&b[s0..self.i]).map_err(
                        |_| ParseError { msg: "invalid utf-8 in string",
                                         at: s0 },
                    )?);
                }
            }
        }
    }

    /// Parse a number in place (no allocation: the digits are sliced from
    /// the line and handed to the std float parser).
    fn parse_number(&mut self) -> Result<f64, ParseError> {
        let start = self.i;
        while matches!(
            self.b.get(self.i),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.i += 1;
        }
        if start == self.i {
            return Err(self.err("expected a number"));
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| ParseError { msg: "bad number", at: start })?;
        s.parse::<f64>()
            .map_err(|_| ParseError { msg: "bad number", at: start })
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Test visitor: records every event as a string (test-only
    /// allocation; the production visitor in `protocol` writes into
    /// preallocated buffers instead).
    #[derive(Default)]
    struct Rec {
        events: Vec<String>,
    }

    impl Visit for Rec {
        fn scalar(&mut self, key: &str, val: Scalar<'_>) -> Result<(), ParseError> {
            self.events.push(match val {
                Scalar::Str(s) => format!("{key}=str:{s}"),
                Scalar::Num(n) => format!("{key}=num:{n}"),
                Scalar::Bool(b) => format!("{key}=bool:{b}"),
                Scalar::Null => format!("{key}=null"),
            });
            Ok(())
        }
        fn begin_array(&mut self, key: &str) -> Result<(), ParseError> {
            self.events.push(format!("{key}=["));
            Ok(())
        }
        fn array_num(&mut self, key: &str, val: f64) -> Result<(), ParseError> {
            self.events.push(format!("{key}+{val}"));
            Ok(())
        }
    }

    fn run(src: &str) -> Result<Vec<String>, ParseError> {
        let mut sc = Scratch::new();
        let mut r = Rec::default();
        read_object(src.as_bytes(), &mut sc, &mut r)?;
        Ok(r.events)
    }

    #[test]
    fn flat_object_all_value_kinds() {
        let ev = run(r#"{"a": "x", "b": -2.5e1, "c": true, "d": null, "e": [1, 2.5]}"#)
            .unwrap();
        assert_eq!(ev, vec!["a=str:x", "b=num:-25", "c=bool:true", "d=null",
                            "e=[", "e+1", "e+2.5"]);
    }

    #[test]
    fn empty_object_and_empty_array() {
        assert_eq!(run("{}").unwrap(), Vec::<String>::new());
        assert_eq!(run(r#"{"x": []}"#).unwrap(), vec!["x=["]);
    }

    #[test]
    fn escapes_decode_into_scratch() {
        let ev = run(r#"{"k\"ey": "a\\b\ncA ☕"}"#).unwrap();
        assert_eq!(ev, vec!["k\"ey=str:a\\b\ncA ☕"]);
        // \uXXXX decodes to the code point (here 'A')
        let ev = run("{\"u\": \"\\u0041é\"}").unwrap();
        assert_eq!(ev, vec!["u=str:Aé"]);
        // invalid escapes are rejected, not smuggled through
        assert!(run(r#"{"a": "\q"}"#).is_err());
        assert!(run(r#"{"a": "\u00zz"}"#).is_err());
    }

    #[test]
    fn numbers_parse_in_place() {
        let ev = run(r#"{"i": 7, "f": 0.125, "e": 1e3, "n": -0.5}"#).unwrap();
        assert_eq!(ev, vec!["i=num:7", "f=num:0.125", "e=num:1000", "n=num:-0.5"]);
        assert!(run(r#"{"bad": 1.2.3}"#).is_err());
        assert!(run(r#"{"bad": --1}"#).is_err());
    }

    #[test]
    fn truncated_input_is_an_error_not_a_panic() {
        for src in ["", "{", r#"{"a""#, r#"{"a":"#, r#"{"a":1"#, r#"{"a":"x"#,
                    r#"{"a":[1"#, r#"{"a":[1,"#, r#"{"a":"x\"#, r#"{"a":"\u00"#] {
            assert!(run(src).is_err(), "accepted truncated input {src:?}");
        }
    }

    #[test]
    fn rejects_nesting_and_trailing_garbage() {
        assert!(run(r#"{"a": {"b": 1}}"#).is_err());
        assert!(run(r#"{"a": [[1]]}"#).is_err());
        assert!(run(r#"{"a": ["x"]}"#).is_err());
        assert!(run(r#"{"a": 1} extra"#).is_err());
        // trailing whitespace (e.g. a stripped \r) is fine
        assert!(run("{\"a\": 1} \t").is_ok());
    }

    #[test]
    fn visitor_errors_carry_a_position() {
        struct Nope;
        impl Visit for Nope {
            fn scalar(&mut self, _: &str, _: Scalar<'_>) -> Result<(), ParseError> {
                Err(ParseError::msg("visitor said no"))
            }
            fn begin_array(&mut self, _: &str) -> Result<(), ParseError> {
                Ok(())
            }
            fn array_num(&mut self, _: &str, _: f64) -> Result<(), ParseError> {
                Ok(())
            }
        }
        let e = read_object(br#"{"a": 1}"#, &mut Scratch::new(), &mut Nope)
            .unwrap_err();
        assert_eq!(e.msg, "visitor said no");
        assert_ne!(e.at, super::NO_POS, "position must be filled in");
        assert!(e.to_string().contains("byte"));
    }

    #[test]
    fn scratch_is_reused_across_lines() {
        let mut sc = Scratch::new();
        let mut r = Rec::default();
        read_object(br#"{"a": "x\ny"}"#, &mut sc, &mut r).unwrap();
        read_object(br#"{"a": "p\tq"}"#, &mut sc, &mut r).unwrap();
        assert_eq!(r.events, vec!["a=str:x\ny", "a=str:p\tq"]);
    }
}
