//! Serving metrics: counters, throughput clock, latency reservoir, and
//! the modeled accelerator energy ledger (launches priced by the
//! launch-schedule estimator, plus refresh/reprogram overhead events).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;

/// Modeled accelerator totals for one `(model, adc_bits)` serving class.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ModeledClass {
    /// samples launched in this class (including padded slots — the
    /// array executes them whether or not a client asked)
    pub inferences: u64,
    /// modeled launch energy, nJ
    pub energy_nj: f64,
    /// modeled MAC ops (2 per MAC)
    pub ops: f64,
}

impl ModeledClass {
    /// Modeled µJ per launched sample.
    pub fn uj_per_inf(&self) -> f64 {
        if self.inferences == 0 {
            0.0
        } else {
            self.energy_nj * 1e-3 / self.inferences as f64
        }
    }

    /// Modeled compute efficiency, TOPS/W.
    pub fn tops_w(&self) -> f64 {
        if self.energy_nj > 0.0 {
            self.ops / self.energy_nj / 1000.0
        } else {
            0.0
        }
    }
}

/// Raw per-model serving tallies behind the `per_model` mutex. Only the
/// multi-model router records these (a single-model coordinator leaves
/// the map empty, so its summary output is unchanged).
#[derive(Clone, Debug, Default)]
struct PerModel {
    requests: u64,
    completed: u64,
    submit_rejects: u64,
    launches: u64,
    batched_slots: u64,
    /// modeled launch energy attributed to this model, nJ
    modeled_nj: f64,
    /// end-to-end latencies of this model's completed requests, µs
    lat_us: Vec<f64>,
}

/// Per-model slice of a [`MetricsSummary`]: the counters a mixed-traffic
/// operator actually watches per model (throughput, rejects, mean batch,
/// tail latency, modeled energy per answered request).
#[derive(Clone, Debug, Default)]
pub struct PerModelSummary {
    pub requests: u64,
    pub completed: u64,
    /// submit-time rejects for this model (admission-control queue-full,
    /// bad feature length, unserveable options)
    pub submit_rejects: u64,
    pub launches: u64,
    /// mean dispatched batch size for this model's launches
    pub mean_batch: f64,
    /// this model's completed requests per wall second since start
    pub req_per_sec: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    /// modeled launch energy per completed request of this model, µJ
    /// (deployment-wide overheads like refresh/reprogram are not split
    /// per model — see [`MetricsSummary::modeled_uj_per_inf`])
    pub modeled_uj_per_inf: f64,
}

/// The modeled-energy ledger behind one mutex: per-launch totals plus
/// event overheads (refresh reads, reprogramming) that have no ops.
#[derive(Clone, Debug, Default)]
struct ModeledLedger {
    /// total modeled energy, nJ: launches + overhead events
    energy_nj: f64,
    /// modeled ops across all launches
    ops: f64,
    /// per-"model@bits" launch breakdown (overheads excluded — they
    /// belong to the deployment, not a serving class)
    by_class: BTreeMap<String, ModeledClass>,
}

pub struct Metrics {
    /// wall-clock origin for throughput (created with the coordinator)
    t0: Instant,
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub launches: AtomicU64,
    /// total request slots dispatched across launches (mean batch size =
    /// `batched_slots / launches`; for dynamic plans padded slots are zero
    /// so this equals `completed`)
    pub batched_slots: AtomicU64,
    pub padded_slots: AtomicU64,
    pub weight_refreshes: AtomicU64,
    /// requests rejected at submit time (`Coordinator::submit_with`: bad
    /// feature length, options the backend cannot serve, stopped worker) —
    /// from any source, in-process or wire
    pub submit_rejects: AtomicU64,
    /// request lines received on wire connections (including rejected
    /// ones); zero when no `server::WireServer` fronts this coordinator
    pub wire_requests: AtomicU64,
    /// wire-level rejects: lines the server answered with an error line
    /// *before* submit (malformed JSON, oversized line, bad sample index,
    /// refused connection). Submit-time failures of wire requests count
    /// under `submit_rejects` like everyone else's.
    pub wire_rejects: AtomicU64,
    /// responses served while the coordinator's health probe judged the
    /// analog path degraded (canary argmax agreement below threshold) —
    /// the clients got answers, but under a failing array
    pub degraded_responses: AtomicU64,
    /// health probes run (startup, after reprogramming, after refreshes)
    pub health_probes: AtomicU64,
    /// canary samples whose analog argmax agreed with the clean native
    /// reference, across all probes
    pub canary_agree: AtomicU64,
    /// canary samples probed, across all probes
    pub canary_total: AtomicU64,
    /// per-request end-to-end latencies, microseconds
    lat_us: Mutex<Vec<f64>>,
    /// simulated accelerator energy, nanojoules
    pub sim_energy_nj: Mutex<f64>,
    /// modeled accelerator energy/ops ledger (see [`ModeledLedger`])
    modeled: Mutex<ModeledLedger>,
    /// per-model serving tallies, keyed by model id; populated only by
    /// the multi-model router (see [`PerModel`])
    per_model: Mutex<BTreeMap<String, PerModel>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            t0: Instant::now(),
            requests: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            launches: AtomicU64::new(0),
            batched_slots: AtomicU64::new(0),
            padded_slots: AtomicU64::new(0),
            weight_refreshes: AtomicU64::new(0),
            submit_rejects: AtomicU64::new(0),
            wire_requests: AtomicU64::new(0),
            wire_rejects: AtomicU64::new(0),
            degraded_responses: AtomicU64::new(0),
            health_probes: AtomicU64::new(0),
            canary_agree: AtomicU64::new(0),
            canary_total: AtomicU64::new(0),
            lat_us: Mutex::new(Vec::new()),
            sim_energy_nj: Mutex::new(0.0),
            modeled: Mutex::new(ModeledLedger::default()),
            per_model: Mutex::new(BTreeMap::new()),
        }
    }
}

impl Metrics {
    pub fn record_latency_us(&self, us: f64) {
        self.lat_us.lock().unwrap().push(us);
    }

    pub fn add_energy_nj(&self, nj: f64) {
        *self.sim_energy_nj.lock().unwrap() += nj;
    }

    /// Account one modeled launch: `slots` samples of `model` at `bits`
    /// costing `energy_nj` nJ for `ops` MAC ops (2 per MAC), as priced by
    /// `timing::ScheduleModel::launch`.
    pub fn add_modeled_launch(&self, model: &str, bits: u32, slots: u64,
                              energy_nj: f64, ops: f64) {
        let mut led = self.modeled.lock().unwrap();
        led.energy_nj += energy_nj;
        led.ops += ops;
        let c = led.by_class.entry(format!("{model}@{bits}b")).or_default();
        c.inferences += slots;
        c.energy_nj += energy_nj;
        c.ops += ops;
    }

    /// Account a modeled overhead event (a cadence conductance-refresh
    /// read or a full reprogramming): pure energy, no ops — it dilutes
    /// `modeled_tops_w` and amortizes into `modeled_uj_per_inf` over the
    /// traffic that shares the deployment.
    pub fn add_modeled_overhead_nj(&self, nj: f64) {
        self.modeled.lock().unwrap().energy_nj += nj;
    }

    /// Count one accepted submit for `model` (multi-model router only;
    /// the global `requests` counter is bumped separately).
    pub fn model_request(&self, model: &str) {
        let mut pm = self.per_model.lock().unwrap();
        pm.entry(model.to_string()).or_default().requests += 1;
    }

    /// Count one submit-time reject for `model` (queue full, bad feature
    /// length, unserveable options; the global `submit_rejects` counter
    /// is bumped separately).
    pub fn model_reject(&self, model: &str) {
        let mut pm = self.per_model.lock().unwrap();
        pm.entry(model.to_string()).or_default().submit_rejects += 1;
    }

    /// Account one launch of `slots` request slots for `model`, with its
    /// modeled launch energy in nJ (0 when no schedule model priced it).
    pub fn model_launch(&self, model: &str, slots: u64, energy_nj: f64) {
        let mut pm = self.per_model.lock().unwrap();
        let e = pm.entry(model.to_string()).or_default();
        e.launches += 1;
        e.batched_slots += slots;
        e.modeled_nj += energy_nj;
    }

    /// Record one completed request for `model` with its end-to-end
    /// latency (the global reservoir receives the same value separately).
    pub fn model_completed(&self, model: &str, lat_us: f64) {
        let mut pm = self.per_model.lock().unwrap();
        let e = pm.entry(model.to_string()).or_default();
        e.completed += 1;
        e.lat_us.push(lat_us);
    }

    pub fn latencies_us(&self) -> Vec<f64> {
        self.lat_us.lock().unwrap().clone()
    }

    /// Seconds since the metrics (i.e. the coordinator) were created.
    pub fn elapsed_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    pub fn summary(&self) -> MetricsSummary {
        let lat = self.latencies_us();
        let completed = self.completed.load(Ordering::Relaxed);
        let launches = self.launches.load(Ordering::Relaxed);
        let elapsed_s = self.elapsed_s();
        MetricsSummary {
            requests: self.requests.load(Ordering::Relaxed),
            completed,
            launches,
            padded_slots: self.padded_slots.load(Ordering::Relaxed),
            weight_refreshes: self.weight_refreshes.load(Ordering::Relaxed),
            submit_rejects: self.submit_rejects.load(Ordering::Relaxed),
            wire_requests: self.wire_requests.load(Ordering::Relaxed),
            wire_rejects: self.wire_rejects.load(Ordering::Relaxed),
            degraded_responses: self.degraded_responses.load(Ordering::Relaxed),
            health_probes: self.health_probes.load(Ordering::Relaxed),
            canary_agree: self.canary_agree.load(Ordering::Relaxed),
            canary_total: self.canary_total.load(Ordering::Relaxed),
            elapsed_s,
            req_per_sec: if elapsed_s > 0.0 {
                completed as f64 / elapsed_s
            } else {
                0.0
            },
            mean_batch: if launches == 0 {
                0.0
            } else {
                self.batched_slots.load(Ordering::Relaxed) as f64 / launches as f64
            },
            p50_us: crate::util::stats::percentile(&lat, 50.0),
            p99_us: crate::util::stats::percentile(&lat, 99.0),
            mean_us: crate::util::stats::mean(&lat),
            sim_uj_per_inf: if completed == 0 {
                0.0
            } else {
                *self.sim_energy_nj.lock().unwrap() * 1e-3 / completed as f64
            },
            modeled_uj_per_inf: {
                let led = self.modeled.lock().unwrap();
                if completed == 0 {
                    0.0
                } else {
                    led.energy_nj * 1e-3 / completed as f64
                }
            },
            modeled_tops_w: {
                let led = self.modeled.lock().unwrap();
                if led.energy_nj > 0.0 {
                    led.ops / led.energy_nj / 1000.0
                } else {
                    0.0
                }
            },
            modeled_by_class: self.modeled.lock().unwrap().by_class.clone(),
            per_model: {
                let pm = self.per_model.lock().unwrap();
                pm.iter()
                    .map(|(model, e)| {
                        (model.clone(), PerModelSummary {
                            requests: e.requests,
                            completed: e.completed,
                            submit_rejects: e.submit_rejects,
                            launches: e.launches,
                            mean_batch: if e.launches == 0 {
                                0.0
                            } else {
                                e.batched_slots as f64 / e.launches as f64
                            },
                            req_per_sec: if elapsed_s > 0.0 {
                                e.completed as f64 / elapsed_s
                            } else {
                                0.0
                            },
                            p50_us: crate::util::stats::percentile(&e.lat_us,
                                                                   50.0),
                            p99_us: crate::util::stats::percentile(&e.lat_us,
                                                                   99.0),
                            modeled_uj_per_inf: if e.completed == 0 {
                                0.0
                            } else {
                                e.modeled_nj * 1e-3 / e.completed as f64
                            },
                        })
                    })
                    .collect()
            },
        }
    }
}

#[derive(Clone, Debug)]
pub struct MetricsSummary {
    pub requests: u64,
    pub completed: u64,
    pub launches: u64,
    pub padded_slots: u64,
    pub weight_refreshes: u64,
    /// submit-time rejects (any source; see [`Metrics::submit_rejects`])
    pub submit_rejects: u64,
    /// wire request lines received (see [`Metrics::wire_requests`])
    pub wire_requests: u64,
    /// pre-submit wire rejects (see [`Metrics::wire_rejects`])
    pub wire_rejects: u64,
    /// responses served while degraded (see [`Metrics::degraded_responses`])
    pub degraded_responses: u64,
    /// health probes run (see [`Metrics::health_probes`])
    pub health_probes: u64,
    /// canary agreements across probes (see [`Metrics::canary_agree`])
    pub canary_agree: u64,
    /// canary samples across probes (see [`Metrics::canary_total`])
    pub canary_total: u64,
    pub elapsed_s: f64,
    /// completed requests per wall second since coordinator start
    pub req_per_sec: f64,
    /// mean dispatched batch size (request slots per launch)
    pub mean_batch: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub mean_us: f64,
    pub sim_uj_per_inf: f64,
    /// total modeled accelerator energy (launches, including padded
    /// slots, plus refresh/reprogram overhead events) per *completed*
    /// request, µJ — the honest serving cost of one answered request
    pub modeled_uj_per_inf: f64,
    /// modeled compute efficiency across all launches, TOPS/W (overhead
    /// events add energy but no ops, so they dilute this number)
    pub modeled_tops_w: f64,
    /// modeled launch totals per `"model@bits"` serving class
    pub modeled_by_class: BTreeMap<String, ModeledClass>,
    /// per-model serving breakdown, keyed by model id; empty unless a
    /// [`MultiCoordinator`](crate::coordinator::MultiCoordinator) is
    /// recording (single-model output is unchanged)
    pub per_model: BTreeMap<String, PerModelSummary>,
}

impl MetricsSummary {
    /// Machine-readable form (the `BENCH_native.json` building block).
    /// Non-finite values (e.g. percentiles of an empty reservoir) are
    /// serialized as 0 so the output is always valid JSON.
    pub fn to_json(&self) -> Json {
        fn num(x: f64) -> Json {
            Json::Num(if x.is_finite() { x } else { 0.0 })
        }
        let mut m = std::collections::BTreeMap::new();
        m.insert("requests".to_string(), num(self.requests as f64));
        m.insert("completed".to_string(), num(self.completed as f64));
        m.insert("launches".to_string(), num(self.launches as f64));
        m.insert("padded_slots".to_string(), num(self.padded_slots as f64));
        m.insert("weight_refreshes".to_string(),
                 num(self.weight_refreshes as f64));
        m.insert("submit_rejects".to_string(), num(self.submit_rejects as f64));
        m.insert("wire_requests".to_string(), num(self.wire_requests as f64));
        m.insert("wire_rejects".to_string(), num(self.wire_rejects as f64));
        m.insert("degraded_responses".to_string(),
                 num(self.degraded_responses as f64));
        m.insert("health_probes".to_string(), num(self.health_probes as f64));
        m.insert("canary_agree".to_string(), num(self.canary_agree as f64));
        m.insert("canary_total".to_string(), num(self.canary_total as f64));
        m.insert("elapsed_s".to_string(), num(self.elapsed_s));
        m.insert("req_per_sec".to_string(), num(self.req_per_sec));
        m.insert("mean_batch".to_string(), num(self.mean_batch));
        m.insert("p50_us".to_string(), num(self.p50_us));
        m.insert("p99_us".to_string(), num(self.p99_us));
        m.insert("mean_us".to_string(), num(self.mean_us));
        m.insert("sim_uj_per_inf".to_string(), num(self.sim_uj_per_inf));
        m.insert("modeled_uj_per_inf".to_string(),
                 num(self.modeled_uj_per_inf));
        m.insert("modeled_tops_w".to_string(), num(self.modeled_tops_w));
        let mut by = BTreeMap::new();
        for (class, c) in &self.modeled_by_class {
            let mut e = BTreeMap::new();
            e.insert("inferences".to_string(), num(c.inferences as f64));
            e.insert("uj_per_inf".to_string(), num(c.uj_per_inf()));
            e.insert("tops_w".to_string(), num(c.tops_w()));
            by.insert(class.clone(), Json::Obj(e));
        }
        m.insert("modeled".to_string(), Json::Obj(by));
        let mut pm = BTreeMap::new();
        for (model, p) in &self.per_model {
            let mut e = BTreeMap::new();
            e.insert("requests".to_string(), num(p.requests as f64));
            e.insert("completed".to_string(), num(p.completed as f64));
            e.insert("submit_rejects".to_string(),
                     num(p.submit_rejects as f64));
            e.insert("launches".to_string(), num(p.launches as f64));
            e.insert("mean_batch".to_string(), num(p.mean_batch));
            e.insert("req_per_sec".to_string(), num(p.req_per_sec));
            e.insert("p50_us".to_string(), num(p.p50_us));
            e.insert("p99_us".to_string(), num(p.p99_us));
            e.insert("modeled_uj_per_inf".to_string(),
                     num(p.modeled_uj_per_inf));
            pm.insert(model.clone(), Json::Obj(e));
        }
        m.insert("per_model".to_string(), Json::Obj(pm));
        Json::Obj(m)
    }
}

impl std::fmt::Display for MetricsSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "req={} done={} launches={} batch={:.1} padded={} refreshes={} \
             submit_rej={} wire={}/{} degraded={} probes={}:{}/{} rps={:.0} \
             lat p50={:.0}us p99={:.0}us mean={:.0}us sim_energy={:.2}uJ/inf \
             modeled={:.2}uJ/inf@{:.2}TOPS/W",
            self.requests, self.completed, self.launches, self.mean_batch,
            self.padded_slots, self.weight_refreshes, self.submit_rejects,
            self.wire_requests, self.wire_rejects, self.degraded_responses,
            self.health_probes, self.canary_agree, self.canary_total,
            self.req_per_sec, self.p50_us, self.p99_us, self.mean_us,
            self.sim_uj_per_inf, self.modeled_uj_per_inf, self.modeled_tops_w
        )?;
        // multi-model suffix; absent for single-model summaries so their
        // one-line form is byte-identical to the pre-router output
        for (model, p) in &self.per_model {
            write!(
                f,
                " [{model}: req={} done={} rej={} rps={:.0} batch={:.1} \
                 p99={:.0}us {:.2}uJ/inf]",
                p.requests, p.completed, p.submit_rejects, p.req_per_sec,
                p.mean_batch, p.p99_us, p.modeled_uj_per_inf
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_math() {
        let m = Metrics::default();
        m.requests.store(10, Ordering::Relaxed);
        m.completed.store(10, Ordering::Relaxed);
        m.launches.store(2, Ordering::Relaxed);
        m.batched_slots.store(10, Ordering::Relaxed);
        for i in 0..10 {
            m.record_latency_us(i as f64);
        }
        m.add_energy_nj(10_000.0); // 10 uJ over 10 inf
        let s = m.summary();
        assert_eq!(s.completed, 10);
        assert!((s.p50_us - 4.5).abs() < 1e-9);
        assert!((s.sim_uj_per_inf - 1.0).abs() < 1e-9);
        assert!((s.mean_batch - 5.0).abs() < 1e-9);
        // throughput clock started at Metrics creation, so rps is finite
        // and positive once anything completed
        assert!(s.elapsed_s > 0.0);
        assert!(s.req_per_sec > 0.0);
    }

    #[test]
    fn json_form_is_finite_and_writable() {
        let m = Metrics::default();
        let j = m.summary().to_json(); // empty reservoir => NaN percentiles
        let txt = crate::util::json::write(&j);
        assert!(txt.contains("\"p50_us\":0"), "{txt}");
        // round-trips through our own parser
        assert!(crate::util::json::parse(&txt).is_ok());
    }

    #[test]
    fn reject_counters_surface_everywhere() {
        let m = Metrics::default();
        m.submit_rejects.store(2, Ordering::Relaxed);
        m.wire_requests.store(7, Ordering::Relaxed);
        m.wire_rejects.store(3, Ordering::Relaxed);
        let s = m.summary();
        assert_eq!((s.submit_rejects, s.wire_requests, s.wire_rejects),
                   (2, 7, 3));
        let txt = crate::util::json::write(&s.to_json());
        assert!(txt.contains("\"submit_rejects\":2"), "{txt}");
        assert!(txt.contains("\"wire_requests\":7"), "{txt}");
        assert!(txt.contains("\"wire_rejects\":3"), "{txt}");
        assert!(s.to_string().contains("wire=7/3"), "{s}");
    }

    #[test]
    fn modeled_ledger_surfaces_everywhere() {
        let m = Metrics::default();
        m.completed.store(10, Ordering::Relaxed);
        // two launches: 8 samples at 8 bits, 2 at 4 bits; 2 ops per nJ at
        // 8 bits => 2 TOPS/W before overheads
        m.add_modeled_launch("kws", 8, 8, 4_000.0, 8.0e6);
        m.add_modeled_launch("kws", 4, 2, 500.0, 2.0e6);
        // plus one refresh event: energy, no ops
        m.add_modeled_overhead_nj(500.0);
        let s = m.summary();
        // (4000 + 500 + 500) nJ over 10 completed = 0.5 uJ/inf
        assert!((s.modeled_uj_per_inf - 0.5).abs() < 1e-12,
                "{}", s.modeled_uj_per_inf);
        // 10e6 ops / 5000 nJ / 1000 = 2.0 TOPS/W
        assert!((s.modeled_tops_w - 2.0).abs() < 1e-12, "{}", s.modeled_tops_w);
        // per-class breakdown excludes the overhead event
        let c8 = &s.modeled_by_class["kws@8b"];
        assert_eq!(c8.inferences, 8);
        assert!((c8.uj_per_inf() - 0.5).abs() < 1e-12);
        assert!((c8.tops_w() - 2.0).abs() < 1e-12);
        let c4 = &s.modeled_by_class["kws@4b"];
        assert_eq!(c4.inferences, 2);
        assert!((c4.tops_w() - 4.0).abs() < 1e-12);
        // json + display surfacing
        let txt = crate::util::json::write(&s.to_json());
        assert!(txt.contains("\"modeled_uj_per_inf\":0.5"), "{txt}");
        assert!(txt.contains("\"modeled_tops_w\":2"), "{txt}");
        assert!(txt.contains("\"kws@8b\""), "{txt}");
        assert!(txt.contains("\"kws@4b\""), "{txt}");
        assert!(crate::util::json::parse(&txt).is_ok());
        assert!(s.to_string().contains("modeled=0.50uJ/inf@2.00TOPS/W"),
                "{s}");
    }

    #[test]
    fn per_model_surfaces_everywhere() {
        let m = Metrics::default();
        // single-model path records nothing per model: map empty, and the
        // Display line carries no per-model suffix
        let s0 = m.summary();
        assert!(s0.per_model.is_empty());
        assert!(!s0.to_string().contains('['), "{s0}");
        // a router serving kws + vww
        for _ in 0..4 {
            m.model_request("kws");
        }
        m.model_request("vww");
        m.model_reject("kws");
        m.model_launch("kws", 3, 1_500.0);
        m.model_completed("kws", 10.0);
        m.model_completed("kws", 20.0);
        m.model_completed("kws", 30.0);
        m.model_completed("vww", 100.0);
        let s = m.summary();
        let kws = &s.per_model["kws"];
        assert_eq!((kws.requests, kws.completed, kws.submit_rejects,
                    kws.launches),
                   (4, 3, 1, 1));
        assert!((kws.mean_batch - 3.0).abs() < 1e-12);
        // 1500 nJ over 3 completed = 0.5 uJ/inf
        assert!((kws.modeled_uj_per_inf - 0.5).abs() < 1e-12);
        assert!((kws.p50_us - 20.0).abs() < 1e-9, "{}", kws.p50_us);
        assert!(kws.req_per_sec > 0.0);
        let vww = &s.per_model["vww"];
        assert_eq!((vww.requests, vww.completed, vww.launches), (1, 1, 0));
        assert_eq!(vww.mean_batch, 0.0);
        assert_eq!(vww.modeled_uj_per_inf, 0.0);
        // json + display surfacing
        let txt = crate::util::json::write(&s.to_json());
        assert!(txt.contains("\"per_model\""), "{txt}");
        assert!(txt.contains("\"kws\""), "{txt}");
        assert!(txt.contains("\"vww\""), "{txt}");
        assert!(txt.contains("\"submit_rejects\":1"), "{txt}");
        assert!(crate::util::json::parse(&txt).is_ok());
        let line = s.to_string();
        assert!(line.contains("[kws: req=4 done=3 rej=1"), "{line}");
        assert!(line.contains("[vww: req=1 done=1 rej=0"), "{line}");
    }

    #[test]
    fn health_counters_surface_everywhere() {
        let m = Metrics::default();
        m.degraded_responses.store(4, Ordering::Relaxed);
        m.health_probes.store(2, Ordering::Relaxed);
        m.canary_agree.store(5, Ordering::Relaxed);
        m.canary_total.store(8, Ordering::Relaxed);
        let s = m.summary();
        assert_eq!((s.degraded_responses, s.health_probes,
                    s.canary_agree, s.canary_total),
                   (4, 2, 5, 8));
        let txt = crate::util::json::write(&s.to_json());
        assert!(txt.contains("\"degraded_responses\":4"), "{txt}");
        assert!(txt.contains("\"health_probes\":2"), "{txt}");
        assert!(txt.contains("\"canary_agree\":5"), "{txt}");
        assert!(txt.contains("\"canary_total\":8"), "{txt}");
        assert!(s.to_string().contains("degraded=4"), "{s}");
        assert!(s.to_string().contains("probes=2:5/8"), "{s}");
    }
}
