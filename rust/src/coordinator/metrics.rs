//! Serving metrics: counters + latency reservoir.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub launches: AtomicU64,
    pub padded_slots: AtomicU64,
    pub weight_refreshes: AtomicU64,
    /// per-request end-to-end latencies, microseconds
    lat_us: Mutex<Vec<f64>>,
    /// simulated accelerator energy, nanojoules
    pub sim_energy_nj: Mutex<f64>,
}

impl Metrics {
    pub fn record_latency_us(&self, us: f64) {
        self.lat_us.lock().unwrap().push(us);
    }

    pub fn add_energy_nj(&self, nj: f64) {
        *self.sim_energy_nj.lock().unwrap() += nj;
    }

    pub fn latencies_us(&self) -> Vec<f64> {
        self.lat_us.lock().unwrap().clone()
    }

    pub fn summary(&self) -> MetricsSummary {
        let lat = self.latencies_us();
        let completed = self.completed.load(Ordering::Relaxed);
        MetricsSummary {
            requests: self.requests.load(Ordering::Relaxed),
            completed,
            launches: self.launches.load(Ordering::Relaxed),
            padded_slots: self.padded_slots.load(Ordering::Relaxed),
            weight_refreshes: self.weight_refreshes.load(Ordering::Relaxed),
            p50_us: crate::util::stats::percentile(&lat, 50.0),
            p99_us: crate::util::stats::percentile(&lat, 99.0),
            mean_us: crate::util::stats::mean(&lat),
            sim_uj_per_inf: if completed == 0 {
                0.0
            } else {
                *self.sim_energy_nj.lock().unwrap() * 1e-3 / completed as f64
            },
        }
    }
}

#[derive(Clone, Debug)]
pub struct MetricsSummary {
    pub requests: u64,
    pub completed: u64,
    pub launches: u64,
    pub padded_slots: u64,
    pub weight_refreshes: u64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub mean_us: f64,
    pub sim_uj_per_inf: f64,
}

impl std::fmt::Display for MetricsSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "req={} done={} launches={} padded={} refreshes={} \
             lat p50={:.0}us p99={:.0}us mean={:.0}us sim_energy={:.2}uJ/inf",
            self.requests, self.completed, self.launches, self.padded_slots,
            self.weight_refreshes, self.p50_us, self.p99_us, self.mean_us,
            self.sim_uj_per_inf
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_math() {
        let m = Metrics::default();
        m.requests.store(10, Ordering::Relaxed);
        m.completed.store(10, Ordering::Relaxed);
        for i in 0..10 {
            m.record_latency_us(i as f64);
        }
        m.add_energy_nj(10_000.0); // 10 uJ over 10 inf
        let s = m.summary();
        assert_eq!(s.completed, 10);
        assert!((s.p50_us - 4.5).abs() < 1e-9);
        assert!((s.sim_uj_per_inf - 1.0).abs() < 1e-9);
    }
}
