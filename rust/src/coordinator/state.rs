//! PCM array state management: drift clock, periodic weight refresh,
//! GDC recalibration, fault-scenario bookkeeping, and the reprogramming
//! policy.

use std::time::Instant;

use crate::backend::HostTensor;
use crate::crossbar::ArrayGeom;
use crate::eval::{DeployedLayer, DeployedModel};
use crate::pcm::{gdc, FaultSpec, LayerGdc, PcmParams};
use crate::util::rng::Rng;

/// One cached explicit-age weight read (see [`PcmState::weights_at`]).
struct AgedRead {
    /// `f64::to_bits` of the clamped age — exact-match key
    age_key: u64,
    /// `FaultSpec::key()` of the scenario this read was taken under —
    /// faulted and clean reads of the same age must never alias
    fault_key: u64,
    /// sim-clock time the read was taken (refresh-cadence staleness;
    /// deliberately NOT bumped on hits — that would freeze noise forever)
    read_at_s: f64,
    /// sim-clock time of the last hit (LRU eviction recency)
    last_used_s: f64,
    ws: Vec<HostTensor>,
    alphas: Vec<LayerGdc>,
}

/// Distinct (device age, fault scenario) entries the explicit-age cache
/// holds at once. Sized for the expected shape of mixed traffic (a
/// handful of cohorts in steady rotation): with N <= this many cohorts
/// alternating, every drain hits the cache instead of re-sampling
/// full-model read noise per group.
const AGED_CACHE_ENTRIES: usize = 4;

/// Non-default fault scenarios whose programmed (faulted) model copies we
/// keep around. Each is a full `DeployedModel` clone, so the cap is small:
/// mixed-scenario traffic beyond it re-derives from the pristine copy.
const DERIVED_CACHE_ENTRIES: usize = 2;

/// Live PCM state behind the serving loop.
pub struct PcmState {
    /// the model currently being served: the pristine programming with the
    /// deployment's default [`FaultSpec`] stamped on
    pub deployed: DeployedModel,
    /// the fault-free programming every scenario derives from
    pristine: DeployedModel,
    /// the deployment's default fault scenario (`none()` unless serving
    /// was started with `--faults`)
    faults: FaultSpec,
    /// per-request fault scenarios other than the default, keyed by
    /// `FaultSpec::key()` — bounded, insertion-order evicted
    derived: Vec<(u64, DeployedModel)>,
    /// tile geometry for per-tile GDC calibration (`None` = uniform GDC,
    /// the right choice for full-K engines)
    calib_geom: Option<ArrayGeom>,
    pub params: PcmParams,
    rng: Rng,
    /// wall-clock origin of the current programming
    programmed_at: Instant,
    /// simulated seconds per wall second (always-on deployments run for
    /// months; examples accelerate the clock)
    pub time_scale: f64,
    /// simulated age offset (programming completes at t_c = 25 s)
    age_offset_s: f64,
    /// cached effective weights + GDC (refreshed on a simulated-time cadence)
    cached: Option<(Vec<HostTensor>, Vec<LayerGdc>)>,
    cached_at_s: f64,
    /// bounded cache for explicit-age/scenario reads
    /// ([`Self::weights_at`], per-request drift and faults): up to
    /// `AGED_CACHE_ENTRIES` cohorts, each reused until the refresh cadence
    /// elapses, LRU-evicted
    aged: Vec<AgedRead>,
    /// refresh cadence in simulated seconds
    pub refresh_every_s: f64,
    /// reprogram when the mean GDC factor exceeds this
    pub reprogram_alpha: f64,
    pub reprogram_count: u64,
    pub gdc_enabled: bool,
}

impl PcmState {
    pub fn new(deployed: DeployedModel, params: PcmParams, seed: u64,
               time_scale: f64) -> Self {
        PcmState {
            pristine: deployed.clone(),
            deployed,
            faults: FaultSpec::none(),
            derived: Vec::new(),
            calib_geom: None,
            params,
            rng: Rng::new(seed),
            programmed_at: Instant::now(),
            time_scale,
            age_offset_s: crate::pcm::T_C_SECONDS,
            cached: None,
            cached_at_s: f64::NEG_INFINITY,
            aged: Vec::new(),
            refresh_every_s: 60.0,
            reprogram_alpha: 1.15,
            reprogram_count: 0,
            gdc_enabled: true,
        }
    }

    /// Drop every cached read — clock-driven, explicit-age, and derived
    /// fault models. The single invalidation point: anything that changes
    /// what a read would return (initial age, reprogramming, fault spec,
    /// calibration geometry) must go through here so stale weights are
    /// never served.
    fn invalidate(&mut self) {
        self.cached = None;
        self.cached_at_s = f64::NEG_INFINITY;
        self.aged.clear();
        self.derived.clear();
    }

    /// The deployment's default fault scenario.
    pub fn faults(&self) -> FaultSpec {
        self.faults
    }

    /// Install `spec` as the deployment default: the served model becomes
    /// the pristine programming with `spec`'s stuck cells / conductance
    /// spread stamped on, and **every** cached read is dropped — a request
    /// arriving after this call can never observe pre-fault weights (the
    /// same invalidation contract `set_initial_age` and `reprogram` keep).
    pub fn set_faults(&mut self, spec: FaultSpec) {
        self.faults = spec;
        self.deployed = self.pristine.clone();
        if spec.has_weight_faults() {
            self.deployed.apply_faults(&spec);
        }
        self.invalidate();
    }

    /// Target tile geometry for per-tile GDC calibration (take it from
    /// [`InferenceBackend::calib_geom`](crate::backend::InferenceBackend::calib_geom)).
    /// Changing it invalidates cached reads: their alphas were calibrated
    /// for the old geometry.
    pub fn set_calib_geom(&mut self, geom: Option<ArrayGeom>) {
        if self.calib_geom != geom {
            self.calib_geom = geom;
            self.invalidate();
        }
    }

    /// Current simulated device age in seconds.
    pub fn sim_age_s(&self) -> f64 {
        self.age_offset_s + self.programmed_at.elapsed().as_secs_f64() * self.time_scale
    }

    /// Start the drift clock at `age_s` simulated seconds after programming
    /// (drift-aware serving: bring the coordinator up against an array that
    /// has already aged a day or a year, `ServeConfig::drift_time`).
    /// Ages below t_c = 25 s clamp to t_c — devices are never read before
    /// programming settles. Invalidates the cached weight read so the next
    /// dispatch sees conductances drifted to the new age.
    pub fn set_initial_age(&mut self, age_s: f64) {
        self.age_offset_s = crate::pcm::clamp_age(age_s);
        self.invalidate();
    }

    /// Mean GDC factor right now (drift health indicator).
    pub fn mean_alpha(&self) -> f64 {
        let t = self.sim_age_s();
        let mut s = 0.0;
        let mut n = 0usize;
        for dl in &self.deployed.layers {
            if let DeployedLayer::Analog(p) = dl {
                s += gdc::alpha(p, t) as f64;
                n += 1;
            }
        }
        if n == 0 {
            1.0
        } else {
            s / n as f64
        }
    }

    /// Reprogram the array (fresh programming noise, drift clock reset).
    /// The deployment's default fault scenario survives: stuck cells are
    /// array properties, so the fresh programming is re-faulted with the
    /// same spec (same pinned pattern, new programming noise around it).
    pub fn reprogram(&mut self, store: &crate::runtime::ArtifactStore,
                     vid: &str) -> anyhow::Result<()> {
        self.pristine =
            DeployedModel::program(store, vid, &self.params, &mut self.rng)?;
        self.deployed = self.pristine.clone();
        if self.faults.has_weight_faults() {
            self.deployed.apply_faults(&self.faults);
        }
        self.programmed_at = Instant::now();
        self.invalidate();
        self.reprogram_count += 1;
        Ok(())
    }

    /// Effective weights + GDC for the current simulated time, refreshed on
    /// the configured cadence (fresh 1/f read noise on each refresh).
    /// The bool is true when this call performed a refresh. Serves the
    /// deployment-default fault scenario; per-request scenarios go through
    /// [`current_weights_spec`](Self::current_weights_spec).
    pub fn current_weights(&mut self)
                           -> (&Vec<HostTensor>, &Vec<LayerGdc>, bool) {
        let t = self.sim_age_s();
        let mut refreshed = false;
        if self.cached.is_none() || t - self.cached_at_s >= self.refresh_every_s {
            let (ws, alphas) = self.deployed.read_at_calibrated(
                t, &self.params, &mut self.rng, self.gdc_enabled,
                self.calib_geom);
            self.cached = Some((ws, alphas));
            self.cached_at_s = t;
            refreshed = true;
        }
        let c = self.cached.as_ref().unwrap();
        (&c.0, &c.1, refreshed)
    }

    /// [`current_weights`](Self::current_weights) under an explicit fault
    /// scenario. The default scenario delegates to the clock cache; any
    /// other spec reads at the cadence-quantized current age through the
    /// explicit cohort cache, so steady mixed-scenario traffic re-samples
    /// noise once per cadence, not once per drain. Returns the device age
    /// actually served.
    pub fn current_weights_spec(&mut self, spec: &FaultSpec)
                                -> (&Vec<HostTensor>, &Vec<LayerGdc>, f64, bool) {
        let now = self.sim_age_s();
        if spec.key() == self.faults.key() {
            let (ws, alphas, refreshed) = self.current_weights();
            return (ws, alphas, now, refreshed);
        }
        let q = if self.refresh_every_s > 0.0 && self.refresh_every_s.is_finite() {
            (now / self.refresh_every_s).floor() * self.refresh_every_s
        } else {
            now
        };
        let (ws, alphas, _, refreshed) = self.weights_at_spec(q, spec);
        (ws, alphas, crate::pcm::clamp_age(q), refreshed)
    }

    /// Effective weights + GDC at an **explicit** device age (per-request
    /// drift: `InferOpts::t_drift`), independent of the serving clock.
    /// Ages below t_c = 25 s clamp up to t_c; the clamped age is returned
    /// so responses can echo the age actually served. A bounded cache
    /// (`AGED_CACHE_ENTRIES` distinct ages, least-recently-*used*
    /// eviction) reuses each age's read until
    /// [`refresh_every_s`](Self::refresh_every_s) of simulated time
    /// elapses (fresh 1/f read noise after that — the same cadence the
    /// clock-driven [`current_weights`](Self::current_weights) cache
    /// follows), so a handful of age cohorts in steady rotation never
    /// re-sample noise per drain, and a one-shot odd age evicts the
    /// coldest cohort, not a hot one. The bool is true when this call
    /// performed a fresh read.
    pub fn weights_at(&mut self, age_s: f64)
                      -> (&Vec<HostTensor>, &Vec<LayerGdc>, f64, bool) {
        let spec = self.faults;
        self.weights_at_spec(age_s, &spec)
    }

    /// [`weights_at`](Self::weights_at) under an explicit fault scenario.
    /// Reads the scenario's own programmed model: the deployment default
    /// serves `deployed` directly; any other spec derives a faulted copy
    /// of the pristine programming (bounded cache of
    /// `DERIVED_CACHE_ENTRIES` scenarios). Cache entries key on
    /// `(age, FaultSpec::key())`, so faulted and clean cohorts of the
    /// same age never alias.
    pub fn weights_at_spec(&mut self, age_s: f64, spec: &FaultSpec)
                           -> (&Vec<HostTensor>, &Vec<LayerGdc>, f64, bool) {
        // same clamp the batch key applies, so key-equal requests are
        // guaranteed to be age-equal reads
        let t = crate::pcm::clamp_age(age_s);
        let age_key = t.to_bits();
        let fault_key = spec.key();
        let now = self.sim_age_s();
        let hit = self
            .aged
            .iter()
            .position(|a| a.age_key == age_key
                && a.fault_key == fault_key
                && now - a.read_at_s < self.refresh_every_s);
        let (idx, refreshed) = match hit {
            Some(i) => (i, false),
            None => {
                let default_key = self.faults.key();
                if fault_key != default_key {
                    self.ensure_derived(fault_key, spec);
                }
                let (ws, alphas) = {
                    let model = if fault_key == default_key {
                        &self.deployed
                    } else {
                        &self
                            .derived
                            .iter()
                            .find(|(k, _)| *k == fault_key)
                            .expect("ensure_derived just inserted it")
                            .1
                    };
                    model.read_at_calibrated(t, &self.params, &mut self.rng,
                                             self.gdc_enabled, self.calib_geom)
                };
                let entry = AgedRead {
                    age_key,
                    fault_key,
                    read_at_s: now,
                    last_used_s: now,
                    ws,
                    alphas,
                };
                if let Some(i) = self.aged.iter().position(|a| {
                    a.age_key == age_key && a.fault_key == fault_key
                }) {
                    // cadence-expired entry for this cohort: refresh in place
                    self.aged[i] = entry;
                    (i, true)
                } else {
                    if self.aged.len() >= AGED_CACHE_ENTRIES {
                        // evict the least recently *used* cohort (hits bump
                        // last_used_s below, so hot cohorts survive a
                        // one-shot odd age)
                        let coldest = self
                            .aged
                            .iter()
                            .enumerate()
                            .min_by(|a, b| {
                                a.1.last_used_s.total_cmp(&b.1.last_used_s)
                            })
                            .map(|(i, _)| i)
                            .unwrap_or(0);
                        self.aged.swap_remove(coldest);
                    }
                    self.aged.push(entry);
                    (self.aged.len() - 1, true)
                }
            }
        };
        let a = &mut self.aged[idx];
        a.last_used_s = now;
        (&a.ws, &a.alphas, t, refreshed)
    }

    /// Materialize (or find) the derived model for a non-default scenario.
    fn ensure_derived(&mut self, fault_key: u64, spec: &FaultSpec) {
        if self.derived.iter().any(|(k, _)| *k == fault_key) {
            return;
        }
        let mut m = self.pristine.clone();
        if spec.has_weight_faults() {
            m.apply_faults(spec);
        }
        if self.derived.len() >= DERIVED_CACHE_ENTRIES {
            self.derived.remove(0);
        }
        self.derived.push((fault_key, m));
    }

    /// Whether the reprogramming policy should fire.
    pub fn needs_reprogram(&self) -> bool {
        self.mean_alpha() > self.reprogram_alpha
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::meta::ModelMeta;
    use crate::pcm::ProgrammedWeights;
    use crate::util::json;

    fn tiny_deployed() -> DeployedModel {
        let src = r#"{
          "model": "tiny", "variant": "t", "input_hwc": [1, 1, 4],
          "num_classes": 2, "eta": 0.0, "fp_test_acc": 1.0,
          "trained_adc_bits": null,
          "layers": [{"name": "fc", "kind": "dense", "in_ch": 4, "out_ch": 2,
            "stride": [1,1], "relu": false, "analog": true,
            "in_h": 1, "in_w": 1, "out_h": 1, "out_w": 1,
            "k_gemm": 4, "weight_shape": [4, 2], "graph_weight_shape": [4, 2],
            "w_scale": 1.0, "w_max": 1.0, "r_dac": 1.0, "r_adc": 4.0,
            "dig_scale": [1, 1], "dig_bias": [0, 0]}],
          "hlo": {}
        }"#;
        let meta = std::sync::Arc::new(
            ModelMeta::from_json(&json::parse(src).unwrap()).unwrap());
        let mut rng = Rng::new(5);
        let w: Vec<f32> = (0..8).map(|i| (i as f32 - 4.0) / 8.0).collect();
        let p = ProgrammedWeights::program(&w, 4, 2, 1.0, &PcmParams::default(),
                                           &mut rng);
        DeployedModel { meta, layers: vec![DeployedLayer::Analog(p)] }
    }

    #[test]
    fn sim_clock_advances_with_scale() {
        let st = PcmState::new(tiny_deployed(), PcmParams::default(), 1, 1e6);
        std::thread::sleep(std::time::Duration::from_millis(5));
        let age = st.sim_age_s();
        assert!(age > 25.0 + 1e3, "age={age}"); // 5ms * 1e6 = 5000s
    }

    #[test]
    fn weights_cached_between_refreshes() {
        let mut st = PcmState::new(tiny_deployed(), PcmParams::default(), 1, 0.0);
        st.refresh_every_s = 1e9;
        let w1 = st.current_weights().0[0].data.clone();
        let w2 = st.current_weights().0[0].data.clone();
        assert_eq!(w1, w2);
    }

    #[test]
    fn initial_age_clamps_and_invalidates_cache() {
        let mut st = PcmState::new(tiny_deployed(), PcmParams::default(), 1, 0.0);
        st.refresh_every_s = 1e9;
        let fresh = st.current_weights().0[0].data.clone();
        st.set_initial_age(86_400.0);
        assert!(st.sim_age_s() >= 86_400.0);
        let aged = st.current_weights();
        assert!(aged.2, "cache must be invalidated by set_initial_age");
        assert_ne!(fresh, aged.0[0].data, "aged read must differ");
        // ages below t_c clamp up to t_c
        st.set_initial_age(0.0);
        assert!((st.sim_age_s() - crate::pcm::T_C_SECONDS).abs() < 1e-6);
    }

    #[test]
    fn weights_at_clamps_caches_and_ages() {
        let mut st = PcmState::new(tiny_deployed(), PcmParams::default(), 1, 0.0);
        st.refresh_every_s = 1e9;
        // clamped below t_c, and the clamped age is echoed back
        let (_, _, t, refreshed) = st.weights_at(0.0);
        assert!((t - crate::pcm::T_C_SECONDS).abs() < 1e-9);
        assert!(refreshed, "first read of an age is fresh");
        // same age within the refresh cadence reuses the cached read
        let day1 = st.weights_at(86_400.0);
        assert!(day1.3);
        let day1 = day1.0[0].data.clone();
        let day2 = st.weights_at(86_400.0);
        assert!(!day2.3, "same-age read within the cadence is a cache hit");
        assert_eq!(day1, day2.0[0].data, "same-age reads must hit the cache");
        // a different age is a fresh (and different) read
        let year = st.weights_at(31_536_000.0);
        assert!((year.2 - 31_536_000.0).abs() < 1e-6);
        let year = year.0[0].data.clone();
        assert_ne!(day1, year, "a year of drift must change the read");
        // the cache is multi-entry: alternating ages keep hitting
        assert!(!st.weights_at(86_400.0).3, "day entry survived the year read");
        assert!(!st.weights_at(31_536_000.0).3, "year entry still cached");
        // the explicit-age path must not disturb the clock-driven cache
        let clock = st.current_weights().0[0].data.clone();
        assert_ne!(clock, year);
    }

    #[test]
    fn applying_faults_invalidates_every_cache() {
        // the cache-staleness contract: after set_faults, no cached clean
        // read (clock-driven or explicit-age) may ever be served again —
        // mirrors the set_initial_age / reprogram invalidation
        let mut st = PcmState::new(tiny_deployed(), PcmParams::default(), 1, 0.0);
        st.refresh_every_s = 1e9;
        let clean_clock = st.current_weights().0[0].data.clone();
        let clean_aged = st.weights_at(86_400.0).0[0].data.clone();
        // sanity: both caches are now warm
        assert!(!st.current_weights().2);
        assert!(!st.weights_at(86_400.0).3);

        let spec = FaultSpec { stuck_max: 0.5, seed: 7, ..FaultSpec::none() };
        st.set_faults(spec);
        assert_eq!(st.faults(), spec);
        let clock = st.current_weights();
        assert!(clock.2, "clock cache must be invalidated by set_faults");
        let faulted_clock = clock.0[0].data.clone();
        assert_ne!(clean_clock, faulted_clock,
                   "stale clean weights must never be served");
        let aged = st.weights_at(86_400.0);
        assert!(aged.3, "aged cache must be invalidated by set_faults");
        assert_ne!(clean_aged, aged.0[0].data);

        // re-applying the same spec still invalidates (fresh jitter draw
        // semantics are the caller's concern; staleness is ours)
        st.set_faults(spec);
        assert!(st.current_weights().2);

        // calibration-geometry changes invalidate too
        st.set_calib_geom(Some(crate::crossbar::ArrayGeom::AON));
        assert!(st.current_weights().2,
                "calib geometry change must drop cached alphas");
        st.set_calib_geom(Some(crate::crossbar::ArrayGeom::AON));
        assert!(!st.current_weights().2, "same geometry is a no-op");
    }

    #[test]
    fn per_request_fault_scenarios_get_their_own_reads() {
        let mut st = PcmState::new(tiny_deployed(), PcmParams::default(), 1, 0.0);
        st.refresh_every_s = 1e9;
        let spec = FaultSpec { stuck_max: 0.5, seed: 3, ..FaultSpec::none() };
        let clean = st.weights_at(86_400.0).0[0].data.clone();
        let faulted = st.weights_at_spec(86_400.0, &spec);
        assert!(faulted.3, "a new scenario is a fresh read");
        let faulted = faulted.0[0].data.clone();
        assert_ne!(clean, faulted,
                   "half the cells stuck at G_max must change the read");
        // both cohorts stay cached side by side
        assert!(!st.weights_at(86_400.0).3, "clean cohort survived");
        assert!(!st.weights_at_spec(86_400.0, &spec).3,
                "faulted cohort cached");
        // the current-clock path serves non-default scenarios too
        let (_, _, age, _) = st.current_weights_spec(&spec);
        assert!(age >= crate::pcm::T_C_SECONDS);
        // an explicitly-none spec matches the (clean) deployment default
        let via_none = st.weights_at_spec(86_400.0, &FaultSpec::none());
        assert!(!via_none.3, "none-spec aliases the clean default cohort");
    }

    #[test]
    fn alpha_grows_as_clock_runs() {
        let st = PcmState::new(tiny_deployed(), PcmParams::default(), 1, 1e7);
        let a0 = st.mean_alpha();
        std::thread::sleep(std::time::Duration::from_millis(10));
        let a1 = st.mean_alpha();
        assert!(a1 >= a0, "{a0} -> {a1}");
    }
}
