//! PCM array state management: drift clock, periodic weight refresh,
//! GDC recalibration, and the reprogramming policy.

use std::time::Instant;

use crate::backend::HostTensor;
use crate::eval::{DeployedLayer, DeployedModel};
use crate::pcm::{gdc, PcmParams};
use crate::util::rng::Rng;

/// One cached explicit-age weight read (see [`PcmState::weights_at`]).
struct AgedRead {
    /// `f64::to_bits` of the clamped age — exact-match key
    age_key: u64,
    /// sim-clock time the read was taken (refresh-cadence staleness;
    /// deliberately NOT bumped on hits — that would freeze noise forever)
    read_at_s: f64,
    /// sim-clock time of the last hit (LRU eviction recency)
    last_used_s: f64,
    ws: Vec<HostTensor>,
    alphas: Vec<f32>,
}

/// Distinct device ages the explicit-age cache holds at once. Sized for
/// the expected shape of mixed-age traffic (a handful of cohorts in
/// steady rotation): with N <= this many ages alternating, every drain
/// hits the cache instead of re-sampling full-model read noise per group.
const AGED_CACHE_ENTRIES: usize = 4;

/// Live PCM state behind the serving loop.
pub struct PcmState {
    pub deployed: DeployedModel,
    pub params: PcmParams,
    rng: Rng,
    /// wall-clock origin of the current programming
    programmed_at: Instant,
    /// simulated seconds per wall second (always-on deployments run for
    /// months; examples accelerate the clock)
    pub time_scale: f64,
    /// simulated age offset (programming completes at t_c = 25 s)
    age_offset_s: f64,
    /// cached effective weights + GDC (refreshed on a simulated-time cadence)
    cached: Option<(Vec<HostTensor>, Vec<f32>)>,
    cached_at_s: f64,
    /// bounded cache for explicit-age reads ([`Self::weights_at`],
    /// per-request drift): up to `AGED_CACHE_ENTRIES` device ages, each
    /// reused until the refresh cadence elapses, LRU-evicted
    aged: Vec<AgedRead>,
    /// refresh cadence in simulated seconds
    pub refresh_every_s: f64,
    /// reprogram when the mean GDC factor exceeds this
    pub reprogram_alpha: f64,
    pub reprogram_count: u64,
    pub gdc_enabled: bool,
}

impl PcmState {
    pub fn new(deployed: DeployedModel, params: PcmParams, seed: u64,
               time_scale: f64) -> Self {
        PcmState {
            deployed,
            params,
            rng: Rng::new(seed),
            programmed_at: Instant::now(),
            time_scale,
            age_offset_s: crate::pcm::T_C_SECONDS,
            cached: None,
            cached_at_s: f64::NEG_INFINITY,
            aged: Vec::new(),
            refresh_every_s: 60.0,
            reprogram_alpha: 1.15,
            reprogram_count: 0,
            gdc_enabled: true,
        }
    }

    /// Current simulated device age in seconds.
    pub fn sim_age_s(&self) -> f64 {
        self.age_offset_s + self.programmed_at.elapsed().as_secs_f64() * self.time_scale
    }

    /// Start the drift clock at `age_s` simulated seconds after programming
    /// (drift-aware serving: bring the coordinator up against an array that
    /// has already aged a day or a year, `ServeConfig::drift_time`).
    /// Ages below t_c = 25 s clamp to t_c — devices are never read before
    /// programming settles. Invalidates the cached weight read so the next
    /// dispatch sees conductances drifted to the new age.
    pub fn set_initial_age(&mut self, age_s: f64) {
        self.age_offset_s = crate::pcm::clamp_age(age_s);
        self.cached = None;
        self.cached_at_s = f64::NEG_INFINITY;
        self.aged.clear();
    }

    /// Mean GDC factor right now (drift health indicator).
    pub fn mean_alpha(&self) -> f64 {
        let t = self.sim_age_s();
        let mut s = 0.0;
        let mut n = 0usize;
        for dl in &self.deployed.layers {
            if let DeployedLayer::Analog(p) = dl {
                s += gdc::alpha(p, t) as f64;
                n += 1;
            }
        }
        if n == 0 {
            1.0
        } else {
            s / n as f64
        }
    }

    /// Reprogram the array (fresh programming noise, drift clock reset).
    pub fn reprogram(&mut self, store: &crate::runtime::ArtifactStore,
                     vid: &str) -> anyhow::Result<()> {
        self.deployed =
            DeployedModel::program(store, vid, &self.params, &mut self.rng)?;
        self.programmed_at = Instant::now();
        self.cached = None;
        self.cached_at_s = f64::NEG_INFINITY;
        self.aged.clear();
        self.reprogram_count += 1;
        Ok(())
    }

    /// Effective weights + GDC for the current simulated time, refreshed on
    /// the configured cadence (fresh 1/f read noise on each refresh).
    /// The bool is true when this call performed a refresh.
    pub fn current_weights(&mut self) -> (&Vec<HostTensor>, &Vec<f32>, bool) {
        let t = self.sim_age_s();
        let mut refreshed = false;
        if self.cached.is_none() || t - self.cached_at_s >= self.refresh_every_s {
            let (ws, alphas) =
                self.deployed
                    .read_at(t, &self.params, &mut self.rng, self.gdc_enabled);
            self.cached = Some((ws, alphas));
            self.cached_at_s = t;
            refreshed = true;
        }
        let c = self.cached.as_ref().unwrap();
        (&c.0, &c.1, refreshed)
    }

    /// Effective weights + GDC at an **explicit** device age (per-request
    /// drift: `InferOpts::t_drift`), independent of the serving clock.
    /// Ages below t_c = 25 s clamp up to t_c; the clamped age is returned
    /// so responses can echo the age actually served. A bounded cache
    /// (`AGED_CACHE_ENTRIES` distinct ages, least-recently-*used*
    /// eviction) reuses each age's read until
    /// [`refresh_every_s`](Self::refresh_every_s) of simulated time
    /// elapses (fresh 1/f read noise after that — the same cadence the
    /// clock-driven [`current_weights`](Self::current_weights) cache
    /// follows), so a handful of age cohorts in steady rotation never
    /// re-sample noise per drain, and a one-shot odd age evicts the
    /// coldest cohort, not a hot one. The bool is true when this call
    /// performed a fresh read.
    pub fn weights_at(&mut self, age_s: f64)
                      -> (&Vec<HostTensor>, &Vec<f32>, f64, bool) {
        // same clamp the batch key applies, so key-equal requests are
        // guaranteed to be age-equal reads
        let t = crate::pcm::clamp_age(age_s);
        let age_key = t.to_bits();
        let now = self.sim_age_s();
        let hit = self
            .aged
            .iter()
            .position(|a| a.age_key == age_key
                && now - a.read_at_s < self.refresh_every_s);
        let (idx, refreshed) = match hit {
            Some(i) => (i, false),
            None => {
                let (ws, alphas) = self.deployed.read_at(
                    t, &self.params, &mut self.rng, self.gdc_enabled);
                let entry = AgedRead {
                    age_key,
                    read_at_s: now,
                    last_used_s: now,
                    ws,
                    alphas,
                };
                if let Some(i) =
                    self.aged.iter().position(|a| a.age_key == age_key)
                {
                    // cadence-expired entry for this age: refresh in place
                    self.aged[i] = entry;
                    (i, true)
                } else {
                    if self.aged.len() >= AGED_CACHE_ENTRIES {
                        // evict the least recently *used* age (hits bump
                        // last_used_s below, so hot cohorts survive a
                        // one-shot odd age)
                        let coldest = self
                            .aged
                            .iter()
                            .enumerate()
                            .min_by(|a, b| {
                                a.1.last_used_s.total_cmp(&b.1.last_used_s)
                            })
                            .map(|(i, _)| i)
                            .unwrap_or(0);
                        self.aged.swap_remove(coldest);
                    }
                    self.aged.push(entry);
                    (self.aged.len() - 1, true)
                }
            }
        };
        let a = &mut self.aged[idx];
        a.last_used_s = now;
        (&a.ws, &a.alphas, t, refreshed)
    }

    /// Whether the reprogramming policy should fire.
    pub fn needs_reprogram(&self) -> bool {
        self.mean_alpha() > self.reprogram_alpha
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::meta::ModelMeta;
    use crate::pcm::ProgrammedWeights;
    use crate::util::json;

    fn tiny_deployed() -> DeployedModel {
        let src = r#"{
          "model": "tiny", "variant": "t", "input_hwc": [1, 1, 4],
          "num_classes": 2, "eta": 0.0, "fp_test_acc": 1.0,
          "trained_adc_bits": null,
          "layers": [{"name": "fc", "kind": "dense", "in_ch": 4, "out_ch": 2,
            "stride": [1,1], "relu": false, "analog": true,
            "in_h": 1, "in_w": 1, "out_h": 1, "out_w": 1,
            "k_gemm": 4, "weight_shape": [4, 2], "graph_weight_shape": [4, 2],
            "w_scale": 1.0, "w_max": 1.0, "r_dac": 1.0, "r_adc": 4.0,
            "dig_scale": [1, 1], "dig_bias": [0, 0]}],
          "hlo": {}
        }"#;
        let meta = std::sync::Arc::new(
            ModelMeta::from_json(&json::parse(src).unwrap()).unwrap());
        let mut rng = Rng::new(5);
        let w: Vec<f32> = (0..8).map(|i| (i as f32 - 4.0) / 8.0).collect();
        let p = ProgrammedWeights::program(&w, 4, 2, 1.0, &PcmParams::default(),
                                           &mut rng);
        DeployedModel { meta, layers: vec![DeployedLayer::Analog(p)] }
    }

    #[test]
    fn sim_clock_advances_with_scale() {
        let st = PcmState::new(tiny_deployed(), PcmParams::default(), 1, 1e6);
        std::thread::sleep(std::time::Duration::from_millis(5));
        let age = st.sim_age_s();
        assert!(age > 25.0 + 1e3, "age={age}"); // 5ms * 1e6 = 5000s
    }

    #[test]
    fn weights_cached_between_refreshes() {
        let mut st = PcmState::new(tiny_deployed(), PcmParams::default(), 1, 0.0);
        st.refresh_every_s = 1e9;
        let w1 = st.current_weights().0[0].data.clone();
        let w2 = st.current_weights().0[0].data.clone();
        assert_eq!(w1, w2);
    }

    #[test]
    fn initial_age_clamps_and_invalidates_cache() {
        let mut st = PcmState::new(tiny_deployed(), PcmParams::default(), 1, 0.0);
        st.refresh_every_s = 1e9;
        let fresh = st.current_weights().0[0].data.clone();
        st.set_initial_age(86_400.0);
        assert!(st.sim_age_s() >= 86_400.0);
        let aged = st.current_weights();
        assert!(aged.2, "cache must be invalidated by set_initial_age");
        assert_ne!(fresh, aged.0[0].data, "aged read must differ");
        // ages below t_c clamp up to t_c
        st.set_initial_age(0.0);
        assert!((st.sim_age_s() - crate::pcm::T_C_SECONDS).abs() < 1e-6);
    }

    #[test]
    fn weights_at_clamps_caches_and_ages() {
        let mut st = PcmState::new(tiny_deployed(), PcmParams::default(), 1, 0.0);
        st.refresh_every_s = 1e9;
        // clamped below t_c, and the clamped age is echoed back
        let (_, _, t, refreshed) = st.weights_at(0.0);
        assert!((t - crate::pcm::T_C_SECONDS).abs() < 1e-9);
        assert!(refreshed, "first read of an age is fresh");
        // same age within the refresh cadence reuses the cached read
        let day1 = st.weights_at(86_400.0);
        assert!(day1.3);
        let day1 = day1.0[0].data.clone();
        let day2 = st.weights_at(86_400.0);
        assert!(!day2.3, "same-age read within the cadence is a cache hit");
        assert_eq!(day1, day2.0[0].data, "same-age reads must hit the cache");
        // a different age is a fresh (and different) read
        let year = st.weights_at(31_536_000.0);
        assert!((year.2 - 31_536_000.0).abs() < 1e-6);
        let year = year.0[0].data.clone();
        assert_ne!(day1, year, "a year of drift must change the read");
        // the cache is multi-entry: alternating ages keep hitting
        assert!(!st.weights_at(86_400.0).3, "day entry survived the year read");
        assert!(!st.weights_at(31_536_000.0).3, "year entry still cached");
        // the explicit-age path must not disturb the clock-driven cache
        let clock = st.current_weights().0[0].data.clone();
        assert_ne!(clock, year);
    }

    #[test]
    fn alpha_grows_as_clock_runs() {
        let st = PcmState::new(tiny_deployed(), PcmParams::default(), 1, 1e7);
        let a0 = st.mean_alpha();
        std::thread::sleep(std::time::Duration::from_millis(10));
        let a1 = st.mean_alpha();
        assert!(a1 >= a0, "{a0} -> {a1}");
    }
}
