//! The multi-model router: one worker thread, N model shards, one submit
//! API.
//!
//! [`MultiCoordinator::start`] takes a [`ShardConfig`] per model and
//! fail-fast-probes each one on the caller thread (missing variants, bad
//! fault specs, backends without serving graphs all error here, not
//! inside the worker). The router worker then builds every
//! [`Shard`](crate::coordinator::shard::Shard) *inside* its own thread —
//! backend trait objects never cross threads, so they need no `Send`
//! bound — and runs the serving loop:
//!
//! 1. block for the first message, route requests into their shard's
//!    staging queue;
//! 2. gather a shared batching window (`max_wait` of the first shard)
//!    until it expires or any shard's queue is full;
//! 3. drain in **weighted round-robin** passes with a rotating cursor:
//!    each pass grants every non-empty shard one quantum (its weight x
//!    its largest launch) before any shard gets a second turn, so a
//!    flooded model cannot starve a quiet one — the quiet model's
//!    requests are always at most one pass away from dispatch;
//! 4. per-shard drift maintenance (reprogram + re-probe).
//!
//! Admission control is per model: each shard bounds its in-flight
//! (admitted but not yet drained) requests at
//! [`ShardConfig::queue_depth`]; submits beyond the bound reject
//! immediately — counted both globally (`submit_rejects`) and per model —
//! instead of queueing without limit. That bound is what makes the
//! fairness guarantee real: a hot model's backlog is capped, so the
//! round-robin drain reaches the quiet model after a bounded amount of
//! work.
//!
//! Responses, metrics, and health probes keep per-model identity: the
//! ledger records req/s, mean batch, latency quantiles, rejects, and
//! modeled µJ/inf under each `model_id`
//! ([`MetricsSummary::per_model`](crate::coordinator::metrics::MetricsSummary)),
//! and [`MultiCoordinator::probe_health`] probes one named shard's
//! canary.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::backend::{self, BackendKind, InferOpts};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::server::{HealthReport, Request, Response};
use crate::coordinator::shard::{Shard, ShardConfig};
use crate::runtime::ArtifactStore;

/// What the router resolved about one served model at start time; the
/// submit path validates against this without touching the worker.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    /// name requests route on
    pub model_id: String,
    pub feat_len: usize,
    pub classes: usize,
    pub backend: BackendKind,
    pub bits: u32,
    /// resolved admission bound (a configured `queue_depth` of 0 becomes
    /// 4x the shard's largest launch)
    pub queue_depth: usize,
    /// weighted-round-robin share at drain time
    pub weight: u32,
}

enum RMsg {
    Req(usize, Request),
    Probe(usize, mpsc::Sender<HealthReport>),
    Stop,
}

/// Handle to a running multi-model router. The first configured shard is
/// the *primary*: wire requests without a `"model"` field route to it.
pub struct MultiCoordinator {
    tx: mpsc::Sender<RMsg>,
    handle: Option<JoinHandle<anyhow::Result<()>>>,
    pub metrics: Arc<Metrics>,
    models: Vec<ModelInfo>,
    /// per-shard in-flight (admitted, not yet drained) request counts:
    /// incremented at submit, decremented by the worker when a drain pops
    /// the requests off the staging queue
    depth: Arc<Vec<AtomicUsize>>,
}

impl MultiCoordinator {
    /// Start the router worker over one shard per config. Fails fast on
    /// the caller thread — per shard — for exactly the reasons
    /// [`Coordinator::start`](crate::coordinator::Coordinator::start)
    /// does: missing variant, invalid deployment fault spec, backend
    /// without serving graphs at the configured bits.
    pub fn start(shards: Vec<ShardConfig>)
                 -> anyhow::Result<MultiCoordinator> {
        anyhow::ensure!(!shards.is_empty(),
                        "MultiCoordinator needs at least one shard");
        for (i, a) in shards.iter().enumerate() {
            anyhow::ensure!(
                !shards[..i].iter().any(|b| b.model_id == a.model_id),
                "duplicate model id `{}`",
                a.model_id
            );
        }
        let metrics = Arc::new(Metrics::default());
        let mut models = Vec::with_capacity(shards.len());
        let mut resolved = Vec::with_capacity(shards.len());
        for mut sc in shards {
            let cfg = &sc.serve;
            let store = ArtifactStore::open(&cfg.artifacts_dir)?;
            let meta = store.meta(&cfg.vid)?;
            backend::validate_opts(cfg.backend, cfg.bits, &InferOpts {
                faults: Some(cfg.faults),
                ..InferOpts::default()
            })?;
            let (dynamic, largest) = {
                let be =
                    backend::create(cfg.backend, &store, &cfg.vid, cfg.bits)?;
                be.probe()?;
                anyhow::ensure!(
                    !be.batch_sizes().is_empty(),
                    "variant {} has no {}b serving graphs for backend `{}`",
                    cfg.vid,
                    cfg.bits,
                    be.name()
                );
                (be.supports_dynamic_batch(), *be.batch_sizes().last().unwrap())
            };
            let (ih, iw, ic) = meta.input_hwc;
            // resolve the admission bound with the same rule the shard
            // applies (4x the largest launch), so submit-side admission
            // and worker-side staging agree on one number
            let xcap = if dynamic && cfg.max_batch > 0 {
                cfg.max_batch
            } else {
                largest
            };
            let queue_depth =
                if sc.queue_depth > 0 { sc.queue_depth } else { xcap * 4 };
            sc.queue_depth = queue_depth;
            sc.weight = sc.weight.max(1);
            models.push(ModelInfo {
                model_id: sc.model_id.clone(),
                feat_len: ih * iw * ic,
                classes: meta.num_classes,
                backend: cfg.backend,
                bits: cfg.bits,
                queue_depth,
                weight: sc.weight,
            });
            resolved.push(sc);
        }
        let depth: Arc<Vec<AtomicUsize>> =
            Arc::new((0..resolved.len()).map(|_| AtomicUsize::new(0)).collect());
        // the batching window is a router-level knob: the primary shard's
        // max_wait governs the shared gather loop
        let max_wait = resolved[0].serve.max_wait;
        let (tx, rx) = mpsc::channel::<RMsg>();
        let m2 = metrics.clone();
        let d2 = depth.clone();
        let handle = std::thread::Builder::new()
            .name("aon-cim-router".into())
            .spawn(move || router_worker(resolved, rx, m2, d2, max_wait))?;
        Ok(MultiCoordinator { tx, handle: Some(handle), metrics, models, depth })
    }

    /// The models served, in configuration order (index 0 is the
    /// primary).
    pub fn models(&self) -> &[ModelInfo] {
        &self.models
    }

    /// The primary model: the default route for requests that name no
    /// model.
    pub fn primary(&self) -> &ModelInfo {
        &self.models[0]
    }

    /// Index of a model id in [`models`](Self::models), if served.
    pub fn model_index(&self, model: &str) -> Option<usize> {
        self.models.iter().position(|m| m.model_id == model)
    }

    fn model_list(&self) -> String {
        let ids: Vec<&str> =
            self.models.iter().map(|m| m.model_id.as_str()).collect();
        ids.join(", ")
    }

    /// Submit a request to a model by name. Unknown models, bad feature
    /// lengths, options the shard's backend cannot serve, and a full
    /// shard queue all reject here — counted per model — without ever
    /// reaching the worker.
    pub fn submit(&self, model: &str, features: Vec<f32>, opts: InferOpts)
                  -> anyhow::Result<mpsc::Receiver<Response>> {
        match self.model_index(model) {
            Some(idx) => self.submit_to(idx, features, opts),
            None => {
                self.metrics.submit_rejects.fetch_add(1, Ordering::Relaxed);
                anyhow::bail!("unknown model `{}` (serving: {})", model,
                              self.model_list())
            }
        }
    }

    /// Submit to a model by index (see [`model_index`](Self::model_index);
    /// the wire front end resolves the name once and routes by index).
    pub fn submit_to(&self, idx: usize, features: Vec<f32>, opts: InferOpts)
                     -> anyhow::Result<mpsc::Receiver<Response>> {
        let info = &self.models[idx];
        if features.len() != info.feat_len {
            self.reject(info);
            anyhow::bail!("bad feature length {} for model `{}` (wants {})",
                          features.len(), info.model_id, info.feat_len);
        }
        if let Err(e) = backend::validate_opts(info.backend, info.bits, &opts)
        {
            self.reject(info);
            return Err(e);
        }
        // per-model admission: claim an in-flight slot before sending; the
        // worker releases slots when a drain pops the requests. A full
        // shard rejects *this* model's submit — other models' lanes are
        // unaffected, which is the whole point of per-shard bounds.
        let d = &self.depth[idx];
        if d.fetch_add(1, Ordering::AcqRel) >= info.queue_depth {
            d.fetch_sub(1, Ordering::AcqRel);
            self.reject(info);
            anyhow::bail!("model `{}` queue full (depth {})", info.model_id,
                          info.queue_depth);
        }
        let (rtx, rrx) = mpsc::channel();
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        self.metrics.model_request(&info.model_id);
        self.tx
            .send(RMsg::Req(idx, Request {
                features,
                opts,
                reply: rtx,
                submitted: Instant::now(),
            }))
            .map_err(|_| {
                d.fetch_sub(1, Ordering::AcqRel);
                self.reject(info);
                anyhow::anyhow!("coordinator stopped")
            })?;
        Ok(rrx)
    }

    fn reject(&self, info: &ModelInfo) {
        self.metrics.submit_rejects.fetch_add(1, Ordering::Relaxed);
        self.metrics.model_reject(&info.model_id);
    }

    /// Blocking single inference against a named model.
    pub fn infer(&self, model: &str, features: Vec<f32>, opts: InferOpts)
                 -> anyhow::Result<Response> {
        let rx = self.submit(model, features, opts)?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("coordinator dropped request"))
    }

    /// Run a health probe on one named shard now and return its report
    /// (the canary replay described at
    /// [`Coordinator::probe_health`](crate::coordinator::Coordinator::probe_health),
    /// scoped to that model's engine and PCM state).
    pub fn probe_health(&self, model: &str) -> anyhow::Result<HealthReport> {
        let idx = self.model_index(model).ok_or_else(|| {
            anyhow::anyhow!("unknown model `{}` (serving: {})", model,
                            self.model_list())
        })?;
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(RMsg::Probe(idx, rtx))
            .map_err(|_| anyhow::anyhow!("coordinator stopped"))?;
        rrx.recv().map_err(|_| anyhow::anyhow!("coordinator stopped"))
    }

    /// Graceful-shutdown hook for shared (`Arc`-held) routers: ask the
    /// worker to finish the current window and exit. Later submits fail
    /// with "coordinator stopped" (and count as submit rejects).
    pub fn request_stop(&self) {
        let _ = self.tx.send(RMsg::Stop);
    }

    pub fn stop(mut self) -> anyhow::Result<()> {
        let _ = self.tx.send(RMsg::Stop);
        if let Some(h) = self.handle.take() {
            h.join()
                .map_err(|_| anyhow::anyhow!("router worker panicked"))??;
        }
        Ok(())
    }
}

impl Drop for MultiCoordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(RMsg::Stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn router_worker(cfgs: Vec<ShardConfig>, rx: mpsc::Receiver<RMsg>,
                 metrics: Arc<Metrics>, depth: Arc<Vec<AtomicUsize>>,
                 max_wait: Duration) -> anyhow::Result<()> {
    // shards are built on this thread: each owns its backend (PJRT
    // handles, when in play, stay on-thread) and runs its startup probe
    let mut shards = Vec::with_capacity(cfgs.len());
    for (i, sc) in cfgs.into_iter().enumerate() {
        shards.push(Shard::build(sc, i, true, &metrics)?);
    }
    let n = shards.len();
    let mut cursor = 0usize;
    let mut stopping = false;

    while !stopping {
        // block for the first message
        match rx.recv() {
            Ok(RMsg::Req(i, r)) => shards[i].queue.push(r),
            Ok(RMsg::Probe(i, reply)) => {
                let hr = shards[i].probe_now(&metrics)?;
                let _ = reply.send(hr);
                continue;
            }
            Ok(RMsg::Stop) | Err(_) => break,
        }
        // shared batching window: gather more until max_wait expires or
        // any shard's staging queue fills (admission caps each at its
        // queue_depth, so "full" is bounded per model)
        let deadline = Instant::now() + max_wait;
        while shards.iter().all(|s| s.queue.len() < s.max_queue) {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(RMsg::Req(i, r)) => shards[i].queue.push(r),
                Ok(RMsg::Probe(i, reply)) => {
                    let hr = shards[i].probe_now(&metrics)?;
                    let _ = reply.send(hr);
                }
                // a stop mid-window still drains what was admitted below
                Ok(RMsg::Stop) => {
                    stopping = true;
                    break;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    stopping = true;
                    break;
                }
            }
        }
        // weighted round-robin drain with a rotating cursor: every
        // non-empty shard gets one quantum per pass, the pass origin
        // rotates so no shard is systematically first, and the loop runs
        // until every staging queue is empty — the shared worker budget
        // is divided by weight, never monopolized
        loop {
            let mut any = false;
            for k in 0..n {
                let i = (cursor + k) % n;
                let popped = shards[i].drain_chunk(&metrics)?;
                if popped > 0 {
                    depth[i].fetch_sub(popped, Ordering::AcqRel);
                    any = true;
                }
            }
            cursor = (cursor + 1) % n;
            if !any {
                break;
            }
        }
        // per-shard drift management between dispatches
        for s in shards.iter_mut() {
            s.maintain(&metrics)?;
        }
    }
    Ok(())
}
