//! One model shard: everything a single served model owns — its
//! [`InferenceBackend`], its [`PcmState`] (drift clock, fault scenario,
//! refresh cadence), its [`ScheduleModel`] pricing, its canary health
//! probe, and its staging queue — plus the drain machinery that turns a
//! queue of [`Request`]s into batched launches.
//!
//! The single-model [`Coordinator`](crate::coordinator::Coordinator)
//! worker and the multi-model
//! [`MultiCoordinator`](crate::coordinator::MultiCoordinator) router are
//! both thin loops over this module: the coordinator drives exactly one
//! shard and drains it whole, the router owns N shards and drains them in
//! weighted round-robin quanta so one hot model cannot starve another.
//! Batch grouping always keys on [`batcher::model_batch_key`] — the
//! per-request [`InferOpts`] key extended with the shard's model index —
//! so two models can never share a launch even if their option sets
//! collide.

use std::sync::atomic::Ordering;

use crate::backend::{self, BackendKind, HostTensor, InferOpts,
                     InferenceBackend};
use crate::coordinator::batcher;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::server::{HealthReport, Request, Response,
                                 ServeConfig};
use crate::coordinator::state::PcmState;
use crate::crossbar::ArrayGeom;
use crate::eval::DeployedModel;
use crate::nn::{expand_dw_dense, LayerKind};
use crate::pcm::PcmParams;
use crate::runtime::ArtifactStore;
use crate::timing::ScheduleModel;
use crate::util::logits;
use crate::util::rng::Rng;
use std::time::Instant;

/// Configuration of one model shard inside a
/// [`MultiCoordinator`](crate::coordinator::MultiCoordinator): the full
/// single-model [`ServeConfig`] (every knob — backend, bits, faults,
/// drift clock, SLO — stays per model) plus the shard-level scheduling
/// knobs the router adds on top.
#[derive(Clone, Debug)]
pub struct ShardConfig {
    /// name requests route on (`submit(model_id, ..)`, the wire `"model"`
    /// field); conventionally the artifact variant id
    pub model_id: String,
    /// the shard's own serving configuration — exactly what a standalone
    /// [`Coordinator`](crate::coordinator::Coordinator) would take
    pub serve: ServeConfig,
    /// admission bound: maximum in-flight (admitted but not yet drained)
    /// requests for this model; `0` = automatic (4x the largest launch,
    /// the same staging bound the single-model coordinator uses). Submits
    /// beyond the bound are rejected — counted per model — instead of
    /// growing the queue without limit.
    pub queue_depth: usize,
    /// weighted-round-robin share at drain time: each drain pass grants
    /// this shard `weight x` its largest launch before moving on (min 1)
    pub weight: u32,
}

impl ShardConfig {
    pub fn new(model_id: &str, serve: ServeConfig) -> Self {
        ShardConfig {
            model_id: model_id.to_string(),
            serve,
            queue_depth: 0,
            weight: 1,
        }
    }
}

/// Everything the drain path needs besides the queue and the PCM state;
/// resolved once at shard build, never on the dispatch path. Owns no
/// borrows, so the shard can hand out `(&mut DispatchState, &dyn
/// InferenceBackend, &mut PcmState)` as disjoint field borrows.
pub(crate) struct DispatchState {
    /// static launch shapes (ascending), for the padded plan
    pub(crate) batch_sizes: Vec<usize>,
    /// true: FIFO zero-padding plan over `max_batch`-sized chunks
    pub(crate) dynamic: bool,
    pub(crate) max_batch: usize,
    /// reusable input buffer (largest launch) — no hot-path allocation
    pub(crate) xbuf: Vec<f32>,
    pub(crate) feat_len: usize,
    pub(crate) classes: usize,
    /// modeled AON-CiM launch schedule for the served model: prices every
    /// launch (nJ, ns) for the metrics ledger and, when `slo_us` is set,
    /// picks each group's operating point
    pub(crate) sched: ScheduleModel,
    /// `ServeConfig::latency_slo_us` — `None` keeps the fixed-config
    /// batcher
    pub(crate) slo_us: Option<f64>,
    /// latest health-probe verdict: while true, every response dispatched
    /// counts under `Metrics::degraded_responses` (the shard keeps
    /// serving — degradation is graceful, not fatal)
    pub(crate) degraded: bool,
    /// weight refreshes observed by THIS shard's drains and probes; the
    /// re-probe-on-refresh logic tracks this instead of the global
    /// `Metrics::weight_refreshes` counter so co-resident shards cannot
    /// trigger each other's probes
    pub(crate) refresh_events: u64,
    /// position in the router's shard table, folded into every batch key
    /// ([`batcher::model_batch_key`]) so launches never mix models
    pub(crate) model_idx: usize,
    /// `Some(model_id)`: record per-model metrics under this label
    /// (multi-model serving); `None` keeps the single-model ledger exactly
    /// as before sharding existed
    pub(crate) model_label: Option<String>,
}

/// Drain a staging queue: partition by per-request options (and the
/// shard's model index), then execute each group as its own launch
/// sequence. With uniform options (the common case) the queue is executed
/// in place with zero grouping allocations. The queue is empty on return.
pub(crate) fn drain(ds: &mut DispatchState, be: &dyn InferenceBackend,
                    metrics: &Metrics, state: &mut PcmState,
                    queue: &mut Vec<Request>) -> anyhow::Result<()> {
    if queue.is_empty() {
        return Ok(());
    }
    // fast path: uniform options (the overwhelmingly common case, and
    // everything that existed before per-request options)
    let k0 = batcher::model_batch_key(ds.model_idx, &queue[0].opts);
    if queue
        .iter()
        .all(|r| batcher::model_batch_key(ds.model_idx, &r.opts) == k0)
    {
        drain_group(ds, be, metrics, state, queue)?;
        queue.clear();
        return Ok(());
    }
    // mixed options: partition into option-homogeneous groups.
    // drain(..) (not mem::take) keeps the queue's preallocated capacity
    // alive across windows.
    let drained: Vec<Request> = queue.drain(..).collect();
    let groups = batcher::group_fifo(drained, |r| {
        batcher::model_batch_key(ds.model_idx, &r.opts)
    });
    for group in groups {
        drain_group(ds, be, metrics, state, &group)?;
    }
    Ok(())
}

/// Execute one option-homogeneous group of requests.
fn drain_group(ds: &mut DispatchState, be: &dyn InferenceBackend,
               metrics: &Metrics, state: &mut PcmState, group: &[Request])
               -> anyhow::Result<()> {
    let opts = group[0].opts;
    // operating point for this group: without an SLO it is exactly the
    // fixed config (requested bits, configured max_batch); with one, the
    // modeled launch schedule caps the batch — and, for requests that
    // opted into a bitwidth range, may lower the bits — so the modeled
    // accelerator latency of every launch stays within the SLO
    let base_bits = opts.effective_bits(be.bits());
    let (adc_bits, cap) = match ds.slo_us {
        Some(slo) => batcher::slo_operating_point(&ds.sched, slo,
                                                  opts.adc_bits_floor,
                                                  base_bits, ds.max_batch),
        None => (base_bits, ds.max_batch),
    };
    let plan = if ds.dynamic {
        batcher::plan_dynamic(group.len(), cap)
    } else {
        // static-shape engines keep their exported-graph launch sizes
        // (the SLO cannot resize a compiled graph); the estimator still
        // prices each launch below
        batcher::plan(group.len(), ds.batch_sizes.clone())
    };
    metrics
        .padded_slots
        .fetch_add(plan.padding as u64, Ordering::Relaxed);

    // which fault scenario this group serves under: the request's own
    // spec when it carries one, the deployment default otherwise
    let spec = opts.faults.unwrap_or_else(|| state.faults());
    // effective weights for this group's device age and scenario: an
    // explicit-age read for `t_drift` requests, the clock-driven cache
    // otherwise. Either way the borrow is straight out of the state
    // cache — no per-drain clone of the full weight set (the PJRT path
    // copies inside run_batch, the native paths read the slices in
    // place).
    let (ws, alphas, sim_age, refreshed) = match opts.t_drift {
        Some(t) => state.weights_at_spec(t, &spec),
        None => state.current_weights_spec(&spec),
    };
    if refreshed {
        metrics.weight_refreshes.fetch_add(1, Ordering::Relaxed);
        ds.refresh_events += 1;
        // a refresh is one full single-sample read+calibrate pass on the
        // array; charge its modeled energy so amortized µJ/inf reflects
        // the maintenance the accelerator actually performed
        metrics.add_modeled_overhead_nj(ds.sched.refresh_nj());
    }
    // the ADC-side faults execute inside the backend, so the resolved
    // scenario must ride the launch options (weight-side faults already
    // live in the conductances read above); a none-equivalent spec stays
    // out so the clean path is bit-identical to pre-fault serving. The
    // operating-point bits are pinned explicitly: with an SLO they may
    // sit below the request's own bits (opt-in floor), and the response
    // echoes what actually ran.
    let run_opts = InferOpts {
        faults: (!spec.is_none()).then_some(spec),
        adc_bits: Some(adc_bits),
        ..opts
    };

    let feat_len = ds.feat_len;
    let mut taken = 0usize;
    for &launch in &plan.launches {
        let count = launch.min(group.len() - taken);

        let xb = &mut ds.xbuf[..launch * feat_len];
        for (i, r) in group[taken..taken + count].iter().enumerate() {
            xb[i * feat_len..(i + 1) * feat_len].copy_from_slice(&r.features);
        }
        for i in count..launch {
            // pad with the first request's features (static plans only;
            // dynamic launches are always exact)
            let (a, b) = xb.split_at_mut(i * feat_len);
            b[..feat_len].copy_from_slice(&a[..feat_len]);
        }

        let out = be.run_batch(xb, launch, ws, alphas, &run_opts)?;
        metrics.launches.fetch_add(1, Ordering::Relaxed);
        metrics
            .batched_slots
            .fetch_add(count as u64, Ordering::Relaxed);
        // price the launch actually dispatched (padded slots execute too,
        // so the full `launch` is charged) and amortize it over the
        // `count` real responses it carried — padding shows up as a
        // higher modeled µJ/inf, exactly as it would on silicon
        let ls = ds.sched.launch(launch, adc_bits);
        metrics.add_modeled_launch(ds.sched.model(), adc_bits, count as u64,
                                   ls.energy_nj, ls.ops);
        if let Some(label) = &ds.model_label {
            metrics.model_launch(label, count as u64, ls.energy_nj);
        }
        if ds.degraded {
            metrics
                .degraded_responses
                .fetch_add(count as u64, Ordering::Relaxed);
        }

        let now = Instant::now();
        for (i, r) in group[taken..taken + count].iter().enumerate() {
            let row = &out[i * ds.classes..(i + 1) * ds.classes];
            let pred = logits::argmax(row);
            // account BEFORE replying: clients must observe settled
            // metrics
            let lat_us = (now - r.submitted).as_secs_f64() * 1e6;
            metrics.completed.fetch_add(1, Ordering::Relaxed);
            metrics.record_latency_us(lat_us);
            metrics.add_energy_nj(ls.energy_nj / count as f64);
            if let Some(label) = &ds.model_label {
                metrics.model_completed(label, lat_us);
            }
            let _ = r.reply.send(Response {
                pred,
                logits: row.to_vec(),
                latency: now - r.submitted,
                sim_age_s: sim_age,
                adc_bits,
            });
        }
        taken += count;
    }
    Ok(())
}

/// The shard's canary: a deterministic synthetic batch plus the clean
/// native reference predictions it was graded against at startup. The
/// probe replays `x` through the *serving* engine (current device age,
/// default fault scenario) and counts argmax agreement — a cheap
/// end-to-end spot-check that the analog path still computes the same
/// answers as an ideal digital execution.
pub(crate) struct Canary {
    x: Vec<f32>,
    n: usize,
    ref_preds: Vec<u32>,
}

/// Run one health probe: serve the canary batch under the deployment
/// default and grade it against the clean reference. Updates the probe
/// counters and the dispatch state's `degraded` flag.
pub(crate) fn probe(be: &dyn InferenceBackend, state: &mut PcmState,
                    canary: &Canary, ds: &mut DispatchState,
                    metrics: &Metrics) -> anyhow::Result<HealthReport> {
    let spec = state.faults();
    let popts = InferOpts {
        faults: (!spec.is_none()).then_some(spec),
        ..InferOpts::default()
    };
    let (ws, alphas, refreshed) = state.current_weights();
    if refreshed {
        metrics.weight_refreshes.fetch_add(1, Ordering::Relaxed);
        ds.refresh_events += 1;
    }
    let out = be.run_batch(&canary.x, canary.n, ws, alphas, &popts)?;
    let agree = (0..canary.n)
        .filter(|&i| {
            logits::argmax(&out[i * ds.classes..(i + 1) * ds.classes])
                == canary.ref_preds[i]
        })
        .count();
    // degraded below 3/4 agreement: drift read noise may flip a borderline
    // canary, a stuck-cell cluster flips most of them
    let degraded = agree * 4 < canary.n * 3;
    metrics.health_probes.fetch_add(1, Ordering::Relaxed);
    metrics.canary_agree.fetch_add(agree as u64, Ordering::Relaxed);
    metrics.canary_total.fetch_add(canary.n as u64, Ordering::Relaxed);
    ds.degraded = degraded;
    Ok(HealthReport { canary: canary.n, agree, degraded })
}

/// One running model shard. Built *inside* the owning worker thread (the
/// backend trait object never crosses a thread boundary, so it needs no
/// `Send` bound), it owns the backend, the PCM state, the artifact store
/// (for reprogramming), the canary, and the staging queue.
pub(crate) struct Shard {
    pub(crate) cfg: ServeConfig,
    pub(crate) store: ArtifactStore,
    pub(crate) be: Box<dyn InferenceBackend>,
    pub(crate) state: PcmState,
    pub(crate) ds: DispatchState,
    pub(crate) canary: Canary,
    /// requests routed to this shard, staged until the next drain
    pub(crate) queue: Vec<Request>,
    /// staging bound: the batching window stops gathering when any
    /// shard's queue reaches this (also the admission bound the router
    /// enforces at submit)
    pub(crate) max_queue: usize,
    /// requests one weighted-round-robin turn may pop (`weight x` the
    /// largest launch)
    pub(crate) quantum: usize,
    /// reused per-chunk drain buffer (weighted draining pops the front of
    /// `queue` into it, preserving FIFO order)
    scratch: Vec<Request>,
    /// `ds.refresh_events` at the last probe: re-probe when they diverge
    probed_at_refresh: u64,
}

impl Shard {
    /// Build the shard and run its startup probe. Mirrors everything the
    /// pre-shard coordinator worker resolved at start: backend creation,
    /// graph preparation, schedule pricing, PCM programming, canary
    /// grading against a clean native reference.
    pub(crate) fn build(sc: ShardConfig, model_idx: usize, per_model: bool,
                        metrics: &Metrics) -> anyhow::Result<Shard> {
        let model_id = sc.model_id;
        let cfg = sc.serve;
        // the shard owns the artifact store and the backend (PJRT
        // handles, when in play, stay on-thread)
        let store = ArtifactStore::open(&cfg.artifacts_dir)?;
        let be = backend::create_with_threads(cfg.backend, &store, &cfg.vid,
                                              cfg.bits, cfg.threads)?;
        // model geometry is invariant across launches: resolve it once
        // here, never on the dispatch path
        let feat_len = be.feat_len();
        let classes = be.num_classes();

        // serving batch sizes available at this bitwidth (ascending, per
        // the trait contract). The coordinator/router start paths already
        // rejected an empty set with a descriptive error; this only guards
        // against the artifact bundle changing on disk between the probe
        // and the worker's re-open.
        let batch_sizes = be.batch_sizes();
        anyhow::ensure!(
            !batch_sizes.is_empty(),
            "serving graphs for {} disappeared between probe and worker start",
            cfg.vid
        );
        // compile/load every batch size up front (never on the hot path)
        for &b in &batch_sizes {
            be.prepare(b)?;
        }

        // modeled AON-CiM launch schedule for this deployment: the
        // backend's own geometry when it reports one (native/analog —
        // identical on the default AON array), the AON mapping otherwise
        // (PJRT). Resolved once here; the dispatch path only evaluates
        // closed-form per-launch costs.
        let meta = store.meta(&cfg.vid)?;
        let sched = match be.schedule_model() {
            Some(s) => s,
            None => ScheduleModel::new(&meta, ArrayGeom::AON)?,
        };

        // deploy onto PCM
        let params = PcmParams::default();
        let mut rng = Rng::new(cfg.seed);
        let deployed =
            DeployedModel::program(&store, &cfg.vid, &params, &mut rng)?;
        let mut state =
            PcmState::new(deployed, params, cfg.seed ^ 0xD1F7, cfg.time_scale);
        state.refresh_every_s = cfg.refresh_every_s;
        // deployment-default fault scenario + per-tile calibration target,
        // both installed before the clock starts so the first read already
        // serves the faulted, tile-calibrated array
        state.set_faults(cfg.faults);
        state.set_calib_geom(be.calib_geom());
        state.set_initial_age(cfg.drift_time);

        let dynamic = be.supports_dynamic_batch();
        let largest_static = *batch_sizes.last().unwrap();
        let max_batch = if cfg.max_batch > 0 {
            cfg.max_batch
        } else {
            largest_static
        };
        // largest single launch either plan can produce, sizing the input
        // buffer
        let xcap = if dynamic { max_batch } else { largest_static };
        if dynamic {
            be.prepare(max_batch)?;
        }
        // canary batch for the health probe: deterministic synthetic
        // features (a function of the seed alone), graded once against
        // the exact FP weights on the clean native engine. Static-shape
        // engines probe at their smallest exported graph size; dynamic
        // engines use 4 samples.
        let canary_n =
            if dynamic { 4.min(max_batch.max(1)) } else { batch_sizes[0] };
        let canary = {
            let mut crng = Rng::new(cfg.seed ^ 0xCA9A_11A5);
            let x: Vec<f32> = (0..canary_n * feat_len)
                .map(|_| crng.uniform() as f32)
                .collect();
            let tensors = store.weights(&cfg.vid)?;
            let mut exact = Vec::with_capacity(tensors.len());
            for (lm, t) in meta.layers.iter().zip(tensors.iter()) {
                // same depthwise expansion the PCM programming applies, so
                // the reference sees the exact weights in the deployed
                // layout
                if lm.analog && lm.kind == LayerKind::Dw3x3 {
                    exact.push(HostTensor::from_tensor(&expand_dw_dense(t)));
                } else {
                    exact.push(HostTensor::from_tensor(t));
                }
            }
            let unity = crate::pcm::gdc::unity(exact.len());
            let nref = backend::create_with_threads(BackendKind::Native,
                                                    &store, &cfg.vid,
                                                    cfg.bits, 1)?;
            nref.prepare(canary_n)?;
            let rout = nref.run_batch(&x, canary_n, &exact, &unity,
                                      &InferOpts::default())?;
            let ref_preds: Vec<u32> = (0..canary_n)
                .map(|i| logits::argmax(&rout[i * classes..(i + 1) * classes]))
                .collect();
            Canary { x, n: canary_n, ref_preds }
        };

        let max_queue = if sc.queue_depth > 0 {
            sc.queue_depth
        } else {
            xcap * 4
        };
        let quantum = sc.weight.max(1) as usize * xcap;
        let ds = DispatchState {
            batch_sizes,
            dynamic,
            max_batch,
            xbuf: vec![0f32; xcap * feat_len],
            feat_len,
            classes,
            sched,
            slo_us: cfg.latency_slo_us,
            degraded: false,
            refresh_events: 0,
            model_idx,
            model_label: per_model.then_some(model_id),
        };
        let mut shard = Shard {
            cfg,
            store,
            be,
            state,
            ds,
            canary,
            queue: Vec::with_capacity(max_queue),
            max_queue,
            quantum,
            scratch: Vec::with_capacity(quantum),
            probed_at_refresh: 0,
        };
        // startup probe: the verdict on the just-deployed (possibly
        // faulted) array, before any traffic is served under it
        shard.probe_now(metrics)?;
        Ok(shard)
    }

    /// Drain the whole staging queue (single-model coordinator
    /// semantics).
    pub(crate) fn drain_all(&mut self, metrics: &Metrics)
                            -> anyhow::Result<()> {
        drain(&mut self.ds, self.be.as_ref(), metrics, &mut self.state,
              &mut self.queue)
    }

    /// Pop and serve at most one weighted-round-robin quantum from the
    /// queue front (FIFO within the shard). Returns how many requests
    /// were popped so the router can release their admission slots.
    pub(crate) fn drain_chunk(&mut self, metrics: &Metrics)
                              -> anyhow::Result<usize> {
        let n = self.queue.len().min(self.quantum);
        if n == 0 {
            return Ok(0);
        }
        self.scratch.extend(self.queue.drain(..n));
        drain(&mut self.ds, self.be.as_ref(), metrics, &mut self.state,
              &mut self.scratch)?;
        Ok(n)
    }

    /// Run a health probe now (startup, on demand, after weight
    /// movement).
    pub(crate) fn probe_now(&mut self, metrics: &Metrics)
                            -> anyhow::Result<HealthReport> {
        let hr = probe(self.be.as_ref(), &mut self.state, &self.canary,
                       &mut self.ds, metrics)?;
        self.probed_at_refresh = self.ds.refresh_events;
        Ok(hr)
    }

    /// Post-drain drift management: reprogram the array when the GDC says
    /// so, then re-probe whenever the served weights moved since the last
    /// verdict (cadence refresh or the reprogram) — the health answer is
    /// a property of the weights actually being served.
    pub(crate) fn maintain(&mut self, metrics: &Metrics)
                           -> anyhow::Result<()> {
        let mut reprogrammed = false;
        if self.cfg.reprogram && self.state.needs_reprogram() {
            self.state.reprogram(&self.store, &self.cfg.vid)?;
            // a reprogram rewrites every allocated cell: charge its
            // modeled energy as serving overhead so amortized µJ/inf
            // carries the maintenance cost of keeping the array in spec
            metrics.add_modeled_overhead_nj(self.ds.sched.reprogram_nj());
            reprogrammed = true;
        }
        if reprogrammed || self.ds.refresh_events != self.probed_at_refresh {
            self.probe_now(metrics)?;
        }
        Ok(())
    }
}
