//! Dynamic batching policy.
//!
//! The exported serving graphs come in a few fixed batch sizes (XLA shapes
//! are static); the batcher packs the waiting queue into the cheapest
//! sequence of graph launches, padding the tail.

/// A planned sequence of graph launches for `queued` requests.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchPlan {
    /// batch sizes to launch, largest first
    pub launches: Vec<usize>,
    /// padded slots in the final launch
    pub padding: usize,
}

/// Greedy plan: repeatedly take the largest graph <= remaining, then one
/// final padded launch with the smallest graph that fits the tail.
pub fn plan(queued: usize, mut sizes: Vec<usize>) -> BatchPlan {
    assert!(!sizes.is_empty());
    sizes.sort_unstable();
    let mut launches = Vec::new();
    let mut left = queued;
    let largest = *sizes.last().unwrap();
    while left >= largest {
        launches.push(largest);
        left -= largest;
    }
    let mut padding = 0;
    if left > 0 {
        let fit = sizes.iter().copied().find(|&s| s >= left).unwrap_or(largest);
        padding = fit - left;
        launches.push(fit);
    }
    BatchPlan { launches, padding }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_fit() {
        let p = plan(32, vec![1, 8, 32]);
        assert_eq!(p.launches, vec![32]);
        assert_eq!(p.padding, 0);
    }

    #[test]
    fn mixed_fit() {
        let p = plan(70, vec![1, 8, 32]);
        assert_eq!(p.launches, vec![32, 32, 8]);
        assert_eq!(p.padding, 2);
    }

    #[test]
    fn single() {
        let p = plan(1, vec![1, 8, 32]);
        assert_eq!(p.launches, vec![1]);
        assert_eq!(p.padding, 0);
    }

    #[test]
    fn pads_to_smallest_fitting() {
        let p = plan(3, vec![1, 8, 32]);
        assert_eq!(p.launches, vec![8]);
        assert_eq!(p.padding, 5);
    }

    #[test]
    fn covers_all_requests() {
        for q in 1..200 {
            let p = plan(q, vec![1, 8, 32]);
            let total: usize = p.launches.iter().sum();
            assert_eq!(total, q + p.padding, "q={q}");
        }
    }
}
