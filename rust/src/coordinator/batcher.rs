//! Dynamic batching policy.
//!
//! Two planners, chosen by the backend's shape constraints
//! (`InferenceBackend::supports_dynamic_batch`):
//!
//! * [`plan`] — the exported serving graphs come in a few fixed batch sizes
//!   (XLA shapes are static); pack the waiting queue into the cheapest
//!   sequence of graph launches, padding the tail.
//! * [`plan_dynamic`] — the native layer-serial engine accepts any batch;
//!   drain the queue FIFO into chunks of at most `max_batch` with zero
//!   padded slots.
//!
//! Before either planner runs, the drained queue is partitioned by
//! per-request options ([`group_fifo`]): a launch executes under exactly
//! one `InferOpts` (one device age, one ADC bitwidth), so requests with
//! differing options never share a batch.
//!
//! When the coordinator runs with `ServeConfig::latency_slo_us`, the
//! per-group batch cap (and, for requests that opted into a bitwidth
//! range, the launch bitwidth) comes from the launch-schedule estimator
//! instead of the fixed config — see [`slo_operating_point`].

use crate::timing::ScheduleModel;

/// A planned sequence of graph launches for `queued` requests.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchPlan {
    /// batch sizes to launch, largest first
    pub launches: Vec<usize>,
    /// padded slots in the final launch
    pub padding: usize,
}

/// Greedy plan: repeatedly take the largest graph <= remaining, then one
/// final padded launch with the smallest graph that fits the tail.
pub fn plan(queued: usize, mut sizes: Vec<usize>) -> BatchPlan {
    assert!(!sizes.is_empty());
    sizes.sort_unstable();
    let mut launches = Vec::new();
    let mut left = queued;
    let largest = *sizes.last().unwrap();
    while left >= largest {
        launches.push(largest);
        left -= largest;
    }
    let mut padding = 0;
    if left > 0 {
        let fit = sizes.iter().copied().find(|&s| s >= left).unwrap_or(largest);
        padding = fit - left;
        launches.push(fit);
    }
    BatchPlan { launches, padding }
}

/// Partition `items` into launch-compatible groups: two items share a
/// group iff their keys are equal, FIFO order is preserved within each
/// group, and groups are ordered by first arrival. The serving drain uses
/// this with [`InferOpts::batch_key`](crate::backend::InferOpts::batch_key)
/// so requests with differing per-request options land in separate
/// batches; with uniform keys it degenerates to one group (the
/// pre-options drain, unchanged).
pub fn group_fifo<T, K: PartialEq>(items: Vec<T>,
                                   key: impl Fn(&T) -> K) -> Vec<Vec<T>> {
    let mut groups: Vec<(K, Vec<T>)> = Vec::new();
    for it in items {
        let k = key(&it);
        match groups.iter_mut().find(|(gk, _)| *gk == k) {
            Some((_, g)) => g.push(it),
            None => groups.push((k, vec![it])),
        }
    }
    groups.into_iter().map(|(_, g)| g).collect()
}

/// The batch key the serving drain actually groups on: the per-request
/// [`InferOpts::batch_key`](crate::backend::InferOpts::batch_key)
/// extended with the shard's model index. Two requests share a launch iff
/// their options AND their model agree — a multi-model router can never
/// mix models into one launch even when their option sets collide
/// (single-model coordinators pass index 0, which degenerates to the
/// plain options key).
pub fn model_batch_key(model_idx: usize,
                       opts: &crate::backend::InferOpts)
                       -> (usize, (u64, u32, u32, u64)) {
    (model_idx, opts.batch_key())
}

/// The SLO policy: pick one launch-compatible group's operating point
/// `(adc_bits, batch cap)` from the modeled launch schedule.
///
/// * Requests pinned to one bitwidth (`floor_bits == None`) keep it;
///   the estimator only caps the batch so the modeled launch latency
///   stays within `slo_us` ([`ScheduleModel::max_batch_within`]).
/// * Requests that permitted a range (`InferOpts::adc_bits_floor`) may
///   additionally be requantized: the policy keeps the highest bitwidth
///   in `[floor, bits]` whose single-inference modeled latency fits the
///   SLO, then batches at that bitwidth ([`ScheduleModel::choose`]).
///
/// Deterministic for fixed shapes: the estimator is a pure function of
/// the mapping, never of host speed. The cap is a *planning* bound — an
/// impossible SLO still serves batch-1 rather than rejecting.
pub fn slo_operating_point(sched: &ScheduleModel, slo_us: f64,
                           floor_bits: Option<u32>, bits: u32,
                           cap: usize) -> (u32, usize) {
    match floor_bits {
        Some(floor) => sched.choose(slo_us, floor, bits, cap),
        None => (bits, sched.max_batch_within(slo_us, bits, cap)),
    }
}

/// FIFO plan for dynamically-shaped engines: full `max_batch` launches
/// followed by one exact-size tail launch. Never pads.
pub fn plan_dynamic(queued: usize, max_batch: usize) -> BatchPlan {
    assert!(max_batch > 0, "max_batch must be positive");
    let mut launches = Vec::with_capacity(queued.div_ceil(max_batch));
    let mut left = queued;
    while left > 0 {
        let b = left.min(max_batch);
        launches.push(b);
        left -= b;
    }
    BatchPlan { launches, padding: 0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_fit() {
        let p = plan(32, vec![1, 8, 32]);
        assert_eq!(p.launches, vec![32]);
        assert_eq!(p.padding, 0);
    }

    #[test]
    fn mixed_fit() {
        let p = plan(70, vec![1, 8, 32]);
        assert_eq!(p.launches, vec![32, 32, 8]);
        assert_eq!(p.padding, 2);
    }

    #[test]
    fn single() {
        let p = plan(1, vec![1, 8, 32]);
        assert_eq!(p.launches, vec![1]);
        assert_eq!(p.padding, 0);
    }

    #[test]
    fn pads_to_smallest_fitting() {
        let p = plan(3, vec![1, 8, 32]);
        assert_eq!(p.launches, vec![8]);
        assert_eq!(p.padding, 5);
    }

    #[test]
    fn dynamic_caps_at_max_batch_and_never_pads() {
        let p = plan_dynamic(10, 4);
        assert_eq!(p.launches, vec![4, 4, 2]);
        assert_eq!(p.padding, 0);
        let p = plan_dynamic(4, 4);
        assert_eq!(p.launches, vec![4]);
        let p = plan_dynamic(3, 64);
        assert_eq!(p.launches, vec![3]);
        let p = plan_dynamic(0, 8);
        assert!(p.launches.is_empty());
    }

    #[test]
    fn prop_dynamic_covers_queue_fifo() {
        for q in 1..300 {
            for mb in [1usize, 3, 8, 32] {
                let p = plan_dynamic(q, mb);
                assert_eq!(p.launches.iter().sum::<usize>(), q, "q={q} mb={mb}");
                assert_eq!(p.padding, 0);
                // FIFO chunking: every launch but the last is exactly full
                for l in &p.launches[..p.launches.len() - 1] {
                    assert_eq!(*l, mb);
                }
                assert!(*p.launches.last().unwrap() <= mb);
            }
        }
    }

    #[test]
    fn group_fifo_partitions_by_key_preserving_order() {
        let items: Vec<(u32, usize)> =
            vec![(7, 0), (7, 1), (4, 2), (7, 3), (4, 4), (9, 5)];
        let groups = group_fifo(items, |&(k, _)| k);
        assert_eq!(groups.len(), 3);
        // groups ordered by first arrival, FIFO within each group
        assert_eq!(groups[0], vec![(7, 0), (7, 1), (7, 3)]);
        assert_eq!(groups[1], vec![(4, 2), (4, 4)]);
        assert_eq!(groups[2], vec![(9, 5)]);
        // uniform keys degenerate to a single group
        let one = group_fifo(vec![1, 2, 3], |_| 0u8);
        assert_eq!(one, vec![vec![1, 2, 3]]);
        assert!(group_fifo(Vec::<u8>::new(), |_| 0u8).is_empty());
    }

    #[test]
    fn model_batch_key_separates_identical_opts_across_models() {
        use crate::backend::InferOpts;
        // identical per-request options: the model index alone must split
        // the launch groups
        let opts = InferOpts::default();
        assert_ne!(model_batch_key(0, &opts), model_batch_key(1, &opts));
        // same model + same options still batch together
        assert_eq!(model_batch_key(1, &opts), model_batch_key(1, &opts));
        // ...and differing options split within one model, exactly as the
        // plain key does
        let aged = InferOpts::default().with_t_drift(86_400.0);
        assert_ne!(model_batch_key(1, &opts), model_batch_key(1, &aged));
        // grouping by the model-aware key never merges models
        let items = vec![(0usize, "a"), (1, "b"), (0, "c"), (1, "d")];
        let groups = group_fifo(items, |&(m, _)| model_batch_key(m, &opts));
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0], vec![(0, "a"), (0, "c")]);
        assert_eq!(groups[1], vec![(1, "b"), (1, "d")]);
    }

    #[test]
    fn slo_policy_tight_shrinks_loose_grows() {
        use crate::crossbar::ArrayGeom;
        use crate::nn::analognets::analognet_kws;

        // fixed shapes => fully deterministic policy: one 8-bit KWS
        // inference models at exactly 696 MVMs x 130 ns = 90.48 us
        let sched =
            ScheduleModel::new(&analognet_kws(), ArrayGeom::AON).unwrap();
        let (b_tight, n_tight) =
            slo_operating_point(&sched, 200.0, None, 8, 64);
        let (b_loose, n_loose) =
            slo_operating_point(&sched, 5_000.0, None, 8, 64);
        // pinned bitwidth is never changed without an opt-in floor
        assert_eq!((b_tight, b_loose), (8, 8));
        assert_eq!(n_tight, 2);
        assert_eq!(n_loose, 55);
        assert!(n_tight < n_loose);

        // with a floor, a sub-single-inference SLO trades bits for latency
        let (b, n) = slo_operating_point(&sched, 50.0, Some(4), 8, 64);
        assert!(b < 8 && b >= 4, "bits={b}");
        assert!(n >= 1);
        // ...and a loose SLO keeps full precision even with a floor
        let (b, n) = slo_operating_point(&sched, 100_000.0, Some(4), 8, 64);
        assert_eq!((b, n), (8, 64));
    }

    #[test]
    fn covers_all_requests() {
        for q in 1..200 {
            let p = plan(q, vec![1, 8, 32]);
            let total: usize = p.launches.iter().sum();
            assert_eq!(total, q + p.padding, "q={q}");
        }
    }
}
