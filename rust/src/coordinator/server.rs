//! The serving loop: request intake -> dynamic batcher -> backend executor,
//! with PCM drift management in the background of every dispatch.
//!
//! The executor is any [`InferenceBackend`] — the native simulator by
//! default (hermetic: no XLA, no exported HLO), the tile-faithful AnalogCim
//! engine (`ServeConfig::backend = BackendKind::AnalogCim`), or the
//! compiled PJRT graphs when built with the `pjrt` feature.
//!
//! Every request carries its own [`InferOpts`] (device age `t_drift`, ADC
//! bitwidth `adc_bits`): the drain partitions the queue into
//! option-compatible groups ([`batcher::group_fifo`]) and executes each
//! group as its own launch sequence, reading PCM weights at the group's
//! requested age ([`PcmState::weights_at`]) and quantizing at the group's
//! bitwidth. Requests without options (`InferOpts::default()` —
//! [`Coordinator::submit`]) serve at the coordinator clock's current
//! device age and the backend's configured bits, exactly as before the
//! options existed.
//!
//! Engines that accept arbitrary batch shapes
//! (`InferenceBackend::supports_dynamic_batch`, i.e. the native
//! layer-serial engines) get the zero-padding FIFO drain: up to
//! [`ServeConfig::max_batch`] queued requests per group are packed into a
//! *single* `run_batch`, which executes one im2col + one batched GEMM per
//! layer across the whole batch — the AON-CiM layer-serial schedule.
//! Static-shape engines (PJRT) keep the padded multi-launch plan over
//! their exported graph sizes.
//!
//! Every launch is also priced on the modeled AON-CiM schedule
//! ([`crate::timing::ScheduleModel`]): the metrics ledger accumulates
//! modeled nJ and ops per drain (plus refresh/reprogram overheads), which
//! surface as `modeled_uj_per_inf` / `modeled_tops_w` in
//! [`MetricsSummary`](crate::coordinator::metrics::MetricsSummary). With
//! [`ServeConfig::latency_slo_us`] set, the same estimator drives the
//! batcher: see [`batcher::slo_operating_point`].

use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::backend::{self, BackendKind, HostTensor, InferOpts,
                     InferenceBackend};
use crate::coordinator::batcher;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::state::PcmState;
use crate::crossbar::ArrayGeom;
use crate::eval::DeployedModel;
use crate::nn::{expand_dw_dense, LayerKind};
use crate::pcm::{FaultSpec, PcmParams};
use crate::runtime::ArtifactStore;
use crate::timing::ScheduleModel;
use crate::util::logits;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// artifact variant to serve, e.g. "kws_full_e10_8b"
    pub vid: String,
    pub bits: u32,
    /// which execution engine serves the traffic
    pub backend: BackendKind,
    /// batcher window: how long to wait for more requests after the first
    pub max_wait: Duration,
    /// largest single launch for dynamically-shaped backends (`0` = use the
    /// backend's largest advertised batch size). Ignored by static-shape
    /// engines, whose launch sizes are fixed by their exported graphs.
    pub max_batch: usize,
    /// native GEMM worker-pool size (`0` = automatic: all cores, capped
    /// at 8). Ignored by the PJRT backend.
    pub threads: usize,
    /// simulated seconds per wall second (drift clock acceleration)
    pub time_scale: f64,
    /// device age (simulated seconds since programming) the serving
    /// **clock** starts at — serve a day-old (86 400) or year-old array
    /// immediately instead of waiting for the accelerated clock to get
    /// there. Clamped below at t_c = 25 s by the PCM state.
    ///
    /// Soft-deprecated as a *request* age: this field only seeds the
    /// coordinator-wide clock that option-less requests serve at. Requests
    /// that need a specific device age should carry it themselves via
    /// [`InferOpts::t_drift`] ([`Coordinator::submit_with`]), which wins
    /// over the clock for that request and lets one coordinator serve
    /// many ages concurrently.
    pub drift_time: f64,
    pub seed: u64,
    /// simulated seconds between weight refreshes (fresh read noise + GDC)
    pub refresh_every_s: f64,
    /// reprogram the array when mean GDC alpha exceeds 1.15
    pub reprogram: bool,
    /// deployment-default device-variability scenario: stamped onto the
    /// programmed array at worker start ([`PcmState::set_faults`]) and
    /// re-stamped after every reprogram. Option-less requests serve this
    /// scenario; requests carrying their own [`InferOpts::faults`] win for
    /// that request. [`FaultSpec::none()`] (the default) serves the
    /// pristine array bit for bit.
    pub faults: FaultSpec,
    /// per-launch latency SLO in microseconds, priced against the modeled
    /// AON-CiM launch schedule ([`ScheduleModel`]). When set, each drained
    /// group's batch cap comes from the estimator — the largest batch whose
    /// *modeled* accelerator latency stays within the SLO — instead of the
    /// fixed `max_batch`; requests that opted into a bitwidth range
    /// ([`InferOpts::adc_bits_floor`]) may additionally be requantized down
    /// to the highest bitwidth whose single-inference model fits. `None`
    /// (the default) keeps the fixed-config batcher exactly as before.
    /// The SLO governs *planning*, not admission: an impossible SLO still
    /// serves at batch 1 rather than rejecting traffic.
    pub latency_slo_us: Option<f64>,
    pub artifacts_dir: std::path::PathBuf,
}

impl ServeConfig {
    pub fn new(vid: &str, bits: u32) -> Self {
        ServeConfig {
            vid: vid.to_string(),
            bits,
            backend: BackendKind::default(),
            max_wait: Duration::from_millis(2),
            max_batch: 0,
            threads: 0,
            time_scale: 1.0,
            drift_time: crate::pcm::T_C_SECONDS,
            seed: 7,
            refresh_every_s: 60.0,
            reprogram: false,
            faults: FaultSpec::none(),
            latency_slo_us: None,
            artifacts_dir: crate::nn::manifest::artifacts_dir(),
        }
    }

    /// Builder-style backend selection.
    pub fn with_backend(mut self, backend: BackendKind) -> Self {
        self.backend = backend;
        self
    }

    /// Builder-style dynamic-batch cap.
    pub fn with_max_batch(mut self, max_batch: usize) -> Self {
        self.max_batch = max_batch;
        self
    }

    /// Builder-style initial device age of the serving clock (see
    /// [`drift_time`](Self::drift_time); per-request ages go through
    /// [`InferOpts::t_drift`] instead).
    pub fn with_drift_time(mut self, drift_time_s: f64) -> Self {
        self.drift_time = drift_time_s;
        self
    }

    /// Builder-style deployment-default fault scenario (see
    /// [`faults`](Self::faults)).
    pub fn with_faults(mut self, faults: FaultSpec) -> Self {
        self.faults = faults;
        self
    }

    /// Builder-style modeled-latency SLO (see
    /// [`latency_slo_us`](Self::latency_slo_us)).
    pub fn with_latency_slo_us(mut self, slo_us: f64) -> Self {
        self.latency_slo_us = Some(slo_us);
        self
    }
}

pub struct Request {
    pub features: Vec<f32>,
    /// per-request options this request must be served under
    opts: InferOpts,
    reply: mpsc::Sender<Response>,
    submitted: Instant,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub pred: u32,
    pub logits: Vec<f32>,
    pub latency: Duration,
    /// device age (simulated seconds) when served: the request's own
    /// `InferOpts::t_drift` (clamped at t_c) when set, the coordinator
    /// clock otherwise
    pub sim_age_s: f64,
    /// ADC bitwidth this response was computed at: the request's own
    /// `InferOpts::adc_bits` when set, the backend's configured bits
    /// otherwise
    pub adc_bits: u32,
}

/// Result of one canary health probe: the worker runs a fixed synthetic
/// batch through the serving engine under the deployment-default fault
/// scenario and compares argmax predictions against a clean native
/// reference computed once at startup. `degraded` means agreement fell
/// below 3 of 4 — the coordinator keeps serving (graceful degradation),
/// but every response dispatched while degraded counts under
/// `Metrics::degraded_responses`.
#[derive(Clone, Copy, Debug)]
pub struct HealthReport {
    /// canary samples probed
    pub canary: usize,
    /// canaries whose analog argmax matched the clean native reference
    pub agree: usize,
    /// agreement below the 3/4 threshold
    pub degraded: bool,
}

enum Msg {
    Req(Request),
    Probe(mpsc::Sender<HealthReport>),
    Stop,
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: mpsc::Sender<Msg>,
    handle: Option<JoinHandle<anyhow::Result<()>>>,
    pub metrics: Arc<Metrics>,
    pub classes: usize,
    pub feat_len: usize,
    /// for rejecting per-request options the backend cannot serve *at
    /// submit time* — a bad option must fail its own request, never reach
    /// the worker and kill the session for everyone
    backend: BackendKind,
    bits: u32,
}

impl Coordinator {
    /// Start the worker thread (it owns the backend and the PCM state).
    pub fn start(cfg: ServeConfig) -> anyhow::Result<Coordinator> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        // probe the artifacts AND the backend on the caller thread, so a
        // missing variant, an uncompiled `pjrt` feature, a missing XLA
        // library, or a bitwidth with no serving graphs all fail fast here
        // with their real error instead of dying inside the worker (where
        // clients would only ever see "coordinator stopped")
        let store = ArtifactStore::open(&cfg.artifacts_dir)?;
        let meta = store.meta(&cfg.vid)?;
        // the deployment-default fault scenario obeys the same per-engine
        // gates as per-request specs: an invalid spec (or one this engine
        // cannot execute, e.g. ADC errors outside AnalogCim) fails here
        // with its real error instead of inside the worker
        backend::validate_opts(cfg.backend, cfg.bits, &InferOpts {
            faults: Some(cfg.faults),
            ..InferOpts::default()
        })?;
        {
            let be = backend::create(cfg.backend, &store, &cfg.vid, cfg.bits)?;
            be.probe()?;
            anyhow::ensure!(
                !be.batch_sizes().is_empty(),
                "variant {} has no {}b serving graphs for backend `{}`",
                cfg.vid,
                cfg.bits,
                be.name()
            );
        }
        let (ih, iw, ic) = meta.input_hwc;
        let classes = meta.num_classes;
        let feat_len = ih * iw * ic;
        drop(store);

        let (backend, bits) = (cfg.backend, cfg.bits);
        let handle = std::thread::Builder::new()
            .name("aon-cim-coordinator".into())
            .spawn(move || worker(cfg, rx, m2))?;
        Ok(Coordinator {
            tx,
            handle: Some(handle),
            metrics,
            classes,
            feat_len,
            backend,
            bits,
        })
    }

    /// Submit a request with default options (serving-clock device age,
    /// backend-configured bits); returns the channel the response arrives
    /// on.
    pub fn submit(&self, features: Vec<f32>) -> anyhow::Result<mpsc::Receiver<Response>> {
        self.submit_with(features, InferOpts::default())
    }

    /// Submit a request with explicit per-request options. Requests whose
    /// options differ are drained into separate batches; a request only
    /// ever shares a launch with option-identical peers.
    ///
    /// Options the backend cannot serve are rejected **here**, so an
    /// invalid request fails on its own submit instead of erroring inside
    /// the worker and taking the session down with it.
    pub fn submit_with(&self, features: Vec<f32>, opts: InferOpts)
                       -> anyhow::Result<mpsc::Receiver<Response>> {
        // every failure path below is a submit-time reject; count them so
        // operators can tell "traffic dropped" from "traffic went bad"
        if features.len() != self.feat_len {
            self.metrics.submit_rejects.fetch_add(1, Ordering::Relaxed);
            anyhow::bail!("bad feature length {} (model wants {})",
                          features.len(), self.feat_len);
        }
        if let Err(e) = backend::validate_opts(self.backend, self.bits, &opts) {
            self.metrics.submit_rejects.fetch_add(1, Ordering::Relaxed);
            return Err(e);
        }
        let (rtx, rrx) = mpsc::channel();
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Msg::Req(Request {
                features,
                opts,
                reply: rtx,
                submitted: Instant::now(),
            }))
            .map_err(|_| {
                self.metrics.submit_rejects.fetch_add(1, Ordering::Relaxed);
                anyhow::anyhow!("coordinator stopped")
            })?;
        Ok(rrx)
    }

    /// Blocking single inference with default options.
    pub fn infer(&self, features: Vec<f32>) -> anyhow::Result<Response> {
        self.infer_with(features, InferOpts::default())
    }

    /// Blocking single inference with explicit per-request options.
    pub fn infer_with(&self, features: Vec<f32>, opts: InferOpts)
                      -> anyhow::Result<Response> {
        let rx = self.submit_with(features, opts)?;
        rx.recv().map_err(|_| anyhow::anyhow!("coordinator dropped request"))
    }

    /// Run a health probe now and return its report: the worker replays
    /// the canary batch through the serving engine (current device age,
    /// deployment-default fault scenario) and spot-checks argmax
    /// consistency against the clean native reference. Also runs
    /// automatically at startup, after every reprogram, and after each
    /// weight-refresh cadence; this entry point is for operators who want
    /// an on-demand answer (and for tests).
    pub fn probe_health(&self) -> anyhow::Result<HealthReport> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Probe(rtx))
            .map_err(|_| anyhow::anyhow!("coordinator stopped"))?;
        rrx.recv()
            .map_err(|_| anyhow::anyhow!("coordinator stopped"))
    }

    /// Graceful-shutdown hook for shared (`Arc`-held) coordinators: ask
    /// the worker to drain the queue and exit, without consuming the
    /// handle. In-flight requests still receive their responses; later
    /// submits fail with "coordinator stopped" (and count as submit
    /// rejects). [`stop`](Self::stop) — or `Drop` — still joins the
    /// worker afterwards.
    pub fn request_stop(&self) {
        let _ = self.tx.send(Msg::Stop);
    }

    pub fn stop(mut self) -> anyhow::Result<()> {
        let _ = self.tx.send(Msg::Stop);
        if let Some(h) = self.handle.take() {
            h.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
        }
        Ok(())
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Everything the drain path needs besides the queue and the PCM state;
/// resolved once at worker start, never on the dispatch path.
struct Dispatcher<'a> {
    be: &'a (dyn InferenceBackend + 'a),
    metrics: &'a Metrics,
    /// static launch shapes (ascending), for the padded plan
    batch_sizes: Vec<usize>,
    /// true: FIFO zero-padding plan over `max_batch`-sized chunks
    dynamic: bool,
    max_batch: usize,
    /// reusable input buffer (largest launch) — no hot-path allocation
    xbuf: Vec<f32>,
    feat_len: usize,
    classes: usize,
    /// modeled AON-CiM launch schedule for the served model: prices every
    /// launch (nJ, ns) for the metrics ledger and, when `slo_us` is set,
    /// picks each group's operating point
    sched: ScheduleModel,
    /// `ServeConfig::latency_slo_us` — `None` keeps the fixed-config batcher
    slo_us: Option<f64>,
    /// latest health-probe verdict: while true, every response dispatched
    /// counts under `Metrics::degraded_responses` (the coordinator keeps
    /// serving — degradation is graceful, not fatal)
    degraded: bool,
}

impl Dispatcher<'_> {
    /// Drain the queue: partition by per-request options, then execute
    /// each option group as its own launch sequence. With uniform options
    /// (the common case) this is exactly the pre-options single-group
    /// drain.
    fn drain(&mut self, state: &mut PcmState, queue: &mut Vec<Request>)
             -> anyhow::Result<()> {
        if queue.is_empty() {
            return Ok(());
        }
        // fast path: uniform options (the overwhelmingly common case,
        // and everything that existed before per-request options) — the
        // queue is executed in place with zero grouping allocations
        let k0 = queue[0].opts.batch_key();
        if queue.iter().all(|r| r.opts.batch_key() == k0) {
            self.drain_group(state, queue)?;
            queue.clear();
            return Ok(());
        }
        // mixed options: partition into option-homogeneous groups.
        // drain(..) (not mem::take) keeps the queue's preallocated
        // capacity alive across windows.
        let drained: Vec<Request> = queue.drain(..).collect();
        let groups = batcher::group_fifo(drained, |r| r.opts.batch_key());
        for group in groups {
            self.drain_group(state, &group)?;
        }
        Ok(())
    }

    /// Execute one option-homogeneous group of requests.
    fn drain_group(&mut self, state: &mut PcmState, group: &[Request])
                   -> anyhow::Result<()> {
        let opts = group[0].opts;
        // operating point for this group: without an SLO it is exactly the
        // fixed config (requested bits, configured max_batch); with one,
        // the modeled launch schedule caps the batch — and, for requests
        // that opted into a bitwidth range, may lower the bits — so the
        // modeled accelerator latency of every launch stays within the SLO
        let base_bits = opts.effective_bits(self.be.bits());
        let (adc_bits, cap) = match self.slo_us {
            Some(slo) => batcher::slo_operating_point(
                &self.sched, slo, opts.adc_bits_floor, base_bits,
                self.max_batch),
            None => (base_bits, self.max_batch),
        };
        let plan = if self.dynamic {
            batcher::plan_dynamic(group.len(), cap)
        } else {
            // static-shape engines keep their exported-graph launch sizes
            // (the SLO cannot resize a compiled graph); the estimator still
            // prices each launch below
            batcher::plan(group.len(), self.batch_sizes.clone())
        };
        self.metrics
            .padded_slots
            .fetch_add(plan.padding as u64, Ordering::Relaxed);

        // which fault scenario this group serves under: the request's own
        // spec when it carries one, the deployment default otherwise
        let spec = opts.faults.unwrap_or_else(|| state.faults());
        // effective weights for this group's device age and scenario: an
        // explicit-age read for `t_drift` requests, the clock-driven cache
        // otherwise. Either way the borrow is straight out of the state
        // cache — no per-drain clone of the full weight set (the PJRT path
        // copies inside run_batch, the native paths read the slices in
        // place).
        let (ws, alphas, sim_age, refreshed) = match opts.t_drift {
            Some(t) => state.weights_at_spec(t, &spec),
            None => state.current_weights_spec(&spec),
        };
        if refreshed {
            self.metrics
                .weight_refreshes
                .fetch_add(1, Ordering::Relaxed);
            // a refresh is one full single-sample read+calibrate pass on
            // the array; charge its modeled energy so amortized µJ/inf
            // reflects the maintenance the accelerator actually performed
            self.metrics.add_modeled_overhead_nj(self.sched.refresh_nj());
        }
        // the ADC-side faults execute inside the backend, so the resolved
        // scenario must ride the launch options (weight-side faults already
        // live in the conductances read above); a none-equivalent spec
        // stays out so the clean path is bit-identical to pre-fault serving.
        // The operating-point bits are pinned explicitly: with an SLO they
        // may sit below the request's own bits (opt-in floor), and the
        // response echoes what actually ran.
        let run_opts = InferOpts {
            faults: (!spec.is_none()).then_some(spec),
            adc_bits: Some(adc_bits),
            ..opts
        };

        let feat_len = self.feat_len;
        let mut taken = 0usize;
        for &launch in &plan.launches {
            let count = launch.min(group.len() - taken);

            let xb = &mut self.xbuf[..launch * feat_len];
            for (i, r) in group[taken..taken + count].iter().enumerate() {
                xb[i * feat_len..(i + 1) * feat_len].copy_from_slice(&r.features);
            }
            for i in count..launch {
                // pad with the first request's features (static plans only;
                // dynamic launches are always exact)
                let (a, b) = xb.split_at_mut(i * feat_len);
                b[..feat_len].copy_from_slice(&a[..feat_len]);
            }

            let out = self.be.run_batch(xb, launch, ws, alphas, &run_opts)?;
            self.metrics.launches.fetch_add(1, Ordering::Relaxed);
            self.metrics
                .batched_slots
                .fetch_add(count as u64, Ordering::Relaxed);
            // price the launch actually dispatched (padded slots execute
            // too, so the full `launch` is charged) and amortize it over
            // the `count` real responses it carried — padding shows up as
            // a higher modeled µJ/inf, exactly as it would on silicon
            let ls = self.sched.launch(launch, adc_bits);
            self.metrics.add_modeled_launch(self.sched.model(), adc_bits,
                                            count as u64, ls.energy_nj,
                                            ls.ops);
            if self.degraded {
                self.metrics
                    .degraded_responses
                    .fetch_add(count as u64, Ordering::Relaxed);
            }

            let now = Instant::now();
            for (i, r) in group[taken..taken + count].iter().enumerate() {
                let row = &out[i * self.classes..(i + 1) * self.classes];
                let pred = logits::argmax(row);
                // account BEFORE replying: clients must observe settled
                // metrics
                self.metrics.completed.fetch_add(1, Ordering::Relaxed);
                self.metrics
                    .record_latency_us((now - r.submitted).as_secs_f64() * 1e6);
                self.metrics.add_energy_nj(ls.energy_nj / count as f64);
                let _ = r.reply.send(Response {
                    pred,
                    logits: row.to_vec(),
                    latency: now - r.submitted,
                    sim_age_s: sim_age,
                    adc_bits,
                });
            }
            taken += count;
        }
        Ok(())
    }
}

/// The worker's canary: a deterministic synthetic batch plus the clean
/// native reference predictions it was graded against at startup. The
/// probe replays `x` through the *serving* engine (current device age,
/// default fault scenario) and counts argmax agreement — a cheap
/// end-to-end spot-check that the analog path still computes the same
/// answers as an ideal digital execution.
struct Canary {
    x: Vec<f32>,
    n: usize,
    ref_preds: Vec<u32>,
}

/// Run one health probe: serve the canary batch under the deployment
/// default and grade it against the clean reference. Updates the probe
/// counters; the caller owns propagating `degraded` to the dispatcher.
fn probe(be: &dyn InferenceBackend, state: &mut PcmState, canary: &Canary,
         classes: usize, metrics: &Metrics) -> anyhow::Result<HealthReport> {
    let spec = state.faults();
    let popts = InferOpts {
        faults: (!spec.is_none()).then_some(spec),
        ..InferOpts::default()
    };
    let (ws, alphas, refreshed) = state.current_weights();
    if refreshed {
        metrics.weight_refreshes.fetch_add(1, Ordering::Relaxed);
    }
    let out = be.run_batch(&canary.x, canary.n, ws, alphas, &popts)?;
    let agree = (0..canary.n)
        .filter(|&i| {
            logits::argmax(&out[i * classes..(i + 1) * classes])
                == canary.ref_preds[i]
        })
        .count();
    // degraded below 3/4 agreement: drift read noise may flip a borderline
    // canary, a stuck-cell cluster flips most of them
    let degraded = agree * 4 < canary.n * 3;
    metrics.health_probes.fetch_add(1, Ordering::Relaxed);
    metrics.canary_agree.fetch_add(agree as u64, Ordering::Relaxed);
    metrics.canary_total.fetch_add(canary.n as u64, Ordering::Relaxed);
    Ok(HealthReport { canary: canary.n, agree, degraded })
}

fn worker(cfg: ServeConfig, rx: mpsc::Receiver<Msg>, metrics: Arc<Metrics>)
          -> anyhow::Result<()> {
    // the worker owns the artifact store and the backend (PJRT handles,
    // when in play, stay on-thread)
    let store = ArtifactStore::open(&cfg.artifacts_dir)?;
    let be = backend::create_with_threads(cfg.backend, &store, &cfg.vid,
                                          cfg.bits, cfg.threads)?;
    // model geometry is invariant across launches: resolve it once here,
    // never on the dispatch path
    let feat_len = be.feat_len();
    let classes = be.num_classes();

    // serving batch sizes available at this bitwidth (ascending, per the
    // trait contract). Coordinator::start already rejected an empty set
    // with a descriptive error; this only guards against the artifact
    // bundle changing on disk between the probe and the worker's re-open.
    let batch_sizes = be.batch_sizes();
    anyhow::ensure!(
        !batch_sizes.is_empty(),
        "serving graphs for {} disappeared between probe and worker start",
        cfg.vid
    );
    // compile/load every batch size up front (never on the hot path)
    for &b in &batch_sizes {
        be.prepare(b)?;
    }

    // modeled AON-CiM launch schedule for this deployment: the backend's
    // own geometry when it reports one (native/analog — identical on the
    // default AON array), the AON mapping otherwise (PJRT). Resolved once
    // here; the dispatch path only evaluates closed-form per-launch costs.
    let meta = store.meta(&cfg.vid)?;
    let sched = match be.schedule_model() {
        Some(s) => s,
        None => ScheduleModel::new(&meta, ArrayGeom::AON)?,
    };

    // deploy onto PCM
    let params = PcmParams::default();
    let mut rng = Rng::new(cfg.seed);
    let deployed = DeployedModel::program(&store, &cfg.vid, &params, &mut rng)?;
    let mut state = PcmState::new(deployed, params, cfg.seed ^ 0xD1F7, cfg.time_scale);
    state.refresh_every_s = cfg.refresh_every_s;
    // deployment-default fault scenario + per-tile calibration target,
    // both installed before the clock starts so the first read already
    // serves the faulted, tile-calibrated array
    state.set_faults(cfg.faults);
    state.set_calib_geom(be.calib_geom());
    state.set_initial_age(cfg.drift_time);

    let dynamic = be.supports_dynamic_batch();
    let largest_static = *batch_sizes.last().unwrap();
    let max_batch = if cfg.max_batch > 0 {
        cfg.max_batch
    } else {
        largest_static
    };
    // largest single launch either plan can produce, sizing the input buffer
    let xcap = if dynamic { max_batch } else { largest_static };
    if dynamic {
        be.prepare(max_batch)?;
    }
    // canary batch for the health probe: deterministic synthetic features
    // (a function of the seed alone), graded once against the exact FP
    // weights on the clean native engine. Static-shape engines probe at
    // their smallest exported graph size; dynamic engines use 4 samples.
    let canary_n = if dynamic { 4.min(max_batch.max(1)) } else { batch_sizes[0] };
    let canary = {
        let mut crng = Rng::new(cfg.seed ^ 0xCA9A_11A5);
        let x: Vec<f32> = (0..canary_n * feat_len)
            .map(|_| crng.uniform() as f32)
            .collect();
        let tensors = store.weights(&cfg.vid)?;
        let mut exact = Vec::with_capacity(tensors.len());
        for (lm, t) in meta.layers.iter().zip(tensors.iter()) {
            // same depthwise expansion the PCM programming applies, so the
            // reference sees the exact weights in the deployed layout
            if lm.analog && lm.kind == LayerKind::Dw3x3 {
                exact.push(HostTensor::from_tensor(&expand_dw_dense(t)));
            } else {
                exact.push(HostTensor::from_tensor(t));
            }
        }
        let unity = crate::pcm::gdc::unity(exact.len());
        let nref = backend::create_with_threads(BackendKind::Native, &store,
                                                &cfg.vid, cfg.bits, 1)?;
        nref.prepare(canary_n)?;
        let rout = nref.run_batch(&x, canary_n, &exact, &unity,
                                  &InferOpts::default())?;
        let ref_preds: Vec<u32> = (0..canary_n)
            .map(|i| logits::argmax(&rout[i * classes..(i + 1) * classes]))
            .collect();
        Canary { x, n: canary_n, ref_preds }
    };

    let max_queue = xcap * 4;
    let mut queue: Vec<Request> = Vec::with_capacity(max_queue);
    let mut disp = Dispatcher {
        be: be.as_ref(),
        metrics: &metrics,
        batch_sizes,
        dynamic,
        max_batch,
        xbuf: vec![0f32; xcap * feat_len],
        feat_len,
        classes,
        sched,
        slo_us: cfg.latency_slo_us,
        degraded: false,
    };

    // startup probe: the verdict on the just-deployed (possibly faulted)
    // array, before any traffic is served under it
    disp.degraded = probe(disp.be, &mut state, &canary, classes,
                          &metrics)?.degraded;
    let mut probed_at_refresh = metrics.weight_refreshes.load(Ordering::Relaxed);

    loop {
        // block for the first request
        match rx.recv() {
            Ok(Msg::Req(r)) => queue.push(r),
            Ok(Msg::Probe(reply)) => {
                let hr = probe(disp.be, &mut state, &canary, classes,
                               &metrics)?;
                disp.degraded = hr.degraded;
                probed_at_refresh =
                    metrics.weight_refreshes.load(Ordering::Relaxed);
                let _ = reply.send(hr);
                continue;
            }
            Ok(Msg::Stop) | Err(_) => break,
        }
        // batching window: gather more until max_wait or queue full
        let deadline = Instant::now() + cfg.max_wait;
        while queue.len() < max_queue {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Req(r)) => queue.push(r),
                Ok(Msg::Probe(reply)) => {
                    let hr = probe(disp.be, &mut state, &canary, classes,
                                   &metrics)?;
                    disp.degraded = hr.degraded;
                    probed_at_refresh =
                        metrics.weight_refreshes.load(Ordering::Relaxed);
                    let _ = reply.send(hr);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        disp.drain(&mut state, &mut queue)?;

        // drift management between dispatches
        let mut reprogrammed = false;
        if cfg.reprogram && state.needs_reprogram() {
            state.reprogram(&store, &cfg.vid)?;
            // a reprogram rewrites every allocated cell: charge its modeled
            // energy as serving overhead so amortized µJ/inf carries the
            // maintenance cost of keeping the array in spec
            metrics.add_modeled_overhead_nj(disp.sched.reprogram_nj());
            reprogrammed = true;
        }
        // re-probe whenever the weights moved since the last verdict
        // (cadence refresh or the reprogram above): the health answer is a
        // property of the weights actually being served
        let refreshes = metrics.weight_refreshes.load(Ordering::Relaxed);
        if reprogrammed || refreshes != probed_at_refresh {
            disp.degraded = probe(disp.be, &mut state, &canary, classes,
                                  &metrics)?.degraded;
            probed_at_refresh =
                metrics.weight_refreshes.load(Ordering::Relaxed);
        }
    }
    Ok(())
}
