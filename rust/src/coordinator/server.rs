//! The serving loop: request intake -> dynamic batcher -> backend executor,
//! with PCM drift management in the background of every dispatch.
//!
//! The executor is any [`InferenceBackend`] — the native simulator by
//! default (hermetic: no XLA, no exported HLO), the tile-faithful AnalogCim
//! engine (`ServeConfig::backend = BackendKind::AnalogCim`, optionally at a
//! pre-aged drift time via [`ServeConfig::drift_time`]), or the compiled
//! PJRT graphs when built with the `pjrt` feature.
//!
//! Engines that accept arbitrary batch shapes
//! (`InferenceBackend::supports_dynamic_batch`, i.e. the native
//! layer-serial engine) get the zero-padding FIFO drain: up to
//! [`ServeConfig::max_batch`] queued requests are packed into a *single*
//! `run_batch`, which executes one im2col + one batched GEMM per layer
//! across the whole batch — the AON-CiM layer-serial schedule. Static-shape
//! engines (PJRT) keep the padded multi-launch plan over their exported
//! graph sizes.

use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::backend::{self, BackendKind, InferenceBackend};
use crate::coordinator::batcher;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::state::PcmState;
use crate::crossbar::ArrayGeom;
use crate::eval::DeployedModel;
use crate::mapping::map_model;
use crate::pcm::PcmParams;
use crate::runtime::ArtifactStore;
use crate::timing::{model_perf, EnergyModel};
use crate::util::logits;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// artifact variant to serve, e.g. "kws_full_e10_8b"
    pub vid: String,
    pub bits: u32,
    /// which execution engine serves the traffic
    pub backend: BackendKind,
    /// batcher window: how long to wait for more requests after the first
    pub max_wait: Duration,
    /// largest single launch for dynamically-shaped backends (`0` = use the
    /// backend's largest advertised batch size). Ignored by static-shape
    /// engines, whose launch sizes are fixed by their exported graphs.
    pub max_batch: usize,
    /// native GEMM worker-pool size (`0` = automatic: all cores, capped
    /// at 8). Ignored by the PJRT backend.
    pub threads: usize,
    /// simulated seconds per wall second (drift clock acceleration)
    pub time_scale: f64,
    /// device age (simulated seconds since programming) the serving clock
    /// starts at — `--t-drift`: serve a day-old (86 400) or year-old array
    /// immediately instead of waiting for the accelerated clock to get
    /// there. Clamped below at t_c = 25 s by the PCM state.
    pub drift_time: f64,
    pub seed: u64,
    /// simulated seconds between weight refreshes (fresh read noise + GDC)
    pub refresh_every_s: f64,
    /// reprogram the array when mean GDC alpha exceeds 1.15
    pub reprogram: bool,
    pub artifacts_dir: std::path::PathBuf,
}

impl ServeConfig {
    pub fn new(vid: &str, bits: u32) -> Self {
        ServeConfig {
            vid: vid.to_string(),
            bits,
            backend: BackendKind::default(),
            max_wait: Duration::from_millis(2),
            max_batch: 0,
            threads: 0,
            time_scale: 1.0,
            drift_time: crate::pcm::T_C_SECONDS,
            seed: 7,
            refresh_every_s: 60.0,
            reprogram: false,
            artifacts_dir: crate::nn::manifest::artifacts_dir(),
        }
    }

    /// Builder-style backend selection.
    pub fn with_backend(mut self, backend: BackendKind) -> Self {
        self.backend = backend;
        self
    }

    /// Builder-style dynamic-batch cap.
    pub fn with_max_batch(mut self, max_batch: usize) -> Self {
        self.max_batch = max_batch;
        self
    }

    /// Builder-style initial device age (drift-aware serving).
    pub fn with_drift_time(mut self, drift_time_s: f64) -> Self {
        self.drift_time = drift_time_s;
        self
    }
}

pub struct Request {
    pub features: Vec<f32>,
    reply: mpsc::Sender<Response>,
    submitted: Instant,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub pred: u32,
    pub logits: Vec<f32>,
    pub latency: Duration,
    /// device age (simulated seconds) when served
    pub sim_age_s: f64,
}

enum Msg {
    Req(Request),
    Stop,
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: mpsc::Sender<Msg>,
    handle: Option<JoinHandle<anyhow::Result<()>>>,
    pub metrics: Arc<Metrics>,
    pub classes: usize,
    pub feat_len: usize,
}

impl Coordinator {
    /// Start the worker thread (it owns the backend and the PCM state).
    pub fn start(cfg: ServeConfig) -> anyhow::Result<Coordinator> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        // probe the artifacts AND the backend on the caller thread, so a
        // missing variant, an uncompiled `pjrt` feature, a missing XLA
        // library, or a bitwidth with no serving graphs all fail fast here
        // with their real error instead of dying inside the worker (where
        // clients would only ever see "coordinator stopped")
        let store = ArtifactStore::open(&cfg.artifacts_dir)?;
        let meta = store.meta(&cfg.vid)?;
        {
            let be = backend::create(cfg.backend, &store, &cfg.vid, cfg.bits)?;
            be.probe()?;
            anyhow::ensure!(
                !be.batch_sizes().is_empty(),
                "variant {} has no {}b serving graphs for backend `{}`",
                cfg.vid,
                cfg.bits,
                be.name()
            );
        }
        let (ih, iw, ic) = meta.input_hwc;
        let classes = meta.num_classes;
        let feat_len = ih * iw * ic;
        drop(store);

        let handle = std::thread::Builder::new()
            .name("aon-cim-coordinator".into())
            .spawn(move || worker(cfg, rx, m2))?;
        Ok(Coordinator {
            tx,
            handle: Some(handle),
            metrics,
            classes,
            feat_len,
        })
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, features: Vec<f32>) -> anyhow::Result<mpsc::Receiver<Response>> {
        anyhow::ensure!(features.len() == self.feat_len, "bad feature length");
        let (rtx, rrx) = mpsc::channel();
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Msg::Req(Request {
                features,
                reply: rtx,
                submitted: Instant::now(),
            }))
            .map_err(|_| anyhow::anyhow!("coordinator stopped"))?;
        Ok(rrx)
    }

    /// Blocking single inference.
    pub fn infer(&self, features: Vec<f32>) -> anyhow::Result<Response> {
        let rx = self.submit(features)?;
        rx.recv().map_err(|_| anyhow::anyhow!("coordinator dropped request"))
    }

    pub fn stop(mut self) -> anyhow::Result<()> {
        let _ = self.tx.send(Msg::Stop);
        if let Some(h) = self.handle.take() {
            h.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
        }
        Ok(())
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Everything the drain path needs besides the queue and the PCM state;
/// resolved once at worker start, never on the dispatch path.
struct Dispatcher<'a> {
    be: &'a (dyn InferenceBackend + 'a),
    metrics: &'a Metrics,
    /// static launch shapes (ascending), for the padded plan
    batch_sizes: Vec<usize>,
    /// true: FIFO zero-padding plan over `max_batch`-sized chunks
    dynamic: bool,
    max_batch: usize,
    /// reusable input buffer (largest launch) — no hot-path allocation
    xbuf: Vec<f32>,
    feat_len: usize,
    classes: usize,
    nj_per_inf: f64,
}

impl Dispatcher<'_> {
    fn drain(&mut self, state: &mut PcmState, queue: &mut Vec<Request>)
             -> anyhow::Result<()> {
        if queue.is_empty() {
            return Ok(());
        }
        let plan = if self.dynamic {
            batcher::plan_dynamic(queue.len(), self.max_batch)
        } else {
            batcher::plan(queue.len(), self.batch_sizes.clone())
        };
        self.metrics
            .padded_slots
            .fetch_add(plan.padding as u64, Ordering::Relaxed);

        let sim_age = state.sim_age_s();
        // borrow the cached effective weights directly — no per-drain clone
        // of the full weight set (the PJRT path copies inside run_batch,
        // the native path reads the slices in place)
        let (ws, alphas, refreshed) = state.current_weights();
        if refreshed {
            self.metrics.weight_refreshes.fetch_add(1, Ordering::Relaxed);
        }

        let feat_len = self.feat_len;
        let mut taken = 0usize;
        for &launch in &plan.launches {
            let count = launch.min(queue.len() - taken);

            let xb = &mut self.xbuf[..launch * feat_len];
            for (i, r) in queue[taken..taken + count].iter().enumerate() {
                xb[i * feat_len..(i + 1) * feat_len].copy_from_slice(&r.features);
            }
            for i in count..launch {
                // pad with the first request's features (static plans only;
                // dynamic launches are always exact)
                let (a, b) = xb.split_at_mut(i * feat_len);
                b[..feat_len].copy_from_slice(&a[..feat_len]);
            }

            let out = self.be.run_batch(xb, launch, ws, alphas)?;
            self.metrics.launches.fetch_add(1, Ordering::Relaxed);
            self.metrics
                .batched_slots
                .fetch_add(count as u64, Ordering::Relaxed);

            let now = Instant::now();
            for (i, r) in queue[taken..taken + count].iter().enumerate() {
                let row = &out[i * self.classes..(i + 1) * self.classes];
                let pred = logits::argmax(row);
                // account BEFORE replying: clients must observe settled
                // metrics
                self.metrics.completed.fetch_add(1, Ordering::Relaxed);
                self.metrics
                    .record_latency_us((now - r.submitted).as_secs_f64() * 1e6);
                self.metrics.add_energy_nj(self.nj_per_inf);
                let _ = r.reply.send(Response {
                    pred,
                    logits: row.to_vec(),
                    latency: now - r.submitted,
                    sim_age_s: sim_age,
                });
            }
            taken += count;
        }
        queue.clear();
        Ok(())
    }
}

fn worker(cfg: ServeConfig, rx: mpsc::Receiver<Msg>, metrics: Arc<Metrics>)
          -> anyhow::Result<()> {
    // the worker owns the artifact store and the backend (PJRT handles,
    // when in play, stay on-thread)
    let store = ArtifactStore::open(&cfg.artifacts_dir)?;
    let be = backend::create_with_threads(cfg.backend, &store, &cfg.vid,
                                          cfg.bits, cfg.threads)?;
    // model geometry is invariant across launches: resolve it once here,
    // never on the dispatch path
    let feat_len = be.feat_len();
    let classes = be.num_classes();

    // serving batch sizes available at this bitwidth (ascending, per the
    // trait contract). Coordinator::start already rejected an empty set
    // with a descriptive error; this only guards against the artifact
    // bundle changing on disk between the probe and the worker's re-open.
    let batch_sizes = be.batch_sizes();
    anyhow::ensure!(
        !batch_sizes.is_empty(),
        "serving graphs for {} disappeared between probe and worker start",
        cfg.vid
    );
    // compile/load every batch size up front (never on the hot path)
    for &b in &batch_sizes {
        be.prepare(b)?;
    }

    // simulated accelerator energy per inference (timing model, Table 2 row)
    let meta = store.meta(&cfg.vid)?;
    let mapping = map_model(&meta, ArrayGeom::AON)?;
    let perf = model_perf(&mapping, cfg.bits, &EnergyModel::default());
    let nj_per_inf = perf.energy_nj;

    // deploy onto PCM
    let params = PcmParams::default();
    let mut rng = Rng::new(cfg.seed);
    let deployed = DeployedModel::program(&store, &cfg.vid, &params, &mut rng)?;
    let mut state = PcmState::new(deployed, params, cfg.seed ^ 0xD1F7, cfg.time_scale);
    state.refresh_every_s = cfg.refresh_every_s;
    state.set_initial_age(cfg.drift_time);

    let dynamic = be.supports_dynamic_batch();
    let largest_static = *batch_sizes.last().unwrap();
    let max_batch = if cfg.max_batch > 0 {
        cfg.max_batch
    } else {
        largest_static
    };
    // largest single launch either plan can produce, sizing the input buffer
    let xcap = if dynamic { max_batch } else { largest_static };
    if dynamic {
        be.prepare(max_batch)?;
    }
    let max_queue = xcap * 4;
    let mut queue: Vec<Request> = Vec::with_capacity(max_queue);
    let mut disp = Dispatcher {
        be: be.as_ref(),
        metrics: &metrics,
        batch_sizes,
        dynamic,
        max_batch,
        xbuf: vec![0f32; xcap * feat_len],
        feat_len,
        classes,
        nj_per_inf,
    };

    loop {
        // block for the first request
        match rx.recv() {
            Ok(Msg::Req(r)) => queue.push(r),
            Ok(Msg::Stop) | Err(_) => break,
        }
        // batching window: gather more until max_wait or queue full
        let deadline = Instant::now() + cfg.max_wait;
        while queue.len() < max_queue {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Req(r)) => queue.push(r),
                Ok(Msg::Stop) => {
                    disp.drain(&mut state, &mut queue)?;
                    return Ok(());
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        disp.drain(&mut state, &mut queue)?;

        // drift management between dispatches
        if cfg.reprogram && state.needs_reprogram() {
            state.reprogram(&store, &cfg.vid)?;
        }
    }
    Ok(())
}
