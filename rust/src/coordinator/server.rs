//! The serving loop: request intake -> dynamic batcher -> backend executor,
//! with PCM drift management in the background of every dispatch.
//!
//! The executor is any [`crate::backend::InferenceBackend`] — the native
//! simulator by default (hermetic: no XLA, no exported HLO), the
//! tile-faithful AnalogCim engine
//! (`ServeConfig::backend = BackendKind::AnalogCim`), or the compiled
//! PJRT graphs when built with the `pjrt` feature. The dispatch machinery
//! itself — dispatch state, canary probe, drain — lives in
//! [`crate::coordinator::shard`], shared with the multi-model
//! [`MultiCoordinator`](crate::coordinator::MultiCoordinator) router.
//!
//! Every request carries its own [`InferOpts`] (device age `t_drift`, ADC
//! bitwidth `adc_bits`): the drain partitions the queue into
//! option-compatible groups
//! ([`crate::coordinator::batcher::group_fifo`], keyed with the shard's
//! model index via [`crate::coordinator::batcher::model_batch_key`]) and
//! executes each group as its own launch sequence, reading PCM weights at
//! the group's requested age
//! ([`PcmState::weights_at`](crate::coordinator::PcmState::weights_at))
//! and quantizing at the group's
//! bitwidth. Requests without options (`InferOpts::default()` —
//! [`Coordinator::submit`]) serve at the coordinator clock's current
//! device age and the backend's configured bits, exactly as before the
//! options existed.
//!
//! Engines that accept arbitrary batch shapes
//! (`InferenceBackend::supports_dynamic_batch`, i.e. the native
//! layer-serial engines) get the zero-padding FIFO drain: up to
//! [`ServeConfig::max_batch`] queued requests per group are packed into a
//! *single* `run_batch`, which executes one im2col + one batched GEMM per
//! layer across the whole batch — the AON-CiM layer-serial schedule.
//! Static-shape engines (PJRT) keep the padded multi-launch plan over
//! their exported graph sizes.
//!
//! Every launch is also priced on the modeled AON-CiM schedule
//! ([`crate::timing::ScheduleModel`]): the metrics ledger accumulates
//! modeled nJ and ops per drain (plus refresh/reprogram overheads), which
//! surface as `modeled_uj_per_inf` / `modeled_tops_w` in
//! [`MetricsSummary`](crate::coordinator::metrics::MetricsSummary). With
//! [`ServeConfig::latency_slo_us`] set, the same estimator drives the
//! batcher: see [`crate::coordinator::batcher::slo_operating_point`].

use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::backend::{self, BackendKind, InferOpts};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::shard::{Shard, ShardConfig};
use crate::pcm::FaultSpec;
use crate::runtime::ArtifactStore;

#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// artifact variant to serve, e.g. "kws_full_e10_8b"
    pub vid: String,
    pub bits: u32,
    /// which execution engine serves the traffic
    pub backend: BackendKind,
    /// batcher window: how long to wait for more requests after the first
    pub max_wait: Duration,
    /// largest single launch for dynamically-shaped backends (`0` = use the
    /// backend's largest advertised batch size). Ignored by static-shape
    /// engines, whose launch sizes are fixed by their exported graphs.
    pub max_batch: usize,
    /// native GEMM worker-pool size (`0` = automatic: all cores, capped
    /// at 8). Ignored by the PJRT backend.
    pub threads: usize,
    /// simulated seconds per wall second (drift clock acceleration)
    pub time_scale: f64,
    /// device age (simulated seconds since programming) the serving
    /// **clock** starts at — serve a day-old (86 400) or year-old array
    /// immediately instead of waiting for the accelerated clock to get
    /// there. Clamped below at t_c = 25 s by the PCM state.
    ///
    /// Soft-deprecated as a *request* age: this field only seeds the
    /// coordinator-wide clock that option-less requests serve at. Requests
    /// that need a specific device age should carry it themselves via
    /// [`InferOpts::t_drift`] ([`Coordinator::submit_with`]), which wins
    /// over the clock for that request and lets one coordinator serve
    /// many ages concurrently.
    pub drift_time: f64,
    pub seed: u64,
    /// simulated seconds between weight refreshes (fresh read noise + GDC)
    pub refresh_every_s: f64,
    /// reprogram the array when mean GDC alpha exceeds 1.15
    pub reprogram: bool,
    /// deployment-default device-variability scenario: stamped onto the
    /// programmed array at worker start
    /// ([`PcmState::set_faults`](crate::coordinator::PcmState::set_faults))
    /// and
    /// re-stamped after every reprogram. Option-less requests serve this
    /// scenario; requests carrying their own [`InferOpts::faults`] win for
    /// that request. [`FaultSpec::none()`] (the default) serves the
    /// pristine array bit for bit.
    pub faults: FaultSpec,
    /// per-launch latency SLO in microseconds, priced against the modeled
    /// AON-CiM launch schedule ([`crate::timing::ScheduleModel`]). When
    /// set, each drained
    /// group's batch cap comes from the estimator — the largest batch whose
    /// *modeled* accelerator latency stays within the SLO — instead of the
    /// fixed `max_batch`; requests that opted into a bitwidth range
    /// ([`InferOpts::adc_bits_floor`]) may additionally be requantized down
    /// to the highest bitwidth whose single-inference model fits. `None`
    /// (the default) keeps the fixed-config batcher exactly as before.
    /// The SLO governs *planning*, not admission: an impossible SLO still
    /// serves at batch 1 rather than rejecting traffic.
    pub latency_slo_us: Option<f64>,
    pub artifacts_dir: std::path::PathBuf,
}

impl ServeConfig {
    pub fn new(vid: &str, bits: u32) -> Self {
        ServeConfig {
            vid: vid.to_string(),
            bits,
            backend: BackendKind::default(),
            max_wait: Duration::from_millis(2),
            max_batch: 0,
            threads: 0,
            time_scale: 1.0,
            drift_time: crate::pcm::T_C_SECONDS,
            seed: 7,
            refresh_every_s: 60.0,
            reprogram: false,
            faults: FaultSpec::none(),
            latency_slo_us: None,
            artifacts_dir: crate::nn::manifest::artifacts_dir(),
        }
    }

    /// Builder-style backend selection.
    pub fn with_backend(mut self, backend: BackendKind) -> Self {
        self.backend = backend;
        self
    }

    /// Builder-style dynamic-batch cap.
    pub fn with_max_batch(mut self, max_batch: usize) -> Self {
        self.max_batch = max_batch;
        self
    }

    /// Builder-style initial device age of the serving clock (see
    /// [`drift_time`](Self::drift_time); per-request ages go through
    /// [`InferOpts::t_drift`] instead).
    pub fn with_drift_time(mut self, drift_time_s: f64) -> Self {
        self.drift_time = drift_time_s;
        self
    }

    /// Builder-style deployment-default fault scenario (see
    /// [`faults`](Self::faults)).
    pub fn with_faults(mut self, faults: FaultSpec) -> Self {
        self.faults = faults;
        self
    }

    /// Builder-style modeled-latency SLO (see
    /// [`latency_slo_us`](Self::latency_slo_us)).
    pub fn with_latency_slo_us(mut self, slo_us: f64) -> Self {
        self.latency_slo_us = Some(slo_us);
        self
    }
}

pub struct Request {
    pub features: Vec<f32>,
    /// per-request options this request must be served under
    pub(crate) opts: InferOpts,
    pub(crate) reply: mpsc::Sender<Response>,
    pub(crate) submitted: Instant,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub pred: u32,
    pub logits: Vec<f32>,
    pub latency: Duration,
    /// device age (simulated seconds) when served: the request's own
    /// `InferOpts::t_drift` (clamped at t_c) when set, the coordinator
    /// clock otherwise
    pub sim_age_s: f64,
    /// ADC bitwidth this response was computed at: the request's own
    /// `InferOpts::adc_bits` when set, the backend's configured bits
    /// otherwise
    pub adc_bits: u32,
}

/// Result of one canary health probe: the worker runs a fixed synthetic
/// batch through the serving engine under the deployment-default fault
/// scenario and compares argmax predictions against a clean native
/// reference computed once at startup. `degraded` means agreement fell
/// below 3 of 4 — the coordinator keeps serving (graceful degradation),
/// but every response dispatched while degraded counts under
/// `Metrics::degraded_responses`.
#[derive(Clone, Copy, Debug)]
pub struct HealthReport {
    /// canary samples probed
    pub canary: usize,
    /// canaries whose analog argmax matched the clean native reference
    pub agree: usize,
    /// agreement below the 3/4 threshold
    pub degraded: bool,
}

enum Msg {
    Req(Request),
    Probe(mpsc::Sender<HealthReport>),
    Stop,
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: mpsc::Sender<Msg>,
    handle: Option<JoinHandle<anyhow::Result<()>>>,
    pub metrics: Arc<Metrics>,
    pub classes: usize,
    pub feat_len: usize,
    /// for rejecting per-request options the backend cannot serve *at
    /// submit time* — a bad option must fail its own request, never reach
    /// the worker and kill the session for everyone
    backend: BackendKind,
    bits: u32,
}

impl Coordinator {
    /// Start the worker thread (it owns the backend and the PCM state).
    pub fn start(cfg: ServeConfig) -> anyhow::Result<Coordinator> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        // probe the artifacts AND the backend on the caller thread, so a
        // missing variant, an uncompiled `pjrt` feature, a missing XLA
        // library, or a bitwidth with no serving graphs all fail fast here
        // with their real error instead of dying inside the worker (where
        // clients would only ever see "coordinator stopped")
        let store = ArtifactStore::open(&cfg.artifacts_dir)?;
        let meta = store.meta(&cfg.vid)?;
        // the deployment-default fault scenario obeys the same per-engine
        // gates as per-request specs: an invalid spec (or one this engine
        // cannot execute, e.g. ADC errors outside AnalogCim) fails here
        // with its real error instead of inside the worker
        backend::validate_opts(cfg.backend, cfg.bits, &InferOpts {
            faults: Some(cfg.faults),
            ..InferOpts::default()
        })?;
        {
            let be = backend::create(cfg.backend, &store, &cfg.vid, cfg.bits)?;
            be.probe()?;
            anyhow::ensure!(
                !be.batch_sizes().is_empty(),
                "variant {} has no {}b serving graphs for backend `{}`",
                cfg.vid,
                cfg.bits,
                be.name()
            );
        }
        let (ih, iw, ic) = meta.input_hwc;
        let classes = meta.num_classes;
        let feat_len = ih * iw * ic;
        drop(store);

        let (backend, bits) = (cfg.backend, cfg.bits);
        let handle = std::thread::Builder::new()
            .name("aon-cim-coordinator".into())
            .spawn(move || worker(cfg, rx, m2))?;
        Ok(Coordinator {
            tx,
            handle: Some(handle),
            metrics,
            classes,
            feat_len,
            backend,
            bits,
        })
    }

    /// Submit a request with default options (serving-clock device age,
    /// backend-configured bits); returns the channel the response arrives
    /// on.
    pub fn submit(&self, features: Vec<f32>) -> anyhow::Result<mpsc::Receiver<Response>> {
        self.submit_with(features, InferOpts::default())
    }

    /// Submit a request with explicit per-request options. Requests whose
    /// options differ are drained into separate batches; a request only
    /// ever shares a launch with option-identical peers.
    ///
    /// Options the backend cannot serve are rejected **here**, so an
    /// invalid request fails on its own submit instead of erroring inside
    /// the worker and taking the session down with it.
    pub fn submit_with(&self, features: Vec<f32>, opts: InferOpts)
                       -> anyhow::Result<mpsc::Receiver<Response>> {
        // every failure path below is a submit-time reject; count them so
        // operators can tell "traffic dropped" from "traffic went bad"
        if features.len() != self.feat_len {
            self.metrics.submit_rejects.fetch_add(1, Ordering::Relaxed);
            anyhow::bail!("bad feature length {} (model wants {})",
                          features.len(), self.feat_len);
        }
        if let Err(e) = backend::validate_opts(self.backend, self.bits, &opts) {
            self.metrics.submit_rejects.fetch_add(1, Ordering::Relaxed);
            return Err(e);
        }
        let (rtx, rrx) = mpsc::channel();
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Msg::Req(Request {
                features,
                opts,
                reply: rtx,
                submitted: Instant::now(),
            }))
            .map_err(|_| {
                self.metrics.submit_rejects.fetch_add(1, Ordering::Relaxed);
                anyhow::anyhow!("coordinator stopped")
            })?;
        Ok(rrx)
    }

    /// Blocking single inference with default options.
    pub fn infer(&self, features: Vec<f32>) -> anyhow::Result<Response> {
        self.infer_with(features, InferOpts::default())
    }

    /// Blocking single inference with explicit per-request options.
    pub fn infer_with(&self, features: Vec<f32>, opts: InferOpts)
                      -> anyhow::Result<Response> {
        let rx = self.submit_with(features, opts)?;
        rx.recv().map_err(|_| anyhow::anyhow!("coordinator dropped request"))
    }

    /// Run a health probe now and return its report: the worker replays
    /// the canary batch through the serving engine (current device age,
    /// deployment-default fault scenario) and spot-checks argmax
    /// consistency against the clean native reference. Also runs
    /// automatically at startup, after every reprogram, and after each
    /// weight-refresh cadence; this entry point is for operators who want
    /// an on-demand answer (and for tests).
    pub fn probe_health(&self) -> anyhow::Result<HealthReport> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Probe(rtx))
            .map_err(|_| anyhow::anyhow!("coordinator stopped"))?;
        rrx.recv()
            .map_err(|_| anyhow::anyhow!("coordinator stopped"))
    }

    /// Graceful-shutdown hook for shared (`Arc`-held) coordinators: ask
    /// the worker to drain the queue and exit, without consuming the
    /// handle. In-flight requests still receive their responses; later
    /// submits fail with "coordinator stopped" (and count as submit
    /// rejects). [`stop`](Self::stop) — or `Drop` — still joins the
    /// worker afterwards.
    pub fn request_stop(&self) {
        let _ = self.tx.send(Msg::Stop);
    }

    pub fn stop(mut self) -> anyhow::Result<()> {
        let _ = self.tx.send(Msg::Stop);
        if let Some(h) = self.handle.take() {
            h.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
        }
        Ok(())
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The single-model worker: one [`Shard`] driven whole — block for the
/// first request, gather a batching window, drain the entire staging
/// queue, then run drift management. All dispatch machinery lives in
/// [`crate::coordinator::shard`], shared verbatim with the multi-model
/// router.
fn worker(cfg: ServeConfig, rx: mpsc::Receiver<Msg>, metrics: Arc<Metrics>)
          -> anyhow::Result<()> {
    let max_wait = cfg.max_wait;
    let model_id = cfg.vid.clone();
    // per_model = false: the single-model ledger stays exactly as it was
    // before sharding existed (no per-model breakdown for one model)
    let mut sh = Shard::build(ShardConfig::new(&model_id, cfg), 0, false,
                              &metrics)?;

    loop {
        // block for the first request
        match rx.recv() {
            Ok(Msg::Req(r)) => sh.queue.push(r),
            Ok(Msg::Probe(reply)) => {
                let hr = sh.probe_now(&metrics)?;
                let _ = reply.send(hr);
                continue;
            }
            Ok(Msg::Stop) | Err(_) => break,
        }
        // batching window: gather more until max_wait or queue full
        let deadline = Instant::now() + max_wait;
        while sh.queue.len() < sh.max_queue {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Req(r)) => sh.queue.push(r),
                Ok(Msg::Probe(reply)) => {
                    let hr = sh.probe_now(&metrics)?;
                    let _ = reply.send(hr);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        sh.drain_all(&metrics)?;
        // drift management between dispatches (reprogram + re-probe when
        // the served weights moved)
        sh.maintain(&metrics)?;
    }
    Ok(())
}
