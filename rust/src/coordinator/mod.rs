//! The always-on serving coordinator (L3).
//!
//! Owns the request loop of the AON-CiM accelerator: clients submit feature
//! frames (KWS spectrograms / VWW images), the batcher drains them into
//! layer-serial batched launches (zero-padding FIFO chunks on the native
//! engine, padded static-graph plans on PJRT), the PCM state manager
//! advances the drift clock and periodically recalibrates GDC, and the
//! executor is any [`backend::InferenceBackend`](crate::backend). Python is
//! never on this path.

pub mod batcher;
pub mod metrics;
pub mod server;
pub mod state;

pub use batcher::BatchPlan;
pub use metrics::Metrics;
pub use server::{Coordinator, Request, Response, ServeConfig};
pub use state::PcmState;
