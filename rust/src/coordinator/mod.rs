//! The always-on serving coordinator (L3).
//!
//! Owns the request loop of the AON-CiM accelerator: clients submit feature
//! frames (KWS spectrograms / VWW images), the batcher drains them into
//! layer-serial batched launches (zero-padding FIFO chunks on the native
//! engine, padded static-graph plans on PJRT), the PCM state manager
//! advances the drift clock and periodically recalibrates GDC, and the
//! executor is any [`backend::InferenceBackend`](crate::backend). Python is
//! never on this path.
//!
//! Clients are either in-process (`Coordinator::submit_with`) or remote
//! over the wire protocol ([`crate::server::WireServer`], which fronts a
//! shared coordinator with a TCP listener and feeds the same submit
//! path). Wire traffic is visible in [`Metrics`] as `wire_requests` /
//! `wire_rejects`; shared coordinators stop gracefully via
//! [`Coordinator::request_stop`].
//!
//! One process can also serve *several* models at once: a
//! [`MultiCoordinator`] owns N model shards ([`ShardConfig`] each — its
//! own backend, PCM state, fault scenario, drift clock, and schedule
//! pricing) behind a single `submit(model_id, x, opts)` API, with
//! per-model admission control and a weighted round-robin drain so a hot
//! model cannot starve a quiet one. Batch grouping keys on
//! [`batcher::model_batch_key`], so launches never mix models.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;
pub mod shard;
pub mod state;

pub use batcher::BatchPlan;
pub use metrics::Metrics;
pub use router::{ModelInfo, MultiCoordinator};
pub use server::{Coordinator, HealthReport, Request, Response, ServeConfig};
pub use shard::ShardConfig;
pub use state::PcmState;
