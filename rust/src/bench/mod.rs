//! Benchmark harness (criterion is not vendored; every `cargo bench` target
//! is a `harness = false` binary built on these helpers).

use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::util::json::{self, Json};

/// Where bench binaries drop their table/CSV/JSON outputs.
pub fn out_dir() -> PathBuf {
    let d = PathBuf::from("bench_out");
    let _ = std::fs::create_dir_all(&d);
    d
}

/// Save a bench artifact (rendered table / CSV series).
pub fn save(name: &str, content: &str) {
    let p = out_dir().join(name);
    if let Err(e) = std::fs::write(&p, content) {
        eprintln!("warn: could not write {}: {e}", p.display());
    } else {
        println!("[bench] wrote {}", p.display());
    }
}

/// Save a machine-readable bench artifact (e.g. `BENCH_native.json`).
pub fn save_json(name: &str, v: &Json) {
    let mut s = json::write(v);
    s.push('\n');
    save(name, &s);
}

/// CI regression gate: compare a measured value against field `key` of a
/// committed baseline JSON; fail when it drops more than `max_drop`
/// (fraction, e.g. 0.30 = 30%) below the baseline. Improvements always
/// pass — the baseline is a floor, ratcheted up by committing fresh CI
/// numbers.
pub fn check_regression(current: f64, baseline_path: &Path, key: &str,
                        max_drop: f64) -> anyhow::Result<()> {
    let v = json::parse_file(baseline_path)?;
    let base = v.req(key)?.as_f64()?;
    let floor = base * (1.0 - max_drop);
    anyhow::ensure!(
        current >= floor,
        "{key} regressed: {current:.1} is below the floor {floor:.1} \
         ({:.0}% of committed baseline {base:.1} in {})",
        100.0 * (1.0 - max_drop),
        baseline_path.display()
    );
    println!(
        "[bench] regression gate OK: {key} {current:.1} >= floor {floor:.1} \
         (baseline {base:.1})"
    );
    Ok(())
}

/// Timing statistics over repeated runs of `f` (after `warmup` runs).
pub struct Timing {
    pub iters: usize,
    pub mean_us: f64,
    pub std_us: f64,
    pub min_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
}

impl std::fmt::Display for Timing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.1}us std={:.1}us min={:.1}us p50={:.1}us p99={:.1}us",
            self.iters, self.mean_us, self.std_us, self.min_us, self.p50_us,
            self.p99_us
        )
    }
}

pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut us = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        us.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    use crate::util::stats;
    Timing {
        iters,
        mean_us: stats::mean(&us),
        std_us: stats::std(&us),
        min_us: us.iter().copied().fold(f64::INFINITY, f64::min),
        p50_us: stats::percentile(&us, 50.0),
        p99_us: stats::percentile(&us, 99.0),
    }
}

/// Shared bench CLI knobs (`--runs`, `--samples`, `--fast`, `--backend`,
/// `--baseline`, `--strict`).
pub struct BenchOpts {
    pub runs: usize,
    pub max_samples: usize,
    pub fast: bool,
    /// execution engine for eval-driven benches (default native; pass
    /// `--backend pjrt` with a `--features pjrt` build to reproduce the
    /// figures over the exported HLO graphs)
    pub backend: crate::backend::BackendKind,
    /// path to a committed baseline JSON; benches that support it exit
    /// non-zero when their headline metric regresses past the gate
    pub baseline: Option<String>,
    /// turn machine-dependent soft targets (e.g. batched speedup) into
    /// hard failures
    pub strict: bool,
}

impl BenchOpts {
    pub fn from_env_args() -> Self {
        let a = crate::util::cli::Args::from_env();
        // `cargo bench -- --fast` and the env var both work
        let fast = a.flag("fast")
            || std::env::var("FAST").map(|v| v != "0" && !v.is_empty()).unwrap_or(false);
        BenchOpts {
            runs: a.opt_usize("runs", if fast { 2 } else { 3 }),
            max_samples: a.opt_usize("samples", if fast { 128 } else { 256 }),
            fast,
            backend: crate::backend::BackendKind::from_args(&a)
                .expect("--backend native|pjrt"),
            baseline: a.opt("baseline").map(String::from),
            strict: a.flag("strict"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regression_gate_floor_math() {
        let dir = std::env::temp_dir().join("analognets_bench_gate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("base.json");
        std::fs::write(&p, "{\"req_s\": 100.0}").unwrap();
        assert!(check_regression(200.0, &p, "req_s", 0.3).is_ok());
        assert!(check_regression(71.0, &p, "req_s", 0.3).is_ok());
        assert!(check_regression(69.0, &p, "req_s", 0.3).is_err());
        assert!(check_regression(100.0, &p, "missing_key", 0.3).is_err());
        assert!(check_regression(1.0, &dir.join("nope.json"), "req_s", 0.3).is_err());
    }

    #[test]
    fn time_it_counts() {
        let mut n = 0;
        let t = time_it(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(t.iters, 5);
        assert!(t.min_us <= t.p50_us && t.p50_us <= t.p99_us + 1e-9);
    }
}
