//! Blocked f32 GEMM for the native simulator.
//!
//! C[M,N] = A[M,K] @ B[K,N], row-major.  The kernel is a straightforward
//! i-k-j loop with a register-blocked inner loop — the B row reuse along `j`
//! autovectorizes well; the §Perf pass adds thread-level parallelism over
//! row chunks.

/// Single-threaded blocked GEMM.
pub fn gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    gemm_into(a, b, &mut c, m, k, n);
    c
}

/// GEMM into a preallocated buffer (hot path; avoids allocation).
pub fn gemm_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    // i-k-j: inner loop streams one row of B, accumulating into one row of C
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue; // quantized activations are often exactly zero
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cj, &bj) in crow.iter_mut().zip(brow.iter()) {
                *cj += aik * bj;
            }
        }
    }
}

/// Multi-threaded GEMM over row chunks (scoped threads, no deps).
pub fn gemm_parallel(a: &[f32], b: &[f32], m: usize, k: usize, n: usize,
                     threads: usize) -> Vec<f32> {
    if threads <= 1 || m < 64 {
        return gemm(a, b, m, k, n);
    }
    let mut c = vec![0f32; m * n];
    let chunk = (m + threads - 1) / threads;
    std::thread::scope(|s| {
        for (ci, cchunk) in c.chunks_mut(chunk * n).enumerate() {
            let lo = ci * chunk;
            let rows = cchunk.len() / n;
            let a = &a[lo * k..(lo + rows) * k];
            s.spawn(move || {
                gemm_into(a, b, cchunk, rows, k, n);
            });
        }
    });
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0f64;
                for kk in 0..k {
                    s += a[i * k + kk] as f64 * b[kk * n + j] as f64;
                }
                c[i * n + j] = s as f32;
            }
        }
        c
    }

    #[test]
    fn matches_naive() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [(3, 4, 5), (17, 9, 13), (64, 27, 48)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
            let c = gemm(&a, &b, m, k, n);
            let want = naive(&a, &b, m, k, n);
            for (x, y) in c.iter().zip(want.iter()) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Rng::new(2);
        let (m, k, n) = (200, 36, 40);
        let a: Vec<f32> = (0..m * k).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
        let c1 = gemm(&a, &b, m, k, n);
        let c2 = gemm_parallel(&a, &b, m, k, n, 4);
        assert_eq!(c1, c2);
    }

    #[test]
    fn identity() {
        let m = 4;
        let mut eye = vec![0f32; m * m];
        for i in 0..m {
            eye[i * m + i] = 1.0;
        }
        let a: Vec<f32> = (0..m * m).map(|i| i as f32).collect();
        assert_eq!(gemm(&a, &eye, m, m, m), a);
    }
}
