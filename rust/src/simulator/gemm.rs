//! Blocked f32 GEMM for the native simulator.
//!
//! C[M,N] = A[M,K] @ B[K,N], row-major.  The kernel is a straightforward
//! i-k-j loop with a register-blocked inner loop — the B row reuse along `j`
//! autovectorizes well.  Thread-level parallelism over row chunks runs on
//! the persistent [`pool::WorkerPool`](crate::simulator::pool::WorkerPool)
//! (no per-call thread spawning; each output row is computed independently
//! with an identical accumulation order, so chunking never changes results).

use crate::simulator::pool;

/// Row count below which parallel dispatch is not worth the latch overhead:
/// a chunked launch costs ~2 channel/condvar round trips per lane, which at
/// fewer than this many rows exceeds the GEMM work itself for the layer
/// shapes we serve.  Callers asking for many threads on a small `m` are
/// deliberately (and now visibly) run single-threaded.
pub const PAR_ROW_THRESHOLD: usize = 64;

/// Resolve a thread-count knob: `0` means "use every available core"
/// (`std::thread::available_parallelism`), anything else is taken as-is.
pub fn effective_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        threads
    }
}

/// Single-threaded blocked GEMM.
pub fn gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    gemm_into(a, b, &mut c, m, k, n);
    c
}

/// GEMM into a preallocated buffer (hot path; avoids allocation).
pub fn gemm_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    // i-k-j: inner loop streams one row of B, accumulating into one row of C
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue; // quantized activations are often exactly zero
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cj, &bj) in crow.iter_mut().zip(brow.iter()) {
                *cj += aik * bj;
            }
        }
    }
}

/// Multi-threaded GEMM over row chunks on the process-wide persistent
/// worker pool ([`pool::global`]).  `threads == 0` means
/// [`effective_threads`] (all cores); `m < `[`PAR_ROW_THRESHOLD`] always
/// runs single-threaded regardless of `threads` (see the constant's docs).
/// Engines that own a pool (`NativeModel`) call it directly instead.
pub fn gemm_parallel(a: &[f32], b: &[f32], m: usize, k: usize, n: usize,
                     threads: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    gemm_parallel_into(a, b, &mut c, m, k, n, threads);
    c
}

/// [`gemm_parallel`] into a preallocated buffer.
pub fn gemm_parallel_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize,
                          k: usize, n: usize, threads: usize) {
    let lanes = effective_threads(threads);
    if lanes <= 1 || m < PAR_ROW_THRESHOLD {
        gemm_into(a, b, c, m, k, n);
    } else {
        pool::global().gemm_chunks(a, b, c, m, k, n, lanes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0f64;
                for kk in 0..k {
                    s += a[i * k + kk] as f64 * b[kk * n + j] as f64;
                }
                c[i * n + j] = s as f32;
            }
        }
        c
    }

    #[test]
    fn matches_naive() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [(3, 4, 5), (17, 9, 13), (64, 27, 48)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
            let c = gemm(&a, &b, m, k, n);
            let want = naive(&a, &b, m, k, n);
            for (x, y) in c.iter().zip(want.iter()) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Rng::new(2);
        let (m, k, n) = (200, 36, 40);
        let a: Vec<f32> = (0..m * k).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
        let c1 = gemm(&a, &b, m, k, n);
        let c2 = gemm_parallel(&a, &b, m, k, n, 4);
        assert_eq!(c1, c2);
    }

    /// Satellite invariant: chunked parallel dispatch is bit-exact against
    /// the serial kernel over ragged row-chunk shapes (m not divisible by
    /// the lane count, m straddling the threshold, more lanes than rows).
    #[test]
    fn prop_parallel_bit_exact_ragged_shapes() {
        let mut rng = Rng::new(0xBEEF);
        for trial in 0..40 {
            let m = 1 + rng.below(300);
            let k = 1 + rng.below(48);
            let n = 1 + rng.below(24);
            let threads = rng.below(9); // includes 0 = available_parallelism
            let a: Vec<f32> = (0..m * k).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
            let c1 = gemm(&a, &b, m, k, n);
            let c2 = gemm_parallel(&a, &b, m, k, n, threads);
            assert_eq!(c1, c2,
                       "trial {trial}: m={m} k={k} n={n} threads={threads}");
        }
    }

    #[test]
    fn threshold_and_thread_knob_semantics() {
        // documented: below the threshold the row count wins over `threads`
        assert_eq!(PAR_ROW_THRESHOLD, 64);
        assert!(effective_threads(0) >= 1);
        assert_eq!(effective_threads(3), 3);
        // small-m calls still produce correct results at any thread count
        let a = vec![1.0f32; 4 * 2];
        let b = vec![2.0f32; 2 * 3];
        let c = gemm_parallel(&a, &b, 4, 2, 3, 0);
        assert!(c.iter().all(|&v| v == 4.0));
    }

    #[test]
    fn identity() {
        let m = 4;
        let mut eye = vec![0f32; m * m];
        for i in 0..m {
            eye[i * m + i] = 1.0;
        }
        let a: Vec<f32> = (0..m * m).map(|i| i as f32).collect();
        assert_eq!(gemm(&a, &eye, m, m, m), a);
    }
}
