//! Blocked, packed f32 GEMM for the native simulator.
//!
//! `C[M,N] = A[M,K] @ B[K,N]`, row-major. Two kernels live here:
//!
//! * [`gemm_naive_into`] — the historical i-k-j reference loop (zero-skip
//!   on the A operand, ascending-k accumulation). It defines the
//!   bit-pattern every other path is measured against, and it is what the
//!   analog per-tile MVM (`analog_forward::tile_band`) replicates — so it
//!   must never change.
//! * The blocked kernel — A and B are packed into contiguous
//!   register-block panels ([`tiling::MR`]-row groups, [`tiling::NR`]-column
//!   strips), a register-blocked microkernel sweeps packed panels, and a
//!   [`TilingScheme`] names the macro-tile / k-slice dimensions. The
//!   persistent [`pool::WorkerPool`] distributes (m-block x n-block)
//!   macro-tiles; each output element is owned by exactly one tile, so the
//!   parallel result is bit-identical to the serial one for *any* scheme.
//!
//! ## Bit-exactness
//!
//! Within one k-block the microkernel accumulates each output element in
//! ascending-k order from `+0.0` — the same per-element sequence as the
//! naive loop. The naive loop's zero-skip (`aik == 0.0 => skip`) is
//! dropped in the packed kernel, which is still bit-identical for finite
//! operands: adding `±0.0 * b` to an accumulator that started at `+0.0`
//! can neither change its value nor flip it to `-0.0` (IEEE-754
//! round-to-nearest: `+0.0 + ±0.0 = +0.0`, and a cancelling sum of
//! nonzero terms yields `+0.0`). Rust never contracts `a*b + c` into an
//! FMA, so single-k-block schemes are bit-exact with [`gemm_naive_into`]
//! (property-tested below, including exact-zero-laden operands).
//!
//! Splitting k into several blocks stores `c = block0 + block1 + ...`,
//! which regroups the f32 sums — close (f64-bounded, tested) but not
//! bit-identical. Default entry points therefore clamp the process-wide
//! scheme through [`TilingScheme::full_k`]; k-split runs only through the
//! explicit-scheme entry points ([`gemm_blocked_into`],
//! [`gemm_with_scheme_into`]) that `NativeGemmEngine::with_scheme` opts
//! into.

use std::cell::RefCell;

use crate::simulator::pool::{self, RawSlice, RawSliceMut, WorkerPool};
use crate::simulator::tiling::{self, TilingScheme, MR, NR};

/// Row count below which parallel dispatch is not worth the latch overhead:
/// a macro-tile launch costs ~2 channel/condvar round trips per lane, which
/// at fewer than this many rows exceeds the GEMM work itself for the layer
/// shapes we serve.  Callers asking for many threads on a small `m` are
/// deliberately (and now visibly) run single-threaded.
pub const PAR_ROW_THRESHOLD: usize = 64;

/// Below this many multiply-adds the blocked path's packing traffic
/// rivals the multiply itself; [`gemm_into`] falls through to the naive
/// kernel instead (bit-identical either way — single-k-block blocked and
/// naive agree, this is purely a latency knob).
const BLOCKED_MIN_MACS: usize = 4096;

/// Resolve a thread-count knob: `0` means "use every available core"
/// (`std::thread::available_parallelism`), anything else is taken as-is.
pub fn effective_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        threads
    }
}

/// Single-threaded GEMM (blocked kernel, process-wide scheme).
pub fn gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    gemm_into(a, b, &mut c, m, k, n);
    c
}

/// GEMM into a preallocated buffer (hot path; avoids allocation).
/// Runs the blocked kernel under the process-wide [`tiling::global`]
/// scheme clamped to a single k-block — bit-identical to
/// [`gemm_naive_into`], which tiny shapes fall through to directly.
pub fn gemm_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    if m * k * n < BLOCKED_MIN_MACS {
        gemm_naive_into(a, b, c, m, k, n);
    } else {
        gemm_blocked_into(a, b, c, m, k, n, tiling::global().full_k());
    }
}

/// The historical reference kernel: i-k-j loop, ascending-k accumulation,
/// zero-skip on the A operand. This is the bit-pattern oracle for the
/// blocked kernel's single-k-block property tests and the accumulation
/// order `analog_forward::tile_band` replicates per crossbar tile — do
/// not change its numerics.
pub fn gemm_naive_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize,
                       k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    // i-k-j: inner loop streams one row of B, accumulating into one row of C
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue; // quantized activations are often exactly zero
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cj, &bj) in crow.iter_mut().zip(brow.iter()) {
                *cj += aik * bj;
            }
        }
    }
}

/// Multi-threaded GEMM over packed macro-tiles on the process-wide
/// persistent worker pool ([`pool::global`]).  `threads == 0` means
/// [`effective_threads`] (all cores); `m < `[`PAR_ROW_THRESHOLD`] always
/// runs single-threaded regardless of `threads` (see the constant's docs).
/// Engines that own a pool (`NativeModel`) call it directly instead.
pub fn gemm_parallel(a: &[f32], b: &[f32], m: usize, k: usize, n: usize,
                     threads: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    gemm_parallel_into(a, b, &mut c, m, k, n, threads);
    c
}

/// [`gemm_parallel`] into a preallocated buffer.
pub fn gemm_parallel_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize,
                          k: usize, n: usize, threads: usize) {
    let lanes = effective_threads(threads);
    if lanes <= 1 || m < PAR_ROW_THRESHOLD {
        gemm_into(a, b, c, m, k, n);
    } else {
        gemm_blocked_pool_into(pool::global(), a, b, c, m, k, n,
                               tiling::global().full_k(), lanes);
    }
}

/// The pre-blocked row-parallel path, kept verbatim for comparison: naive
/// kernel over `threads` row chunks on the global pool (what
/// `gemm_parallel` was before the packed kernel landed). The bench's
/// `gemm` section measures the blocked kernel against this.
pub fn gemm_rowpar(a: &[f32], b: &[f32], m: usize, k: usize, n: usize,
                   threads: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    gemm_rowpar_into(a, b, &mut c, m, k, n, threads);
    c
}

/// [`gemm_rowpar`] into a preallocated buffer.
pub fn gemm_rowpar_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize,
                        k: usize, n: usize, threads: usize) {
    let lanes = effective_threads(threads);
    if lanes <= 1 || m < PAR_ROW_THRESHOLD {
        gemm_naive_into(a, b, c, m, k, n);
    } else {
        pool::global().gemm_chunks(a, b, c, m, k, n, lanes);
    }
}

/// Explicit-scheme GEMM on a caller-owned pool: the entry point
/// `NativeGemmEngine::with_scheme` opts into (k-split schemes included —
/// see the module docs for what that does to f32 accumulation). Applies
/// the same small-`m` serial policy as the default paths.
#[allow(clippy::too_many_arguments)]
pub fn gemm_with_scheme_into(pool: &WorkerPool, a: &[f32], b: &[f32],
                             c: &mut [f32], m: usize, k: usize, n: usize,
                             scheme: TilingScheme) {
    if pool.lanes() <= 1 || m < PAR_ROW_THRESHOLD {
        gemm_blocked_into(a, b, c, m, k, n, scheme);
    } else {
        gemm_blocked_pool_into(pool, a, b, c, m, k, n, scheme, pool.lanes());
    }
}

thread_local! {
    /// Per-thread packing scratch (A panels, B panels): steady-state the
    /// hot path packs into capacity it already owns, allocating nothing.
    static PACK: RefCell<(Vec<f32>, Vec<f32>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
}

/// Pack `A[M,K]` into MR-row groups: `pa[g][kk*MR + ri]` holds
/// `A[g*MR + ri][kk]`, edge-group rows zero-padded. Each group's k-slice
/// `[k0, k0+kc)` is the contiguous run `pa[g*k*MR + k0*MR ..][.. kc*MR]`.
fn pack_a(a: &[f32], m: usize, k: usize, pa: &mut Vec<f32>) {
    let groups = m.div_ceil(MR);
    pa.clear();
    pa.resize(groups * k * MR, 0.0); // clear+resize zero-fills everything
    for g in 0..groups {
        let row0 = g * MR;
        let vrows = MR.min(m - row0);
        let dst = &mut pa[g * k * MR..(g + 1) * k * MR];
        for ri in 0..vrows {
            let src = &a[(row0 + ri) * k..(row0 + ri + 1) * k];
            for (kk, &v) in src.iter().enumerate() {
                dst[kk * MR + ri] = v;
            }
        }
    }
}

/// Pack `B[K,N]` into NR-column strips: `pb[s][kk*NR + j]` holds
/// `B[kk][s*NR + j]`, edge-strip columns zero-padded. Each strip's
/// k-slice is the contiguous run `pb[s*k*NR + k0*NR ..][.. kc*NR]`.
fn pack_b(b: &[f32], k: usize, n: usize, pb: &mut Vec<f32>) {
    let strips = n.div_ceil(NR);
    pb.clear();
    pb.resize(strips * k * NR, 0.0);
    for s in 0..strips {
        let col0 = s * NR;
        let vcols = NR.min(n - col0);
        let dst = &mut pb[s * k * NR..(s + 1) * k * NR];
        for kk in 0..k {
            dst[kk * NR..kk * NR + vcols]
                .copy_from_slice(&b[kk * n + col0..kk * n + col0 + vcols]);
        }
    }
}

/// The register-blocked microkernel: accumulate one MR x NR tile over a
/// packed k-slice. Ascending-k, per-lane-independent accumulation — the
/// per-element order is exactly the naive kernel's (see module docs).
#[inline]
fn micro_acc(pa: &[f32], pb: &[f32], acc: &mut [[f32; NR]; MR]) {
    debug_assert_eq!(pa.len() / MR, pb.len() / NR);
    for (arow, brow) in pa.chunks_exact(MR).zip(pb.chunks_exact(NR)) {
        for (accr, &aval) in acc.iter_mut().zip(arow.iter()) {
            for (accj, &bval) in accr.iter_mut().zip(brow.iter()) {
                *accj += aval * bval;
            }
        }
    }
}

/// Compute one macro-tile (rows `[i0, i0+mc)`, cols `[j0, j0+nc)`) of C
/// from packed panels, sweeping the whole inner dimension in
/// `k_block`-sized slices: the first slice stores, later slices add.
///
/// `i0`/`j0` must be multiples of [`MR`]/[`NR`] (macro-tile origins are —
/// block sizes are validated multiples of the register blocks).
///
/// # Safety
/// `rc` must point at the live `m x n` output buffer, and this tile's
/// rows x cols must not be written by anyone else while the call runs
/// (macro-tiles partition C, so concurrent jobs on distinct tiles are
/// disjoint by construction).
#[allow(clippy::too_many_arguments)]
unsafe fn tile_kernel(pa: &[f32], pb: &[f32], rc: RawSliceMut, k: usize,
                      n: usize, i0: usize, mc: usize, j0: usize, nc: usize,
                      k_block: usize) {
    debug_assert_eq!(i0 % MR, 0);
    debug_assert_eq!(j0 % NR, 0);
    let g0 = i0 / MR;
    let g1 = (i0 + mc).div_ceil(MR);
    let s0 = j0 / NR;
    let s1 = (j0 + nc).div_ceil(NR);
    let mut k0 = 0usize;
    let mut first = true;
    while k0 < k {
        let kc = k_block.min(k - k0);
        for g in g0..g1 {
            let row0 = g * MR;
            let vrows = MR.min(i0 + mc - row0);
            let pa_g = &pa[g * k * MR + k0 * MR..][..kc * MR];
            for s in s0..s1 {
                let col0 = s * NR;
                let vcols = NR.min(j0 + nc - col0);
                let pb_s = &pb[s * k * NR + k0 * NR..][..kc * NR];
                let mut acc = [[0f32; NR]; MR];
                micro_acc(pa_g, pb_s, &mut acc);
                for (ri, accr) in acc.iter().enumerate().take(vrows) {
                    // SAFETY: row segments of distinct (group, strip)
                    // pairs never overlap, and the caller guarantees this
                    // tile is exclusively ours and `rc` outlives the call.
                    let crow =
                        unsafe { rc.slice_at((row0 + ri) * n + col0, vcols) };
                    if first {
                        crow.copy_from_slice(&accr[..vcols]);
                    } else {
                        for (cj, &av) in crow.iter_mut().zip(accr.iter()) {
                            *cj += av;
                        }
                    }
                }
            }
        }
        first = false;
        k0 += kc;
    }
}

/// Serial blocked GEMM under an explicit [`TilingScheme`]. Single-k-block
/// schemes are bit-identical to [`gemm_naive_into`]; k-split schemes
/// regroup the f32 sums (see the module docs).
pub fn gemm_blocked_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize,
                         k: usize, n: usize, scheme: TilingScheme) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if c.is_empty() {
        return;
    }
    if k == 0 {
        c.fill(0.0);
        return;
    }
    let s = scheme.validated();
    PACK.with(|cell| {
        let (pa, pb) = &mut *cell.borrow_mut();
        pack_a(a, m, k, pa);
        pack_b(b, k, n, pb);
        let rc = RawSliceMut::of(c);
        let mut i0 = 0;
        while i0 < m {
            let mc = s.m_block.min(m - i0);
            let mut j0 = 0;
            while j0 < n {
                let nc = s.n_block.min(n - j0);
                // SAFETY: serial loop — every tile is written from this
                // thread only, and `c` is borrowed for the whole call.
                unsafe {
                    tile_kernel(pa, pb, rc, k, n, i0, mc, j0, nc, s.k_block);
                }
                j0 += nc;
            }
            i0 += mc;
        }
    });
}

/// Blocked GEMM with (m-block x n-block) macro-tiles distributed over
/// `pool` (at most `max_lanes` concurrent jobs; contiguous tile runs per
/// job). The caller thread packs both panels, then becomes a lane.
/// Bit-identical to [`gemm_blocked_into`] under the same scheme for any
/// lane count: each output element is owned by exactly one macro-tile.
#[allow(clippy::too_many_arguments)]
pub fn gemm_blocked_pool_into(pool: &WorkerPool, a: &[f32], b: &[f32],
                              c: &mut [f32], m: usize, k: usize, n: usize,
                              scheme: TilingScheme, max_lanes: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if c.is_empty() {
        return;
    }
    if k == 0 {
        c.fill(0.0);
        return;
    }
    let s = scheme.validated();
    let mtiles = m.div_ceil(s.m_block);
    let ntiles = n.div_ceil(s.n_block);
    let tiles = mtiles * ntiles;
    let lanes = max_lanes.min(pool.lanes()).min(tiles).max(1);
    if lanes <= 1 {
        gemm_blocked_into(a, b, c, m, k, n, s);
        return;
    }
    PACK.with(|cell| {
        let (pa, pb) = &mut *cell.borrow_mut();
        pack_a(a, m, k, pa);
        pack_b(b, k, n, pb);
        let rpa = RawSlice::of(pa);
        let rpb = RawSlice::of(pb);
        let rc = RawSliceMut::of(c);
        let per = tiles.div_ceil(lanes);
        let mut jobs: Vec<pool::Job> = Vec::with_capacity(lanes);
        let mut t0 = 0usize;
        while t0 < tiles {
            let t1 = (t0 + per).min(tiles);
            jobs.push(Box::new(move || {
                for t in t0..t1 {
                    let i0 = (t / ntiles) * s.m_block;
                    let j0 = (t % ntiles) * s.n_block;
                    let mc = s.m_block.min(m - i0);
                    let nc = s.n_block.min(n - j0);
                    // SAFETY: `run_all` blocks the dispatching thread
                    // until every job has run, so the packed panels and
                    // `c` outlive the job; tiles partition C and each
                    // tile index lands in exactly one job.
                    unsafe {
                        tile_kernel(rpa.get(), rpb.get(), rc, k, n, i0, mc,
                                    j0, nc, s.k_block);
                    }
                }
            }));
            t0 = t1;
        }
        pool.run_all(jobs);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_f64(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0f64;
                for kk in 0..k {
                    s += a[i * k + kk] as f64 * b[kk * n + j] as f64;
                }
                c[i * n + j] = s as f32;
            }
        }
        c
    }

    /// Gaussian data with an exact-zero fraction — quantized activations
    /// are often exactly 0.0, and the packed kernel drops the naive
    /// loop's zero-skip, so zeros must be exercised deliberately.
    fn zero_laden(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len)
            .map(|_| {
                if rng.below(4) == 0 {
                    0.0
                } else {
                    rng.gauss(0.0, 1.0) as f32
                }
            })
            .collect()
    }

    #[test]
    fn matches_naive() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [(3, 4, 5), (17, 9, 13), (64, 27, 48)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
            let c = gemm(&a, &b, m, k, n);
            let want = naive_f64(&a, &b, m, k, n);
            for (x, y) in c.iter().zip(want.iter()) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Rng::new(2);
        let (m, k, n) = (200, 36, 40);
        let a: Vec<f32> = (0..m * k).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
        let c1 = gemm(&a, &b, m, k, n);
        let c2 = gemm_parallel(&a, &b, m, k, n, 4);
        assert_eq!(c1, c2);
    }

    /// Tentpole invariant: the blocked kernel under any single-k-block
    /// scheme is bit-exact against the naive reference across ragged
    /// shapes — including `m < PAR_ROW_THRESHOLD`, register-block edges,
    /// and exact-zero-laden operands (the dropped zero-skip).
    #[test]
    fn prop_blocked_single_k_bit_exact_vs_naive() {
        let mut rng = Rng::new(0xD1CE);
        for trial in 0..60 {
            let m = 1 + rng.below(160);
            let k = 1 + rng.below(96);
            let n = 1 + rng.below(48);
            let scheme = TilingScheme::new(
                MR * (1 + rng.below(24)),
                usize::MAX,
                NR * (1 + rng.below(8)),
            );
            let a = zero_laden(&mut rng, m * k);
            let b = zero_laden(&mut rng, k * n);
            let mut want = vec![0f32; m * n];
            gemm_naive_into(&a, &b, &mut want, m, k, n);
            let mut got = vec![7f32; m * n]; // must be fully overwritten
            gemm_blocked_into(&a, &b, &mut got, m, k, n, scheme);
            assert_eq!(got, want,
                       "trial {trial}: serial {scheme} at {m}x{k}x{n}");
            let mut got_p = vec![7f32; m * n];
            gemm_blocked_pool_into(pool::global(), &a, &b, &mut got_p, m, k,
                                   n, scheme, 8);
            assert_eq!(got_p, want,
                       "trial {trial}: pooled {scheme} at {m}x{k}x{n}");
        }
    }

    /// Multi-k-block schemes regroup f32 sums: not bit-identical, but
    /// bounded against the f64 reference, and the pooled dispatch stays
    /// bit-identical to the serial blocked kernel (tile ownership).
    #[test]
    fn prop_multi_k_block_bounded_and_pool_exact() {
        let mut rng = Rng::new(0xFADE);
        for trial in 0..30 {
            let m = 1 + rng.below(120);
            let k = 2 + rng.below(96);
            let n = 1 + rng.below(40);
            let scheme = TilingScheme::new(
                MR * (1 + rng.below(16)),
                1 + rng.below(k), // genuine k-split most trials
                NR * (1 + rng.below(4)),
            );
            let a = zero_laden(&mut rng, m * k);
            let b = zero_laden(&mut rng, k * n);
            let want = naive_f64(&a, &b, m, k, n);
            let mut got = vec![0f32; m * n];
            gemm_blocked_into(&a, &b, &mut got, m, k, n, scheme);
            for (i, (x, y)) in got.iter().zip(want.iter()).enumerate() {
                assert!((x - y).abs() < 1e-3,
                        "trial {trial}: {scheme} at {m}x{k}x{n} elem {i}: \
                         {x} vs {y}");
            }
            let mut got_p = vec![0f32; m * n];
            gemm_blocked_pool_into(pool::global(), &a, &b, &mut got_p, m, k,
                                   n, scheme, 5);
            assert_eq!(got_p, got,
                       "trial {trial}: pooled k-split {scheme} at {m}x{k}x{n}");
        }
    }

    /// Satellite invariant: chunked parallel dispatch is bit-exact against
    /// the serial kernel over ragged row-chunk shapes (m not divisible by
    /// the lane count, m straddling the threshold, more lanes than rows).
    #[test]
    fn prop_parallel_bit_exact_ragged_shapes() {
        let mut rng = Rng::new(0xBEEF);
        for trial in 0..40 {
            let m = 1 + rng.below(300);
            let k = 1 + rng.below(48);
            let n = 1 + rng.below(24);
            let threads = rng.below(9); // includes 0 = available_parallelism
            let a: Vec<f32> = (0..m * k).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
            let c1 = gemm(&a, &b, m, k, n);
            let c2 = gemm_parallel(&a, &b, m, k, n, threads);
            assert_eq!(c1, c2,
                       "trial {trial}: m={m} k={k} n={n} threads={threads}");
        }
    }

    /// The legacy row-parallel path (kept for the bench's blocked-vs-rowpar
    /// section) still equals the naive kernel bit for bit — and therefore
    /// the blocked default too.
    #[test]
    fn rowpar_legacy_path_matches_naive() {
        let mut rng = Rng::new(0xCAFE);
        for (m, k, n) in [(40, 9, 8), (200, 36, 40), (65, 7, 17)] {
            let a = zero_laden(&mut rng, m * k);
            let b = zero_laden(&mut rng, k * n);
            let mut want = vec![0f32; m * n];
            gemm_naive_into(&a, &b, &mut want, m, k, n);
            for threads in [1, 4, 0] {
                let got = gemm_rowpar(&a, &b, m, k, n, threads);
                assert_eq!(got, want, "rowpar {m}x{k}x{n} threads={threads}");
            }
            assert_eq!(gemm(&a, &b, m, k, n), want, "blocked {m}x{k}x{n}");
        }
    }

    #[test]
    fn blocked_handles_degenerate_and_edge_shapes() {
        // k = 0: a defined all-zeros result
        let mut c = vec![5f32; 6];
        gemm_blocked_into(&[], &[], &mut c, 2, 0, 3, TilingScheme::DEFAULT);
        assert_eq!(c, vec![0f32; 6]);
        // single row/column and register-block edges (m % MR, n % NR != 0)
        let mut rng = Rng::new(77);
        for (m, k, n) in [(1, 8, 1), (1, 64, 17), (5, 3, 16), (4, 1, 33)] {
            let a = zero_laden(&mut rng, m * k);
            let b = zero_laden(&mut rng, k * n);
            let mut want = vec![0f32; m * n];
            gemm_naive_into(&a, &b, &mut want, m, k, n);
            let mut got = vec![9f32; m * n];
            gemm_blocked_into(&a, &b, &mut got, m, k, n,
                              TilingScheme::new(8, usize::MAX, 16));
            assert_eq!(got, want, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn threshold_and_thread_knob_semantics() {
        // documented: below the threshold the row count wins over `threads`
        assert_eq!(PAR_ROW_THRESHOLD, 64);
        assert!(effective_threads(0) >= 1);
        assert_eq!(effective_threads(3), 3);
        // small-m calls still produce correct results at any thread count
        let a = vec![1.0f32; 4 * 2];
        let b = vec![2.0f32; 2 * 3];
        let c = gemm_parallel(&a, &b, 4, 2, 3, 0);
        assert!(c.iter().all(|&v| v == 4.0));
    }

    #[test]
    fn identity() {
        let m = 4;
        let mut eye = vec![0f32; m * m];
        for i in 0..m {
            eye[i * m + i] = 1.0;
        }
        let a: Vec<f32> = (0..m * m).map(|i| i as f32).collect();
        assert_eq!(gemm(&a, &eye, m, m, m), a);
    }
}
