//! Tile-faithful analog CiM forward pass.
//!
//! The native engine fake-quantizes each layer's ADC *after* the full-K
//! GEMM accumulation — numerically convenient, but not what the hardware
//! does. On the AON-CiM array every crossbar tile produces *analog*
//! partial sums that pass through the tile's ADCs **before** the digital
//! processor ever sees them; K-slices programmed onto different tiles are
//! therefore quantized independently and only then accumulated in digital
//! f32. That ordering is exactly where fixed-ADC-gain error enters (Xiao
//! et al. 2021, "On the Accuracy of Analog Neural Network Inference
//! Accelerators").
//!
//! [`TileGridEngine`] is that schedule as a
//! [`MatmulEngine`](crate::simulator::pipeline::MatmulEngine): each
//! layer's [K x N] GEMM rectangle is split into crossbar-sized tiles
//! ([`mapping::tiler::tile_grid`](crate::mapping::tile_grid)), every tile
//! MVM is ADC-quantized per tile column at the GDC-scaled range, and
//! K-tile partials accumulate in f32, fanned out across the executor's
//! persistent [`WorkerPool`] as (column-band, row-chunk) jobs.
//! [`AnalogModel`] pairs the engine with the shared
//! [`LayerExecutor`] — all staging (im2col, DAC quantization, pooling,
//! affine, ReLU) is the *same code* the native engine runs, so the two
//! engines observe bit-identical pre-matmul staged inputs by construction
//! (pinned by `tests/test_pipeline.rs`).
//!
//! When a layer fits a single tile (the paper's models on the 1024x512
//! array) and GDC is exactly 1, the per-tile schedule degenerates to the
//! native one bit for bit — tested below and in
//! tests/test_backend_analog.rs. Multi-tile geometries (64x64 ablations)
//! diverge by design: that divergence *is* the modeled physics.

use std::sync::Arc;

use crate::crossbar::ArrayGeom;
use crate::mapping::{tile_grid, Tile};
use crate::nn::ModelMeta;
use crate::pcm::{AdcFault, LayerGdc};
use crate::quant;
use crate::simulator::pipeline::{LayerExecutor, MatmulCtx, MatmulEngine};
use crate::simulator::pool::{Job, RawSlice, RawSliceMut, WorkerPool};

/// The tile-faithful matmul step: per-crossbar-tile MVM with per-tile ADC
/// quantization before digital accumulation, on a fixed array geometry.
/// Tile plans are precomputed per layer at construction (digital layers
/// never touch the array and carry no plan).
pub struct TileGridEngine {
    geom: ArrayGeom,
    /// per-layer crossbar tiling of the [K x N] GEMM rectangle, indexed by
    /// `MatmulCtx::layer_index`
    plans: Vec<Option<Vec<Tile>>>,
}

impl TileGridEngine {
    /// Plan every analog layer of `meta` onto `geom`-sized tiles.
    pub fn new(meta: &ModelMeta, geom: ArrayGeom) -> Self {
        let plans = meta
            .layers
            .iter()
            .map(|lm| {
                lm.analog.then(|| {
                    tile_grid(lm.graph_weight_shape[0],
                              lm.graph_weight_shape[1], geom)
                })
            })
            .collect();
        TileGridEngine { geom, plans }
    }

    pub fn geom(&self) -> ArrayGeom {
        self.geom
    }

    /// Crossbar tiles the plan occupies across all analog layers (1 per
    /// layer on the AON array; more under small-tile ablation geometries).
    pub fn tiles_total(&self) -> usize {
        self.plans.iter().flatten().map(|p| p.len()).sum()
    }
}

impl MatmulEngine for TileGridEngine {
    fn name(&self) -> &'static str {
        "tile-grid"
    }

    fn analog_matmul(&self, ctx: &MatmulCtx<'_>, a: &[f32], w: &[f32],
                     out: &mut [f32]) {
        let plan = self.plans[ctx.layer_index]
            .as_deref()
            .expect("analog layer has a tile plan");
        tiled_mvm(ctx.pool, a, w, out, ctx.m, ctx.k, ctx.n, plan,
                  ctx.layer.r_adc, ctx.adc_bits, ctx.gdc, ctx.adc_fault,
                  ctx.layer_index);
    }

    fn schedule_geom(&self) -> ArrayGeom {
        self.geom
    }
}

/// The [`LayerExecutor`] driven by a [`TileGridEngine`]: the drop-in
/// tile-faithful counterpart of `NativeModel`, sharing its staging loop,
/// argument contract, and batch-invariance guarantee.
pub struct AnalogModel {
    exec: LayerExecutor,
    engine: TileGridEngine,
}

impl AnalogModel {
    /// Single-threaded execution on the paper's 1024x512 mux-4 array.
    pub fn new(meta: impl Into<Arc<ModelMeta>>) -> Self {
        Self::with_threads(meta, ArrayGeom::AON, 1)
    }

    /// Custom array geometry (tile-ablation studies) and worker count
    /// (`0` = all available cores); the pool is spawned here, never on the
    /// execution path.
    pub fn with_threads(meta: impl Into<Arc<ModelMeta>>, geom: ArrayGeom,
                        threads: usize) -> Self {
        let exec = LayerExecutor::new(meta, threads);
        let engine = TileGridEngine::new(exec.meta_arc(), geom);
        AnalogModel { exec, engine }
    }

    pub fn meta(&self) -> &ModelMeta {
        self.exec.meta()
    }

    pub fn geom(&self) -> ArrayGeom {
        self.engine.geom()
    }

    /// Worker lanes tile jobs are dispatched over.
    pub fn threads(&self) -> usize {
        self.exec.lanes()
    }

    /// Crossbar tiles the model occupies across all analog layers.
    pub fn tiles_total(&self) -> usize {
        self.engine.tiles_total()
    }

    /// Launch-schedule estimator on this engine's configured geometry
    /// (see [`LayerExecutor::schedule_model`]).
    pub fn schedule_model(&self) -> anyhow::Result<crate::timing::ScheduleModel> {
        self.exec.schedule_model(&self.engine)
    }

    /// Forward a batch: `x` is [batch, H, W, C] flat; returns logits
    /// [batch, classes].
    ///
    /// The argument contract matches `NativeModel::forward` — `weights[l]`
    /// in graph shape (the *effective*, possibly drifted read of the
    /// programmed conductances), `gdc[l]` the layer's drift-compensation
    /// scale — so the two engines are drop-in interchangeable behind
    /// `InferenceBackend`. Results are bit-identical for any batch
    /// decomposition and lane count: every output element's accumulation
    /// order depends only on its own row and tile plan.
    pub fn forward<W: AsRef<[f32]>>(&self, x: &[f32], batch: usize,
                                    weights: &[W], gdc: &[LayerGdc],
                                    adc_bits: u32) -> Vec<f32> {
        self.exec.forward(&self.engine, x, batch, weights, gdc, adc_bits)
    }

    /// [`forward`](Self::forward) under a per-tile ADC gain/offset fault
    /// model: each tile's converter applies `code((p * gain + off * r_adc))`
    /// instead of `code(p)`. `AdcFault::NONE` is bit-identical to
    /// `forward` — the clean quantization expression is untouched.
    pub fn forward_faulted<W: AsRef<[f32]>>(&self, x: &[f32], batch: usize,
                                            weights: &[W], gdc: &[LayerGdc],
                                            adc_bits: u32,
                                            adc_fault: AdcFault) -> Vec<f32> {
        self.exec.forward_faulted(&self.engine, x, batch, weights, gdc,
                                  adc_bits, adc_fault)
    }
}

/// One tile's resolved execution parameters: its GDC alpha (plan order)
/// and its ADC converter's gain/offset draw — computed once per layer
/// call, *before* tiles are regrouped into column bands, so the plan-index
/// ↔ alpha correspondence set up by `gdc::calibrate` survives banding.
#[derive(Clone, Copy)]
struct TileParams {
    alpha: f32,
    gain: f32,
    offset: f32,
}

/// One layer's tile-faithful MVM sweep: every crossbar tile of the [k x n]
/// weight rectangle multiplies the DAC-quantized activations against its
/// weight slice, the tile's analog partial sums are ADC-quantized per
/// column at the GDC-scaled range, and the digitized partials accumulate
/// in f32 across K-tiles into `out`.
///
/// Work is dispatched as (column-band, row-chunk) jobs on the worker pool:
/// tiles sharing a `ct` feed the same output columns, so one job owns one
/// column band for a chunk of rows and performs the K-tile accumulation
/// itself — jobs therefore write disjoint rectangles of `out`, which keeps
/// the dispatch sound and the results independent of the lane count.
#[allow(clippy::too_many_arguments)]
fn tiled_mvm(pool: &WorkerPool, a: &[f32], w: &[f32], out: &mut [f32],
             m: usize, k: usize, n: usize, tiles: &[Tile], r_adc: f32,
             adc_bits: u32, gdc: &LayerGdc, adc_fault: AdcFault,
             layer_index: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(w.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    // resolve each tile's alpha (by *plan* index — the order
    // `gdc::calibrate` emitted) and its ADC fault draw before regrouping
    let n_bands = tiles.iter().map(|t| t.ct + 1).max().unwrap_or(0);
    let mut bands: Vec<Vec<(Tile, TileParams)>> = vec![Vec::new(); n_bands];
    for (i, t) in tiles.iter().enumerate() {
        let (gain, offset) = adc_fault.tile_gain_offset(layer_index, t.kt, t.ct);
        let p = TileParams { alpha: gdc.tile(i), gain, offset };
        bands[t.ct].push((t.clone(), p));
    }
    // split the batch rows so every lane gets work even when the whole
    // layer fits one tile (the common AON-array case)
    let lanes = pool.lanes().max(1);
    let row_chunks = lanes.div_ceil(n_bands.max(1)).min(m).max(1);
    let rows_per = m.div_ceil(row_chunks);

    // ADC quantizer grid (shared by every tile of the layer) — from the
    // same source as the native engine's `fake_quant_slice`, which is what
    // keeps single-tile execution bit-identical to it
    let (step, inv) = quant::grid(r_adc, adc_bits);

    let ra = RawSlice::of(a);
    let rw = RawSlice::of(w);
    let ro = RawSliceMut::of(out);
    let mut jobs: Vec<Job> = Vec::with_capacity(n_bands * row_chunks);
    for band in bands {
        debug_assert!(!band.is_empty(), "tile grid bands are dense");
        let mut r0 = 0usize;
        while r0 < m {
            let rows = rows_per.min(m - r0);
            let band = band.clone();
            jobs.push(Box::new(move || {
                // SAFETY: `run_all` blocks until every job has finished, so
                // `a`, `w`, `out` outlive the job; jobs write disjoint
                // (row-chunk x column-band) rectangles of `out`, which
                // `tile_band` materializes one row-slice at a time via
                // `slice_at` so no two live `&mut` views ever overlap.
                unsafe {
                    tile_band(ra.get(), rw.get(), ro, r0, rows, k, n, &band,
                              r_adc, step, inv);
                }
            }));
            r0 += rows;
        }
    }
    pool.run_all(jobs);
}

/// Rows [r0, r0+rows) of one column band: per K-tile analog MVM, per-tile
/// ADC quantization (clamp to the full-scale range, round to the GDC-scaled
/// grid), digital f32 accumulation. The inner product streams K ascending
/// with the same zero-skip as `gemm::gemm_naive_into` — the accumulation
/// order the blocked packed kernel is property-tested bit-exact against
/// for single-k-block schemes — so a single-tile band at `alpha == 1`
/// reproduces the native engine's bits exactly. This per-tile path is
/// deliberately *not* blocked/packed: the ADC-before-accumulate ordering
/// is the hardware contract and its bits must not move. A faulted
/// converter reads `p * gain + offset * r_adc` instead of `p`; the clean
/// `(gain, offset) == (1, 0)` case keeps the original expression
/// untouched, preserving no-fault bit-identity.
///
/// SAFETY: the caller must guarantee `out` outlives the call and that no
/// other live view overlaps this band's (row-chunk x column-band)
/// rectangle; each output row-slice is materialized individually through
/// `slice_at` so concurrent bands never hold aliasing `&mut` views.
#[allow(clippy::too_many_arguments)]
unsafe fn tile_band(a: &[f32], w: &[f32], out: RawSliceMut, r0: usize,
                    rows: usize, k: usize, n: usize,
                    band: &[(Tile, TileParams)], r_adc: f32, step: f32,
                    inv: f32) {
    let n0 = band[0].0.n0;
    let nc = band[0].0.cols;
    let mut part = vec![0f32; nc];
    for r in r0..r0 + rows {
        let arow = &a[r * k..(r + 1) * k];
        let orow = out.slice_at(r * n + n0, nc);
        for (t, p) in band {
            debug_assert_eq!((t.n0, t.cols), (n0, nc), "band shares columns");
            part.fill(0.0);
            for (ki, &aik) in arow[t.k0..t.k0 + t.rows].iter().enumerate() {
                if aik == 0.0 {
                    continue; // quantized activations are often exactly zero
                }
                let wrow = &w[(t.k0 + ki) * n + n0..(t.k0 + ki) * n + n0 + nc];
                for (pj, &wj) in part.iter_mut().zip(wrow.iter()) {
                    *pj += aik * wj;
                }
            }
            // the tile's ADCs: clamp to full scale, snap to the code grid,
            // apply the digital GDC gain — then accumulate
            let alpha = p.alpha;
            if p.gain == 1.0 && p.offset == 0.0 {
                for (oj, &pj) in orow.iter_mut().zip(part.iter()) {
                    *oj += (pj.clamp(-r_adc, r_adc) * inv).round() * step * alpha;
                }
            } else {
                let (gain, off) = (p.gain, p.offset * r_adc);
                for (oj, &pj) in orow.iter_mut().zip(part.iter()) {
                    *oj += ((pj * gain + off).clamp(-r_adc, r_adc) * inv)
                        .round() * step * alpha;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::NativeModel;
    use crate::util::json;
    use crate::util::rng::Rng;

    fn tiny_meta() -> ModelMeta {
        let src = r#"{
          "model": "tiny", "variant": "t", "input_hwc": [4, 4, 1],
          "num_classes": 2, "eta": 0.0, "fp_test_acc": 1.0,
          "trained_adc_bits": null,
          "layers": [
            {"name": "c0", "kind": "conv3x3", "in_ch": 1, "out_ch": 2,
             "stride": [1, 1], "relu": true, "analog": true,
             "in_h": 4, "in_w": 4, "out_h": 4, "out_w": 4,
             "k_gemm": 9, "weight_shape": [9, 2],
             "graph_weight_shape": [9, 2],
             "w_scale": 1.0, "w_max": 1.0, "r_dac": 8.0, "r_adc": 8.0,
             "dig_scale": [1, 1], "dig_bias": [0, 0]},
            {"name": "fc", "kind": "dense", "in_ch": 2, "out_ch": 2,
             "stride": [1, 1], "relu": false, "analog": true,
             "in_h": 4, "in_w": 4, "out_h": 1, "out_w": 1,
             "k_gemm": 2, "weight_shape": [2, 2],
             "graph_weight_shape": [2, 2],
             "w_scale": 1.0, "w_max": 1.0, "r_dac": 8.0, "r_adc": 8.0,
             "dig_scale": [1, 1], "dig_bias": [0, 0]}
          ],
          "hlo": {}
        }"#;
        ModelMeta::from_json(&json::parse(src).unwrap()).unwrap()
    }

    fn random_case(rng: &mut Rng)
                   -> (Vec<f32>, Vec<Vec<f32>>, Vec<LayerGdc>) {
        let batch = 3;
        let x: Vec<f32> = (0..batch * 16).map(|_| rng.gauss(0.4, 0.3) as f32).collect();
        let w0: Vec<f32> = (0..18).map(|_| rng.gauss(0.0, 0.4) as f32).collect();
        let w1: Vec<f32> = (0..4).map(|_| rng.gauss(0.0, 0.4) as f32).collect();
        (x, vec![w0, w1], crate::pcm::gdc::unity(2))
    }

    #[test]
    fn single_tile_layers_match_native_bit_for_bit() {
        // on the AON array both layers fit one tile, so per-tile ADC
        // degenerates to the native post-accumulation quantization
        let meta = tiny_meta();
        let native = NativeModel::with_threads(meta.clone(), 3);
        let analog = AnalogModel::with_threads(meta, ArrayGeom::AON, 3);
        assert_eq!(analog.tiles_total(), 2);
        let mut rng = Rng::new(11);
        for _ in 0..5 {
            let (x, ws, gdc) = random_case(&mut rng);
            let a = analog.forward(&x, 3, &ws, &gdc, 8);
            let b = native.forward(&x, 3, &ws, &gdc, 8);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn batched_forward_is_bit_identical_to_sequential() {
        // multi-tile geometry on purpose: K-tile accumulation must be
        // batch-invariant too
        let geom = ArrayGeom::new(4, 1, 1).unwrap();
        let analog = AnalogModel::with_threads(tiny_meta(), geom, 4);
        assert!(analog.tiles_total() > 4, "{}", analog.tiles_total());
        let mut rng = Rng::new(12);
        let (x, ws, gdc) = random_case(&mut rng);
        let batched = analog.forward(&x, 3, &ws, &gdc, 8);
        assert_eq!(batched.len(), 3 * 2);
        for s in 0..3 {
            let one = analog.forward(&x[s * 16..(s + 1) * 16], 1, &ws, &gdc, 8);
            assert_eq!(one[..], batched[s * 2..(s + 1) * 2], "sample {s}");
        }
    }

    #[test]
    fn lane_count_does_not_change_bits() {
        let geom = ArrayGeom::new(5, 1, 1).unwrap();
        let a1 = AnalogModel::with_threads(tiny_meta(), geom, 1);
        let a4 = AnalogModel::with_threads(tiny_meta(), geom, 4);
        let mut rng = Rng::new(13);
        let (x, ws, gdc) = random_case(&mut rng);
        assert_eq!(a1.forward(&x, 3, &ws, &gdc, 8),
                   a4.forward(&x, 3, &ws, &gdc, 8));
    }

    #[test]
    fn per_tile_quantization_diverges_from_native_at_low_bits() {
        // the physics the engine exists to model: splitting K across tiles
        // quantizes partials independently, which a coarse ADC makes
        // visible against the post-accumulation reference
        let geom = ArrayGeom::new(2, 2, 2).unwrap();
        let native = NativeModel::new(tiny_meta());
        let analog = AnalogModel::with_threads(tiny_meta(), geom, 1);
        let mut rng = Rng::new(14);
        let mut diverged = false;
        for _ in 0..8 {
            let (x, ws, gdc) = random_case(&mut rng);
            if analog.forward(&x, 3, &ws, &gdc, 4)
                != native.forward(&x, 3, &ws, &gdc, 4)
            {
                diverged = true;
                break;
            }
        }
        assert!(diverged, "4-bit multi-tile execution should not match the \
                           post-accumulation reference");
    }

    #[test]
    fn gdc_scales_tile_outputs() {
        let meta = tiny_meta();
        let analog = AnalogModel::new(meta);
        let x: Vec<f32> = (0..16).map(|i| (i as f32) / 16.0).collect();
        let mut w0 = vec![0f32; 18];
        w0[4 * 2] = 0.5; // "drifted" weights at half scale
        w0[4 * 2 + 1] = 0.25;
        let w1 = vec![1.0, 0.0, 0.0, 1.0];
        let weights = vec![w0, w1];
        let no_comp =
            analog.forward(&x, 1, &weights, &crate::pcm::gdc::unity(2), 8);
        let comped = analog.forward(&x, 1, &weights,
                                    &crate::pcm::gdc::flat_vec(&[2.0, 1.0]), 8);
        assert!(comped[0] > no_comp[0] * 1.5);
    }

    #[test]
    fn per_tile_alphas_are_applied_by_plan_index() {
        // two K-tiles (4-row array on a 9-row layer): doubling only tile
        // 0's alpha must scale just that tile's digitized partials
        let geom = ArrayGeom::new(4, 2, 1).unwrap();
        let analog = AnalogModel::with_threads(tiny_meta(), geom, 1);
        let mut rng = Rng::new(15);
        let (x, ws, _) = random_case(&mut rng);
        let unity = crate::pcm::gdc::unity(2);
        let mut split = unity.clone();
        split[0] = LayerGdc { uniform: 1.0, tiles: vec![2.0, 1.0, 1.0] };
        let base = analog.forward(&x, 3, &ws, &unity, 8);
        let boosted = analog.forward(&x, 3, &ws, &split, 8);
        assert_ne!(base, boosted, "tile-0 alpha must reach the output");
        // and a per-tile vector of all-ones is exactly the uniform path
        let mut ones = unity.clone();
        ones[0] = LayerGdc { uniform: 1.0, tiles: vec![1.0, 1.0, 1.0] };
        assert_eq!(analog.forward(&x, 3, &ws, &ones, 8), base);
    }

    #[test]
    fn adc_faults_perturb_and_none_is_bit_identical() {
        let analog = AnalogModel::new(tiny_meta());
        let mut rng = Rng::new(16);
        let (x, ws, gdc) = random_case(&mut rng);
        let clean = analog.forward(&x, 3, &ws, &gdc, 8);
        let same =
            analog.forward_faulted(&x, 3, &ws, &gdc, 8, AdcFault::NONE);
        assert_eq!(clean, same, "AdcFault::NONE must be a strict no-op");
        let f = AdcFault { gain_sigma: 0.2, offset_sigma: 0.1, seed: 5 };
        let faulted = analog.forward_faulted(&x, 3, &ws, &gdc, 8, f);
        assert_ne!(clean, faulted, "a 20% gain sigma must move the codes");
        assert_eq!(faulted, analog.forward_faulted(&x, 3, &ws, &gdc, 8, f),
                   "fault draws are deterministic per (seed, layer, tile)");
    }
}
