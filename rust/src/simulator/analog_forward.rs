//! Tile-faithful analog CiM forward pass.
//!
//! `NativeModel` fake-quantizes each layer's ADC *after* the full-K GEMM
//! accumulation — numerically convenient, but not what the hardware does.
//! On the AON-CiM array every crossbar tile produces *analog* partial sums
//! that pass through the tile's ADCs **before** the digital processor ever
//! sees them; K-slices programmed onto different tiles are therefore
//! quantized independently and only then accumulated in digital f32. That
//! ordering is exactly where fixed-ADC-gain error enters (Xiao et al. 2021,
//! "On the Accuracy of Analog Neural Network Inference Accelerators").
//!
//! `AnalogModel` executes that schedule: each layer's [K x N] GEMM
//! rectangle is split into crossbar-sized tiles
//! ([`mapping::tiler::tile_grid`](crate::mapping::tile_grid)), inputs are
//! DAC-quantized once per layer, every tile MVM is ADC-quantized per tile
//! column at the GDC-scaled range, and K-tile partials accumulate in f32.
//! Execution is layer-serial over the whole batch (the shared-array
//! schedule `NativeModel::forward` also follows) with tile work fanned out
//! across the persistent [`WorkerPool`] as (column-band, row-chunk) jobs.
//!
//! When a layer fits a single tile (the paper's models on the 1024x512
//! array) and GDC is exactly 1, the per-tile schedule degenerates to the
//! native one bit for bit — tested below and in
//! tests/test_backend_analog.rs. Multi-tile geometries (64x64 ablations)
//! diverge by design: that divergence *is* the modeled physics.

use std::sync::{Arc, Mutex};

use crate::crossbar::ArrayGeom;
use crate::mapping::{tile_grid, Tile};
use crate::nn::{LayerKind, ModelMeta};
use crate::quant;
use crate::simulator::forward::{scratch_capacity, Scratch};
use crate::simulator::im2col;
use crate::simulator::pool::{Job, RawSlice, RawSliceMut, WorkerPool};

pub struct AnalogModel {
    meta: Arc<ModelMeta>,
    geom: ArrayGeom,
    /// per-layer crossbar tiling of the [K x N] GEMM rectangle; digital
    /// (`analog = false`) layers never touch the array and carry no plan
    plans: Vec<Option<Vec<Tile>>>,
    pool: Arc<WorkerPool>,
    scratch: Mutex<Scratch>,
}

impl AnalogModel {
    /// Single-threaded execution on the paper's 1024x512 mux-4 array.
    pub fn new(meta: impl Into<Arc<ModelMeta>>) -> Self {
        Self::with_threads(meta, ArrayGeom::AON, 1)
    }

    /// Custom array geometry (tile-ablation studies) and worker count
    /// (`0` = all available cores); the pool is spawned here, never on the
    /// execution path.
    pub fn with_threads(meta: impl Into<Arc<ModelMeta>>, geom: ArrayGeom,
                        threads: usize) -> Self {
        let meta = meta.into();
        let plans = meta
            .layers
            .iter()
            .map(|lm| {
                lm.analog.then(|| {
                    tile_grid(lm.graph_weight_shape[0],
                              lm.graph_weight_shape[1], geom)
                })
            })
            .collect();
        AnalogModel {
            meta,
            geom,
            plans,
            pool: Arc::new(WorkerPool::new(threads)),
            scratch: Mutex::new(Scratch::default()),
        }
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    pub fn geom(&self) -> ArrayGeom {
        self.geom
    }

    /// Worker lanes tile jobs are dispatched over.
    pub fn threads(&self) -> usize {
        self.pool.lanes()
    }

    /// Crossbar tiles the model occupies across all analog layers (1 per
    /// layer on the AON array; more under small-tile ablation geometries).
    pub fn tiles_total(&self) -> usize {
        self.plans.iter().flatten().map(|p| p.len()).sum()
    }

    /// Forward a batch: `x` is [batch, H, W, C] flat; returns logits
    /// [batch, classes].
    ///
    /// The argument contract matches `NativeModel::forward` — `weights[l]`
    /// in graph shape (the *effective*, possibly drifted read of the
    /// programmed conductances), `gdc[l]` the layer's drift-compensation
    /// scale — so the two engines are drop-in interchangeable behind
    /// `InferenceBackend`. Results are bit-identical for any batch
    /// decomposition and lane count: every output element's accumulation
    /// order depends only on its own row and tile plan.
    pub fn forward<W: AsRef<[f32]>>(&self, x: &[f32], batch: usize,
                                    weights: &[W], gdc: &[f32],
                                    adc_bits: u32) -> Vec<f32> {
        let (ih, iw, ic) = self.meta.input_hwc;
        assert_eq!(x.len(), batch * ih * iw * ic, "input shape mismatch");
        assert_eq!(weights.len(), self.meta.layers.len());
        assert_eq!(gdc.len(), self.meta.layers.len());
        let b_dac = quant::dac_bits(adc_bits);

        let mut guard = self.scratch.lock().unwrap();
        guard.ensure(scratch_capacity(&self.meta, batch));
        let Scratch { ping, pong } = &mut *guard;
        let (mut cur, mut nxt): (&mut Vec<f32>, &mut Vec<f32>) = (ping, pong);
        cur[..x.len()].copy_from_slice(x);
        let mut len = x.len();

        let (mut ch, mut cw, mut cc) = (ih, iw, ic);
        for (li, lm) in self.meta.layers.iter().enumerate() {
            let w = weights[li].as_ref();
            match lm.kind {
                LayerKind::Dw3x3 if !lm.analog => {
                    // exact depthwise on the digital processor, compact
                    // [9, C] — identical to the native engine
                    let c = lm.in_ch;
                    assert_eq!(w.len(), 9 * c);
                    let ho = im2col::out_dim(ch, lm.stride.0);
                    let wo = im2col::out_dim(cw, lm.stride.1);
                    let rows = batch * ho * wo;
                    im2col::patches3x3_into(&cur[..len], &mut nxt[..rows * 9 * c],
                                            batch, ch, cw, cc, lm.stride);
                    // patches in `nxt`; depthwise result overwrites `cur`
                    for r in 0..rows {
                        for ci in 0..c {
                            let mut acc = 0f32;
                            for t in 0..9 {
                                acc += nxt[r * 9 * c + t * c + ci] * w[t * c + ci];
                            }
                            cur[r * c + ci] = acc * lm.dig_scale[ci] + lm.dig_bias[ci];
                        }
                    }
                    len = rows * c;
                    ch = ho;
                    cw = wo;
                }
                _ => {
                    // stage the GEMM input so it ends up in `cur` (same
                    // staging as the native engine)
                    let (m_rows, k) = match lm.kind {
                        LayerKind::Conv3x3 | LayerKind::Dw3x3 => {
                            let ho = im2col::out_dim(ch, lm.stride.0);
                            let wo = im2col::out_dim(cw, lm.stride.1);
                            let kk = 9 * cc;
                            let rows = batch * ho * wo;
                            im2col::patches3x3_into(&cur[..len],
                                                    &mut nxt[..rows * kk],
                                                    batch, ch, cw, cc, lm.stride);
                            std::mem::swap(&mut cur, &mut nxt);
                            len = rows * kk;
                            ch = ho;
                            cw = wo;
                            (rows, kk)
                        }
                        LayerKind::Conv1x1 => (batch * ch * cw, cc),
                        LayerKind::Dense => {
                            // global average pool into `nxt`, then flip
                            let pix = ch * cw;
                            let g = &mut nxt[..batch * cc];
                            g.fill(0.0);
                            for ni in 0..batch {
                                for p_ in 0..pix {
                                    for ci in 0..cc {
                                        g[ni * cc + ci] += cur[(ni * pix + p_) * cc + ci];
                                    }
                                }
                            }
                            let inv = 1.0 / pix as f32;
                            g.iter_mut().for_each(|v| *v *= inv);
                            std::mem::swap(&mut cur, &mut nxt);
                            len = batch * cc;
                            ch = 1;
                            cw = 1;
                            (batch, cc)
                        }
                    };
                    let gw = &lm.graph_weight_shape;
                    assert_eq!(gw[0], k, "{}: K mismatch", lm.name);
                    let n_cols = gw[1];
                    assert_eq!(w.len(), k * n_cols, "{}: weight len", lm.name);
                    debug_assert_eq!(len, m_rows * k);

                    if lm.analog {
                        // source-line DACs quantize the activations once;
                        // every tile sees the same driven lines
                        quant::fake_quant_slice(&mut cur[..m_rows * k], lm.r_dac,
                                                b_dac);
                        let plan = self.plans[li]
                            .as_deref()
                            .expect("analog layer has a tile plan");
                        tiled_mvm(&self.pool, &cur[..m_rows * k], w,
                                  &mut nxt[..m_rows * n_cols], m_rows, k,
                                  n_cols, plan, lm.r_adc, adc_bits, gdc[li]);
                    } else {
                        // digital layers never touch the array: exact GEMM
                        self.pool.gemm_into(&cur[..m_rows * k], w,
                                            &mut nxt[..m_rows * n_cols],
                                            m_rows, k, n_cols);
                    }
                    let out = &mut nxt[..m_rows * n_cols];
                    // digital per-channel affine (folded BN / bias)
                    for r in 0..m_rows {
                        let row = &mut out[r * n_cols..(r + 1) * n_cols];
                        for (j, v) in row.iter_mut().enumerate() {
                            *v = *v * lm.dig_scale[j] + lm.dig_bias[j];
                        }
                    }
                    std::mem::swap(&mut cur, &mut nxt);
                    len = m_rows * n_cols;
                    cc = n_cols;
                }
            }
            if lm.relu {
                cur[..len].iter_mut().for_each(|v| *v = v.max(0.0));
            }
        }
        cur[..len].to_vec()
    }
}

/// One layer's tile-faithful MVM sweep: every crossbar tile of the [k x n]
/// weight rectangle multiplies the DAC-quantized activations against its
/// weight slice, the tile's analog partial sums are ADC-quantized per
/// column at the GDC-scaled range, and the digitized partials accumulate
/// in f32 across K-tiles into `out`.
///
/// Work is dispatched as (column-band, row-chunk) jobs on the worker pool:
/// tiles sharing a `ct` feed the same output columns, so one job owns one
/// column band for a chunk of rows and performs the K-tile accumulation
/// itself — jobs therefore write disjoint rectangles of `out`, which keeps
/// the dispatch sound and the results independent of the lane count.
#[allow(clippy::too_many_arguments)]
fn tiled_mvm(pool: &WorkerPool, a: &[f32], w: &[f32], out: &mut [f32],
             m: usize, k: usize, n: usize, tiles: &[Tile], r_adc: f32,
             adc_bits: u32, alpha: f32) {
    assert_eq!(a.len(), m * k);
    assert_eq!(w.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    // group tiles into column bands (all tiles of one `ct`)
    let n_bands = tiles.iter().map(|t| t.ct + 1).max().unwrap_or(0);
    let mut bands: Vec<Vec<Tile>> = vec![Vec::new(); n_bands];
    for t in tiles {
        bands[t.ct].push(t.clone());
    }
    // split the batch rows so every lane gets work even when the whole
    // layer fits one tile (the common AON-array case)
    let lanes = pool.lanes().max(1);
    let row_chunks = lanes.div_ceil(n_bands.max(1)).min(m).max(1);
    let rows_per = m.div_ceil(row_chunks);

    // ADC quantizer grid (shared by every tile of the layer) — from the
    // same source as the native engine's `fake_quant_slice`, which is what
    // keeps single-tile execution bit-identical to it
    let (step, inv) = quant::grid(r_adc, adc_bits);

    let ra = RawSlice::of(a);
    let rw = RawSlice::of(w);
    let ro = RawSliceMut::of(out);
    let mut jobs: Vec<Job> = Vec::with_capacity(n_bands * row_chunks);
    for band in bands {
        debug_assert!(!band.is_empty(), "tile grid bands are dense");
        let mut r0 = 0usize;
        while r0 < m {
            let rows = rows_per.min(m - r0);
            let band = band.clone();
            jobs.push(Box::new(move || {
                // SAFETY: `run_all` blocks until every job has finished, so
                // `a`, `w`, `out` outlive the job; jobs write disjoint
                // (row-chunk x column-band) rectangles of `out`, which
                // `tile_band` materializes one row-slice at a time via
                // `slice_at` so no two live `&mut` views ever overlap.
                unsafe {
                    tile_band(ra.get(), rw.get(), ro, r0, rows, k, n, &band,
                              r_adc, step, inv, alpha);
                }
            }));
            r0 += rows;
        }
    }
    pool.run_all(jobs);
}

/// Rows [r0, r0+rows) of one column band: per K-tile analog MVM, per-tile
/// ADC quantization (clamp to the full-scale range, round to the GDC-scaled
/// grid), digital f32 accumulation. The inner product streams K ascending
/// with the same zero-skip as `gemm::gemm_into`, so a single-tile band at
/// `alpha == 1` reproduces the native engine's bits exactly.
///
/// SAFETY: the caller must guarantee `out` outlives the call and that no
/// other live view overlaps this band's (row-chunk x column-band)
/// rectangle; each output row-slice is materialized individually through
/// `slice_at` so concurrent bands never hold aliasing `&mut` views.
#[allow(clippy::too_many_arguments)]
unsafe fn tile_band(a: &[f32], w: &[f32], out: RawSliceMut, r0: usize,
                    rows: usize, k: usize, n: usize, band: &[Tile],
                    r_adc: f32, step: f32, inv: f32, alpha: f32) {
    let n0 = band[0].n0;
    let nc = band[0].cols;
    let mut part = vec![0f32; nc];
    for r in r0..r0 + rows {
        let arow = &a[r * k..(r + 1) * k];
        let orow = out.slice_at(r * n + n0, nc);
        for t in band {
            debug_assert_eq!((t.n0, t.cols), (n0, nc), "band shares columns");
            part.fill(0.0);
            for (ki, &aik) in arow[t.k0..t.k0 + t.rows].iter().enumerate() {
                if aik == 0.0 {
                    continue; // quantized activations are often exactly zero
                }
                let wrow = &w[(t.k0 + ki) * n + n0..(t.k0 + ki) * n + n0 + nc];
                for (pj, &wj) in part.iter_mut().zip(wrow.iter()) {
                    *pj += aik * wj;
                }
            }
            // the tile's ADCs: clamp to full scale, snap to the code grid,
            // apply the digital GDC gain — then accumulate
            for (oj, &pj) in orow.iter_mut().zip(part.iter()) {
                *oj += (pj.clamp(-r_adc, r_adc) * inv).round() * step * alpha;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::NativeModel;
    use crate::util::json;
    use crate::util::rng::Rng;

    fn tiny_meta() -> ModelMeta {
        let src = r#"{
          "model": "tiny", "variant": "t", "input_hwc": [4, 4, 1],
          "num_classes": 2, "eta": 0.0, "fp_test_acc": 1.0,
          "trained_adc_bits": null,
          "layers": [
            {"name": "c0", "kind": "conv3x3", "in_ch": 1, "out_ch": 2,
             "stride": [1, 1], "relu": true, "analog": true,
             "in_h": 4, "in_w": 4, "out_h": 4, "out_w": 4,
             "k_gemm": 9, "weight_shape": [9, 2],
             "graph_weight_shape": [9, 2],
             "w_scale": 1.0, "w_max": 1.0, "r_dac": 8.0, "r_adc": 8.0,
             "dig_scale": [1, 1], "dig_bias": [0, 0]},
            {"name": "fc", "kind": "dense", "in_ch": 2, "out_ch": 2,
             "stride": [1, 1], "relu": false, "analog": true,
             "in_h": 4, "in_w": 4, "out_h": 1, "out_w": 1,
             "k_gemm": 2, "weight_shape": [2, 2],
             "graph_weight_shape": [2, 2],
             "w_scale": 1.0, "w_max": 1.0, "r_dac": 8.0, "r_adc": 8.0,
             "dig_scale": [1, 1], "dig_bias": [0, 0]}
          ],
          "hlo": {}
        }"#;
        ModelMeta::from_json(&json::parse(src).unwrap()).unwrap()
    }

    fn random_case(rng: &mut Rng) -> (Vec<f32>, Vec<Vec<f32>>, Vec<f32>) {
        let batch = 3;
        let x: Vec<f32> = (0..batch * 16).map(|_| rng.gauss(0.4, 0.3) as f32).collect();
        let w0: Vec<f32> = (0..18).map(|_| rng.gauss(0.0, 0.4) as f32).collect();
        let w1: Vec<f32> = (0..4).map(|_| rng.gauss(0.0, 0.4) as f32).collect();
        (x, vec![w0, w1], vec![1.0, 1.0])
    }

    #[test]
    fn single_tile_layers_match_native_bit_for_bit() {
        // on the AON array both layers fit one tile, so per-tile ADC
        // degenerates to the native post-accumulation quantization
        let meta = tiny_meta();
        let native = NativeModel::with_threads(meta.clone(), 3);
        let analog = AnalogModel::with_threads(meta, ArrayGeom::AON, 3);
        assert_eq!(analog.tiles_total(), 2);
        let mut rng = Rng::new(11);
        for _ in 0..5 {
            let (x, ws, gdc) = random_case(&mut rng);
            let a = analog.forward(&x, 3, &ws, &gdc, 8);
            let b = native.forward(&x, 3, &ws, &gdc, 8);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn batched_forward_is_bit_identical_to_sequential() {
        // multi-tile geometry on purpose: K-tile accumulation must be
        // batch-invariant too
        let geom = ArrayGeom::new(4, 1, 1).unwrap();
        let analog = AnalogModel::with_threads(tiny_meta(), geom, 4);
        assert!(analog.tiles_total() > 4, "{}", analog.tiles_total());
        let mut rng = Rng::new(12);
        let (x, ws, gdc) = random_case(&mut rng);
        let batched = analog.forward(&x, 3, &ws, &gdc, 8);
        assert_eq!(batched.len(), 3 * 2);
        for s in 0..3 {
            let one = analog.forward(&x[s * 16..(s + 1) * 16], 1, &ws, &gdc, 8);
            assert_eq!(one[..], batched[s * 2..(s + 1) * 2], "sample {s}");
        }
    }

    #[test]
    fn lane_count_does_not_change_bits() {
        let geom = ArrayGeom::new(5, 1, 1).unwrap();
        let a1 = AnalogModel::with_threads(tiny_meta(), geom, 1);
        let a4 = AnalogModel::with_threads(tiny_meta(), geom, 4);
        let mut rng = Rng::new(13);
        let (x, ws, gdc) = random_case(&mut rng);
        assert_eq!(a1.forward(&x, 3, &ws, &gdc, 8),
                   a4.forward(&x, 3, &ws, &gdc, 8));
    }

    #[test]
    fn per_tile_quantization_diverges_from_native_at_low_bits() {
        // the physics the engine exists to model: splitting K across tiles
        // quantizes partials independently, which a coarse ADC makes
        // visible against the post-accumulation reference
        let geom = ArrayGeom::new(2, 2, 2).unwrap();
        let native = NativeModel::new(tiny_meta());
        let analog = AnalogModel::with_threads(tiny_meta(), geom, 1);
        let mut rng = Rng::new(14);
        let mut diverged = false;
        for _ in 0..8 {
            let (x, ws, gdc) = random_case(&mut rng);
            if analog.forward(&x, 3, &ws, &gdc, 4)
                != native.forward(&x, 3, &ws, &gdc, 4)
            {
                diverged = true;
                break;
            }
        }
        assert!(diverged, "4-bit multi-tile execution should not match the \
                           post-accumulation reference");
    }

    #[test]
    fn gdc_scales_tile_outputs() {
        let meta = tiny_meta();
        let analog = AnalogModel::new(meta);
        let x: Vec<f32> = (0..16).map(|i| (i as f32) / 16.0).collect();
        let mut w0 = vec![0f32; 18];
        w0[4 * 2] = 0.5; // "drifted" weights at half scale
        w0[4 * 2 + 1] = 0.25;
        let w1 = vec![1.0, 0.0, 0.0, 1.0];
        let weights = vec![w0, w1];
        let no_comp = analog.forward(&x, 1, &weights, &[1.0, 1.0], 8);
        let comped = analog.forward(&x, 1, &weights, &[2.0, 1.0], 8);
        assert!(comped[0] > no_comp[0] * 1.5);
    }
}
