//! The shared layer-pipeline executor: one staging loop, many matmul
//! engines.
//!
//! The AON-CiM accelerator runs a single layer-serial schedule regardless
//! of how the MVM itself is realized: stage the layer input (im2col patch
//! extraction, global average pooling), multiply, quantize, apply the
//! digital per-channel affine, ReLU — with the whole batch finishing layer
//! `k` before any sample starts layer `k+1`. Historically `NativeModel`
//! and `AnalogModel` each owned a private copy of that staging loop and
//! only differed in the multiply+quantize step, which meant every staging
//! fix or new layer kind had to land twice (the ROADMAP called this
//! divergence hazard out explicitly).
//!
//! [`LayerExecutor`] is that loop, extracted once: it owns the persistent
//! GEMM [`WorkerPool`] and the ping-pong activation scratch, performs all
//! engine-independent work (staging, DAC fake-quantization of analog-layer
//! inputs, exact digital GEMM/depthwise, affine, ReLU), and delegates
//! exactly one step — the analog matmul + output quantization — to a
//! [`MatmulEngine`]:
//!
//! * [`NativeGemmEngine`] — full-K batched GEMM, ADC fake-quantized
//!   *after* accumulation, GDC as a single output scale (mirrors the
//!   exported HLO graph);
//! * [`TileGridEngine`](crate::simulator::TileGridEngine) — the
//!   tile-faithful schedule: one MVM per mapped crossbar tile, per-tile
//!   ADC quantization at the GDC-scaled range, digital f32 accumulation
//!   across K-tiles (see `analog_forward`).
//!
//! A new engine (a per-tile GDC variant, a stochastic-ADC model, an
//! instrumentation wrapper) is one `MatmulEngine` impl — the staging loop
//! is shared by construction, which is what the staged-input bit-identity
//! property test in `tests/test_pipeline.rs` pins down.

use std::sync::{Arc, Mutex};

use crate::crossbar::ArrayGeom;
use crate::nn::{LayerKind, LayerMeta, ModelMeta};
use crate::pcm::{AdcFault, LayerGdc};
use crate::quant;
use crate::simulator::gemm;
use crate::simulator::im2col;
use crate::simulator::pool::WorkerPool;
use crate::simulator::tiling::{self, TilingScheme};

/// Ping-pong activation scratch: two buffers, each sized for the largest
/// intermediate (patch matrix or activation block) of the model at the
/// largest batch seen so far.  Layer `k` reads one buffer and writes the
/// other; ownership flips each step, so no layer ever allocates.
#[derive(Default)]
struct Scratch {
    ping: Vec<f32>,
    pong: Vec<f32>,
}

impl Scratch {
    fn ensure(&mut self, cap: usize) {
        if self.ping.len() < cap {
            self.ping.resize(cap, 0.0);
        }
        if self.pong.len() < cap {
            self.pong.resize(cap, 0.0);
        }
    }
}

/// Largest f32 count any single intermediate (input block, im2col patch
/// matrix, layer output) occupies for `meta` at `batch`.
pub fn scratch_capacity(meta: &ModelMeta, batch: usize) -> usize {
    let (ih, iw, ic) = meta.input_hwc;
    let mut cap = batch * ih * iw * ic;
    let (mut ch, mut cw, mut cc) = (ih, iw, ic);
    for lm in &meta.layers {
        match lm.kind {
            LayerKind::Conv3x3 | LayerKind::Dw3x3 => {
                let ho = im2col::out_dim(ch, lm.stride.0);
                let wo = im2col::out_dim(cw, lm.stride.1);
                let out_c = if lm.kind == LayerKind::Dw3x3 && !lm.analog {
                    lm.in_ch
                } else {
                    lm.graph_weight_shape[1]
                };
                cap = cap.max(batch * ho * wo * 9 * cc); // patch matrix
                cap = cap.max(batch * ho * wo * out_c); // layer output
                ch = ho;
                cw = wo;
                cc = out_c;
            }
            LayerKind::Conv1x1 => {
                let out_c = lm.graph_weight_shape[1];
                cap = cap.max(batch * ch * cw * out_c);
                cc = out_c;
            }
            LayerKind::Dense => {
                let out_c = lm.graph_weight_shape[1];
                cap = cap.max(batch * cc); // pooled features
                cap = cap.max(batch * out_c); // logits
                ch = 1;
                cw = 1;
                cc = out_c;
            }
        }
    }
    cap
}

/// Everything a [`MatmulEngine`] may need for one analog layer's multiply:
/// the executor's worker pool, the layer's metadata and position, the GEMM
/// shape, and the per-call quantization parameters. Passed by reference so
/// engine impls stay signature-stable when context grows.
pub struct MatmulCtx<'a> {
    /// the executor's persistent worker pool — engines dispatch parallel
    /// work here instead of spawning threads
    pub pool: &'a WorkerPool,
    /// index of the layer in `ModelMeta::layers` (tile plans and other
    /// per-layer engine state are looked up by this)
    pub layer_index: usize,
    /// the layer being executed (quantizer ranges, name for diagnostics)
    pub layer: &'a LayerMeta,
    /// GEMM rows: `batch * out_pixels` for convs, `batch` for dense
    pub m: usize,
    /// GEMM inner dimension (crossbar rows)
    pub k: usize,
    /// GEMM columns (crossbar columns / output channels)
    pub n: usize,
    /// the layer's drift compensation: a uniform scale plus optional
    /// per-tile alphas (tile-granular engines index
    /// [`LayerGdc::tile`]; the native engine uses `uniform`)
    pub gdc: &'a LayerGdc,
    /// per-tile ADC gain/offset faults ([`AdcFault::NONE`] on the clean
    /// path — engines must treat it as a strict no-op)
    pub adc_fault: AdcFault,
    /// ADC bitwidth this call quantizes at (per-request capable via
    /// [`InferOpts`](crate::backend::InferOpts))
    pub adc_bits: u32,
}

/// The engine-specific step of the layer pipeline: multiply the staged,
/// DAC-quantized `[m x k]` activation block `a` against the `[k x n]`
/// effective weights `w` into `out`, applying the engine's ADC
/// quantization model and the GDC gain(s) in `ctx.gdc`.
///
/// Contract (what [`LayerExecutor`] guarantees and expects):
/// * `a` is already DAC fake-quantized at the layer's `r_dac` — every
///   engine sees the same driven source lines, bit for bit (the staged
///   input bit-identity property);
/// * `out` is an uninitialized scratch view of exactly `m * n` elements
///   the engine must fully overwrite;
/// * the engine must be batch-invariant: each output element's
///   accumulation order may depend only on its own row and the engine's
///   static per-layer state, never on `m` — the coordinator's dynamic
///   batcher relies on `run_batch(N)` equalling N single-sample runs;
/// * the digital per-channel affine and ReLU are applied by the executor
///   *after* this call — engines produce raw quantized MVM results.
pub trait MatmulEngine {
    /// Short engine name for logs and diagnostics.
    fn name(&self) -> &'static str;

    /// One analog layer's multiply + output quantization; see the trait
    /// docs for the exact contract.
    fn analog_matmul(&self, ctx: &MatmulCtx<'_>, a: &[f32], w: &[f32],
                     out: &mut [f32]);

    /// Array geometry this engine's analog multiply stands in for — the
    /// basis of the launch-schedule estimator
    /// ([`LayerExecutor::schedule_model`]). The native GEMM engine
    /// numerically mirrors the exported HLO graph of the AON array, so the
    /// default is [`ArrayGeom::AON`]; the tile-grid engine overrides this
    /// with its configured geometry. Host GEMM speed never enters the
    /// schedule — two engines with the same geometry report the same
    /// modeled latency/energy.
    fn schedule_geom(&self) -> ArrayGeom {
        ArrayGeom::AON
    }
}

/// The native matmul step: full-K batched GEMM on the pool, ADC
/// fake-quantization *after* accumulation, GDC as one output scale —
/// numerically the exported HLO graph, and the reference the tile-faithful
/// engine degenerates to on single-tile layers at unity GDC.
///
/// By default the multiply runs the blocked packed kernel under the
/// process-wide autotuned **single-k-block** scheme, which is bit-exact
/// with the naive reference — so every bit-identity property in the test
/// suite (and the analog argmax-consistency gate) is preserved. An
/// executor may opt a specific engine instance into an explicit
/// [`TilingScheme`] via [`with_scheme`](Self::with_scheme) — including
/// k-split schemes, whose f32 sums regroup (f64-bounded, never default).
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeGemmEngine {
    scheme: Option<TilingScheme>,
}

impl NativeGemmEngine {
    /// Opt this engine into an explicit tiling scheme. A single-k-block
    /// scheme stays bit-exact with the default engine; a k-split scheme
    /// trades bit-exactness for cache-resident inner panels (the bound is
    /// property-tested in `simulator::gemm`).
    pub fn with_scheme(scheme: TilingScheme) -> Self {
        NativeGemmEngine { scheme: Some(scheme.validated()) }
    }

    /// The explicit scheme this engine was opted into, if any.
    pub fn scheme(&self) -> Option<TilingScheme> {
        self.scheme
    }
}

impl MatmulEngine for NativeGemmEngine {
    fn name(&self) -> &'static str {
        "native-gemm"
    }

    fn analog_matmul(&self, ctx: &MatmulCtx<'_>, a: &[f32], w: &[f32],
                     out: &mut [f32]) {
        match self.scheme {
            Some(s) => gemm::gemm_with_scheme_into(ctx.pool, a, w, out,
                                                   ctx.m, ctx.k, ctx.n, s),
            None => ctx.pool.gemm_into(a, w, out, ctx.m, ctx.k, ctx.n),
        }
        quant::fake_quant_slice(out, ctx.layer.r_adc, ctx.adc_bits);
        let g = ctx.gdc.uniform;
        if (g - 1.0).abs() > 1e-9 {
            out.iter_mut().for_each(|v| *v *= g);
        }
    }
}

/// The shared layer-serial execution loop. Owns the persistent GEMM
/// [`WorkerPool`] and the preallocated ping-pong activation scratch;
/// executes every engine-independent stage itself (im2col, pooling, exact
/// digital layers, DAC quantization, digital affine, ReLU) and delegates
/// the analog multiply to the [`MatmulEngine`] passed to
/// [`forward`](Self::forward).
///
/// `NativeModel` and `AnalogModel` are thin wrappers pairing one executor
/// with one engine; tests and custom engines may drive an executor
/// directly.
pub struct LayerExecutor {
    meta: Arc<ModelMeta>,
    /// persistent row-chunk GEMM workers (created once, parked between
    /// launches — never spawned on the execution path)
    pool: Arc<WorkerPool>,
    /// per-executor activation scratch; a Mutex because `forward` takes
    /// `&self` (the serving coordinator drives one model from one thread,
    /// so this lock is uncontended on the hot path)
    scratch: Mutex<Scratch>,
}

impl LayerExecutor {
    /// `threads` GEMM lanes (`0` = all available cores); the worker pool
    /// is spawned here, never on the execution path.
    ///
    /// Construction also triggers the process-wide GEMM tiling autotune
    /// ([`tiling::ensure_autotuned`]) on this model's real layer shapes at
    /// the nominal serving batch — a one-time, time-boxed probe cached in
    /// a `OnceLock`, so backends pay it once before the first request and
    /// the hot path only ever reads the cached scheme. The
    /// `ANALOGNETS_TILING` env override wins over the probe (reproducible
    /// CI runs).
    pub fn new(meta: impl Into<Arc<ModelMeta>>, threads: usize) -> Self {
        let meta: Arc<ModelMeta> = meta.into();
        let pool = Arc::new(WorkerPool::new(threads));
        let shapes: Vec<(usize, usize, usize)> = meta
            .layers
            .iter()
            .map(|lm| crate::timing::perf::layer_gemm_dims(
                lm, tiling::AUTOTUNE_BATCH))
            .collect();
        tiling::ensure_autotuned(&shapes, &pool);
        LayerExecutor {
            meta,
            pool,
            scratch: Mutex::new(Scratch::default()),
        }
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// Shared handle to the model metadata (engines that precompute
    /// per-layer state — tile plans — are built against the same meta).
    pub fn meta_arc(&self) -> &Arc<ModelMeta> {
        &self.meta
    }

    /// Parallel lanes the pool can drive (workers + the calling thread).
    pub fn lanes(&self) -> usize {
        self.pool.lanes()
    }

    /// Launch-schedule estimator for this model on the array geometry
    /// `engine` simulates: maps the meta onto
    /// [`schedule_geom`](MatmulEngine::schedule_geom) and prices batched
    /// layer-serial launches with the Table-2-calibrated energy model.
    /// Fails only if the model does not fit the engine's array whole.
    pub fn schedule_model(&self, engine: &dyn MatmulEngine)
                          -> anyhow::Result<crate::timing::ScheduleModel> {
        crate::timing::ScheduleModel::new(&self.meta, engine.schedule_geom())
    }

    /// Forward a batch through `engine`: `x` is [batch, H, W, C] flat;
    /// returns logits [batch, classes].
    ///
    /// `weights[l]` must match the layer's graph weight shape (anything
    /// slice-like works: `Vec<f32>`, `HostTensor`, ...); `gdc[l]` is the
    /// drift-compensation scale (1.0 when freshly programmed); `adc_bits`
    /// the converter bitwidth this call quantizes at (DAC bits derive from
    /// it, eq. 3).
    ///
    /// Results are bit-identical for any batch decomposition: running N
    /// samples in one call equals N single-sample calls, because every
    /// staging step is row-local and [`MatmulEngine`] impls are required
    /// to be batch-invariant (the layer-serial correctness invariant the
    /// coordinator's batcher relies on).
    pub fn forward<W: AsRef<[f32]>>(&self, engine: &dyn MatmulEngine,
                                    x: &[f32], batch: usize, weights: &[W],
                                    gdc: &[LayerGdc], adc_bits: u32)
                                    -> Vec<f32> {
        self.forward_faulted(engine, x, batch, weights, gdc, adc_bits,
                             AdcFault::NONE)
    }

    /// [`forward`](Self::forward) with per-tile ADC gain/offset faults
    /// threaded into every [`MatmulCtx`]. `AdcFault::NONE` is bit-identical
    /// to `forward`.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_faulted<W: AsRef<[f32]>>(&self, engine: &dyn MatmulEngine,
                                            x: &[f32], batch: usize,
                                            weights: &[W], gdc: &[LayerGdc],
                                            adc_bits: u32,
                                            adc_fault: AdcFault) -> Vec<f32> {
        let (ih, iw, ic) = self.meta.input_hwc;
        assert_eq!(x.len(), batch * ih * iw * ic, "input shape mismatch");
        assert_eq!(weights.len(), self.meta.layers.len());
        assert_eq!(gdc.len(), self.meta.layers.len());
        let b_dac = quant::dac_bits(adc_bits);

        let mut guard = self.scratch.lock().unwrap();
        guard.ensure(scratch_capacity(&self.meta, batch));
        let Scratch { ping, pong } = &mut *guard;
        let (mut cur, mut nxt): (&mut Vec<f32>, &mut Vec<f32>) = (ping, pong);
        cur[..x.len()].copy_from_slice(x);
        let mut len = x.len();

        let (mut ch, mut cw, mut cc) = (ih, iw, ic);
        for (li, lm) in self.meta.layers.iter().enumerate() {
            let w = weights[li].as_ref();
            match lm.kind {
                LayerKind::Dw3x3 if !lm.analog => {
                    // exact depthwise on the digital processor, compact
                    // [9, C] — never touches any matmul engine
                    let c = lm.in_ch;
                    assert_eq!(w.len(), 9 * c);
                    let ho = im2col::out_dim(ch, lm.stride.0);
                    let wo = im2col::out_dim(cw, lm.stride.1);
                    let rows = batch * ho * wo;
                    im2col::patches3x3_into(&cur[..len], &mut nxt[..rows * 9 * c],
                                            batch, ch, cw, cc, lm.stride);
                    // patches in `nxt`; depthwise result overwrites `cur`
                    for r in 0..rows {
                        for ci in 0..c {
                            let mut acc = 0f32;
                            for t in 0..9 {
                                acc += nxt[r * 9 * c + t * c + ci] * w[t * c + ci];
                            }
                            // digital per-channel affine, fused
                            cur[r * c + ci] = acc * lm.dig_scale[ci] + lm.dig_bias[ci];
                        }
                    }
                    len = rows * c;
                    ch = ho;
                    cw = wo;
                }
                _ => {
                    // GEMM path (conv as im2col, 1x1, dense, analog dw):
                    // stage the GEMM input so it ends up in `cur`
                    let (m_rows, k) = match lm.kind {
                        LayerKind::Conv3x3 | LayerKind::Dw3x3 => {
                            let ho = im2col::out_dim(ch, lm.stride.0);
                            let wo = im2col::out_dim(cw, lm.stride.1);
                            let kk = 9 * cc;
                            let rows = batch * ho * wo;
                            im2col::patches3x3_into(&cur[..len],
                                                    &mut nxt[..rows * kk],
                                                    batch, ch, cw, cc, lm.stride);
                            std::mem::swap(&mut cur, &mut nxt);
                            len = rows * kk;
                            ch = ho;
                            cw = wo;
                            (rows, kk)
                        }
                        LayerKind::Conv1x1 => (batch * ch * cw, cc),
                        LayerKind::Dense => {
                            // global average pool into `nxt`, then flip
                            let pix = ch * cw;
                            let g = &mut nxt[..batch * cc];
                            g.fill(0.0);
                            for ni in 0..batch {
                                for p_ in 0..pix {
                                    for ci in 0..cc {
                                        g[ni * cc + ci] += cur[(ni * pix + p_) * cc + ci];
                                    }
                                }
                            }
                            let inv = 1.0 / pix as f32;
                            g.iter_mut().for_each(|v| *v *= inv);
                            std::mem::swap(&mut cur, &mut nxt);
                            len = batch * cc;
                            ch = 1;
                            cw = 1;
                            (batch, cc)
                        }
                    };
                    let gw = &lm.graph_weight_shape;
                    assert_eq!(gw[0], k, "{}: K mismatch", lm.name);
                    let n_cols = gw[1];
                    assert_eq!(w.len(), k * n_cols, "{}: weight len", lm.name);
                    debug_assert_eq!(len, m_rows * k);

                    if lm.analog {
                        // source-line DACs quantize the activations once;
                        // every engine sees the same driven lines
                        quant::fake_quant_slice(&mut cur[..m_rows * k],
                                                lm.r_dac, b_dac);
                        let ctx = MatmulCtx {
                            pool: &self.pool,
                            layer_index: li,
                            layer: lm,
                            m: m_rows,
                            k,
                            n: n_cols,
                            gdc: &gdc[li],
                            adc_fault,
                            adc_bits,
                        };
                        engine.analog_matmul(&ctx, &cur[..m_rows * k], w,
                                             &mut nxt[..m_rows * n_cols]);
                    } else {
                        // digital layers never touch the array: exact GEMM
                        self.pool.gemm_into(&cur[..m_rows * k], w,
                                            &mut nxt[..m_rows * n_cols],
                                            m_rows, k, n_cols);
                    }
                    let out = &mut nxt[..m_rows * n_cols];
                    // digital per-channel affine (folded BN / bias)
                    for r in 0..m_rows {
                        let row = &mut out[r * n_cols..(r + 1) * n_cols];
                        for (j, v) in row.iter_mut().enumerate() {
                            *v = *v * lm.dig_scale[j] + lm.dig_bias[j];
                        }
                    }
                    std::mem::swap(&mut cur, &mut nxt);
                    len = m_rows * n_cols;
                    cc = n_cols;
                }
            }
            if lm.relu {
                cur[..len].iter_mut().for_each(|v| *v = v.max(0.0));
            }
        }
        cur[..len].to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::meta::ModelMeta;
    use crate::util::json;

    fn tiny_meta() -> ModelMeta {
        let src = r#"{
          "model": "tiny", "variant": "t", "input_hwc": [4, 4, 1],
          "num_classes": 2, "eta": 0.0, "fp_test_acc": 1.0,
          "trained_adc_bits": null,
          "layers": [
            {"name": "c0", "kind": "conv3x3", "in_ch": 1, "out_ch": 2,
             "stride": [1, 1], "relu": true, "analog": true,
             "in_h": 4, "in_w": 4, "out_h": 4, "out_w": 4,
             "k_gemm": 9, "weight_shape": [9, 2],
             "graph_weight_shape": [9, 2],
             "w_scale": 1.0, "w_max": 1.0, "r_dac": 8.0, "r_adc": 8.0,
             "dig_scale": [1, 1], "dig_bias": [0, 0]},
            {"name": "fc", "kind": "dense", "in_ch": 2, "out_ch": 2,
             "stride": [1, 1], "relu": false, "analog": true,
             "in_h": 4, "in_w": 4, "out_h": 1, "out_w": 1,
             "k_gemm": 2, "weight_shape": [2, 2],
             "graph_weight_shape": [2, 2],
             "w_scale": 1.0, "w_max": 1.0, "r_dac": 8.0, "r_adc": 8.0,
             "dig_scale": [1, 1], "dig_bias": [0, 0]}
          ],
          "hlo": {}
        }"#;
        ModelMeta::from_json(&json::parse(src).unwrap()).unwrap()
    }

    /// An engine that counts its invocations and delegates to the native
    /// step — the executor must call it exactly once per analog layer.
    struct Counting {
        inner: NativeGemmEngine,
        calls: std::sync::atomic::AtomicUsize,
    }

    impl MatmulEngine for Counting {
        fn name(&self) -> &'static str {
            "counting"
        }

        fn analog_matmul(&self, ctx: &MatmulCtx<'_>, a: &[f32], w: &[f32],
                         out: &mut [f32]) {
            self.calls
                .fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            assert_eq!(a.len(), ctx.m * ctx.k);
            assert_eq!(out.len(), ctx.m * ctx.n);
            self.inner.analog_matmul(ctx, a, w, out);
        }
    }

    #[test]
    fn executor_consults_engine_once_per_analog_layer() {
        let exec = LayerExecutor::new(tiny_meta(), 1);
        let engine = Counting {
            inner: NativeGemmEngine::default(),
            calls: std::sync::atomic::AtomicUsize::new(0),
        };
        let x: Vec<f32> = (0..16).map(|i| (i as f32) / 16.0).collect();
        let mut w0 = vec![0f32; 18];
        w0[4 * 2] = 1.0;
        let w1 = vec![1.0, 0.0, 0.0, 1.0];
        let out = exec.forward(&engine, &x, 1, &[w0, w1],
                               &crate::pcm::gdc::unity(2), 8);
        assert_eq!(out.len(), 2);
        assert_eq!(engine.calls.load(std::sync::atomic::Ordering::SeqCst), 2);
    }

    #[test]
    fn custom_engine_matches_native_reference() {
        // a delegating engine is transparent: same bits as the plain
        // native engine on the same executor
        let exec = LayerExecutor::new(tiny_meta(), 2);
        let engine = Counting {
            inner: NativeGemmEngine::default(),
            calls: std::sync::atomic::AtomicUsize::new(0),
        };
        let mut rng = crate::util::rng::Rng::new(21);
        let x: Vec<f32> = (0..3 * 16).map(|_| rng.gauss(0.4, 0.3) as f32).collect();
        let w0: Vec<f32> = (0..18).map(|_| rng.gauss(0.0, 0.4) as f32).collect();
        let w1: Vec<f32> = (0..4).map(|_| rng.gauss(0.0, 0.4) as f32).collect();
        let weights = vec![w0, w1];
        let gdc = crate::pcm::gdc::flat_vec(&[1.1, 1.0]);
        let a = exec.forward(&engine, &x, 3, &weights, &gdc, 8);
        let b = exec.forward(&NativeGemmEngine::default(), &x, 3, &weights,
                             &gdc, 8);
        assert_eq!(a, b);
    }

    #[test]
    fn explicit_scheme_opt_in_semantics() {
        // a pinned single-k-block scheme is bit-identical to the default
        // engine; a k-split scheme is the explicit opt-OUT of bit-exactness
        // and must stay within quantization-step distance
        let exec = LayerExecutor::new(tiny_meta(), 2);
        let mut rng = crate::util::rng::Rng::new(33);
        let x: Vec<f32> = (0..3 * 16).map(|_| rng.gauss(0.4, 0.3) as f32).collect();
        let w0: Vec<f32> = (0..18).map(|_| rng.gauss(0.0, 0.4) as f32).collect();
        let w1: Vec<f32> = (0..4).map(|_| rng.gauss(0.0, 0.4) as f32).collect();
        let weights = vec![w0, w1];
        let gdc = crate::pcm::gdc::unity(2);
        let base = exec.forward(&NativeGemmEngine::default(), &x, 3, &weights,
                                &gdc, 8);
        let pinned = NativeGemmEngine::with_scheme(
            TilingScheme::new(32, usize::MAX, 32));
        assert_eq!(pinned.scheme().unwrap().k_block, usize::MAX);
        assert_eq!(exec.forward(&pinned, &x, 3, &weights, &gdc, 8), base);
        let split = NativeGemmEngine::with_scheme(TilingScheme::new(32, 4, 32));
        let out = exec.forward(&split, &x, 3, &weights, &gdc, 8);
        assert_eq!(out.len(), base.len());
        for (a, b) in out.iter().zip(base.iter()) {
            assert!((a - b).abs() < 0.2, "{a} vs {b}");
        }
    }

    #[test]
    fn scratch_capacity_covers_every_intermediate() {
        let meta = tiny_meta();
        // input 16, patch matrix 4*4*9 = 144, conv out 32, pooled 2,
        // logits 2 — the patch matrix dominates at batch 1
        assert_eq!(scratch_capacity(&meta, 1), 144);
        assert_eq!(scratch_capacity(&meta, 3), 3 * 144);
    }
}
