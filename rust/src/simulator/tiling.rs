//! Tiling configuration and startup autotune for the blocked GEMM engine.
//!
//! A [`TilingScheme`] names the three cache-blocking dimensions of the
//! packed kernel in `simulator::gemm`: output macro-tiles are
//! `m_block x n_block`, and the inner dimension is swept in `k_block`
//! slices (`k_block == usize::MAX` — rendered `0` in the string form —
//! means "one k-block": the whole inner dimension in a single sweep,
//! which is the bit-exactness-preserving configuration, see below).
//!
//! ## Accumulation-order contract
//!
//! The blocked microkernel accumulates each output element in ascending-k
//! order inside a k-block, starting from `+0.0`, exactly like the naive
//! reference kernel (`gemm::gemm_naive_into`). With a **single k-block**
//! the result is therefore bit-identical to the naive kernel (property
//! tested in `gemm`). Splitting k into several blocks regroups the f32
//! sums (`c = block0 + block1 + ...`) and is *not* bit-identical — only
//! bounded against an f64 reference — so k-split schemes are never chosen
//! here: the candidate set is single-k-block only, the default scheme is
//! single-k-block, and every default GEMM entry point clamps the scheme
//! through [`TilingScheme::full_k`]. A k-split scheme runs only when an
//! executor opts in explicitly (`NativeGemmEngine::with_scheme`).
//!
//! ## Autotune
//!
//! [`ensure_autotuned`] probes a small fixed candidate set on the first
//! real layer GEMM shapes (deterministic candidate order, time-boxed to
//! [`AUTOTUNE_BUDGET_MS`]) and caches the winner in a process-wide
//! `OnceLock`, so serving pays the probe once at backend construction.
//! The env override `ANALOGNETS_TILING=MxKxN` (e.g. `64x0x64`; `K = 0`
//! means full-K) pins the scheme for reproducible CI runs and wins over
//! the probe.

use std::fmt;
use std::sync::OnceLock;
use std::time::{Duration, Instant};

use crate::simulator::gemm;
use crate::simulator::pool::WorkerPool;

/// Microkernel register-block rows: each packed-A group interleaves `MR`
/// output rows. `m_block` is kept a multiple of this.
pub const MR: usize = 4;

/// Microkernel register-block columns: each packed-B strip holds `NR`
/// output columns contiguously per k step. `n_block` is kept a multiple
/// of this (and `NR` f32 lanes autovectorize to a few SIMD registers).
pub const NR: usize = 16;

/// Env var pinning the process-wide scheme: `MxKxN` with `K = 0` for
/// full-K, e.g. `ANALOGNETS_TILING=64x0x128`.
pub const TILING_ENV: &str = "ANALOGNETS_TILING";

/// Wall-clock budget for the startup autotune probe, in milliseconds.
/// The first candidate (the default scheme) is always timed in full;
/// later candidates are skipped once the budget is exhausted.
pub const AUTOTUNE_BUDGET_MS: u64 = 60;

/// Nominal batch the first-real-layer-shapes probe is sized at (the
/// serving coordinator's usual `max_batch`).
pub const AUTOTUNE_BATCH: usize = 32;

/// Cache-blocking dimensions for the packed GEMM kernel: output
/// macro-tiles are `m_block x n_block`, the inner dimension is swept in
/// `k_block` slices. See the module docs for the accumulation-order
/// contract attached to `k_block`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TilingScheme {
    /// Output-row extent of one macro-tile (multiple of [`MR`]).
    pub m_block: usize,
    /// Inner-dimension slice length; `usize::MAX` = one k-block (the
    /// bit-exact configuration, and the only one default paths use).
    pub k_block: usize,
    /// Output-column extent of one macro-tile (multiple of [`NR`]).
    pub n_block: usize,
}

impl TilingScheme {
    /// The scheme used when no autotune has run and no override is set:
    /// 64x64 macro-tiles, single k-block.
    pub const DEFAULT: TilingScheme = TilingScheme {
        m_block: 64,
        k_block: usize::MAX,
        n_block: 64,
    };

    pub const fn new(m_block: usize, k_block: usize, n_block: usize) -> Self {
        TilingScheme { m_block, k_block, n_block }
    }

    /// Clamp into the shape the kernel requires: `m_block` a positive
    /// multiple of [`MR`], `n_block` a positive multiple of [`NR`]
    /// (rounded down, floored at one register block), `k_block >= 1`
    /// with `0` normalized to `usize::MAX` (full-K).
    pub fn validated(self) -> TilingScheme {
        let m = self.m_block.max(MR);
        let n = self.n_block.max(NR);
        let k = if self.k_block == 0 { usize::MAX } else { self.k_block };
        TilingScheme {
            m_block: m - m % MR,
            k_block: k,
            n_block: n - n % NR,
        }
    }

    /// This scheme with the k-split removed (`k_block = usize::MAX`):
    /// the bit-exactness-preserving form every default GEMM entry point
    /// routes through.
    pub fn full_k(self) -> TilingScheme {
        TilingScheme { k_block: usize::MAX, ..self }
    }

    /// Whether an inner dimension of `k` fits in one k-block under this
    /// scheme (the bit-exact regime).
    pub fn is_single_k(&self, k: usize) -> bool {
        self.k_block >= k
    }

    /// Parse the `MxKxN` string form (`K = 0` means full-K), e.g.
    /// `64x0x128`. Inverse of the `Display` rendering.
    pub fn parse(s: &str) -> Result<TilingScheme, String> {
        let parts: Vec<&str> = s.trim().split('x').collect();
        if parts.len() != 3 {
            return Err(format!(
                "tiling scheme `{s}`: want MxKxN (K=0 for full-K)"));
        }
        let field = |i: usize, name: &str| -> Result<usize, String> {
            parts[i].trim().parse::<usize>().map_err(|e| {
                format!("tiling scheme `{s}`: bad {name} `{}`: {e}", parts[i])
            })
        };
        Ok(TilingScheme {
            m_block: field(0, "m_block")?,
            k_block: field(1, "k_block")?,
            n_block: field(2, "n_block")?,
        }
        .validated())
    }
}

impl fmt::Display for TilingScheme {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let k = if self.k_block == usize::MAX { 0 } else { self.k_block };
        write!(f, "{}x{k}x{}", self.m_block, self.n_block)
    }
}

/// The fixed autotune candidate set, probed in this order. All
/// single-k-block (see the module docs); the default scheme is first so
/// the time-box can never skip it.
pub fn candidates() -> &'static [TilingScheme] {
    const C: &[TilingScheme] = &[
        TilingScheme::DEFAULT, // 64x64
        TilingScheme::new(64, usize::MAX, 128),
        TilingScheme::new(128, usize::MAX, 64),
        TilingScheme::new(128, usize::MAX, 128),
        TilingScheme::new(32, usize::MAX, 128),
        TilingScheme::new(32, usize::MAX, 64),
    ];
    C
}

/// Read and parse [`TILING_ENV`]. A malformed value is reported on
/// stderr and ignored (serving should not refuse to start over a typo'd
/// tuning knob).
pub fn env_override() -> Option<TilingScheme> {
    let raw = std::env::var(TILING_ENV).ok()?;
    match TilingScheme::parse(&raw) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("[tiling] ignoring {TILING_ENV}: {e}");
            None
        }
    }
}

// Probe caps: shapes are clamped so one rep costs at most a couple of
// milliseconds and the whole probe respects AUTOTUNE_BUDGET_MS.
const PROBE_CAP_M: usize = 256;
const PROBE_CAP_K: usize = 1024;
const PROBE_CAP_N: usize = 256;
const PROBE_MAX_SHAPES: usize = 4;
const PROBE_REPS: usize = 2;

/// Clamp, dedupe and rank the layer shapes the probe will time:
/// largest-flops first, at most [`PROBE_MAX_SHAPES`].
fn probe_shapes(shapes: &[(usize, usize, usize)]) -> Vec<(usize, usize, usize)> {
    let mut v: Vec<(usize, usize, usize)> = shapes
        .iter()
        .map(|&(m, k, n)| {
            (m.clamp(1, PROBE_CAP_M), k.clamp(1, PROBE_CAP_K),
             n.clamp(1, PROBE_CAP_N))
        })
        .collect();
    v.sort_by_key(|&(m, k, n)| std::cmp::Reverse(m * k * n));
    v.dedup();
    v.truncate(PROBE_MAX_SHAPES);
    v
}

/// Time every candidate on the (clamped) layer shapes and return the
/// fastest. Deterministic candidate order, min-of-[`PROBE_REPS`] per
/// shape, time-boxed: once [`AUTOTUNE_BUDGET_MS`] is spent, remaining
/// candidates are skipped (the default candidate always completes).
/// Which candidate wins is machine-dependent by nature — for
/// reproducible runs pin the scheme via [`TILING_ENV`] instead.
pub fn autotune(shapes: &[(usize, usize, usize)], pool: &WorkerPool)
                -> TilingScheme {
    let shapes = probe_shapes(shapes);
    if shapes.is_empty() {
        return TilingScheme::DEFAULT;
    }
    let (mut mm, mut mk, mut mn) = (0usize, 0usize, 0usize);
    for &(m, k, n) in &shapes {
        mm = mm.max(m);
        mk = mk.max(k);
        mn = mn.max(n);
    }
    // deterministic probe operands (values are irrelevant to timing)
    let a: Vec<f32> =
        (0..mm * mk).map(|i| ((i % 31) as f32 - 15.0) * 0.05).collect();
    let b: Vec<f32> =
        (0..mk * mn).map(|i| ((i % 29) as f32 - 14.0) * 0.04).collect();
    let mut c = vec![0f32; mm * mn];

    let budget = Duration::from_millis(AUTOTUNE_BUDGET_MS);
    let start = Instant::now();
    let mut best: Option<(TilingScheme, Duration)> = None;
    for (ci, cand) in candidates().iter().enumerate() {
        let mut total = Duration::ZERO;
        for &(m, k, n) in &shapes {
            let mut fastest = Duration::MAX;
            for _ in 0..PROBE_REPS {
                let t0 = Instant::now();
                gemm::gemm_blocked_pool_into(pool, &a[..m * k], &b[..k * n],
                                             &mut c[..m * n], m, k, n, *cand,
                                             pool.lanes());
                fastest = fastest.min(t0.elapsed());
            }
            total += fastest;
        }
        if best.map(|(_, t)| total < t).unwrap_or(true) {
            best = Some((*cand, total));
        }
        if ci + 1 < candidates().len() && start.elapsed() > budget {
            break; // time-boxed: later candidates keep the current winner
        }
    }
    best.map(|(s, _)| s).unwrap_or(TilingScheme::DEFAULT)
}

/// Resolve the scheme a process should run: an explicit pin (validated)
/// wins, otherwise [`autotune`]. Pure in its inputs — the determinism
/// property tests pin a scheme through this instead of mutating the
/// process env.
pub fn resolve(pinned: Option<TilingScheme>,
               shapes: &[(usize, usize, usize)], pool: &WorkerPool)
               -> TilingScheme {
    match pinned {
        Some(s) => s.validated(),
        None => autotune(shapes, pool).validated(),
    }
}

static CHOSEN: OnceLock<TilingScheme> = OnceLock::new();

/// Run the startup autotune once per process (env override wins, see
/// [`TILING_ENV`]) and cache the winner; every later call — and every
/// [`global`] lookup — returns the cached scheme. Called by
/// `LayerExecutor::new`, i.e. by backend construction, so serving pays
/// the probe exactly once before the first request.
pub fn ensure_autotuned(shapes: &[(usize, usize, usize)], pool: &WorkerPool)
                        -> TilingScheme {
    *CHOSEN.get_or_init(|| resolve(env_override(), shapes, pool))
}

/// The process-wide scheme. If no autotune has run yet (a raw
/// `gemm_parallel` call before any backend exists), the env override or
/// [`TilingScheme::DEFAULT`] is locked in instead — every candidate is
/// single-k-block, so which one wins never changes results, only speed.
pub fn global() -> TilingScheme {
    *CHOSEN.get_or_init(|| {
        env_override().map(TilingScheme::validated)
                      .unwrap_or(TilingScheme::DEFAULT)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_display_round_trip() {
        let s = TilingScheme::parse("64x0x128").unwrap();
        assert_eq!(s, TilingScheme::new(64, usize::MAX, 128));
        assert_eq!(s.to_string(), "64x0x128");
        let s = TilingScheme::parse(" 32x7x16 ").unwrap();
        assert_eq!(s, TilingScheme::new(32, 7, 16));
        assert_eq!(s.to_string(), "32x7x16");
        assert_eq!(TilingScheme::parse(&s.to_string()).unwrap(), s);
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in ["", "64", "64x64", "64xax64", "64x64x64x64", "-1x0x64"] {
            assert!(TilingScheme::parse(bad).is_err(), "{bad:?} parsed");
        }
    }

    #[test]
    fn validated_clamps_to_register_blocks() {
        let s = TilingScheme::new(0, 0, 0).validated();
        assert_eq!(s, TilingScheme::new(MR, usize::MAX, NR));
        let s = TilingScheme::new(65, 5, 20).validated();
        assert_eq!(s, TilingScheme::new(64, 5, 16));
        // validated is idempotent
        assert_eq!(s.validated(), s);
    }

    #[test]
    fn candidates_are_single_k_block_and_validated() {
        // the bit-exactness contract: autotune can only ever pick a
        // single-k-block scheme, whatever the layer shapes are
        assert!(!candidates().is_empty());
        assert_eq!(candidates()[0], TilingScheme::DEFAULT);
        for c in candidates() {
            assert_eq!(c.k_block, usize::MAX, "{c} is not single-k-block");
            assert_eq!(c.validated(), *c, "{c} is not validated");
            assert!(c.is_single_k(1 << 20));
        }
    }

    #[test]
    fn resolve_pinned_is_deterministic() {
        let pool = WorkerPool::new(2);
        let shapes = [(128, 64, 32), (32, 576, 64)];
        let pin = TilingScheme::new(32, 9, 32);
        for _ in 0..3 {
            assert_eq!(resolve(Some(pin), &shapes, &pool), pin.validated());
        }
        // unpinned resolution picks from the candidate set
        let tuned = resolve(None, &shapes, &pool);
        assert!(candidates().contains(&tuned), "{tuned} not a candidate");
    }

    #[test]
    fn global_is_stable_across_calls() {
        let g = global();
        assert_eq!(global(), g);
        assert_eq!(g.validated(), g);
        assert!(g.is_single_k(usize::MAX - 1) || g.k_block > 0);
    }
}
