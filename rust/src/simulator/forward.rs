//! Native full-model forward pass over effective (possibly drifted) weights.
//!
//! Mirrors the exported HLO graph layer by layer:
//! DAC fake-quant -> GEMM -> ADC fake-quant -> GDC scale -> digital affine ->
//! ReLU, with global average pooling before the dense head, and exact
//! (unquantized) compute for `analog=false` layers (Fig. 9 ablation).
//!
//! [`NativeModel`] is the [`LayerExecutor`] driven by the
//! [`NativeGemmEngine`]: all staging (im2col, scratch ping-pong, pooling,
//! affine, ReLU) lives in the shared executor — see
//! [`pipeline`](crate::simulator::pipeline) — and only the matmul step
//! (full-K batched GEMM, ADC quantized *after* accumulation) is
//! engine-specific. Execution is **layer-serial over the whole batch**,
//! mirroring the AON-CiM schedule: every sample finishes layer `k` on the
//! (simulated) shared crossbar before any sample starts layer `k+1` — one
//! im2col and one batched GEMM per layer, never per-request forward
//! passes, on a persistent worker pool with no per-layer allocation.

use std::sync::Arc;

use crate::nn::ModelMeta;
use crate::pcm::LayerGdc;
use crate::simulator::pipeline::{LayerExecutor, NativeGemmEngine};

pub struct NativeModel {
    exec: LayerExecutor,
    engine: NativeGemmEngine,
}

impl NativeModel {
    pub fn new(meta: impl Into<Arc<ModelMeta>>) -> Self {
        Self::with_threads(meta, 1)
    }

    /// `threads` GEMM lanes (`0` = all available cores); the worker pool is
    /// spawned here, never on the execution path.
    pub fn with_threads(meta: impl Into<Arc<ModelMeta>>, threads: usize) -> Self {
        NativeModel {
            exec: LayerExecutor::new(meta, threads),
            // default engine: blocked packed GEMM under the process-wide
            // autotuned single-k-block scheme (bit-exact with the naive
            // reference; executor construction ran the one-time autotune)
            engine: NativeGemmEngine::default(),
        }
    }

    pub fn meta(&self) -> &ModelMeta {
        self.exec.meta()
    }

    /// GEMM lanes this model multiplies on (workers + calling thread).
    pub fn threads(&self) -> usize {
        self.exec.lanes()
    }

    /// Launch-schedule estimator for the AON array this engine numerically
    /// mirrors (see [`LayerExecutor::schedule_model`]).
    pub fn schedule_model(&self) -> anyhow::Result<crate::timing::ScheduleModel> {
        self.exec.schedule_model(&self.engine)
    }

    /// Forward a batch: `x` is [batch, H, W, C] flat; returns logits
    /// [batch, classes].
    ///
    /// `weights[l]` must match the layer's graph weight shape (anything
    /// slice-like works: `Vec<f32>`, `HostTensor`, ...); `gdc[l]` is the
    /// drift-compensation scale (1.0 when freshly programmed).
    ///
    /// Results are bit-identical for any batch decomposition: running N
    /// samples in one call equals N single-sample calls, because every
    /// per-row accumulation order is batch-invariant (the layer-serial
    /// correctness invariant the coordinator's batcher relies on).
    pub fn forward<W: AsRef<[f32]>>(&self, x: &[f32], batch: usize,
                                    weights: &[W], gdc: &[LayerGdc],
                                    adc_bits: u32) -> Vec<f32> {
        self.exec.forward(&self.engine, x, batch, weights, gdc, adc_bits)
    }

    /// Argmax predictions from logits (thin wrapper over the shared
    /// [`util::logits`](crate::util::logits) helpers).
    pub fn predict(logits: &[f32], classes: usize) -> Vec<u32> {
        crate::util::logits::predictions(logits, classes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::meta::ModelMeta;
    use crate::util::json;

    fn tiny_meta() -> ModelMeta {
        let src = r#"{
          "model": "tiny", "variant": "t", "input_hwc": [4, 4, 1],
          "num_classes": 2, "eta": 0.0, "fp_test_acc": 1.0,
          "trained_adc_bits": null,
          "layers": [
            {"name": "c0", "kind": "conv3x3", "in_ch": 1, "out_ch": 2,
             "stride": [1, 1], "relu": true, "analog": true,
             "in_h": 4, "in_w": 4, "out_h": 4, "out_w": 4,
             "k_gemm": 9, "weight_shape": [9, 2],
             "graph_weight_shape": [9, 2],
             "w_scale": 1.0, "w_max": 1.0, "r_dac": 8.0, "r_adc": 8.0,
             "dig_scale": [1, 1], "dig_bias": [0, 0]},
            {"name": "fc", "kind": "dense", "in_ch": 2, "out_ch": 2,
             "stride": [1, 1], "relu": false, "analog": true,
             "in_h": 4, "in_w": 4, "out_h": 1, "out_w": 1,
             "k_gemm": 2, "weight_shape": [2, 2],
             "graph_weight_shape": [2, 2],
             "w_scale": 1.0, "w_max": 1.0, "r_dac": 8.0, "r_adc": 8.0,
             "dig_scale": [1, 1], "dig_bias": [0, 0]}
          ],
          "hlo": {}
        }"#;
        ModelMeta::from_json(&json::parse(src).unwrap()).unwrap()
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let meta = tiny_meta();
        let m = NativeModel::new(meta);
        let x: Vec<f32> = (0..16).map(|i| (i as f32) / 16.0).collect();
        // center-tap identity conv into 2 channels, then identity dense
        let mut w0 = vec![0f32; 18];
        w0[4 * 2] = 1.0;       // center tap -> ch0
        w0[4 * 2 + 1] = 0.5;   // center tap -> ch1
        let w1 = vec![1.0, 0.0, 0.0, 1.0];
        let weights = vec![w0, w1];
        let gdc = crate::pcm::gdc::unity(2);
        let l1 = m.forward(&x, 1, &weights, &gdc, 8);
        let l2 = m.forward(&x, 1, &weights, &gdc, 8);
        assert_eq!(l1.len(), 2);
        assert_eq!(l1, l2);
        // channel 0 average ~ mean(x) (quantization-limited)
        let mean_x: f32 = x.iter().sum::<f32>() / 16.0;
        assert!((l1[0] - mean_x).abs() < 0.1, "{} vs {}", l1[0], mean_x);
        // ch1 = 0.5 * ch0 approximately
        assert!((l1[1] - 0.5 * l1[0]).abs() < 0.05);
    }

    #[test]
    fn batched_forward_is_bit_identical_to_sequential() {
        // the layer-serial correctness invariant, at the model level: one
        // run_batch(N) == N single-sample runs, bit for bit
        let meta = tiny_meta();
        let m = NativeModel::with_threads(meta, 4);
        let mut rng = crate::util::rng::Rng::new(9);
        let batch = 5;
        let x: Vec<f32> = (0..batch * 16).map(|_| rng.gauss(0.4, 0.3) as f32).collect();
        let w0: Vec<f32> = (0..18).map(|_| rng.gauss(0.0, 0.4) as f32).collect();
        let w1: Vec<f32> = (0..4).map(|_| rng.gauss(0.0, 0.4) as f32).collect();
        let weights = vec![w0, w1];
        let gdc = crate::pcm::gdc::flat_vec(&[1.1, 1.0]);
        let batched = m.forward(&x, batch, &weights, &gdc, 8);
        assert_eq!(batched.len(), batch * 2);
        for s in 0..batch {
            let one = m.forward(&x[s * 16..(s + 1) * 16], 1, &weights, &gdc, 8);
            assert_eq!(one, batched[s * 2..(s + 1) * 2].to_vec(), "sample {s}");
        }
    }

    #[test]
    fn gdc_rescales_output() {
        let meta = tiny_meta();
        let m = NativeModel::new(meta);
        let x: Vec<f32> = (0..16).map(|i| (i as f32) / 16.0).collect();
        let mut w0 = vec![0f32; 18];
        w0[4 * 2] = 0.5; // "drifted" weights at half scale
        w0[4 * 2 + 1] = 0.25;
        let w1 = vec![1.0, 0.0, 0.0, 1.0];
        let weights = vec![w0, w1];
        let no_comp =
            m.forward(&x, 1, &weights, &crate::pcm::gdc::unity(2), 8);
        let comped = m.forward(&x, 1, &weights,
                               &crate::pcm::gdc::flat_vec(&[2.0, 1.0]), 8);
        assert!(comped[0] > no_comp[0] * 1.5);
    }

    #[test]
    fn adc_bits_change_the_computed_numbers() {
        // per-request `InferOpts::adc_bits` rides this knob: a coarser
        // converter must actually change analog-layer outputs
        let meta = tiny_meta();
        let m = NativeModel::new(meta);
        let x: Vec<f32> = (0..16).map(|i| 0.3 + (i as f32) / 40.0).collect();
        let mut rng = crate::util::rng::Rng::new(19);
        let w0: Vec<f32> = (0..18).map(|_| rng.gauss(0.0, 0.4) as f32).collect();
        let w1: Vec<f32> = (0..4).map(|_| rng.gauss(0.0, 0.4) as f32).collect();
        let weights = vec![w0, w1];
        let gdc = crate::pcm::gdc::unity(2);
        let l8 = m.forward(&x, 1, &weights, &gdc, 8);
        let l4 = m.forward(&x, 1, &weights, &gdc, 4);
        assert_ne!(l8, l4, "4-bit conversion must differ from 8-bit");
    }

    #[test]
    fn predict_argmax() {
        let p = NativeModel::predict(&[0.1, 0.9, 0.7, 0.3], 2);
        assert_eq!(p, vec![1, 0]);
    }
}
