//! Native full-model forward pass over effective (possibly drifted) weights.
//!
//! Mirrors the exported HLO graph layer by layer:
//! DAC fake-quant -> GEMM -> ADC fake-quant -> GDC scale -> digital affine ->
//! ReLU, with global average pooling before the dense head, and exact
//! (unquantized) compute for `analog=false` layers (Fig. 9 ablation).

use std::sync::Arc;

use crate::nn::{LayerKind, ModelMeta};
use crate::quant;
use crate::simulator::{gemm, im2col};

pub struct NativeModel {
    meta: Arc<ModelMeta>,
    pub threads: usize,
}

impl NativeModel {
    pub fn new(meta: impl Into<Arc<ModelMeta>>) -> Self {
        Self::with_threads(meta, 1)
    }

    pub fn with_threads(meta: impl Into<Arc<ModelMeta>>, threads: usize) -> Self {
        NativeModel {
            meta: meta.into(),
            threads,
        }
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// Forward a batch: `x` is [batch, H, W, C] flat; returns logits
    /// [batch, classes].
    ///
    /// `weights[l]` must match the layer's graph weight shape (anything
    /// slice-like works: `Vec<f32>`, `HostTensor`, ...); `gdc[l]` is the
    /// drift-compensation scale (1.0 when freshly programmed).
    pub fn forward<W: AsRef<[f32]>>(&self, x: &[f32], batch: usize,
                                    weights: &[W], gdc: &[f32],
                                    adc_bits: u32) -> Vec<f32> {
        let (ih, iw, ic) = self.meta.input_hwc;
        assert_eq!(x.len(), batch * ih * iw * ic, "input shape mismatch");
        assert_eq!(weights.len(), self.meta.layers.len());
        assert_eq!(gdc.len(), self.meta.layers.len());
        let b_dac = quant::dac_bits(adc_bits);

        let mut h = x.to_vec();
        let (mut ch, mut cw, mut cc) = (ih, iw, ic);
        for (li, lm) in self.meta.layers.iter().enumerate() {
            let w = weights[li].as_ref();
            let gw: Vec<usize> = lm.graph_weight_shape.clone();
            match lm.kind {
                LayerKind::Dw3x3 if !lm.analog => {
                    // exact depthwise on the digital processor, compact [9, C]
                    assert_eq!(w.len(), 9 * lm.in_ch);
                    let p = im2col::patches3x3(&h, batch, ch, cw, cc, lm.stride);
                    let ho = im2col::out_dim(ch, lm.stride.0);
                    let wo = im2col::out_dim(cw, lm.stride.1);
                    let c = lm.in_ch;
                    let mut y = vec![0f32; batch * ho * wo * c];
                    for r in 0..batch * ho * wo {
                        for ci in 0..c {
                            let mut acc = 0f32;
                            for t in 0..9 {
                                acc += p[r * 9 * c + t * c + ci] * w[t * c + ci];
                            }
                            // digital per-channel affine, fused
                            y[r * c + ci] = acc * lm.dig_scale[ci] + lm.dig_bias[ci];
                        }
                    }
                    h = y;
                    ch = ho;
                    cw = wo;
                }
                _ => {
                    // GEMM path (conv as im2col, 1x1, dense, analog dw)
                    let (m_rows, k) = match lm.kind {
                        LayerKind::Conv3x3 | LayerKind::Dw3x3 => {
                            let p = im2col::patches3x3(&h, batch, ch, cw, cc, lm.stride);
                            let ho = im2col::out_dim(ch, lm.stride.0);
                            let wo = im2col::out_dim(cw, lm.stride.1);
                            h = p;
                            ch = ho;
                            cw = wo;
                            (batch * ch * cw, 9 * cc)
                        }
                        LayerKind::Conv1x1 => (batch * ch * cw, cc),
                        LayerKind::Dense => {
                            // global average pool
                            let mut g = vec![0f32; batch * cc];
                            let pix = ch * cw;
                            for n in 0..batch {
                                for p_ in 0..pix {
                                    for ci in 0..cc {
                                        g[n * cc + ci] += h[(n * pix + p_) * cc + ci];
                                    }
                                }
                            }
                            let inv = 1.0 / pix as f32;
                            g.iter_mut().for_each(|v| *v *= inv);
                            h = g;
                            ch = 1;
                            cw = 1;
                            (batch, cc)
                        }
                    };
                    assert_eq!(gw[0], k, "{}: K mismatch", lm.name);
                    let n_cols = gw[1];
                    assert_eq!(w.len(), k * n_cols, "{}: weight len", lm.name);

                    let mut a = if lm.analog {
                        let mut m = std::mem::take(&mut h);
                        quant::fake_quant_slice(&mut m, lm.r_dac, b_dac);
                        let mut out = gemm::gemm_parallel(&m, w, m_rows, k,
                                                          n_cols, self.threads);
                        quant::fake_quant_slice(&mut out, lm.r_adc, adc_bits);
                        let g = gdc[li];
                        if (g - 1.0).abs() > 1e-9 {
                            out.iter_mut().for_each(|v| *v *= g);
                        }
                        out
                    } else {
                        gemm::gemm_parallel(&h, w, m_rows, k, n_cols, self.threads)
                    };

                    // digital per-channel affine (folded BN / bias)
                    for r in 0..m_rows {
                        let row = &mut a[r * n_cols..(r + 1) * n_cols];
                        for (j, v) in row.iter_mut().enumerate() {
                            *v = *v * lm.dig_scale[j] + lm.dig_bias[j];
                        }
                    }
                    h = a;
                    cc = n_cols;
                }
            }
            if lm.relu {
                h.iter_mut().for_each(|v| *v = v.max(0.0));
            }
        }
        h
    }

    /// Argmax predictions from logits (thin wrapper over the shared
    /// [`util::logits`](crate::util::logits) helpers).
    pub fn predict(logits: &[f32], classes: usize) -> Vec<u32> {
        crate::util::logits::predictions(logits, classes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::meta::ModelMeta;
    use crate::util::json;

    fn tiny_meta() -> ModelMeta {
        let src = r#"{
          "model": "tiny", "variant": "t", "input_hwc": [4, 4, 1],
          "num_classes": 2, "eta": 0.0, "fp_test_acc": 1.0,
          "trained_adc_bits": null,
          "layers": [
            {"name": "c0", "kind": "conv3x3", "in_ch": 1, "out_ch": 2,
             "stride": [1, 1], "relu": true, "analog": true,
             "in_h": 4, "in_w": 4, "out_h": 4, "out_w": 4,
             "k_gemm": 9, "weight_shape": [9, 2],
             "graph_weight_shape": [9, 2],
             "w_scale": 1.0, "w_max": 1.0, "r_dac": 8.0, "r_adc": 8.0,
             "dig_scale": [1, 1], "dig_bias": [0, 0]},
            {"name": "fc", "kind": "dense", "in_ch": 2, "out_ch": 2,
             "stride": [1, 1], "relu": false, "analog": true,
             "in_h": 4, "in_w": 4, "out_h": 1, "out_w": 1,
             "k_gemm": 2, "weight_shape": [2, 2],
             "graph_weight_shape": [2, 2],
             "w_scale": 1.0, "w_max": 1.0, "r_dac": 8.0, "r_adc": 8.0,
             "dig_scale": [1, 1], "dig_bias": [0, 0]}
          ],
          "hlo": {}
        }"#;
        ModelMeta::from_json(&json::parse(src).unwrap()).unwrap()
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let meta = tiny_meta();
        let m = NativeModel::new(meta);
        let x: Vec<f32> = (0..16).map(|i| (i as f32) / 16.0).collect();
        // center-tap identity conv into 2 channels, then identity dense
        let mut w0 = vec![0f32; 18];
        w0[4 * 2] = 1.0;       // center tap -> ch0
        w0[4 * 2 + 1] = 0.5;   // center tap -> ch1
        let w1 = vec![1.0, 0.0, 0.0, 1.0];
        let weights = vec![w0, w1];
        let gdc = vec![1.0, 1.0];
        let l1 = m.forward(&x, 1, &weights, &gdc, 8);
        let l2 = m.forward(&x, 1, &weights, &gdc, 8);
        assert_eq!(l1.len(), 2);
        assert_eq!(l1, l2);
        // channel 0 average ~ mean(x) (quantization-limited)
        let mean_x: f32 = x.iter().sum::<f32>() / 16.0;
        assert!((l1[0] - mean_x).abs() < 0.1, "{} vs {}", l1[0], mean_x);
        // ch1 = 0.5 * ch0 approximately
        assert!((l1[1] - 0.5 * l1[0]).abs() < 0.05);
    }

    #[test]
    fn gdc_rescales_output() {
        let meta = tiny_meta();
        let m = NativeModel::new(meta);
        let x: Vec<f32> = (0..16).map(|i| (i as f32) / 16.0).collect();
        let mut w0 = vec![0f32; 18];
        w0[4 * 2] = 0.5; // "drifted" weights at half scale
        w0[4 * 2 + 1] = 0.25;
        let w1 = vec![1.0, 0.0, 0.0, 1.0];
        let weights = vec![w0, w1];
        let no_comp = m.forward(&x, 1, &weights, &[1.0, 1.0], 8);
        let comped = m.forward(&x, 1, &weights, &[2.0, 1.0], 8);
        assert!(comped[0] > no_comp[0] * 1.5);
    }

    #[test]
    fn predict_argmax() {
        let p = NativeModel::predict(&[0.1, 0.9, 0.7, 0.3], 2);
        assert_eq!(p, vec![1, 0]);
    }
}
