//! Native full-model forward pass over effective (possibly drifted) weights.
//!
//! Mirrors the exported HLO graph layer by layer:
//! DAC fake-quant -> GEMM -> ADC fake-quant -> GDC scale -> digital affine ->
//! ReLU, with global average pooling before the dense head, and exact
//! (unquantized) compute for `analog=false` layers (Fig. 9 ablation).
//!
//! Execution is **layer-serial over the whole batch**, mirroring the
//! AON-CiM schedule: every sample finishes layer `k` on the (simulated)
//! shared crossbar before any sample starts layer `k+1` — one im2col and
//! one batched GEMM per layer, never per-request forward passes.  The GEMM
//! runs on a persistent [`WorkerPool`] owned by the model, and activations
//! ping-pong between two preallocated scratch buffers, so the serving hot
//! path performs no per-layer allocation.

use std::sync::{Arc, Mutex};

use crate::nn::{LayerKind, ModelMeta};
use crate::quant;
use crate::simulator::im2col;
use crate::simulator::pool::WorkerPool;

/// Ping-pong activation scratch: two buffers, each sized for the largest
/// intermediate (patch matrix or activation block) of the model at the
/// largest batch seen so far.  Layer `k` reads one buffer and writes the
/// other; ownership flips each step, so no layer ever allocates.
/// (Shared with the tile-faithful `AnalogModel`, whose layer loop has the
/// same staging structure.)
#[derive(Default)]
pub(crate) struct Scratch {
    pub(crate) ping: Vec<f32>,
    pub(crate) pong: Vec<f32>,
}

impl Scratch {
    pub(crate) fn ensure(&mut self, cap: usize) {
        if self.ping.len() < cap {
            self.ping.resize(cap, 0.0);
        }
        if self.pong.len() < cap {
            self.pong.resize(cap, 0.0);
        }
    }
}

/// Largest f32 count any single intermediate (input block, im2col patch
/// matrix, layer output) occupies for `meta` at `batch`.
pub(crate) fn scratch_capacity(meta: &ModelMeta, batch: usize) -> usize {
    let (ih, iw, ic) = meta.input_hwc;
    let mut cap = batch * ih * iw * ic;
    let (mut ch, mut cw, mut cc) = (ih, iw, ic);
    for lm in &meta.layers {
        match lm.kind {
            LayerKind::Conv3x3 | LayerKind::Dw3x3 => {
                let ho = im2col::out_dim(ch, lm.stride.0);
                let wo = im2col::out_dim(cw, lm.stride.1);
                let out_c = if lm.kind == LayerKind::Dw3x3 && !lm.analog {
                    lm.in_ch
                } else {
                    lm.graph_weight_shape[1]
                };
                cap = cap.max(batch * ho * wo * 9 * cc); // patch matrix
                cap = cap.max(batch * ho * wo * out_c); // layer output
                ch = ho;
                cw = wo;
                cc = out_c;
            }
            LayerKind::Conv1x1 => {
                let out_c = lm.graph_weight_shape[1];
                cap = cap.max(batch * ch * cw * out_c);
                cc = out_c;
            }
            LayerKind::Dense => {
                let out_c = lm.graph_weight_shape[1];
                cap = cap.max(batch * cc); // pooled features
                cap = cap.max(batch * out_c); // logits
                ch = 1;
                cw = 1;
                cc = out_c;
            }
        }
    }
    cap
}

pub struct NativeModel {
    meta: Arc<ModelMeta>,
    /// persistent row-chunk GEMM workers (created once, parked between
    /// launches — the old implementation spawned scoped threads per call)
    pool: Arc<WorkerPool>,
    /// per-model activation scratch; a Mutex because `forward` takes
    /// `&self` (the serving coordinator drives one model from one thread,
    /// so this lock is uncontended on the hot path)
    scratch: Mutex<Scratch>,
}

impl NativeModel {
    pub fn new(meta: impl Into<Arc<ModelMeta>>) -> Self {
        Self::with_threads(meta, 1)
    }

    /// `threads` GEMM lanes (`0` = all available cores); the worker pool is
    /// spawned here, never on the execution path.
    pub fn with_threads(meta: impl Into<Arc<ModelMeta>>, threads: usize) -> Self {
        NativeModel {
            meta: meta.into(),
            pool: Arc::new(WorkerPool::new(threads)),
            scratch: Mutex::new(Scratch::default()),
        }
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// GEMM lanes this model multiplies on (workers + calling thread).
    pub fn threads(&self) -> usize {
        self.pool.lanes()
    }

    /// Forward a batch: `x` is [batch, H, W, C] flat; returns logits
    /// [batch, classes].
    ///
    /// `weights[l]` must match the layer's graph weight shape (anything
    /// slice-like works: `Vec<f32>`, `HostTensor`, ...); `gdc[l]` is the
    /// drift-compensation scale (1.0 when freshly programmed).
    ///
    /// Results are bit-identical for any batch decomposition: running N
    /// samples in one call equals N single-sample calls, because every
    /// per-row accumulation order is batch-invariant (the layer-serial
    /// correctness invariant the coordinator's batcher relies on).
    pub fn forward<W: AsRef<[f32]>>(&self, x: &[f32], batch: usize,
                                    weights: &[W], gdc: &[f32],
                                    adc_bits: u32) -> Vec<f32> {
        let (ih, iw, ic) = self.meta.input_hwc;
        assert_eq!(x.len(), batch * ih * iw * ic, "input shape mismatch");
        assert_eq!(weights.len(), self.meta.layers.len());
        assert_eq!(gdc.len(), self.meta.layers.len());
        let b_dac = quant::dac_bits(adc_bits);

        let mut guard = self.scratch.lock().unwrap();
        guard.ensure(scratch_capacity(&self.meta, batch));
        let Scratch { ping, pong } = &mut *guard;
        let (mut cur, mut nxt): (&mut Vec<f32>, &mut Vec<f32>) = (ping, pong);
        cur[..x.len()].copy_from_slice(x);
        let mut len = x.len();

        let (mut ch, mut cw, mut cc) = (ih, iw, ic);
        for (li, lm) in self.meta.layers.iter().enumerate() {
            let w = weights[li].as_ref();
            match lm.kind {
                LayerKind::Dw3x3 if !lm.analog => {
                    // exact depthwise on the digital processor, compact [9, C]
                    let c = lm.in_ch;
                    assert_eq!(w.len(), 9 * c);
                    let ho = im2col::out_dim(ch, lm.stride.0);
                    let wo = im2col::out_dim(cw, lm.stride.1);
                    let rows = batch * ho * wo;
                    im2col::patches3x3_into(&cur[..len], &mut nxt[..rows * 9 * c],
                                            batch, ch, cw, cc, lm.stride);
                    // patches in `nxt`; depthwise result overwrites `cur`
                    for r in 0..rows {
                        for ci in 0..c {
                            let mut acc = 0f32;
                            for t in 0..9 {
                                acc += nxt[r * 9 * c + t * c + ci] * w[t * c + ci];
                            }
                            // digital per-channel affine, fused
                            cur[r * c + ci] = acc * lm.dig_scale[ci] + lm.dig_bias[ci];
                        }
                    }
                    len = rows * c;
                    ch = ho;
                    cw = wo;
                }
                _ => {
                    // GEMM path (conv as im2col, 1x1, dense, analog dw):
                    // stage the GEMM input so it ends up in `cur`
                    let (m_rows, k) = match lm.kind {
                        LayerKind::Conv3x3 | LayerKind::Dw3x3 => {
                            let ho = im2col::out_dim(ch, lm.stride.0);
                            let wo = im2col::out_dim(cw, lm.stride.1);
                            let kk = 9 * cc;
                            let rows = batch * ho * wo;
                            im2col::patches3x3_into(&cur[..len],
                                                    &mut nxt[..rows * kk],
                                                    batch, ch, cw, cc, lm.stride);
                            std::mem::swap(&mut cur, &mut nxt);
                            len = rows * kk;
                            ch = ho;
                            cw = wo;
                            (rows, kk)
                        }
                        LayerKind::Conv1x1 => (batch * ch * cw, cc),
                        LayerKind::Dense => {
                            // global average pool into `nxt`, then flip
                            let pix = ch * cw;
                            let g = &mut nxt[..batch * cc];
                            g.fill(0.0);
                            for ni in 0..batch {
                                for p_ in 0..pix {
                                    for ci in 0..cc {
                                        g[ni * cc + ci] += cur[(ni * pix + p_) * cc + ci];
                                    }
                                }
                            }
                            let inv = 1.0 / pix as f32;
                            g.iter_mut().for_each(|v| *v *= inv);
                            std::mem::swap(&mut cur, &mut nxt);
                            len = batch * cc;
                            ch = 1;
                            cw = 1;
                            (batch, cc)
                        }
                    };
                    let gw = &lm.graph_weight_shape;
                    assert_eq!(gw[0], k, "{}: K mismatch", lm.name);
                    let n_cols = gw[1];
                    assert_eq!(w.len(), k * n_cols, "{}: weight len", lm.name);
                    debug_assert_eq!(len, m_rows * k);

                    if lm.analog {
                        quant::fake_quant_slice(&mut cur[..m_rows * k], lm.r_dac, b_dac);
                    }
                    self.pool.gemm_into(&cur[..m_rows * k], w,
                                        &mut nxt[..m_rows * n_cols],
                                        m_rows, k, n_cols);
                    let out = &mut nxt[..m_rows * n_cols];
                    if lm.analog {
                        quant::fake_quant_slice(out, lm.r_adc, adc_bits);
                        let g = gdc[li];
                        if (g - 1.0).abs() > 1e-9 {
                            out.iter_mut().for_each(|v| *v *= g);
                        }
                    }
                    // digital per-channel affine (folded BN / bias)
                    for r in 0..m_rows {
                        let row = &mut out[r * n_cols..(r + 1) * n_cols];
                        for (j, v) in row.iter_mut().enumerate() {
                            *v = *v * lm.dig_scale[j] + lm.dig_bias[j];
                        }
                    }
                    std::mem::swap(&mut cur, &mut nxt);
                    len = m_rows * n_cols;
                    cc = n_cols;
                }
            }
            if lm.relu {
                cur[..len].iter_mut().for_each(|v| *v = v.max(0.0));
            }
        }
        cur[..len].to_vec()
    }

    /// Argmax predictions from logits (thin wrapper over the shared
    /// [`util::logits`](crate::util::logits) helpers).
    pub fn predict(logits: &[f32], classes: usize) -> Vec<u32> {
        crate::util::logits::predictions(logits, classes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::meta::ModelMeta;
    use crate::util::json;

    fn tiny_meta() -> ModelMeta {
        let src = r#"{
          "model": "tiny", "variant": "t", "input_hwc": [4, 4, 1],
          "num_classes": 2, "eta": 0.0, "fp_test_acc": 1.0,
          "trained_adc_bits": null,
          "layers": [
            {"name": "c0", "kind": "conv3x3", "in_ch": 1, "out_ch": 2,
             "stride": [1, 1], "relu": true, "analog": true,
             "in_h": 4, "in_w": 4, "out_h": 4, "out_w": 4,
             "k_gemm": 9, "weight_shape": [9, 2],
             "graph_weight_shape": [9, 2],
             "w_scale": 1.0, "w_max": 1.0, "r_dac": 8.0, "r_adc": 8.0,
             "dig_scale": [1, 1], "dig_bias": [0, 0]},
            {"name": "fc", "kind": "dense", "in_ch": 2, "out_ch": 2,
             "stride": [1, 1], "relu": false, "analog": true,
             "in_h": 4, "in_w": 4, "out_h": 1, "out_w": 1,
             "k_gemm": 2, "weight_shape": [2, 2],
             "graph_weight_shape": [2, 2],
             "w_scale": 1.0, "w_max": 1.0, "r_dac": 8.0, "r_adc": 8.0,
             "dig_scale": [1, 1], "dig_bias": [0, 0]}
          ],
          "hlo": {}
        }"#;
        ModelMeta::from_json(&json::parse(src).unwrap()).unwrap()
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let meta = tiny_meta();
        let m = NativeModel::new(meta);
        let x: Vec<f32> = (0..16).map(|i| (i as f32) / 16.0).collect();
        // center-tap identity conv into 2 channels, then identity dense
        let mut w0 = vec![0f32; 18];
        w0[4 * 2] = 1.0;       // center tap -> ch0
        w0[4 * 2 + 1] = 0.5;   // center tap -> ch1
        let w1 = vec![1.0, 0.0, 0.0, 1.0];
        let weights = vec![w0, w1];
        let gdc = vec![1.0, 1.0];
        let l1 = m.forward(&x, 1, &weights, &gdc, 8);
        let l2 = m.forward(&x, 1, &weights, &gdc, 8);
        assert_eq!(l1.len(), 2);
        assert_eq!(l1, l2);
        // channel 0 average ~ mean(x) (quantization-limited)
        let mean_x: f32 = x.iter().sum::<f32>() / 16.0;
        assert!((l1[0] - mean_x).abs() < 0.1, "{} vs {}", l1[0], mean_x);
        // ch1 = 0.5 * ch0 approximately
        assert!((l1[1] - 0.5 * l1[0]).abs() < 0.05);
    }

    #[test]
    fn batched_forward_is_bit_identical_to_sequential() {
        // the layer-serial correctness invariant, at the model level: one
        // run_batch(N) == N single-sample runs, bit for bit
        let meta = tiny_meta();
        let m = NativeModel::with_threads(meta, 4);
        let mut rng = crate::util::rng::Rng::new(9);
        let batch = 5;
        let x: Vec<f32> = (0..batch * 16).map(|_| rng.gauss(0.4, 0.3) as f32).collect();
        let w0: Vec<f32> = (0..18).map(|_| rng.gauss(0.0, 0.4) as f32).collect();
        let w1: Vec<f32> = (0..4).map(|_| rng.gauss(0.0, 0.4) as f32).collect();
        let weights = vec![w0, w1];
        let gdc = vec![1.1, 1.0];
        let batched = m.forward(&x, batch, &weights, &gdc, 8);
        assert_eq!(batched.len(), batch * 2);
        for s in 0..batch {
            let one = m.forward(&x[s * 16..(s + 1) * 16], 1, &weights, &gdc, 8);
            assert_eq!(one, batched[s * 2..(s + 1) * 2].to_vec(), "sample {s}");
        }
    }

    #[test]
    fn gdc_rescales_output() {
        let meta = tiny_meta();
        let m = NativeModel::new(meta);
        let x: Vec<f32> = (0..16).map(|i| (i as f32) / 16.0).collect();
        let mut w0 = vec![0f32; 18];
        w0[4 * 2] = 0.5; // "drifted" weights at half scale
        w0[4 * 2 + 1] = 0.25;
        let w1 = vec![1.0, 0.0, 0.0, 1.0];
        let weights = vec![w0, w1];
        let no_comp = m.forward(&x, 1, &weights, &[1.0, 1.0], 8);
        let comped = m.forward(&x, 1, &weights, &[2.0, 1.0], 8);
        assert!(comped[0] > no_comp[0] * 1.5);
    }

    #[test]
    fn predict_argmax() {
        let p = NativeModel::predict(&[0.1, 0.9, 0.7, 0.3], 2);
        assert_eq!(p, vec![1, 0]);
    }
}
