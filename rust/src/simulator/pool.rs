//! Persistent worker pool for parallel GEMM macro-tiles (and arbitrary
//! jobs such as the AnalogCim per-tile MVMs).
//!
//! The serving hot path used to spawn scoped threads on *every*
//! `gemm_parallel` call; at serving rates that is thousands of
//! thread-spawn/join cycles per second. A [`WorkerPool`] is created once
//! (owned by `NativeModel`, or process-wide via [`global`]) and its workers
//! park on a job queue between launches, so a batched GEMM costs one channel
//! send per macro-tile job instead of one thread spawn.
//!
//! The pool is std-only: `mpsc` job queue + `Mutex`/`Condvar` completion
//! latch. Jobs carry raw-pointer views of the caller's slices; soundness
//! comes from the dispatch protocol — the caller blocks on the latch until
//! every submitted chunk has run, so the borrowed buffers strictly outlive
//! the jobs that touch them, and row chunks of `C` are disjoint by
//! construction (`chunks_mut`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

use crate::simulator::gemm;
use crate::simulator::tiling;

/// A unit of pool work. Jobs may capture raw views ([`RawSlice`],
/// [`RawSliceMut`]) of caller-owned buffers; the dispatch protocol
/// ([`WorkerPool::run_all`], [`WorkerPool::gemm_chunks`]) blocks the caller
/// until every job has run, which is what makes those views sound.
pub type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed set of parked worker threads executing row-chunk GEMM jobs.
///
/// `lanes` counts the caller thread too: a pool built with `threads = 4`
/// spawns 3 workers and runs the first chunk inline, so a 4-lane GEMM uses
/// exactly 4 cores. `threads == 0` means [`gemm::effective_threads`]
/// (`available_parallelism`), and `threads <= 1` spawns no workers at all —
/// every call degenerates to the single-threaded kernel.
pub struct WorkerPool {
    tx: Mutex<Option<mpsc::Sender<Job>>>,
    workers: Vec<JoinHandle<()>>,
    lanes: usize,
}

impl WorkerPool {
    pub fn new(threads: usize) -> Self {
        let lanes = gemm::effective_threads(threads);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(lanes.saturating_sub(1));
        for i in 0..lanes.saturating_sub(1) {
            let rx = rx.clone();
            let h = std::thread::Builder::new()
                .name(format!("gemm-worker-{i}"))
                .spawn(move || loop {
                    // take the lock only long enough to pop one job
                    let job = rx.lock().unwrap().recv();
                    match job {
                        Ok(j) => j(),
                        Err(_) => break, // pool dropped
                    }
                })
                .expect("spawn gemm worker");
            workers.push(h);
        }
        WorkerPool {
            tx: Mutex::new(Some(tx)),
            workers,
            lanes,
        }
    }

    /// Parallel lanes this pool can drive (workers + the calling thread).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    fn submit(&self, job: Job) {
        self.tx
            .lock()
            .unwrap()
            .as_ref()
            .expect("worker pool already shut down")
            .send(job)
            .expect("gemm worker hung up");
    }

    /// Execute arbitrary jobs across this pool's lanes and block until all
    /// of them have finished. The calling thread is a lane: it runs the
    /// first job inline while the workers drain the rest (same latch
    /// protocol as [`gemm_chunks`](Self::gemm_chunks), so jobs may capture
    /// raw views of caller-owned buffers — they strictly outlive the jobs).
    /// Jobs writing the same output buffer must target disjoint regions.
    ///
    /// This is the dispatch surface behind the AnalogCim engine's
    /// per-crossbar-tile MVMs, where each job quantizes and accumulates a
    /// whole column band and a plain row-chunk GEMM split does not fit.
    pub fn run_all(&self, jobs: Vec<Job>) {
        let mut jobs = jobs.into_iter();
        let Some(head) = jobs.next() else { return };
        if self.workers.is_empty() {
            head();
            for job in jobs {
                job();
            }
            return;
        }
        let latch = Arc::new(Latch::new());
        let mut submitted = 0usize;
        for job in jobs {
            let latch = latch.clone();
            submitted += 1;
            self.submit(Box::new(move || {
                job();
                latch.arrive();
            }));
        }
        head();
        latch.wait(submitted);
    }

    /// `C[M,N] = A[M,K] @ B[K,N]` over this pool's lanes: the blocked,
    /// packed kernel under the process-wide single-k-block scheme
    /// ([`tiling::global`] clamped through [`tiling::TilingScheme::full_k`]
    /// — bit-identical to [`gemm::gemm_naive_into`]), with (m-block x
    /// n-block) macro-tiles distributed across the workers. Falls back to
    /// the single-threaded kernel below [`gemm::PAR_ROW_THRESHOLD`] rows.
    pub fn gemm_into(&self, a: &[f32], b: &[f32], c: &mut [f32], m: usize,
                     k: usize, n: usize) {
        if self.workers.is_empty() || m < gemm::PAR_ROW_THRESHOLD {
            gemm::gemm_into(a, b, c, m, k, n);
        } else {
            gemm::gemm_blocked_pool_into(self, a, b, c, m, k, n,
                                         tiling::global().full_k(),
                                         self.lanes);
        }
    }

    /// The legacy row-chunk dispatch: `lanes` contiguous row chunks of the
    /// *naive* kernel (what [`gemm_into`](Self::gemm_into) was before the
    /// packed kernel landed). Kept verbatim so the bench's `gemm` section
    /// can measure blocked-vs-rowpar on identical pool machinery; not on
    /// any serving path.
    #[allow(clippy::too_many_arguments)]
    pub fn gemm_chunks(&self, a: &[f32], b: &[f32], c: &mut [f32], m: usize,
                       k: usize, n: usize, lanes: usize) {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        assert_eq!(c.len(), m * n);
        let lanes = lanes.min(m).max(1);
        if lanes <= 1 || m < gemm::PAR_ROW_THRESHOLD || self.workers.is_empty() {
            gemm::gemm_naive_into(a, b, c, m, k, n);
            return;
        }
        let chunk = m.div_ceil(lanes);
        let latch = Arc::new(Latch::new());
        let mut submitted = 0usize;
        let mut chunks = c.chunks_mut(chunk * n).enumerate();
        let (_, head) = chunks.next().expect("m > 0");
        for (ci, cchunk) in chunks {
            let lo = ci * chunk;
            let rows = cchunk.len() / n;
            let ra = RawSlice::of(&a[lo * k..(lo + rows) * k]);
            let rb = RawSlice::of(b);
            let rc = RawSliceMut::of(cchunk);
            let latch = latch.clone();
            submitted += 1;
            self.submit(Box::new(move || {
                // SAFETY: the caller blocks on `latch.wait` until this job
                // has arrived, so `a`, `b` and this (disjoint) chunk of `c`
                // outlive the job.
                unsafe {
                    gemm::gemm_naive_into(ra.get(), rb.get(), rc.get_mut(),
                                          rows, k, n);
                }
                latch.arrive();
            }));
        }
        // the calling thread is a lane too: it computes the first chunk
        let head_rows = head.len() / n;
        gemm::gemm_naive_into(&a[..head_rows * k], b, head, head_rows, k, n);
        latch.wait(submitted);
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // closing the channel wakes every worker out of recv()
        *self.tx.lock().unwrap() = None;
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Process-wide pool sized to `available_parallelism`, created on first use.
/// Backs the free-function [`gemm::gemm_parallel`] so one-off callers
/// (benches, tests) share workers instead of spawning their own.
pub fn global() -> &'static WorkerPool {
    static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();
    GLOBAL.get_or_init(|| WorkerPool::new(0))
}

/// Count-up completion latch: jobs `arrive`, the dispatcher waits for all.
struct Latch {
    done: AtomicUsize,
    lock: Mutex<()>,
    cv: Condvar,
}

impl Latch {
    fn new() -> Self {
        Latch {
            done: AtomicUsize::new(0),
            lock: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    fn arrive(&self) {
        // hold the lock across the increment so a waiter can't check the
        // counter between our store and our notify and then sleep forever
        let _g = self.lock.lock().unwrap();
        self.done.fetch_add(1, Ordering::Release);
        self.cv.notify_all();
    }

    fn wait(&self, target: usize) {
        let mut g = self.lock.lock().unwrap();
        while self.done.load(Ordering::Acquire) < target {
            g = self.cv.wait(g).unwrap();
        }
    }
}

/// Raw view of a shared f32 slice, Send across the job channel.
#[derive(Clone, Copy)]
pub(crate) struct RawSlice {
    ptr: *const f32,
    len: usize,
}

unsafe impl Send for RawSlice {}

impl RawSlice {
    pub(crate) fn of(s: &[f32]) -> Self {
        RawSlice { ptr: s.as_ptr(), len: s.len() }
    }

    /// SAFETY: caller must guarantee the source slice outlives the use.
    pub(crate) unsafe fn get<'a>(self) -> &'a [f32] {
        std::slice::from_raw_parts(self.ptr, self.len)
    }
}

/// Raw view of an exclusive f32 slice, Send across the job channel. Copies
/// of one view may live in several jobs at once (that is how disjoint
/// strided regions of a shared output buffer are dispatched); exclusivity
/// of the *regions actually written* is the dispatcher's obligation.
#[derive(Clone, Copy)]
pub(crate) struct RawSliceMut {
    ptr: *mut f32,
    len: usize,
}

unsafe impl Send for RawSliceMut {}

impl RawSliceMut {
    pub(crate) fn of(s: &mut [f32]) -> Self {
        RawSliceMut { ptr: s.as_mut_ptr(), len: s.len() }
    }

    /// SAFETY: caller must guarantee exclusivity and lifetime of the source.
    pub(crate) unsafe fn get_mut<'a>(self) -> &'a mut [f32] {
        std::slice::from_raw_parts_mut(self.ptr, self.len)
    }

    /// A `&mut` view of `[offset, offset + len)` only. Concurrent jobs
    /// holding copies of one `RawSliceMut` must go through this (never
    /// [`get_mut`](Self::get_mut)) so that no two live `&mut` slices ever
    /// overlap — materializing the whole buffer in several jobs at once
    /// would alias even if the actual writes are disjoint.
    ///
    /// SAFETY: caller must guarantee the range is in bounds, disjoint from
    /// every other outstanding view, and that the source outlives the use.
    pub(crate) unsafe fn slice_at<'a>(self, offset: usize, len: usize)
                                      -> &'a mut [f32] {
        debug_assert!(offset + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(offset), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pool_matches_single_thread() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.lanes(), 4);
        let mut rng = Rng::new(42);
        for (m, k, n) in [(64, 9, 8), (127, 17, 5), (300, 36, 16)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
            let mut c1 = vec![0f32; m * n];
            let mut c2 = vec![0f32; m * n];
            gemm::gemm_into(&a, &b, &mut c1, m, k, n);
            pool.gemm_into(&a, &b, &mut c2, m, k, n);
            assert_eq!(c1, c2, "pool result differs at {m}x{k}x{n}");
        }
    }

    #[test]
    fn pool_is_reusable_across_calls() {
        let pool = WorkerPool::new(3);
        let (m, k, n) = (96, 4, 4);
        let a: Vec<f32> = (0..m * k).map(|i| i as f32 * 0.01).collect();
        let b: Vec<f32> = (0..k * n).map(|i| i as f32 * 0.1).collect();
        let mut want = vec![0f32; m * n];
        gemm::gemm_into(&a, &b, &mut want, m, k, n);
        let mut c = vec![0f32; m * n];
        for _ in 0..50 {
            c.fill(7.0); // gemm_into must overwrite
            pool.gemm_into(&a, &b, &mut c, m, k, n);
            assert_eq!(c, want);
        }
    }

    #[test]
    fn single_lane_pool_spawns_no_workers() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.lanes(), 1);
        let a = vec![1.0f32; 128 * 2];
        let b = vec![1.0f32; 2 * 2];
        let mut c = vec![0f32; 128 * 2];
        pool.gemm_into(&a, &b, &mut c, 128, 2, 2);
        assert!(c.iter().all(|&v| v == 2.0));
    }

    #[test]
    fn zero_threads_means_available_parallelism() {
        let pool = WorkerPool::new(0);
        assert!(pool.lanes() >= 1);
        assert_eq!(pool.lanes(), gemm::effective_threads(0));
    }

    #[test]
    fn run_all_executes_every_job_exactly_once() {
        for threads in [1, 4] {
            let pool = WorkerPool::new(threads);
            let hits = Arc::new(AtomicUsize::new(0));
            let jobs: Vec<Job> = (0..13)
                .map(|_| {
                    let hits = hits.clone();
                    Box::new(move || {
                        hits.fetch_add(1, Ordering::SeqCst);
                    }) as Job
                })
                .collect();
            pool.run_all(jobs);
            assert_eq!(hits.load(Ordering::SeqCst), 13, "threads={threads}");
            pool.run_all(Vec::new()); // empty dispatch is a no-op
        }
    }

    #[test]
    fn concurrent_callers_share_one_pool() {
        let pool = Arc::new(WorkerPool::new(4));
        let (m, k, n) = (128, 8, 8);
        let a: Vec<f32> = (0..m * k).map(|i| (i % 13) as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 7) as f32).collect();
        let mut want = vec![0f32; m * n];
        gemm::gemm_into(&a, &b, &mut want, m, k, n);
        let want = Arc::new(want);
        let a = Arc::new(a);
        let b = Arc::new(b);
        let mut handles = Vec::new();
        for _ in 0..4 {
            let (pool, a, b, want) = (pool.clone(), a.clone(), b.clone(), want.clone());
            handles.push(std::thread::spawn(move || {
                for _ in 0..20 {
                    let mut c = vec![0f32; m * n];
                    pool.gemm_into(&a, &b, &mut c, m, k, n);
                    assert_eq!(&c, want.as_ref());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
