//! Rust-native CiM forward simulators.
//!
//! Two independent implementations of the deployed inference graph, used to
//! cross-validate the PJRT path and to run device-physics experiments
//! without XLA in the loop:
//!
//! * [`NativeModel`] — im2col + full-K GEMM + DAC/ADC fake quantization +
//!   digital affine, mirroring the exported HLO graph layer by layer;
//! * [`AnalogModel`] — the tile-faithful schedule: one MVM per mapped
//!   crossbar tile, per-tile ADC quantization at the GDC-scaled range,
//!   digital f32 accumulation across K-tiles (see `analog_forward`).
//!
//! The im2col ordering and SAME-padding convention are a shared contract
//! with `python/compile/layers.py`.

pub mod analog_forward;
pub mod forward;
pub mod gemm;
pub mod im2col;
pub mod pool;

pub use analog_forward::AnalogModel;
pub use forward::NativeModel;
pub use pool::WorkerPool;
