//! Rust-native CiM forward simulators.
//!
//! One staging loop, many matmul engines: the full layer-serial schedule
//! (im2col, scratch ping-pong, pooling, DAC quantization, digital affine,
//! ReLU) lives in [`pipeline::LayerExecutor`], and the only step that
//! differs between execution styles — the analog matmul + output
//! quantization — is a [`pipeline::MatmulEngine`] implementation:
//!
//! * [`NativeModel`] = executor + [`NativeGemmEngine`]: full-K GEMM with
//!   ADC fake-quantization after accumulation, mirroring the exported HLO
//!   graph layer by layer;
//! * [`AnalogModel`] = executor + [`TileGridEngine`]: the tile-faithful
//!   schedule — one MVM per mapped crossbar tile, per-tile ADC
//!   quantization at the GDC-scaled range, digital f32 accumulation
//!   across K-tiles (see `analog_forward`).
//!
//! A staging fix or a new layer kind lands in both engines by
//! construction; a new engine (per-tile GDC, stochastic ADCs, ...) is one
//! trait impl, not a third copy of the loop.
//!
//! The im2col ordering and SAME-padding convention are a shared contract
//! with `python/compile/layers.py`.

pub mod analog_forward;
pub mod forward;
pub mod gemm;
pub mod im2col;
pub mod pipeline;
pub mod pool;
pub mod tiling;

pub use analog_forward::{AnalogModel, TileGridEngine};
pub use forward::NativeModel;
pub use pipeline::{LayerExecutor, MatmulCtx, MatmulEngine, NativeGemmEngine};
pub use pool::WorkerPool;
pub use tiling::TilingScheme;
