//! Rust-native CiM forward simulator.
//!
//! An independent implementation of the exported inference graph (im2col +
//! GEMM + DAC/ADC quantization + digital affine) used to cross-validate the
//! PJRT path and to run device-physics experiments without XLA in the loop.
//! The im2col ordering and SAME-padding convention are a shared contract
//! with `python/compile/layers.py`.

pub mod forward;
pub mod gemm;
pub mod im2col;
pub mod pool;

pub use forward::NativeModel;
pub use pool::WorkerPool;
