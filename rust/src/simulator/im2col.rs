//! im2col patch extraction (3x3, SAME, pad=1, out = ceil(in/stride)).
//!
//! Feature ordering is `(ky, kx, c)`: column `(ky*3 + kx)*C + c` of the
//! output matrix holds `x[n, oh*sh + ky - 1, ow*sw + kx - 1, c]` (zero when
//! out of bounds) — identical to `python/compile/layers.patches3x3`.

/// Output spatial size for stride `s` with our SAME convention.
pub fn out_dim(input: usize, stride: usize) -> usize {
    (input + stride - 1) / stride
}

/// Extract 3x3 patches of `x` ([n, h, w, c] flat, row-major) into a
/// [n*ho*wo, 9c] matrix.
pub fn patches3x3(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    stride: (usize, usize),
) -> Vec<f32> {
    let (sh, sw) = stride;
    let ho = out_dim(h, sh);
    let wo = out_dim(w, sw);
    let mut out = vec![0f32; n * ho * wo * 9 * c];
    patches3x3_into(x, &mut out, n, h, w, c, stride);
    out
}

/// [`patches3x3`] into a caller-provided buffer (hot path: the batched
/// engine ping-pongs two preallocated scratch buffers instead of allocating
/// a patch matrix per layer).  `out` must be exactly `n*ho*wo*9c` long; it
/// is fully overwritten (zero-padding included).
pub fn patches3x3_into(
    x: &[f32],
    out: &mut [f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    stride: (usize, usize),
) {
    let (sh, sw) = stride;
    let ho = out_dim(h, sh);
    let wo = out_dim(w, sw);
    let k = 9 * c;
    assert_eq!(x.len(), n * h * w * c, "im2col input shape");
    assert_eq!(out.len(), n * ho * wo * k, "im2col output shape");
    out.fill(0.0);
    for ni in 0..n {
        for oh in 0..ho {
            for ow in 0..wo {
                let row = ((ni * ho + oh) * wo + ow) * k;
                for ky in 0..3 {
                    let iy = (oh * sh + ky) as isize - 1;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..3 {
                        let ix = (ow * sw + kx) as isize - 1;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = ((ni * h + iy as usize) * w + ix as usize) * c;
                        let dst = row + (ky * 3 + kx) * c;
                        out[dst..dst + c].copy_from_slice(&x[src..src + c]);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn into_matches_allocating_and_clears_stale_data() {
        let (n, h, w, c) = (2, 5, 4, 3);
        let x: Vec<f32> = (0..n * h * w * c).map(|i| (i as f32).sin()).collect();
        let want = patches3x3(&x, n, h, w, c, (2, 1));
        let mut out = vec![123.0f32; want.len()]; // stale garbage
        patches3x3_into(&x, &mut out, n, h, w, c, (2, 1));
        assert_eq!(out, want);
    }

    #[test]
    fn out_dims() {
        assert_eq!(out_dim(49, 2), 25);
        assert_eq!(out_dim(10, 1), 10);
        assert_eq!(out_dim(100, 2), 50);
        assert_eq!(out_dim(13, 2), 7);
    }

    #[test]
    fn identity_kernel_center() {
        // with stride 1, the center tap (ky=1,kx=1) reproduces the input
        let (n, h, w, c) = (1, 4, 5, 2);
        let x: Vec<f32> = (0..n * h * w * c).map(|i| i as f32).collect();
        let p = patches3x3(&x, n, h, w, c, (1, 1));
        let k = 9 * c;
        for oh in 0..h {
            for ow in 0..w {
                for ci in 0..c {
                    let got = p[(oh * w + ow) * k + 4 * c + ci]; // ky=1,kx=1
                    let want = x[(oh * w + ow) * c + ci];
                    assert_eq!(got, want);
                }
            }
        }
    }

    #[test]
    fn border_is_zero_padded() {
        let (n, h, w, c) = (1, 3, 3, 1);
        let x = vec![1f32; 9];
        let p = patches3x3(&x, n, h, w, c, (1, 1));
        // top-left output pixel (row 0 of the [.., 9] patch matrix): taps
        // with iy<0 or ix<0 must be 0
        assert_eq!(p[0], 0.0); // (ky=0,kx=0)
        assert_eq!(p[1], 0.0); // (ky=0,kx=1)
        assert_eq!(p[3], 0.0); // (ky=1,kx=0)
        assert_eq!(p[4], 1.0); // center
    }

    #[test]
    fn stride2_samples_even_pixels() {
        let (n, h, w, c) = (1, 4, 4, 1);
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let p = patches3x3(&x, n, h, w, c, (2, 2));
        let k = 9;
        // output (1,1) = patch row 3; center tap = x[2*1, 2*1] = x[2,2] = 10
        assert_eq!(p[3 * k + 4], 10.0);
    }
}
