//! `analognets` CLI: the L3 leader entrypoint.
//!
//! Subcommands:
//!   serve    run the always-on coordinator on synthetic request traffic
//!   eval     drift-accuracy evaluation of one variant (Fig 7 style)
//!   map      print the CiM array mapping of a variant (Fig 6 / Fig 11)
//!   report   accelerator performance summary (Table 2 style)
//!   selftest sanity-check the artifact bundle end to end

use analognets::backend::{auto_threads, AnalogCimBackend, BackendKind,
                          InferOpts};
use analognets::coordinator::{Coordinator, ServeConfig};
use analognets::crossbar::ArrayGeom;
use analognets::eval::{drift_accuracy, drift_accuracy_on, EvalOpts};
use analognets::mapping::{layout, map_model};
use analognets::pcm::{FaultSpec, FIG7_TIMES, T_C_SECONDS};
use analognets::runtime::ArtifactStore;
use analognets::timing::{model_perf, peak, EnergyModel};
use analognets::util::cli::Args;
use analognets::util::stats;
use analognets::util::table::Table;

const USAGE: &str = "usage: analognets <serve|eval|map|report|selftest> [options]
  serve    --vid kws_full_e10_8b [--bits 8] [--requests 500] [--time-scale 1e4]
           [--max-batch N (0=auto)] [--threads N (0=auto)]
           [--models vidA,vidB (serve several variants behind one
                                multi-model router instead of --vid; the
                                first is the primary, wire requests pick
                                one with a \"model\" field)]
           [--queue-depth N (multi-model: per-shard admission bound,
                             0=auto 4x the largest launch)]
           [--t-drift SECONDS (stamp every request with this device age;
                               also seeds the serving clock, default 25)]
           [--adc-bits B (stamp every request with this ADC bitwidth,
                          e.g. 4 for the paper's Table-2 scenario)]
           [--faults SPEC (deployment-default device-variability scenario,
                           e.g. stuck_min=0.01,adc_gain=0.02,seed=7; keys
                           stuck_min stuck_max g_sigma adc_offset adc_gain
                           seed — ADC keys need --backend analog)]
           [--listen ADDR:PORT (wire-protocol TCP server instead of the
                                synthetic driver; PORT 0 picks a free port)]
           [--max-conns N (wire: concurrent connection cap, default 64)]
           [--max-line-bytes B (wire: request line cap, default 262144)]
           [--duration SECONDS (wire: serve this long, then exit;
                                default: until stdin EOF / Ctrl-D)]
  eval     --vid kws_full_e10_8b [--bits 8] [--runs 5] [--samples 256]
           [--t-drift SECONDS (single time point instead of the Fig-7 sweep)]
           [--adc-bits B (per-request ADC override, e.g. 4-bit serving)]
           [--faults SPEC (device-variability scenario, same grammar as
                           serve; stamped onto every programming run)]
           [--rows R --cols C [--mux M]  (analog backend: tile geometry)]
  map      --vid kws_full_e10_8b [--rows 1024 --cols 512] [--mux 4] [--split]
  report   --vid kws_full_e10_8b [--bits 8]
  selftest
options: --artifacts <dir> (or env ANALOGNETS_ARTIFACTS)
         --backend native|analog|pjrt (serve/eval/selftest; default native —
                                `analog` is the tile-faithful CiM engine,
                                pjrt needs a build with `--features pjrt`)";

fn main() {
    let args = Args::from_env();
    if let Some(dir) = args.opt("artifacts") {
        std::env::set_var("ANALOGNETS_ARTIFACTS", dir);
    }
    let cmd = args.positional.first().cloned().unwrap_or_default();
    let r = match cmd.as_str() {
        "serve" => cmd_serve(&args),
        "eval" => cmd_eval(&args),
        "map" => cmd_map(&args),
        "report" => cmd_report(&args),
        "selftest" => cmd_selftest(&args),
        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn default_vid(args: &Args) -> String {
    args.opt_or("vid", "kws_full_e10_8b")
}

/// Optional `--adc-bits B` (per-request ADC bitwidth override).
fn opt_adc_bits(args: &Args) -> Option<u32> {
    args.opt("adc-bits")
        .map(|v| v.parse().expect("integer --adc-bits"))
}

/// Optional `--faults SPEC` (device-variability scenario; see
/// [`FaultSpec::parse`] for the grammar).
fn opt_faults(args: &Args) -> anyhow::Result<Option<FaultSpec>> {
    args.opt("faults").map(FaultSpec::parse).transpose()
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    if args.opt("models").is_some() {
        return cmd_serve_multi(args);
    }
    let vid = default_vid(args);
    let bits = args.opt_usize("bits", 8) as u32;
    let n_requests = args.opt_usize("requests", 500);
    let mut cfg = ServeConfig::new(&vid, bits);
    cfg.backend = BackendKind::from_args(args)?;
    cfg.time_scale = args.opt_f64("time-scale", 1e4);
    cfg.max_batch = args.opt_usize("max-batch", 0);
    cfg.threads = args.opt_usize("threads", 0);
    cfg.drift_time = args.opt_f64("t-drift", T_C_SECONDS);
    // the fault scenario is deployment state, not a per-request stamp: it
    // goes through ServeConfig so the PCM state programs (and calibrates)
    // the faulted array once, and every option-less request serves it
    if let Some(f) = opt_faults(args)? {
        cfg.faults = f;
    }
    // per-request options: an explicit --t-drift stamps each request with
    // that device age (winning over the serving clock, which it also
    // seeds for consistent metrics); --adc-bits stamps the quantization
    // bitwidth. Both absent = default options = pre-options behavior.
    let req_opts = InferOpts {
        t_drift: args.opt("t-drift").map(|v| v.parse().expect("float --t-drift")),
        adc_bits: opt_adc_bits(args),
        adc_bits_floor: None,
        faults: None,
    };
    let store = ArtifactStore::open_default()?;
    let meta = store.meta(&vid)?;
    let task = if meta.model.contains("vww") { "vww" } else { "kws" };
    let ds = store.dataset(task)?;
    drop(store);

    println!("[serve] starting coordinator for {vid} ({bits}-bit) on the \
              `{}` backend, time scale {}x, device age {}s, request opts \
              {req_opts:?}",
             cfg.backend, cfg.time_scale, cfg.drift_time);

    // wire mode: front the coordinator with the TCP line protocol instead
    // of driving synthetic traffic in-process
    if let Some(listen) = args.opt("listen") {
        return serve_wire(args, cfg, listen, ds);
    }

    let coord = Coordinator::start(cfg)?;
    let feat = ds.feat_len();
    let mut correct = 0usize;
    for i in 0..n_requests {
        let s = i % ds.len();
        let resp =
            coord.infer_with(ds.x[s * feat..(s + 1) * feat].to_vec(), req_opts)?;
        if resp.pred == ds.y[s] {
            correct += 1;
        }
    }
    println!("[serve] {}", coord.metrics.summary());
    println!("[serve] streaming accuracy {:.2}% over {} requests",
             100.0 * correct as f64 / n_requests as f64, n_requests);
    coord.stop()?;
    Ok(())
}

/// `serve --models vidA,vidB`: one multi-model router serving every
/// listed variant (the first is the primary). Shares the single-model
/// knobs (`--bits`, `--backend`, `--time-scale`, ... apply to every
/// shard); without `--listen` a synthetic driver round-robins requests
/// across the models.
fn cmd_serve_multi(args: &Args) -> anyhow::Result<()> {
    use analognets::coordinator::{MultiCoordinator, ShardConfig};

    let spec = args.opt("models").unwrap_or_default();
    let vids: Vec<String> = spec
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    anyhow::ensure!(!vids.is_empty(), "--models needs at least one variant id");
    let bits = args.opt_usize("bits", 8) as u32;
    let n_requests = args.opt_usize("requests", 500);
    let queue_depth = args.opt_usize("queue-depth", 0);
    let req_opts = InferOpts {
        t_drift: args.opt("t-drift").map(|v| v.parse().expect("float --t-drift")),
        adc_bits: opt_adc_bits(args),
        adc_bits_floor: None,
        faults: None,
    };
    let store = ArtifactStore::open_default()?;
    let mut shards = Vec::with_capacity(vids.len());
    let mut datasets = Vec::with_capacity(vids.len());
    for vid in &vids {
        let mut cfg = ServeConfig::new(vid, bits);
        cfg.backend = BackendKind::from_args(args)?;
        cfg.time_scale = args.opt_f64("time-scale", 1e4);
        cfg.max_batch = args.opt_usize("max-batch", 0);
        cfg.threads = args.opt_usize("threads", 0);
        cfg.drift_time = args.opt_f64("t-drift", T_C_SECONDS);
        if let Some(f) = opt_faults(args)? {
            cfg.faults = f;
        }
        let meta = store.meta(vid)?;
        let task = if meta.model.contains("vww") { "vww" } else { "kws" };
        datasets.push(store.dataset(task)?);
        let mut sc = ShardConfig::new(vid, cfg);
        sc.queue_depth = queue_depth;
        shards.push(sc);
    }
    drop(store);

    println!("[serve] starting multi-model router ({bits}-bit): serving {} \
              (primary `{}`)",
             vids.join(", "), vids[0]);

    if let Some(listen) = args.opt("listen") {
        return serve_wire_multi(args, shards, listen, datasets);
    }

    let mc = MultiCoordinator::start(shards)?;
    let mut correct = 0usize;
    for i in 0..n_requests {
        let m = i % vids.len();
        let ds = &datasets[m];
        let feat = ds.feat_len();
        let s = (i / vids.len()) % ds.len();
        let resp = mc.infer(&vids[m],
                            ds.x[s * feat..(s + 1) * feat].to_vec(),
                            req_opts)?;
        if resp.pred == ds.y[s] {
            correct += 1;
        }
    }
    println!("[serve] {}", mc.metrics.summary());
    println!("[serve] streaming accuracy {:.2}% over {} mixed requests",
             100.0 * correct as f64 / n_requests.max(1) as f64, n_requests);
    mc.stop()?;
    Ok(())
}

/// `serve --models --listen`: the wire server fronting the router; one
/// dataset per model backs `"sample"` requests.
fn serve_wire_multi(args: &Args, shards: Vec<analognets::coordinator::ShardConfig>,
                    listen: &str, datasets: Vec<analognets::datasets::Dataset>)
                    -> anyhow::Result<()> {
    use analognets::coordinator::MultiCoordinator;
    use analognets::server::{WireConfig, WireServer};
    use std::sync::Arc;

    let wcfg = WireConfig {
        listen: listen.to_string(),
        max_conns: args.opt_usize("max-conns", 64),
        max_line_bytes: args.opt_usize("max-line-bytes", 256 * 1024),
    };
    let mc = Arc::new(MultiCoordinator::start(shards)?);
    let slots: Vec<_> =
        datasets.into_iter().map(|d| Some(Arc::new(d))).collect();
    let mut server = WireServer::start_multi(mc.clone(), slots, wcfg.clone())?;
    println!("[serve] wire protocol on {} (max_conns={}, max_line_bytes={})",
             server.local_addr(), wcfg.max_conns, wcfg.max_line_bytes);
    for info in mc.models() {
        println!("[serve] model `{}`: {} floats (`x`), queue depth {}",
                 info.model_id, info.feat_len, info.queue_depth);
    }
    println!("[serve] route with {{\"model\":\"{}\"}} (default: `{}`)",
             mc.models().last().unwrap().model_id, mc.primary().model_id);

    match args.opt("duration") {
        Some(_) => {
            let secs = args.opt_f64("duration", 0.0).max(0.0);
            std::thread::sleep(std::time::Duration::from_secs_f64(secs));
        }
        None => {
            println!("[serve] serving until stdin EOF (Ctrl-D)...");
            let mut sink = String::new();
            while std::io::stdin().read_line(&mut sink)? > 0 {
                sink.clear();
            }
        }
    }

    server.shutdown();
    drop(server);
    println!("[serve] {}", mc.metrics.summary());
    match Arc::try_unwrap(mc) {
        Ok(c) => c.stop()?,
        Err(c) => c.request_stop(),
    }
    Ok(())
}

/// `serve --listen`: run the wire-protocol server until `--duration`
/// elapses or stdin reaches EOF, then shut down gracefully (drain the
/// connections, stop the coordinator, print the final metrics).
fn serve_wire(args: &Args, cfg: ServeConfig, listen: &str,
              ds: analognets::datasets::Dataset) -> anyhow::Result<()> {
    use analognets::server::{WireConfig, WireServer};
    use std::sync::Arc;

    let wcfg = WireConfig {
        listen: listen.to_string(),
        max_conns: args.opt_usize("max-conns", 64),
        max_line_bytes: args.opt_usize("max-line-bytes", 256 * 1024),
    };
    let coord = Arc::new(Coordinator::start(cfg)?);
    let feat = coord.feat_len;
    let mut server =
        WireServer::start(coord.clone(), Some(Arc::new(ds)), wcfg.clone())?;
    println!("[serve] wire protocol on {} (max_conns={}, max_line_bytes={})",
             server.local_addr(), wcfg.max_conns, wcfg.max_line_bytes);
    println!("[serve] try: echo '{{\"id\":\"probe\",\"sample\":0}}' | nc {} {}",
             server.local_addr().ip(), server.local_addr().port());
    println!("[serve] request tensors are {feat} floats (`x`)");

    match args.opt("duration") {
        Some(_) => {
            let secs = args.opt_f64("duration", 0.0).max(0.0);
            std::thread::sleep(std::time::Duration::from_secs_f64(secs));
        }
        None => {
            println!("[serve] serving until stdin EOF (Ctrl-D)...");
            let mut sink = String::new();
            while std::io::stdin().read_line(&mut sink)? > 0 {
                sink.clear();
            }
        }
    }

    server.shutdown();
    drop(server);
    println!("[serve] {}", coord.metrics.summary());
    match Arc::try_unwrap(coord) {
        Ok(c) => c.stop()?,
        Err(c) => c.request_stop(),
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> anyhow::Result<()> {
    let vid = default_vid(args);
    let store = ArtifactStore::open_default()?;
    let meta = store.meta(&vid)?;
    let bits =
        args.opt_usize("bits", meta.trained_adc_bits.unwrap_or(8) as usize) as u32;
    let opts = EvalOpts {
        bits,
        runs: args.opt_usize("runs", 5),
        max_samples: args.opt_usize("samples", 256),
        backend: BackendKind::from_args(args)?,
        t_drift: args.opt("t-drift")
            .map(|v| v.parse().expect("float --t-drift")),
        adc_bits: opt_adc_bits(args),
        faults: opt_faults(args)?.unwrap_or_else(FaultSpec::none),
        ..Default::default()
    };
    let times = opts.sweep_times();
    let labels: Vec<String> = match opts.t_drift {
        Some(t) => vec![format!("{t}s")],
        None => FIG7_TIMES.iter().map(|(l, _)| l.to_string()).collect(),
    };
    println!("[eval] {vid} at {bits}-bit on `{}`, {} runs x {} samples \
              (fp ref {:.2}%)",
             opts.backend, opts.runs, opts.max_samples,
             100.0 * meta.fp_test_acc);
    if let Some(b) = opts.adc_bits {
        println!("[eval] per-request ADC override: quantizing at {b} bits");
    }
    if !opts.faults.is_none() {
        println!("[eval] device-variability scenario: {:?}", opts.faults);
    }

    // tile-geometry ablation: a custom array geometry changes which
    // K-slices get independently ADC-quantized, so it only exists on the
    // tile-faithful engine — built explicitly, run via drift_accuracy_on
    let custom_geom = args.opt("rows").is_some() || args.opt("cols").is_some()
        || args.opt("mux").is_some();
    let accs = if custom_geom {
        anyhow::ensure!(
            opts.backend == BackendKind::AnalogCim,
            "--rows/--cols/--mux select a crossbar tile geometry, which \
             only the `analog` backend executes (pass --backend analog)"
        );
        let geom = ArrayGeom::new(args.opt_usize("rows", 1024),
                                  args.opt_usize("cols", 512),
                                  args.opt_usize("mux", 4))?;
        let be = AnalogCimBackend::with_geom(meta.clone(), bits, geom,
                                             auto_threads(0));
        println!("[eval] tile geometry {}x{} mux{} -> {} crossbar tiles",
                 geom.rows, geom.cols, geom.adc_mux, be.tiles_total());
        drift_accuracy_on(&be, &store, &vid, &times, &opts)?
    } else {
        drift_accuracy(&store, &vid, &times, &opts)?
    };

    let mut t = Table::new(&format!("drift accuracy: {vid}"),
                           &["time", "acc mean %", "acc std %"]);
    for (label, a) in labels.iter().zip(accs.iter()) {
        let (m, s) = stats::acc_summary(a);
        t.row(&[label.to_string(), format!("{m:.2}"), format!("{s:.2}")]);
    }
    t.print();
    Ok(())
}

fn cmd_map(args: &Args) -> anyhow::Result<()> {
    let vid = default_vid(args);
    let store = ArtifactStore::open_default()?;
    let meta = store.meta(&vid)?;
    let geom = ArrayGeom::new(args.opt_usize("rows", 1024),
                              args.opt_usize("cols", 512),
                              args.opt_usize("mux", 4))?;
    if args.flag("split") {
        let s = analognets::mapping::split_map_model(&meta, geom);
        println!("split mapping on {}x{} tiles: {} tiles allocated, \
                  effective utilization {:.1}%",
                 geom.rows, geom.cols, s.alloc_tiles(),
                 100.0 * s.effective_utilization());
        for l in &s.layers {
            println!("  {:<8} {}x{}  tiles {}/{}  row-splits {}",
                     l.name, l.rows, l.cols, l.alloc_tiles, l.grid_tiles,
                     l.row_splits);
        }
    } else {
        let m = map_model(&meta, geom)?;
        print!("{}", layout::ascii_map(&m, 64, 32));
    }
    Ok(())
}

fn cmd_report(args: &Args) -> anyhow::Result<()> {
    let vid = default_vid(args);
    let bits = args.opt_usize("bits", 8) as u32;
    let store = ArtifactStore::open_default()?;
    let meta = store.meta(&vid)?;
    let em = EnergyModel::default();
    let mapping = map_model(&meta, ArrayGeom::AON)?;
    let p = model_perf(&mapping, bits, &em);

    let mut t = Table::new(&format!("AON-CiM report: {vid} @ {bits}-bit"),
                           &["metric", "value"]);
    let (pk_t, pk_w) = peak(ArrayGeom::AON, bits, &em);
    t.row(&["array".into(), "1024 x 512 (mux4)".into()]);
    t.row(&["peak TOPS".into(), format!("{pk_t:.2}")]);
    t.row(&["peak TOPS/W".into(), format!("{pk_w:.2}")]);
    t.row(&["params (effective)".into(), format!("{}", meta.param_count())]);
    t.row(&["ops/inference".into(), format!("{:.2}M", p.ops / 1e6)]);
    t.row(&["achieved TOPS".into(), format!("{:.3}", p.tops)]);
    t.row(&["achieved TOPS/W".into(), format!("{:.2}", p.tops_w)]);
    t.row(&["inf/sec".into(), format!("{:.0}", p.inf_per_sec)]);
    t.row(&["uJ/inf".into(), format!("{:.2}", p.uj_per_inf)]);
    t.row(&["array utilization".into(),
            format!("{:.1}%", 100.0 * mapping.allocated_utilization())]);
    t.print();
    Ok(())
}

fn cmd_selftest(args: &Args) -> anyhow::Result<()> {
    let store = ArtifactStore::open_default()?;
    println!("backends: native, analog{}",
             if BackendKind::Pjrt.available() { ", pjrt" } else { "" });
    println!("variants: {}", store.manifest.variants.len());
    for e in &store.manifest.variants {
        let meta = store.meta(&e.vid)?;
        let w = store.weights(&e.vid)?;
        anyhow::ensure!(w.len() == meta.layers.len(), "{}: weight count", e.vid);
        println!("  {:<24} {:>8} params  fp acc {:>6.2}%  hlo files {}",
                 e.vid, meta.param_count(), 100.0 * meta.fp_test_acc,
                 meta.hlo_keys().len());
    }
    // one end-to-end numeric check on the first variant
    if let Some(e) = store.manifest.variants.first() {
        let meta = store.meta(&e.vid)?;
        let bits = meta.trained_adc_bits.unwrap_or(8);
        let backend = BackendKind::from_args(args)?;
        let accs = drift_accuracy(
            &store, &e.vid, &[25.0],
            &EvalOpts { bits, runs: 1, max_samples: 64, backend,
                        ..Default::default() })?;
        println!("selftest eval {} @25s on `{backend}`: {:.2}%",
                 e.vid, 100.0 * accs[0][0]);
    }
    println!("selftest OK");
    Ok(())
}
