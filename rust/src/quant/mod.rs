//! DAC/ADC quantizer models (Rust mirror of `python/compile/quantizers.py`).
//!
//! Used by the native simulator for cross-validation against the exported
//! HLO graphs — the math must match the Python side bit-for-bit in intent
//! (symmetric uniform fake quantization, eq. 4).

/// The quantizer grid for a symmetric `bits`-bit converter over
/// `[-r_max, r_max]`: `(step, 1/step)` with `2^(bits-1)-1` levels per
/// side. The single source of the level formula — every quantization in
/// the crate (the native post-accumulation ADC, the AnalogCim per-tile
/// ADC, the DACs) must derive its grid here or the engines' bit-identity
/// guarantee silently breaks.
#[inline]
pub fn grid(r_max: f32, bits: u32) -> (f32, f32) {
    let levels = ((1u32 << (bits - 1)) - 1) as f32;
    let step = r_max / levels;
    (step, 1.0 / step)
}

/// Symmetric uniform fake quantization: clip to [-r, r], round to
/// `2^(bits-1)-1` levels per side, return the dequantized value.
#[inline]
pub fn fake_quant(x: f32, r_max: f32, bits: u32) -> f32 {
    let (step, _) = grid(r_max, bits);
    let xc = x.clamp(-r_max, r_max);
    (xc / step).round() * step
}

/// In-place fake quantization of a buffer.
pub fn fake_quant_slice(xs: &mut [f32], r_max: f32, bits: u32) {
    let (step, inv) = grid(r_max, bits);
    for x in xs {
        let xc = x.clamp(-r_max, r_max);
        *x = (xc * inv).round() * step;
    }
}

/// DAC bits = ADC bits + 1 (eq. 3).
pub fn dac_bits(adc_bits: u32) -> u32 {
    adc_bits + 1
}

/// Integer code for a value (hardware-side view; for tests/inspection).
pub fn code(x: f32, r_max: f32, bits: u32) -> i32 {
    let (step, _) = grid(r_max, bits);
    (x.clamp(-r_max, r_max) / step).round() as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_on_grid() {
        // grid points are fixed points of the quantizer
        let r = 2.0f32;
        let bits = 4;
        let step = r / 7.0;
        for i in -7..=7 {
            let v = i as f32 * step;
            assert!((fake_quant(v, r, bits) - v).abs() < 1e-6);
        }
    }

    #[test]
    fn clips_out_of_range() {
        assert_eq!(fake_quant(10.0, 1.0, 8), 1.0);
        assert_eq!(fake_quant(-10.0, 1.0, 8), -1.0);
    }

    #[test]
    fn error_bounded_by_half_step() {
        let r = 1.0f32;
        let bits = 6;
        let step = r / 31.0;
        let mut x = -1.0f32;
        while x < 1.0 {
            let q = fake_quant(x, r, bits);
            assert!((q - x).abs() <= step / 2.0 + 1e-6);
            x += 0.001;
        }
    }

    #[test]
    fn slice_matches_scalar() {
        let xs: Vec<f32> = (-20..20).map(|i| i as f32 * 0.13).collect();
        let mut ys = xs.clone();
        fake_quant_slice(&mut ys, 1.7, 5);
        for (x, y) in xs.iter().zip(ys.iter()) {
            assert_eq!(fake_quant(*x, 1.7, 5), *y);
        }
    }

    #[test]
    fn codes_cover_range() {
        assert_eq!(code(1.0, 1.0, 8), 127);
        assert_eq!(code(-1.0, 1.0, 8), -127);
        assert_eq!(code(0.0, 1.0, 8), 0);
    }
}
