//! Per-layer and whole-model performance on the AON-CiM accelerator
//! (Figure 8 scatter data, Table 2 model rows, Table 3 inference rates).

use crate::crossbar::ArrayGeom;
use crate::mapping::{ModelMapping, SplitMapping};
use crate::timing::{EnergyModel, DIGITAL_LANES, T_DIGITAL_NS};

#[derive(Clone, Debug)]
pub struct LayerPerf {
    pub name: String,
    pub weights: usize,
    pub ops: f64,
    pub latency_ns: f64,
    pub energy_nj: f64,
    pub tops: f64,
    pub tops_w: f64,
}

#[derive(Clone, Debug)]
pub struct ModelPerf {
    pub layers: Vec<LayerPerf>,
    pub latency_ns: f64,
    pub energy_nj: f64,
    pub ops: f64,
    pub tops: f64,
    pub tops_w: f64,
    pub inf_per_sec: f64,
    pub uj_per_inf: f64,
}

/// Digital post-processing time for `words` output words (pipelined with
/// the array; only binds when it exceeds the analog time).
fn digital_ns(words: usize) -> f64 {
    (words as f64 / DIGITAL_LANES as f64) * T_DIGITAL_NS
}

/// Performance of one mapped layer executing all its MVMs (layer-serial).
pub fn layer_perf(geom: ArrayGeom, rows: usize, cols: usize, mvms: usize,
                  bits: u32, em: &EnergyModel) -> (f64, f64, f64) {
    let phases = geom.adc_phases(cols);
    let analog_ns = em.mvm_latency_ns(phases, bits);
    // activation processing / SRAM / IM2COL are pipelined; the array stalls
    // only if the digital side is slower than one MVM
    let per_mvm_ns = analog_ns.max(digital_ns(cols));
    let e_nj = em.mvm_energy_nj(geom, rows, cols, phases, bits);
    let ops = 2.0 * (rows * cols) as f64 * mvms as f64;
    (mvms as f64 * per_mvm_ns, mvms as f64 * e_nj, ops)
}

/// Whole-model performance from a whole-array mapping (Figure 8, Table 2).
pub fn model_perf(m: &ModelMapping, bits: u32, em: &EnergyModel) -> ModelPerf {
    let mut layers = Vec::new();
    let (mut lat, mut en, mut ops) = (0f64, 0f64, 0f64);
    for l in &m.layers {
        let (l_ns, l_nj, l_ops) = layer_perf(m.geom, l.rows, l.cols, l.mvms, bits, em);
        layers.push(LayerPerf {
            name: l.name.clone(),
            weights: l.cells(),
            ops: l_ops,
            latency_ns: l_ns,
            energy_nj: l_nj,
            tops: l_ops / l_ns / 1000.0,
            tops_w: l_ops / l_nj / 1000.0,
        });
        lat += l_ns;
        en += l_nj;
        ops += l_ops;
    }
    ModelPerf {
        layers,
        latency_ns: lat,
        energy_nj: en,
        ops,
        tops: ops / lat / 1000.0,
        tops_w: ops / en / 1000.0,
        inf_per_sec: 1e9 / lat,
        uj_per_inf: en * 1e-3,
    }
}

/// The host-simulator GEMM shape `(M, K, N)` one layer multiplies at
/// `batch` samples under the layer-serial schedule: `M` im2col rows across
/// the whole batch, `K` the crossbar-row inner dimension, `N` the output
/// channels. This is what the native engine actually executes (the
/// accelerator-side analog timing above counts MVMs instead); the serving
/// bench uses it to report per-layer GEMM GFLOP/s.
pub fn layer_gemm_dims(lm: &crate::nn::LayerMeta, batch: usize)
                       -> (usize, usize, usize) {
    let m = match lm.kind {
        crate::nn::LayerKind::Dense => batch,
        _ => batch * lm.out_h * lm.out_w,
    };
    (m, lm.k_gemm, lm.graph_weight_shape[1])
}

/// Inference rate under split-GEMM mapping (Table 3): every allocated tile
/// of a layer operates sequentially per output pixel, and row-split partial
/// sums are accumulated digitally.
pub fn split_inference_rate(s: &SplitMapping, bits: u32, em: &EnergyModel) -> f64 {
    let mut lat = 0f64;
    for l in &s.layers {
        let cols_per_tile = l.cols.min(s.geom.cols);
        let phases = s.geom.adc_phases(cols_per_tile);
        let per_tile_ns = em
            .mvm_latency_ns(phases, bits)
            .max(digital_ns(cols_per_tile));
        lat += l.mvms as f64 * l.alloc_tiles as f64 * per_tile_ns;
    }
    1e9 / lat
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crossbar::ArrayGeom;
    use crate::mapping::tiler::MappedLayer;
    use crate::nn::LayerKind;

    fn mapping(rows: usize, cols: usize, mvms: usize) -> ModelMapping {
        ModelMapping {
            geom: ArrayGeom::AON,
            layers: vec![MappedLayer {
                name: "l".into(),
                kind: LayerKind::Conv3x3,
                row0: 0,
                col0: 0,
                rows,
                cols,
                effective: rows * cols,
                mvms,
            }],
        }
    }

    #[test]
    fn bigger_layers_higher_tops_w() {
        let em = EnergyModel::default();
        let small = model_perf(&mapping(72, 16, 100), 8, &em);
        let big = model_perf(&mapping(720, 160, 100), 8, &em);
        assert!(big.tops_w > small.tops_w);
        assert!(big.tops > small.tops);
    }

    #[test]
    fn lower_bits_faster(){
        let em = EnergyModel::default();
        let p8 = model_perf(&mapping(512, 128, 50), 8, &em);
        let p4 = model_perf(&mapping(512, 128, 50), 4, &em);
        assert!(p4.inf_per_sec > 5.0 * p8.inf_per_sec);
        assert!(p4.tops_w > p8.tops_w);
    }

    #[test]
    fn digital_never_stalls_8bit() {
        // 512 cols at 8 bits: digital (512/16)*1.25 = 40ns < 130ns
        assert!(digital_ns(512) < crate::timing::t_cim_ns(8));
        // and exactly meets the worst case at 4 bits with <=128 cols
        assert!(digital_ns(128) <= crate::timing::t_cim_ns(4));
    }

    #[test]
    fn gemm_dims_scale_with_batch() {
        let lm = crate::nn::LayerMeta {
            name: "c0".into(),
            kind: LayerKind::Conv3x3,
            in_ch: 4,
            out_ch: 16,
            stride: (1, 1),
            relu: true,
            analog: true,
            in_h: 6,
            in_w: 6,
            out_h: 6,
            out_w: 6,
            k_gemm: 36,
            weight_shape: vec![36, 16],
            graph_weight_shape: vec![36, 16],
            w_scale: 1.0,
            w_max: 1.0,
            r_dac: 8.0,
            r_adc: 8.0,
            dig_scale: vec![1.0; 16],
            dig_bias: vec![0.0; 16],
        };
        assert_eq!(layer_gemm_dims(&lm, 1), (36, 36, 16));
        assert_eq!(layer_gemm_dims(&lm, 8), (8 * 36, 36, 16));
        let mut dense = lm.clone();
        dense.kind = LayerKind::Dense;
        assert_eq!(layer_gemm_dims(&dense, 8).0, 8);
    }

    #[test]
    fn whole_model_latency_is_sum() {
        let em = EnergyModel::default();
        let p = model_perf(&mapping(100, 50, 10), 8, &em);
        let l = &p.layers[0];
        assert!((p.latency_ns - l.latency_ns).abs() < 1e-9);
        assert!((p.uj_per_inf - p.energy_nj * 1e-3).abs() < 1e-12);
    }
}
