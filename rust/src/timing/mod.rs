//! AON-CiM cycle/energy model (Section 5, Table 2, Figure 8).
//!
//! Calibration (DESIGN.md section 5): the paper's three Table-2 peak points
//! are exactly consistent with a linear energy model in the PWM cycle time,
//!     E_fullMVM(b) = ALPHA * T_cim(b) + BETA,
//! with ALPHA covering pulse-duration-proportional energy (DAC drivers +
//! array current) and BETA the per-conversion ADC + per-word digital energy.
//! Per-layer numbers scale these components by the rows/columns the layer
//! actually uses (unused DACs/ADCs are clock-gated, Section 5.2).
//!
//! The full derivation of the fit (units, the mux-rotation "always pay the
//! full phases" assumption, the β split rationale, and how close the model
//! rows land to Table 1/2) lives in `docs/ENERGY_MODEL.md`.
//!
//! # Example: the Table-2 8-bit peak row
//!
//! ```
//! use analognets::crossbar::ArrayGeom;
//! use analognets::timing::{peak, EnergyModel};
//!
//! // Table 2, "peak performance" at 8 bits: 2 TOPS, 13.55 TOPS/W
//! let (tops, tops_w) = peak(ArrayGeom::AON, 8, &EnergyModel::default());
//! assert!((tops - 2.02).abs() < 0.03);
//! assert!((tops_w - 13.55).abs() / 13.55 < 0.02);
//! ```

pub mod perf;
pub mod schedule;

pub use perf::{layer_gemm_dims, layer_perf, model_perf, LayerPerf, ModelPerf};
pub use schedule::{LaunchSchedule, ScheduleModel};

use crate::crossbar::ArrayGeom;

/// PWM DAC cycle time per activation precision, ns (Table 2).
pub fn t_cim_ns(bits: u32) -> f64 {
    match bits {
        8 => 130.0,
        6 => 34.0,
        4 => 10.0,
        // PWM latency is exponential in bitwidth: T = T0 * 2^b (fit through
        // the table points for other bitwidths)
        b => 130.0 * (2f64.powi(b as i32 - 8)),
    }
}

/// Digital pipeline clock period, ns (800 MHz).
pub const T_DIGITAL_NS: f64 = 1.25;
/// Digital activation-processing lanes (sized for the worst-case 4-bit
/// throughput of 128 words / 10 ns at 800 MHz).
pub const DIGITAL_LANES: usize = 16;

/// Energy model constants, fit to Table 2 (see module docs).
/// Units: nanojoules and nanoseconds.
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    /// pulse-proportional energy at full array use, nJ per ns of total
    /// pulse time (the mux rotation is a static schedule: every MVM pays
    /// the full `adc_mux` phases of PWM pulsing regardless of columns used)
    pub alpha_nj_per_ns: f64,
    /// fraction of alpha that is DAC drive (row-proportional); the rest is
    /// array current (rows*cols-proportional). DACs are cheap relative to
    /// the array + ADCs (Section 5.2: "ADCs consume more energy than DACs")
    pub dac_fraction: f64,
    /// energy per ADC conversion, nJ
    pub adc_nj: f64,
    /// fixed per-MVM overhead (controller, SRAM, clock tree — not gated)
    pub fixed_nj: f64,
    /// digital post-processing energy per output word, nJ
    pub dig_nj: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        // alpha/beta from the linear fit of full-MVM energy against *total*
        // pulse time (4 mux phases x T_cim): (520ns, 77.38nJ), (136ns,
        // 23.02nJ), (40ns, 9.33nJ).  beta = 3.66nJ splits into per-
        // conversion ADC energy (55%), fixed per-MVM overhead (40%) and
        // per-word digital (5%) — chosen so whole-model achieved TOPS/W
        // lands at the paper's achieved/peak ratio (Table 2 model rows)
        // while preserving the Figure-8 tall-beats-wide ordering.
        let alpha = 0.14177;
        let beta = 3.6629;
        EnergyModel {
            alpha_nj_per_ns: alpha,
            dac_fraction: 0.02,
            adc_nj: beta * 0.55 / ArrayGeom::AON.cols as f64,
            fixed_nj: beta * 0.40,
            dig_nj: beta * 0.05 / ArrayGeom::AON.cols as f64,
        }
    }
}

impl EnergyModel {
    /// Energy of ONE array MVM using `rows_used` x `cols_used` of `geom`,
    /// at `bits` activation precision.
    ///
    /// Pulse energy always pays the full mux rotation (`geom.adc_mux`
    /// phases — static schedule); latency may terminate early, see
    /// `mvm_latency_ns`.  The `_phases` argument is kept for the latency
    /// path's call-site symmetry.
    pub fn mvm_energy_nj(&self, geom: ArrayGeom, rows_used: usize,
                         cols_used: usize, _phases: usize, bits: u32) -> f64 {
        let t = t_cim_ns(bits) * geom.adc_mux as f64;
        let row_frac = rows_used as f64 / geom.rows as f64;
        let cell_frac =
            (rows_used * cols_used) as f64 / geom.cells() as f64;
        // pulse-proportional: DAC drive scales with active rows; array
        // current with active cells. Scaled relative to the AON geometry so
        // smaller crossbars (Table 3) keep per-cell energy constant.
        let scale = geom.cells() as f64 / ArrayGeom::AON.cells() as f64;
        let pulse = self.alpha_nj_per_ns
            * t
            * scale
            * (self.dac_fraction * row_frac
                + (1.0 - self.dac_fraction) * cell_frac);
        let adc = self.adc_nj * cols_used as f64;
        let dig = self.dig_nj * cols_used as f64;
        pulse + adc + dig + self.fixed_nj * scale
    }

    /// Latency of one MVM, ns (PWM pulse repeated per mux phase).
    pub fn mvm_latency_ns(&self, phases: usize, bits: u32) -> f64 {
        t_cim_ns(bits) * phases as f64
    }
}

/// Peak numbers at 100% utilization (Table 2 "peak performance" row).
pub fn peak(geom: ArrayGeom, bits: u32, em: &EnergyModel) -> (f64, f64) {
    let phases = geom.adc_phases(geom.cols);
    let ops = 2.0 * geom.cells() as f64;
    let t_ns = em.mvm_latency_ns(phases, bits);
    let e_nj = em.mvm_energy_nj(geom, geom.rows, geom.cols, phases, bits);
    let tops = ops / t_ns / 1000.0;
    let tops_w = ops / e_nj / 1000.0;
    (tops, tops_w)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn t_cim_table2() {
        assert_eq!(t_cim_ns(8), 130.0);
        assert_eq!(t_cim_ns(6), 34.0);
        assert_eq!(t_cim_ns(4), 10.0);
    }

    #[test]
    fn full_mvm_energy_hits_the_three_fit_points() {
        // The Table-2 peak TOPS/W rows pin the full-MVM energy at each
        // bitwidth: E = 2*cells / (TOPS/W * 1000). The linear fit
        // E = alpha*T + beta reproduces all three within 0.5%.
        let em = EnergyModel::default();
        let g = ArrayGeom::AON;
        let ops = 2.0 * g.cells() as f64;
        for (bits, tops_w) in [(8u32, 13.55), (6, 45.55), (4, 112.44)] {
            let want_nj = ops / (tops_w * 1000.0);
            let got_nj =
                em.mvm_energy_nj(g, g.rows, g.cols, g.adc_phases(g.cols), bits);
            assert!(
                (got_nj - want_nj).abs() / want_nj < 0.005,
                "{bits}b: {got_nj:.3} nJ vs Table-2-implied {want_nj:.3} nJ"
            );
        }
    }

    #[test]
    fn peak_matches_table2() {
        // paper: 2 / 7.71 / 26.21 TOPS and 13.55 / 45.55 / 112.44 TOPS/W
        let em = EnergyModel::default();
        let (t8, w8) = peak(ArrayGeom::AON, 8, &em);
        let (t6, w6) = peak(ArrayGeom::AON, 6, &em);
        let (t4, w4) = peak(ArrayGeom::AON, 4, &em);
        assert!((t8 - 2.02).abs() < 0.03, "t8={t8}");
        assert!((t6 - 7.71).abs() < 0.1, "t6={t6}");
        assert!((t4 - 26.21).abs() < 0.3, "t4={t4}");
        assert!((w8 - 13.55).abs() / 13.55 < 0.02, "w8={w8}");
        assert!((w6 - 45.55).abs() / 45.55 < 0.02, "w6={w6}");
        assert!((w4 - 112.44).abs() / 112.44 < 0.02, "w4={w4}");
    }

    #[test]
    fn tall_layers_more_efficient() {
        // same cell count, taller aspect => fewer ADC conversions per MAC
        // => better energy per op (Figure 8's second trend)
        let em = EnergyModel::default();
        let g = ArrayGeom::AON;
        let e_tall = em.mvm_energy_nj(g, 512, 64, g.adc_phases(64), 8);
        let e_wide = em.mvm_energy_nj(g, 64, 512, g.adc_phases(512), 8);
        // identical MACs per MVM => direct energy comparison
        assert!(e_tall < e_wide, "{e_tall} !< {e_wide}");
    }

    #[test]
    fn achieved_below_peak() {
        // per-MVM efficiency of any partial layer stays below the full-array
        // peak (the fixed overhead + static mux schedule see to it)
        let em = EnergyModel::default();
        let g = ArrayGeom::AON;
        let (_, peak_w) = peak(g, 8, &em);
        for (r, c) in [(9, 64), (576, 64), (792, 112), (1008, 128)] {
            let e = em.mvm_energy_nj(g, r, c, g.adc_phases(c), 8);
            let eff = 2.0 * (r * c) as f64 / e / 1000.0;
            assert!(eff <= peak_w * 1.001, "{r}x{c}: {eff} > {peak_w}");
        }
    }

    #[test]
    fn energy_positive_and_monotone_in_cols() {
        let em = EnergyModel::default();
        let g = ArrayGeom::AON;
        let e1 = em.mvm_energy_nj(g, 256, 64, 1, 8);
        let e2 = em.mvm_energy_nj(g, 256, 128, 1, 8);
        assert!(e2 > e1 && e1 > 0.0);
    }
}
