//! Launch-schedule estimator: modeled latency/energy for the batched,
//! layer-serial launches the coordinator actually runs.
//!
//! [`model_perf`](crate::timing::model_perf) prices one inference of a
//! mapped model; serving executes *launches* — `batch` samples pushed
//! through every layer in sequence, with occasional conductance-refresh
//! reads and full array reprogramming in between. [`ScheduleModel`] prices
//! exactly that unit of work so the coordinator can (a) account modeled
//! energy per drain and (b) run an SLO policy: pick the largest batch (and,
//! when a request permits a bitwidth range, the highest `adc_bits`) whose
//! modeled launch latency still fits `ServeConfig::latency_slo_us`.
//!
//! Batch amortization falls out of the layer-serial schedule: launch
//! latency and array energy are linear in `batch`, while refresh and
//! reprogram costs are charged per *event*, so their share of µJ/inference
//! shrinks as traffic and batch size grow.
//!
//! # Example: one Table-2 model row
//!
//! Reproduce the modeled AnalogNet-KWS 8-bit row (paper: 0.6 TOPS,
//! 8.58 TOPS/W — the model lands within the committed tolerance, see
//! `docs/ENERGY_MODEL.md` for the calibration story):
//!
//! ```
//! use analognets::crossbar::ArrayGeom;
//! use analognets::nn::analognets::analognet_kws;
//! use analognets::timing::schedule::ScheduleModel;
//!
//! let sched = ScheduleModel::new(&analognet_kws(), ArrayGeom::AON).unwrap();
//! let one = sched.launch(1, 8);
//! // 696 MVMs x 130 ns = 90.48 us per inference
//! assert!((one.latency_ns - 90_480.0).abs() < 1e-6);
//! // ~0.59 modeled TOPS vs the paper's 0.6
//! let tops = one.ops / one.latency_ns / 1000.0;
//! assert!((tops - 0.6).abs() / 0.6 < 0.05);
//! ```

use crate::crossbar::ArrayGeom;
use crate::mapping::{map_model, ModelMapping};
use crate::nn::ModelMeta;
use crate::timing::{layer_perf, EnergyModel};

/// Modeled PCM program-and-verify energy per programmed cell, nJ.
///
/// Order-of-magnitude constant: iterative program-and-verify converges in
/// ~8 pulses of ~10 pJ apiece (SET/RESET partial pulses plus verify
/// reads). Reprogramming the full KWS mapping (~300k cells) then costs
/// ~30 µJ — a few inferences' worth, which is why the coordinator
/// reprograms on a cadence instead of per request.
pub const REPROGRAM_NJ_PER_CELL: f64 = 0.1;

/// Modeled cost of one batched, layer-serial launch.
///
/// All three totals are linear in `batch`: the schedule runs every layer's
/// `batch x mvms` MVMs back to back, so there is no cross-sample overlap
/// to model.
#[derive(Clone, Copy, Debug)]
pub struct LaunchSchedule {
    /// samples in the launch (including any padding the batcher added)
    pub batch: usize,
    /// ADC/activation precision the launch runs at
    pub adc_bits: u32,
    /// modeled end-to-end launch latency, ns
    pub latency_ns: f64,
    /// modeled array + ADC + digital energy, nJ
    pub energy_nj: f64,
    /// MAC ops performed (2 ops per MAC), across the whole batch
    pub ops: f64,
}

impl LaunchSchedule {
    /// Modeled energy per sample in this launch, nJ.
    pub fn nj_per_inf(&self) -> f64 {
        if self.batch == 0 {
            0.0
        } else {
            self.energy_nj / self.batch as f64
        }
    }

    /// Modeled compute efficiency of the launch, TOPS/W.
    pub fn tops_w(&self) -> f64 {
        if self.energy_nj > 0.0 {
            self.ops / self.energy_nj / 1000.0
        } else {
            0.0
        }
    }
}

/// Prices the coordinator's launches for one mapped model.
///
/// Built once per serving session from the backend's [`ModelMeta`] and the
/// array geometry its engine simulates (see
/// `InferenceBackend::schedule_model`), then consulted per drain. Native
/// and tile-grid engines report the same schedule for the same geometry:
/// the estimator depends only on the mapping, never on host GEMM speed.
#[derive(Clone, Debug)]
pub struct ScheduleModel {
    model: String,
    mapping: ModelMapping,
    em: EnergyModel,
}

impl ScheduleModel {
    /// Map `meta` onto `geom` (shelf-packing tiler) and price launches
    /// with the default Table-2-calibrated [`EnergyModel`].
    ///
    /// Fails only if the model does not fit the array whole.
    pub fn new(meta: &ModelMeta, geom: ArrayGeom) -> anyhow::Result<Self> {
        Ok(Self::from_mapping(
            meta.model.clone(),
            map_model(meta, geom)?,
            EnergyModel::default(),
        ))
    }

    /// Wrap an existing mapping with an explicit energy calibration.
    pub fn from_mapping(model: String, mapping: ModelMapping, em: EnergyModel) -> Self {
        ScheduleModel { model, mapping, em }
    }

    /// Model name (used as the metrics breakdown key).
    pub fn model(&self) -> &str {
        &self.model
    }

    /// Array geometry the schedule is priced against.
    pub fn geom(&self) -> ArrayGeom {
        self.mapping.geom
    }

    /// Per-inference (latency ns, energy nJ, ops) at `adc_bits`.
    fn per_inference(&self, adc_bits: u32) -> (f64, f64, f64) {
        let (mut ns, mut nj, mut ops) = (0f64, 0f64, 0f64);
        for l in &self.mapping.layers {
            let (l_ns, l_nj, l_ops) =
                layer_perf(self.mapping.geom, l.rows, l.cols, l.mvms, adc_bits, &self.em);
            ns += l_ns;
            nj += l_nj;
            ops += l_ops;
        }
        (ns, nj, ops)
    }

    /// Price one launch of `batch` samples at `adc_bits`.
    pub fn launch(&self, batch: usize, adc_bits: u32) -> LaunchSchedule {
        let (ns, nj, ops) = self.per_inference(adc_bits);
        let b = batch as f64;
        LaunchSchedule {
            batch,
            adc_bits,
            latency_ns: ns * b,
            energy_nj: nj * b,
            ops: ops * b,
        }
    }

    /// Modeled cost of one cadence conductance refresh, nJ: the refresh
    /// replays one calibration sample through every mapped layer at 8 bits
    /// (a full-precision read of the drifted conductances) to rescale the
    /// global drift compensation.
    pub fn refresh_nj(&self) -> f64 {
        self.per_inference(8).1
    }

    /// Modeled cost of fully reprogramming the mapping, nJ
    /// (program-and-verify over every allocated cell).
    pub fn reprogram_nj(&self) -> f64 {
        let cells: usize = self.mapping.layers.iter().map(|l| l.cells()).sum();
        cells as f64 * REPROGRAM_NJ_PER_CELL
    }

    /// Largest batch whose modeled launch latency fits `slo_us`, clamped
    /// to `1..=cap`.
    ///
    /// Launch latency is linear in batch, so this is
    /// `floor(slo / latency(1))`. Returns 1 even when a single inference
    /// misses the SLO — the coordinator must still serve; the policy only
    /// stops it from making things worse by batching.
    pub fn max_batch_within(&self, slo_us: f64, adc_bits: u32, cap: usize) -> usize {
        let lat1_ns = self.per_inference(adc_bits).0;
        if lat1_ns <= 0.0 || !lat1_ns.is_finite() || !slo_us.is_finite() {
            return cap.max(1);
        }
        let fit = (slo_us * 1000.0 / lat1_ns).floor() as usize;
        fit.clamp(1, cap.max(1))
    }

    /// SLO operating point over a permitted bitwidth range: the highest
    /// `adc_bits` in `floor_bits..=ceil_bits` whose *single-inference*
    /// modeled latency fits `slo_us` (accuracy-first), then the largest
    /// batch at that bitwidth ([`Self::max_batch_within`]). Falls back to
    /// `(floor_bits, 1)` when even one inference at the floor misses the
    /// SLO. Deterministic for fixed shapes.
    pub fn choose(
        &self,
        slo_us: f64,
        floor_bits: u32,
        ceil_bits: u32,
        cap: usize,
    ) -> (u32, usize) {
        let lo = floor_bits.min(ceil_bits);
        let slo_ns = slo_us * 1000.0;
        for bits in (lo..=ceil_bits).rev() {
            if self.per_inference(bits).0 <= slo_ns {
                return (bits, self.max_batch_within(slo_us, bits, cap));
            }
        }
        (lo, 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::analognets::{analognet_kws, analognet_vww};

    /// Committed tolerance for the paper's Table-1/2 model-row anchors —
    /// keep in sync with `energy_tol_rel` in `ci/bench_baseline.json` and
    /// the deviation table in `docs/ENERGY_MODEL.md`. The linear-fit
    /// calibration pins the Table-2 *peak* rows within 2%; whole-model
    /// rows land within ~55% because the bits-independent per-MVM
    /// overhead (fixed_nj) dominates small-MVM layers at 4 bits and the
    /// paper's own model rows are not mutually consistent with its
    /// µJ/inference and inferences/s columns (see docs/ENERGY_MODEL.md).
    const ANCHOR_TOL: f64 = 0.60;

    fn kws() -> ScheduleModel {
        ScheduleModel::new(&analognet_kws(), ArrayGeom::AON).unwrap()
    }
    fn vww() -> ScheduleModel {
        ScheduleModel::new(&analognet_vww(), ArrayGeom::AON).unwrap()
    }

    #[test]
    fn paper_tops_w_anchors_within_tolerance() {
        // Table 1 / Table 2 model rows: (model, bits, paper TOPS/W)
        let anchors = [
            ("kws", 8u32, 8.58),
            ("kws", 4u32, 57.39),
            ("vww", 8u32, 4.37),
            ("vww", 4u32, 25.69),
        ];
        for (m, bits, paper) in anchors {
            let sched = if m == "kws" { kws() } else { vww() };
            let l = sched.launch(1, bits);
            let dev = (l.tops_w() - paper).abs() / paper;
            assert!(
                dev <= ANCHOR_TOL,
                "{m}@{bits}b: modeled {:.2} TOPS/W vs paper {paper} (dev {dev:.2})",
                l.tops_w()
            );
        }
    }

    #[test]
    fn paper_tops_anchors_are_tight() {
        // Modeled TOPS (pure latency) tracks the paper's KWS rows much
        // closer than TOPS/W: 0.6 / 2.29 / 7.8 at 8/6/4 bits.
        let sched = kws();
        for (bits, paper) in [(8u32, 0.6), (6, 2.29), (4, 7.8)] {
            let l = sched.launch(1, bits);
            let tops = l.ops / l.latency_ns / 1000.0;
            assert!(
                (tops - paper).abs() / paper < 0.05,
                "kws@{bits}b: modeled {tops:.3} TOPS vs paper {paper}"
            );
        }
    }

    #[test]
    fn launch_is_linear_in_batch() {
        let sched = kws();
        let one = sched.launch(1, 8);
        let eight = sched.launch(8, 8);
        assert!((eight.latency_ns - 8.0 * one.latency_ns).abs() < 1e-6);
        assert!((eight.energy_nj - 8.0 * one.energy_nj).abs() < 1e-6);
        assert!((eight.ops - 8.0 * one.ops).abs() < 1e-3);
        assert!((eight.nj_per_inf() - one.nj_per_inf()).abs() < 1e-9);
    }

    #[test]
    fn kws_launch_latency_is_exact() {
        // 696 MVMs, every layer <=128 cols => 1 mux phase => 130 ns/MVM
        let one = kws().launch(1, 8);
        assert!((one.latency_ns - 696.0 * 130.0).abs() < 1e-9);
    }

    #[test]
    fn slo_tight_shrinks_batch_loose_grows_it() {
        let sched = kws();
        // single 8-bit KWS inference models at 90.48 us
        let tight = sched.max_batch_within(200.0, 8, 64);
        let loose = sched.max_batch_within(5_000.0, 8, 64);
        assert_eq!(tight, 2, "200us SLO fits exactly two 90.48us inferences");
        assert_eq!(loose, 55);
        assert!(tight < loose);
        // impossible SLO still serves one at a time
        assert_eq!(sched.max_batch_within(10.0, 8, 64), 1);
        // cap always wins
        assert_eq!(sched.max_batch_within(1e9, 8, 64), 64);
    }

    #[test]
    fn choose_prefers_accuracy_then_drops_bits() {
        let sched = kws();
        // loose SLO: stay at the requested 8 bits, batch to the cap
        let (bits, batch) = sched.choose(100_000.0, 4, 8, 32);
        assert_eq!(bits, 8);
        assert_eq!(batch, 32);
        // 50 us SLO: one 8-bit inference (90.48 us) misses, 4-bit serves
        let (bits, batch) = sched.choose(50.0, 4, 8, 32);
        assert!(bits < 8, "tight SLO must drop bits, got {bits}");
        assert!(batch >= 1);
        // hopeless SLO: floor bits, batch 1
        let (bits, batch) = sched.choose(0.001, 4, 8, 32);
        assert_eq!((bits, batch), (4, 1));
    }

    #[test]
    fn refresh_and_reprogram_are_positive_and_ordered() {
        let sched = kws();
        let refresh = sched.refresh_nj();
        let reprogram = sched.reprogram_nj();
        assert!(refresh > 0.0 && reprogram > 0.0);
        // a full program-and-verify dwarfs one calibration read
        assert!(reprogram > refresh);
        // ~300k allocated cells at 0.1 nJ each
        assert!((reprogram - 307_392.0 * REPROGRAM_NJ_PER_CELL).abs() < 1e-6);
    }

    #[test]
    fn engines_with_same_geom_report_same_schedule() {
        let meta = analognet_kws();
        let a = ScheduleModel::new(&meta, ArrayGeom::AON).unwrap();
        let b = ScheduleModel::from_mapping(
            meta.model.clone(),
            map_model(&meta, ArrayGeom::AON).unwrap(),
            EnergyModel::default(),
        );
        let (la, lb) = (a.launch(4, 6), b.launch(4, 6));
        assert_eq!(la.latency_ns, lb.latency_ns);
        assert_eq!(la.energy_nj, lb.energy_nj);
    }
}
