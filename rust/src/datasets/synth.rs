//! Synthetic artifact bundles: a complete on-disk artifact directory
//! (manifest + meta + ANWT weights + ANDS dataset, no HLO files) generated
//! in-process.
//!
//! Everything that consumes artifacts — the serving coordinator, eval, the
//! serving bench, hermetic tests — can run against one of these bundles on
//! a fresh checkout: no `make artifacts`, no Python, no XLA. The writers
//! mirror the binary formats of `python/compile/export.py` exactly, so the
//! bundle exercises the same `ArtifactStore` loading paths as real exports.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::{self, Json};
use crate::util::rng::Rng;

/// Shape of a generated bundle: a stack of stride-1 SAME conv3x3 layers
/// followed by a global-average-pool dense head.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    /// variant id (manifest key, file prefix)
    pub vid: String,
    /// dataset task name; the dataset file is `<task>_test.bin`
    pub task: String,
    /// square input: H = W = `hw`
    pub hw: usize,
    pub in_ch: usize,
    /// output channels of each conv3x3 layer, in order
    pub conv_ch: Vec<usize>,
    pub classes: usize,
    /// labelled samples in the test set
    pub samples: usize,
    /// whether layers run on the simulated analog array (DAC/ADC quant +
    /// PCM programming) or exactly on the digital path
    pub analog: bool,
    pub seed: u64,
}

impl SynthSpec {
    /// Minimal two-layer bundle (conv + dense): fast to program, used by
    /// hermetic tests.
    pub fn tiny(vid: &str) -> Self {
        SynthSpec {
            vid: vid.to_string(),
            task: "kws".to_string(),
            hw: 4,
            in_ch: 1,
            conv_ch: vec![2],
            classes: 2,
            samples: 8,
            analog: true,
            seed: 7,
        }
    }

    /// The serving-bench workload: per-sample conv rows (6x6 = 36) sit
    /// *below* `gemm::PAR_ROW_THRESHOLD`, so single-request launches run
    /// the GEMM single-threaded while batched launches cross the threshold
    /// and use the worker pool — the regime the layer-serial batcher is
    /// designed for.
    pub fn bench(vid: &str) -> Self {
        SynthSpec {
            vid: vid.to_string(),
            task: "kws".to_string(),
            hw: 6,
            in_ch: 1,
            conv_ch: vec![8, 16],
            classes: 2,
            samples: 64,
            analog: true,
            seed: 11,
        }
    }

    /// A single *digital* (exact, unquantized) dense layer with identity
    /// weights over a `[1, 1, classes]` input: logits == features, bit for
    /// bit. Tests use it to observe batch assembly directly — any
    /// cross-request mixup or reordering in the batcher is visible in the
    /// response payload.
    pub fn identity_dense(vid: &str, classes: usize) -> Self {
        SynthSpec {
            vid: vid.to_string(),
            task: "kws".to_string(),
            hw: 1,
            in_ch: classes,
            conv_ch: vec![],
            classes,
            samples: 8,
            analog: false,
            seed: 3,
        }
    }

    pub fn feat_len(&self) -> usize {
        self.hw * self.hw * self.in_ch
    }
}

/// Write the complete bundle into `dir` (created if missing).
pub fn write_bundle(dir: &Path, spec: &SynthSpec) -> anyhow::Result<()> {
    write_multi_bundle(dir, std::slice::from_ref(spec))
}

/// Write several model variants into one bundle directory sharing a single
/// `manifest.json` — the layout a multi-model coordinator loads. Each
/// spec's dataset file is keyed by its `task`, so specs that should serve
/// distinct datasets (e.g. a KWS-wake / VWW-confirm pair) need distinct
/// task names; same-task specs share (the last writer's) dataset file.
pub fn write_multi_bundle(dir: &Path, specs: &[SynthSpec])
                          -> anyhow::Result<()> {
    anyhow::ensure!(!specs.is_empty(), "write_multi_bundle: no specs");
    std::fs::create_dir_all(dir)?;
    let mut entries = Vec::with_capacity(specs.len());
    for spec in specs {
        let meta = meta_json(spec);
        std::fs::write(dir.join(format!("{}.meta.json", spec.vid)),
                       json::write(&meta))?;
        write_weights(&dir.join(format!("{}.weights.bin", spec.vid)), spec)?;
        write_dataset(&dir.join(format!("{}_test.bin", spec.task)), spec)?;
        entries.push(manifest_entry(spec));
    }
    let manifest = Json::Arr(entries);
    std::fs::write(dir.join("manifest.json"), json::write(&manifest))?;
    Ok(())
}

fn manifest_entry(spec: &SynthSpec) -> Json {
    let mut entry = BTreeMap::new();
    entry.insert("vid".to_string(), Json::Str(spec.vid.clone()));
    entry.insert("task".to_string(), Json::Str(spec.task.clone()));
    entry.insert("model".to_string(), Json::Str("synth".to_string()));
    entry.insert("eta".to_string(), Json::Num(0.0));
    entry.insert("trained_bits".to_string(), Json::Num(8.0));
    entry.insert("fp_test_acc".to_string(), Json::Num(1.0));
    entry.insert("meta".to_string(),
                 Json::Str(format!("{}.meta.json", spec.vid)));
    entry.insert("weights".to_string(),
                 Json::Str(format!("{}.weights.bin", spec.vid)));
    Json::Obj(entry)
}

/// Write the bundle into a fresh process-unique temp directory and return
/// its path (callers may delete it when done).
pub fn write_bundle_tmp(tag: &str, spec: &SynthSpec)
                        -> anyhow::Result<std::path::PathBuf> {
    write_multi_bundle_tmp(tag, std::slice::from_ref(spec))
}

/// [`write_multi_bundle`] into a fresh process-unique temp directory.
pub fn write_multi_bundle_tmp(tag: &str, specs: &[SynthSpec])
                              -> anyhow::Result<std::path::PathBuf> {
    let dir = std::env::temp_dir()
        .join(format!("analognets_synth_{}_{tag}", std::process::id()));
    write_multi_bundle(&dir, specs)?;
    Ok(dir)
}

fn usizes(v: &[usize]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn f32s(v: &[f32]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
}

#[allow(clippy::too_many_arguments)]
fn layer_json(name: &str, kind: &str, in_ch: usize, out_ch: usize, hw: usize,
              out_hw: usize, k_gemm: usize, analog: bool, relu: bool) -> Json {
    let mut l = BTreeMap::new();
    l.insert("name".to_string(), Json::Str(name.to_string()));
    l.insert("kind".to_string(), Json::Str(kind.to_string()));
    l.insert("in_ch".to_string(), Json::Num(in_ch as f64));
    l.insert("out_ch".to_string(), Json::Num(out_ch as f64));
    l.insert("stride".to_string(), usizes(&[1, 1]));
    l.insert("relu".to_string(), Json::Bool(relu));
    l.insert("analog".to_string(), Json::Bool(analog));
    l.insert("in_h".to_string(), Json::Num(hw as f64));
    l.insert("in_w".to_string(), Json::Num(hw as f64));
    l.insert("out_h".to_string(), Json::Num(out_hw as f64));
    l.insert("out_w".to_string(), Json::Num(out_hw as f64));
    l.insert("k_gemm".to_string(), Json::Num(k_gemm as f64));
    l.insert("weight_shape".to_string(), usizes(&[k_gemm, out_ch]));
    l.insert("graph_weight_shape".to_string(), usizes(&[k_gemm, out_ch]));
    l.insert("w_scale".to_string(), Json::Num(1.0));
    l.insert("w_max".to_string(), Json::Num(1.0));
    l.insert("r_dac".to_string(), Json::Num(8.0));
    l.insert("r_adc".to_string(), Json::Num(8.0));
    l.insert("dig_scale".to_string(), f32s(&vec![1.0f32; out_ch]));
    l.insert("dig_bias".to_string(), f32s(&vec![0.0f32; out_ch]));
    Json::Obj(l)
}

fn meta_json(spec: &SynthSpec) -> Json {
    let mut layers = Vec::new();
    let mut ch = spec.in_ch;
    for (i, &out_c) in spec.conv_ch.iter().enumerate() {
        layers.push(layer_json(&format!("c{i}"), "conv3x3", ch, out_c,
                               spec.hw, spec.hw, 9 * ch, spec.analog, true));
        ch = out_c;
    }
    layers.push(layer_json("fc", "dense", ch, spec.classes, spec.hw, 1, ch,
                           spec.analog, false));

    let mut m = BTreeMap::new();
    m.insert("model".to_string(), Json::Str("synth".to_string()));
    m.insert("variant".to_string(), Json::Str(spec.vid.clone()));
    m.insert("input_hwc".to_string(),
             usizes(&[spec.hw, spec.hw, spec.in_ch]));
    m.insert("num_classes".to_string(), Json::Num(spec.classes as f64));
    m.insert("eta".to_string(), Json::Num(0.0));
    m.insert("fp_test_acc".to_string(), Json::Num(1.0));
    m.insert("trained_adc_bits".to_string(), Json::Num(8.0));
    m.insert("layers".to_string(), Json::Arr(layers));
    m.insert("hlo".to_string(), Json::Obj(BTreeMap::new()));
    Json::Obj(m)
}

/// ANWT weight file: per-layer tensors, deterministic from the spec seed.
/// Conv layers get a dominant positive center tap plus small Gaussian
/// jitter (activations survive ReLU); the dense head reads the first
/// pooled channels so bright/dim inputs stay separable. The identity spec
/// writes an exact identity matrix.
fn write_weights(path: &Path, spec: &SynthSpec) -> anyhow::Result<()> {
    let mut rng = Rng::new(spec.seed);
    let mut tensors: Vec<(Vec<u32>, Vec<f32>)> = Vec::new();
    let mut ch = spec.in_ch;
    for &out_c in &spec.conv_ch {
        let k = 9 * ch;
        let mut w = vec![0f32; k * out_c];
        for (i, v) in w.iter_mut().enumerate() {
            *v = 0.08 * rng.gauss(0.0, 1.0) as f32;
            // center tap (ky=1, kx=1): rows 4*ch .. 5*ch of the [9ch, out]
            // matrix
            let row = i / out_c;
            if (4 * ch..5 * ch).contains(&row) {
                *v += 0.5;
            }
        }
        tensors.push((vec![k as u32, out_c as u32], w));
        ch = out_c;
    }
    // dense head: class j reads pooled channel j (mod ch)
    let mut w = vec![0f32; ch * spec.classes];
    for j in 0..spec.classes {
        w[(j % ch) * spec.classes + j] = 1.0;
    }
    tensors.push((vec![ch as u32, spec.classes as u32], w));

    let mut b = Vec::new();
    b.extend_from_slice(b"ANWT");
    b.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (shape, data) in &tensors {
        b.extend_from_slice(&(shape.len() as u32).to_le_bytes());
        for d in shape {
            b.extend_from_slice(&d.to_le_bytes());
        }
        for v in data {
            b.extend_from_slice(&v.to_le_bytes());
        }
    }
    std::fs::write(path, b)?;
    Ok(())
}

/// ANDS dataset: alternating dim/bright frames (labels 0/1 mod `classes`)
/// with a small per-pixel ramp so samples are pairwise distinct.
fn write_dataset(path: &Path, spec: &SynthSpec) -> anyhow::Result<()> {
    let feat = spec.feat_len();
    let mut x = Vec::with_capacity(spec.samples * feat);
    let mut y = Vec::with_capacity(spec.samples);
    for s in 0..spec.samples {
        let label = s % spec.classes.max(1);
        let base = 0.1 + 0.7 * label as f32 / spec.classes.max(1) as f32;
        for i in 0..feat {
            x.push(base + 0.01 * (i as f32) + 0.001 * (s as f32));
        }
        y.push(label as u32);
    }

    let mut b = Vec::new();
    b.extend_from_slice(b"ANDS");
    b.extend_from_slice(&(y.len() as u32).to_le_bytes());
    b.extend_from_slice(&3u32.to_le_bytes());
    for d in [spec.hw, spec.hw, spec.in_ch] {
        b.extend_from_slice(&(d as u32).to_le_bytes());
    }
    for v in &x {
        b.extend_from_slice(&v.to_le_bytes());
    }
    for v in &y {
        b.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, b)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{BackendKind, InferenceBackend};
    use crate::runtime::ArtifactStore;

    #[test]
    fn bundle_loads_and_serves_a_batch() {
        let spec = SynthSpec::bench("synthmod");
        let dir = write_bundle_tmp("synthmod", &spec).unwrap();
        let store = ArtifactStore::open(&dir).unwrap();
        let meta = store.meta("synthmod").unwrap();
        assert_eq!(meta.layers.len(), 3);
        assert_eq!(meta.input_hwc, (6, 6, 1));
        let w = store.weights("synthmod").unwrap();
        assert_eq!(w.len(), meta.layers.len());
        for (t, lm) in w.iter().zip(meta.layers.iter()) {
            assert_eq!(t.shape, lm.graph_weight_shape);
        }
        let ds = store.dataset("kws").unwrap();
        assert_eq!(ds.len(), 64);
        assert_eq!(ds.feat_len(), 36);

        // the bundle executes end-to-end on the native backend
        let be = crate::backend::create(BackendKind::Native, &store,
                                        "synthmod", 8).unwrap();
        let ws: Vec<crate::backend::HostTensor> =
            w.iter().map(crate::backend::HostTensor::from_tensor).collect();
        let gdc = crate::pcm::gdc::unity(ws.len());
        let xb = ds.padded_batch(0, 4);
        let out = be
            .run_batch(&xb, 4, &ws, &gdc,
                       &crate::backend::InferOpts::default())
            .unwrap();
        assert_eq!(out.len(), 4 * 2);
        assert!(out.iter().all(|v| v.is_finite()));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn multi_bundle_carries_every_variant_in_one_manifest() {
        let kws = SynthSpec::identity_dense("multi_kws", 3);
        let mut vww = SynthSpec::identity_dense("multi_vww", 5);
        vww.task = "vww".to_string();
        let dir = write_multi_bundle_tmp("multimod", &[kws, vww]).unwrap();
        let store = ArtifactStore::open(&dir).unwrap();
        // both variants resolve from the shared manifest, with their own
        // shapes and their own task-keyed datasets
        let mk = store.meta("multi_kws").unwrap();
        let mv = store.meta("multi_vww").unwrap();
        assert_eq!(mk.num_classes, 3);
        assert_eq!(mv.num_classes, 5);
        assert_eq!(mk.input_hwc, (1, 1, 3));
        assert_eq!(mv.input_hwc, (1, 1, 5));
        assert_eq!(store.dataset("kws").unwrap().feat_len(), 3);
        assert_eq!(store.dataset("vww").unwrap().feat_len(), 5);
        // each variant's weights stay its own (identity at its own size)
        for (vid, classes) in [("multi_kws", 3usize), ("multi_vww", 5)] {
            let w = store.weights(vid).unwrap();
            assert_eq!(w.len(), 1);
            assert_eq!(w[0].shape, vec![classes, classes]);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn identity_bundle_is_exact() {
        let spec = SynthSpec::identity_dense("ident", 3);
        let dir = write_bundle_tmp("ident", &spec).unwrap();
        let store = ArtifactStore::open(&dir).unwrap();
        let be = crate::backend::create(BackendKind::Native, &store, "ident",
                                        8).unwrap();
        let w = store.weights("ident").unwrap();
        let ws: Vec<crate::backend::HostTensor> =
            w.iter().map(crate::backend::HostTensor::from_tensor).collect();
        let x = vec![0.25f32, -1.5, 3.0];
        let out = be
            .run_batch(&x, 1, &ws, &crate::pcm::gdc::unity(1),
                       &crate::backend::InferOpts::default())
            .unwrap();
        assert_eq!(out, x, "digital identity dense must be exact");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
