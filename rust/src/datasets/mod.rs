//! Test-set loading (ANDS binary, written by `python/compile/data.py`) and
//! synthetic artifact-bundle generation ([`synth`]).

pub mod synth;

use std::io::Read;
use std::path::Path;

/// A loaded evaluation set: row-major f32 inputs + integer labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// per-sample feature dims, e.g. [49, 10, 1]
    pub dims: Vec<usize>,
    /// flattened inputs, sample-major
    pub x: Vec<f32>,
    pub y: Vec<u32>,
}

const MAGIC: &[u8; 4] = b"ANDS";

impl Dataset {
    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let mut f = std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        if buf.len() < 12 || &buf[0..4] != MAGIC {
            anyhow::bail!("bad ANDS file {}", path.display());
        }
        let rd_u32 = |o: usize| u32::from_le_bytes(buf[o..o + 4].try_into().unwrap());
        let n = rd_u32(4) as usize;
        let ndim = rd_u32(8) as usize;
        let mut dims = Vec::with_capacity(ndim);
        let mut pos = 12;
        for _ in 0..ndim {
            dims.push(rd_u32(pos) as usize);
            pos += 4;
        }
        let feat: usize = dims.iter().product();
        let xbytes = n * feat * 4;
        if buf.len() != pos + xbytes + n * 4 {
            anyhow::bail!("ANDS size mismatch in {}", path.display());
        }
        let mut x = vec![0f32; n * feat];
        for (i, c) in buf[pos..pos + xbytes].chunks_exact(4).enumerate() {
            x[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        pos += xbytes;
        let mut y = vec![0u32; n];
        for (i, c) in buf[pos..].chunks_exact(4).enumerate() {
            y[i] = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        Ok(Dataset { dims, x, y })
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn feat_len(&self) -> usize {
        self.dims.iter().product()
    }

    /// Slice of samples [lo, hi) as a flat buffer.
    pub fn batch(&self, lo: usize, hi: usize) -> &[f32] {
        let f = self.feat_len();
        &self.x[lo * f..hi * f]
    }

    /// A batch padded (by repeating the last sample) to exactly `batch` rows.
    pub fn padded_batch(&self, lo: usize, batch: usize) -> Vec<f32> {
        let f = self.feat_len();
        let hi = (lo + batch).min(self.len());
        let mut out = Vec::with_capacity(batch * f);
        out.extend_from_slice(self.batch(lo, hi));
        let last = self.batch(self.len() - 1, self.len());
        while out.len() < batch * f {
            out.extend_from_slice(last);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_sample(path: &Path) {
        let mut b = Vec::new();
        b.extend_from_slice(b"ANDS");
        b.extend_from_slice(&3u32.to_le_bytes()); // n
        b.extend_from_slice(&2u32.to_le_bytes()); // ndim
        b.extend_from_slice(&2u32.to_le_bytes());
        b.extend_from_slice(&2u32.to_le_bytes());
        for i in 0..12 {
            b.extend_from_slice(&(i as f32).to_le_bytes());
        }
        for y in [0u32, 1, 2] {
            b.extend_from_slice(&y.to_le_bytes());
        }
        std::fs::write(path, b).unwrap();
    }

    #[test]
    fn loads_and_batches() {
        let dir = std::env::temp_dir().join("ands_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("d.bin");
        write_sample(&p);
        let d = Dataset::load(&p).unwrap();
        assert_eq!(d.len(), 3);
        assert_eq!(d.feat_len(), 4);
        assert_eq!(d.batch(1, 2), &[4.0, 5.0, 6.0, 7.0]);
        let pb = d.padded_batch(2, 4);
        assert_eq!(pb.len(), 16);
        assert_eq!(&pb[0..4], &[8.0, 9.0, 10.0, 11.0]);
        assert_eq!(&pb[4..8], &[8.0, 9.0, 10.0, 11.0]); // padded w/ last
        assert_eq!(d.y, vec![0, 1, 2]);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("ands_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"NOPE00000000").unwrap();
        assert!(Dataset::load(&p).is_err());
    }
}
