//! Table 3 (Appendix D): MicroNet-KWS-S depthwise deployment — effective
//! utilization vs inference rate across crossbar configurations
//! {1024x512, 128x128, 64x64}.
//!
//! Paper: 9% / 40% / 66% utilization against 4122 / 1467 / 642 inf/s.
//! The reproduction target is the *trade-off direction*: smaller tiles
//! allocate the depthwise diagonals more tightly (utilization up) but pay
//! sequential tile operation (inference rate down).  Our utilization metric
//! counts non-zero weights over allocated tile area with diagonal-band tile
//! skipping; the paper's packing heuristic differs in unstated details, so
//! absolute percentages deviate — see EXPERIMENTS.md.

use analognets::bench::save;
use analognets::crossbar::ArrayGeom;
use analognets::mapping::{map_model, split_map_model};
use analognets::runtime::ArtifactStore;
use analognets::timing::perf::split_inference_rate;
use analognets::timing::{model_perf, EnergyModel};
use analognets::util::table::Table;

fn main() -> anyhow::Result<()> {
    let store = ArtifactStore::open_default()?;
    let meta = store.meta("micro_noise_e10")?;
    let em = EnergyModel::default();

    let mut t = Table::new(
        "Table 3: MicroNet-KWS-S depthwise deployment trade-off",
        &["crossbar", "eff util %", "paper util", "inf/s", "paper inf/s"],
    );
    let mut csv = String::from("config,eff_util,inf_s\n");

    for (label, geom, paper_u, paper_r) in [
        ("1024x512", ArrayGeom::AON, "9%", "4122"),
        ("128x128", ArrayGeom::new(128, 128, 4)?, "40%", "1467"),
        ("64x64", ArrayGeom::new(64, 64, 4)?, "66%", "642"),
    ] {
        let (util, rate) = if geom.rows == 1024 {
            // fits whole: layer-serial on the single big array
            let m = map_model(&meta, geom)?;
            let p = model_perf(&m, 8, &em);
            (m.effective_utilization(), p.inf_per_sec)
        } else {
            let s = split_map_model(&meta, geom);
            (s.effective_utilization(), split_inference_rate(&s, 8, &em))
        };
        t.row(&[label.into(), format!("{:.1}", 100.0 * util), paper_u.into(),
                format!("{rate:.0}"), paper_r.into()]);
        csv.push_str(&format!("{label},{util:.4},{rate:.1}\n"));
    }
    t.print();
    save("table3.txt", &t.render());
    save("table3.csv", &csv);

    // sanity: the trade-off direction must reproduce
    Ok(())
}
