//! Layer-serial serving benchmark (the CI bench-smoke + analog-smoke
//! workloads).
//!
//! Generates a synthetic artifact bundle, drives the coordinator with 4
//! concurrent clients — single-request launches vs the batched layer-serial
//! drain on the native engine, plus a batched run on the tile-faithful
//! AnalogCim engine — and emits machine-readable
//! `bench_out/BENCH_native.json` / `bench_out/BENCH_analog.json` with
//! req/s, latency percentiles, and (native) a `gemm` section comparing the
//! blocked packed kernel against the legacy row-parallel loop per layer
//! shape (GFLOP/s + speedup, plus the active tiling scheme).
//!
//! The analog side additionally runs two accuracy gates:
//! * a degenerate-noise logits-consistency check — with the exact stored
//!   weights (no PCM in the loop) and a 12-bit ADC, the analog engine's
//!   argmax must match the native engine's on every dataset sample (always
//!   enforced: this is the "clean physics degenerates to the reference"
//!   invariant);
//! * a clean-weights drift-accuracy comparison through `eval::drift_accuracy`
//!   (ideal PCM, t = 25 s): with `--baseline`, the native/analog accuracy
//!   gap must stay within `analog_acc_gap_max` from ci/bench_baseline.json.
//!
//! A Figure-7-style drift sweep (25 s -> 1 yr, paper-default PCM params)
//! also runs end-to-end on the analog backend and is recorded in
//! BENCH_analog.json, together with a 4-bit-ADC serving point (paper
//! Table 2): the same coordinator driven with per-request
//! `InferOpts { adc_bits: Some(4) }`, plus the 4-bit clean-weights
//! accuracy through `eval::drift_accuracy`, under the `adc4` key.
//!
//! A device-variability fault sweep (stuck-cell fraction x ADC gain/offset
//! sigma grid, fixed seed, ideal PCM at t = 25 s) lands under the
//! `fault_sweep` key; the mild cells (stuck <= 1%) gate against
//! `fault_acc_gap_max` from the committed baseline — per-tile GDC
//! calibration must hold the accuracy drop there.
//!
//! An `energy` section reproduces the paper's Table-1/2 modeled
//! efficiency: both AnalogNet topologies mapped onto the AON array and
//! priced at 8/6/4-bit ADC precision, with the four headline TOPS/W
//! anchors gated against `energy_tol_rel` (see docs/ENERGY_MODEL.md).
//!
//! Knobs: `--fast` (smaller request counts), `--requests N` (per client),
//! `--max-batch N`, `--baseline <json>`, `--strict` (make the 2x
//! batched-vs-single speedup target a hard failure), `--analog-only`
//! (skip the native load/GEMM sections; the CI analog-smoke job),
//! `--native-only` (skip the analog sections and their gates; the CI
//! bench-smoke job — analog-smoke owns the analog work, so the two jobs
//! never duplicate it).
//!
//! `--wire` additionally measures the TCP front end: a `server::WireServer`
//! on a loopback port, `--wire-clients` connections (default 8) driving an
//! *open-loop* Poisson-ish arrival schedule at `--wire-rate` total req/s
//! for `--wire-duration` seconds. Wall-clock latency is measured from the
//! socket write to the reply line — wire time included — and the achieved
//! req/s plus p50/p99/p999 land in a `wire` section of BENCH_native.json,
//! gated against the `wire_req_s` baseline floor. `--wire-only` (the CI
//! wire-smoke job) runs just this section.

use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::Path;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use analognets::backend::{self, BackendKind, HostTensor, InferOpts,
                          InferenceBackend};
use analognets::bench::{self, save_json, time_it, BenchOpts};
use analognets::coordinator::metrics::MetricsSummary;
use analognets::coordinator::{Coordinator, ServeConfig};
use analognets::datasets::synth::{self, SynthSpec};
use analognets::eval::{drift_accuracy, EvalOpts};
use analognets::pcm::{gdc, FaultSpec, PcmParams, FIG7_TIMES, T_25S};
use analognets::crossbar::ArrayGeom;
use analognets::mapping::map_model;
use analognets::nn::analognets::{analognet_kws, analognet_vww};
use analognets::server::{client as wire_client, WireConfig, WireServer};
use analognets::simulator::{gemm, tiling};
use analognets::timing::{layer_gemm_dims, model_perf, EnergyModel};
use analognets::util::cli::Args;
use analognets::util::json::{self, Json};
use analognets::util::logits;
use analognets::util::rng::Rng;
use analognets::util::stats;

const CLIENTS: usize = 4;
/// per-client submissions kept in flight (pipelined open-loop load)
const WINDOW: usize = 16;

fn num(x: f64) -> Json {
    Json::Num(if x.is_finite() { x } else { 0.0 })
}

/// Drive `CLIENTS` pipelined client threads, every request stamped with
/// `opts`; returns measured req/s and the coordinator's own metrics
/// summary.
fn run_load(cfg: ServeConfig, per_client: usize, feat: usize,
            opts: InferOpts) -> anyhow::Result<(f64, MetricsSummary)> {
    let coord = Arc::new(Coordinator::start(cfg)?);
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let coord = coord.clone();
        handles.push(std::thread::spawn(move || {
            let mut pending = VecDeque::with_capacity(WINDOW);
            for i in 0..per_client {
                let v = 0.1 + 0.8 * (((c * per_client + i) % 13) as f32 / 13.0);
                let rx = coord.submit_with(vec![v; feat], opts).expect("submit");
                pending.push_back(rx);
                if pending.len() >= WINDOW {
                    let _ = pending.pop_front().unwrap().recv().expect("recv");
                }
            }
            for rx in pending {
                let _ = rx.recv().expect("recv tail");
            }
        }));
    }
    for h in handles {
        h.join().expect("client thread");
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let req_s = (CLIENTS * per_client) as f64 / elapsed;
    let summary = coord.metrics.summary();
    match Arc::try_unwrap(coord) {
        Ok(c) => c.stop()?,
        Err(_) => anyhow::bail!("coordinator handle still shared"),
    }
    Ok((req_s, summary))
}

/// The serving config both engines are benchmarked under — one source for
/// the batching window and bitwidth, so the native and analog req/s in
/// BENCH_native.json / BENCH_analog.json stay comparable by construction.
fn bench_cfg(vid: &str, dir: &Path, max_batch: usize) -> ServeConfig {
    let mut cfg = ServeConfig::new(vid, 8);
    cfg.artifacts_dir = dir.to_path_buf();
    cfg.max_batch = max_batch;
    cfg.max_wait = Duration::from_micros(500);
    cfg
}

fn mode_json(req_s: f64, m: &MetricsSummary) -> Json {
    let mut o = match m.to_json() {
        Json::Obj(o) => o,
        _ => unreachable!("MetricsSummary::to_json returns an object"),
    };
    o.insert("req_s".to_string(), num(req_s));
    Json::Obj(o)
}

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::from_env_args();
    let args = Args::from_env();
    let per_client = args.opt_usize("requests", if opts.fast { 200 } else { 800 });
    let max_batch = args.opt_usize("max-batch", 32);
    let analog_only = args.flag("analog-only");
    let native_only = args.flag("native-only");
    let wire_only = args.flag("wire-only");
    let wire = wire_only || args.flag("wire");
    anyhow::ensure!(!(analog_only && native_only),
                    "--analog-only and --native-only are mutually exclusive");
    anyhow::ensure!(!(wire_only && (analog_only || native_only)),
                    "--wire-only cannot be combined with --analog-only or \
                     --native-only");

    let spec = SynthSpec::bench("bench_serving");
    let dir = synth::write_bundle_tmp("bench_serving", &spec)?;
    let feat = spec.feat_len();
    // mirror backend::create's automatic pool policy (cores capped at 8) so
    // the per-layer GFLOP/s below are measured at the same lane count the
    // serving runs above actually used
    let threads = gemm::effective_threads(0).min(8);
    println!("[bench_serving] synthetic bundle `{}` at {} ({} GEMM lanes, \
              {CLIENTS} clients x {per_client} requests)",
             spec.vid, dir.display(), threads);

    let mk_cfg = |max_batch: usize| bench_cfg(&spec.vid, &dir, max_batch);

    // ---- native: single-request baseline vs batched layer-serial -------
    let mut native_gate: Option<f64> = None;
    let mut native_speedup: Option<f64> = None;
    if !analog_only && !wire_only {
        println!("[bench_serving] single-request baseline (max_batch=1)...");
        let (rps_single, m_single) =
            run_load(mk_cfg(1), per_client, feat, InferOpts::default())?;
        println!("  {rps_single:.0} req/s   {m_single}");
        println!("[bench_serving] batched layer-serial (max_batch={max_batch})...");
        let (rps_batched, m_batched) =
            run_load(mk_cfg(max_batch), per_client, feat, InferOpts::default())?;
        println!("  {rps_batched:.0} req/s   {m_batched}");
        let speedup = rps_batched / rps_single;
        println!("[bench_serving] batched speedup: {speedup:.2}x");
        native_gate = Some(rps_batched);
        native_speedup = Some(speedup);

        // ---- per-layer GEMM: blocked kernel vs legacy row-parallel -----
        // Every bench layer shape is timed on both paths at the same lane
        // count: the blocked packed kernel the serving runs above actually
        // used (`gemm_parallel`, process-wide autotuned scheme) and the
        // pre-blocked naive row-chunk loop kept verbatim as
        // `gemm_rowpar`. The speedup is a tracked artifact in the `gemm`
        // section, not a claim.
        let store = analognets::runtime::ArtifactStore::open(&dir)?;
        let meta = store.meta(&spec.vid)?;
        let scheme = tiling::global();
        println!("[bench_serving] GEMM blocked (scheme {scheme}) vs \
                  row-parallel, {threads} lanes:");
        let mut per_layer = Vec::new();
        let mut min_speedup = f64::INFINITY;
        let mut rng = Rng::new(17);
        let reps = if opts.fast { 5 } else { 15 };
        for lm in &meta.layers {
            let (m, k, n) = layer_gemm_dims(lm, max_batch);
            let a: Vec<f32> = (0..m * k).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
            let t_blk = time_it(2, reps, || {
                let _ = gemm::gemm_parallel(&a, &b, m, k, n, threads);
            });
            let t_row = time_it(2, reps, || {
                let _ = gemm::gemm_rowpar(&a, &b, m, k, n, threads);
            });
            let macs = 2.0 * (m * k * n) as f64;
            let gf_blk = macs / (t_blk.min_us * 1e3);
            let gf_row = macs / (t_row.min_us * 1e3);
            let speedup = gf_blk / gf_row;
            min_speedup = min_speedup.min(speedup);
            println!("  layer {:<4} GEMM {m}x{k}x{n}: blocked {gf_blk:.2} \
                      vs rowpar {gf_row:.2} GFLOP/s ({speedup:.2}x)",
                     lm.name);
            let mut o = BTreeMap::new();
            o.insert("name".to_string(), Json::Str(lm.name.clone()));
            o.insert("m".to_string(), num(m as f64));
            o.insert("k".to_string(), num(k as f64));
            o.insert("n".to_string(), num(n as f64));
            o.insert("gflops_blocked".to_string(), num(gf_blk));
            o.insert("gflops_rowpar".to_string(), num(gf_row));
            o.insert("speedup".to_string(), num(speedup));
            per_layer.push(Json::Obj(o));
        }
        // the blocked kernel must not lose to the loop it replaced; 0.85
        // (not 1.0) because the small layers run near-identical code and
        // the ratio there is timing noise around 1.0
        if min_speedup < 0.85 {
            let msg = format!(
                "blocked GEMM at {min_speedup:.2}x of the row-parallel \
                 loop on some bench layer shape (scheme {scheme}, \
                 {threads} lanes) — expected >= 1.0x");
            if opts.strict || opts.baseline.is_some() {
                anyhow::bail!("{msg}");
            }
            eprintln!("[bench_serving] warning: {msg}");
        }
        let mut gemm_sec = BTreeMap::new();
        gemm_sec.insert("scheme".to_string(), Json::Str(scheme.to_string()));
        gemm_sec.insert("lanes".to_string(), num(threads as f64));
        gemm_sec.insert("min_speedup".to_string(), num(min_speedup));
        gemm_sec.insert("per_layer".to_string(), Json::Arr(per_layer));

        // ---- BENCH_native.json -----------------------------------------
        // schema 2.0: `per_layer_gemm` (one gflops number per layer)
        // became the `gemm` section — blocked vs rowpar GFLOP/s + speedup
        // per layer shape, plus the process-wide tiling scheme
        let mut root = BTreeMap::new();
        root.insert("schema".to_string(), num(2.0));
        root.insert("bench".to_string(), Json::Str("serving".to_string()));
        root.insert("backend".to_string(), Json::Str("native".to_string()));
        root.insert("vid".to_string(), Json::Str(spec.vid.clone()));
        root.insert("threads".to_string(), num(threads as f64));
        root.insert("clients".to_string(), num(CLIENTS as f64));
        root.insert("requests_per_client".to_string(), num(per_client as f64));
        root.insert("max_batch".to_string(), num(max_batch as f64));
        // headline metrics (the regression gate reads `req_s`)
        root.insert("req_s".to_string(), num(rps_batched));
        root.insert("p50_us".to_string(), num(m_batched.p50_us));
        root.insert("p99_us".to_string(), num(m_batched.p99_us));
        root.insert("speedup_vs_single".to_string(), num(speedup));
        root.insert("single".to_string(), mode_json(rps_single, &m_single));
        root.insert("batched".to_string(), mode_json(rps_batched, &m_batched));
        root.insert("gemm".to_string(), Json::Obj(gemm_sec));
        save_json("BENCH_native.json", &Json::Obj(root));
    }

    // mixed-traffic multi-model serving: two shards behind one router,
    // clients alternating models; per-model req/s merge into
    // BENCH_native.json under `multi` and gate against the committed
    // `kws_req_s` / `vww_req_s` floors (the CI bench-smoke job runs this
    // via --native-only)
    if !analog_only && !wire_only {
        run_multi(per_client, max_batch, &opts)?;
    }

    // analog sections (serving load, consistency + accuracy gates, drift
    // sweep, BENCH_analog.json): owned by the CI analog-smoke job, so the
    // bench-smoke job skips them with --native-only instead of running the
    // same workload twice
    if !native_only && !wire_only {
        run_analog(&dir, &spec, per_client, max_batch, threads, &opts)?;
    }

    // TCP front-end load (the CI wire-smoke job runs only this section)
    if wire {
        run_wire(&dir, &spec, max_batch, &args, &opts)?;
    }

    let _ = std::fs::remove_dir_all(&dir);

    // ---- native gates ---------------------------------------------------
    if let Some(baseline) = &opts.baseline {
        if let Some(rps_batched) = native_gate {
            bench::check_regression(rps_batched, Path::new(baseline), "req_s",
                                    0.30)?;
        }
    }
    if let Some(speedup) = native_speedup {
        if speedup < 2.0 {
            let msg = format!(
                "batched speedup {speedup:.2}x is below the 2x target \
                 (machine-dependent; {threads} lanes available)"
            );
            if opts.strict {
                anyhow::bail!("{msg}");
            }
            eprintln!("[bench_serving] warning: {msg}");
        }
    }
    Ok(())
}

/// The multi-model half of the bench: a KWS-flavored and a VWW-flavored
/// synthetic variant behind one `MultiCoordinator`, `CLIENTS` pipelined
/// threads alternating models request by request. Per-model throughput
/// lands in BENCH_native.json under `multi` (with the router's per-model
/// metrics) and gates against the `kws_req_s` / `vww_req_s` floors when
/// `--baseline` is given.
fn run_multi(per_client: usize, max_batch: usize, opts: &BenchOpts)
             -> anyhow::Result<()> {
    use analognets::coordinator::{MultiCoordinator, ShardConfig};

    // distinct tasks give each model its own dataset file; the vww twin
    // reshapes so the two feature lengths differ like the real pair does
    let kws = SynthSpec::bench("bench_multi_kws");
    let mut vww = SynthSpec::bench("bench_multi_vww");
    vww.task = "vww".to_string();
    vww.hw = 8; // distinct feature length, like the real KWS/VWW pair
    vww.seed = 23;
    let dir = synth::write_multi_bundle_tmp("bench_multi",
                                            &[kws.clone(), vww.clone()])?;
    println!("[bench_serving] mixed-traffic multi-model serving \
              (`{}` + `{}`, max_batch={max_batch})...",
             kws.vid, vww.vid);

    let shards = vec![
        ShardConfig::new(&kws.vid, bench_cfg(&kws.vid, &dir, max_batch)),
        ShardConfig::new(&vww.vid, bench_cfg(&vww.vid, &dir, max_batch)),
    ];
    let mc = Arc::new(MultiCoordinator::start(shards)?);
    let ids = [kws.vid.clone(), vww.vid.clone()];
    let feats = [kws.feat_len(), vww.feat_len()];

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let mc = mc.clone();
        let ids = ids.clone();
        handles.push(std::thread::spawn(move || -> [usize; 2] {
            let mut sent = [0usize; 2];
            let mut pending = VecDeque::with_capacity(WINDOW);
            for i in 0..per_client {
                let m = (c + i) % 2;
                let v = 0.1 + 0.8 * (((c * per_client + i) % 13) as f32 / 13.0);
                let rx = mc
                    .submit(&ids[m], vec![v; feats[m]], InferOpts::default())
                    .expect("multi submit");
                sent[m] += 1;
                pending.push_back(rx);
                if pending.len() >= WINDOW {
                    let _ = pending.pop_front().unwrap().recv().expect("recv");
                }
            }
            for rx in pending {
                let _ = rx.recv().expect("recv tail");
            }
            sent
        }));
    }
    let mut sent = [0usize; 2];
    for h in handles {
        let s = h.join().expect("multi client thread");
        sent[0] += s[0];
        sent[1] += s[1];
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let kws_req_s = sent[0] as f64 / elapsed;
    let vww_req_s = sent[1] as f64 / elapsed;
    let m = mc.metrics.summary();
    anyhow::ensure!(m.submit_rejects == 0,
                    "mixed load was rejected at submit time: {} rejects",
                    m.submit_rejects);
    anyhow::ensure!(m.completed as usize == sent[0] + sent[1],
                    "router completed {} of {} mixed requests",
                    m.completed, sent[0] + sent[1]);
    println!("  multi: {} `{}` + {} `{}` requests in {elapsed:.2}s -> \
              {kws_req_s:.0} + {vww_req_s:.0} req/s",
             sent[0], ids[0], sent[1], ids[1]);
    println!("  {m}");
    match Arc::try_unwrap(mc) {
        Ok(c) => c.stop()?,
        Err(_) => anyhow::bail!("router handle still shared"),
    }
    let _ = std::fs::remove_dir_all(&dir);

    // ---- merge the `multi` section into BENCH_native.json ---------------
    let mut sec = BTreeMap::new();
    sec.insert("models".to_string(),
               Json::Arr(ids.iter().map(|i| Json::Str(i.clone())).collect()));
    sec.insert("clients".to_string(), num(CLIENTS as f64));
    sec.insert("requests_per_client".to_string(), num(per_client as f64));
    sec.insert("duration_s".to_string(), num(elapsed));
    sec.insert("kws_req_s".to_string(), num(kws_req_s));
    sec.insert("vww_req_s".to_string(), num(vww_req_s));
    sec.insert("coordinator".to_string(), m.to_json());
    let path = bench::out_dir().join("BENCH_native.json");
    let mut root = match json::parse_file(&path) {
        Ok(Json::Obj(o)) => o,
        _ => {
            let mut o = BTreeMap::new();
            o.insert("schema".to_string(), num(2.0));
            o.insert("bench".to_string(), Json::Str("serving".to_string()));
            o.insert("backend".to_string(), Json::Str("native".to_string()));
            o
        }
    };
    root.insert("multi".to_string(), Json::Obj(sec));
    save_json("BENCH_native.json", &Json::Obj(root));

    if let Some(baseline) = &opts.baseline {
        bench::check_regression(kws_req_s, Path::new(baseline), "kws_req_s",
                                0.30)?;
        bench::check_regression(vww_req_s, Path::new(baseline), "vww_req_s",
                                0.30)?;
    }
    Ok(())
}

/// The analog half of the bench: batched serving load on the tile-faithful
/// engine, the degenerate-noise argmax-consistency check (always enforced),
/// the clean-weights accuracy gap through `eval::drift_accuracy` (gated by
/// `analog_acc_gap_max` when `--baseline` is given), the Fig.7-style drift
/// sweep, and `bench_out/BENCH_analog.json`.
fn run_analog(dir: &Path, spec: &SynthSpec, per_client: usize,
              max_batch: usize, threads: usize, opts: &BenchOpts)
              -> anyhow::Result<()> {
    let feat = spec.feat_len();

    // ---- batched serving on the tile-faithful engine --------------------
    println!("[bench_serving] analog tile-faithful serving \
              (max_batch={max_batch})...");
    let mut acfg = bench_cfg(&spec.vid, dir, max_batch);
    acfg.backend = BackendKind::AnalogCim;
    let (rps_analog, m_analog) =
        run_load(acfg, per_client, feat, InferOpts::default())?;
    println!("  {rps_analog:.0} req/s   {m_analog}");

    // ---- 4-bit ADC serving (paper Table 2) ------------------------------
    // the same coordinator config, every request stamped with a per-request
    // 4-bit override — the backend stays configured at 8 bits, the options
    // select the coarse converters launch by launch
    println!("[bench_serving] analog 4-bit-ADC serving (per-request \
              adc_bits=4)...");
    let mut acfg4 = bench_cfg(&spec.vid, dir, max_batch);
    acfg4.backend = BackendKind::AnalogCim;
    let (rps_adc4, m_adc4) = run_load(acfg4, per_client, feat,
                                      InferOpts::default().with_adc_bits(4))?;
    println!("  {rps_adc4:.0} req/s   {m_adc4}");

    // ---- degenerate-noise logits consistency vs native ------------------
    // no PCM in the loop at all: the exact stored weights, unity GDC, a
    // 12-bit ADC. On the AON array every layer of the bench model fits a
    // single tile, so per-tile ADC quantization must reproduce the native
    // argmax on every sample.
    let store = analognets::runtime::ArtifactStore::open(dir)?;
    let meta = store.meta(&spec.vid)?;
    let w = store.weights(&spec.vid)?;
    let ws: Vec<HostTensor> = w.iter().map(HostTensor::from_tensor).collect();
    let unity = gdc::unity(ws.len());
    let ds = store.dataset(&spec.task)?;
    let n = ds.len();
    let xb = ds.padded_batch(0, n);
    let nat = backend::create(BackendKind::Native, &store, &spec.vid, 12)?;
    let ana = backend::create(BackendKind::AnalogCim, &store, &spec.vid, 12)?;
    let iopts = InferOpts::default();
    let lo_n = nat.run_batch(&xb, n, &ws, &unity, &iopts)?;
    let lo_a = ana.run_batch(&xb, n, &ws, &unity, &iopts)?;
    let classes = meta.num_classes;
    let pred_n = logits::predictions(&lo_n, classes);
    let pred_a = logits::predictions(&lo_a, classes);
    let argmax_matches = pred_n.iter().zip(pred_a.iter())
        .filter(|(a, b)| a == b).count();
    let max_abs_diff = lo_n.iter().zip(lo_a.iter())
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0f64, f64::max);
    println!("[bench_serving] analog-vs-native consistency: {argmax_matches}/{n} \
              argmax matches, max |logit diff| {max_abs_diff:.2e}");
    anyhow::ensure!(
        argmax_matches == n,
        "degenerate-noise analog execution changed {} / {n} predictions \
         against the native reference",
        n - argmax_matches
    );

    // ---- clean-weights accuracy through eval::drift_accuracy ------------
    // ideal PCM (no programming/read noise, no drift) at t = 25 s: the two
    // engines should agree; the committed baseline bounds the gap.
    let clean = EvalOpts {
        bits: 8,
        batch: 16,
        max_samples: 64,
        runs: 1,
        params: PcmParams::ideal(),
        backend: BackendKind::Native,
        t_drift: Some(T_25S),
        ..Default::default()
    };
    let acc_native = drift_accuracy(&store, &spec.vid, &clean.sweep_times(),
                                    &clean)?[0][0];
    let clean_analog = EvalOpts { backend: BackendKind::AnalogCim, ..clean };
    let acc_analog = drift_accuracy(&store, &spec.vid,
                                    &clean_analog.sweep_times(),
                                    &clean_analog)?[0][0];
    let acc_gap = (acc_native - acc_analog).abs();
    println!("[bench_serving] clean-weights accuracy: native {:.2}% vs \
              analog {:.2}% (gap {:.4})",
             100.0 * acc_native, 100.0 * acc_analog, acc_gap);

    // ---- 4-bit clean-weights accuracy (Table-2 companion number) --------
    // same eval, per-request `adc_bits: Some(4)` on the analog engine
    let clean_adc4 = EvalOpts { adc_bits: Some(4), ..clean_analog.clone() };
    let acc_adc4 = drift_accuracy(&store, &spec.vid, &clean_adc4.sweep_times(),
                                  &clean_adc4)?[0][0];
    println!("[bench_serving] 4-bit-ADC analog accuracy: {:.2}% \
              ({rps_adc4:.0} req/s)",
             100.0 * acc_adc4);

    // ---- Fig.7-style drift sweep on the analog backend ------------------
    let sweep_opts = EvalOpts {
        bits: 8,
        batch: 16,
        max_samples: if opts.fast { 32 } else { 64 },
        runs: 1,
        backend: BackendKind::AnalogCim,
        ..Default::default()
    };
    let times: Vec<f64> = FIG7_TIMES.iter().map(|(_, t)| *t).collect();
    let sweep = drift_accuracy(&store, &spec.vid, &times, &sweep_opts)?;
    let mut sweep_json = Vec::new();
    for ((label, t), accs) in FIG7_TIMES.iter().zip(sweep.iter()) {
        println!("  analog drift {label:>4}: {:.2}%", 100.0 * accs[0]);
        let mut o = BTreeMap::new();
        o.insert("label".to_string(), Json::Str(label.to_string()));
        o.insert("t_s".to_string(), num(*t));
        o.insert("acc".to_string(), num(accs[0]));
        sweep_json.push(Json::Obj(o));
    }

    // ---- device-variability fault sweep (robustness gate) ---------------
    // ideal PCM at t = 25 s so the grid isolates the injected faults:
    // stuck-cell fraction (split evenly between stuck-at-Gmin and
    // stuck-at-Gmax) x ADC gain/offset sigma, fixed seed. The sigma = 0
    // column doubles as a Fig.7-style degradation curve over stuck
    // fraction. Mild cells (stuck fraction <= 1%) gate against the
    // committed `fault_acc_gap_max` floor: per-tile GDC calibration must
    // hold the accuracy drop there. The severe cells are reported, not
    // gated — degrading under heavy faults is the expected physics.
    const FAULT_SEED: u64 = 0xFA117;
    let stuck_fracs: [f32; 5] = [0.0, 0.005, 0.01, 0.02, 0.05];
    let adc_sigmas: [f32; 2] = [0.0, 0.02];
    let fault_base = EvalOpts {
        bits: 8,
        batch: 16,
        max_samples: if opts.fast { 32 } else { 64 },
        runs: 1,
        params: PcmParams::ideal(),
        backend: BackendKind::AnalogCim,
        t_drift: Some(T_25S),
        ..Default::default()
    };
    let mut fault_acc =
        vec![vec![0.0f64; adc_sigmas.len()]; stuck_fracs.len()];
    for (fi, &frac) in stuck_fracs.iter().enumerate() {
        for (si, &sigma) in adc_sigmas.iter().enumerate() {
            let fopts = EvalOpts {
                faults: FaultSpec {
                    stuck_min: frac / 2.0,
                    stuck_max: frac / 2.0,
                    adc_offset_sigma: sigma,
                    adc_gain_sigma: sigma,
                    seed: FAULT_SEED,
                    ..FaultSpec::none()
                },
                ..fault_base.clone()
            };
            fault_acc[fi][si] = drift_accuracy(&store, &spec.vid,
                                               &fopts.sweep_times(),
                                               &fopts)?[0][0];
        }
        let row = adc_sigmas.iter().zip(fault_acc[fi].iter())
            .map(|(s, a)| format!("adc {s:.2} -> {:.2}%", 100.0 * a))
            .collect::<Vec<_>>().join("   ");
        println!("  fault sweep stuck {:>4.1}%: {row}", 100.0 * frac as f64);
    }
    let fault_acc_clean = fault_acc[0][0];
    let fault_mild_gap = stuck_fracs.iter().enumerate()
        .filter(|(_, &f)| f <= 0.01)
        .flat_map(|(fi, _)| fault_acc[fi].iter())
        .map(|a| fault_acc_clean - a)
        .fold(0.0f64, f64::max);
    println!("[bench_serving] fault sweep: clean {:.2}%, worst mild-cell \
              drop {fault_mild_gap:.4}", 100.0 * fault_acc_clean);

    // ---- modeled AON-CiM energy: paper Table 1/2 reproduction -----------
    // The paper's two deployment models (AnalogNet-KWS / AnalogNet-VWW)
    // mapped whole onto the 1024x512 mux-4 AON array and priced by the
    // calibrated timing/energy model at 8/6/4-bit ADC precision. This is
    // pure arithmetic over the mapping — no hardware, no host timing — so
    // the numbers are bit-stable across machines. The four headline TOPS/W
    // anchors from Tables 1/2 (KWS 8.58 @ 8b / 57.39 @ 4b, VWW 4.37 @ 8b /
    // 25.69 @ 4b) gate against `energy_tol_rel` in the committed baseline;
    // docs/ENERGY_MODEL.md derives the model and explains why the band is
    // wide (the fit is anchored to Table 2's peak rows, and the paper's
    // own model-level columns are internally inconsistent at 4 bits).
    println!("[bench_serving] modeled energy (paper Table 1/2 anchors):");
    let anchors: [(&str, u32, f64); 4] = [
        ("analognet_kws", 8, 8.58),
        ("analognet_kws", 4, 57.39),
        ("analognet_vww", 8, 4.37),
        ("analognet_vww", 4, 25.69),
    ];
    let em = EnergyModel::default();
    let mut energy_rows = Vec::new();
    let mut energy_max_dev = 0.0f64;
    for pmeta in [analognet_kws(), analognet_vww()] {
        let mapping = map_model(&pmeta, ArrayGeom::AON)?;
        for bits in [8u32, 6, 4] {
            let p = model_perf(&mapping, bits, &em);
            let mut o = BTreeMap::new();
            o.insert("model".to_string(), Json::Str(pmeta.model.clone()));
            o.insert("adc_bits".to_string(), num(bits as f64));
            o.insert("tops".to_string(), num(p.tops));
            o.insert("tops_w".to_string(), num(p.tops_w));
            o.insert("uj_per_inf".to_string(), num(p.uj_per_inf));
            o.insert("inf_per_sec".to_string(), num(p.inf_per_sec));
            let anchor = anchors.iter()
                .find(|(m, b, _)| *m == pmeta.model && *b == bits)
                .map(|&(_, _, a)| a);
            let dev_txt = match anchor {
                Some(a) => {
                    let dev = (p.tops_w - a).abs() / a;
                    energy_max_dev = energy_max_dev.max(dev);
                    o.insert("paper_tops_w".to_string(), num(a));
                    o.insert("rel_dev".to_string(), num(dev));
                    format!("  (paper {a:.2}, dev {:.0}%)", 100.0 * dev)
                }
                None => String::new(),
            };
            println!("  {:<14} {bits}b: {:7.2} TOPS/W  {:7.2} uJ/inf\
                      {dev_txt}",
                     pmeta.model, p.tops_w, p.uj_per_inf);
            energy_rows.push(Json::Obj(o));
        }
    }
    println!("[bench_serving] energy anchors: max rel dev \
              {energy_max_dev:.3}");

    // ---- BENCH_analog.json ----------------------------------------------
    // schema 2.0: adds the `energy` section (modeled Table-1/2 TOPS/W and
    // uJ/inf for both paper models at 8/6/4 bits, with per-anchor relative
    // deviations and the gated `max_rel_dev`)
    let mut aroot = BTreeMap::new();
    aroot.insert("schema".to_string(), num(2.0));
    aroot.insert("bench".to_string(), Json::Str("serving".to_string()));
    aroot.insert("backend".to_string(), Json::Str("analog".to_string()));
    aroot.insert("vid".to_string(), Json::Str(spec.vid.clone()));
    aroot.insert("threads".to_string(), num(threads as f64));
    aroot.insert("clients".to_string(), num(CLIENTS as f64));
    aroot.insert("requests_per_client".to_string(), num(per_client as f64));
    aroot.insert("max_batch".to_string(), num(max_batch as f64));
    aroot.insert("req_s".to_string(), num(rps_analog));
    aroot.insert("p50_us".to_string(), num(m_analog.p50_us));
    aroot.insert("p99_us".to_string(), num(m_analog.p99_us));
    aroot.insert("batched".to_string(), mode_json(rps_analog, &m_analog));
    let mut cons = BTreeMap::new();
    cons.insert("samples".to_string(), num(n as f64));
    cons.insert("argmax_matches".to_string(), num(argmax_matches as f64));
    cons.insert("max_abs_logit_diff".to_string(), num(max_abs_diff));
    aroot.insert("consistency".to_string(), Json::Obj(cons));
    let mut cl = BTreeMap::new();
    cl.insert("acc_native".to_string(), num(acc_native));
    cl.insert("acc_analog".to_string(), num(acc_analog));
    cl.insert("acc_gap".to_string(), num(acc_gap));
    aroot.insert("clean_weights".to_string(), Json::Obj(cl));
    // the Table-2 4-bit serving point: throughput + latency of the
    // per-request adc_bits=4 load, plus its clean-weights accuracy
    let mut a4 = BTreeMap::new();
    a4.insert("adc_bits".to_string(), num(4.0));
    a4.insert("req_s".to_string(), num(rps_adc4));
    a4.insert("p50_us".to_string(), num(m_adc4.p50_us));
    a4.insert("p99_us".to_string(), num(m_adc4.p99_us));
    a4.insert("acc".to_string(), num(acc_adc4));
    aroot.insert("adc4".to_string(), Json::Obj(a4));
    aroot.insert("drift_sweep".to_string(), Json::Arr(sweep_json));
    // the fault grid: acc[frac_idx][sigma_idx], plus the clean reference
    // cell and the worst mild-cell drop the gate below checks
    let mut fsec = BTreeMap::new();
    fsec.insert("seed".to_string(), num(FAULT_SEED as f64));
    fsec.insert("stuck_fracs".to_string(),
                Json::Arr(stuck_fracs.iter().map(|&f| num(f as f64)).collect()));
    fsec.insert("adc_sigmas".to_string(),
                Json::Arr(adc_sigmas.iter().map(|&s| num(s as f64)).collect()));
    fsec.insert("acc".to_string(),
                Json::Arr(fault_acc.iter()
                    .map(|row| Json::Arr(row.iter().map(|&a| num(a)).collect()))
                    .collect()));
    fsec.insert("acc_clean".to_string(), num(fault_acc_clean));
    fsec.insert("mild_gap_max".to_string(), num(fault_mild_gap));
    aroot.insert("fault_sweep".to_string(), Json::Obj(fsec));
    let mut esec = BTreeMap::new();
    esec.insert("rows".to_string(), Json::Arr(energy_rows));
    esec.insert("max_rel_dev".to_string(), num(energy_max_dev));
    aroot.insert("energy".to_string(), Json::Obj(esec));
    save_json("BENCH_analog.json", &Json::Obj(aroot));

    // clean-weights accuracy gate: the analog engine may not diverge
    // from the native reference beyond the committed floor; the analog
    // throughput additionally gates against its own committed req/s floor
    if let Some(baseline) = &opts.baseline {
        let v = json::parse_file(Path::new(baseline))?;
        let max_gap = v.req("analog_acc_gap_max")?.as_f64()?;
        anyhow::ensure!(
            acc_gap <= max_gap,
            "clean-weights analog accuracy diverged from native by \
             {acc_gap:.4} (gate: {max_gap:.4} in {baseline})"
        );
        println!("[bench_serving] analog accuracy gate OK: gap {acc_gap:.4} \
                  <= {max_gap:.4}");
        let fault_gate = v.req("fault_acc_gap_max")?.as_f64()?;
        anyhow::ensure!(
            fault_mild_gap <= fault_gate,
            "mild fault cells (stuck <= 1%, ADC sigma <= 0.02) dropped \
             accuracy by {fault_mild_gap:.4} (gate: {fault_gate:.4} in \
             {baseline})"
        );
        println!("[bench_serving] fault-sweep gate OK: mild drop \
                  {fault_mild_gap:.4} <= {fault_gate:.4}");
        let energy_tol = v.req("energy_tol_rel")?.as_f64()?;
        anyhow::ensure!(
            energy_max_dev <= energy_tol,
            "modeled TOPS/W drifted {energy_max_dev:.3} (relative) from the \
             paper Table-1/2 anchors (gate: {energy_tol:.3} in {baseline}); \
             the timing/energy model or the paper-model mappings changed — \
             see docs/ENERGY_MODEL.md before touching the tolerance"
        );
        println!("[bench_serving] energy-anchor gate OK: max rel dev \
                  {energy_max_dev:.3} <= {energy_tol:.3}");
        bench::check_regression(rps_analog, Path::new(baseline),
                                "analog_req_s", 0.30)?;
    }
    Ok(())
}

/// The wire half of the bench: a `WireServer` on a loopback port, K client
/// connections driving an open-loop Poisson-ish arrival schedule, latency
/// measured socket-write -> reply-line. Every reply id is checked against
/// the per-connection FIFO order, so this doubles as an ordering test under
/// load. Results merge into BENCH_native.json under `"wire"` and gate
/// against the committed `wire_req_s` floor when `--baseline` is given.
fn run_wire(dir: &Path, spec: &SynthSpec, max_batch: usize, args: &Args,
            opts: &BenchOpts) -> anyhow::Result<()> {
    let feat = spec.feat_len();
    let clients = args.opt_usize("wire-clients", 8);
    let rate = args.opt_f64("wire-rate",
                            if opts.fast { 400.0 } else { 2000.0 });
    let duration_s = args.opt_f64("wire-duration",
                                  if opts.fast { 2.0 } else { 5.0 });
    anyhow::ensure!(clients > 0 && rate > 0.0 && duration_s > 0.0,
                    "--wire-clients / --wire-rate / --wire-duration must be \
                     positive");
    println!("[bench_serving] wire open-loop load: {clients} connections, \
              offered {rate:.0} req/s for {duration_s:.1}s...");

    let coord = Arc::new(Coordinator::start(bench_cfg(&spec.vid, dir,
                                                      max_batch))?);
    let mut server = WireServer::start(coord.clone(), None,
                                       WireConfig::default())?;
    let addr = server.local_addr();

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let per_conn_rate = rate / clients as f64;
        handles.push(std::thread::spawn(move || {
            wire_client_load(addr, c, per_conn_rate, duration_s, feat)
        }));
    }
    let mut lat_us: Vec<f64> = Vec::new();
    for h in handles {
        lat_us.extend(h.join().expect("wire client thread")?);
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let total = lat_us.len();
    anyhow::ensure!(total > 0, "wire load produced no replies");
    let achieved = total as f64 / elapsed;
    let m = coord.metrics.summary();
    // a well-formed load must never be rejected, at the wire layer or at
    // submit time — any reject here is a front-end bug, not backpressure
    anyhow::ensure!(m.wire_rejects == 0 && m.submit_rejects == 0,
                    "wire load was rejected: wire_rejects={} \
                     submit_rejects={}",
                    m.wire_rejects, m.submit_rejects);
    let (p50, p99, p999) = (stats::percentile(&lat_us, 50.0),
                            stats::percentile(&lat_us, 99.0),
                            stats::percentile(&lat_us, 99.9));
    println!("  wire: {total} replies, achieved {achieved:.0} req/s \
              (offered {rate:.0}), p50 {p50:.0}us p99 {p99:.0}us \
              p999 {p999:.0}us");
    println!("  {m}");

    server.shutdown();
    drop(server);
    match Arc::try_unwrap(coord) {
        Ok(c) => c.stop()?,
        Err(_) => anyhow::bail!("coordinator handle still shared"),
    }

    // ---- merge the `wire` section into BENCH_native.json ----------------
    // --wire-only runs without the native section, so start from the file
    // on disk when it exists and a minimal root when it does not
    let mut w = BTreeMap::new();
    w.insert("clients".to_string(), num(clients as f64));
    w.insert("offered_req_s".to_string(), num(rate));
    w.insert("req_s".to_string(), num(achieved));
    w.insert("requests".to_string(), num(total as f64));
    w.insert("duration_s".to_string(), num(elapsed));
    w.insert("p50_us".to_string(), num(p50));
    w.insert("p99_us".to_string(), num(p99));
    w.insert("p999_us".to_string(), num(p999));
    w.insert("coordinator".to_string(), m.to_json());
    let path = bench::out_dir().join("BENCH_native.json");
    let mut root = match json::parse_file(&path) {
        Ok(Json::Obj(o)) => o,
        _ => {
            let mut o = BTreeMap::new();
            o.insert("schema".to_string(), num(2.0));
            o.insert("bench".to_string(), Json::Str("serving".to_string()));
            o.insert("backend".to_string(), Json::Str("native".to_string()));
            o.insert("vid".to_string(), Json::Str(spec.vid.clone()));
            o
        }
    };
    root.insert("wire".to_string(), Json::Obj(w));
    save_json("BENCH_native.json", &Json::Obj(root));

    if let Some(baseline) = &opts.baseline {
        bench::check_regression(achieved, Path::new(baseline), "wire_req_s",
                                0.30)?;
    }
    Ok(())
}

/// One load-generator connection: a sender pacing requests on an
/// exponential inter-arrival clock and a receiver pairing each reply line
/// with its send-time `Instant` (the wire protocol guarantees per-connection
/// FIFO replies, so a plain channel of timestamps is enough). Returns the
/// wall-clock latencies in microseconds.
fn wire_client_load(addr: SocketAddr, c: usize, rate: f64, duration_s: f64,
                    feat: usize) -> anyhow::Result<Vec<f64>> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    let mut wr = stream.try_clone()?;
    let mut rd = BufReader::new(stream);
    let (sent_tx, sent_rx) = mpsc::channel::<Instant>();
    let reader = std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
        let mut line = String::new();
        let mut lat_us = Vec::new();
        let mut seq = 0usize;
        while let Ok(sent) = sent_rx.recv() {
            line.clear();
            anyhow::ensure!(rd.read_line(&mut line)? > 0,
                            "server closed the connection mid-load");
            let rep = wire_client::parse_reply(line.trim_end())?;
            anyhow::ensure!(rep.ok, "error reply under well-formed load: {:?}",
                            rep.error);
            anyhow::ensure!(rep.id == format!("c{c}-{seq}"),
                            "reply id {} broke FIFO order (expected c{c}-{seq})",
                            rep.id);
            lat_us.push(sent.elapsed().as_secs_f64() * 1e6);
            seq += 1;
        }
        Ok(lat_us)
    });

    let mut rng = Rng::new(0xA11CE ^ ((c as u64 + 1) << 8));
    let t0 = Instant::now();
    let mut next_s = 0.0f64;
    let mut out = String::with_capacity(64 + 12 * feat);
    let mut x = vec![0.0f32; feat];
    let mut seq = 0usize;
    loop {
        // exponential inter-arrival at `rate` req/s; 1 - uniform() is in
        // (0, 1], so the log never hits -inf
        next_s += -(1.0 - rng.uniform()).ln() / rate;
        if next_s >= duration_s {
            break;
        }
        let target = Duration::from_secs_f64(next_s);
        let now = t0.elapsed();
        if target > now {
            std::thread::sleep(target - now);
        }
        let v = 0.1 + 0.8 * ((seq % 13) as f32 / 13.0);
        x.fill(v);
        let id = format!("c{c}-{seq}");
        out.clear();
        wire_client::build_x_line(&mut out, &id, &x, None, None);
        let sent = Instant::now();
        wr.write_all(out.as_bytes())?;
        sent_tx.send(sent).expect("receiver alive while sending");
        seq += 1;
    }
    drop(sent_tx); // receiver drains the in-flight tail, then stops
    wr.flush()?;
    reader.join().expect("wire reader thread")
}
