//! Layer-serial serving benchmark (the CI bench-smoke workload).
//!
//! Generates a synthetic artifact bundle, drives the coordinator with 4
//! concurrent clients twice — once pinned to single-request launches
//! (`max_batch = 1`), once with the batched layer-serial drain — and emits
//! a machine-readable `bench_out/BENCH_native.json` with req/s, latency
//! percentiles, and per-layer GEMM GFLOP/s. With `--baseline <file>` the
//! run fails if batched req/s drops >30% below the committed baseline
//! (the CI regression gate).
//!
//! Knobs: `--fast` (smaller request counts), `--requests N` (per client),
//! `--max-batch N`, `--baseline <json>`, `--strict` (make the 2x
//! batched-vs-single speedup target a hard failure).

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;
use std::time::{Duration, Instant};

use analognets::bench::{self, save_json, time_it, BenchOpts};
use analognets::coordinator::metrics::MetricsSummary;
use analognets::coordinator::{Coordinator, ServeConfig};
use analognets::datasets::synth::{self, SynthSpec};
use analognets::simulator::gemm;
use analognets::timing::layer_gemm_dims;
use analognets::util::cli::Args;
use analognets::util::json::Json;
use analognets::util::rng::Rng;

const CLIENTS: usize = 4;
/// per-client submissions kept in flight (pipelined open-loop load)
const WINDOW: usize = 16;

fn num(x: f64) -> Json {
    Json::Num(if x.is_finite() { x } else { 0.0 })
}

/// Drive `CLIENTS` pipelined client threads; returns measured req/s and the
/// coordinator's own metrics summary.
fn run_load(cfg: ServeConfig, per_client: usize, feat: usize)
            -> anyhow::Result<(f64, MetricsSummary)> {
    let coord = Arc::new(Coordinator::start(cfg)?);
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let coord = coord.clone();
        handles.push(std::thread::spawn(move || {
            let mut pending = VecDeque::with_capacity(WINDOW);
            for i in 0..per_client {
                let v = 0.1 + 0.8 * (((c * per_client + i) % 13) as f32 / 13.0);
                let rx = coord.submit(vec![v; feat]).expect("submit");
                pending.push_back(rx);
                if pending.len() >= WINDOW {
                    let _ = pending.pop_front().unwrap().recv().expect("recv");
                }
            }
            for rx in pending {
                let _ = rx.recv().expect("recv tail");
            }
        }));
    }
    for h in handles {
        h.join().expect("client thread");
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let req_s = (CLIENTS * per_client) as f64 / elapsed;
    let summary = coord.metrics.summary();
    match Arc::try_unwrap(coord) {
        Ok(c) => c.stop()?,
        Err(_) => anyhow::bail!("coordinator handle still shared"),
    }
    Ok((req_s, summary))
}

fn mode_json(req_s: f64, m: &MetricsSummary) -> Json {
    let mut o = match m.to_json() {
        Json::Obj(o) => o,
        _ => unreachable!("MetricsSummary::to_json returns an object"),
    };
    o.insert("req_s".to_string(), num(req_s));
    Json::Obj(o)
}

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::from_env_args();
    let args = Args::from_env();
    let per_client = args.opt_usize("requests", if opts.fast { 200 } else { 800 });
    let max_batch = args.opt_usize("max-batch", 32);

    let spec = SynthSpec::bench("bench_serving");
    let dir = synth::write_bundle_tmp("bench_serving", &spec)?;
    let feat = spec.feat_len();
    // mirror backend::create's automatic pool policy (cores capped at 8) so
    // the per-layer GFLOP/s below are measured at the same lane count the
    // serving runs above actually used
    let threads = gemm::effective_threads(0).min(8);
    println!("[bench_serving] synthetic bundle `{}` at {} ({} GEMM lanes, \
              {CLIENTS} clients x {per_client} requests)",
             spec.vid, dir.display(), threads);

    let mk_cfg = |max_batch: usize| {
        let mut cfg = ServeConfig::new(&spec.vid, 8);
        cfg.artifacts_dir = dir.clone();
        cfg.max_batch = max_batch;
        cfg.max_wait = Duration::from_micros(500);
        cfg
    };

    // ---- single-request baseline vs batched layer-serial drain ---------
    println!("[bench_serving] single-request baseline (max_batch=1)...");
    let (rps_single, m_single) = run_load(mk_cfg(1), per_client, feat)?;
    println!("  {rps_single:.0} req/s   {m_single}");
    println!("[bench_serving] batched layer-serial (max_batch={max_batch})...");
    let (rps_batched, m_batched) = run_load(mk_cfg(max_batch), per_client, feat)?;
    println!("  {rps_batched:.0} req/s   {m_batched}");
    let speedup = rps_batched / rps_single;
    println!("[bench_serving] batched speedup: {speedup:.2}x");

    // ---- per-layer GEMM GFLOP/s at the batched launch shape ------------
    let store = analognets::runtime::ArtifactStore::open(&dir)?;
    let meta = store.meta(&spec.vid)?;
    let mut per_layer = Vec::new();
    let mut rng = Rng::new(17);
    for lm in &meta.layers {
        let (m, k, n) = layer_gemm_dims(lm, max_batch);
        let a: Vec<f32> = (0..m * k).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
        let t = time_it(2, if opts.fast { 5 } else { 15 }, || {
            let _ = gemm::gemm_parallel(&a, &b, m, k, n, threads);
        });
        let gflops = 2.0 * (m * k * n) as f64 / (t.min_us * 1e3);
        println!("  layer {:<4} GEMM {m}x{k}x{n}: {gflops:.2} GFLOP/s", lm.name);
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Json::Str(lm.name.clone()));
        o.insert("m".to_string(), num(m as f64));
        o.insert("k".to_string(), num(k as f64));
        o.insert("n".to_string(), num(n as f64));
        o.insert("gflops".to_string(), num(gflops));
        per_layer.push(Json::Obj(o));
    }

    // ---- BENCH_native.json ---------------------------------------------
    let mut root = BTreeMap::new();
    root.insert("schema".to_string(), num(1.0));
    root.insert("bench".to_string(), Json::Str("serving".to_string()));
    root.insert("backend".to_string(), Json::Str("native".to_string()));
    root.insert("vid".to_string(), Json::Str(spec.vid.clone()));
    root.insert("threads".to_string(), num(threads as f64));
    root.insert("clients".to_string(), num(CLIENTS as f64));
    root.insert("requests_per_client".to_string(), num(per_client as f64));
    root.insert("max_batch".to_string(), num(max_batch as f64));
    // headline metrics (the regression gate reads `req_s`)
    root.insert("req_s".to_string(), num(rps_batched));
    root.insert("p50_us".to_string(), num(m_batched.p50_us));
    root.insert("p99_us".to_string(), num(m_batched.p99_us));
    root.insert("speedup_vs_single".to_string(), num(speedup));
    root.insert("single".to_string(), mode_json(rps_single, &m_single));
    root.insert("batched".to_string(), mode_json(rps_batched, &m_batched));
    root.insert("per_layer_gemm".to_string(), Json::Arr(per_layer));
    save_json("BENCH_native.json", &Json::Obj(root));

    let _ = std::fs::remove_dir_all(&dir);

    // ---- gates ----------------------------------------------------------
    if let Some(baseline) = &opts.baseline {
        bench::check_regression(rps_batched, std::path::Path::new(baseline),
                                "req_s", 0.30)?;
    }
    if speedup < 2.0 {
        let msg = format!(
            "batched speedup {speedup:.2}x is below the 2x target \
             (machine-dependent; {threads} lanes available)"
        );
        if opts.strict {
            anyhow::bail!("{msg}");
        }
        eprintln!("[bench_serving] warning: {msg}");
    }
    Ok(())
}
