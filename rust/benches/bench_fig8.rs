//! Figure 8: layer-wise and whole-model TOPS vs TOPS/W scatter for both
//! AnalogNets on the AON-CiM accelerator (8-bit activations).
//!
//! Trends to reproduce: (1) larger layers amortize DAC/ADC cost -> higher
//! TOPS and TOPS/W; (2) at equal size, taller layers (more rows, fewer
//! columns) are more efficient because ADCs dominate periphery energy;
//! (3) KWS (tall layers) beats VWW overall.  The dotted "limit" line is the
//! array-only roofline with zero periphery energy.

use analognets::bench::save;
use analognets::crossbar::ArrayGeom;
use analognets::mapping::map_model;
use analognets::runtime::ArtifactStore;
use analognets::timing::{model_perf, t_cim_ns, EnergyModel};
use analognets::util::table::Table;

fn main() -> anyhow::Result<()> {
    let store = ArtifactStore::open_default()?;
    let em = EnergyModel::default();
    let geom = ArrayGeom::AON;
    let bits = 8;

    let mut csv = String::from("model,layer,weights,aspect,tops,tops_w\n");
    let mut t = Table::new(
        "Figure 8: per-layer TOPS vs TOPS/W (8-bit)",
        &["model", "layer", "weights", "rows x cols", "TOPS", "TOPS/W"],
    );

    for (vid, name) in [("kws_full_e10_8b", "AnalogNet-KWS"),
                        ("vww_full_e10_8b", "AnalogNet-VWW")] {
        let meta = store.meta(vid)?;
        let mapping = map_model(&meta, geom)?;
        let p = model_perf(&mapping, bits, &em);
        for (lp, ml) in p.layers.iter().zip(mapping.layers.iter()) {
            t.row(&[name.into(), lp.name.clone(), format!("{}", lp.weights),
                    format!("{}x{}", ml.rows, ml.cols),
                    format!("{:.4}", lp.tops), format!("{:.2}", lp.tops_w)]);
            csv.push_str(&format!("{name},{},{},{:.3},{:.5},{:.3}\n",
                                  lp.name, lp.weights,
                                  ml.rows as f64 / ml.cols as f64,
                                  lp.tops, lp.tops_w));
        }
        t.row(&[name.into(), "== whole model ==".into(),
                format!("{}", meta.param_count()), "".into(),
                format!("{:.4}", p.tops), format!("{:.2}", p.tops_w)]);
        csv.push_str(&format!("{name},MODEL,{},0,{:.5},{:.3}\n",
                              meta.param_count(), p.tops, p.tops_w));
    }

    // array-only roofline (no ADC/DAC/digital energy): the dotted limit line
    let t_mvm = t_cim_ns(bits); // one phase
    let full_pulse = em.alpha_nj_per_ns * (1.0 - em.dac_fraction) * t_mvm;
    let limit = 2.0 * geom.cells() as f64 / (full_pulse * 4.0) / 1000.0;
    t.row(&["(limit)".into(), "array-only roofline".into(), "".into(),
            "".into(), "".into(), format!("{limit:.2}")]);
    t.print();
    save("fig8.txt", &t.render());
    save("fig8.csv", &csv);
    Ok(())
}
