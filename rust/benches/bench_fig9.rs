//! Figure 9 (Appendix A): MicroNet-KWS-S on the PCM CiM simulator —
//! all-layers-analog vs depthwise-on-digital-processor, over deployment
//! time and activation bitwidth.
//!
//! Trends to reproduce: depthwise-in-analog is strictly worse (the
//! zero-programmed expansion cells inject bitline noise); moving the
//! depthwise layers to a digital processor recovers part of the gap but
//! stays below AnalogNet-KWS (Figure 7); lower bitwidths amplify the
//! depthwise penalty.

use analognets::bench::{save, BenchOpts};
use analognets::eval::{drift_accuracy, EvalOpts};
use analognets::pcm::FIG7_TIMES;
use analognets::runtime::ArtifactStore;
use analognets::util::stats;
use analognets::util::table::Table;

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::from_env_args();
    let store = ArtifactStore::open_default()?;
    let times: Vec<f64> = FIG7_TIMES.iter().map(|(_, t)| *t).collect();

    let mut t = Table::new(
        "Figure 9: MicroNet-KWS-S accuracy (%) on the PCM simulator",
        &["config", "bits", "25s", "1h", "1d", "1mo", "1yr"],
    );
    let mut csv = String::from("config,bits,time_s,acc_mean,acc_std\n");

    for (vid, label) in [("micro_noise_e10", "all analog"),
                         ("microdig_noise_e10", "depthwise in digital (FP)")] {
        for bits in [8u32, 6, 4] {
            let e = EvalOpts {
                bits,
                runs: opts.runs,
                max_samples: opts.max_samples,
                backend: opts.backend,
                ..Default::default()
            };
            let accs = drift_accuracy(&store, vid, &times, &e)?;
            let mut cells = vec![label.to_string(), format!("{bits}")];
            for (ti, (_, ts)) in FIG7_TIMES.iter().enumerate() {
                let (m, s) = stats::acc_summary(&accs[ti]);
                cells.push(format!("{m:.1}+/-{s:.1}"));
                csv.push_str(&format!("{label},{bits},{ts},{m:.3},{s:.3}\n"));
            }
            t.row(&cells);
            eprintln!("[fig9] done: {label} @ {bits}b");
        }
    }
    t.print();
    save("fig9.txt", &t.render());
    save("fig9.csv", &csv);
    Ok(())
}
