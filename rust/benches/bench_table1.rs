//! Table 1: accuracy (%) after 24 hours of PCM drift, across training
//! methods and activation bitwidths.
//!
//! Paper rows: baseline (no re-training) collapses; vanilla noise injection
//! holds at 8-bit but collapses at 4-bit; noise injection + ADC/DAC
//! constraints degrades gracefully; the VWW bottleneck-layers variant is
//! worse than AnalogNet-VWW despite having more parameters.
//! Absolute values differ (synthetic datasets — DESIGN.md Substitutions);
//! those orderings are the reproduction target.

use analognets::bench::{save, BenchOpts};
use analognets::eval::{accuracy_24h, EvalOpts};
use analognets::runtime::ArtifactStore;
use analognets::util::table::Table;

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::from_env_args();
    let store = ArtifactStore::open_default()?;

    let rows: &[(&str, fn(u32) -> String)] = &[
        ("KWS baseline (no re-training)", |_| "kws_base".into()),
        ("KWS noise injection (eta=10%)", |_| "kws_noise_e10".into()),
        ("KWS noise + ADC/DAC constraints", |b| format!("kws_full_e10_{b}b")),
        ("VWW baseline (no re-training)", |_| "vww_base".into()),
        ("VWW noise injection (eta=10%)", |_| "vww_noise_e10".into()),
        ("VWW noise + ADC/DAC constraints", |b| format!("vww_full_e10_{b}b")),
        ("VWW bottleneck layers included", |b| format!("vwwbott_full_e10_{b}b")),
    ];

    let mut t = Table::new(
        "Table 1: accuracy (%) after 24h PCM drift (mean +/- std)",
        &["method", "8bit", "6bit", "4bit"],
    );
    let mut csv = String::from("method,bits,acc_mean,acc_std\n");
    for (label, vid_for) in rows {
        let mut cells = vec![label.to_string()];
        for bits in [8u32, 6, 4] {
            // variants whose vid embeds the bitwidth were trained at it;
            // heuristic-range variants share one set of weights across all
            let vid = vid_for(bits);
            let e = EvalOpts {
                bits,
                runs: opts.runs,
                max_samples: opts.max_samples,
                backend: opts.backend,
                ..Default::default()
            };
            match accuracy_24h(&store, &vid, &e) {
                Ok((m, s)) => {
                    cells.push(format!("{m:.1} +/- {s:.1}"));
                    csv.push_str(&format!("{label},{bits},{m:.3},{s:.3}\n"));
                }
                Err(err) => cells.push(format!("n/a ({err})")),
            }
        }
        t.row(&cells);
        eprintln!("[table1] done: {label}");
    }
    t.print();
    save("table1.txt", &t.render());
    save("table1.csv", &csv);
    Ok(())
}
