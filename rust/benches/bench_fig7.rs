//! Figure 7: accuracy over deployment time (25s .. 1 year) for AnalogNet-KWS
//! and AnalogNet-VWW across training noise levels eta and activation
//! bitwidths, mean +/- std over repeated programming runs.
//!
//! The default artifact bundle carries eta = 10%; `make artifacts-sweep`
//! adds the full eta sweep (KWS: 2/5/10/20%, VWW: 5/10/20%).  This bench
//! evaluates whatever subset is present.

use analognets::bench::{save, BenchOpts};
use analognets::eval::{drift_accuracy, EvalOpts};
use analognets::pcm::FIG7_TIMES;
use analognets::runtime::ArtifactStore;
use analognets::util::stats;
use analognets::util::table::Table;

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::from_env_args();
    let store = ArtifactStore::open_default()?;
    let times: Vec<f64> = FIG7_TIMES.iter().map(|(_, t)| *t).collect();

    let mut csv = String::from("task,eta,bits,time_s,acc_mean,acc_std\n");
    let mut t = Table::new(
        "Figure 7: accuracy (%) vs deployment time (mean over runs)",
        &["variant", "25s", "1h", "1d", "1mo", "1yr"],
    );

    let mut vids: Vec<(String, String, u32, u32)> = Vec::new(); // vid, task, eta, bits
    for e in &store.manifest.variants {
        let vid = &e.vid;
        if let Some(rest) = vid.find("_full_e") {
            let tail = &vid[rest + 7..];
            if let Some((eta_s, bits_s)) = tail.split_once('_') {
                let eta: u32 = eta_s.parse().unwrap_or(0);
                let bits: u32 = bits_s.trim_end_matches('b').parse().unwrap_or(8);
                if vid.starts_with("kws") || vid.starts_with("vww_") {
                    vids.push((vid.clone(), e.task.clone(), eta, bits));
                }
            }
        }
    }
    vids.sort();

    for (vid, task, eta, bits) in vids {
        let e = EvalOpts {
            bits,
            runs: opts.runs,
            max_samples: opts.max_samples,
            backend: opts.backend,
            ..Default::default()
        };
        let accs = drift_accuracy(&store, &vid, &times, &e)?;
        let mut cells = vec![vid.clone()];
        for (ti, (_, ts)) in FIG7_TIMES.iter().enumerate() {
            let (m, s) = stats::acc_summary(&accs[ti]);
            cells.push(format!("{m:.1}+/-{s:.1}"));
            csv.push_str(&format!("{task},{eta},{bits},{ts},{m:.3},{s:.3}\n"));
        }
        t.row(&cells);
        eprintln!("[fig7] done: {vid}");
    }
    t.print();
    save("fig7.txt", &t.render());
    save("fig7.csv", &csv);
    Ok(())
}
