//! Figures 6 and 11: CiM array mapping visualizations.
//!
//! Fig 6: AnalogNet-KWS (paper: 57.3% util) and AnalogNet-VWW (67.5%) shelf-
//! packed onto the single 1024x512 array.  Fig 11: MicroNet-KWS-S with its
//! depthwise diagonal expansions on 1024x512 / 128x128 / 64x64 crossbars.

use analognets::bench::save;
use analognets::crossbar::ArrayGeom;
use analognets::mapping::{layout, map_model, split_map_model};
use analognets::runtime::ArtifactStore;

fn main() -> anyhow::Result<()> {
    let store = ArtifactStore::open_default()?;

    // ---- Figure 6 ----------------------------------------------------
    for (vid, name, paper) in [
        ("kws_full_e10_8b", "AnalogNet-KWS", 57.3),
        ("vww_full_e10_8b", "AnalogNet-VWW", 67.5),
    ] {
        let meta = store.meta(vid)?;
        let m = map_model(&meta, ArrayGeom::AON)?;
        let map = layout::ascii_map(&m, 64, 24);
        println!("\n=== Figure 6: {name} on 1024x512 \
                  (paper utilization {paper}%) ===");
        print!("{map}");
        save(&format!("fig6_{name}.txt"), &map);
        save(&format!("fig6_{name}.csv"), &layout::csv_map(&m));
    }

    // ---- Figure 11 ---------------------------------------------------
    let meta = store.meta("micro_noise_e10")?;
    let m = map_model(&meta, ArrayGeom::AON)?;
    println!("\n=== Figure 11a: MicroNet-KWS-S on 1024x512 (depthwise \
              diagonals dominate allocation) ===");
    let map = layout::ascii_map(&m, 64, 24);
    print!("{map}");
    save("fig11a.txt", &map);
    println!("  effective utilization {:.1}% (paper: ~9%)",
             100.0 * m.effective_utilization());

    let mut csv = String::from("config,layer,alloc_tiles,grid_tiles,row_splits\n");
    for (label, geom) in [("128x128", ArrayGeom::new(128, 128, 4)?),
                          ("64x64", ArrayGeom::new(64, 64, 4)?)] {
        let s = split_map_model(&meta, geom);
        println!("\n=== Figure 11b/c: MicroNet-KWS-S split onto {label} \
                  tiles: {} tiles, eff util {:.1}% ===",
                 s.alloc_tiles(), 100.0 * s.effective_utilization());
        for l in &s.layers {
            println!("  {:<6} {:>4}x{:<4} tiles {}/{} row-splits {}",
                     l.name, l.rows, l.cols, l.alloc_tiles, l.grid_tiles,
                     l.row_splits);
            csv.push_str(&format!("{label},{},{},{},{}\n", l.name,
                                  l.alloc_tiles, l.grid_tiles, l.row_splits));
        }
    }
    save("fig11_split.csv", &csv);
    Ok(())
}
