//! Runtime microbenchmarks (§6.4 infrastructure + §Perf L3 numbers):
//! backend execute latency across batch sizes (native by default,
//! `--backend pjrt` with the feature), batcher overhead, PCM read/GDC
//! cost, and native-GEMM throughput.

use analognets::backend::{self, InferenceBackend};
use analognets::bench::{save, time_it, BenchOpts};
use analognets::coordinator::{Coordinator, ServeConfig};
use analognets::eval::DeployedModel;
use analognets::pcm::PcmParams;
use analognets::runtime::ArtifactStore;
use analognets::simulator::gemm;
use analognets::util::rng::Rng;
use analognets::util::table::Table;

fn main() -> anyhow::Result<()> {
    let opts = BenchOpts::from_env_args();
    let kind = opts.backend;
    let iters = if opts.fast { 5 } else { 20 };
    let store = ArtifactStore::open_default()?;
    let mut t = Table::new("Runtime microbenchmarks",
                           &["benchmark", "result"]);

    // ---- backend execute latency by batch (kws serving graphs) --------
    let vid = "kws_full_e10_8b";
    let ds = store.dataset("kws")?;
    let be = backend::create(kind, &store, vid, 8)?;
    let params = PcmParams::default();
    let mut rng = Rng::new(1);
    let dep = DeployedModel::program(&store, vid, &params, &mut rng)?;
    let (ws, alphas) = dep.read_at(25.0, &params, &mut rng, true);

    let mut per_inf_us = Vec::new();
    let sizes = be.batch_sizes();
    for batch in [1usize, 8, 32, 128] {
        if !sizes.contains(&batch) {
            continue;
        }
        be.prepare(batch)?;
        let xb = ds.padded_batch(0, batch);
        let iopts = analognets::backend::InferOpts::default();
        let timing = time_it(3, iters, || {
            let _ = be.run_batch(&xb, batch, &ws, &alphas, &iopts).unwrap();
        });
        per_inf_us.push((batch, timing.p50_us / batch as f64));
        t.row(&[format!("{} exec kws batch={batch}", be.name()),
                format!("{timing} ({:.1}us/inf)", timing.p50_us / batch as f64)]);
    }

    // ---- PCM read + GDC cost ------------------------------------------
    let timing = time_it(1, iters, || {
        let _ = dep.read_at(86_400.0, &params, &mut Rng::new(9), true);
    });
    t.row(&["PCM read_weights+GDC (307k w)".into(), format!("{timing}")]);

    // ---- coordinator end-to-end overhead vs raw execute ----------------
    let mut cfg = ServeConfig::new(vid, 8).with_backend(kind);
    cfg.max_wait = std::time::Duration::from_micros(200);
    let coord = Coordinator::start(cfg)?;
    let feat = ds.feat_len();
    let n = if opts.fast { 50 } else { 200 };
    let timing = time_it(5, n, || {
        let i = 3 % ds.len();
        let _ = coord.infer(ds.x[i * feat..(i + 1) * feat].to_vec()).unwrap();
    });
    t.row(&[format!("coordinator blocking infer (batch=1, {})", kind),
            format!("{timing}")]);
    let summary = coord.metrics.summary();
    t.row(&["coordinator metrics".into(), format!("{summary}")]);
    coord.stop()?;

    // ---- native GEMM throughput (simulator substrate) ------------------
    // blocked packed kernel (the serving path) vs the legacy row-parallel
    // loop at each lane count, on one large representative shape
    let (m, k, n2) = (4096, 576, 128);
    let mut r = Rng::new(3);
    let a: Vec<f32> = (0..m * k).map(|_| r.gauss(0.0, 1.0) as f32).collect();
    let b: Vec<f32> = (0..k * n2).map(|_| r.gauss(0.0, 1.0) as f32).collect();
    let macs = 2.0 * (m * k * n2) as f64;
    for threads in [1usize, 4, 8, 0] {
        let label = if threads == 0 {
            format!("auto({})", gemm::effective_threads(0))
        } else {
            threads.to_string()
        };
        let t_blk = time_it(1, 5, || {
            let _ = gemm::gemm_parallel(&a, &b, m, k, n2, threads);
        });
        let t_row = time_it(1, 5, || {
            let _ = gemm::gemm_rowpar(&a, &b, m, k, n2, threads);
        });
        let gf_blk = macs / (t_blk.min_us * 1e3);
        let gf_row = macs / (t_row.min_us * 1e3);
        t.row(&[format!("native GEMM 4096x576x128 t={label}"),
                format!("blocked {:.1}ms min, {gf_blk:.1} GFLOP/s \
                         (rowpar {gf_row:.1}, {:.2}x)",
                        t_blk.min_us / 1e3, gf_blk / gf_row)]);
    }

    t.print();
    save("runtime_bench.txt", &t.render());
    if let Some((b, us)) = per_inf_us.last() {
        println!("[runtime] best per-inference latency: {us:.1}us at batch {b}");
    }
    Ok(())
}
