//! Table 2: AON-CiM accelerator summary — peak TOPS / TOPS/W at 8/6/4-bit
//! activation precision, and per-model throughput / inference rate / energy
//! of AnalogNet-KWS and AnalogNet-VWW.

use analognets::bench::save;
use analognets::crossbar::ArrayGeom;
use analognets::mapping::map_model;
use analognets::runtime::ArtifactStore;
use analognets::timing::{model_perf, peak, t_cim_ns, EnergyModel};
use analognets::util::table::Table;

fn main() -> anyhow::Result<()> {
    let store = ArtifactStore::open_default()?;
    let em = EnergyModel::default();
    let geom = ArrayGeom::AON;

    let mut t = Table::new(
        "Table 2: AON-CiM accelerator summary",
        &["metric", "8bit", "6bit", "4bit", "paper (8/6/4)"],
    );
    let mut csv = String::from("metric,bits,value\n");

    let mut peak_tops = Vec::new();
    let mut peak_topsw = Vec::new();
    for bits in [8u32, 6, 4] {
        let (tp, tw) = peak(geom, bits, &em);
        csv.push_str(&format!("peak_tops,{bits},{tp:.4}\n"));
        csv.push_str(&format!("peak_tops_w,{bits},{tw:.4}\n"));
        peak_tops.push(format!("{tp:.2}"));
        peak_topsw.push(format!("{tw:.2}"));
    }
    t.row(&["T_CiM (ns)".into(), t_cim_ns(8).to_string(), t_cim_ns(6).to_string(),
            t_cim_ns(4).to_string(), "130 / 34 / 10".into()]);
    t.row(&["peak TOPS".into(), peak_tops[0].clone(), peak_tops[1].clone(),
            peak_tops[2].clone(), "2 / 7.71 / 26.21".into()]);
    t.row(&["peak TOPS/W".into(), peak_topsw[0].clone(), peak_topsw[1].clone(),
            peak_topsw[2].clone(), "13.55 / 45.55 / 112.44".into()]);

    for (task, vid, paper_tops, paper_topsw) in [
        ("KWS", "kws_full_e10_8b", "0.6 / 2.29 / 7.8", "8.58 / 26.76 / 57.39"),
        ("VWW", "vww_full_e10_8b", "0.076 / 0.29 / 0.98", "4.37 / 12.82 / 25.69"),
    ] {
        let meta = store.meta(vid)?;
        let mapping = map_model(&meta, geom)?;
        let (mut tops, mut topsw, mut infs, mut uj) =
            (Vec::new(), Vec::new(), Vec::new(), Vec::new());
        for bits in [8u32, 6, 4] {
            let p = model_perf(&mapping, bits, &em);
            tops.push(format!("{:.3}", p.tops));
            topsw.push(format!("{:.2}", p.tops_w));
            infs.push(format!("{:.0}", p.inf_per_sec));
            uj.push(format!("{:.2}", p.uj_per_inf));
            for (k, v) in [("tops", p.tops), ("tops_w", p.tops_w),
                           ("inf_s", p.inf_per_sec), ("uj_inf", p.uj_per_inf)] {
                csv.push_str(&format!("{task}_{k},{bits},{v:.4}\n"));
            }
        }
        t.row(&[format!("{task} TOPS"), tops[0].clone(), tops[1].clone(),
                tops[2].clone(), paper_tops.into()]);
        t.row(&[format!("{task} TOPS/W"), topsw[0].clone(), topsw[1].clone(),
                topsw[2].clone(), paper_topsw.into()]);
        t.row(&[format!("{task} inf/s"), infs[0].clone(), infs[1].clone(),
                infs[2].clone(),
                if task == "KWS" { "7762 (8b)".into() } else { "1063 (8b)".into() }]);
        t.row(&[format!("{task} uJ/inf"), uj[0].clone(), uj[1].clone(),
                uj[2].clone(),
                if task == "KWS" { "8.22 (8b)".into() } else { "15.6 (8b)".into() }]);
        t.row(&[format!("{task} array util"),
                format!("{:.1}%", 100.0 * mapping.allocated_utilization()),
                "".into(), "".into(),
                if task == "KWS" { "57.3% (Fig 6)".into() }
                else { "67.5% (Fig 6)".into() }]);
    }
    t.print();
    save("table2.txt", &t.render());
    save("table2.csv", &csv);
    Ok(())
}
