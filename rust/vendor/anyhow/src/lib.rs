//! Minimal, API-compatible shim of the `anyhow` crate for the offline
//! build environment (the crates.io registry is not vendored here).
//!
//! Implements the subset the workspace uses: [`Error`], [`Result`], the
//! blanket `From<E: std::error::Error>` conversion that makes `?` work, and
//! the `anyhow!` / `bail!` / `ensure!` macros. Error *chains* and
//! downcasting are intentionally out of scope — the wrapped error is
//! flattened to its `Display` rendering at conversion time.

use std::fmt;

/// A type-erased error, rendered eagerly to a message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything printable (the `anyhow!` macro calls this).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` on real anyhow prints the whole chain; the shim carries a
        // single flattened message, so both renderings coincide.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`: that keeps this blanket conversion coherent with the
// reflexive `From<T> for T` impl in core.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(&e)
    }
}

/// `anyhow::Result<T>`: `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(
                ::std::concat!("condition failed: ", ::std::stringify!($cond)),
            ));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/7f3a")?;
        Ok(())
    }

    fn ensures(x: i32) -> Result<i32> {
        ensure!(x > 0, "x must be positive, got {x}");
        Ok(x)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn macros_build_messages() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let n = 3;
        let e = anyhow!("n = {}", n);
        assert_eq!(e.to_string(), "n = 3");
        let e = anyhow!("n = {n}");
        assert_eq!(e.to_string(), "n = 3");
        assert!(ensures(5).is_ok());
        assert_eq!(
            ensures(-1).unwrap_err().to_string(),
            "x must be positive, got -1"
        );
    }

    #[test]
    fn alternate_format_matches_plain() {
        let e = anyhow!("msg");
        assert_eq!(format!("{e:#}"), format!("{e}"));
    }
}
