//! Build-only stub of the `xla` crate (xla-rs, PJRT CPU backend).
//!
//! The real crate links the XLA native library (`xla_extension`), which is
//! not present in hermetic CI environments. This stub mirrors the API
//! surface `analognets::runtime` uses so `--features pjrt` always *type
//! checks*; attempting to create a [`PjRtClient`] at runtime returns a
//! descriptive error instead. To run real HLO graphs, replace the `xla`
//! path dependency in `rust/Cargo.toml` with a real xla-rs checkout.

use std::fmt;

/// Error type standing in for `xla::Error`.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn stub() -> Self {
        Error(
            "xla stub crate: the real XLA/PJRT native library is not linked \
             in this build; see rust/Cargo.toml `[dependencies] xla` to swap \
             in a real xla-rs checkout"
                .to_string(),
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host literal (stub: shape bookkeeping only, no device buffers).
#[derive(Clone, Debug)]
pub struct Literal {
    dims: Vec<i64>,
    len: usize,
}

impl Literal {
    pub fn vec1(data: &[f32]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            len: data.len(),
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.len {
            return Err(Error(format!(
                "reshape: {} elements into shape {dims:?}",
                self.len
            )));
        }
        Ok(Literal {
            dims: dims.to_vec(),
            len: self.len,
        })
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::stub())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::stub())
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module proto (stub: checks the file exists and is readable).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        std::fs::metadata(path)
            .map_err(|e| Error(format!("read {path}: {e}")))?;
        Ok(HloModuleProto)
    }
}

/// XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client. The stub cannot create one: this is the single runtime
/// choke point that reports the missing native library.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub())
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub())
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_reports_stub() {
        let err = PjRtClient::cpu().err().expect("stub must refuse");
        assert!(err.to_string().contains("xla stub"));
    }

    #[test]
    fn literal_shape_math() {
        let l = Literal::vec1(&[0.0; 6]);
        assert!(l.reshape(&[2, 3]).is_ok());
        assert!(l.reshape(&[4, 2]).is_err());
        assert_eq!(l.dims(), &[6]);
    }
}
