//! Coordinator end-to-end: concurrent clients, batching behaviour, drift
//! clock, metrics.  Requires `make artifacts` (skips otherwise).

mod common;

use std::sync::Arc;
use std::time::Duration;

use analognets::coordinator::{batcher, Coordinator, ServeConfig};

fn serving_cfg(vid: &str) -> ServeConfig {
    let mut cfg = ServeConfig::new(vid, 8);
    cfg.max_wait = Duration::from_millis(1);
    cfg.time_scale = 1e4;
    cfg
}

#[test]
fn concurrent_clients_all_served() {
    let Some(store) = common::store_or_skip("concurrent_clients") else {
        return;
    };
    let Some(vid) = common::pick_vid(&store, &["kws_full_e10_8b"]) else {
        return;
    };
    let meta = store.meta(&vid).unwrap();
    if meta.hlo_keys().iter().filter(|(b, _)| *b == 8).count() < 2 {
        eprintln!("SKIP: {vid} has no serving graphs");
        return;
    }
    let ds = Arc::new(store.dataset("kws").unwrap());
    drop(store);

    let coord = Arc::new(Coordinator::start(serving_cfg(&vid)).unwrap());
    let feat = ds.feat_len();
    let clients = 8;
    let per_client = 20;
    let mut handles = Vec::new();
    for c in 0..clients {
        let coord = coord.clone();
        let ds = ds.clone();
        handles.push(std::thread::spawn(move || {
            let mut ok = 0usize;
            for i in 0..per_client {
                let s = (c * 31 + i) % ds.len();
                let resp = coord
                    .infer(ds.x[s * feat..(s + 1) * feat].to_vec())
                    .unwrap();
                ok += (resp.pred == ds.y[s]) as usize;
                assert_eq!(resp.logits.len(), 12);
            }
            ok
        }));
    }
    let total_ok: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let m = coord.metrics.summary();
    assert_eq!(m.completed as usize, clients * per_client);
    // concurrent submission must produce some multi-request launches
    assert!(m.launches <= m.completed, "{m}");
    // the model should be right most of the time even while drifting
    assert!(total_ok * 2 > clients * per_client, "accuracy collapsed: {total_ok}");
    eprintln!("coordinator metrics: {m}");
}

#[test]
fn rejects_bad_feature_length() {
    let Some(store) = common::store_or_skip("rejects_bad_feature_length") else {
        return;
    };
    let Some(vid) = common::pick_vid(&store, &["kws_full_e10_8b"]) else {
        return;
    };
    drop(store);
    let coord = Coordinator::start(serving_cfg(&vid)).unwrap();
    assert!(coord.submit(vec![0.0; 3]).is_err());
    coord.stop().unwrap();
}

#[test]
fn drift_clock_advances_during_serving() {
    let Some(store) = common::store_or_skip("drift_clock_advances") else {
        return;
    };
    let Some(vid) = common::pick_vid(&store, &["kws_full_e10_8b"]) else {
        return;
    };
    let ds = store.dataset("kws").unwrap();
    drop(store);
    let mut cfg = serving_cfg(&vid);
    cfg.time_scale = 1e6; // ~1 sim-day per wall-ms
    let coord = Coordinator::start(cfg).unwrap();
    let feat = ds.feat_len();
    let r1 = coord.infer(ds.x[..feat].to_vec()).unwrap();
    std::thread::sleep(Duration::from_millis(30));
    let r2 = coord.infer(ds.x[..feat].to_vec()).unwrap();
    assert!(r2.sim_age_s > r1.sim_age_s + 1e4,
            "clock stuck: {} -> {}", r1.sim_age_s, r2.sim_age_s);
    coord.stop().unwrap();
}

// ---------------------------------------------------------------------------
// batcher plan properties (no artifacts needed)
// ---------------------------------------------------------------------------

#[test]
fn prop_batch_plans_cover_queue() {
    use analognets::util::rng::Rng;
    let mut rng = Rng::new(77);
    for _ in 0..200 {
        let queued = 1 + rng.below(300);
        let sizes = vec![1, 8, 32];
        let plan = batcher::plan(queued, sizes.clone());
        let total: usize = plan.launches.iter().sum();
        assert_eq!(total, queued + plan.padding);
        assert!(plan.padding < 32);
        for l in &plan.launches {
            assert!(sizes.contains(l));
        }
    }
}
