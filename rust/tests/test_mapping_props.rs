//! Property tests on the mapper: placements are disjoint and in bounds for
//! random synthetic models; split mappings cover every weight; timing
//! invariants hold across geometries.

use analognets::crossbar::ArrayGeom;
use analognets::mapping::{map_model, slice_tile, split_map_model, tile_grid};
use analognets::nn::meta::ModelMeta;
use analognets::timing::perf::split_inference_rate;
use analognets::timing::{model_perf, EnergyModel};
use analognets::util::json;
use analognets::util::rng::Rng;

/// Build a random plausible model meta (layers sized to fit 1024x512).
fn random_meta(rng: &mut Rng) -> ModelMeta {
    let n_layers = 2 + rng.below(6);
    let mut in_ch = 1 + rng.below(4);
    let mut layers = String::new();
    let mut budget = 1024 * 512 / 2; // keep total under half the array
    for li in 0..n_layers {
        let kind = match rng.below(if li == n_layers - 1 { 1 } else { 3 }) {
            _ if li == n_layers - 1 => "dense",
            0 => "conv3x3",
            1 => "conv1x1",
            _ => "dw3x3",
        };
        let out_ch = if kind == "dw3x3" { in_ch } else { 4 + rng.below(96) };
        let k = match kind {
            "conv3x3" | "dw3x3" => 9 * in_ch,
            _ => in_ch,
        };
        if k > 1024 || k * out_ch > budget {
            break;
        }
        budget -= k * out_ch;
        let wshape = if kind == "dw3x3" {
            format!("[9,{in_ch}]")
        } else {
            format!("[{k},{out_ch}]")
        };
        let pix = 1 + rng.below(20);
        if li > 0 {
            layers.push(',');
        }
        layers.push_str(&format!(
            r#"{{"name":"l{li}","kind":"{kind}","in_ch":{in_ch},"out_ch":{out_ch},
            "stride":[1,1],"relu":true,"analog":true,
            "in_h":{pix},"in_w":1,"out_h":{pix},"out_w":1,
            "k_gemm":{k},"weight_shape":{wshape},
            "graph_weight_shape":[{k},{out_ch}],
            "w_scale":1,"w_max":1,"r_dac":1,"r_adc":1,
            "dig_scale":[{s}],"dig_bias":[{b}]}}"#,
            s = vec!["1"; out_ch].join(","),
            b = vec!["0"; out_ch].join(","),
        ));
        in_ch = out_ch;
    }
    let src = format!(
        r#"{{"model":"rand","variant":"v","input_hwc":[8,1,1],
        "num_classes":2,"eta":0,"fp_test_acc":1,"trained_adc_bits":null,
        "layers":[{layers}],"hlo":{{}}}}"#
    );
    ModelMeta::from_json(&json::parse(&src).unwrap()).unwrap()
}

#[test]
fn prop_placements_disjoint_in_bounds() {
    let mut rng = Rng::new(2001);
    for case in 0..40 {
        let meta = random_meta(&mut rng);
        if meta.layers.is_empty() {
            continue;
        }
        let Ok(m) = map_model(&meta, ArrayGeom::AON) else { continue };
        assert_eq!(m.layers.len(), meta.layers.len());
        for (i, a) in m.layers.iter().enumerate() {
            assert!(a.row0 + a.rows <= 1024 && a.col0 + a.cols <= 512,
                    "case {case}: {} out of bounds", a.name);
            for b in &m.layers[..i] {
                let overlap = a.row0 < b.row0 + b.rows && b.row0 < a.row0 + a.rows
                    && a.col0 < b.col0 + b.cols && b.col0 < a.col0 + a.cols;
                assert!(!overlap, "case {case}: {} overlaps {}", a.name, b.name);
            }
        }
        let u = m.allocated_utilization();
        assert!(u > 0.0 && u <= 1.0, "case {case}: util {u}");
        assert!(m.effective_utilization() <= u + 1e-12);
    }
}

#[test]
fn prop_split_covers_all_weights() {
    let mut rng = Rng::new(2002);
    for case in 0..30 {
        let meta = random_meta(&mut rng);
        if meta.layers.is_empty() {
            continue;
        }
        for geom in [ArrayGeom::new(128, 128, 4).unwrap(),
                     ArrayGeom::new(64, 64, 4).unwrap()] {
            let s = split_map_model(&meta, geom);
            for (sl, lm) in s.layers.iter().zip(meta.layers.iter()) {
                // allocated tile area must cover every non-zero weight
                assert!(sl.alloc_tiles * geom.cells() >= sl.effective,
                        "case {case} {}: tiles cannot hold weights", sl.name);
                assert!(sl.alloc_tiles <= sl.grid_tiles);
                assert!(sl.row_splits >= 1);
                assert_eq!(sl.effective, lm.effective_weights());
            }
            let u = s.effective_utilization();
            assert!(u > 0.0 && u <= 1.0, "case {case}: split util {u}");
        }
    }
}

/// Satellite invariant behind the AnalogCim engine: for random rectangles
/// and geometries (mux ratios included), every execution tile fits the
/// array bounds, the grid covers the rectangle exactly once, and writing
/// every tile's slice back at its origin reconstructs the dense weight
/// matrix bit-exactly — ragged edge tiles included.
#[test]
fn prop_tiles_fit_bounds_and_reassemble_bit_exact() {
    let mut rng = Rng::new(2004);
    for case in 0..60 {
        let k = 1 + rng.below(300);
        let n = 1 + rng.below(200);
        let g_rows = 1 + rng.below(96);
        let mux = [1, 2, 4][rng.below(3)];
        let g_cols = mux * (1 + rng.below(64));
        let geom = ArrayGeom::new(g_rows, g_cols, mux).unwrap();
        let tiles = tile_grid(k, n, geom);
        assert_eq!(tiles.len(),
                   k.div_ceil(geom.rows) * n.div_ceil(geom.cols),
                   "case {case}: grid size");
        let mut area = 0usize;
        for t in &tiles {
            assert!(t.rows >= 1 && t.rows <= geom.rows,
                    "case {case}: tile rows {} exceed array {}", t.rows,
                    geom.rows);
            assert!(t.cols >= 1 && t.cols <= geom.cols,
                    "case {case}: tile cols {} exceed array {}", t.cols,
                    geom.cols);
            assert!(t.k0 + t.rows <= k && t.n0 + t.cols <= n,
                    "case {case}: tile out of rectangle bounds");
            assert_eq!((t.k0, t.n0), (t.kt * geom.rows, t.ct * geom.cols),
                       "case {case}: tile origin disagrees with grid index");
            area += t.rows * t.cols;
        }
        assert_eq!(area, k * n, "case {case}: tiles must cover exactly once");

        // bit-exact reassembly from per-tile slices
        let w: Vec<f32> = (0..k * n).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
        let mut rebuilt = vec![7777.0f32; k * n];
        for t in &tiles {
            let s = slice_tile(&w, n, t);
            assert_eq!(s.len(), t.rows * t.cols);
            for (ri, row) in s.chunks_exact(t.cols).enumerate() {
                let dst = (t.k0 + ri) * n + t.n0;
                rebuilt[dst..dst + t.cols].copy_from_slice(row);
            }
        }
        assert_eq!(rebuilt, w, "case {case}: reassembly must be bit-exact");
    }
}

#[test]
fn prop_timing_monotone() {
    // for any mapping: lower bitwidth => faster + more efficient;
    // split mapping on smaller arrays is never faster than whole-array
    let mut rng = Rng::new(2003);
    let em = EnergyModel::default();
    for case in 0..20 {
        let meta = random_meta(&mut rng);
        if meta.layers.is_empty() {
            continue;
        }
        let Ok(m) = map_model(&meta, ArrayGeom::AON) else { continue };
        let p8 = model_perf(&m, 8, &em);
        let p4 = model_perf(&m, 4, &em);
        assert!(p4.latency_ns < p8.latency_ns, "case {case}");
        assert!(p4.energy_nj < p8.energy_nj, "case {case}");
        assert!(p8.ops == p4.ops);

        let s = split_map_model(&meta, ArrayGeom::new(64, 64, 4).unwrap());
        let r_split = split_inference_rate(&s, 8, &em);
        assert!(r_split <= p8.inf_per_sec * 1.001,
                "case {case}: split faster than whole ({r_split} vs {})",
                p8.inf_per_sec);
    }
}
