//! Hermetic backend/serving integration: a synthetic artifact bundle
//! (manifest + meta + ANWT weights + ANDS dataset, no HLO files) written to
//! a temp directory, served end-to-end over `NativeBackend`.  Runs on a
//! fresh checkout with no `make artifacts`, no XLA library, and no `pjrt`
//! feature — this is the tier-1 coverage for the unified InferenceBackend
//! API: submit -> batch -> execute -> respond.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use analognets::backend::{BackendKind, InferOpts, InferenceBackend,
                          NativeBackend};
use analognets::coordinator::{Coordinator, ServeConfig};
use analognets::eval::{drift_accuracy, drift_accuracy_on, EvalOpts};
use analognets::pcm::PcmParams;
use analognets::runtime::ArtifactStore;
use analognets::util::rng::Rng;

const VID: &str = "tiny_native";

const META: &str = r#"{
  "model": "tiny_kws", "variant": "tiny", "input_hwc": [4, 4, 1],
  "num_classes": 2, "eta": 0.0, "fp_test_acc": 1.0, "trained_adc_bits": 8,
  "layers": [
    {"name": "c0", "kind": "conv3x3", "in_ch": 1, "out_ch": 2,
     "stride": [1, 1], "relu": true, "analog": true,
     "in_h": 4, "in_w": 4, "out_h": 4, "out_w": 4,
     "k_gemm": 9, "weight_shape": [9, 2], "graph_weight_shape": [9, 2],
     "w_scale": 1.0, "w_max": 1.0, "r_dac": 8.0, "r_adc": 8.0,
     "dig_scale": [1, 1], "dig_bias": [0, 0]},
    {"name": "fc", "kind": "dense", "in_ch": 2, "out_ch": 2,
     "stride": [1, 1], "relu": false, "analog": true,
     "in_h": 4, "in_w": 4, "out_h": 1, "out_w": 1,
     "k_gemm": 2, "weight_shape": [2, 2], "graph_weight_shape": [2, 2],
     "w_scale": 1.0, "w_max": 1.0, "r_dac": 8.0, "r_adc": 8.0,
     "dig_scale": [1, 1], "dig_bias": [0.3, 0.0]}
  ],
  "hlo": {}
}"#;

fn write_anwt(path: &Path, tensors: &[(&[u32], Vec<f32>)]) {
    let mut b = Vec::new();
    b.extend_from_slice(b"ANWT");
    b.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (shape, data) in tensors {
        b.extend_from_slice(&(shape.len() as u32).to_le_bytes());
        for &d in *shape {
            b.extend_from_slice(&d.to_le_bytes());
        }
        for v in data {
            b.extend_from_slice(&v.to_le_bytes());
        }
    }
    std::fs::write(path, b).unwrap();
}

fn write_ands(path: &Path, dims: &[u32], x: &[f32], y: &[u32]) {
    let mut b = Vec::new();
    b.extend_from_slice(b"ANDS");
    b.extend_from_slice(&(y.len() as u32).to_le_bytes());
    b.extend_from_slice(&(dims.len() as u32).to_le_bytes());
    for &d in dims {
        b.extend_from_slice(&d.to_le_bytes());
    }
    for v in x {
        b.extend_from_slice(&v.to_le_bytes());
    }
    for v in y {
        b.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, b).unwrap();
}

/// Write the complete synthetic bundle and return its directory.
fn synth_artifacts(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("analognets_backend_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        format!(
            r#"[{{"vid":"{VID}","task":"kws","model":"tiny_kws","eta":0.0,
                "trained_bits":8,"fp_test_acc":1.0,
                "meta":"{VID}.meta.json","weights":"{VID}.weights.bin",
                "hlo":{{}}}}]"#
        ),
    )
    .unwrap();
    std::fs::write(dir.join(format!("{VID}.meta.json")), META).unwrap();

    // conv: center tap -> ch0 at 1.0, ch1 at 0.5.  The dense head turns
    // pooled brightness into a threshold classifier: class 0's logit is the
    // constant dig_bias 0.3, class 1's logit is pooled ch0 (~0.17 for dim
    // frames, ~0.88 for bright ones) — separable well beyond the PCM
    // programming-noise margin.
    let mut w0 = vec![0f32; 18];
    w0[4 * 2] = 1.0;
    w0[4 * 2 + 1] = 0.5;
    let w1 = vec![0.0, 1.0, 0.0, 0.0];
    write_anwt(
        &dir.join(format!("{VID}.weights.bin")),
        &[(&[9, 2][..], w0), (&[2, 2][..], w1)],
    );

    // 8 labelled samples: label 1 = bright frames, label 0 = dim frames
    let n = 8usize;
    let feat = 16usize;
    let mut x = Vec::with_capacity(n * feat);
    let mut y = Vec::with_capacity(n);
    for s in 0..n {
        let bright = s % 2 == 1;
        let base = if bright { 0.8 } else { 0.1 };
        for i in 0..feat {
            x.push(base + 0.01 * (i as f32));
        }
        y.push(bright as u32);
    }
    write_ands(&dir.join("kws_test.bin"), &[4, 4, 1], &x, &y);
    dir
}

fn serving_cfg(dir: PathBuf) -> ServeConfig {
    let mut cfg = ServeConfig::new(VID, 8);
    cfg.artifacts_dir = dir;
    cfg.max_wait = Duration::from_millis(1);
    cfg.time_scale = 1e4;
    cfg
}

#[test]
fn native_coordinator_serves_end_to_end() {
    let dir = synth_artifacts("serve");
    let cfg = serving_cfg(dir);
    assert_eq!(cfg.backend, BackendKind::Native);
    let coord = Arc::new(Coordinator::start(cfg).unwrap());
    assert_eq!(coord.feat_len, 16);
    assert_eq!(coord.classes, 2);

    // concurrent clients force the batcher through the submit->drain path
    let clients = 4;
    let per_client = 10;
    let mut handles = Vec::new();
    for c in 0..clients {
        let coord = coord.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..per_client {
                let v = ((c * per_client + i) % 7) as f32 / 7.0;
                let resp = coord.infer(vec![v; 16]).unwrap();
                assert_eq!(resp.logits.len(), 2);
                assert!(resp.pred < 2);
                assert!(resp.sim_age_s >= 25.0, "age {}", resp.sim_age_s);
                assert!(resp.logits.iter().all(|l| l.is_finite()));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = coord.metrics.summary();
    assert_eq!(m.completed as usize, clients * per_client);
    assert_eq!(m.requests, m.completed);
    assert!(m.launches >= 1 && m.launches <= m.completed, "{m}");
    eprintln!("hermetic native coordinator metrics: {m}");
}

/// The layer-serial correctness invariant behind the coordinator's dynamic
/// batcher: one `run_batch(N)` over drifted PCM weights is bit-identical
/// to N sequential single-request runs — batching can never change a
/// served result, only its latency.
#[test]
fn batched_run_batch_is_bit_identical_to_sequential() {
    let dir = synth_artifacts("batchserial");
    let store = ArtifactStore::open(&dir).unwrap();
    let meta = store.meta(VID).unwrap();
    // multi-lane pool on purpose: chunked row dispatch must not change bits
    let be = NativeBackend::with_threads(meta, 8, 4);
    let params = PcmParams::default();
    let mut rng = Rng::new(33);
    let dep = analognets::eval::DeployedModel::program(&store, VID, &params,
                                                       &mut rng).unwrap();
    let (ws, alphas) = dep.read_at(3600.0, &params, &mut rng, true);

    let n = 6;
    let feat = 16;
    let mut x = Vec::with_capacity(n * feat);
    for s in 0..n {
        for i in 0..feat {
            x.push(0.05 * (s as f32 + 1.0) + 0.01 * i as f32);
        }
    }
    let opts = InferOpts::default();
    let batched = be.run_batch(&x, n, &ws, &alphas, &opts).unwrap();
    assert_eq!(batched.len(), n * 2);
    for s in 0..n {
        let one = be
            .run_batch(&x[s * feat..(s + 1) * feat], 1, &ws, &alphas, &opts)
            .unwrap();
        assert_eq!(one[..], batched[s * 2..(s + 1) * 2], "sample {s} diverged");
    }
}

#[test]
fn native_coordinator_rejects_bad_feature_length() {
    let dir = synth_artifacts("badlen");
    let coord = Coordinator::start(serving_cfg(dir)).unwrap();
    assert!(coord.submit(vec![0.0; 3]).is_err());
    coord.stop().unwrap();
}

#[test]
fn native_eval_runs_without_hlo_artifacts() {
    let dir = synth_artifacts("eval");
    let store = ArtifactStore::open(&dir).unwrap();
    let opts = EvalOpts {
        bits: 8,
        batch: 4,
        max_samples: 8,
        runs: 2,
        backend: BackendKind::Native,
        ..Default::default()
    };
    let accs = drift_accuracy(&store, VID, &[25.0, 86_400.0], &opts).unwrap();
    assert_eq!(accs.len(), 2);
    for per_time in &accs {
        assert_eq!(per_time.len(), opts.runs);
        for a in per_time {
            assert!((0.0..=1.0).contains(a), "accuracy out of range: {a}");
        }
    }
    // the bright/dim threshold task is separable with margin: fresh
    // accuracy must be high even with programming noise
    let fresh: f64 = accs[0].iter().sum::<f64>() / accs[0].len() as f64;
    assert!(fresh >= 0.75, "fresh accuracy collapsed: {fresh}");

    // the caller-constructed-backend hook must agree with the factory path
    // bit for bit (same EvalOpts seed => same programming/read noise)
    let meta = store.meta(VID).unwrap();
    let be = NativeBackend::new(meta, opts.bits);
    let accs_on =
        drift_accuracy_on(&be, &store, VID, &[25.0, 86_400.0], &opts).unwrap();
    assert_eq!(accs, accs_on);
}

#[cfg(not(feature = "pjrt"))]
#[test]
fn pjrt_backend_unavailable_without_feature() {
    let dir = synth_artifacts("nopjrt");
    // the factory refuses…
    let store = ArtifactStore::open(&dir).unwrap();
    let err = analognets::backend::create(BackendKind::Pjrt, &store, VID, 8)
        .err()
        .expect("pjrt must be unavailable in default builds");
    assert!(err.to_string().contains("pjrt"), "{err}");
    // …and so does the coordinator, with an early error on start
    let cfg = serving_cfg(dir).with_backend(BackendKind::Pjrt);
    assert!(Coordinator::start(cfg).is_err());
}
