//! The unified layer-pipeline executor's cross-engine invariants.
//!
//! `NativeModel` and `AnalogModel` are one `LayerExecutor` (the shared
//! layer-serial staging loop) driven by two `MatmulEngine`s. These tests
//! pin the property that motivated the refactor:
//!
//! * **staged-input bit-identity** — both engines observe *bit-identical*
//!   pre-matmul staged inputs per layer (im2col, pooling, DAC
//!   quantization are shared code, so they cannot drift apart), verified
//!   with a recording engine wrapper over random models/inputs;
//! * **single-tile unity-GDC regression** — tile-faithful execution on
//!   the AON array degenerates to the native reference bit for bit
//!   through the new executor, at the default and at overridden ADC
//!   bitwidths.

use std::sync::Mutex;

use analognets::crossbar::ArrayGeom;
use analognets::nn::ModelMeta;
use analognets::simulator::{LayerExecutor, MatmulCtx, MatmulEngine,
                            NativeGemmEngine, TileGridEngine, TilingScheme};
use analognets::util::json;
use analognets::util::rng::Rng;

/// Three-layer model covering every staged GEMM path: conv3x3 (im2col),
/// conv1x1 (pass-through), dense (global average pool).
fn meta3() -> ModelMeta {
    let src = r#"{
      "model": "pipe", "variant": "p", "input_hwc": [4, 4, 2],
      "num_classes": 2, "eta": 0.0, "fp_test_acc": 1.0,
      "trained_adc_bits": null,
      "layers": [
        {"name": "c0", "kind": "conv3x3", "in_ch": 2, "out_ch": 3,
         "stride": [1, 1], "relu": true, "analog": true,
         "in_h": 4, "in_w": 4, "out_h": 4, "out_w": 4,
         "k_gemm": 18, "weight_shape": [18, 3],
         "graph_weight_shape": [18, 3],
         "w_scale": 1.0, "w_max": 1.0, "r_dac": 8.0, "r_adc": 8.0,
         "dig_scale": [1, 1, 1], "dig_bias": [0, 0, 0]},
        {"name": "p1", "kind": "conv1x1", "in_ch": 3, "out_ch": 4,
         "stride": [1, 1], "relu": true, "analog": true,
         "in_h": 4, "in_w": 4, "out_h": 4, "out_w": 4,
         "k_gemm": 3, "weight_shape": [3, 4],
         "graph_weight_shape": [3, 4],
         "w_scale": 1.0, "w_max": 1.0, "r_dac": 8.0, "r_adc": 8.0,
         "dig_scale": [1, 1, 1, 1], "dig_bias": [0, 0, 0, 0]},
        {"name": "fc", "kind": "dense", "in_ch": 4, "out_ch": 2,
         "stride": [1, 1], "relu": false, "analog": true,
         "in_h": 4, "in_w": 4, "out_h": 1, "out_w": 1,
         "k_gemm": 4, "weight_shape": [4, 2],
         "graph_weight_shape": [4, 2],
         "w_scale": 1.0, "w_max": 1.0, "r_dac": 8.0, "r_adc": 8.0,
         "dig_scale": [1, 1], "dig_bias": [0.1, 0]}
      ],
      "hlo": {}
    }"#;
    ModelMeta::from_json(&json::parse(src).unwrap()).unwrap()
}

fn random_model(rng: &mut Rng, batch: usize)
                -> (Vec<f32>, Vec<Vec<f32>>) {
    let x: Vec<f32> = (0..batch * 4 * 4 * 2)
        .map(|_| rng.gauss(0.4, 0.3) as f32)
        .collect();
    let ws: Vec<Vec<f32>> = [18 * 3, 3 * 4, 4 * 2]
        .iter()
        .map(|&n| (0..n).map(|_| rng.gauss(0.0, 0.4) as f32).collect())
        .collect();
    (x, ws)
}

/// Wraps any engine and records the staged input handed to every analog
/// matmul — the observable the bit-identity property is stated over.
struct Recording<'e> {
    inner: &'e dyn MatmulEngine,
    staged: Mutex<Vec<(usize, Vec<f32>)>>,
}

impl<'e> Recording<'e> {
    fn over(inner: &'e dyn MatmulEngine) -> Self {
        Recording { inner, staged: Mutex::new(Vec::new()) }
    }

    fn take(&self) -> Vec<(usize, Vec<f32>)> {
        std::mem::take(&mut *self.staged.lock().unwrap())
    }
}

impl MatmulEngine for Recording<'_> {
    fn name(&self) -> &'static str {
        "recording"
    }

    fn analog_matmul(&self, ctx: &MatmulCtx<'_>, a: &[f32], w: &[f32],
                     out: &mut [f32]) {
        self.staged
            .lock()
            .unwrap()
            .push((ctx.layer_index, a.to_vec()));
        self.inner.analog_matmul(ctx, a, w, out);
    }
}

/// Property: over random models and inputs, the native and tile-faithful
/// engines observe bit-identical pre-matmul staged inputs at *every*
/// layer (single-tile AON geometry + unity GDC, so layer outputs — and
/// hence downstream staging — agree exactly), and their final logits are
/// bitwise equal.
#[test]
fn prop_engines_observe_bit_identical_staged_inputs() {
    let meta = meta3();
    let native_exec = LayerExecutor::new(meta.clone(), 2);
    let analog_exec = LayerExecutor::new(meta.clone(), 3);
    let native_engine = NativeGemmEngine::default();
    let analog_engine = TileGridEngine::new(&meta, ArrayGeom::AON);
    assert_eq!(analog_engine.tiles_total(), 3, "AON fits one tile per layer");

    let mut rng = Rng::new(0xBEEF);
    for case in 0..8 {
        let batch = 1 + case % 3;
        let (x, ws) = random_model(&mut rng, batch);
        let gdc = analognets::pcm::gdc::unity(3);

        let rec_n = Recording::over(&native_engine);
        let out_n = native_exec.forward(&rec_n, &x, batch, &ws, &gdc, 8);
        let rec_a = Recording::over(&analog_engine);
        let out_a = analog_exec.forward(&rec_a, &x, batch, &ws, &gdc, 8);

        let staged_n = rec_n.take();
        let staged_a = rec_a.take();
        assert_eq!(staged_n.len(), 3, "one staged block per analog layer");
        assert_eq!(staged_a.len(), 3);
        for ((li_n, a_n), (li_a, a_a)) in staged_n.iter().zip(staged_a.iter()) {
            assert_eq!(li_n, li_a);
            assert_eq!(a_n, a_a,
                       "case {case}: staged input of layer {li_n} diverged \
                        between engines");
        }
        assert_eq!(out_n, out_a, "case {case}: single-tile unity-GDC logits");
    }
}

/// Even when engine *outputs* diverge (multi-tile geometry, coarse ADC),
/// the first layer's staged input is engine-independent: staging happens
/// before any engine runs.
#[test]
fn first_layer_staging_is_engine_independent() {
    let meta = meta3();
    let exec = LayerExecutor::new(meta.clone(), 1);
    let native_engine = NativeGemmEngine::default();
    let tiled = TileGridEngine::new(&meta, ArrayGeom::new(4, 2, 1).unwrap());
    assert!(tiled.tiles_total() > 3, "geometry must split layers");

    let mut rng = Rng::new(0xF00D);
    let gdc = analognets::pcm::gdc::unity(3);
    let mut diverged = false;
    for case in 0..6 {
        let (x, ws) = random_model(&mut rng, 2);
        let rec_n = Recording::over(&native_engine);
        let out_n = exec.forward(&rec_n, &x, 2, &ws, &gdc, 4);
        let rec_t = Recording::over(&tiled);
        let out_t = exec.forward(&rec_t, &x, 2, &ws, &gdc, 4);

        let staged_n = rec_n.take();
        let staged_t = rec_t.take();
        assert_eq!(staged_n[0], staged_t[0],
                   "case {case}: layer-0 staging must not depend on engine");
        diverged |= out_n != out_t;
    }
    // multi-tile 4-bit outputs are expected to diverge on at least some
    // inputs — that divergence is the modeled physics, not a staging
    // difference
    assert!(diverged, "multi-tile 4-bit execution never diverged from native");
}

/// Regression: the single-tile unity-GDC analog-equals-native guarantee
/// survives the executor refactor at overridden bitwidths too (the knob
/// `InferOpts::adc_bits` rides).
#[test]
fn single_tile_unity_gdc_matches_native_at_every_bitwidth() {
    let meta = meta3();
    let exec = LayerExecutor::new(meta.clone(), 2);
    let analog = TileGridEngine::new(&meta, ArrayGeom::AON);
    let mut rng = Rng::new(0xCAFE);
    let (x, ws) = random_model(&mut rng, 3);
    let gdc = analognets::pcm::gdc::unity(3);
    for bits in [4u32, 6, 8, 12] {
        let out_n = exec.forward(&NativeGemmEngine::default(), &x, 3, &ws,
                                 &gdc, bits);
        let out_a = exec.forward(&analog, &x, 3, &ws, &gdc, bits);
        assert_eq!(out_n, out_a, "bitwidth {bits}");
    }
}

/// The blocked-GEMM tentpole must not perturb the staged-input contract:
/// a `NativeGemmEngine` opted into an explicit scheme — even a k-split
/// one, whose *outputs* regroup f32 sums — observes staged inputs bit-
/// identical to the default engine's and to the tile-faithful engine's
/// at every layer. Staging happens before any engine touches data, and a
/// k-split first layer cannot leak into later staged inputs unseen: the
/// comparison below is per-layer against the default engine's own run.
#[test]
fn explicit_scheme_engine_observes_bit_identical_staged_inputs() {
    let meta = meta3();
    let exec = LayerExecutor::new(meta.clone(), 2);
    let default_engine = NativeGemmEngine::default();
    let pinned = NativeGemmEngine::with_scheme(
        TilingScheme::new(32, usize::MAX, 32));
    let split = NativeGemmEngine::with_scheme(TilingScheme::new(64, 8, 64));

    let mut rng = Rng::new(0xA11A);
    let gdc = analognets::pcm::gdc::unity(3);
    for case in 0..6 {
        let batch = 1 + case % 3;
        let (x, ws) = random_model(&mut rng, batch);

        let rec_d = Recording::over(&default_engine);
        let out_d = exec.forward(&rec_d, &x, batch, &ws, &gdc, 8);
        let rec_p = Recording::over(&pinned);
        let out_p = exec.forward(&rec_p, &x, batch, &ws, &gdc, 8);
        let rec_s = Recording::over(&split);
        let _out_s = exec.forward(&rec_s, &x, batch, &ws, &gdc, 8);

        let staged_d = rec_d.take();
        let staged_p = rec_p.take();
        let staged_s = rec_s.take();
        assert_eq!(staged_d.len(), 3);
        // single-k-block pin: outputs (and hence all staging) bit-identical
        assert_eq!(staged_d, staged_p,
                   "case {case}: pinned single-k staging diverged");
        assert_eq!(out_d, out_p, "case {case}: pinned single-k logits");
        // k-split: the *first* staged input precedes any engine work and
        // must still be bit-identical; later layers see the (bounded)
        // k-split outputs, so only layer 0 is pinned here
        assert_eq!(staged_d[0], staged_s[0],
                   "case {case}: layer-0 staging must not depend on scheme");
    }
}
