//! Property tests on the PCM substrate (hand-rolled: seeded generators +
//! invariant assertions over many random cases — proptest is not vendored).

use analognets::pcm::{device, gdc, PcmParams, ProgrammedWeights};
use analognets::util::rng::Rng;

fn random_weights(rng: &mut Rng, n: usize, scale: f64) -> Vec<f32> {
    (0..n).map(|_| rng.gauss(0.0, scale) as f32).collect()
}

#[test]
fn prop_conductances_always_physical() {
    // over many random layers: conductances stay in [0, ~1.2] and reads
    // are finite, for any time in [25s, 10y]
    let mut rng = Rng::new(1001);
    for case in 0..25 {
        let rows = 1 + rng.below(40);
        let cols = 1 + rng.below(40);
        let scale = 0.05 + 0.3 * rng.uniform();
        let w = random_weights(&mut rng, rows * cols, scale);
        let p = PcmParams::default();
        let prog = ProgrammedWeights::program(&w, rows, cols, 0.0, &p, &mut rng);
        for g in prog.gp_pos.iter().chain(prog.gp_neg.iter()) {
            assert!(*g >= 0.0 && *g < 1.3, "case {case}: g={g}");
        }
        let t = 25.0 * 10f64.powf(rng.uniform() * 7.0);
        let r = prog.read_weights(t, &p, &mut rng);
        assert!(r.iter().all(|x| x.is_finite()), "case {case}");
    }
}

#[test]
fn prop_drift_error_monotone_in_time() {
    // average |error| grows (weakly) along 25s -> 1d -> 1y for any layer
    let mut rng = Rng::new(1002);
    for case in 0..10 {
        let w = random_weights(&mut rng, 4096, 0.2);
        let p = PcmParams::default();
        let prog = ProgrammedWeights::program(&w, 64, 64, 0.0, &p, &mut rng);
        let mut errs = Vec::new();
        for t in [25.0, 86_400.0, 31_536_000.0] {
            // average over a few reads to suppress 1/f sampling noise
            let mut e = 0.0;
            for _ in 0..3 {
                let r = prog.read_weights(t, &p, &mut rng);
                e += w.iter().zip(&r)
                    .map(|(a, b)| (a - b).abs() as f64)
                    .sum::<f64>();
            }
            errs.push(e);
        }
        assert!(errs[1] > errs[0] * 0.95 && errs[2] > errs[1] * 0.95,
                "case {case}: {errs:?}");
    }
}

#[test]
fn prop_gdc_alpha_bounds() {
    // GDC alpha ~1 at t_c and within [1, 2] out to 10 years for default nu
    let mut rng = Rng::new(1003);
    for _ in 0..10 {
        let scale = 0.1 + rng.uniform();
        let w = random_weights(&mut rng, 2048, scale);
        let p = PcmParams::default();
        let prog = ProgrammedWeights::program(&w, 32, 64, 0.0, &p, &mut rng);
        let a0 = gdc::alpha(&prog, 25.0);
        assert!((a0 - 1.0).abs() < 0.1, "a0={a0}");
        let a10y = gdc::alpha(&prog, 3.15e8);
        assert!(a10y >= a0 * 0.99 && a10y < 2.5, "a10y={a10y}");
    }
}

#[test]
fn prop_gdc_reduces_weight_error_under_drift() {
    // compensated reads are closer to the target weights than raw reads
    let mut rng = Rng::new(1004);
    for case in 0..10 {
        let w = random_weights(&mut rng, 8192, 0.2);
        let p = PcmParams { read_noise: false, ..Default::default() };
        let prog = ProgrammedWeights::program(&w, 128, 64, 0.0, &p, &mut rng);
        let t = 2_592_000.0; // 1 month
        let r = prog.read_weights(t, &p, &mut rng);
        let a = gdc::alpha(&prog, t) as f64;
        let err_raw: f64 = w.iter().zip(&r)
            .map(|(x, y)| ((x - y) as f64).powi(2)).sum();
        let err_gdc: f64 = w.iter().zip(&r)
            .map(|(x, y)| (*x as f64 - a * *y as f64).powi(2)).sum();
        assert!(err_gdc < err_raw, "case {case}: {err_gdc} !< {err_raw}");
    }
}

#[test]
fn prop_sigma_formulas_match_reference_constants() {
    // anchor values cross-checked with python/tests/test_pcm_consistency.py
    assert!((device::sigma_prog(0.0) - 0.01054).abs() < 1e-4);
    assert!((device::q_factor(0.04) - 0.0088).abs() < 1e-4); // 1uS device
    let f = device::drift_factor(86_400.0, 0.031);
    assert!((f - (86_400.0f64 / 25.0).powf(-0.031)).abs() < 1e-12);
}

#[test]
fn prop_programming_deterministic_per_seed() {
    let w = random_weights(&mut Rng::new(7), 512, 0.2);
    let p = PcmParams::default();
    let a = ProgrammedWeights::program(&w, 16, 32, 0.0, &p, &mut Rng::new(99));
    let b = ProgrammedWeights::program(&w, 16, 32, 0.0, &p, &mut Rng::new(99));
    assert_eq!(a.gp_pos, b.gp_pos);
    assert_eq!(a.nu_neg, b.nu_neg);
}
