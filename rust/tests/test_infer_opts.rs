//! Per-request inference options end-to-end: one hermetic coordinator
//! session (synthetic artifact bundle, native backend) serving concurrent
//! requests with *different* `InferOpts` — two distinct device ages and a
//! 4-bit ADC override — and every response reflecting its own options.
//!
//! This is the acceptance test for the per-request options redesign: the
//! pre-options API froze one `ServeConfig::drift_time` and one bitwidth
//! per coordinator; here a single session serves a fresh array, a
//! year-old array, and a 4-bit Table-2-style request side by side, with
//! option-incompatible requests drained into separate launches
//! (`batcher::group_fifo`).

use std::time::Duration;

use analognets::backend::InferOpts;
use analognets::coordinator::{Coordinator, ServeConfig};
use analognets::datasets::synth::{self, SynthSpec};
use analognets::pcm::{T_1Y, T_C_SECONDS};

/// Coordinator over an analog synthetic bundle with a frozen drift clock
/// (time_scale 0), so option-less requests always serve at exactly t_c.
fn start_coord(tag: &str, max_wait_ms: u64)
               -> (Coordinator, std::path::PathBuf, usize) {
    let spec = SynthSpec::tiny(tag);
    let dir = synth::write_bundle_tmp(tag, &spec).unwrap();
    let feat = spec.feat_len();
    let mut cfg = ServeConfig::new(&spec.vid, 8);
    cfg.artifacts_dir = dir.clone();
    cfg.max_wait = Duration::from_millis(max_wait_ms);
    cfg.time_scale = 0.0;
    cfg.seed = 99;
    (Coordinator::start(cfg).unwrap(), dir, feat)
}

#[test]
fn one_session_serves_mixed_drift_times_and_adc_bits() {
    let (coord, dir, feat) = start_coord("opts_mixed", 250);
    let features = vec![0.9f32; feat];

    // submit four option flavors inside one batching window: the drain
    // must split them into option-homogeneous launches
    let rx_fresh = coord
        .submit_with(features.clone(),
                     InferOpts::default().with_t_drift(T_C_SECONDS))
        .unwrap();
    let rx_aged = coord
        .submit_with(features.clone(), InferOpts::default().with_t_drift(T_1Y))
        .unwrap();
    let rx_4bit = coord
        .submit_with(features.clone(), InferOpts::default().with_adc_bits(4))
        .unwrap();
    let rx_default = coord.submit(features.clone()).unwrap();

    let fresh = rx_fresh.recv().unwrap();
    let aged = rx_aged.recv().unwrap();
    let coarse = rx_4bit.recv().unwrap();
    let default = rx_default.recv().unwrap();

    // every response echoes the options it was actually served under
    assert_eq!(fresh.sim_age_s, T_C_SECONDS, "explicit fresh age");
    assert_eq!(fresh.adc_bits, 8);
    assert_eq!(aged.sim_age_s, T_1Y, "explicit year-old age");
    assert_eq!(aged.adc_bits, 8);
    assert_eq!(coarse.sim_age_s, T_C_SECONDS,
               "no t_drift: the (frozen) serving clock age");
    assert_eq!(coarse.adc_bits, 4, "per-request 4-bit override");
    assert_eq!(default.sim_age_s, T_C_SECONDS);
    assert_eq!(default.adc_bits, 8, "default options keep backend bits");

    // ... and the options change the numbers, not just the labels: a year
    // of drift moves the conductances, and 4-bit conversion is far
    // coarser than 8-bit (inputs at 0.9 quantize to different DAC codes)
    assert_ne!(fresh.logits, aged.logits,
               "a year of drift must change the served logits");
    assert_ne!(coarse.logits, default.logits,
               "the 4-bit request must quantize differently");
    for r in [&fresh, &aged, &coarse, &default] {
        assert_eq!(r.logits.len(), 2);
        assert!(r.logits.iter().all(|l| l.is_finite()));
    }

    let m = coord.metrics.summary();
    assert_eq!(m.completed, 4);
    // four requests, three distinct option groups: at least 3 launches
    // even when all four land in one batching window, and never any
    // padding on the dynamic plan
    assert!(m.launches >= 3, "option groups must not share launches: {m}");
    assert_eq!(m.padded_slots, 0, "{m}");
    coord.stop().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn same_opts_requests_still_batch_together() {
    let (coord, dir, feat) = start_coord("opts_same", 300);
    let opts = InferOpts::default().with_t_drift(86_400.0).with_adc_bits(6);
    let rxs: Vec<_> = (0..6)
        .map(|i| {
            let mut f = vec![0.5f32; feat];
            f[0] += 0.01 * i as f32;
            coord.submit_with(f, opts).unwrap()
        })
        .collect();
    for rx in rxs {
        let r = rx.recv().unwrap();
        assert_eq!(r.sim_age_s, 86_400.0);
        assert_eq!(r.adc_bits, 6);
    }
    let m = coord.metrics.summary();
    assert_eq!(m.completed, 6);
    // identical options are launch-compatible: the six submits land in a
    // tight loop (microseconds) against a 300 ms batching window, so if
    // grouping ever split same-key requests, launches would hit 6 — a
    // correct batch_key keeps at least two requests in one launch
    assert!(m.launches < 6, "identical opts must share launches: {m}");
    assert!(m.mean_batch > 1.0, "{m}");
    assert_eq!(m.padded_slots, 0, "{m}");
    coord.stop().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn t_drift_below_t_c_clamps_in_response() {
    let (coord, dir, feat) = start_coord("opts_clamp", 50);
    let r = coord
        .infer_with(vec![0.4f32; feat], InferOpts::default().with_t_drift(0.0))
        .unwrap();
    assert_eq!(r.sim_age_s, T_C_SECONDS,
               "ages below t_c must clamp up to t_c");
    coord.stop().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
