//! Shared helpers for integration tests.

// each test binary compiles this module independently and may use only a
// subset of the helpers
#![allow(dead_code)]

use analognets::runtime::ArtifactStore;

/// Open the artifact store, or None when `make artifacts` has not run
/// (artifact-dependent tests skip themselves to keep `cargo test` usable
/// on a fresh checkout).
pub fn store_or_skip(test: &str) -> Option<ArtifactStore> {
    let dir = analognets::nn::manifest::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP {test}: no artifacts at {} (run `make artifacts`)",
                  dir.display());
        return None;
    }
    match ArtifactStore::open(&dir) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("SKIP {test}: {e}");
            None
        }
    }
}

/// First variant id that exists, preferring the given list.
pub fn pick_vid(store: &ArtifactStore, prefer: &[&str]) -> Option<String> {
    for p in prefer {
        if store.manifest.variants.iter().any(|v| v.vid == *p) {
            return Some(p.to_string());
        }
    }
    store.manifest.variants.first().map(|v| v.vid.clone())
}
