//! Integration over the AOT bridge: artifact-bundle consistency always;
//! exported HLO graphs vs the Rust-native simulator when built with
//! `--features pjrt`.  Artifact-dependent tests require `make artifacts`
//! and skip themselves otherwise.

mod common;

use analognets::eval::DeployedModel;
use analognets::nn::LayerKind;
use analognets::pcm::PcmParams;
use analognets::util::rng::Rng;

#[test]
fn artifact_bundle_consistent() {
    let Some(store) = common::store_or_skip("artifact_bundle_consistent") else {
        return;
    };
    for e in &store.manifest.variants {
        let meta = store.meta(&e.vid).unwrap();
        let ws = store.weights(&e.vid).unwrap();
        assert_eq!(ws.len(), meta.layers.len(), "{}", e.vid);
        for (t, lm) in ws.iter().zip(meta.layers.iter()) {
            assert_eq!(t.shape, lm.weight_shape, "{}/{}", e.vid, lm.name);
            // trained clipped weights must respect their own w_scale
            let mx = t.data.iter().fold(0f32, |m, x| m.max(x.abs()));
            assert!(mx <= lm.w_scale + 1e-5, "{}/{}: {mx} > {}", e.vid,
                    lm.name, lm.w_scale);
            assert!(lm.r_dac > 0.0 && lm.r_adc > 0.0);
            assert_eq!(lm.dig_scale.len(), lm.out_ch);
        }
        // every layer fits the AON array (the paper's no-split requirement)
        for lm in meta.layers.iter().filter(|l| l.analog) {
            assert!(lm.mapped_rows() <= 1024 && lm.mapped_cols() <= 512,
                    "{}/{} does not fit", e.vid, lm.name);
        }
    }
}

/// Cross-backend consistency through the unified API: the same drifted
/// weights must produce (near-)identical logits on `NativeBackend` and
/// `PjrtBackend`.  Only meaningful with a real xla crate, hence the
/// feature gate; skips when the artifacts or the PJRT runtime are absent.
#[cfg(feature = "pjrt")]
#[test]
fn native_and_pjrt_backends_agree() {
    use analognets::backend::{self, BackendKind, InferenceBackend};

    let Some(store) = common::store_or_skip("native_and_pjrt_agree") else {
        return;
    };
    let Some(vid) = common::pick_vid(&store, &["kws_full_e10_8b", "kws_base"])
    else {
        return;
    };
    let meta = store.meta(&vid).unwrap();
    let bits = meta.trained_adc_bits.unwrap_or(8);
    let batch = 128;
    if meta.hlo_for(bits, batch).is_none() {
        eprintln!("SKIP: no {batch}-batch graph for {vid}");
        return;
    }
    let pjrt = backend::create(BackendKind::Pjrt, &store, &vid, bits).unwrap();
    if let Err(e) = pjrt.prepare(batch) {
        eprintln!("SKIP: PJRT unavailable ({e})");
        return;
    }
    let native = backend::create(BackendKind::Native, &store, &vid, bits).unwrap();
    let ds = store.dataset("kws").unwrap();

    // ideal PCM (no noise): both backends see identical weights
    let params = PcmParams::ideal();
    let mut rng = Rng::new(42);
    let dep = DeployedModel::program(&store, &vid, &params, &mut rng).unwrap();
    let (ws, alphas) = dep.read_at(25.0, &params, &mut rng, true);

    let xb = ds.padded_batch(0, batch);
    let opts = analognets::backend::InferOpts::default();
    let hlo_logits = pjrt.run_batch(&xb, batch, &ws, &alphas, &opts).unwrap();
    let native_logits =
        native.run_batch(&xb, batch, &ws, &alphas, &opts).unwrap();

    assert_eq!(hlo_logits.len(), native_logits.len());
    // two fp32 implementations of the same quantized graph: identical
    // argmax on virtually all rows, logits close
    let classes = meta.num_classes;
    let pred_h = analognets::util::logits::predictions(&hlo_logits, classes);
    let pred_n = analognets::util::logits::predictions(&native_logits, classes);
    let agree = pred_h.iter().zip(&pred_n).filter(|(a, b)| a == b).count();
    assert!(agree >= batch * 98 / 100, "argmax agreement {agree}/{batch}");
    let mut big = 0;
    for (a, b) in hlo_logits.iter().zip(&native_logits) {
        if (a - b).abs() > 0.05 * (1.0 + a.abs().max(b.abs())) {
            big += 1;
        }
    }
    assert!(big < hlo_logits.len() / 50,
            "{big}/{} logit mismatches", hlo_logits.len());
}

#[test]
fn dw_expansion_matches_meta_graph_shape() {
    let Some(store) = common::store_or_skip("dw_expansion_graph_shape") else {
        return;
    };
    let Some(vid) = common::pick_vid(&store, &["micro_noise_e10"]) else {
        return;
    };
    if !vid.contains("micro") {
        eprintln!("SKIP: no micronet artifacts");
        return;
    }
    let meta = store.meta(&vid).unwrap();
    let params = PcmParams::ideal();
    let mut rng = Rng::new(3);
    let dep = DeployedModel::program(&store, &vid, &params, &mut rng).unwrap();
    let (ws, _) = dep.read_at(25.0, &params, &mut rng, false);
    for (t, lm) in ws.iter().zip(meta.layers.iter()) {
        assert_eq!(t.shape, lm.graph_weight_shape, "{}", lm.name);
        if lm.kind == LayerKind::Dw3x3 && lm.analog {
            // dense expansion: exactly 9*C non-zeros on the tap diagonals
            let c = lm.in_ch;
            let nz = t.data.iter().filter(|x| x.abs() > 0.0).count();
            assert!(nz <= 9 * c);
        }
    }
}

/// Runs on whichever backend `EvalOpts::backend` defaults to (native), so
/// this is exercised in hermetic builds too — it only needs the artifact
/// bundle's weights + datasets, not the HLO graphs.
#[test]
fn drift_degrades_and_gdc_helps_end_to_end() {
    let Some(store) = common::store_or_skip("drift_degrades_e2e") else {
        return;
    };
    let Some(vid) = common::pick_vid(&store, &["kws_full_e10_8b"]) else {
        return;
    };
    let meta = store.meta(&vid).unwrap();
    let bits = meta.trained_adc_bits.unwrap_or(8);
    let opts = analognets::eval::EvalOpts {
        bits,
        runs: 2,
        max_samples: 128,
        ..Default::default()
    };
    let times = [25.0, 31_536_000.0];
    let accs = analognets::eval::drift_accuracy(&store, &vid, &times, &opts)
        .unwrap();
    let fresh: f64 = accs[0].iter().sum::<f64>() / accs[0].len() as f64;
    let aged: f64 = accs[1].iter().sum::<f64>() / accs[1].len() as f64;
    assert!(fresh > 0.5, "fresh accuracy collapsed: {fresh}");
    assert!(aged <= fresh + 0.02, "drift did not degrade: {fresh} -> {aged}");

    let no_gdc = analognets::eval::drift_accuracy(
        &store, &vid, &[31_536_000.0],
        &analognets::eval::EvalOpts { use_gdc: false, ..opts }).unwrap();
    let aged_no_gdc: f64 =
        no_gdc[0].iter().sum::<f64>() / no_gdc[0].len() as f64;
    assert!(aged_no_gdc <= aged + 0.05,
            "GDC should not hurt: {aged_no_gdc} vs {aged}");
}
