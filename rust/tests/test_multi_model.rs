//! Multi-model router integration tests, hermetic via a two-variant
//! synthetic bundle: the KWS-wake -> VWW-confirm pipeline, response
//! integrity under mixed concurrent traffic (the model-extended batch key
//! must never mix models in one launch), per-model admission control, and
//! the weighted round-robin fairness guarantee — a flooded shard cannot
//! starve the quiet model.
//!
//! Both shards serve identity models (logits bit-identical to the
//! submitted features) with *different* feature lengths, so any
//! cross-model routing or batching mixup corrupts a payload or its length
//! and fails an exact assertion — no statistical accuracy arguments.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use analognets::backend::InferOpts;
use analognets::coordinator::{MultiCoordinator, ServeConfig, ShardConfig};
use analognets::datasets::synth::{self, SynthSpec};

const KWS: &str = "wake_kws";
const VWW: &str = "confirm_vww";
const KWS_CLASSES: usize = 3;
const VWW_CLASSES: usize = 5;

/// Two identity shards in one bundle dir: a 3-feature "kws" wake model
/// (the primary) and a 5-feature "vww" confirm model.
fn shard_pair(tag: &str, max_wait_ms: u64, kws_depth: usize)
              -> (Vec<ShardConfig>, std::path::PathBuf) {
    let kws = SynthSpec::identity_dense(KWS, KWS_CLASSES);
    let mut vww = SynthSpec::identity_dense(VWW, VWW_CLASSES);
    vww.task = "vww".to_string();
    vww.seed = 11;
    let dir = synth::write_multi_bundle_tmp(tag, &[kws, vww]).unwrap();
    let mk = |vid: &str| {
        let mut cfg = ServeConfig::new(vid, 8);
        cfg.artifacts_dir = dir.clone();
        cfg.max_batch = 8;
        cfg.max_wait = Duration::from_millis(max_wait_ms);
        ShardConfig::new(vid, cfg)
    };
    let mut sk = mk(KWS);
    sk.queue_depth = kws_depth;
    (vec![sk, mk(VWW)], dir)
}

fn kws_x(i: usize) -> Vec<f32> {
    (0..KWS_CLASSES).map(|j| i as f32 + 0.125 * j as f32).collect()
}

fn vww_x(i: usize) -> Vec<f32> {
    (0..VWW_CLASSES).map(|j| i as f32 + 0.25 * j as f32).collect()
}

#[test]
fn kws_wake_then_vww_confirm_pipeline() {
    let (shards, dir) = shard_pair("pipeline", 5, 0);
    let mc = MultiCoordinator::start(shards).unwrap();
    assert_eq!(mc.primary().model_id, KWS, "first configured shard is primary");
    assert_eq!(mc.models().len(), 2);
    assert_eq!(mc.models()[0].feat_len, KWS_CLASSES);
    assert_eq!(mc.models()[1].feat_len, VWW_CLASSES);

    // always-on wake stage: the tiny KWS model screens the frame
    let wake = mc.infer(KWS, kws_x(4), InferOpts::default()).unwrap();
    assert_eq!(wake.logits, kws_x(4));
    let woke = wake.pred as usize == KWS_CLASSES - 1;
    assert!(woke, "monotone features argmax to the last channel");
    // wake fired -> the confirm stage routes to the VWW model, same router
    let confirm = mc.infer(VWW, vww_x(9), InferOpts::default()).unwrap();
    assert_eq!(confirm.logits, vww_x(9));
    assert_eq!(confirm.pred as usize, VWW_CLASSES - 1);

    // each shard keeps its own canary health verdict
    assert!(!mc.probe_health(KWS).unwrap().degraded);
    assert!(!mc.probe_health(VWW).unwrap().degraded);

    let m = mc.metrics.summary();
    assert_eq!(m.completed, 2);
    assert_eq!(m.per_model[KWS].completed, 1, "{m}");
    assert_eq!(m.per_model[VWW].completed, 1, "{m}");
    assert!(m.per_model[VWW].modeled_uj_per_inf > 0.0, "{m}");
    mc.stop().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn mixed_traffic_responses_never_cross_models() {
    let (shards, dir) = shard_pair("mixed", 1, 0);
    let mc = Arc::new(MultiCoordinator::start(shards).unwrap());
    let mut handles = Vec::new();
    for c in 0..4usize {
        let mc = mc.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..25usize {
                let id = c * 1000 + i;
                // alternate models within and across clients so both
                // shards' staging queues are populated in the same windows
                if (c + i) % 2 == 0 {
                    let r = mc.infer(KWS, kws_x(id), InferOpts::default())
                        .unwrap();
                    assert_eq!(r.logits, kws_x(id),
                               "client {c} request {i} got foreign logits");
                } else {
                    let r = mc.infer(VWW, vww_x(id), InferOpts::default())
                        .unwrap();
                    assert_eq!(r.logits, vww_x(id),
                               "client {c} request {i} got foreign logits");
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = mc.metrics.summary();
    assert_eq!(m.completed, 100);
    assert_eq!(m.submit_rejects, 0, "{m}");
    assert_eq!(m.per_model[KWS].completed, 50, "{m}");
    assert_eq!(m.per_model[VWW].completed, 50, "{m}");
    // a launch that mixed models would already have failed the exact
    // logits assertions above (the feature lengths differ); the per-model
    // launch ledgers must also partition the global launch count exactly
    assert_eq!(m.per_model[KWS].launches + m.per_model[VWW].launches,
               m.launches, "{m}");
    let mc = Arc::try_unwrap(mc).ok().expect("clients joined");
    mc.stop().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn flooded_kws_shard_cannot_starve_quiet_vww() {
    // tiny admission bound on the hot shard: the flood must reject (not
    // queue without limit), and the round-robin drain must keep serving
    // the quiet model from its own lane
    let (shards, dir) = shard_pair("starve", 1, 8);
    let mc = Arc::new(MultiCoordinator::start(shards).unwrap());
    assert_eq!(mc.models()[0].queue_depth, 8);

    let stop = Arc::new(AtomicBool::new(false));
    let mut floods = Vec::new();
    for _ in 0..2 {
        let mc = mc.clone();
        let stop = stop.clone();
        floods.push(std::thread::spawn(move || {
            // open-loop flood far beyond the shard's admission bound;
            // rejects are the expected outcome. At most 64 accepted
            // requests stay outstanding so the flood never blocks on the
            // drain, yet memory stays bounded.
            let mut rxs = std::collections::VecDeque::new();
            let mut i = 0usize;
            while !stop.load(Ordering::Relaxed) {
                if let Ok(rx) = mc.submit(KWS, kws_x(i), InferOpts::default())
                {
                    rxs.push_back(rx);
                }
                if rxs.len() > 64 {
                    let _ = rxs.pop_front().unwrap()
                        .recv_timeout(Duration::from_secs(10));
                }
                i += 1;
            }
            for rx in rxs {
                let _ = rx.recv_timeout(Duration::from_secs(10));
            }
        }));
    }

    // the quiet model: a closed-loop client that must keep being served
    // with ms-scale latency while the other shard is saturated
    for i in 0..25usize {
        let rx = mc.submit(VWW, vww_x(i), InferOpts::default())
            .expect("quiet model must never reject: its lane is its own");
        let r = rx.recv_timeout(Duration::from_secs(10))
            .expect("quiet model starved: confirm request never answered");
        assert_eq!(r.logits, vww_x(i), "request {i}");
    }
    stop.store(true, Ordering::Relaxed);
    for h in floods {
        h.join().unwrap();
    }

    let m = mc.metrics.summary();
    let kws = &m.per_model[KWS];
    let vww = &m.per_model[VWW];
    assert_eq!(vww.completed, 25, "{m}");
    assert_eq!(vww.submit_rejects, 0, "admission is per model: {m}");
    assert!(kws.submit_rejects > 0,
            "the flood never hit the admission bound: {m}");
    assert!(kws.completed > 0, "rejecting everything is not fairness: {m}");
    // generous CI bound: weighted round-robin keeps the quiet model at
    // most one drain pass away, so its tail latency stays far below the
    // starvation regime even under scheduler jitter
    assert!(vww.p99_us < 5_000_000.0, "quiet-model p99 {}us: {m}",
            vww.p99_us);
    let mc = Arc::try_unwrap(mc).ok().expect("floods joined");
    mc.stop().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn unknown_models_and_bad_lengths_reject_before_the_worker() {
    let (shards, dir) = shard_pair("rejects", 2, 0);
    // duplicate ids are a start-time configuration error
    let dup = vec![shards[0].clone(), shards[0].clone()];
    let err = MultiCoordinator::start(dup).unwrap_err();
    assert!(format!("{err}").contains("duplicate model id"), "{err}");

    let mc = MultiCoordinator::start(shards).unwrap();
    let err =
        mc.submit("nope", kws_x(0), InferOpts::default()).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("unknown model `nope`"), "{msg}");
    assert!(msg.contains(KWS) && msg.contains(VWW),
            "the error must list the served models: {msg}");
    // wrong per-model length: a vww-sized payload on the kws shard
    let err = mc.submit(KWS, vww_x(0), InferOpts::default()).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("bad feature length"), "{msg}");

    let m = mc.metrics.summary();
    assert_eq!(m.submit_rejects, 2, "{m}");
    assert_eq!(m.per_model[KWS].submit_rejects, 1, "{m}");
    // the unknown-model reject belongs to no shard, and an untouched
    // model has no per-model entry at all (single-model ledgers stay
    // empty the same way)
    assert!(!m.per_model.contains_key(VWW), "{m}");
    assert_eq!(m.completed, 0);
    mc.stop().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
